"""Paper-claims benchmarks — one function per paper table/figure.

Fig. 7a  speedup: SALO cycle model vs dense-on-SALO, PLUS measured
         wall-clock of SALO blockwise vs dense attention on this host CPU
         (the honest locally-measurable analog of the paper's CPU/GPU rows).
§2.1     quadratic scaling: dense attention latency vs n (the paper's
         "145.70ms at n=8192 vs 9.20ms at n=2048 ~ 16x" observation),
         and SALO's linear scaling on the same sweep.
§6.3     Sanger comparison: PE utilization of hybrid patterns (>75% claim)
         vs Sanger's irregular-sparsity 55-75% band; 1.33x speedup model.
Table 2  workload sparsities (asserted in tests; reported here).
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.salo_cycle_model import (PAPER_SPEEDUP_CPU,
                                         PAPER_SPEEDUP_GPU,
                                         attention_cycles,
                                         dense_attention_cycles)
from repro.core import patterns as P
from repro.core.blockwise import blockwise_attention

WORKLOADS = {
    "longformer": dict(pattern=P.longformer(512, n_global=1), n=4096,
                       d_head=64, n_heads=12),
    "vil-stage1": dict(pattern=P.vil((56, 56), (15, 15), 1), n=1 + 56 * 56,
                       d_head=64, n_heads=3),
    "vil-stage2": dict(pattern=P.vil((28, 28), (15, 15), 1), n=1 + 28 * 28,
                       d_head=64, n_heads=6),
}


def _time(fn: Callable, *args, reps=3) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def fig7_speedup(rows):
    """Fig. 7a analog. Cycle-model speedup = dense cycles / SALO cycles;
    measured = dense-masked attention vs SALO blockwise on host CPU."""
    rng = np.random.default_rng(0)
    for name, w in WORKLOADS.items():
        pat, n, d, h = w["pattern"], w["n"], w["d_head"], w["n_heads"]
        cyc = attention_cycles(pat, n, d, h)
        dense_cyc = dense_attention_cycles(n, d, h)
        model_speedup = dense_cyc["cycles"] / cyc["cycles"]

        B = h  # fold heads
        q, k, v = (jnp.asarray(rng.normal(size=(B, n, d)), jnp.float32)
                   for _ in range(3))
        t_sparse = _time(jax.jit(lambda a, b, c: blockwise_attention(
            a, b, c, pat, block_q=128, block_k=128)), q, k, v)
        t_dense = _time(jax.jit(lambda a, b, c: blockwise_attention(
            a, b, c, P.full(), block_q=128, block_k=128)), q, k, v)
        rows.append((f"fig7/{name}/salo_cycle_model_latency",
                     cyc["latency_s"] * 1e6,
                     f"util={cyc['utilization']:.3f}"))
        rows.append((f"fig7/{name}/speedup_vs_dense_cyclemodel",
                     model_speedup,
                     f"paper_gpu={PAPER_SPEEDUP_GPU[name]}x_cpu="
                     f"{PAPER_SPEEDUP_CPU[name]}x"))
        rows.append((f"fig7/{name}/speedup_vs_dense_measured_cpu",
                     t_dense / t_sparse,
                     f"dense={t_dense*1e3:.1f}ms_sparse={t_sparse*1e3:.1f}ms"))


def sec21_quadratic_scaling(rows):
    """§2.1: dense grows ~quadratically with n; SALO grows linearly."""
    rng = np.random.default_rng(0)
    d, w_ = 64, 256
    times_dense, times_salo, ns = [], [], [1024, 2048, 4096]
    for n in ns:
        q, k, v = (jnp.asarray(rng.normal(size=(2, n, d)), jnp.float32)
                   for _ in range(3))
        pat = P.causal_sliding_window(w_)
        times_salo.append(_time(jax.jit(
            lambda a, b, c, p=pat: blockwise_attention(a, b, c, p)), q, k, v))
        times_dense.append(_time(jax.jit(
            lambda a, b, c: blockwise_attention(a, b, c, P.full())), q, k, v))
    g_dense = times_dense[-1] / times_dense[0]
    g_salo = times_salo[-1] / times_salo[0]
    rows.append(("sec21/dense_growth_4x_n", g_dense,
                 "expect ~16 (quadratic)"))
    rows.append(("sec21/salo_growth_4x_n", g_salo, "expect ~4 (linear)"))


def sec63_sanger_comparison(rows):
    """§6.3: utilization of hybrid patterns (SALO >75%) vs Sanger's 55-75%
    on irregular sparsity; same-PE-count speedup = util ratio + Sanger's
    quadratic low-precision predict pass."""
    for name, w in WORKLOADS.items():
        cyc = attention_cycles(w["pattern"], w["n"], w["d_head"],
                               w["n_heads"])
        # The paper computes sparsity with the interior approximation
        # (window^2/grid^2, no edge clipping — see Table 2); normalizing our
        # exact-mask utilization by the same convention recovers its basis.
        exact_s = w["pattern"].sparsity(w["n"])
        if w["pattern"].is_2d:
            wh, ww = w["pattern"].window2d
            h_, w_ = w["pattern"].grid2d
            interior_s = wh * ww / (h_ * w_)
        else:
            interior_s = exact_s
        util_interior = cyc["utilization"] * interior_s / exact_s
        rows.append((f"sec63/{name}/pe_utilization", cyc["utilization"],
                     f"interior-convention={util_interior:.3f}; paper "
                     "claims >0.75 (interior); Sanger 0.55-0.75"))
    # Sanger (§6.3): same PE count (64x16 = 1024), same sparsity, but (a)
    # irregular patterns -> 55-75% utilization (use the 0.65 midpoint), and
    # (b) a low-precision quadratic predict pass for the mask (4-bit QK^T,
    # modeled at 4x MAC throughput) that SALO does not need.
    w = WORKLOADS["longformer"]
    salo = attention_cycles(w["pattern"], w["n"], w["d_head"], w["n_heads"])
    n_pe = 32 * 32
    sanger_util = 0.65
    sanger_main = salo["useful_macs"] / (n_pe * sanger_util)
    predict = w["n"] ** 2 * w["d_head"] * w["n_heads"] / (n_pe * 4)
    rows.append(("sec63/salo_vs_sanger_speedup",
                 (sanger_main + predict) / salo["cycles"],
                 "paper claims 1.33x"))


def table3_quantization(rows):
    """Table 3 analog: int8(4-frac) QKV quantization error on the paper's
    workloads (accuracy deltas in the paper are within noise; here we report
    the attention-output error that drives them)."""
    from repro.core.quant import quantized_attention
    rng = np.random.default_rng(0)
    for name, w in WORKLOADS.items():
        pat, n, d = w["pattern"], w["n"], w["d_head"]
        q, k, v = (jnp.asarray(rng.normal(size=(1, 2, n, d)) * 0.7,
                               jnp.float32) for _ in range(3))
        ref = jax.jit(lambda a, b, c, p=pat: blockwise_attention(
            a.reshape(2, n, d), b.reshape(2, n, d), c.reshape(2, n, d), p)
        )(q, k, v)
        out = quantized_attention(q, k, v, pat, mode="fixed")
        err = float(jnp.sqrt(jnp.mean(
            (out.reshape(2, n, d) - ref) ** 2)))
        rel = err / float(jnp.sqrt(jnp.mean(ref ** 2)))
        rows.append((f"table3/{name}/quant_rel_rmse", rel,
                     "paper: accuracy within 0.14pp of fp32"))
