"""Static-soundness benchmark -> BENCH_verify.json.

Runs the repro.analysis gate (plan soundness prover + jaxpr effect lint +
code lint) over every registered config/pattern and reports the result as
benchmark rows, so ``python -m benchmarks.run`` gates
``verify/plans_sound == 1.0`` — every registered pattern's coverage,
adjoint, shard-exchange, never-drop and chunk-slice proofs must hold, the
traced entry points must be effect-clean, and the tree must be lint-clean.

Used by ``python -m benchmarks.run`` (section ``verify/``) and standalone:

  PYTHONPATH=src python -m benchmarks.verify_stats [--out BENCH_verify.json]
"""
from __future__ import annotations

import argparse
import json
import sys


def collect(measure: bool = True) -> dict:
    """The analysis gate's report. ``measure=False`` skips the (slow)
    serving-engine decode trace; the pure-numpy proofs always run."""
    from repro.analysis.lint import collect as lint_collect

    return lint_collect(engine=measure)


def verify_benchmark(rows, measure: bool = True,
                     out_path: str = "BENCH_verify.json") -> dict:
    """benchmarks.run section: report + write BENCH_verify.json."""
    data = collect(measure=measure)
    s = data["summary"]
    rows.append(("verify/plans_sound", s["plans_sound"],
                 "all_registered_patterns_proven_sound"))
    rows.append(("verify/targets_checked", float(s["targets_checked"]),
                 "plan+chunk+jaxpr+code_lint_targets"))
    rows.append(("verify/findings", float(s["findings"]),
                 "total_findings_all_passes"))
    with open(out_path, "w") as fh:
        json.dump(data, fh, indent=2, sort_keys=True)
    return data


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_verify.json")
    ap.add_argument("--quick", action="store_true",
                    help="skip the serving-engine decode trace")
    args = ap.parse_args()
    rows: list = []
    data = verify_benchmark(rows, measure=not args.quick,
                            out_path=args.out)
    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value:.6g},{derived}")
    if data["summary"]["errors"]:
        for f in data["findings"]:
            print(f"CHECK-FAILED: {f}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
