"""Benchmark harness: one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,value,derived`` CSV. Roofline tables (from the dry-run) are
produced by ``python -m benchmarks.roofline_report``; paper-claim benchmarks
run here on the host CPU + the SALO cycle model.
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the slow measured-speedup benchmarks")
    args = ap.parse_args()

    from benchmarks import (dist_stats, dynamic_stats, obs_stats,
                            paper_claims, plan_stats, serve_dist_stats,
                            serve_stats, verify_stats)

    rows = []
    # Static soundness: every registered pattern's plan/adjoint/exchange/
    # never-drop/chunk proofs + jaxpr effect lint + code lint (BENCH_verify)
    verify_stats.verify_benchmark(rows, measure=not args.quick)
    paper_claims.sec63_sanger_comparison(rows)
    paper_claims.table3_quantization(rows)
    # ExecutionPlan: fused single-launch vs per-band-launch (BENCH_plan.json)
    plan_stats.plan_benchmark(rows, measure=not args.quick)
    # Backward: fwd-plan dQ vs transposed-plan dK/dV vs dense (BENCH_bwd.json)
    plan_stats.bwd_benchmark(rows, measure=not args.quick)
    # Serving: continuous batching vs lockstep (BENCH_serve.json)
    serve_stats.serve_benchmark(rows, measure=not args.quick)
    # Sequence parallelism: halo bytes vs all-gather + parity (BENCH_dist)
    dist_stats.dist_benchmark(rows, measure=not args.quick)
    # Sequence-parallel serving: sharded slab + decode psum bytes + 8-shard
    # greedy parity (BENCH_serve_dist.json)
    serve_dist_stats.serve_dist_benchmark(rows, measure=not args.quick)
    # Observability: zero-cost-when-disabled contract + traced overhead +
    # lifecycle latency percentiles (BENCH_obs.json)
    obs_stats.obs_benchmark(rows, measure=not args.quick)
    # Runtime ExecutionPlans: full-keep parity, executed-tile ratio vs
    # dense, oracle recall, quality vs a bigger static plan (BENCH_dynamic)
    dynamic_stats.dynamic_benchmark(rows, measure=not args.quick)
    if not args.quick:
        paper_claims.fig7_speedup(rows)
        paper_claims.sec21_quadratic_scaling(rows)

    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value:.6g},{derived}")

    # quick invariant checks so `benchmarks.run` doubles as a regression gate
    d = {name: value for name, value, _ in rows}
    failures = []
    # static soundness: the analysis gate must prove every registered
    # pattern's tables sound — a 0.0 here names a real counterexample
    if d.get("verify/plans_sound") != 1.0:
        failures.append(("verify_plans_sound", d.get("verify/plans_sound"),
                         "== 1.0 (all registered patterns proven sound)"))
    for k, v in d.items():
        if k.endswith("pe_utilization") and v < 0.65:
            failures.append((k, v, ">=0.65 (exact-mask convention)"))
        if k.endswith("quant_rel_rmse") and v > 0.05:
            failures.append((k, v, "<=0.05"))
    if "sec63/salo_vs_sanger_speedup" in d and \
            not 1.0 < d["sec63/salo_vs_sanger_speedup"] < 2.5:
        failures.append(("sanger_speedup", d["sec63/salo_vs_sanger_speedup"],
                         "in (1, 2.5)"))
    for k, v in d.items():
        # multi-band workloads: the plan's dedup must be real, not cosmetic
        if k.startswith("plan/vil") and k.endswith("dedup_ratio") and v <= 1.0:
            failures.append((k, v, "> 1.0 (fused < sum of per-band walks)"))
        # backward: transposed walk must preserve the forward dedup — two-
        # sided, since a transpose that DROPS visits (ratio < 1) means
        # missing dK/dV contributions, not savings
        if k.startswith("bwd/") and k.endswith("transposed_ratio") \
                and abs(v - 1.0) > 0.1:
            failures.append((k, v, "in [0.9, 1.1] (transposed plan dedup)"))
        # flash-style residual reuse: custom VJP must need well under the
        # scan-autodiff's temp memory (measured 3.2-9.1x on these workloads)
        if k.startswith("bwd/") and k.endswith("bwd_mem_ratio") and v < 2.0:
            failures.append((k, v, ">= 2.0 (fused bwd temp memory win)"))
    # serving gates: chunked prefill must hit the launch contract EXACTLY
    # (ceil(P/chunk) fused launches per prompt, counted by the engine), the
    # continuous engine must be token-exact vs lockstep, and the paged slab
    # must beat the dense long-context cache by a wide margin
    if "serve/prefill_launch_ratio" in d and \
            abs(d["serve/prefill_launch_ratio"] - 1.0) > 1e-9:
        failures.append(("serve_prefill_launches",
                         d["serve/prefill_launch_ratio"],
                         "== 1.0 (counted == ceil(P/chunk))"))
    if "serve/greedy_parity" in d and d["serve/greedy_parity"] != 1.0:
        failures.append(("serve_greedy_parity", d["serve/greedy_parity"],
                         "== 1.0 (token-exact vs lockstep)"))
    if "serve/cache_bytes_ratio" in d and d["serve/cache_bytes_ratio"] < 10:
        failures.append(("serve_cache_bytes", d["serve/cache_bytes_ratio"],
                         ">= 10 (paged slab vs dense 32k cache)"))
    if "serve/decode_launch_reduction" in d and \
            d["serve/decode_launch_reduction"] <= 1.0:
        failures.append(("serve_decode_launches",
                         d["serve/decode_launch_reduction"],
                         "> 1.0 (ragged batching shares launches)"))
    # quantized serving: int8 slab must be close to 4x smaller than the
    # f32 compute-dtype slab (scales are the only overhead), the int8
    # engine greedy-exact vs fp on the smoke workload, threshold=-inf
    # token-identical to the machinery being off, page skipping must
    # actually engage (at parity with the dense-read int8 twin), and the
    # 8-shard int8+sparse engine must match its single-device twin
    if "serve/quant_slab_bytes_ratio" in d and \
            d["serve/quant_slab_bytes_ratio"] < 3.5:
        failures.append(("quant_slab_bytes", d["serve/quant_slab_bytes_ratio"],
                         ">= 3.5 (int8 slab vs f32 slab)"))
    for k in ("serve/quant_parity_vs_fp", "serve/quant_keepall_exact",
              "serve/quant_sparse_parity", "serve/quant_sharded_parity"):
        if k in d and d[k] != 1.0:
            failures.append((k, d[k], "== 1.0"))
    if "serve/quant_page_read_fraction" in d and \
            d["serve/quant_page_read_fraction"] >= 1.0:
        failures.append(("quant_page_reads",
                         d["serve/quant_page_read_fraction"],
                         "< 1.0 (stats-driven page skipping engages)"))
    # fault-tolerant serving: a killed-and-resumed run must emit tokens
    # identical to the uninterrupted engine (exactly-once), work lost per
    # crash bounded by the checkpoint interval, the page-pressure scenario
    # that previously raised 'page pool too small' must now complete via
    # preemption + re-prefill at token parity, and injected allocator
    # exhaustion must be recovered by the supervisor
    for k in ("serve/recovery_restore_parity",
              "serve/recovery_preempt_parity",
              "serve/recovery_exhaustion_recovered"):
        if k in d and d[k] != 1.0:
            failures.append((k, d[k], "== 1.0"))
    if "serve/recovery_max_step_loss" in d and \
            d["serve/recovery_max_step_loss"] > serve_stats.RECOVERY_CKPT_EVERY:
        failures.append(("serve_recovery_step_loss",
                         d["serve/recovery_max_step_loss"],
                         f"<= {serve_stats.RECOVERY_CKPT_EVERY} "
                         f"(work loss bounded by checkpoint interval)"))
    if "serve/recovery_preemptions" in d and \
            d["serve/recovery_preemptions"] <= 0:
        failures.append(("serve_recovery_preemptions",
                         d["serve/recovery_preemptions"],
                         "> 0 (preemption must engage)"))
    # fairness: only the low priority class may be preempted or miss its
    # armed deadline in the deterministic two-class scenario
    if "serve/fair_low_pri_preemptions" in d and \
            d["serve/fair_low_pri_preemptions"] <= 0:
        failures.append(("serve_fair_preemptions",
                         d["serve/fair_low_pri_preemptions"],
                         "> 0 (low class preempted)"))
    if "serve/fair_high_pri_miss_rate" in d and \
            d["serve/fair_high_pri_miss_rate"] != 0.0:
        failures.append(("serve_fair_high_pri_misses",
                         d["serve/fair_high_pri_miss_rate"],
                         "== 0 (high class never misses here)"))
    # observability: disabled instrumentation must add ZERO jitted operands
    # (jaxpr + launch-count identity) and full tracing at most 5% wall
    for k in ("obs/decode_jaxpr_identical", "obs/launch_counts_identical",
              "obs/token_parity", "obs/trace_lifecycle_complete"):
        if k in d and d[k] != 1.0:
            failures.append((k, d[k], "== 1.0"))
    if "obs/traced_overhead" in d and \
            d["obs/traced_overhead"] > obs_stats.OVERHEAD_GATE:
        failures.append(("obs_traced_overhead", d["obs/traced_overhead"],
                         f"<= {obs_stats.OVERHEAD_GATE} (tracing cost)"))
    # sequence parallelism: halo exchange must beat the all-gather ring on
    # EVERY workload (the (w+Bk)·d vs n·d claim), and the sharded engines
    # must be numerically identical to the single-device fused path
    for k, v in d.items():
        if k.startswith("dist/") and k.endswith("bytes_ratio") and v >= 1.0:
            failures.append((k, v, "< 1.0 (halo bytes < all-gather bytes)"))
    if "dist/parity" in d and d["dist/parity"] != 1.0:
        failures.append(("dist_parity", d["dist/parity"],
                         "== 1.0 (sharded fwd+bwd == single-device fused)"))
    # sequence-parallel serving: sharding must shrink each device's slab
    # AND the decode combine must beat all-gathering the KV view slices,
    # with the 8-shard engine token-exact vs the single-device engine
    for k, v in d.items():
        if k.startswith("serve_dist/") and k.endswith("bytes_ratio") \
                and v >= 1.0:
            failures.append((k, v, "< 1.0 (sharded serving bytes win)"))
    if "serve_dist/parity" in d and d["serve_dist/parity"] != 1.0:
        failures.append(("serve_dist_parity", d["serve_dist/parity"],
                         "== 1.0 (8-shard greedy == single-device)"))
    # runtime ExecutionPlans: full keep must reproduce the static walk,
    # the dynamic plan must execute < half the dense tiles, selection must
    # hit >= 0.9 oracle recall on both measured workloads, and it must
    # beat a bigger static plan on the content-routed workload
    for k, v in dynamic_stats.gates(rows):
        gate = {"dynamic/full_keep_parity": "== 1.0 (dynamic == static)",
                "dynamic/tile_ratio_vs_dense": "< 0.5 (executed tiles)",
                "dynamic/oracle_recall_structured": ">= 0.9",
                "dynamic/oracle_recall_random": ">= 0.9",
                "dynamic/quality_err_ratio_vs_static":
                    "<= 1.0 (beats bigger static plan)"}[k]
        failures.append((k, v, gate))
    if failures:
        for f in failures:
            print(f"CHECK-FAILED: {f}", file=sys.stderr)
        raise SystemExit(1)
    print("# all benchmark invariants hold")


if __name__ == "__main__":
    main()
