"""SALO cycle model — the paper's performance model (extends Sanger's),
§6.1 "we extend the cycle-accurate performance model from Sanger".

Models the 32x32 PE array at 1 GHz executing the 5-stage pipeline (paper
Fig. 6) over the data scheduler's tile passes:

  stage 1  Q.K^T   output-stationary systolic: d cycles + array fill/drain
  stage 2  exp     Softermax PWL: ~4 cycles
  stage 3  rowsum  horizontal accumulation: 32 + inverse latency
  stage 4  scale   1 cycle
  stage 5  S'V     weight-stationary: d cycles + drain
  (+ weighted-sum module merge per pass — paper §5.3, overlapped)

Passes = q-tiles x kv-tiles over the scheduled bands; global attention rides
the same passes on the extra PE row/column (no additional passes, paper
§5.2), which is why hybrid patterns keep utilization > 75% (§6.3).

Used by benchmarks/paper_claims.py to reproduce Fig. 7 speedups and the
Sanger comparison.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.patterns import HybridSparsePattern
from repro.core.scheduler import schedule


@dataclasses.dataclass(frozen=True)
class SALOHardware:
    rows: int = 32
    cols: int = 32
    freq_hz: float = 1e9
    fill: int = 32           # systolic fill/drain
    exp_cycles: int = 4
    inv_cycles: int = 8


def attention_cycles(pattern: HybridSparsePattern, n: int, d_head: int,
                     n_heads: int, hw: SALOHardware = SALOHardware()) -> dict:
    """Cycles for one attention layer on SALO (all heads, sequential).

    Key modeling point (paper §4.2 / Fig. 4): after data reordering the
    scheduler PACKS band segments back-to-back, so a query tile's KV passes
    cover the UNION width of all its bands (+ the diagonal shift of
    ``rows-1``), not one tile-walk per band. That packing is what keeps PE
    utilization > 75% on ViL's 15 narrow bands (§6.3)."""
    sched = schedule(pattern, n)
    nq_tiles = math.ceil(sched.n_work / hw.rows)
    union_width = sum(band.hi - band.lo + 1 for band in sched.bands)
    kv_tiles = math.ceil((union_width + hw.rows - 1) / hw.cols)
    passes = nq_tiles * kv_tiles
    per_pass = (d_head + hw.fill            # stage 1
                + hw.exp_cycles             # stage 2
                + hw.cols + hw.inv_cycles   # stage 3
                + 1                         # stage 4
                + d_head + hw.fill)         # stage 5
    total = passes * per_pass * n_heads
    useful_pairs = int(pattern.mask(n).sum())
    executed_pairs = passes * hw.rows * hw.cols
    return {
        "passes": passes * n_heads,
        "cycles": total,
        "latency_s": total / hw.freq_hz,
        "utilization": useful_pairs / max(executed_pairs, 1),
        # one MAC per (i, j) pair per d element, QK^T and S'V stages
        "useful_macs": useful_pairs * 2 * d_head * n_heads,
    }


def dense_attention_cycles(n: int, d_head: int, n_heads: int,
                           hw: SALOHardware = SALOHardware()) -> dict:
    """Same array, dense attention (the no-sparsity baseline)."""
    from repro.core.patterns import full
    return attention_cycles(full(), n, d_head, n_heads, hw)


# Paper-reported baselines (Fig. 7; latencies reconstructed from the
# paper's speedup ratios and our cycle model, used ONLY to present the
# Fig. 7 comparison — clearly marked as paper-reported in the output).
PAPER_SPEEDUP_GPU = {"longformer": 7.38, "vil-stage1": 20.10,
                     "vil-stage2": 25.51}
PAPER_SPEEDUP_CPU = {"longformer": 83.57, "vil-stage1": 83.12,
                     "vil-stage2": 101.31}
