"""Sequence-parallel continuous-serving benchmark -> BENCH_serve_dist.json.

Quantifies what sharding the paged slab over the "seq" mesh axis buys —
the 500k+-context serving regime where one chip's HBM caps the paged pool:

  * **per-shard slab bytes** — each device's slab pool under ``seq_shards=N``
    vs the whole pool replicated-per-device (what a single-device engine
    pins in HBM for the same traffic). The ratio approaches ``1/N`` (page-
    striping alignment padding is the only overhead), which is exactly the
    context-length headroom gained per chip;
  * **decode exchange bytes** — the masked-psum combine of per-shard
    ``(out, m, l)`` partials (R·H·(hd+2)·4 bytes per device per layer per
    step — independent of context length) vs all-gathering the other
    shards' KV view slices ((N-1)·R·S_shard·Hkv·hd·K+V bytes — linear in
    context), per decode step per layer;
  * **greedy parity** — the 8-shard engine's tokens vs the single-device
    ``ContinuousEngine``, token-for-token on a ragged batch over an
    8-forced-host-device mesh (subprocess, same pattern as
    ``benchmarks/dist_stats.py``), gated ``== 1.0``.

Used by ``python -m benchmarks.run`` (section ``serve_dist/``) and writable
standalone via ``python -m benchmarks.serve_dist_stats``.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap

from repro.core import patterns as P
from repro.serve.paged_cache import layout_for_pattern, slab_bytes

N_SHARDS = 8
DTYPE_BYTES = 2     # bf16 KV at scale

# (name, pattern, page, max_batch, n_layers, n_heads, n_kv_heads, head_dim)
WORKLOADS = [
    ("long_512k_w4096",
     P.causal_sliding_window(4096, n_sinks=4), 128, 8, 32, 64, 8, 128),
    ("long_64k_w1024_d4",
     P.causal_sliding_window(1024, n_sinks=4, dilation=4), 64, 16, 32, 64,
     8, 128),
    ("smoke_w16",
     P.causal_sliding_window(16, n_sinks=2), 8, 4, 2, 3, 1, 16),
]


def _accounting() -> dict:
    out = {}
    for name, pat, page, B, L, H, Hkv, hd in WORKLOADS:
        lay1 = layout_for_pattern(pat, page)
        layN = layout_for_pattern(pat, page, shards=N_SHARDS)
        # per-device slab pool: 1 null page + max_batch full page sets
        rep = slab_bytes(L, 1 + B * lay1.pages_per_req, page, Hkv, hd,
                         DTYPE_BYTES)
        shard = slab_bytes(L, 1 + B * layN.pages_per_shard, page, Hkv, hd,
                           DTYPE_BYTES)
        # decode exchange, per step per layer per device
        psum = B * H * (hd + 2) * 4                      # (out, m, l) f32
        allgather = ((N_SHARDS - 1) * B * layN.slots_per_shard * Hkv * hd
                     * 2 * DTYPE_BYTES)                  # K + V view slices
        out[name] = dict(
            n_shards=N_SHARDS,
            slots_per_request=layN.slots_per_req,
            replicated_slab_bytes=rep,
            shard_slab_bytes=shard,
            slab_bytes_ratio=shard / rep,
            decode_psum_bytes=psum,
            decode_allgather_bytes=allgather,
            decode_bytes_ratio=psum / allgather,
        )
    return out


_PARITY_PROG = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.configs import get_smoke
    from repro.models.model import build_model
    from repro.models.layers import salo_pattern
    from repro.serve.engine import ContinuousConfig, ContinuousEngine
    from repro.serve.paged_cache import layout_for_pattern

    cfg = get_smoke("smollm-135m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
               for L in (24, 17, 9, 30)]
    pat = salo_pattern(cfg, causal=True)
    l1 = layout_for_pattern(pat, 8)
    e1 = ContinuousEngine(model, ContinuousConfig(
        n_pages=1 + 4 * l1.pages_per_req, page=8, chunk=8, max_batch=4))
    r1 = [e1.submit(p, 8) for p in prompts]
    ref = e1.run(params)
    mesh = jax.make_mesh((8,), ("seq",))
    l8 = layout_for_pattern(pat, 8, shards=8)
    e8 = ContinuousEngine(model, ContinuousConfig(
        n_pages=1 + 4 * l8.pages_per_shard, page=8, chunk=8, max_batch=4,
        seq_shards=8), mesh=mesh)
    r8 = [e8.submit(p, 8) for p in prompts]
    out = e8.run(params)
    match = all(np.array_equal(ref[a], out[b]) for a, b in zip(r1, r8))
    print("PARITY", 1.0 if match else 0.0)
"""


def _measure_parity() -> dict:
    """Greedy token parity of the 8-shard engine vs single-device, via a
    subprocess with 8 forced host devices (the running process already
    initialized jax with 1)."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_PARITY_PROG)],
        env={**os.environ, "PYTHONPATH": src},
        capture_output=True, text=True, timeout=900)
    if r.returncode != 0:
        raise RuntimeError(f"parity subprocess failed:\n{r.stderr[-2000:]}")
    parity = float(r.stdout.strip().split("PARITY")[-1])
    return {"greedy_token_match": parity, "n_shards": N_SHARDS}


def collect(measure: bool = True) -> dict:
    data = {"workloads": _accounting()}
    if measure:
        data["parity"] = _measure_parity()
    return data


def _write_json(data, out_path, measure):
    if not measure:
        return
    with open(out_path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)


def serve_dist_benchmark(rows, measure: bool = True,
                         out_path: str = "BENCH_serve_dist.json") -> dict:
    """benchmarks.run section: report + write BENCH_serve_dist.json."""
    data = collect(measure=measure)
    for name, st in data["workloads"].items():
        rows.append((f"serve_dist/{name}/slab_bytes_ratio",
                     st["slab_bytes_ratio"],
                     f"shard={st['shard_slab_bytes']}_replicated="
                     f"{st['replicated_slab_bytes']}"))
        rows.append((f"serve_dist/{name}/decode_bytes_ratio",
                     st["decode_bytes_ratio"],
                     f"psum={st['decode_psum_bytes']}_allgather="
                     f"{st['decode_allgather_bytes']}"))
    if "parity" in data:
        rows.append(("serve_dist/parity",
                     data["parity"]["greedy_token_match"],
                     "8shard_vs_single_device_greedy_tokens"))
    _write_json(data, out_path, measure)
    return data


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serve_dist.json")
    ap.add_argument("--no-measure", action="store_true",
                    help="static byte accounting only (skips the 8-device "
                         "parity subprocess; does NOT rewrite the "
                         "committed JSON)")
    args = ap.parse_args()
    rows = []
    serve_dist_benchmark(rows, measure=not args.no_measure,
                         out_path=args.out)
    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value:.6g},{derived}")
    if not args.no_measure:
        print(f"# wrote {args.out}")
    # standalone gates (benchmarks.run applies the same ones)
    d = {name: value for name, value, _ in rows}
    bad = [(k, v) for k, v in d.items()
           if k.endswith("bytes_ratio") and v >= 1.0]
    if "serve_dist/parity" in d and d["serve_dist/parity"] != 1.0:
        bad.append(("serve_dist/parity", d["serve_dist/parity"]))
    if bad:
        for k, v in bad:
            print(f"CHECK-FAILED: {k} = {v}", file=sys.stderr)
        raise SystemExit(1)
    print("# serve_dist gates hold")


if __name__ == "__main__":
    main()
