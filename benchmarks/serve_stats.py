"""Continuous-batching serving benchmark -> BENCH_serve.json.

For a ragged smoke workload (prompt lengths spread around the mean — real
traffic) this reports, always (static / counted):

  * **chunked prefill launch accounting** — fused table-driven launches the
    engine actually issued (counted by the engine, not estimated) vs the
    exact contract sum(ceil(P_i / chunk)) vs the token-by-token replay
    (sum P_i decode launches — what ``ServeEngine.prefill`` costs);
  * **greedy parity** — continuous-batching output vs per-request lockstep
    generation, token-for-token (1.0 = every token of every request);
  * **cache bytes** — the pooled paged ring-cache slab vs the dense
    full-length cache the lockstep baseline would allocate for the same
    concurrency at a long-context ``max_len`` (the paper's O(window + g)
    live set as a serving footprint);

and with ``measure`` (wall-clock, host CPU — the TPU story is the kernels'):

  * **tokens/s** — the continuous engine serving the ragged batch vs the
    lockstep baseline driving each request separately (lockstep cannot
    batch ragged requests without padding semantics changes — that gap IS
    the subsystem's reason to exist).

Quantized serving (section ``quant`` of the JSON, always collected):

  * **int8 slab footprint** — resident bytes of the int8 slab (K/V int8 +
    per-(layer, page) f32 scales) vs the same pool in the compute dtype,
    gated >= 3.5x smaller (f32 smoke compute dtype -> ~4x minus scales);
  * **quantized greedy parity** — int8 engine tokens vs the fp engine,
    per-request exact-match rate, gated == 1.0 on the smoke workload;
  * **keep-all exactness** — ``page_sparsity_threshold=-inf`` (stats
    machinery ON, nothing skipped) must be token-identical to the int8
    engine with the machinery off — the read-masking-only invariant;
  * **stats-driven page skipping** — a window-64 variant with a finite
    threshold + decay: fraction of decode page reads actually issued
    (gated < 1.0 — skipping must engage) at token parity with its own
    dense-read int8 reference;

and with ``measure``: an 8-shard (forced host devices, subprocess) int8 +
page-sparse engine vs its single-device twin, gated token-exact — scales
stripe with the pages and the keep mask comes from merged shard stats.

Fairness (section ``fairness`` of the JSON, always collected): per-priority
queue-wait percentiles, preemption counts, and deadline-miss rates, read
from the engine's own metrics registry on a deterministic two-class
scenario — a high-priority arrival preempting the low-priority decoder in
a too-small pool, plus one already-due low-priority deadline. Gated: only
the low class is preempted, only the low class misses its deadline.

Fault-tolerant serving (section ``recovery`` of the JSON, always
collected, tempdir snapshot dirs):

  * **kill/resume parity** — the ServeSupervisor with injected step
    crashes: restored runs must emit tokens identical to the
    uninterrupted engine (exactly-once emission), gated == 1.0, with work
    lost per crash gated <= the checkpoint interval;
  * **preemption + re-prefill** — a pool SMALLER than the worst-case
    request footprint (the scenario that previously died with a
    drain-time 'page pool too small' RuntimeError) now completes: a
    higher-priority arrival preempts the resident decoder, which recovers
    by chunked re-prefill — both token-exact vs lockstep, preemptions
    gated > 0;
  * **exhaustion recovery** — an injected allocator-exhaustion window
    makes the bare engine raise the recoverable ResourceExhausted; the
    supervisor retries through the window and still matches the oracle.

Used by ``python -m benchmarks.run`` (section ``serve/``, launch-count and
parity gates) and writable standalone via ``python -m benchmarks.serve_stats``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np

PROMPT_LENS = (24, 17, 9, 30)
N_NEW = 8
CHUNK = 8
PAGE = 8
LONG_CTX = 32_768  # footprint comparison point for the dense baseline

# stats-driven page-sparse variant: a wider window gives each request a
# page tail the history can actually retire (decay must be > 0 or the
# optimistic init never drops below the threshold). -3.0 is the loosest
# threshold that still skips pages on this workload while staying
# greedy-exact — the random-init smoke model has near-tie logits, so
# aggressive thresholds (e.g. -0.3 -> ~40% reads) flip some argmaxes
QUANT_WINDOW = 64
QUANT_N_NEW = 24
QUANT_THRESHOLD = -3.0
QUANT_DECAY = 0.3


def _build():
    from repro.configs import get_smoke
    from repro.models.layers import salo_pattern
    from repro.models.model import build_model
    from repro.serve.engine import ContinuousConfig, ContinuousEngine
    from repro.serve.paged_cache import layout_for_pattern

    cfg = get_smoke("smollm-135m")
    model = build_model(cfg)
    lay = layout_for_pattern(salo_pattern(cfg, causal=True), PAGE)
    eng = ContinuousEngine(model, ContinuousConfig(
        n_pages=1 + len(PROMPT_LENS) * lay.pages_per_req, page=PAGE,
        chunk=CHUNK, max_batch=len(PROMPT_LENS)))
    return cfg, model, eng


def _engine_for(cfg, model, *, kv_dtype="compute", thr=None, decay=0.0):
    from repro.models.layers import salo_pattern
    from repro.serve.engine import ContinuousConfig, ContinuousEngine
    from repro.serve.paged_cache import layout_for_pattern

    lay = layout_for_pattern(salo_pattern(cfg, causal=True), PAGE)
    return ContinuousEngine(model, ContinuousConfig(
        n_pages=1 + len(PROMPT_LENS) * lay.pages_per_req, page=PAGE,
        chunk=CHUNK, max_batch=len(PROMPT_LENS), kv_dtype=kv_dtype,
        page_sparsity_threshold=thr, page_stat_decay=decay))


def _quant_section(cfg, model, params, prompts) -> dict:
    """Quantized-serving stats: int8 footprint + parity, keep-all
    exactness, and the stats-driven page-sparse variant."""
    from repro.models.model import build_model

    def run(eng, pp, n_new):
        rids = [eng.submit(p, n_new) for p in prompts]
        res = eng.run(pp)
        return [res[r] for r in rids]

    fp_eng = _engine_for(cfg, model)
    fp_toks = run(fp_eng, params, N_NEW)
    q_eng = _engine_for(cfg, model, kv_dtype="int8")
    q_toks = run(q_eng, params, N_NEW)
    ka_eng = _engine_for(cfg, model, kv_dtype="int8",
                         thr=float("-inf"), decay=QUANT_DECAY)
    ka_toks = run(ka_eng, params, N_NEW)
    assert (ka_eng.counters["decode_pages_read"]
            == ka_eng.counters["decode_pages_total"])

    fp_bytes = fp_eng.slab_resident_bytes()
    q_bytes = q_eng.slab_resident_bytes()
    parity = float(np.mean([np.array_equal(a, b)
                            for a, b in zip(q_toks, fp_toks)]))
    keepall = float(all(np.array_equal(a, b)
                        for a, b in zip(ka_toks, q_toks)))

    # page-sparse variant on the wide-window model: compare against its
    # OWN dense-read int8 twin (same model/params), so the only delta is
    # the keep mask
    cfg64 = dataclasses.replace(
        cfg, salo=dataclasses.replace(cfg.salo, window=QUANT_WINDOW))
    model64 = build_model(cfg64)
    params64 = model64.init(jax.random.PRNGKey(0))
    d64_toks = run(_engine_for(cfg64, model64, kv_dtype="int8"),
                   params64, QUANT_N_NEW)
    sp_eng = _engine_for(cfg64, model64, kv_dtype="int8",
                         thr=QUANT_THRESHOLD, decay=QUANT_DECAY)
    sp_toks = run(sp_eng, params64, QUANT_N_NEW)
    read = sp_eng.counters["decode_pages_read"]
    total = sp_eng.counters["decode_pages_total"]
    sparse_parity = float(np.mean([np.array_equal(a, b)
                                   for a, b in zip(sp_toks, d64_toks)]))
    return {
        "fp_slab_resident_bytes": fp_bytes,
        "int8_slab_resident_bytes": q_bytes,
        "slab_bytes_ratio": fp_bytes / q_bytes,
        "parity_vs_fp": parity,
        "keepall_exact_vs_dense_read": keepall,
        "sparse": {"window": QUANT_WINDOW, "n_new": QUANT_N_NEW,
                   "threshold": QUANT_THRESHOLD, "decay": QUANT_DECAY,
                   "decode_pages_read": read, "decode_pages_total": total,
                   "page_read_fraction": read / total,
                   "parity_vs_dense_read": sparse_parity},
    }


RECOVERY_CRASH_AT = frozenset({3, 6})
RECOVERY_CKPT_EVERY = 2


def _recovery_section(cfg, model, params) -> dict:
    """Fault-tolerance stats: supervisor kill/resume parity, page-pressure
    preemption + re-prefill in a pool too small for the worst-case
    footprint, and injected-exhaustion recovery."""
    import tempfile

    from repro.ft import FaultInjector, FaultPlan, ServeSupervisor
    from repro.ft.faults import ResourceExhausted
    from repro.models.layers import salo_pattern
    from repro.serve.engine import (ContinuousConfig, ContinuousEngine,
                                    ServeConfig, ServeEngine)
    from repro.serve.paged_cache import layout_for_pattern

    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
               for L in PROMPT_LENS]

    def lockstep(pp, n):
        outs = []
        for p in pp:
            ls = ServeEngine(model, ServeConfig(max_len=len(p) + n))
            outs.append(np.asarray(
                ls.generate(params, jnp.asarray(p)[None], n))[0])
        return outs

    # --- kill/resume: injected crashes vs the uninterrupted run ---------- #
    base = _engine_for(cfg, model)
    base_rids = [base.submit(p, N_NEW) for p in prompts]
    uninterrupted = base.run(params)

    def mk():
        eng = _engine_for(cfg, model)
        for p in prompts:
            eng.submit(p, N_NEW)
        return eng

    with tempfile.TemporaryDirectory() as ck:
        sup = ServeSupervisor(
            mk, params, ck, checkpoint_every=RECOVERY_CKPT_EVERY,
            injector=FaultInjector(FaultPlan(crash_steps=RECOVERY_CRASH_AT)))
        eng, hist = sup.run()
    res = eng.batcher.results()
    restore_parity = float(all(
        np.array_equal(uninterrupted[a], res[b])
        for a, b in zip(base_rids, sorted(res))))

    # --- preemption + re-prefill in a too-small pool --------------------- #
    # pool = pages_per_req -> 1 null + (pages_per_req - 1) usable: SMALLER
    # than the worst-case footprint. Every request here previously ended in
    # the drain-time 'page pool too small' RuntimeError; with variable
    # footprints + preemption the whole scenario completes token-exact.
    lay = layout_for_pattern(salo_pattern(cfg, causal=True), PAGE)
    pa = rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)
    pb = rng.integers(0, cfg.vocab_size, (5,)).astype(np.int32)
    ref_a, ref_b = lockstep([pa, pb], 4)
    small = ContinuousEngine(model, ContinuousConfig(
        n_pages=lay.pages_per_req, page=PAGE, chunk=CHUNK, max_batch=4))
    ra = small.submit(pa, 4, priority=0)
    while not small.batcher.assemble()[1]:    # drive A into decode
        small.step(params)
    rb = small.submit(pb, 4, priority=1)      # preempts A for its pages
    pres = small.run(params)
    preempt_parity = float(np.array_equal(pres[ra], ref_a)
                           and np.array_equal(pres[rb], ref_b))
    preemptions = small.batcher.preemptions

    # --- injected allocator exhaustion ----------------------------------- #
    plan = FaultPlan(exhaust_steps=frozenset({0, 1}))
    inj = FaultInjector(plan)
    bare = mk()
    inj.attach(bare)
    inj.before_step(0)
    try:
        bare.step(params)
        raised = False
    except ResourceExhausted:
        raised = True
    with tempfile.TemporaryDirectory() as ck:
        sup = ServeSupervisor(mk, params, ck,
                              injector=FaultInjector(plan))
        eng2, hist2 = sup.run()
    res2 = eng2.batcher.results()
    exh_parity = all(np.array_equal(uninterrupted[a], res2[b])
                     for a, b in zip(base_rids, sorted(res2)))
    return {
        "kill_resume": {
            "crash_attempts": sorted(RECOVERY_CRASH_AT),
            "checkpoint_every": RECOVERY_CKPT_EVERY,
            "restarts": hist["restarts"],
            "steps_lost": hist["steps_lost"],
            "max_step_loss": hist["max_step_loss"],
            "restore_parity": restore_parity,
        },
        "preemption": {
            "pool_pages_usable": lay.pages_per_req - 1,
            "worst_case_pages": lay.pages_per_req,
            "preemptions": preemptions,
            "parity": preempt_parity,
        },
        "exhaustion": {
            "bare_engine_raised": raised,
            "supervisor_restarts": hist2["restarts"],
            "recovered": float(raised and exh_parity),
        },
    }


def _fairness_section(cfg, model, params) -> dict:
    """Per-priority fairness stats, read from the engine's own metrics
    registry (the observability layer): queue-wait percentiles, preemption
    counts, and deadline-miss rates by priority class.

    The scenario makes the priority mechanics observable deterministically:
    a pool too small for two residents, so the high-priority arrival must
    preempt the low-priority decoder; plus one low-priority request armed
    with an already-due deadline, so exactly the low class records a miss.
    """
    from repro.models.layers import salo_pattern
    from repro.obs import Observability
    from repro.serve.engine import ContinuousConfig, ContinuousEngine
    from repro.serve.paged_cache import layout_for_pattern

    rng = np.random.default_rng(2)
    obs = Observability()
    lay = layout_for_pattern(salo_pattern(cfg, causal=True), PAGE)
    eng = ContinuousEngine(model, ContinuousConfig(
        n_pages=lay.pages_per_req, page=PAGE, chunk=CHUNK, max_batch=4),
        obs=obs)
    pa = rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)
    pb = rng.integers(0, cfg.vocab_size, (5,)).astype(np.int32)
    eng.submit(pa, 4, priority=0)
    while not eng.batcher.assemble()[1]:      # drive the low-pri into decode
        eng.step(params)
    eng.submit(pb, 4, priority=1)             # preempts for its pages
    eng.submit(rng.integers(0, cfg.vocab_size, (4,)).astype(np.int32), 4,
               priority=0, deadline_s=0.0)    # already due -> certain miss
    eng.run(params)

    reg = obs.registry

    def cnt(name, p):
        try:
            return int(reg.value(name, priority=p))
        except KeyError:
            return 0

    by_priority = {}
    for p in (0, 1):
        sub = cnt("serve_requests_submitted", p)
        miss = cnt("serve_deadline_miss", p)
        wait = reg.percentiles("serve_queue_wait_s", qs=(0.5, 0.99),
                               priority=p)
        by_priority[str(p)] = {
            "submitted": sub,
            "finished": cnt("serve_requests_finished", p),
            "preemptions": cnt("serve_preemptions", p),
            "deadline_miss": miss,
            "deadline_miss_rate": miss / sub if sub else 0.0,
            "queue_wait_p50_s": wait["p50"],
            "queue_wait_p99_s": wait["p99"],
            "queue_wait_n": wait["count"],
        }
    return {"by_priority": by_priority}


_QUANT_SHARD_PROG = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.configs import get_smoke
    from repro.models.model import build_model
    from repro.models.layers import salo_pattern
    from repro.serve.engine import ContinuousConfig, ContinuousEngine
    from repro.serve.paged_cache import layout_for_pattern

    cfg = get_smoke("smollm-135m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
               for L in (24, 17, 9, 30)]
    pat = salo_pattern(cfg, causal=True)
    quant = dict(kv_dtype="int8", page_sparsity_threshold=-0.5,
                 page_stat_decay=0.3)
    l1 = layout_for_pattern(pat, 8)
    e1 = ContinuousEngine(model, ContinuousConfig(
        n_pages=1 + 4 * l1.pages_per_req, page=8, chunk=8, max_batch=4,
        **quant))
    r1 = [e1.submit(p, 8) for p in prompts]
    ref = e1.run(params)
    mesh = jax.make_mesh((8,), ("seq",))
    l8 = layout_for_pattern(pat, 8, shards=8)
    e8 = ContinuousEngine(model, ContinuousConfig(
        n_pages=1 + 4 * l8.pages_per_shard, page=8, chunk=8, max_batch=4,
        seq_shards=8, **quant), mesh=mesh)
    r8 = [e8.submit(p, 8) for p in prompts]
    out = e8.run(params)
    match = all(np.array_equal(ref[a], out[b]) for a, b in zip(r1, r8))
    skipped = (e8.counters["decode_pages_read"]
               < e8.counters["decode_pages_total"])
    print("PARITY", 1.0 if (match and skipped) else 0.0)
"""


def _measure_quant_shard_parity() -> dict:
    """8-shard int8 + page-sparse engine vs its single-device twin, via a
    subprocess with 8 forced host devices (same pattern as
    benchmarks/serve_dist_stats.py). Parity requires token-exact output
    AND that the sharded engine actually skipped pages."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_QUANT_SHARD_PROG)],
        env={**os.environ, "PYTHONPATH": src},
        capture_output=True, text=True, timeout=900)
    if r.returncode != 0:
        raise RuntimeError(
            f"quant shard parity subprocess failed:\n{r.stderr[-2000:]}")
    parity = float(r.stdout.strip().split("PARITY")[-1])
    return {"greedy_token_match": parity, "n_shards": 8}


def collect(measure: bool = True) -> dict:
    from repro.serve.engine import ServeConfig, ServeEngine
    from repro.serve.paged_cache import full_cache_bytes, slab_bytes

    cfg, model, eng = _build()
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
               for L in PROMPT_LENS]

    # --- lockstep baseline: one request at a time (greedy oracle) -------- #
    def run_lockstep():
        outs = []
        for p in prompts:
            ls = ServeEngine(model, ServeConfig(max_len=len(p) + N_NEW))
            outs.append(np.asarray(jax.block_until_ready(
                ls.generate(params, jnp.asarray(p)[None], N_NEW)))[0])
        return outs

    refs = run_lockstep()

    # --- continuous engine (counted launches) ---------------------------- #
    rids = [eng.submit(p, N_NEW) for p in prompts]
    t0 = time.perf_counter()
    results = eng.run(params)
    cont_wall = time.perf_counter() - t0

    parity = float(all(
        np.array_equal(results[r], ref) for r, ref in zip(rids, refs)))
    expected_chunks = sum(math.ceil(L / CHUNK) for L in PROMPT_LENS)
    counted = eng.counters["prefill_launches"]

    lay = eng.layout
    n_layers_total = sum(n for _, n in model.program)
    dtype_bytes = jnp.dtype(cfg.compute_dtype).itemsize
    slab = slab_bytes(n_layers_total, eng.ccfg.n_pages, PAGE,
                      cfg.n_kv_heads, cfg.hd, dtype_bytes)
    dense = full_cache_bytes(n_layers_total, len(PROMPT_LENS), LONG_CTX,
                             cfg.n_kv_heads, cfg.hd, dtype_bytes)

    data = {
        "workload": {"arch": cfg.name, "prompt_lens": list(PROMPT_LENS),
                     "n_new": N_NEW, "chunk": CHUNK, "page": PAGE,
                     "window": cfg.salo.window,
                     "n_global": cfg.salo.n_global},
        "prefill": {
            "fused_launches_counted": counted,
            "fused_launches_expected": expected_chunks,
            "token_by_token_launches": int(sum(PROMPT_LENS)),
            "launch_ratio": counted / expected_chunks,
            "launch_reduction": sum(PROMPT_LENS) / counted,
        },
        "decode": {
            "ragged_launches": eng.counters["decode_launches"],
            "lockstep_launches": len(PROMPT_LENS) * (N_NEW - 1),
            "tokens": eng.counters["decode_tokens"],
        },
        "parity": {"greedy_token_match": parity},
        "cache": {
            "slab_bytes": slab,
            "pages": eng.ccfg.n_pages,
            "slots_per_request": lay.slots_per_req,
            "dense_bytes_at_32k": dense,
            "bytes_ratio": dense / slab,
        },
        "quant": _quant_section(cfg, model, params, prompts),
        "recovery": _recovery_section(cfg, model, params),
        "fairness": _fairness_section(cfg, model, params),
    }
    if measure:
        data["quant"]["sharded"] = _measure_quant_shard_parity()
        # second pass for the throughput comparison: resubmit to the SAME
        # engine — its jitted chunk/decode steps are genuinely warm (a
        # fresh engine would recompile). The lockstep side re-traces its
        # scan closures every call; that is inherent to the baseline (no
        # persistent compiled step) and part of what it is measured on.
        rids2 = [eng.submit(p, N_NEW) for p in prompts]
        t0 = time.perf_counter()
        eng.run(params)
        cont_wall = time.perf_counter() - t0
        assert len(rids2) == len(prompts)
        t0 = time.perf_counter()
        run_lockstep()
        lock_wall = time.perf_counter() - t0
        new_tokens = len(PROMPT_LENS) * N_NEW
        data["throughput"] = {
            "continuous_tok_s": new_tokens / cont_wall,
            "lockstep_tok_s": new_tokens / lock_wall,
            "speedup": lock_wall / cont_wall,
        }
    return data


def _write_json(data, out_path, measure):
    if not measure:
        return
    with open(out_path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)


def serve_benchmark(rows, measure: bool = True,
                    out_path: str = "BENCH_serve.json") -> dict:
    """benchmarks.run section: report + write BENCH_serve.json."""
    data = collect(measure=measure)
    pre, dec, cache = data["prefill"], data["decode"], data["cache"]
    rows.append(("serve/prefill_launch_ratio", pre["launch_ratio"],
                 f"counted={pre['fused_launches_counted']}_expected="
                 f"{pre['fused_launches_expected']}"))
    rows.append(("serve/prefill_launch_reduction", pre["launch_reduction"],
                 f"token_by_token={pre['token_by_token_launches']}"))
    rows.append(("serve/greedy_parity", data["parity"]["greedy_token_match"],
                 "continuous==lockstep_tokens"))
    rows.append(("serve/decode_launch_reduction",
                 dec["lockstep_launches"] / max(dec["ragged_launches"], 1),
                 f"ragged={dec['ragged_launches']}_lockstep="
                 f"{dec['lockstep_launches']}"))
    rows.append(("serve/cache_bytes_ratio", cache["bytes_ratio"],
                 f"slab={cache['slab_bytes']}_dense32k="
                 f"{cache['dense_bytes_at_32k']}"))
    qu = data["quant"]
    rows.append(("serve/quant_slab_bytes_ratio", qu["slab_bytes_ratio"],
                 f"fp={qu['fp_slab_resident_bytes']}_int8="
                 f"{qu['int8_slab_resident_bytes']}"))
    rows.append(("serve/quant_parity_vs_fp", qu["parity_vs_fp"],
                 "int8_engine==fp_engine_tokens"))
    rows.append(("serve/quant_keepall_exact",
                 qu["keepall_exact_vs_dense_read"],
                 "threshold=-inf==no_stats_machinery"))
    sp = qu["sparse"]
    rows.append(("serve/quant_page_read_fraction", sp["page_read_fraction"],
                 f"read={sp['decode_pages_read']}_total="
                 f"{sp['decode_pages_total']}_thr={sp['threshold']}"))
    rows.append(("serve/quant_sparse_parity", sp["parity_vs_dense_read"],
                 f"page_sparse==dense_read_w{sp['window']}"))
    if "sharded" in qu:
        rows.append(("serve/quant_sharded_parity",
                     qu["sharded"]["greedy_token_match"],
                     "8shard_int8_sparse==single_device"))
    rec = data["recovery"]
    kr, pe, ex = rec["kill_resume"], rec["preemption"], rec["exhaustion"]
    rows.append(("serve/recovery_restore_parity", kr["restore_parity"],
                 f"restarts={kr['restarts']}_crash_at="
                 f"{'+'.join(map(str, kr['crash_attempts']))}"))
    rows.append(("serve/recovery_max_step_loss", float(kr["max_step_loss"]),
                 f"checkpoint_every={kr['checkpoint_every']}"))
    rows.append(("serve/recovery_preempt_parity", pe["parity"],
                 f"pool={pe['pool_pages_usable']}_worst_case="
                 f"{pe['worst_case_pages']}_pages"))
    rows.append(("serve/recovery_preemptions", float(pe["preemptions"]),
                 "victims_evicted_then_reprefilled"))
    rows.append(("serve/recovery_exhaustion_recovered", ex["recovered"],
                 f"supervisor_restarts={ex['supervisor_restarts']}"))
    fp = data["fairness"]["by_priority"]
    rows.append(("serve/fair_low_pri_preemptions",
                 float(fp["0"]["preemptions"]),
                 "high_pri_arrival_evicts_low_pri_decoder"))
    rows.append(("serve/fair_low_pri_miss_rate",
                 fp["0"]["deadline_miss_rate"],
                 f"missed={fp['0']['deadline_miss']}_of_"
                 f"{fp['0']['submitted']}"))
    rows.append(("serve/fair_high_pri_miss_rate",
                 fp["1"]["deadline_miss_rate"],
                 f"missed={fp['1']['deadline_miss']}_of_"
                 f"{fp['1']['submitted']}"))
    if "throughput" in data:
        tp = data["throughput"]
        rows.append(("serve/ragged_throughput_speedup", tp["speedup"],
                     f"cont={tp['continuous_tok_s']:.1f}tok/s_lock="
                     f"{tp['lockstep_tok_s']:.1f}tok/s"))
    _write_json(data, out_path, measure)
    return data


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--no-measure", action="store_true",
                    help="counted/static stats only (no wall-time; does "
                         "NOT rewrite the committed JSON)")
    args = ap.parse_args()
    rows = []
    serve_benchmark(rows, measure=not args.no_measure, out_path=args.out)
    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value:.6g},{derived}")
    if not args.no_measure:
        print(f"# wrote {args.out}")
    # standalone quantized-serving gates (benchmarks.run applies the same
    # ones; --no-measure skips only the 8-shard subprocess row)
    d = {name: value for name, value, _ in rows}
    bad = []
    if d["serve/quant_slab_bytes_ratio"] < 3.5:
        bad.append(("serve/quant_slab_bytes_ratio",
                    d["serve/quant_slab_bytes_ratio"], ">= 3.5"))
    for k in ("serve/greedy_parity", "serve/quant_parity_vs_fp",
              "serve/quant_keepall_exact", "serve/quant_sparse_parity",
              "serve/quant_sharded_parity",
              "serve/recovery_restore_parity",
              "serve/recovery_preempt_parity",
              "serve/recovery_exhaustion_recovered"):
        if k in d and d[k] != 1.0:
            bad.append((k, d[k], "== 1.0"))
    if d["serve/quant_page_read_fraction"] >= 1.0:
        bad.append(("serve/quant_page_read_fraction",
                    d["serve/quant_page_read_fraction"], "< 1.0"))
    if d["serve/recovery_max_step_loss"] > RECOVERY_CKPT_EVERY:
        bad.append(("serve/recovery_max_step_loss",
                    d["serve/recovery_max_step_loss"],
                    f"<= {RECOVERY_CKPT_EVERY} (bounded work loss)"))
    if d["serve/recovery_preemptions"] <= 0:
        bad.append(("serve/recovery_preemptions",
                    d["serve/recovery_preemptions"],
                    "> 0 (preemption must engage)"))
    if d["serve/fair_low_pri_preemptions"] <= 0:
        bad.append(("serve/fair_low_pri_preemptions",
                    d["serve/fair_low_pri_preemptions"],
                    "> 0 (only the low class is preemptible)"))
    if d["serve/fair_low_pri_miss_rate"] <= 0.0:
        bad.append(("serve/fair_low_pri_miss_rate",
                    d["serve/fair_low_pri_miss_rate"],
                    "> 0 (the armed low-pri deadline must register)"))
    if d["serve/fair_high_pri_miss_rate"] != 0.0:
        bad.append(("serve/fair_high_pri_miss_rate",
                    d["serve/fair_high_pri_miss_rate"],
                    "== 0 (high class never misses here)"))
    if bad:
        for b in bad:
            print(f"CHECK-FAILED: {b}", file=sys.stderr)
        raise SystemExit(1)
    print("# serve quant + recovery gates hold")


if __name__ == "__main__":
    main()
