"""Continuous-batching serving benchmark -> BENCH_serve.json.

For a ragged smoke workload (prompt lengths spread around the mean — real
traffic) this reports, always (static / counted):

  * **chunked prefill launch accounting** — fused table-driven launches the
    engine actually issued (counted by the engine, not estimated) vs the
    exact contract sum(ceil(P_i / chunk)) vs the token-by-token replay
    (sum P_i decode launches — what ``ServeEngine.prefill`` costs);
  * **greedy parity** — continuous-batching output vs per-request lockstep
    generation, token-for-token (1.0 = every token of every request);
  * **cache bytes** — the pooled paged ring-cache slab vs the dense
    full-length cache the lockstep baseline would allocate for the same
    concurrency at a long-context ``max_len`` (the paper's O(window + g)
    live set as a serving footprint);

and with ``measure`` (wall-clock, host CPU — the TPU story is the kernels'):

  * **tokens/s** — the continuous engine serving the ragged batch vs the
    lockstep baseline driving each request separately (lockstep cannot
    batch ragged requests without padding semantics changes — that gap IS
    the subsystem's reason to exist).

Used by ``python -m benchmarks.run`` (section ``serve/``, launch-count and
parity gates) and writable standalone via ``python -m benchmarks.serve_stats``.
"""
from __future__ import annotations

import argparse
import json
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

PROMPT_LENS = (24, 17, 9, 30)
N_NEW = 8
CHUNK = 8
PAGE = 8
LONG_CTX = 32_768  # footprint comparison point for the dense baseline


def _build():
    from repro.configs import get_smoke
    from repro.models.layers import salo_pattern
    from repro.models.model import build_model
    from repro.serve.engine import ContinuousConfig, ContinuousEngine
    from repro.serve.paged_cache import layout_for_pattern

    cfg = get_smoke("smollm-135m")
    model = build_model(cfg)
    lay = layout_for_pattern(salo_pattern(cfg, causal=True), PAGE)
    eng = ContinuousEngine(model, ContinuousConfig(
        n_pages=1 + len(PROMPT_LENS) * lay.pages_per_req, page=PAGE,
        chunk=CHUNK, max_batch=len(PROMPT_LENS)))
    return cfg, model, eng


def collect(measure: bool = True) -> dict:
    from repro.serve.engine import ServeConfig, ServeEngine
    from repro.serve.paged_cache import full_cache_bytes, slab_bytes

    cfg, model, eng = _build()
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
               for L in PROMPT_LENS]

    # --- lockstep baseline: one request at a time (greedy oracle) -------- #
    def run_lockstep():
        outs = []
        for p in prompts:
            ls = ServeEngine(model, ServeConfig(max_len=len(p) + N_NEW))
            outs.append(np.asarray(jax.block_until_ready(
                ls.generate(params, jnp.asarray(p)[None], N_NEW)))[0])
        return outs

    refs = run_lockstep()

    # --- continuous engine (counted launches) ---------------------------- #
    rids = [eng.submit(p, N_NEW) for p in prompts]
    t0 = time.perf_counter()
    results = eng.run(params)
    cont_wall = time.perf_counter() - t0

    parity = float(all(
        np.array_equal(results[r], ref) for r, ref in zip(rids, refs)))
    expected_chunks = sum(math.ceil(L / CHUNK) for L in PROMPT_LENS)
    counted = eng.counters["prefill_launches"]

    lay = eng.layout
    n_layers_total = sum(n for _, n in model.program)
    dtype_bytes = jnp.dtype(cfg.compute_dtype).itemsize
    slab = slab_bytes(n_layers_total, eng.ccfg.n_pages, PAGE,
                      cfg.n_kv_heads, cfg.hd, dtype_bytes)
    dense = full_cache_bytes(n_layers_total, len(PROMPT_LENS), LONG_CTX,
                             cfg.n_kv_heads, cfg.hd, dtype_bytes)

    data = {
        "workload": {"arch": cfg.name, "prompt_lens": list(PROMPT_LENS),
                     "n_new": N_NEW, "chunk": CHUNK, "page": PAGE,
                     "window": cfg.salo.window,
                     "n_global": cfg.salo.n_global},
        "prefill": {
            "fused_launches_counted": counted,
            "fused_launches_expected": expected_chunks,
            "token_by_token_launches": int(sum(PROMPT_LENS)),
            "launch_ratio": counted / expected_chunks,
            "launch_reduction": sum(PROMPT_LENS) / counted,
        },
        "decode": {
            "ragged_launches": eng.counters["decode_launches"],
            "lockstep_launches": len(PROMPT_LENS) * (N_NEW - 1),
            "tokens": eng.counters["decode_tokens"],
        },
        "parity": {"greedy_token_match": parity},
        "cache": {
            "slab_bytes": slab,
            "pages": eng.ccfg.n_pages,
            "slots_per_request": lay.slots_per_req,
            "dense_bytes_at_32k": dense,
            "bytes_ratio": dense / slab,
        },
    }
    if measure:
        # second pass for the throughput comparison: resubmit to the SAME
        # engine — its jitted chunk/decode steps are genuinely warm (a
        # fresh engine would recompile). The lockstep side re-traces its
        # scan closures every call; that is inherent to the baseline (no
        # persistent compiled step) and part of what it is measured on.
        rids2 = [eng.submit(p, N_NEW) for p in prompts]
        t0 = time.perf_counter()
        eng.run(params)
        cont_wall = time.perf_counter() - t0
        assert len(rids2) == len(prompts)
        t0 = time.perf_counter()
        run_lockstep()
        lock_wall = time.perf_counter() - t0
        new_tokens = len(PROMPT_LENS) * N_NEW
        data["throughput"] = {
            "continuous_tok_s": new_tokens / cont_wall,
            "lockstep_tok_s": new_tokens / lock_wall,
            "speedup": lock_wall / cont_wall,
        }
    return data


def _write_json(data, out_path, measure):
    if not measure:
        return
    with open(out_path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)


def serve_benchmark(rows, measure: bool = True,
                    out_path: str = "BENCH_serve.json") -> dict:
    """benchmarks.run section: report + write BENCH_serve.json."""
    data = collect(measure=measure)
    pre, dec, cache = data["prefill"], data["decode"], data["cache"]
    rows.append(("serve/prefill_launch_ratio", pre["launch_ratio"],
                 f"counted={pre['fused_launches_counted']}_expected="
                 f"{pre['fused_launches_expected']}"))
    rows.append(("serve/prefill_launch_reduction", pre["launch_reduction"],
                 f"token_by_token={pre['token_by_token_launches']}"))
    rows.append(("serve/greedy_parity", data["parity"]["greedy_token_match"],
                 "continuous==lockstep_tokens"))
    rows.append(("serve/decode_launch_reduction",
                 dec["lockstep_launches"] / max(dec["ragged_launches"], 1),
                 f"ragged={dec['ragged_launches']}_lockstep="
                 f"{dec['lockstep_launches']}"))
    rows.append(("serve/cache_bytes_ratio", cache["bytes_ratio"],
                 f"slab={cache['slab_bytes']}_dense32k="
                 f"{cache['dense_bytes_at_32k']}"))
    if "throughput" in data:
        tp = data["throughput"]
        rows.append(("serve/ragged_throughput_speedup", tp["speedup"],
                     f"cont={tp['continuous_tok_s']:.1f}tok/s_lock="
                     f"{tp['lockstep_tok_s']:.1f}tok/s"))
    _write_json(data, out_path, measure)
    return data


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--no-measure", action="store_true",
                    help="counted/static stats only (no wall-time; does "
                         "NOT rewrite the committed JSON)")
    args = ap.parse_args()
    rows = []
    serve_benchmark(rows, measure=not args.no_measure, out_path=args.out)
    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value:.6g},{derived}")
    if not args.no_measure:
        print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
