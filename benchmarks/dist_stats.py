"""Sequence-parallel ShardedPlan benchmark -> BENCH_dist.json.

Quantifies the paper's hierarchical-splitting claim at datacenter scale:
a sequence shard only exchanges its **halo** (the band reach, ``(w + Bk)·d``
bytes — independent of sequence length) plus the tiny global-tile psum,
versus all-gather ring attention cycling every other shard's full KV
through each device (``(n_shards - 1)·n_local·d`` bytes):

  * static per-layer collective-byte accounting from the ShardedPlan
    metadata (``ShardedPlan.stats``) for the paper's workloads — gated in
    ``benchmarks/run.py`` as ``bytes_ratio < 1`` per workload;
  * measured parity: sharded fwd+bwd vs the single-device fused path on an
    8-device forced-host mesh (subprocess, same pattern as
    tests/test_distributed.py), reported as ``dist/parity`` and gated
    ``== 1.0``.

Used by ``python -m benchmarks.run`` (section ``dist/``) and writable as a
standalone JSON via ``python -m benchmarks.dist_stats``.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap

from repro.core import patterns as P
from repro.core.scheduler import build_plan, schedule
from repro.dist.sharded_plan import shard_plan

N_SHARDS = 8
HEAD_DIM = 64
DTYPE_BYTES = 2     # bf16 activations at scale

# (name, pattern, n, block) — longformer-4k and a long_64k window stand in
# for the paper's 1-D workloads; vil_64x64 for the 2-D multi-band case.
WORKLOADS = [
    ("longformer_4k", P.longformer(512, n_global=1), 4096, 128),
    ("long_64k_w4096", P.causal_sliding_window(4096, n_sinks=4), 65536, 128),
    ("dilated_64k_w1024_d4",
     P.causal_sliding_window(1024, n_sinks=4, dilation=4), 65536, 128),
    ("vil_64x64", P.vil((64, 64), (15, 15), 1), None, 128),
]


def _accounting() -> dict:
    out = {}
    for name, pat, n, blk in WORKLOADS:
        n = n if n is not None else pat.seq_len()
        sched = schedule(pat, n)
        plan = build_plan(sched, blk, blk, N_SHARDS * blk)
        sp = shard_plan(plan, N_SHARDS)
        out[name] = sp.stats(HEAD_DIM, DTYPE_BYTES)
    return out


_PARITY_PROG = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import patterns as P_
    from repro.core.blockwise import blockwise_attention
    from repro.dist.sharded_plan import sharded_attention
    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    worst = 0.0
    for pat, N in ((P_.longformer(8, n_global=2), 128),
                   (P_.causal_sliding_window(5, n_sinks=2, dilation=2), 128),
                   (P_.vil((16, 16), (5, 5), 1), 257)):
        q, k, v, cot = (jnp.asarray(rng.normal(size=(2, N, 16)), jnp.float32)
                        for _ in range(4))
        ref = blockwise_attention(q, k, v, pat, block_q=16, block_k=16)
        g_ref = jax.grad(lambda a, b, c: jnp.sum(blockwise_attention(
            a, b, c, pat, block_q=16, block_k=16) * cot),
            argnums=(0, 1, 2))(q, k, v)
        with mesh:
            out = jax.jit(lambda a, b, c: sharded_attention(
                a, b, c, pat, mesh))(q, k, v)
            g = jax.jit(jax.grad(lambda a, b, c: jnp.sum(sharded_attention(
                a, b, c, pat, mesh) * cot), argnums=(0, 1, 2)))(q, k, v)
        worst = max(worst, float(jnp.max(jnp.abs(out - ref))))
        for a, b in zip(g_ref, g):
            worst = max(worst, float(jnp.max(jnp.abs(a - b))))
    print("WORST_ERR", worst)
"""


def _measure_parity() -> dict:
    """Max |sharded - single-device| over fwd + all grads, via a subprocess
    with 8 forced host devices (the running process already initialized
    jax with 1)."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_PARITY_PROG)],
        env={**os.environ, "PYTHONPATH": src},
        capture_output=True, text=True, timeout=900)
    if r.returncode != 0:
        raise RuntimeError(f"parity subprocess failed:\n{r.stderr[-2000:]}")
    worst = float(r.stdout.strip().split("WORST_ERR")[-1])
    return {"worst_abs_err": worst,
            "parity": 1.0 if worst <= 1e-4 else 0.0,
            "n_shards": N_SHARDS, "tol": 1e-4}


def collect(measure: bool = True) -> dict:
    data = {"workloads": _accounting()}
    if measure:
        data["parity"] = _measure_parity()
    return data


def _write_json(data, out_path, measure):
    if not measure:
        return
    with open(out_path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)


def dist_benchmark(rows, measure: bool = True,
                   out_path: str = "BENCH_dist.json") -> dict:
    """benchmarks.run section: report + write BENCH_dist.json."""
    data = collect(measure=measure)
    for name, st in data["workloads"].items():
        rows.append((f"dist/{name}/exchange_bytes", st["exchange_bytes"],
                     f"halo={st['halo_bytes']}_bcast={st['bcast_bytes']}"))
        rows.append((f"dist/{name}/allgather_bytes", st["allgather_bytes"],
                     f"ring_{st['n_shards']}x{st['n_local']}"))
        rows.append((f"dist/{name}/bytes_ratio", st["bytes_ratio"],
                     f"halo_tiles={st['halo_tiles']}"
                     f"_gtiles={st['global_tiles']}"))
    if "parity" in data:
        p = data["parity"]
        rows.append(("dist/parity", p["parity"],
                     f"worst_err={p['worst_abs_err']:.2e}_8dev_fwd+bwd"))
    _write_json(data, out_path, measure)
    return data


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_dist.json")
    ap.add_argument("--no-measure", action="store_true",
                    help="static halo accounting only (skips the 8-device "
                         "parity subprocess; does NOT rewrite the "
                         "committed JSON)")
    args = ap.parse_args()
    rows = []
    dist_benchmark(rows, measure=not args.no_measure, out_path=args.out)
    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value:.6g},{derived}")
    if not args.no_measure:
        print(f"# wrote {args.out}")
    # standalone gates (benchmarks.run applies the same ones): the halo
    # exchange must beat the all-gather ring on every workload, and the
    # sharded engines must match the single-device fused path exactly.
    d = {name: value for name, value, _ in rows}
    bad = [(k, v) for k, v in d.items()
           if k.endswith("bytes_ratio") and v >= 1.0]
    if "dist/parity" in d and d["dist/parity"] != 1.0:
        bad.append(("dist/parity", d["dist/parity"]))
    if bad:
        for k, v in bad:
            print(f"CHECK-FAILED: {k} = {v}", file=sys.stderr)
        raise SystemExit(1)
    print("# dist gates hold")


if __name__ == "__main__":
    main()
