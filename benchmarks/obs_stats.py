"""Observability benchmark -> BENCH_obs.json.

The observability layer ships with two hard promises, and this benchmark
is where they are enforced rather than asserted in prose:

* **zero cost on the jitted hot path when disabled** (static, always
  collected):

  - *jaxpr identity* — the ragged-decode step function of an engine built
    with full tracing enabled lowers to the character-for-character same
    jaxpr as the default engine's: instrumentation lives host-side around
    the jitted calls and adds ZERO traced operands;
  - *launch identity* — two engines (obs on/off) serving the identical
    ragged workload issue exactly the same number of prefill/decode
    launches and emit token-identical results;

* **negligible cost when enabled** (``measure``): warm traced vs untraced
  wall-clock per engine step (min over alternating repetitions), gated
  ``<= 1.05`` — a full trace of every span/instant may cost at most 5 %.

On top of the contract checks, the traced run itself is summarized
(section ``latency``): TTFT / per-output-token latency / queue-wait
percentiles from the registry's log-bucketed histograms, the exported
Chrome trace is schema-validated (``validate_chrome_trace``) and its event
census reported — one ``engine.step`` span per engine step, request
lifecycle instants for every submitted request.

Used by ``python -m benchmarks.run`` (section ``obs/``) and standalone via
``python -m benchmarks.obs_stats``.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

PROMPT_LENS = (24, 17, 9, 30)
PRIORITIES = (0, 1, 0, 1)
N_NEW = 8
CHUNK = 8
PAGE = 8
OVERHEAD_REPS = 5
OVERHEAD_GATE = 1.05


def _engine(cfg, model, obs=None):
    from repro.models.layers import salo_pattern
    from repro.serve.engine import ContinuousConfig, ContinuousEngine
    from repro.serve.paged_cache import layout_for_pattern

    lay = layout_for_pattern(salo_pattern(cfg, causal=True), PAGE)
    return ContinuousEngine(model, ContinuousConfig(
        n_pages=1 + len(PROMPT_LENS) * lay.pages_per_req, page=PAGE,
        chunk=CHUNK, max_batch=len(PROMPT_LENS)), obs=obs)


def _run(eng, params, prompts):
    rids = [eng.submit(p, N_NEW, priority=pr)
            for p, pr in zip(prompts, PRIORITIES)]
    res = eng.run(params)
    return [res[r] for r in rids]


def _decode_jaxpr(eng, params) -> str:
    """The ragged-decode step's jaxpr, from the engine's live state — the
    string the zero-traced-operand check compares."""
    R = eng.ccfg.max_batch
    z = jnp.zeros(R, jnp.int32)
    return str(jax.make_jaxpr(eng._decode_fn)(
        params, eng.slabs, eng.page_tables.copy(), eng.slot_pos,
        z, z, jnp.zeros(R, bool)))


def _hist_summary(reg, name) -> dict:
    h = reg.merged_hist(name)
    if not h.count:
        return {"count": 0}
    return {"count": h.count, "mean": h.sum / h.count,
            "p50": h.percentile(0.5), "p99": h.percentile(0.99),
            "min": h.min, "max": h.max}


def collect(measure: bool = True) -> dict:
    from repro.configs import get_smoke
    from repro.models.model import build_model
    from repro.obs import Observability, validate_chrome_trace

    cfg = get_smoke("smollm-135m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
               for L in PROMPT_LENS]

    # --- plain engine (obs default: metrics only, tracer disabled) ------- #
    plain = _engine(cfg, model)
    plain_toks = _run(plain, params, prompts)

    # --- fully traced engine, identical workload ------------------------- #
    obs = Observability(tracing=True)
    traced = _engine(cfg, model, obs=obs)
    traced_toks = _run(traced, params, prompts)

    # --- zero-cost contract ---------------------------------------------- #
    jaxpr_equal = (_decode_jaxpr(plain, params)
                   == _decode_jaxpr(traced, params))
    launch_equal = all(plain.counters[k] == traced.counters[k]
                       for k in ("prefill_launches", "decode_launches",
                                 "prefill_tokens", "decode_tokens",
                                 "engine_steps"))
    token_equal = all(np.array_equal(a, b)
                      for a, b in zip(plain_toks, traced_toks))

    # --- the traced run's own story -------------------------------------- #
    reg = obs.registry
    doc = obs.tracer.to_chrome_trace()
    validate_chrome_trace(doc)
    census: dict = {}
    for ev in doc["traceEvents"]:
        if ev["ph"] != "M":
            census[ev["name"]] = census.get(ev["name"], 0) + 1
    steps = traced.counters["engine_steps"]
    latency = {
        "ttft_s": _hist_summary(reg, "serve_ttft_s"),
        "tpot_s": _hist_summary(reg, "serve_tpot_s"),
        "queue_wait_s": _hist_summary(reg, "serve_queue_wait_s"),
        "decode_est_hbm_bytes": reg.total("serve_decode_est_hbm_bytes"),
        "prefill_tiles": reg.total("serve_prefill_tiles"),
    }

    data = {
        "workload": {"arch": cfg.name, "prompt_lens": list(PROMPT_LENS),
                     "priorities": list(PRIORITIES), "n_new": N_NEW,
                     "chunk": CHUNK, "page": PAGE},
        "zero_cost": {
            "decode_jaxpr_identical": float(jaxpr_equal),
            "launch_counts_identical": float(launch_equal),
            "token_parity": float(token_equal),
        },
        "latency": latency,
        "trace": {
            "events": sum(census.values()),
            "census": dict(sorted(census.items())),
            "step_spans": census.get("engine.step", 0),
            "engine_steps": steps,
            "lifecycle_complete": float(
                census.get("request.submitted", 0) == len(PROMPT_LENS)
                and census.get("request.finished", 0) == len(PROMPT_LENS)
                and census.get("request.first_token", 0)
                == len(PROMPT_LENS)),
        },
    }

    if measure:
        # Warm traced vs untraced step time: both engines already compiled
        # above; alternate full re-runs of the identical workload and take
        # the min (noise floor) of each side.
        t_plain, t_traced = [], []
        for _ in range(OVERHEAD_REPS):
            t0 = time.perf_counter()
            _run(plain, params, prompts)
            t_plain.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            _run(traced, params, prompts)
            t_traced.append(time.perf_counter() - t0)
        data["overhead"] = {
            "reps": OVERHEAD_REPS,
            "untraced_wall_s": min(t_plain),
            "traced_wall_s": min(t_traced),
            "traced_over_untraced": min(t_traced) / min(t_plain),
            "gate": OVERHEAD_GATE,
        }
    return data


def obs_benchmark(rows, measure: bool = True,
                  out_path: str = "BENCH_obs.json") -> dict:
    """benchmarks.run section: report + write BENCH_obs.json."""
    data = collect(measure=measure)
    zc, tr, lat = data["zero_cost"], data["trace"], data["latency"]
    rows.append(("obs/decode_jaxpr_identical", zc["decode_jaxpr_identical"],
                 "obs_on_vs_off_zero_traced_operands"))
    rows.append(("obs/launch_counts_identical",
                 zc["launch_counts_identical"],
                 "same_launches_either_way"))
    rows.append(("obs/token_parity", zc["token_parity"],
                 "traced_engine==plain_engine_tokens"))
    rows.append(("obs/trace_step_spans", float(tr["step_spans"]),
                 f"engine_steps={tr['engine_steps']}"))
    rows.append(("obs/trace_lifecycle_complete", tr["lifecycle_complete"],
                 f"submitted=finished=first_token={len(PROMPT_LENS)}"))
    if lat["ttft_s"]["count"]:
        rows.append(("obs/ttft_p50_s", lat["ttft_s"]["p50"],
                     f"n={lat['ttft_s']['count']}"))
    if lat["tpot_s"]["count"]:
        rows.append(("obs/tpot_p50_s", lat["tpot_s"]["p50"],
                     f"n={lat['tpot_s']['count']}"))
    if "overhead" in data:
        ov = data["overhead"]
        rows.append(("obs/traced_overhead", ov["traced_over_untraced"],
                     f"traced={ov['traced_wall_s']:.4f}s_untraced="
                     f"{ov['untraced_wall_s']:.4f}s_min_of_"
                     f"{ov['reps']}"))
        with open(out_path, "w") as f:
            json.dump(data, f, indent=1, sort_keys=True)
    return data


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_obs.json")
    ap.add_argument("--no-measure", action="store_true",
                    help="static contract checks only (no wall-time; does "
                         "NOT rewrite the committed JSON)")
    args = ap.parse_args()
    rows = []
    obs_benchmark(rows, measure=not args.no_measure, out_path=args.out)
    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value:.6g},{derived}")
    if not args.no_measure:
        print(f"# wrote {args.out}")
    d = {name: value for name, value, _ in rows}
    bad = []
    for k in ("obs/decode_jaxpr_identical", "obs/launch_counts_identical",
              "obs/token_parity", "obs/trace_lifecycle_complete"):
        if d[k] != 1.0:
            bad.append((k, d[k], "== 1.0"))
    if d["obs/trace_step_spans"] <= 0:
        bad.append(("obs/trace_step_spans", d["obs/trace_step_spans"],
                    "> 0"))
    if "obs/traced_overhead" in d and d["obs/traced_overhead"] > OVERHEAD_GATE:
        bad.append(("obs/traced_overhead", d["obs/traced_overhead"],
                    f"<= {OVERHEAD_GATE}"))
    if bad:
        for b in bad:
            print(f"CHECK-FAILED: {b}", file=sys.stderr)
        raise SystemExit(1)
    print("# observability contract gates hold")


if __name__ == "__main__":
    main()
