"""Fused ExecutionPlan vs per-band-launch comparison -> BENCH_plan.json,
plus backward-plan accounting -> BENCH_bwd.json.

For the paper's workloads (Longformer-4k, ViL grids from 8x9 up to 64x64)
this reports, per workload:

  * executed KV tiles of the fused plan (one visit per deduplicated tile)
    vs the retired per-band walk (one tile walk per band + a global pass);
  * kernel launches: 1 vs n_bands;
  * measured wall-time of the fused single-pass blockwise engine vs a
    faithful emulation of the per-band path (one plan pass per band + one
    global-only pass, partials merged with renorm.merge — exactly the
    retired ops.py data flow);
  * **backward accounting** (``BENCH_bwd.json``): tiles of the dQ pass
    (forward tables) vs the dK/dV pass (transposed tables) vs a dense
    backward — the transposed walk must preserve the forward dedup
    (ratio ~1.0) — plus measured wall-time AND XLA temp-buffer bytes of the
    plan-driven custom VJP vs autodiff through the sequential scan (the
    retired backward): the flash-style residual reuse shows up as a multi-x
    temp-memory reduction (the scan autodiff stashes every step's gathered
    tiles and probability matrices).

Used by ``python -m benchmarks.run`` (sections ``plan/`` and ``bwd/``) and
writable as standalone JSONs via ``python -m benchmarks.plan_stats``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import patterns as P
from repro.core import renorm
from repro.core.blockwise import blockwise_attention
from repro.core.scheduler import build_plan, schedule

WORKLOADS = [
    # (name, pattern, n (None = implied), block_q, block_k)
    ("longformer_4k", P.longformer(512, n_global=1), 4096, 128, 128),
    ("vil_8x9", P.vil((8, 9), (3, 5), 1), None, 16, 16),
    ("vil_16x16", P.vil((16, 16), (5, 5), 1), None, 32, 32),
    ("vil_28x28", P.vil((28, 28), (15, 15), 1), None, 64, 64),
    ("vil_64x64", P.vil((64, 64), (15, 15), 1), None, 128, 128),
]


def _working_stream(q, k, v, sched, plan):
    """Reorder + pad to the plan's tile grid — the engines' shared helper."""
    from repro.core.blockwise import working_stream
    return (working_stream(q, sched, plan), working_stream(k, sched, plan),
            working_stream(v, sched, plan),
            jnp.asarray(plan.positions_padded()))


def _band_pass(q_blk, k_pad, v_pad, pos_pad, sub_plan, band, scale):
    """One retired-style band pass: the sub-plan's tile walk with the
    working-space band restriction the per-band launches used (without it,
    tile-granular walks of different bands double count shared pairs)."""
    B, nq, Bq, D = q_blk.shape
    bk = sub_plan.block_k
    k_r = k_pad.reshape(B, sub_plan.nkb, bk, D)
    v_r = v_pad.reshape(B, sub_plan.nkb, bk, D)
    pos_r = pos_pad.reshape(sub_plan.nkb, bk)
    pos_q = pos_pad.reshape(nq, Bq)
    table = jnp.asarray(sub_plan.kv_blocks)
    flags = jnp.asarray(sub_plan.flags)
    wq = (jnp.arange(nq) * Bq)[:, None] + jnp.arange(Bq)[None, :]

    def body(st, s):
        blk = jax.lax.dynamic_index_in_dim(table, s, 1, keepdims=False)
        fl = jax.lax.dynamic_index_in_dim(flags, s, 1, keepdims=False)
        k_blk = jnp.take(k_r, blk, axis=1)
        v_blk = jnp.take(v_r, blk, axis=1)
        pos_k = jnp.take(pos_r, blk, axis=0)
        scores = jnp.einsum("bnqd,bnkd->bnqk", q_blk, k_blk,
                            preferred_element_type=jnp.float32) * scale
        mask = sub_plan.step_mask(pos_q[:, :, None], pos_k[:, None, :],
                                  fl[:, None, None])
        if band is not None:
            rel_w = (blk[:, None] * bk + jnp.arange(bk)[None, :]
                     )[:, None, :] - wq[:, :, None]
            mask = mask & (rel_w >= band.lo) & (rel_w <= band.hi)
        return renorm.update(st, scores, v_blk, mask[None]), ()

    st = renorm.empty_state((B, nq, Bq), D)
    st, _ = jax.lax.scan(body, st, jnp.arange(sub_plan.max_steps,
                                              dtype=jnp.int32))
    return st


def per_band_forward(q, k, v, pattern, block_q, block_k):
    """The retired data flow: one windowed pass per band (band-restricted
    masks, global stripped), one global-column pass, partials merged
    pairwise — the timing/tile-count baseline the fused plan replaced.
    (The retired kernel fused the global column into its first launch
    rather than a separate pass, so this slightly favors the baseline.)"""
    B, N, D = q.shape
    scale = D ** -0.5
    sched = schedule(pattern, N)
    plan = build_plan(sched, block_q, block_k)
    qw, kw, vw, pos = _working_stream(q, k, v, sched, plan)
    q_blk = qw.reshape(B, plan.nq, block_q, D)

    passes = [(build_plan(dataclasses.replace(sched, bands=(b,), n_global=0),
                          block_q, block_k), b) for b in sched.bands]
    if sched.n_global > 0:
        passes.append((build_plan(dataclasses.replace(sched, bands=()),
                                  block_q, block_k), None))
    state = None
    for sp, band in passes:
        st = _band_pass(q_blk, kw, vw, pos, sp, band, scale)
        state = st if state is None else renorm.merge(state, st)
    return renorm.finalize(state, q.dtype).reshape(B, plan.n_pad, D)


def _time(fn, *args, reps=3) -> float:
    jax.block_until_ready(fn(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def collect(measure: bool = True, d_head: int = 64) -> dict:
    rng = np.random.default_rng(0)
    out = {}
    for name, pat, n, bq, bk in WORKLOADS:
        n = n if n is not None else pat.seq_len()
        sched = schedule(pat, n)
        plan = build_plan(sched, bq, bk)
        stats = plan.stats()
        entry = {
            "n": n, "block_q": bq, "block_k": bk,
            "bands": len(sched.bands),
            "fused": {
                "launches": stats["launches"],
                "executed_tiles": stats["executed_tiles"],
                "executed_pairs": stats["executed_pairs"],
                "utilization": stats["utilization"],
            },
            "per_band": {
                "launches": stats["per_band_launches"],
                "executed_tiles": stats["per_band_tiles"],
                "executed_pairs": stats["per_band_tiles"] * bq * bk,
                "utilization": stats["useful_pairs"]
                / max(stats["per_band_tiles"] * bq * bk, 1),
            },
            "dedup_ratio": stats["per_band_tiles"]
            / max(stats["executed_tiles"], 1),
        }
        if measure:
            q, k, v = (jnp.asarray(rng.normal(size=(2, n, d_head)),
                                   jnp.float32) for _ in range(3))
            fused = jax.jit(lambda a, b, c, p=pat: blockwise_attention(
                a, b, c, p, block_q=bq, block_k=bk))
            legacy = jax.jit(lambda a, b, c, p=pat: per_band_forward(
                a, b, c, p, bq, bk))
            entry["fused"]["wall_s"] = _time(fused, q, k, v)
            entry["per_band"]["wall_s"] = _time(legacy, q, k, v)
            entry["wall_speedup"] = (entry["per_band"]["wall_s"]
                                     / entry["fused"]["wall_s"])
        out[name] = entry
    return out


def _write_json(data, out_path, measure):
    """Write the artifact only for measured runs — a --quick/--no-measure
    pass must not clobber the committed JSON's wall/memory fields."""
    if not measure:
        return
    with open(out_path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)


def plan_benchmark(rows, measure: bool = True,
                   out_path: str = "BENCH_plan.json") -> dict:
    """benchmarks.run section: report + write BENCH_plan.json."""
    data = collect(measure=measure)
    for name, e in data.items():
        rows.append((f"plan/{name}/fused_tiles", e["fused"]["executed_tiles"],
                     f"launches={e['fused']['launches']}"))
        rows.append((f"plan/{name}/per_band_tiles",
                     e["per_band"]["executed_tiles"],
                     f"launches={e['per_band']['launches']}"))
        rows.append((f"plan/{name}/dedup_ratio", e["dedup_ratio"],
                     "per_band_tiles/fused_tiles"))
        if "wall_speedup" in e:
            rows.append((f"plan/{name}/wall_speedup", e["wall_speedup"],
                         f"fused={e['fused']['wall_s']*1e3:.1f}ms_perband="
                         f"{e['per_band']['wall_s']*1e3:.1f}ms"))
    _write_json(data, out_path, measure)
    return data


# ---------------------------------------------------------------------- #
# Backward accounting: fwd-plan dQ vs transposed-plan dK/dV vs dense
# ---------------------------------------------------------------------- #
def collect_bwd(measure: bool = True, d_head: int = 64) -> dict:
    from repro.core.blockwise import _blockwise_forward

    rng = np.random.default_rng(0)
    out = {}
    for name, pat, n, bq, bk in WORKLOADS:
        n = n if n is not None else pat.seq_len()
        sched = schedule(pat, n)
        plan = build_plan(sched, bq, bk)
        stats = plan.stats()
        dense_tiles = plan.nq * plan.nkb  # one dense pass, per direction
        entry = {
            "n": n, "block_q": bq, "block_k": bk,
            "dq_tiles": stats["bwd_dq_tiles"],            # forward tables
            "dkv_tiles": stats["bwd_dkv_tiles"],          # transposed tables
            "dense_tiles_per_pass": dense_tiles,
            "bwd_launches": stats["bwd_launches"],
            # dedup preservation: the transposed walk must not exceed the
            # forward walk (they regroup the SAME deduplicated visit set)
            "transposed_ratio": stats["bwd_dkv_tiles"]
            / max(stats["bwd_dq_tiles"], 1),
            "dense_ratio": 2 * dense_tiles
            / max(stats["bwd_dq_tiles"] + stats["bwd_dkv_tiles"], 1),
        }
        if measure:
            q, k, v, cot = (jnp.asarray(rng.normal(size=(2, n, d_head)),
                                        jnp.float32) for _ in range(4))

            def loss_fused(a, b, c, p=pat):
                return jnp.sum(blockwise_attention(
                    a, b, c, p, block_q=bq, block_k=bk) * cot)

            def loss_scan_autodiff(a, b, c, p=pat):
                # the retired backward: differentiate THROUGH the scan
                out_, _ = _blockwise_forward(a, b, c, p, bq, bk, None)
                return jnp.sum(out_ * cot)

            g_fused = jax.jit(jax.grad(loss_fused, argnums=(0, 1, 2)))
            g_scan = jax.jit(jax.grad(loss_scan_autodiff, argnums=(0, 1, 2)))
            entry["fused_bwd_wall_s"] = _time(g_fused, q, k, v)
            entry["scan_autodiff_wall_s"] = _time(g_scan, q, k, v)
            entry["bwd_speedup"] = (entry["scan_autodiff_wall_s"]
                                    / entry["fused_bwd_wall_s"])
            # The flash-style payoff: residuals are (out, m, l) — O(N) —
            # instead of XLA stashing every scan step's gathered tiles and
            # probability matrices. XLA's own accounting of temp buffers:
            for key, fn in (("fused", g_fused), ("scan_autodiff", g_scan)):
                ma = fn.lower(q, k, v).compile().memory_analysis()
                if isinstance(ma, list):  # old jax: one entry per device
                    ma = ma[0] if ma else None
                if ma is not None:  # some backends provide no analysis
                    entry[f"{key}_temp_bytes"] = int(ma.temp_size_in_bytes)
            if "scan_autodiff_temp_bytes" in entry \
                    and "fused_temp_bytes" in entry:
                entry["bwd_mem_ratio"] = (entry["scan_autodiff_temp_bytes"]
                                          / max(entry["fused_temp_bytes"], 1))
        out[name] = entry
    return out


def bwd_benchmark(rows, measure: bool = True,
                  out_path: str = "BENCH_bwd.json") -> dict:
    """benchmarks.run section: report + write BENCH_bwd.json."""
    data = collect_bwd(measure=measure)
    for name, e in data.items():
        rows.append((f"bwd/{name}/dq_tiles", e["dq_tiles"],
                     "forward-plan walk"))
        rows.append((f"bwd/{name}/dkv_tiles", e["dkv_tiles"],
                     "transposed-plan walk"))
        rows.append((f"bwd/{name}/transposed_ratio", e["transposed_ratio"],
                     "dkv_tiles/dq_tiles (dedup preserved ~1.0)"))
        rows.append((f"bwd/{name}/dense_ratio", e["dense_ratio"],
                     "2*dense_tiles/(dq+dkv)"))
        if "bwd_speedup" in e:
            rows.append((f"bwd/{name}/bwd_speedup", e["bwd_speedup"],
                         f"fused={e['fused_bwd_wall_s']*1e3:.1f}ms_scanAD="
                         f"{e['scan_autodiff_wall_s']*1e3:.1f}ms"))
        if "bwd_mem_ratio" in e:
            rows.append((f"bwd/{name}/bwd_mem_ratio", e["bwd_mem_ratio"],
                         f"scanAD_temp={e['scan_autodiff_temp_bytes']}"
                         f"_fused_temp={e['fused_temp_bytes']}"))
    _write_json(data, out_path, measure)
    return data


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_plan.json")
    ap.add_argument("--bwd-out", default="BENCH_bwd.json")
    ap.add_argument("--no-measure", action="store_true",
                    help="static tile/launch stats only (no wall-time; "
                         "does NOT rewrite the committed JSONs)")
    args = ap.parse_args()
    rows = []
    plan_benchmark(rows, measure=not args.no_measure, out_path=args.out)
    bwd_benchmark(rows, measure=not args.no_measure, out_path=args.bwd_out)
    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value:.6g},{derived}")
    if not args.no_measure:
        print(f"# wrote {args.out} and {args.bwd_out}")


if __name__ == "__main__":
    main()
