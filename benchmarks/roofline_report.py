"""Roofline table from the dry-run JSONs (repro/roofline/analysis.py).

  PYTHONPATH=src python -m benchmarks.roofline_report [--mesh pod1|pod2]
                                                      [--markdown]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load(mesh: str = "pod1", results: str = RESULTS):
    cells = []
    for f in sorted(glob.glob(os.path.join(results, f"*__{mesh}.json"))):
        with open(f) as fh:
            cells.append(json.load(fh))
    return cells


def row(c):
    r = c["roofline"]
    bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
    return dict(
        cell=f"{c['arch']}/{c['shape']}",
        compute_s=r["compute_s"], memory_s=r["memory_s"],
        collective_s=r["collective_s"], dominant=r["dominant"],
        bound_s=bound,
        model_tflops=r["model_flops"] / 1e12,
        useful=r["useful_flops_ratio"],
        roofline_frac=r["roofline_fraction"],
        mem_gb=c["memory"]["peak_bytes_per_device"] / 1e9,
        fits=c["memory"]["fits_16GB"],
    )


def print_table(cells, markdown=False):
    rows = [row(c) for c in cells]
    hdr = ["cell", "compute_s", "memory_s", "collective_s", "dominant",
           "useful", "roofline_frac", "mem_gb", "fits"]
    if markdown:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
        for r in rows:
            print("| " + " | ".join(
                (f"{r[h]:.4g}" if isinstance(r[h], float) else str(r[h]))
                for h in hdr) + " |")
    else:
        print(",".join(hdr))
        for r in rows:
            print(",".join(
                (f"{r[h]:.6g}" if isinstance(r[h], float) else str(r[h]))
                for h in hdr))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2"])
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--results", default=RESULTS)
    args = ap.parse_args()
    cells = load(args.mesh, args.results)
    if not cells:
        raise SystemExit(f"no dry-run results under {args.results} "
                         f"(run python -m repro.launch.dryrun --all first)")
    rows = print_table(cells, args.markdown)
    worst = min(rows, key=lambda r: r["roofline_frac"])
    print(f"\n# worst roofline fraction: {worst['cell']} "
          f"({worst['roofline_frac']:.4f})")
    colls = [r for r in rows if r["dominant"] == "collective"]
    if colls:
        top = max(colls, key=lambda r: r["collective_s"])
        print(f"# most collective-bound: {top['cell']} "
              f"({top['collective_s']:.2f}s)")


if __name__ == "__main__":
    main()
