"""Runtime-ExecutionPlan benchmark -> BENCH_dynamic.json.

Quantifies the content-based dynamic selector of :mod:`repro.core.dynamic`
on four axes, each gated in ``benchmarks/run.py``:

  * ``full_keep_parity`` — with ``keep >= max_steps`` the dynamic path must
    reproduce the static fused walk exactly (fwd + all grads <= 1e-4),
    the machinery-off invariant. Gated ``== 1.0``.
  * ``tile_ratio_vs_dense`` — executed KV tiles (counted from the emitted
    tables' non-padding slots) over the dense-causal tile count at the
    paper's long-sequence shape (N=2048, 64x64 tiles, keep=8). Gated
    ``< 0.5``: the dynamic plan must execute less than half of dense.
  * ``oracle_recall`` — selection quality against the exact oracle
    (per-tile attention mass from the dense causal softmax, batch-mean).
    Two measured workloads:
      - ``structured``: planted q/k-tile alignments (shared unit
        directions, far off the diagonal) — strict recall@keep of the
        oracle top-``keep``. Gated ``>= 0.9``.
      - ``random``: segment-topic inputs (topical runs of geometric
        length, the realistic "content decides" regime) — recall of the
        oracle top-``keep/2`` within the ``keep`` selected (the ANN-style
        recall@2x convention). Gated ``>= 0.9``.
    An ``isotropic`` i.i.d.-gaussian row is reported UNGATED: with no
    structure, per-tile masses differ by ~1.5% and pooled estimation has
    nothing to rank (documented floor, not a selector defect).
  * ``quality_vs_static`` — output error vs the dense-causal reference for
    the dynamic plan against a static sliding-window+sinks plan of a
    LARGER executed-tile budget, on the structured workload. The
    content-based plan must win (``err_ratio <= 1.0``) despite executing
    fewer tiles — the point of runtime ExecutionPlans.

Used by ``python -m benchmarks.run`` (section ``dynamic/``) and writable
standalone via ``python -m benchmarks.dynamic_stats``.
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import patterns as P
from repro.core.blockwise import blockwise_attention
from repro.core.dynamic import DynamicConfig, dynamic_attention, dynamic_tables

B, N, D, BLK = 2, 2048, 64, 64
KEEP = 8
CFG = DynamicConfig(keep=KEEP, pool_k=4)
CAUSAL = P.full(causal=True)
# static comparison plan: window 448 + 64 sinks executes MORE tiles than
# keep=8 (~276 vs 228 of 528 dense) — the handicap the dynamic plan beats.
STATIC_PAT = P.causal_sliding_window(448, n_sinks=64)
TOL = 1e-4


# ------------------------------- workloads -------------------------------

def _planted(rng, a: float = 3.0, per_row: int = KEEP):
    """Structured content routing: each k-tile carries one topic from an
    orthonormal basis (nt <= D, so topics don't cross-talk); each q-tile
    queries ``per_row`` of them — its own tile, the previous tile, and the
    rest randomly far off the diagonal, where no static pattern looks.
    The oracle top-``per_row`` per row is exactly the planted set."""
    q = rng.normal(size=(B, N, D))
    k = rng.normal(size=(B, N, D))
    nt = N // BLK
    basis, _ = np.linalg.qr(rng.normal(size=(D, nt)))
    for j in range(nt):
        k[:, j * BLK:(j + 1) * BLK] += a * basis[:, j]
    for i in range(nt):
        fixed = [j for j in (i, i - 1) if j >= 0]
        pool = np.setdiff1d(np.arange(i + 1), fixed)
        extra = (rng.choice(pool, size=min(per_row - len(fixed), pool.size),
                            replace=False) if pool.size else [])
        for j in [*fixed, *map(int, extra)]:
            q[:, i * BLK:(i + 1) * BLK] += a * basis[:, j]
    return jnp.asarray(q, jnp.float32), jnp.asarray(k, jnp.float32)


def _segments(rng, a: float = 1.5, n_topics: int = 16, mean_seg: int = 96):
    """Random-but-realistic: geometric-length topical runs; q and k inside
    a segment share that segment's topic direction."""
    topics = rng.normal(size=(n_topics, D))
    topics /= np.linalg.norm(topics, axis=1, keepdims=True)
    q = rng.normal(size=(B, N, D))
    k = rng.normal(size=(B, N, D))
    for b in range(B):
        pos = 0
        while pos < N:
            ln = max(16, int(rng.geometric(1.0 / mean_seg)))
            t = topics[rng.integers(n_topics)]
            q[b, pos:pos + ln] += a * t
            k[b, pos:pos + ln] += a * t
            pos += ln
    return jnp.asarray(q, jnp.float32), jnp.asarray(k, jnp.float32)


def _isotropic(rng):
    q = rng.normal(size=(B, N, D))
    k = rng.normal(size=(B, N, D))
    return jnp.asarray(q, jnp.float32), jnp.asarray(k, jnp.float32)


def _oracle_mass(q, k) -> np.ndarray:
    """Exact per-(q-tile, k-tile) attention mass, (nt, nt), batch-mean:
    dense causal softmax folded to tile granularity."""
    nt = N // BLK
    s = jnp.einsum("bqd,bkd->bqk", q, k) * (D ** -0.5)
    s = jnp.where(np.tril(np.ones((N, N), bool))[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return np.asarray(p.reshape(B, nt, BLK, nt, BLK).sum((2, 4)).mean(0))


def _recall(q, k, top_m: int) -> float:
    """Mean fraction of the oracle's top-``top_m`` tiles caught by the real
    selector's ``KEEP`` picks, over rows with more than KEEP candidates
    (rows that keep everything are excluded — no trivial inflation)."""
    mass = _oracle_mass(q, k)
    _, kvt, flg, _ = dynamic_tables(q, k, CAUSAL, CFG,
                                    block_q=BLK, block_k=BLK)
    kvt, flg = np.asarray(kvt), np.asarray(flg)
    hits, rows = 0, 0
    for i in range(N // BLK):
        if i + 1 <= KEEP:
            continue
        oracle = set(np.argsort(mass[i, :i + 1])[-top_m:].tolist())
        picked = set(kvt[i][flg[i] != 0].tolist())
        hits += len(oracle & picked) / top_m
        rows += 1
    return hits / rows


# ------------------------------- sections --------------------------------

def _full_keep_parity() -> dict:
    """keep >= max_steps must reproduce the static fused walk: fwd + all
    grads within 1e-4 across window/sink, longformer-global and dilated
    patterns."""
    rng = np.random.default_rng(0)
    worst = 0.0
    for pat in (P.causal_sliding_window(48, n_sinks=8),
                P.longformer(32, n_global=8),
                P.dilated_window(32, 2)):
        q, k, v, cot = (jnp.asarray(rng.normal(size=(2, 256, 32)),
                                    jnp.float32) for _ in range(4))
        cfg = DynamicConfig(keep=10 ** 6)
        ref = blockwise_attention(q, k, v, pat, block_q=32, block_k=32)
        out = dynamic_attention(q, k, v, pat, cfg, block_q=32, block_k=32)
        worst = max(worst, float(jnp.max(jnp.abs(out - ref))))
        g_ref = jax.grad(lambda a, b, c: jnp.sum(blockwise_attention(
            a, b, c, pat, block_q=32, block_k=32) * cot),
            argnums=(0, 1, 2))(q, k, v)
        g_dyn = jax.grad(lambda a, b, c: jnp.sum(dynamic_attention(
            a, b, c, pat, cfg, block_q=32, block_k=32) * cot),
            argnums=(0, 1, 2))(q, k, v)
        for ga, gb in zip(g_ref, g_dyn):
            worst = max(worst, float(jnp.max(jnp.abs(ga - gb))))
    return {"worst_abs_err": worst,
            "parity": 1.0 if worst <= TOL else 0.0, "tol": TOL}


def _tile_ratio() -> dict:
    """Executed tiles (non-padding slots of the emitted tables) over the
    dense causal count, at N=2048 / 64x64 / keep=8."""
    rng = np.random.default_rng(1)
    q, k = _segments(rng)
    plan, kvt, flg, _ = dynamic_tables(q, k, CAUSAL, CFG,
                                       block_q=BLK, block_k=BLK)
    executed = int((np.asarray(flg) != 0).sum())
    dense = int((plan.flags != 0).sum())
    return {"executed_tiles": executed, "dense_tiles": dense,
            "ratio": executed / dense, "keep": KEEP,
            "n": N, "block": BLK}


def _oracle_recall() -> dict:
    rng = np.random.default_rng(2)
    q, k = _planted(rng)
    structured = _recall(q, k, top_m=KEEP)
    q, k = _segments(np.random.default_rng(3))
    random_ = _recall(q, k, top_m=KEEP // 2)
    q, k = _isotropic(np.random.default_rng(4))
    iso = _recall(q, k, top_m=KEEP)
    return {"structured_recall_at_keep": structured,
            "random_recall_at_2x": random_,
            "isotropic_recall_ungated": iso,
            "keep": KEEP, "gate": 0.9}


def _quality_vs_static() -> dict:
    """On the structured workload: dynamic keep=8 vs a static
    window+sinks plan with a larger tile budget, both scored by rel-L2
    against the dense causal reference."""
    rng = np.random.default_rng(5)
    q, k = _planted(rng)
    v = jnp.asarray(rng.normal(size=(B, N, D)), jnp.float32)
    ref = blockwise_attention(q, k, v, CAUSAL, block_q=BLK, block_k=BLK)

    def rel(x):
        return float(jnp.linalg.norm(x - ref) / jnp.linalg.norm(ref))

    dyn_err = rel(dynamic_attention(q, k, v, CAUSAL, CFG,
                                    block_q=BLK, block_k=BLK))
    stat_err = rel(blockwise_attention(q, k, v, STATIC_PAT,
                                       block_q=BLK, block_k=BLK))
    plan, _, flg, _ = dynamic_tables(q, k, CAUSAL, CFG,
                                     block_q=BLK, block_k=BLK)
    from repro.core.scheduler import build_plan, schedule
    spl = build_plan(schedule(STATIC_PAT, N), BLK, BLK)
    return {"dynamic_rel_err": dyn_err, "static_rel_err": stat_err,
            "err_ratio": dyn_err / stat_err if stat_err > 0 else 0.0,
            "dynamic_tiles": int((np.asarray(flg) != 0).sum()),
            "static_tiles": int((spl.flags != 0).sum())}


def collect(measure: bool = True) -> dict:
    data = {"config": {"b": B, "n": N, "d": D, "block": BLK, "keep": KEEP,
                       "pool_k": CFG.pool_k}}
    if measure:
        data["full_keep_parity"] = _full_keep_parity()
        data["tile_ratio_vs_dense"] = _tile_ratio()
        data["oracle_recall"] = _oracle_recall()
        data["quality_vs_static"] = _quality_vs_static()
    return data


def _write_json(data, out_path, measure):
    if not measure:
        return
    with open(out_path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)


def dynamic_benchmark(rows, measure: bool = True,
                      out_path: str = "BENCH_dynamic.json") -> dict:
    """benchmarks.run section: report + write BENCH_dynamic.json."""
    data = collect(measure=measure)
    if measure:
        p = data["full_keep_parity"]
        rows.append(("dynamic/full_keep_parity", p["parity"],
                     f"worst_err={p['worst_abs_err']:.2e}_fwd+bwd"))
        t = data["tile_ratio_vs_dense"]
        rows.append(("dynamic/tile_ratio_vs_dense", t["ratio"],
                     f"{t['executed_tiles']}of{t['dense_tiles']}"
                     f"_keep{t['keep']}"))
        r = data["oracle_recall"]
        rows.append(("dynamic/oracle_recall_structured",
                     r["structured_recall_at_keep"],
                     f"planted_recall@{KEEP}"))
        rows.append(("dynamic/oracle_recall_random",
                     r["random_recall_at_2x"],
                     f"segments_recall@{KEEP // 2}of{KEEP}"))
        rows.append(("dynamic/oracle_recall_isotropic_ungated",
                     r["isotropic_recall_ungated"], "noise_floor"))
        s = data["quality_vs_static"]
        rows.append(("dynamic/quality_err_ratio_vs_static",
                     s["err_ratio"],
                     f"tiles_{s['dynamic_tiles']}v{s['static_tiles']}"))
    _write_json(data, out_path, measure)
    return data


def gates(rows) -> list:
    """The dynamic/ gate set, shared with benchmarks.run."""
    d = {name: value for name, value, _ in rows
         if name.startswith("dynamic/")}
    bad = []
    def _chk(key, ok):
        if key in d and not ok(d[key]):
            bad.append((key, d[key]))
    _chk("dynamic/full_keep_parity", lambda v: v == 1.0)
    _chk("dynamic/tile_ratio_vs_dense", lambda v: v < 0.5)
    _chk("dynamic/oracle_recall_structured", lambda v: v >= 0.9)
    _chk("dynamic/oracle_recall_random", lambda v: v >= 0.9)
    _chk("dynamic/quality_err_ratio_vs_static", lambda v: v <= 1.0)
    return bad


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_dynamic.json")
    ap.add_argument("--no-measure", action="store_true",
                    help="config echo only — exercises the import/CLI "
                         "path without the measured sections and does "
                         "NOT rewrite the committed JSON")
    args = ap.parse_args()
    rows = []
    dynamic_benchmark(rows, measure=not args.no_measure, out_path=args.out)
    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value:.6g},{derived}")
    if not args.no_measure:
        print(f"# wrote {args.out}")
    bad = gates(rows)
    if bad:
        for kk, vv in bad:
            print(f"CHECK-FAILED: {kk} = {vv}", file=sys.stderr)
        raise SystemExit(1)
    if not args.no_measure:
        print("# dynamic gates hold")


if __name__ == "__main__":
    main()
