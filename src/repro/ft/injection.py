"""Deterministic, seedable fault injection for the serving control plane.

The recovery story (:class:`~repro.ft.manager.ServeSupervisor`, the
``serve_ft`` test suite, BENCH_serve.json §recovery) is only testable if
faults are reproducible. This module injects three fault classes:

* **step-fn crashes** — :class:`~repro.ft.faults.StepCrash` raised before
  the chosen engine step runs (the kill-the-process stand-in; the engine
  state at the crash point is whatever the last completed step left);
* **allocator exhaustion** — admission sees zero free pages for a window
  of steps (:attr:`FaultPlan.exhaust_steps` gates
  ``Batcher.admission_gate``), driving the page-pressure paths: stalled
  admission, preemption, and — when nothing at all is in flight — the
  engine's recoverable :class:`~repro.ft.faults.ResourceExhausted`;
* **straggler steps** — an injected sleep before the step, flagged by the
  supervisor's :class:`~repro.ft.manager.StragglerWatchdog`.

Faults are keyed by the injector's **attempt counter**, which increments on
every ``before_step`` call and NEVER rewinds on restore — so each planned
crash fires exactly once and every exhaustion window eventually passes,
regardless of how far a restart rewinds the engine's own step counter.
"""
from __future__ import annotations

import dataclasses
import time
from typing import FrozenSet

import numpy as np

from repro.ft.faults import StepCrash


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Which attempt indices fault, and how. Build explicitly for targeted
    tests or via :meth:`sample` for seeded random soak runs."""
    crash_steps: FrozenSet[int] = frozenset()
    exhaust_steps: FrozenSet[int] = frozenset()
    straggle_steps: FrozenSet[int] = frozenset()
    straggle_s: float = 0.25

    @classmethod
    def sample(cls, seed: int, n_steps: int, *, crash_rate: float = 0.0,
               exhaust_rate: float = 0.0, straggle_rate: float = 0.0,
               straggle_s: float = 0.25) -> "FaultPlan":
        """Deterministic plan: each attempt in ``[0, n_steps)`` faults
        independently at the given rates (one seeded stream per class)."""
        rng = np.random.default_rng(seed)

        def pick(rate):
            return frozenset(
                int(i) for i in np.nonzero(rng.random(n_steps) < rate)[0])

        return cls(crash_steps=pick(crash_rate),
                   exhaust_steps=pick(exhaust_rate),
                   straggle_steps=pick(straggle_rate),
                   straggle_s=straggle_s)


class FaultInjector:
    """Executes a :class:`FaultPlan` against a supervised serving run.

    The supervisor calls :meth:`before_step` ahead of every engine step and
    :meth:`attach` after every engine (re)build; ``injected`` counts what
    actually fired (tests assert against it)."""

    def __init__(self, plan: FaultPlan, sleep=time.sleep):
        self.plan = plan
        self._sleep = sleep
        self.attempts = 0
        self._current = -1
        self.injected = {"crashes": 0, "exhaustions": 0, "stragglers": 0}

    def attach(self, engine) -> None:
        """Wire the exhaustion gate into the engine's admission path."""
        engine.batcher.admission_gate = self.admission_open

    def admission_open(self) -> bool:
        """False while the current attempt sits in an exhaustion window —
        admission then behaves exactly as if the page pool were empty."""
        if self._current in self.plan.exhaust_steps:
            self.injected["exhaustions"] += 1
            return False
        return True

    def before_step(self, engine_step: int) -> None:
        """Fire this attempt's faults. Raises
        :class:`~repro.ft.faults.StepCrash` for crash attempts; sleeps for
        straggler attempts; exhaustion is consulted lazily via the gate."""
        a = self.attempts
        self.attempts += 1
        self._current = a
        if a in self.plan.straggle_steps:
            self.injected["stragglers"] += 1
            self._sleep(self.plan.straggle_s)
        if a in self.plan.crash_steps:
            self.injected["crashes"] += 1
            raise StepCrash(f"injected crash at attempt {a} "
                            f"(engine step {engine_step})")
