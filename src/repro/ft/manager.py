"""Run manager: straggler watchdog, failure/restart loops, elastic rescale.

What actually runs on the fleet:

* **StragglerWatchdog** — per-step wall-time EWMA; a step exceeding
  ``threshold x`` the EWMA is flagged (on a real pod this triggers hot-spare
  swap / re-slicing; here it's surfaced in metrics and tested by injection).
  It is deliberately generic over what a "step" is: the train loop feeds it
  train steps, :class:`ServeSupervisor` feeds it serving-engine steps.
* **run_with_restarts** — the training supervisor loop: run step fn, on a
  recoverable fault (:data:`repro.ft.faults.RECOVERABLE`) restore the latest
  checkpoint and continue — under a bounded restart budget with exponential
  backoff, so a deterministically failing step raises
  :class:`~repro.ft.faults.RestartsExhausted` instead of looping forever.
* **ServeSupervisor** — the serving twin: drives a
  :class:`~repro.serve.engine.ContinuousEngine` step by step, snapshotting
  the FULL serving state (slabs + scales, page tables, request lifecycle —
  see ``ContinuousEngine.state_dict``) every ``checkpoint_every`` steps
  through the atomic keep-k writer, and on a fault rebuilds the engine and
  restores the latest snapshot. Greedy token output is **exactly-once**: a
  run killed at any step and resumed emits tokens identical to an
  uninterrupted run (tests/test_serve_ft.py). Work lost per restart is
  bounded by the checkpoint interval.
* **elastic rescale** — because checkpoints are mesh-portable
  (ft/checkpoint.py), a job interrupted on mesh A restarts on mesh B with a
  different device count; ``reshard`` re-places a live pytree.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax

from repro.ft.checkpoint import CheckpointManager
from repro.ft.faults import RECOVERABLE, RestartsExhausted, StepCrash
from repro.obs import Observability

_BACKOFF_CAP_S = 30.0


@dataclasses.dataclass
class StragglerWatchdog:
    threshold: float = 3.0      # x EWMA counts as straggler
    alpha: float = 0.1          # EWMA smoothing
    warmup_steps: int = 3       # compile steps excluded
    _ewma: Optional[float] = None
    _seen: int = 0
    events: int = 0

    def observe(self, step_time: float) -> bool:
        """Record one step; True if flagged as straggler."""
        self._seen += 1
        if self._seen <= self.warmup_steps:
            return False
        if self._ewma is None:
            self._ewma = step_time
            return False
        flagged = step_time > self.threshold * self._ewma
        if flagged:
            self.events += 1
        else:  # stragglers don't poison the baseline
            self._ewma = (1 - self.alpha) * self._ewma + self.alpha * step_time
        return flagged


def reshard(tree: Any, shardings: Any) -> Any:
    """Re-place a live pytree onto new shardings (elastic rescale)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(jax.numpy.asarray(x), s),
        tree, shardings)


def _backoff_sleep(backoff: float, n_restarts: int, sleep=time.sleep):
    if backoff > 0.0:
        sleep(min(backoff * (2 ** max(n_restarts - 1, 0)), _BACKOFF_CAP_S))


def run_with_restarts(step_fn: Callable, state: Any, n_steps: int,
                      manager, *, checkpoint_every: int = 50,
                      fail_at: Optional[set] = None,
                      watchdog: Optional[StragglerWatchdog] = None,
                      start_step: int = 0, max_restarts: int = 16,
                      backoff: float = 0.0, recoverable=RECOVERABLE,
                      obs: Optional[Observability] = None):
    """Supervisor loop with checkpoint/restart semantics.

    ``step_fn(state, step) -> state``; ``fail_at``: steps at which to inject
    a :class:`~repro.ft.faults.StepCrash` (tests). Only ``recoverable``
    exceptions (default: the :mod:`repro.ft.faults` taxonomy — NOT bare
    ``RuntimeError``) trigger a restore; each restart sleeps
    ``backoff * 2**k`` (capped) and after ``max_restarts`` restarts the
    loop raises :class:`~repro.ft.faults.RestartsExhausted` chaining the
    last fault — a deterministically failing step can no longer spin
    forever. ``obs``: checkpoint saves, faults, restores, and straggler
    flags land on the tracer's ``ft`` track + the registry (the same event
    vocabulary :class:`ServeSupervisor` emits). Returns (state, history
    dict).
    """
    fail_at = set(fail_at or ())
    obs = obs if obs is not None else Observability()
    history = {"restarts": 0, "straggler_events": 0, "steps_run": 0}
    step = start_step
    while step < n_steps:
        try:
            t0 = time.perf_counter()
            if step in fail_at:
                fail_at.discard(step)
                raise StepCrash(f"injected failure at step {step}")
            with obs.tracer.span("train.step", track="ft", step=step):
                state = step_fn(state, step)
            dt = time.perf_counter() - t0
            if watchdog is not None and watchdog.observe(dt):
                history["straggler_events"] += 1
                obs.registry.inc("ft_straggler_events")
                obs.tracer.instant("ft.straggler", track="ft", step=step,
                                   step_time_s=round(dt, 6))
            history["steps_run"] += 1
            if checkpoint_every and (step + 1) % checkpoint_every == 0:
                manager.save(state, step + 1)
                obs.tracer.instant("ft.snapshot", track="ft", step=step + 1)
        except recoverable as e:
            history["restarts"] += 1
            obs.registry.inc("ft_faults", kind=type(e).__name__)
            obs.tracer.instant("ft.fault", track="ft", step=step,
                               kind=type(e).__name__, message=str(e))
            if history["restarts"] > max_restarts:
                raise RestartsExhausted(
                    f"step fn still failing after {max_restarts} restarts "
                    f"(last fault: {e})") from e
            _backoff_sleep(backoff, history["restarts"])
            restored, ck_step = manager.restore_latest(state)
            if restored is None:
                step = start_step  # no checkpoint yet: restart from scratch
            else:
                state, step = restored, ck_step
            obs.registry.inc("ft_restarts")
            obs.tracer.instant("ft.restore", track="ft", step=step,
                               restarts=history["restarts"])
            continue
        step += 1
    manager.wait()
    return state, history


class ServeSupervisor:
    """Fault-tolerant driver for the continuous serving engine.

    ``make_engine()`` must return a fully-loaded engine — constructed AND
    with its requests submitted; the supervisor then overwrites the
    engine's state wholesale from the latest snapshot (if any), so the
    factory is also the "restart from scratch" path when no checkpoint
    exists yet. It may return a fresh engine each call (the true
    killed-process semantics — also how the 8-shard subprocess test runs
    it) or the same engine object (in-process recovery; ``load_state`` is
    a wholesale replacement, so a boundary-consistent engine is restored
    correctly either way, without re-jitting).

    Per step: run injected faults (``injector.before_step``), one
    ``engine.step``, feed the watchdog, snapshot every
    ``checkpoint_every`` engine steps. On a recoverable fault
    (:data:`repro.ft.faults.RECOVERABLE`): bounded restarts with
    exponential backoff, engine rebuilt + restored from the latest
    snapshot. ``run()`` returns ``(engine, history)``; completed tokens
    are ``engine.batcher.results()``, expired/failed requests
    ``engine.batcher.failures()``.
    """

    def __init__(self, make_engine: Callable, params, ckpt_dir: str, *,
                 checkpoint_every: int = 4, max_restarts: int = 4,
                 backoff: float = 0.0, keep: int = 3,
                 injector=None, watchdog: Optional[StragglerWatchdog] = None,
                 timer: Callable[[], float] = time.perf_counter,
                 max_steps: Optional[int] = None,
                 obs: Optional[Observability] = None,
                 on_step: Optional[Callable[[Any, dict], None]] = None):
        self.make_engine = make_engine
        self.params = params
        self.manager = CheckpointManager(ckpt_dir, keep=keep,
                                         async_write=False)
        self.checkpoint_every = checkpoint_every
        self.max_restarts = max_restarts
        self.backoff = backoff
        self.injector = injector
        self.watchdog = watchdog
        self.timer = timer
        self.max_steps = max_steps
        # No explicit obs: adopt the first engine's bundle in _boot, so the
        # supervisor's kill/restore timeline lands in the SAME exported
        # trace as the engine's step spans (the whole point of the track).
        self.obs = obs
        self.on_step = on_step   # (engine, history) after every good step

    def _boot(self):
        engine = self.make_engine()
        if self.obs is None:
            self.obs = getattr(engine, "obs", None) or Observability()
        restored, ck_step = self.manager.restore_latest(engine.state_dict())
        if restored is not None:
            engine.load_state(restored)
            self.obs.registry.inc("ft_restores")
            self.obs.tracer.instant("ft.restore", track="ft", step=ck_step)
        if self.injector is not None:
            self.injector.attach(engine)
        return engine

    def run(self):
        history = {"restarts": 0, "straggler_events": 0, "steps_run": 0,
                   "steps_lost": 0, "max_step_loss": 0, "faults": []}
        engine = self._boot()
        while True:
            step = engine.counters["engine_steps"]
            if self.max_steps is not None \
                    and history["steps_run"] >= self.max_steps:
                break
            try:
                if self.injector is not None:
                    self.injector.before_step(step)
                t0 = self.timer()
                more = engine.step(self.params)
                dt = self.timer() - t0
                if self.watchdog is not None and self.watchdog.observe(dt):
                    history["straggler_events"] += 1
                    self.obs.registry.inc("ft_straggler_events")
                    self.obs.tracer.instant("ft.straggler", track="ft",
                                            step=step,
                                            step_time_s=round(dt, 6))
                history["steps_run"] += 1
                done = engine.counters["engine_steps"]
                if more and self.checkpoint_every \
                        and done % self.checkpoint_every == 0:
                    self.manager.save(engine.state_dict(), done)
                    self.obs.tracer.instant("ft.snapshot", track="ft",
                                            step=done)
                if self.on_step is not None:
                    self.on_step(engine, history)
                if not more:
                    break
            except RECOVERABLE as e:
                history["restarts"] += 1
                history["faults"].append(f"{type(e).__name__}: {e}")
                self.obs.tracer.instant("ft.fault", track="ft", step=step,
                                        kind=type(e).__name__,
                                        message=str(e))
                if history["restarts"] > self.max_restarts:
                    raise RestartsExhausted(
                        f"serving still failing after {self.max_restarts} "
                        f"restarts (last fault: {e})") from e
                _backoff_sleep(self.backoff, history["restarts"])
                done_before = engine.counters["engine_steps"]
                engine = self._boot()
                lost = max(done_before - engine.counters["engine_steps"], 0)
                history["steps_lost"] += lost
                history["max_step_loss"] = max(history["max_step_loss"],
                                               lost)
                # Counters AFTER _boot: load_state wholesale-restores a
                # shared registry, so pre-restore increments would be wiped.
                self.obs.registry.inc("ft_faults", kind=type(e).__name__)
                self.obs.registry.inc("ft_restarts")
                self.obs.registry.inc("ft_steps_lost", lost)
                self.obs.tracer.instant("ft.restart", track="ft",
                                        step=engine.counters["engine_steps"],
                                        steps_lost=lost,
                                        restarts=history["restarts"])
        self.manager.wait()
        return engine, history
