"""Run manager: straggler watchdog, failure/restart loop, elastic rescale.

What actually runs on the fleet:

* **StragglerWatchdog** — per-step wall-time EWMA; a step exceeding
  ``threshold x`` the EWMA is flagged (on a real pod this triggers hot-spare
  swap / re-slicing; here it's surfaced in metrics and tested by injection).
* **run_with_restarts** — the supervisor loop: run step fn, on (injected or
  real) failure restore the latest checkpoint and continue. Together with
  atomic checkpoints this gives at-most-one-interval loss of work.
* **elastic rescale** — because checkpoints are mesh-portable
  (ft/checkpoint.py), a job interrupted on mesh A restarts on mesh B with a
  different device count; ``reshard`` re-places a live pytree.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax


@dataclasses.dataclass
class StragglerWatchdog:
    threshold: float = 3.0      # x EWMA counts as straggler
    alpha: float = 0.1          # EWMA smoothing
    warmup_steps: int = 3       # compile steps excluded
    _ewma: Optional[float] = None
    _seen: int = 0
    events: int = 0

    def observe(self, step_time: float) -> bool:
        """Record one step; True if flagged as straggler."""
        self._seen += 1
        if self._seen <= self.warmup_steps:
            return False
        if self._ewma is None:
            self._ewma = step_time
            return False
        flagged = step_time > self.threshold * self._ewma
        if flagged:
            self.events += 1
        else:  # stragglers don't poison the baseline
            self._ewma = (1 - self.alpha) * self._ewma + self.alpha * step_time
        return flagged


def reshard(tree: Any, shardings: Any) -> Any:
    """Re-place a live pytree onto new shardings (elastic rescale)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(jax.numpy.asarray(x), s),
        tree, shardings)


def run_with_restarts(step_fn: Callable, state: Any, n_steps: int,
                      manager, *, checkpoint_every: int = 50,
                      fail_at: Optional[set] = None,
                      watchdog: Optional[StragglerWatchdog] = None,
                      start_step: int = 0):
    """Supervisor loop with checkpoint/restart semantics.

    ``step_fn(state, step) -> state``; ``fail_at``: steps at which to inject
    a failure (tests). Returns (state, history dict).
    """
    fail_at = set(fail_at or ())
    history = {"restarts": 0, "straggler_events": 0, "steps_run": 0}
    step = start_step
    while step < n_steps:
        try:
            t0 = time.perf_counter()
            if step in fail_at:
                fail_at.discard(step)
                raise RuntimeError(f"injected failure at step {step}")
            state = step_fn(state, step)
            dt = time.perf_counter() - t0
            if watchdog is not None and watchdog.observe(dt):
                history["straggler_events"] += 1
            history["steps_run"] += 1
            if checkpoint_every and (step + 1) % checkpoint_every == 0:
                manager.save(state, step + 1)
            step += 1
        except RuntimeError:
            history["restarts"] += 1
            restored, ck_step = manager.restore_latest(state)
            if restored is None:
                step = start_step  # no checkpoint yet: restart from scratch
            else:
                state, step = restored, ck_step
    manager.wait()
    return state, history
