from repro.ft.checkpoint import CheckpointManager, save, restore, latest_step
from repro.ft.manager import StragglerWatchdog, run_with_restarts, reshard
