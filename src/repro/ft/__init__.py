from repro.ft.checkpoint import (CheckpointManager, latest_step, restore,
                                 save, sweep_stale_tmp)
from repro.ft.faults import (RECOVERABLE, Fault, QueueFull, RejectedRequest,
                             ResourceExhausted, RestartsExhausted, StepCrash)
from repro.ft.injection import FaultInjector, FaultPlan
from repro.ft.manager import (ServeSupervisor, StragglerWatchdog, reshard,
                              run_with_restarts)
