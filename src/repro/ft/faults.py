"""Failure taxonomy shared by the training and serving control planes.

The old supervisor loop caught bare ``RuntimeError`` — too narrow to cover
real faults and too broad to distinguish "retry will help" from "retry will
loop forever". This module pins the contract instead:

* :class:`Fault` subclasses are **recoverable**: a restart-from-checkpoint
  has a chance of making progress (the fault is transient — a crashed step,
  an injected failure, resource pressure that drains over time). Supervisors
  (``run_with_restarts``, ``ServeSupervisor``) catch exactly
  :data:`RECOVERABLE` and nothing else, under a bounded restart budget.
* :class:`RestartsExhausted` is **terminal**: the restart budget ran out on
  a deterministically failing step — surfacing the original fault via
  ``__cause__`` instead of looping forever.
* :class:`RejectedRequest` / :class:`QueueFull` are **admission verdicts**,
  not faults: raised synchronously at ``submit`` so the caller (not a
  restart loop) decides what to do — resize, shed load, or retry later.

Every class subclasses ``RuntimeError`` so pre-taxonomy callers that caught
``RuntimeError`` keep working.
"""
from __future__ import annotations


class Fault(RuntimeError):
    """Base of recoverable faults: restart-from-checkpoint may help."""


class StepCrash(Fault):
    """A step function died mid-step (real crash or injected)."""


class ResourceExhausted(Fault):
    """A resource pool (KV pages, ...) could not satisfy a request that
    normally fits — transient pressure, recoverable by backoff/preemption."""


class RestartsExhausted(RuntimeError):
    """Terminal: the supervisor's restart budget ran out. ``__cause__``
    carries the last underlying fault."""


class RejectedRequest(ValueError):
    """Admission verdict at ``submit``: the request can NEVER fit the
    engine's layout/pool — no amount of waiting or preemption helps."""


class QueueFull(RuntimeError):
    """Admission backpressure at ``submit``: the bounded queue is full;
    the caller should shed load or retry later."""


#: What supervisor loops catch. Deliberately NOT bare RuntimeError: a
#: deterministic bug must propagate, not restart forever.
RECOVERABLE = (Fault,)
