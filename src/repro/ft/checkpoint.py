"""Checkpointing: atomic, keep-k, async, mesh-portable.

Design points for 1000+-node runs:
  * **Atomic**: write to ``<dir>/tmp.<step>`` then ``os.rename`` — a
    preempted writer never corrupts the latest checkpoint.
  * **Keep-k GC**: bounded disk usage under frequent checkpoints.
  * **Async**: the device->host copy is synchronous (cheap) but serialization
    happens on a background thread, overlapping the next train steps.
  * **Mesh-portable**: checkpoints store plain host numpy per leaf (gathered)
    plus the pytree structure; ``restore(..., shardings=...)`` re-places onto
    ANY mesh — this is the elastic-rescale path (tested 8 -> 4 devices).

On a real multi-host pod each host would write only its addressable shards
(same layout, one subdir per host); single-process here, so the gather is a
no-op device->host copy.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_SEP = "::"
_TMP_RE = re.compile(r"tmp\.(\d+)\.(\d+)")


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True  # exists, owned by someone else
    except OSError:
        return False
    return True


def sweep_stale_tmp(path: str) -> int:
    """Remove orphaned ``tmp.<step>.<pid>`` dirs (a writer killed between
    ``makedirs`` and the atomic ``os.rename`` leaks its tmp dir forever).

    A tmp dir is stale when its writer pid is dead, or is THIS process
    (writes within a process are serialized — see ``CheckpointManager.save``
    joining the previous writer thread — so a same-pid tmp can only be an
    abandoned earlier attempt). Returns the number of dirs removed; called
    from :func:`save` before each write and from the keep-k GC."""
    removed = 0
    if not os.path.isdir(path):
        return removed
    for d in os.listdir(path):
        m = _TMP_RE.fullmatch(d)
        if m and (int(m.group(2)) == os.getpid()
                  or not _pid_alive(int(m.group(2)))):
            shutil.rmtree(os.path.join(path, d), ignore_errors=True)
            removed += 1
    return removed


def _flatten(tree, upcast: bool = True):
    """Flatten to {path: np.array}. ``upcast`` converts ml_dtypes leaves
    (bf16/f8 — npz-unsafe) to float32; restore() recasts to the original
    dtype, which it reads from the un-upcast `like` tree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        arr = np.asarray(leaf)
        if upcast and arr.dtype.kind not in "fiub?":
            arr = arr.astype(np.float32)
        out[key] = arr
    return out, treedef


def save(path: str, tree: Any, step: int) -> str:
    """Atomic checkpoint write. Returns the final directory."""
    final = os.path.join(path, f"step_{step:08d}")
    sweep_stale_tmp(path)
    tmp = os.path.join(path, f"tmp.{step}.{os.getpid()}")
    os.makedirs(tmp, exist_ok=True)
    flat, _ = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    meta = {"step": step, "keys": sorted(flat.keys())}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [int(m.group(1)) for d in os.listdir(path)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def restore(path: str, like: Any, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``like``. ``shardings`` (same pytree
    structure, or None) places each leaf onto the target mesh — the same
    checkpoint restores onto any device topology (elastic rescale)."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {path}")
    d = os.path.join(path, f"step_{step:08d}")
    data = np.load(os.path.join(d, "arrays.npz"))
    flat_like, treedef = _flatten(like, upcast=False)
    missing = set(flat_like) - set(data.files)
    if missing:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]}...")
    shard_flat = (jax.tree.leaves(shardings) if shardings is not None
                  else [None] * len(flat_like))
    leaves = []
    for key, sh in zip(sorted(flat_like.keys()), shard_flat):
        arr = data[key]
        like_leaf = flat_like[key]
        arr = arr.astype(like_leaf.dtype)
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jax.numpy.asarray(arr))
    # sorted(keys) matches tree_flatten order for dict-only trees; rebuild:
    order = {k: i for i, k in enumerate(sorted(flat_like.keys()))}
    flat_keys = list(flat_like.keys())
    rebuilt = [leaves[order[k]] for k in flat_keys]
    return jax.tree_util.tree_unflatten(treedef, rebuilt)


class CheckpointManager:
    """keep-k GC + async background writes + restart bookkeeping."""

    def __init__(self, path: str, keep: int = 3, async_write: bool = True):
        self.path = path
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        os.makedirs(path, exist_ok=True)

    def _gc(self):
        sweep_stale_tmp(self.path)
        steps = sorted(int(m.group(1)) for d in os.listdir(self.path)
                       if (m := re.fullmatch(r"step_(\d+)", d)))
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.path, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, tree: Any, step: int):
        self.wait()
        # Synchronous device->host snapshot (consistent view), async write.
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            save(self.path, host_tree, step)
            self._gc()

        if self.async_write:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def restore_latest(self, like: Any, shardings: Any = None):
        self.wait()
        step = latest_step(self.path)
        if step is None:
            return None, None
        return restore(self.path, like, step, shardings), step
