from repro.optim import adamw
from repro.optim.schedule import Schedule
