"""AdamW with dtype-configurable moments (built from scratch — no optax).

At 480B/1T-parameter scale the optimizer state dominates HBM: fp32 m/v for a
1T model is 8 TB. ``moment_dtype="bfloat16"`` halves it (the launch.specs
train-cell default above 10B params); state is sharded exactly like the
parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    # master fp32 copy of bf16 params (None = update in param dtype)
    use_master: bool = False


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any
    master: Any  # fp32 params or None


def init(cfg: AdamWConfig, params) -> AdamWState:
    mdt = jnp.dtype(cfg.moment_dtype)

    def zeros(p):
        return jnp.zeros(p.shape, mdt)

    master = (jax.tree.map(lambda p: p.astype(jnp.float32), params)
              if cfg.use_master else None)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params),
                      master=master)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def update(cfg: AdamWConfig, state: AdamWState, params, grads,
           lr_scale: jax.Array | float = 1.0):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)

    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    base = state.master if cfg.use_master else params

    def upd(p, g, m, v):
        mf = m.astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        vf = v.astype(jnp.float32) * cfg.b2 + g * g * (1 - cfg.b2)
        mhat = mf / b1c
        vhat = vf / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p, mf.astype(m.dtype), vf.astype(v.dtype)

    out = jax.tree.map(upd, base, grads, state.m, state.v)
    treedef = jax.tree.structure(base)
    flat = jax.tree.leaves(out, is_leaf=lambda x: isinstance(x, tuple))
    new_base = treedef.unflatten([t[0] for t in flat])
    new_m = treedef.unflatten([t[1] for t in flat])
    new_v = treedef.unflatten([t[2] for t in flat])

    if cfg.use_master:
        new_params = jax.tree.map(lambda nb, p: nb.astype(p.dtype),
                                  new_base, params)
        new_master = new_base
    else:
        new_params = jax.tree.map(lambda nb, p: nb.astype(p.dtype),
                                  new_base, params)
        new_master = None
    new_state = AdamWState(step=step, m=new_m, v=new_v, master=new_master)
    return new_params, new_state, {"grad_norm": gnorm,
                                   "lr": jnp.asarray(lr, jnp.float32)}
