"""LR schedules: linear warmup + {cosine, rsqrt, constant} decay."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Schedule:
    warmup_steps: int = 100
    total_steps: int = 10000
    kind: str = "cosine"       # cosine | rsqrt | constant
    min_ratio: float = 0.1

    def __call__(self, step):
        s = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(s / jnp.maximum(self.warmup_steps, 1), 1.0)
        if self.kind == "constant":
            decay = 1.0
        elif self.kind == "rsqrt":
            decay = jnp.sqrt(jnp.maximum(self.warmup_steps, 1) /
                             jnp.maximum(s, self.warmup_steps))
        else:  # cosine
            frac = jnp.clip((s - self.warmup_steps) /
                            max(self.total_steps - self.warmup_steps, 1),
                            0.0, 1.0)
            decay = self.min_ratio + (1 - self.min_ratio) * 0.5 * (
                1 + jnp.cos(jnp.pi * frac))
        return warm * decay
