"""Roofline analysis from compiled dry-run artifacts
(tabulated by benchmarks/roofline_report.py).

Three terms per (arch x shape x mesh) cell, all in seconds:

  compute    = HLO_FLOPs_per_device / peak_FLOP/s
  memory     = HLO_bytes_per_device / HBM_bw
  collective = sum over collective ops of ring-model time over ICI links

``cost_analysis()`` is per-device (post-SPMD partitioning — verified).
Collective bytes are NOT in cost_analysis: we parse the compiled HLO text and
apply standard ring formulas (S = per-device payload bytes, p = group size):

  all-reduce       2 * S * (p-1)/p        (reduce-scatter + all-gather ring)
  all-gather       S * (p-1)/p            (S = gathered output size)
  reduce-scatter   S_in * (p-1)/p         (we see the op output; S_in = S*p)
  all-to-all       S * (p-1)/p
  collective-permute  S

One ICI link per collective is assumed (conservative: v5e has 4 per chip and
bidirectional rings; real overlap makes this an upper bound on the term).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, world: int) -> int:
    # iota format: replica_groups=[G,S]<=[N]...
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]", line)
    if m:
        return int(m.group(2))
    # explicit: replica_groups={{0,1,2,3},...}
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    # empty groups = all devices
    return world


def parse_collectives(hlo_text: str, world: int) -> List[Dict]:
    """Extract (kind, out_bytes, group) for every collective op."""
    out = []
    for line in hlo_text.splitlines():
        m = re.search(r"=\s+((?:\([^)]*\)|\S+))\s+(" +
                      "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(", line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        if "-done(" in line:  # async pair: count only the start
            continue
        size = _shape_bytes(type_str)
        if size == 0:
            continue
        out.append({"kind": kind, "bytes": size,
                    "group": _group_size(line, world)})
    return out


def collective_seconds(colls: List[Dict], link_bw: float = ICI_BW) -> float:
    t = 0.0
    for c in colls:
        s, p = c["bytes"], max(c["group"], 1)
        frac = (p - 1) / p if p > 1 else 0.0
        if c["kind"] == "all-reduce":
            moved = 2 * s * frac
        elif c["kind"] == "all-gather":
            moved = s * frac
        elif c["kind"] == "reduce-scatter":
            moved = s * p * frac  # we parsed the (scattered) output
        elif c["kind"] == "all-to-all":
            moved = s * frac
        else:  # collective-permute
            moved = s
        t += moved / link_bw
    return t


def collective_bytes_by_kind(colls: List[Dict]) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for c in colls:
        out[c["kind"]] = out.get(c["kind"], 0) + c["bytes"]
    return out


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    n_chips: int
    model_flops: float            # 6*N_active*tokens (train) etc.
    collectives: Dict[str, int]

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / total compiled FLOPs — catches remat/redundancy."""
        total = self.flops_per_device * self.n_chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved if the dominant term
        were the runtime: (model_flops/chips/peak) / bound."""
        ideal = self.model_flops / self.n_chips / PEAK_FLOPS_BF16
        return ideal / self.bound_s if self.bound_s else 0.0

    def to_dict(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "n_chips": self.n_chips, "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "collective_bytes": self.collectives,
        }


def analyze(cost: dict, hlo_text: str, n_chips: int,
            model_flops: float) -> Roofline:
    """Primary source: the trip-count-aware HLO analyzer (XLA's own
    cost_analysis counts while bodies once — see hlo_analyzer docstring).
    ``cost`` (XLA's numbers) is kept as a floor/sanity reference."""
    from repro.roofline.hlo_analyzer import analyze_hlo

    hc = analyze_hlo(hlo_text, n_chips)
    if isinstance(cost, (list, tuple)):  # older jax: one dict per device
        cost = cost[0] if cost else {}
    flops = max(hc.flops, float(cost.get("flops", 0.0)))
    byts = max(hc.bytes, float(cost.get("bytes accessed", 0.0)))
    colls = hc.collectives
    return Roofline(
        compute_s=flops / PEAK_FLOPS_BF16,
        memory_s=byts / HBM_BW,
        collective_s=collective_seconds(colls),
        flops_per_device=flops, bytes_per_device=byts, n_chips=n_chips,
        model_flops=model_flops,
        collectives=collective_bytes_by_kind(colls))


# ----------------------- model FLOPs accounting -------------------------- #
def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS per the assignment: 6*N*D (train, dense), 6*N_active*D
    (MoE); forward-only shapes use 2*N*D; decode uses 2*N_active per token."""
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence in the batch
    return 2.0 * n_active * shape.global_batch


# ------------------- Pallas-kernel-target memory model ------------------- #
def kernel_attention_bytes(pattern, n: int, n_heads: int, head_dim: int,
                           batch: int, block_q: int = 256,
                           block_k: int = 256, dtype_bytes: int = 2) -> int:
    """HBM bytes the SALO Pallas kernel moves for one attention layer
    (TPU target): per grid cell, Q/K/V tiles in + out tile written once.
    Score tensors stay in VMEM (the kernel's whole point) — this is the
    memory-roofline term the blockwise-XLA dry-run CANNOT show on CPU
    (its HLO materializes the interior).
    """
    from repro.core.scheduler import schedule

    sched = schedule(pattern, n)
    n_pad = -(-sched.n_work // max(block_q, block_k)) * max(block_q, block_k)
    nq = n_pad // block_q
    bh = batch * n_heads
    total = 0
    for band in sched.bands:
        steps = band.kv_steps(block_q, block_k)
        # q tile read once per (bh, i); k/v tiles per step; out written once
        total += bh * nq * (block_q * head_dim          # q
                            + steps * 2 * block_k * head_dim  # k+v stream
                            + block_q * head_dim)       # out
    return total * dtype_bytes
