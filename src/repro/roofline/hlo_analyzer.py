"""Trip-count-aware HLO cost analyzer.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, regardless of trip
count (verified empirically) — for scan-over-layers models that undercounts
FLOPs, bytes and collectives by ~n_layers. This module re-derives the costs
from ``compiled.as_text()``:

1. split the module into computations,
2. walk the call graph from ENTRY, assigning each computation an *execution
   multiplier* (while bodies/conditions multiply by the XLA-annotated
   ``known_trip_count``; fusions/calls inherit the caller's multiplier),
3. count per computation:
     * FLOPs: ``dot`` ops (2 x prod(output dims) x contraction size) —
       the MXU-relevant compute; elementwise ops are ignored (documented
       roofline approximation),
     * bytes: operands + outputs of buffer-touching ops at computation level
       (fusion internals excluded — they live in registers/VMEM, matching
       XLA's "bytes accessed" semantics),
     * collectives: kind, payload bytes, replica-group size,
   each scaled by the multiplier.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

_SHAPE_RE = re.compile(r"(pred|s8|u8|s16|u16|f16|bf16|s32|u32|f32|s64|u64|"
                       r"f64|c64|c128|f8e4m3fn|f8e5m2)\[([\d,]*)\]")
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_BYTES_OPS = ("parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "after-all", "iota")


def _dims(dim_str: str) -> List[int]:
    return [int(d) for d in dim_str.split(",") if d]


def _nelems(dim_str: str) -> int:
    n = 1
    for d in _dims(dim_str):
        n *= d
    return n


def _all_shape_bytes(segment: str) -> int:
    return sum(_nelems(dims) * _DTYPE_BYTES[dt]
               for dt, dims in _SHAPE_RE.findall(segment))


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: List[Dict] = dataclasses.field(default_factory=list)


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in text.splitlines():
        # computation header: [ENTRY] %name (params...) -> type {
        m = re.match(r"(?:ENTRY\s+)?%([\w\.\-]+)\s*\(.*->.*\{\s*$", line)
        if m:
            cur = m.group(1)
            comps[cur] = []
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps


def _entry_name(text: str) -> str:
    m = re.search(r"ENTRY\s+%?([\w\.\-]+)", text)
    return m.group(1)


# Single-computation attrs (body=%x, condition=%x, calls=%x, to_apply=%x)
_CALL_ONE_RE = re.compile(r"(?:calls|to_apply|body|condition)=%([\w\.\-]+)")
# List form: branch_computations={%a, %b} / called_computations={...}
_CALL_LIST_RE = re.compile(
    r"(?:branch_computations|called_computations)=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _callees(line: str):
    out = [m.group(1) for m in _CALL_ONE_RE.finditer(line)]
    for m in _CALL_LIST_RE.finditer(line):
        out.extend(n.strip().lstrip("%") for n in m.group(1).split(","))
    return out


# One instruction: %name = TYPE opkind(operands...), attrs...
# Operands carry no type annotations in compiled HLO text, so shapes are
# resolved through a per-computation symbol table.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*?)\s+([a-z][a-z0-9\-]*)\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_ATTR_CUT_RE = re.compile(
    r",\s*(?:metadata|backend_config|calls|to_apply|body|condition|"
    r"custom_call_target|api_version|sharding|channel_id|replica_groups|"
    r"dimensions|slice)=")


def _parse_instr(line: str):
    """-> (result_name, type_str, op_kind, operand_segment) or None."""
    m = _INSTR_RE.match(line)
    if not m:
        return None
    name, type_str, kind = m.groups()
    rest = line[m.end():]
    operands = _ATTR_CUT_RE.split(rest)[0]
    return name, type_str, kind, operands


def _op_kind(line: str) -> str:
    p = _parse_instr(line)
    return p[2] if p else ""


def _group_size(line: str, world: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return world


def analyze_hlo(text: str, world: int) -> Costs:
    comps = _split_computations(text)
    entry = _entry_name(text)

    # --- pass 1: multipliers via BFS over the call graph ----------------- #
    mult: Dict[str, float] = {entry: 1.0}
    fusion_bodies = set()
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        name = order[i]
        i += 1
        m_here = mult[name]
        for line in comps.get(name, ()):
            trip = 1.0
            tm = _TRIP_RE.search(line)
            is_while = " while(" in line
            if is_while and tm:
                trip = float(tm.group(1))
            is_fusion = _op_kind(line) == "fusion"
            for callee in _callees(line):
                if callee not in comps:
                    continue
                if is_fusion:
                    fusion_bodies.add(callee)
                factor = trip if is_while else 1.0
                new = m_here * factor
                if callee not in mult or new > mult[callee]:
                    mult[callee] = new
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)

    # --- pass 2: per-computation costs x multiplier ---------------------- #
    costs = Costs()
    for name, lines in comps.items():
        m_here = mult.get(name, 0.0)
        if m_here == 0.0:
            continue  # dead computation
        in_fusion = name in fusion_bodies
        # Symbol table: result name -> (type_str, bytes) for operand lookup.
        table: Dict[str, Tuple[str, int]] = {}
        parsed = []
        for line in lines:
            p = _parse_instr(line)
            if p is None:
                continue
            rname, type_str, kind, operands = p
            table[rname] = (type_str, _all_shape_bytes(type_str))
            parsed.append((line, rname, type_str, kind, operands))

        for line, rname, type_str, kind, operands in parsed:
            # ---- FLOPs: dot ops (counted even inside fusion bodies) ----- #
            if kind == "dot":
                out_m = _SHAPE_RE.search(type_str)
                out_elems = _nelems(out_m.group(2)) if out_m else 0
                ops = _OPERAND_RE.findall(operands)
                lhs_type = table.get(ops[0], ("", 0))[0] if ops else ""
                lhs_m = _SHAPE_RE.search(lhs_type)
                contract = 1
                if lhs_m:
                    lhs_dims = _dims(lhs_m.group(2))
                    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
                    if mc:
                        for i in _dims(mc.group(1)):
                            if i < len(lhs_dims):
                                contract *= lhs_dims[i]
                costs.flops += m_here * 2.0 * out_elems * contract

            if (in_fusion or kind in _SKIP_BYTES_OPS
                    or kind in ("while", "call", "conditional")):
                continue  # fusion internals / control flow don't touch HBM
            # ---- bytes: op-specific HBM traffic model ------------------- #
            out_bytes = _all_shape_bytes(type_str)
            op_sizes = [table.get(op, ("", 0))[1]
                        for op in _OPERAND_RE.findall(operands)]
            if kind in ("gather", "dynamic-slice"):
                # reads only the gathered/sliced elements (+indices), not
                # the whole operand
                nbytes = 2 * out_bytes + sum(op_sizes[1:])
            elif kind == "dynamic-update-slice":
                # in-place RMW of the update region (XLA aliases the buffer)
                upd = op_sizes[1] if len(op_sizes) > 1 else out_bytes
                nbytes = 2 * upd + sum(op_sizes[2:])
            elif kind == "scatter":
                # read+write touched region ~= updates; indices read once
                upd = op_sizes[2] if len(op_sizes) > 2 else out_bytes
                idx = op_sizes[1] if len(op_sizes) > 1 else 0
                nbytes = 2 * upd + idx
            else:
                nbytes = out_bytes + sum(op_sizes)
            costs.bytes += m_here * nbytes
            # ---- collectives -------------------------------------------- #
            if kind in _COLLECTIVES and "-done(" not in line:
                out_bytes = _all_shape_bytes(type_str)
                if out_bytes:
                    costs.collectives.append({
                        "kind": kind, "bytes": out_bytes * m_here,
                        "group": _group_size(line, world),
                        "count": m_here})
    return costs


def count_fusion_bytes_only(text: str) -> float:
    """Debug helper: bytes at entry level only (XLA-equivalent view)."""
    return analyze_hlo(text, 1).bytes


def bytes_by_op_kind(text: str, world: int) -> Dict[str, float]:
    """Debug/profiling helper: per-op-kind byte totals (trip-count scaled) —
    shows WHERE the memory roofline term comes from."""
    comps = _split_computations(text)
    entry = _entry_name(text)
    mult: Dict[str, float] = {entry: 1.0}
    fusion_bodies = set()
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        name = order[i]
        i += 1
        m_here = mult[name]
        for line in comps.get(name, ()):
            trip = 1.0
            tm = _TRIP_RE.search(line)
            is_while = " while(" in line
            if is_while and tm:
                trip = float(tm.group(1))
            is_fusion = _op_kind(line) == "fusion"
            for callee in _callees(line):
                if callee not in comps:
                    continue
                if is_fusion:
                    fusion_bodies.add(callee)
                new = m_here * (trip if is_while else 1.0)
                if callee not in mult or new > mult[callee]:
                    mult[callee] = new
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)
    out: Dict[str, float] = {}
    for name, lines in comps.items():
        m_here = mult.get(name, 0.0)
        if m_here == 0.0 or name in fusion_bodies:
            continue
        table = {}
        parsed = []
        for line in lines:
            p_ = _parse_instr(line)
            if p_ is None:
                continue
            rname, type_str, kind, operands = p_
            table[rname] = _all_shape_bytes(type_str)
            parsed.append((rname, type_str, kind, operands))
        for rname, type_str, kind, operands in parsed:
            if kind in _SKIP_BYTES_OPS or kind in ("while", "call",
                                                   "conditional"):
                continue
            out_bytes = _all_shape_bytes(type_str)
            op_sizes = [table.get(op, 0)
                        for op in _OPERAND_RE.findall(operands)]
            if kind in ("gather", "dynamic-slice"):
                nb = 2 * out_bytes + sum(op_sizes[1:])
            elif kind == "dynamic-update-slice":
                upd = op_sizes[1] if len(op_sizes) > 1 else out_bytes
                nb = 2 * upd + sum(op_sizes[2:])
            elif kind == "scatter":
                upd = op_sizes[2] if len(op_sizes) > 2 else out_bytes
                nb = 2 * upd + (op_sizes[1] if len(op_sizes) > 1 else 0)
            else:
                nb = out_bytes + sum(op_sizes)
            out[kind] = out.get(kind, 0.0) + m_here * nb
    return out
