"""gemma-7b [dense] — GeGLU, head_dim=256. [arXiv:2403.08295; hf]"""
import dataclasses
from repro.configs.base import ModelConfig, SALOConfig

CONFIG = ModelConfig(
    name="gemma-7b", family="dense", n_layers=28, d_model=3072,
    n_heads=16, n_kv_heads=16, head_dim=256, d_ff=24576,
    vocab_size=256000, act="geglu", tie_embeddings=True,
    logit_softcap=30.0, salo=SALOConfig(window=1024, n_global=4))

SMOKE = dataclasses.replace(
    CONFIG, name="gemma-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, head_dim=32, d_ff=128, vocab_size=256,
    salo=SALOConfig(window=16, n_global=2, block_q=32, block_k=32),
    param_dtype="float32", compute_dtype="float32")
