"""whisper-base [audio] — enc-dec, conv frontend stubbed (input_specs
supplies precomputed frame embeddings). [arXiv:2212.04356; unverified]"""
import dataclasses
from repro.configs.base import ModelConfig, SALOConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio", n_layers=6, d_model=512,
    n_heads=8, n_kv_heads=8, d_ff=2048, vocab_size=51865, act="gelu",
    encoder_decoder=True, n_audio_frames=1500,
    salo=SALOConfig(window=512, n_global=4, bidirectional=True))

SMOKE = dataclasses.replace(
    CONFIG, name="whisper-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab_size=256, n_audio_frames=32,
    salo=SALOConfig(window=16, n_global=2, bidirectional=True,
                    block_q=32, block_k=32),
    param_dtype="float32", compute_dtype="float32")
