"""phi4-mini-3.8b [dense] — RoPE SwiGLU GQA. [arXiv:2412.08905; hf]"""
import dataclasses
from repro.configs.base import ModelConfig, SALOConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b", family="dense", n_layers=32, d_model=3072,
    n_heads=24, n_kv_heads=8, d_ff=8192, vocab_size=200064,
    salo=SALOConfig(window=1024, n_global=4))

SMOKE = dataclasses.replace(
    CONFIG, name="phi4-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256,
    salo=SALOConfig(window=16, n_global=2, block_q=32, block_k=32),
    param_dtype="float32", compute_dtype="float32")
