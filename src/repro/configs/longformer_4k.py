"""Paper workload (Table 2 row 1): Longformer-Base-4096 attention layer —
n=4096, window=512, hidden=768 (12 heads x 64), 1 global token,
sparsity 0.125. Used by the paper-claims benchmarks; also a full small LM
config for end-to-end runs."""
import dataclasses
from repro.configs.base import ModelConfig, SALOConfig

CONFIG = ModelConfig(
    name="longformer-4k", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=3072, vocab_size=50265, act="gelu",
    salo=SALOConfig(window=512, n_global=1, bidirectional=True,
                    global_rows=True))

SMOKE = dataclasses.replace(
    CONFIG, name="longformer-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab_size=256,
    salo=SALOConfig(window=16, n_global=1, bidirectional=True,
                    global_rows=True, block_q=32, block_k=32),
    param_dtype="float32", compute_dtype="float32")
