"""mamba2-370m [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]. SALO inapplicable (DESIGN.md §5)."""
import dataclasses
from repro.configs.base import ModelConfig, SSMConfig, SALOConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm", n_layers=48, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=0, vocab_size=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2),
    salo=SALOConfig(enabled=False), tie_embeddings=True)

SMOKE = dataclasses.replace(
    CONFIG, name="mamba2-smoke", n_layers=2, d_model=64, vocab_size=256,
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, chunk=16),
    param_dtype="float32", compute_dtype="float32")
