"""smollm-135m [dense] — llama-arch small; also the ~100M end-to-end
training example. [hf:HuggingFaceTB/SmolLM-135M; hf]"""
import dataclasses
from repro.configs.base import ModelConfig, SALOConfig

CONFIG = ModelConfig(
    name="smollm-135m", family="dense", n_layers=30, d_model=576,
    n_heads=9, n_kv_heads=3, d_ff=1536, vocab_size=49152,
    tie_embeddings=True, salo=SALOConfig(window=1024, n_global=4))

SMOKE = dataclasses.replace(
    CONFIG, name="smollm-smoke", n_layers=2, d_model=48, n_heads=3,
    n_kv_heads=1, d_ff=96, vocab_size=256,
    salo=SALOConfig(window=16, n_global=2, block_q=32, block_k=32),
    param_dtype="float32", compute_dtype="float32")
