"""Config system: one frozen dataclass describes any supported architecture.

``--arch <id>`` resolves through :func:`repro.configs.get_config`. Every
assigned architecture gets a module ``configs/<id>.py`` exporting ``CONFIG``
(the exact published shape) and ``SMOKE`` (a reduced same-family config for
CPU tests). Shapes (seq x batch cells) live in ``SHAPES``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    dense_residual: bool = False   # arctic: dense FFN in parallel with MoE
    n_shared_experts: int = 0      # kimi/deepseek-style shared expert
    first_k_dense: int = 0         # kimi: leading dense layers
    router_z_coef: float = 1e-3
    load_balance_coef: float = 1e-2
    # Dispatch locality: tokens are split into this many groups (aligned
    # with the DP sharding) and each group routes/sorts independently —
    # no global sort, no cross-shard scatter (models/moe.py).
    dispatch_groups: int = 16


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class RecurrentConfig:
    """RG-LRU (RecurrentGemma) settings; layers follow (rec, rec, attn)."""
    d_rnn: Optional[int] = None     # defaults to d_model
    conv_width: int = 4
    block_pattern: Tuple[str, ...] = ("rec", "rec", "attn")
    local_window: int = 2048


@dataclasses.dataclass(frozen=True)
class SALOConfig:
    """How the paper's technique is applied to this architecture."""
    enabled: bool = True
    window: int = 4096              # sliding window size (causal: lookback)
    n_global: int = 4               # global tokens / attention sinks
    dilation: int = 1
    bidirectional: bool = False     # encoders: symmetric window
    global_rows: bool = False       # Longformer-style global queries
    impl: str = "blockwise"         # blockwise | pallas | pallas_interpret
    block_q: int = 256
    block_k: int = 256
    # SALO windowed decode: read only window+sinks cache slots per step
    # (O(w) HBM traffic instead of O(n); core/attention.py decode path).
    decode_slice: bool = False
    # SALO ring cache: the KV cache itself has window+sinks slots — O(w)
    # memory at ANY context length (the paper's pattern as a cache layout).
    ring_cache: bool = False


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    act: str = "swiglu"             # swiglu | geglu | gelu
    norm_eps: float = 1e-6
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    logit_softcap: Optional[float] = None   # gemma-style
    salo: SALOConfig = SALOConfig()
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    recurrent: Optional[RecurrentConfig] = None
    # enc-dec (whisper): n_layers applies to each side
    encoder_decoder: bool = False
    n_audio_frames: int = 1500      # stub frontend output length
    # vlm (qwen2-vl)
    mrope_sections: Optional[Tuple[int, int, int]] = None
    n_vision_tokens: int = 0        # stub patch embeddings per sample
    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # remat policy for the layer scan: "none" | "full" | "dots"
    remat: str = "full"

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else (
            self.d_model // self.n_heads)

    def n_params(self) -> int:
        """Total parameter count (embedding included)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.hd
        attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads \
            + hd * self.n_heads * d
        mlp_mults = {"swiglu": 3, "geglu": 3, "gelu": 2}[self.act]
        dense_mlp = mlp_mults * d * f
        per_layer = attn + dense_mlp + 2 * d
        total = self.n_layers * per_layer
        if self.moe is not None:
            m = self.moe
            expert = mlp_mults * d * m.d_ff_expert
            moe_layers = self.n_layers - m.first_k_dense
            total += moe_layers * (m.n_experts + m.n_shared_experts) * expert
            total += moe_layers * d * m.n_experts  # router
            if not m.dense_residual:
                total -= moe_layers * dense_mlp    # MoE replaces dense FFN
        if self.ssm is not None:
            di = self.ssm.expand * d
            total = self.n_layers * (2 * d * di + di * d + 2 * d) + 0
        total += v * d * (1 if self.tie_embeddings else 2)
        if self.encoder_decoder:
            total *= 2  # encoder + decoder stacks (approximation)
        return int(total)

    def n_active_params(self) -> int:
        """Active-per-token parameters (MoE: top_k experts only)."""
        if self.moe is None:
            return self.n_params()
        m = self.moe
        mlp_mults = {"swiglu": 3, "geglu": 3, "gelu": 2}[self.act]
        expert = mlp_mults * self.d_model * m.d_ff_expert
        moe_layers = self.n_layers - m.first_k_dense
        inactive = moe_layers * (m.n_experts - m.top_k) * expert
        return int(self.n_params() - inactive)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (architecture x input-shape) dry-run cell."""
    name: str                       # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}
