"""Architecture registry: ``--arch <id>`` -> ModelConfig."""
from repro.configs.base import (ModelConfig, MoEConfig, SSMConfig,
                                RecurrentConfig, SALOConfig, ShapeCell,
                                SHAPES, SHAPES_BY_NAME)

ARCHS = (
    "mamba2-370m", "arctic-480b", "kimi-k2-1t-a32b", "whisper-base",
    "phi4-mini-3.8b", "smollm-135m", "granite-3-8b", "gemma-7b",
    "qwen2-vl-2b", "recurrentgemma-9b", "longformer-4k",
)

_MODULES = {
    "mamba2-370m": "mamba2_370m",
    "arctic-480b": "arctic_480b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "whisper-base": "whisper_base",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "smollm-135m": "smollm_135m",
    "granite-3-8b": "granite_3_8b",
    "gemma-7b": "gemma_7b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "longformer-4k": "longformer_4k",
}


def _module(name: str):
    import importlib
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ARCHS}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> ModelConfig:
    return _module(name).SMOKE
