"""recurrentgemma-9b [hybrid] — RG-LRU + local attention 1:2; the closest
published arch to the paper's sliding-window workload.
[arXiv:2402.19427; unverified]"""
import dataclasses
from repro.configs.base import ModelConfig, RecurrentConfig, SALOConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid", n_layers=38, d_model=4096,
    n_heads=16, n_kv_heads=1, head_dim=256, d_ff=12288,
    vocab_size=256000, act="geglu", tie_embeddings=True,
    recurrent=RecurrentConfig(local_window=2048),
    salo=SALOConfig(window=2048, n_global=4))

SMOKE = dataclasses.replace(
    CONFIG, name="rgemma-smoke", n_layers=3, d_model=64, n_heads=4,
    n_kv_heads=1, head_dim=16, d_ff=128, vocab_size=256,
    recurrent=RecurrentConfig(local_window=16),
    salo=SALOConfig(window=16, n_global=2, block_q=32, block_k=32),
    param_dtype="float32", compute_dtype="float32")
