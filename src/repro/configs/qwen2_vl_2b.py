"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution (vision frontend stubbed:
input_specs supplies patch embeddings aligned to token slots).
[arXiv:2409.12191; hf]"""
import dataclasses
from repro.configs.base import ModelConfig, SALOConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm", n_layers=28, d_model=1536,
    n_heads=12, n_kv_heads=2, head_dim=128, d_ff=8960, vocab_size=151936,
    mrope_sections=(16, 24, 24), n_vision_tokens=1024,
    salo=SALOConfig(window=1024, n_global=4))

SMOKE = dataclasses.replace(
    CONFIG, name="qwen2vl-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
    mrope_sections=(2, 3, 3), n_vision_tokens=16,
    salo=SALOConfig(window=16, n_global=2, block_q=32, block_k=32),
    param_dtype="float32", compute_dtype="float32")
