"""Paper workload (Table 2 rows 2-3): ViL stages with 15x15 2-D windows.
stage1: 56x56 grid, hidden 192; stage2: 28x28 grid, hidden 384; 1 global
token each. These drive the paper-claims benchmarks (attention layer level,
as the paper evaluates)."""
from repro.core.patterns import vil

VIL_STAGE1 = dict(grid=(56, 56), window=(15, 15), hidden=192, n_global=1,
                  pattern=vil((56, 56), (15, 15), 1))
VIL_STAGE2 = dict(grid=(28, 28), window=(15, 15), hidden=384, n_global=1,
                  pattern=vil((28, 28), (15, 15), 1))
