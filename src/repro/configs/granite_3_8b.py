"""granite-3-8b [dense] — GQA. [hf:ibm-granite/granite-3.0-2b-base; hf]"""
import dataclasses
from repro.configs.base import ModelConfig, SALOConfig

CONFIG = ModelConfig(
    name="granite-3-8b", family="dense", n_layers=40, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=12800, vocab_size=49155,
    salo=SALOConfig(window=1024, n_global=4))

SMOKE = dataclasses.replace(
    CONFIG, name="granite-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256,
    salo=SALOConfig(window=16, n_global=2, block_q=32, block_k=32),
    param_dtype="float32", compute_dtype="float32")
