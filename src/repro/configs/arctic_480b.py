"""arctic-480b [moe] — 128 experts top-2 + dense residual.
[hf:Snowflake/snowflake-arctic-base; hf]"""
import dataclasses
from repro.configs.base import ModelConfig, MoEConfig, SALOConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe", n_layers=35, d_model=7168,
    n_heads=56, n_kv_heads=8, d_ff=4864, vocab_size=32000,
    moe=MoEConfig(n_experts=128, top_k=2, d_ff_expert=4864,
                  dense_residual=True),
    salo=SALOConfig(window=1024, n_global=4))

SMOKE = dataclasses.replace(
    CONFIG, name="arctic-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256,
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128,
                  dense_residual=True),
    salo=SALOConfig(window=16, n_global=2, block_q=32, block_k=32),
    param_dtype="float32", compute_dtype="float32")
