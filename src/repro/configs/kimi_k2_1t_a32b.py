"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 experts top-8, shared
expert, leading dense layer. [arXiv:2501.kimi2; unverified]"""
import dataclasses
from repro.configs.base import ModelConfig, MoEConfig, SALOConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe", n_layers=61, d_model=7168,
    n_heads=64, n_kv_heads=8, head_dim=128, d_ff=7168, vocab_size=163840,
    moe=MoEConfig(n_experts=384, top_k=8, d_ff_expert=2048,
                  n_shared_experts=1, first_k_dense=1),
    salo=SALOConfig(window=1024, n_global=4))

SMOKE = dataclasses.replace(
    CONFIG, name="kimi-smoke", n_layers=3, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                  n_shared_experts=1, first_k_dense=1),
    salo=SALOConfig(window=16, n_global=2, block_q=32, block_k=32),
    param_dtype="float32", compute_dtype="float32")
