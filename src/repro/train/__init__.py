from repro.train.trainer import TrainConfig, make_train_step, make_eval_step
