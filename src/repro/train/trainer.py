"""Training step factory: loss -> grads -> (optionally compressed) psum ->
AdamW, with microbatch gradient accumulation and LR schedule.

``make_train_step`` returns a pure jittable function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable for
``jax.jit(..., donate_argnums=(0, 1))`` under a mesh. All parallelism is
expressed through shardings (pjit); gradient compression (int8 + error
feedback) hooks in via :mod:`repro.dist.compression` when enabled.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.optim import adamw
from repro.optim.schedule import Schedule


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: adamw.AdamWConfig = adamw.AdamWConfig()
    schedule: Schedule = Schedule()
    microbatches: int = 1            # gradient accumulation
    compress_grads: bool = False     # int8 all-reduce w/ error feedback


def make_train_step(model, tcfg: TrainConfig) -> Callable:
    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(params, opt_state, batch, ef_state=None):
        mb = tcfg.microbatches
        if mb == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            # Microbatch accumulation: split the batch axis and scan.
            # (M-RoPE "positions" carries batch on axis 1, everything else
            # on axis 0.)
            def slice_mb(i, key, x):
                axis = 1 if key == "positions" else 0
                b = x.shape[axis] // mb
                return jax.lax.dynamic_slice_in_dim(x, i * b, b, axis=axis)

            def body(carry, i):
                acc_g, acc_l = carry
                mbatch = {k: slice_mb(i, k, v) for k, v in batch.items()}
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mbatch)
                return (jax.tree.map(jnp.add, acc_g, g), acc_l + l), m

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), metrics = jax.lax.scan(
                body, (zeros, 0.0), jnp.arange(mb))
            grads = jax.tree.map(lambda g: g / mb, grads)
            loss = loss / mb
            metrics = jax.tree.map(lambda m: m[-1], metrics)

        if tcfg.compress_grads:
            from repro.dist import compression
            grads, ef_state = compression.compress_decompress(
                grads, ef_state)

        lr_scale = tcfg.schedule(opt_state.step)
        params, opt_state, opt_metrics = adamw.update(
            tcfg.optimizer, opt_state, params, grads, lr_scale)
        metrics = dict(metrics, **opt_metrics, loss=loss)
        if tcfg.compress_grads:
            return params, opt_state, metrics, ef_state
        return params, opt_state, metrics

    return train_step


def make_eval_step(model) -> Callable:
    def eval_step(params, batch):
        _, metrics = model.loss(params, batch)
        return metrics
    return eval_step
