"""Training step factory: loss -> grads -> (optionally compressed) psum ->
AdamW, with microbatch gradient accumulation and LR schedule.

``make_train_step`` returns a pure jittable function
``(params, opt_state, batch, ef_state=None) -> (params, opt_state,
metrics, ef_state)`` suitable for ``jax.jit(..., donate_argnums=(0, 1))``
under a mesh. The arity is FIXED: ``ef_state`` (the int8 error-feedback
residual) is always threaded — ``None`` unless gradient compression is
active — so callers and donation plumbing never switch shapes on a config
flag.

Gradient compression (``compress_grads=True``) is wired into the WIRE, not
just the values: when an ambient mesh maps any of ``compress_axes`` to
real devices, the gradient computation runs under ``shard_map`` over those
axes (batch sharded, params replicated) and the cross-device reduce is
:func:`repro.dist.compression.compressed_psum_with_residual` — each
participant ships int8 + one f32 scale per tensor instead of fp32 grads,
with the per-participant quantization residual carried in ``ef_state``
(leading axis = participant). The previous implementation
quantize-dequantized AFTER pjit's implicit fp32 all-reduce, moving exactly
as many bytes as the uncompressed step. Without a live mesh the step
degrades to the local quantize-dequantize (numerics-faithful, nothing to
compress on one device).

Note: inside the compressed region the loss/metrics are per-shard means
combined by ``pmean`` — exact for the equal-sized shards the batch axis
splitter produces; masked losses with unequal per-shard mask counts would
bias slightly (the synthetic pipeline emits no mask).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.optim import adamw
from repro.optim.schedule import Schedule


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: adamw.AdamWConfig = adamw.AdamWConfig()
    schedule: Schedule = Schedule()
    microbatches: int = 1            # gradient accumulation
    compress_grads: bool = False     # int8 all-reduce w/ error feedback
    # mesh axes whose reduce rides the compressed wire (the DCN-crossing
    # pod axis and the data axis — whichever exist on the ambient mesh)
    compress_axes: Tuple[str, ...] = ("pod", "data")


def make_train_step(model, tcfg: TrainConfig) -> Callable:
    def loss_fn(params, batch):
        return model.loss(params, batch)

    def grads_and_metrics(params, batch):
        """(grads, loss, metrics) with f32 grads on BOTH microbatch paths
        (the mb > 1 accumulator is f32; mb == 1 used to hand param-dtype
        grads — the optimizer/wire dtype must not depend on mb) and
        metrics averaged across microbatches (``m[-1]`` used to report
        only the LAST microbatch while the loss was averaged)."""
        mb = tcfg.microbatches
        if mb == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            return grads, loss, metrics

        # Microbatch accumulation: split the batch axis and scan.
        # (M-RoPE "positions" carries batch on axis 1, everything else
        # on axis 0.)
        def slice_mb(i, key, x):
            axis = 1 if key == "positions" else 0
            b = x.shape[axis] // mb
            return jax.lax.dynamic_slice_in_dim(x, i * b, b, axis=axis)

        def body(carry, i):
            acc_g, acc_l = carry
            mbatch = {k: slice_mb(i, k, v) for k, v in batch.items()}
            (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mbatch)
            return (jax.tree.map(jnp.add, acc_g, g), acc_l + l), m

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss), metrics = jax.lax.scan(
            body, (zeros, 0.0), jnp.arange(mb))
        grads = jax.tree.map(lambda g: g / mb, grads)
        loss = loss / mb
        metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), metrics)
        return grads, loss, metrics

    def _compress_axes():
        """(mesh, live compress axes, participant count) — the axes from
        tcfg.compress_axes present on the ambient mesh, i.e. the
        participants of the compressed wire. axes == () = nothing to
        shard. The single place mesh sizes are read."""
        from repro.dist.sharding import _ambient_mesh

        mesh = _ambient_mesh()
        if mesh is None or mesh.empty:
            return None, (), 1
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        axes = tuple(a for a in tcfg.compress_axes if sizes.get(a, 1) > 1)
        return mesh, axes, math.prod(sizes[a] for a in axes)

    def compressed_grads(mesh, axes, n, params, batch, ef_state):
        """Grad computation under shard_map over ``axes`` (``n``
        participants): batch sharded, params replicated, the reduce a
        compressed psum + error feedback."""
        from repro.compat import shard_map
        from repro.dist import compression
        from repro.dist import sharding as shlib

        if ef_state is None:
            ef_state = jax.tree.map(
                lambda p: jnp.zeros((n,) + p.shape, jnp.float32), params)
        bspec = {k: P(None, axes) if k == "positions" else P(axes)
                 for k in batch}

        def local(params, batch, ef):
            ef = jax.tree.map(lambda e: e[0], ef)
            # constrain() is a no-op inside the shard_map region (arrays
            # are device-local); neutralize the ambient rules.
            with shlib.axis_rules({}):
                g, loss, metrics = grads_and_metrics(params, batch)

            def one(g_, e_):
                tot, resid = compression.compressed_psum_with_residual(
                    g_ + e_, axes)
                return tot / n, resid

            pairs = jax.tree.map(one, g, ef)
            is_pair = lambda t: isinstance(t, tuple)  # noqa: E731
            g = jax.tree.map(lambda t: t[0], pairs, is_leaf=is_pair)
            ef = jax.tree.map(lambda t: t[1], pairs, is_leaf=is_pair)
            loss = jax.lax.pmean(loss, axes)
            metrics = jax.tree.map(lambda m: jax.lax.pmean(m, axes),
                                   metrics)
            return g, loss, metrics, jax.tree.map(lambda e: e[None], ef)

        fn = shard_map(local, mesh=mesh,
                       in_specs=(P(), bspec, P(axes)),
                       out_specs=(P(), P(), P(), P(axes)),
                       check_vma=False)
        return fn(params, batch, ef_state)

    def train_step(params, opt_state, batch, ef_state=None):
        if tcfg.compress_grads:
            mesh, axes, n = _compress_axes()
            if axes:
                if any(v.shape[1 if k == "positions" else 0] % n
                       for k, v in batch.items()):
                    raise ValueError(
                        f"compress_grads: batch axis must divide the "
                        f"compress mesh axes {axes} (x{n})")
                grads, loss, metrics, ef_state = compressed_grads(
                    mesh, axes, n, params, batch, ef_state)
            else:
                # single participant: nothing on the wire; keep the
                # quantization numerics + error feedback locally so the
                # step is faithful to the distributed one
                from repro.dist import compression
                grads, loss, metrics = grads_and_metrics(params, batch)
                grads, ef_state = compression.compress_decompress(
                    grads, ef_state)
        else:
            grads, loss, metrics = grads_and_metrics(params, batch)

        lr_scale = tcfg.schedule(opt_state.step)
        params, opt_state, opt_metrics = adamw.update(
            tcfg.optimizer, opt_state, params, grads, lr_scale)
        metrics = dict(metrics, **opt_metrics, loss=loss)
        return params, opt_state, metrics, ef_state

    return train_step


def make_eval_step(model) -> Callable:
    def eval_step(params, batch):
        _, metrics = model.loss(params, batch)
        return metrics
    return eval_step
