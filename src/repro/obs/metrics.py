"""Metrics registry: labeled counters, gauges, and log-bucketed histograms
with mergeable snapshots.

SALO's whole argument is an accounting argument — speedup comes from knowing
how many tiles, launches, and bytes each hybrid sparse pattern costs — so
the runtime's accounting deserves a first-class home instead of scattered
ad-hoc dicts. This module is that home: a small, dependency-free,
host-side-only registry.

Design constraints (shared with :mod:`repro.obs.trace`):

* **Zero cost on the jitted hot path.** Every mutation here is plain host
  Python on plain host numbers. Nothing in this module touches a JAX array
  or adds a traced operand; instrumented code records AROUND its jitted
  calls (or once at trace time), never inside them.
* **Mergeable snapshots.** :meth:`MetricsRegistry.snapshot` produces a
  pure-JSON dict; :func:`merge_snapshots` is associative and commutative
  (counters/histogram buckets add, gauges combine by max), so per-shard /
  per-restart / per-process snapshots fold in any order — the property the
  test suite pins.
* **Exact state round-trip.** ``state_dict()``/``load_state()`` rebuild the
  registry bit-for-bit (the serving engine rides them through its
  snapshot/restore path, exactly as the old ``counters`` dict did).

Histograms are log-bucketed: bucket ``i`` covers
``[BASE**i, BASE**(i+1))`` with ``BASE = 2**0.25`` (~19 % resolution — at
most ~9 % quantile error at the geometric bucket midpoint), plus exact
min/max/sum/count, so latency percentiles survive merging without storing
samples.
"""
from __future__ import annotations

import json
import math
import threading
from typing import Dict, Iterable, List, Mapping, Optional, Tuple

# ~19% bucket resolution: fine enough for latency percentiles, coarse
# enough that a histogram is a handful of sparse buckets.
BASE = 2.0 ** 0.25
_LOG_BASE = math.log(BASE)
# Values at or below this land in the underflow bucket (perf_counter deltas
# on a busy host bottom out well above a nanosecond).
_FLOOR = 1e-9

COUNTER, GAUGE, HISTOGRAM = "counter", "gauge", "histogram"


def bucket_index(x: float) -> int:
    """Log-bucket index of a positive value (floor of log_BASE)."""
    return int(math.floor(math.log(max(float(x), _FLOOR)) / _LOG_BASE))


def bucket_hi(i: int) -> float:
    """Exclusive upper edge of bucket ``i``."""
    return BASE ** (i + 1)


def _labels_key(label_names: Tuple[str, ...],
                labels: Mapping[str, object]) -> Tuple[str, ...]:
    if set(labels) != set(label_names):
        raise ValueError(
            f"metric labels {sorted(labels)} != declared {list(label_names)}")
    return tuple(str(labels[n]) for n in label_names)


class _Family:
    """One named metric family: kind + label names + per-labelset values."""

    def __init__(self, name: str, kind: str, help: str,
                 label_names: Tuple[str, ...]):
        self.name = name
        self.kind = kind
        self.help = help
        self.label_names = label_names
        # counter/gauge: key -> float; histogram: key -> _Hist
        self.values: Dict[Tuple[str, ...], object] = {}


class _Hist:
    """Sparse log-bucketed histogram cell."""

    __slots__ = ("buckets", "count", "sum", "min", "max")

    def __init__(self):
        self.buckets: Dict[int, int] = {}
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def record(self, x: float) -> None:
        x = float(x)
        i = bucket_index(x)
        self.buckets[i] = self.buckets.get(i, 0) + 1
        self.count += 1
        self.sum += x
        self.min = min(self.min, x)
        self.max = max(self.max, x)

    def percentile(self, q: float) -> float:
        """Quantile estimate at the geometric midpoint of the covering
        bucket, clamped to the exact observed [min, max]."""
        if self.count == 0:
            return math.nan
        rank = max(1, math.ceil(q * self.count))   # nearest-rank
        seen = 0
        for i in sorted(self.buckets):
            seen += self.buckets[i]
            if seen >= rank:
                mid = BASE ** (i + 0.5)
                return min(max(mid, self.min), self.max)
        return self.max

    def to_dict(self) -> dict:
        return {"buckets": {str(i): c for i, c in sorted(self.buckets.items())},
                "count": self.count, "sum": self.sum,
                "min": (None if self.count == 0 else self.min),
                "max": (None if self.count == 0 else self.max)}

    @classmethod
    def from_dict(cls, d: dict) -> "_Hist":
        h = cls()
        h.buckets = {int(i): int(c) for i, c in d["buckets"].items()}
        h.count = int(d["count"])
        h.sum = float(d["sum"])
        h.min = math.inf if d["min"] is None else float(d["min"])
        h.max = -math.inf if d["max"] is None else float(d["max"])
        return h

    def merged(self, other: "_Hist") -> "_Hist":
        out = _Hist()
        out.buckets = dict(self.buckets)
        for i, c in other.buckets.items():
            out.buckets[i] = out.buckets.get(i, 0) + c
        out.count = self.count + other.count
        out.sum = self.sum + other.sum
        out.min = min(self.min, other.min)
        out.max = max(self.max, other.max)
        return out


class MetricsRegistry:
    """Thread-safe registry of counter/gauge/histogram families.

    All mutators take the family name plus keyword labels::

        reg.inc("decode_launches")
        reg.inc("requests_finished", priority=1)
        reg.observe("ttft_s", 0.042, priority=0)
        reg.set("slab_resident_bytes", 1 << 20)
    """

    def __init__(self):
        self._fams: Dict[str, _Family] = {}
        self._lock = threading.Lock()

    # ------------------------- declaration --------------------------- #
    def _family(self, name: str, kind: str, help: str,
                label_names: Iterable[str]) -> _Family:
        label_names = tuple(label_names)
        with self._lock:
            fam = self._fams.get(name)
            if fam is None:
                fam = _Family(name, kind, help, label_names)
                self._fams[name] = fam
            elif fam.kind != kind or fam.label_names != label_names:
                raise ValueError(
                    f"metric {name!r} re-declared as {kind}{label_names} "
                    f"(was {fam.kind}{fam.label_names})")
            return fam

    def counter(self, name: str, help: str = "",
                label_names: Iterable[str] = ()) -> None:
        self._family(name, COUNTER, help, label_names)

    def gauge(self, name: str, help: str = "",
              label_names: Iterable[str] = ()) -> None:
        self._family(name, GAUGE, help, label_names)

    def histogram(self, name: str, help: str = "",
                  label_names: Iterable[str] = ()) -> None:
        self._family(name, HISTOGRAM, help, label_names)

    # -------------------------- mutation ----------------------------- #
    def inc(self, name: str, amount: float = 1.0, **labels) -> None:
        fam = self._family(name, COUNTER, "", tuple(sorted(labels)))
        key = _labels_key(fam.label_names, labels)
        with self._lock:
            fam.values[key] = fam.values.get(key, 0.0) + amount

    def set(self, name: str, value: float, **labels) -> None:
        fam = self._family(name, GAUGE, "", tuple(sorted(labels)))
        key = _labels_key(fam.label_names, labels)
        with self._lock:
            fam.values[key] = float(value)

    def observe(self, name: str, value: float, **labels) -> None:
        fam = self._family(name, HISTOGRAM, "", tuple(sorted(labels)))
        key = _labels_key(fam.label_names, labels)
        with self._lock:
            h = fam.values.get(key)
            if h is None:
                h = fam.values[key] = _Hist()
            h.record(value)

    def set_counter(self, name: str, value: float, **labels) -> None:
        """Restore-path escape hatch: set a counter's absolute total (the
        snapshot/restore contract needs exact round-trips, not monotone
        increments)."""
        fam = self._family(name, COUNTER, "", tuple(sorted(labels)))
        key = _labels_key(fam.label_names, labels)
        with self._lock:
            fam.values[key] = float(value)

    # --------------------------- reading ----------------------------- #
    def value(self, name: str, **labels) -> float:
        fam = self._fams[name]
        v = fam.values.get(_labels_key(fam.label_names, labels), 0.0)
        if isinstance(v, _Hist):
            raise TypeError(f"{name} is a histogram; use hist()")
        return v

    def hist(self, name: str, **labels) -> Optional[_Hist]:
        fam = self._fams.get(name)
        if fam is None:
            return None
        return fam.values.get(_labels_key(fam.label_names, labels))

    def percentiles(self, name: str, qs: Iterable[float] = (0.5, 0.9, 0.99),
                    **labels) -> Dict[str, float]:
        """``{"p50": ..., "mean": ..., "count": ...}`` for one histogram
        cell (NaN percentiles / zero count when nothing was observed)."""
        h = self.hist(name, **labels) or _Hist()
        out = {f"p{q * 100:g}": h.percentile(q) for q in qs}
        out["mean"] = h.sum / h.count if h.count else math.nan
        out["count"] = h.count
        return out

    def total(self, name: str) -> float:
        """Sum of a counter/gauge family across ALL label sets (0.0 when the
        family doesn't exist yet — summary lines read metrics that may not
        have fired)."""
        fam = self._fams.get(name)
        if fam is None:
            return 0.0
        if fam.kind == HISTOGRAM:
            raise TypeError(f"{name} is a histogram; use merged_hist()")
        with self._lock:
            return float(sum(fam.values.values()))

    def merged_hist(self, name: str) -> "_Hist":
        """One histogram folding ALL label sets of a family together (empty
        when the family doesn't exist) — e.g. TTFT over every priority."""
        out = _Hist()
        fam = self._fams.get(name)
        if fam is None or fam.kind != HISTOGRAM:
            return out
        with self._lock:
            for h in fam.values.values():
                out = out.merged(h)
        return out

    def families(self) -> List[str]:
        return sorted(self._fams)

    def label_sets(self, name: str) -> List[Tuple[str, ...]]:
        fam = self._fams.get(name)
        return sorted(fam.values) if fam else []

    # ------------------- snapshot / merge / restore ------------------- #
    def snapshot(self) -> dict:
        """Pure-JSON image of the whole registry (also the state_dict)."""
        with self._lock:
            out = {}
            for name, fam in sorted(self._fams.items()):
                cells = {}
                for key, v in sorted(fam.values.items()):
                    k = json.dumps(list(key))
                    cells[k] = v.to_dict() if isinstance(v, _Hist) else v
                out[name] = {"kind": fam.kind, "help": fam.help,
                             "labels": list(fam.label_names),
                             "cells": cells}
            return out

    state_dict = snapshot

    def load_state(self, snap: dict) -> None:
        """Exact wholesale restore from a :meth:`snapshot` image."""
        with self._lock:
            self._fams = {}
        for name, fd in snap.items():
            fam = self._family(name, fd["kind"], fd.get("help", ""),
                               tuple(fd["labels"]))
            for k, v in fd["cells"].items():
                key = tuple(json.loads(k))
                fam.values[key] = (_Hist.from_dict(v)
                                   if fd["kind"] == HISTOGRAM else float(v))

    def merge(self, snap: dict) -> None:
        """Fold a snapshot into the live registry (counter/bucket adds,
        gauge max) — how per-shard or per-restart registries combine."""
        self.load_state(merge_snapshots(self.snapshot(), snap))

    def to_json(self, **dump_kw) -> str:
        return json.dumps(self.snapshot(), sort_keys=True, **dump_kw)


def merge_snapshots(a: dict, b: dict) -> dict:
    """Associative + commutative merge of two :meth:`snapshot` images:
    counters and histogram buckets add, gauges combine by max (the only
    order-free gauge semantics without timestamps)."""
    out = json.loads(json.dumps(a))   # deep copy, stays pure-JSON
    for name, fb in b.items():
        fa = out.get(name)
        if fa is None:
            out[name] = json.loads(json.dumps(fb))
            continue
        if fa["kind"] != fb["kind"] or fa["labels"] != fb["labels"]:
            raise ValueError(f"cannot merge metric {name!r}: "
                             f"{fa['kind']}{fa['labels']} vs "
                             f"{fb['kind']}{fb['labels']}")
        for k, v in fb["cells"].items():
            if k not in fa["cells"]:
                fa["cells"][k] = json.loads(json.dumps(v))
            elif fa["kind"] == COUNTER:
                fa["cells"][k] += v
            elif fa["kind"] == GAUGE:
                fa["cells"][k] = max(fa["cells"][k], v)
            else:
                fa["cells"][k] = _Hist.from_dict(fa["cells"][k]).merged(
                    _Hist.from_dict(v)).to_dict()
    return out


# One process-wide registry for call sites with no engine to hang state on
# (kernel wrappers record their trace-time launch accounting here).
_GLOBAL = MetricsRegistry()


def global_registry() -> MetricsRegistry:
    return _GLOBAL
