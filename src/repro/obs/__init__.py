"""Observability layer: metrics registry + request-lifecycle tracer.

One object, :class:`Observability`, bundles what every instrumented
subsystem needs:

* ``registry`` — a :class:`~repro.obs.metrics.MetricsRegistry` (always
  live: the serving engine's launch/token/page counters are registry
  counters even with tracing off — they replaced the old ad-hoc
  ``ContinuousEngine.counters`` dict and must keep working);
* ``tracer`` — a :class:`~repro.obs.trace.Tracer`; disabled by default
  (``Observability()``), where every span/instant is a host-side no-op.

The hard contract, end to end: **disabled observability is zero-cost on
the jitted hot path**. All hooks run host-side around jitted calls (or
once at trace time); no instrumentation adds a traced operand, so the
jaxprs of the engine's compiled steps are bit-identical with observability
on or off (``benchmarks/obs_stats.py`` asserts this).
"""
from __future__ import annotations

import time
from typing import Callable, Optional

from repro.obs.metrics import (MetricsRegistry, global_registry,
                               merge_snapshots)
from repro.obs.trace import NULL_TRACER, Tracer, validate_chrome_trace

__all__ = ["MetricsRegistry", "Observability", "Tracer", "global_registry",
           "merge_snapshots", "summary_line", "validate_chrome_trace"]


def summary_line(registry: MetricsRegistry) -> str:
    """One-line operator summary of the serving/FT metrics that exist so
    far (families that never fired are simply omitted) — the launch
    drivers print this to stderr every ``--summary-every`` steps."""
    t = registry.total
    parts = []
    for label, name in (("steps", "serve_engine_steps"),
                        ("prefill", "serve_prefill_launches"),
                        ("decode", "serve_decode_launches"),
                        ("tok", "serve_decode_tokens"),
                        ("finished", "serve_requests_finished"),
                        ("preempt", "serve_preemptions"),
                        ("expired", "serve_deadline_miss"),
                        ("restarts", "ft_restarts")):
        v = t(name)
        if v or label == "steps":
            parts.append(f"{label}={int(v)}")
    for label, name in (("ttft_p50", "serve_ttft_s"),
                        ("tpot_p50", "serve_tpot_s"),
                        ("qwait_p50", "serve_queue_wait_s")):
        h = registry.merged_hist(name)
        if h.count:
            parts.append(f"{label}={h.percentile(0.5) * 1e3:.2f}ms")
    return " ".join(parts)


class Observability:
    """Registry + tracer bundle threaded through engine/batcher/supervisor.

    ``Observability()`` — metrics only (the default everywhere);
    ``Observability(tracing=True)`` — metrics + span tracing;
    ``clock`` — shared monotonic clock for trace timestamps (inject a fake
    for deterministic traces; the batcher keeps its own injectable clock
    for deadlines).
    """

    def __init__(self, tracing: bool = False, trace_capacity: int = 65536,
                 clock: Callable[[], float] = time.perf_counter,
                 registry: Optional[MetricsRegistry] = None):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = (Tracer(capacity=trace_capacity, clock=clock)
                       if tracing else NULL_TRACER)

    @property
    def tracing(self) -> bool:
        return self.tracer.enabled

    def write_trace(self, path: str) -> None:
        self.tracer.write(path)

    def write_metrics(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.registry.to_json(indent=1))
