"""Span-based tracer: ring-buffer event log with Chrome-trace JSON export.

The runtime's request-lifecycle and step-phase story ("assemble, then one
chunk for request 3, then the shared ragged decode, then the page-stats
fold — and THEN the supervisor killed the process") is a timeline, not a
counter. This module records it as nested spans and instant events on an
**injectable monotonic clock** (deterministic tests, deadline-consistent
serving) in a bounded ring buffer (old events evicted, a long-running
server never grows without bound), and exports the standard Chrome
trace-event JSON that ``chrome://tracing`` and https://ui.perfetto.dev load
directly.

Zero-cost-when-disabled contract: a disabled tracer's ``span``/``instant``
are guard-checked no-ops on the host — instrumented code never adds traced
operands or device work either way, so observability on/off cannot change
any jitted computation (pinned by the jaxpr check in
``benchmarks/obs_stats.py``).

Chrome trace-event mapping (the subset every viewer supports):

* spans  -> ``"ph": "X"`` complete events (``ts`` + ``dur``, microseconds);
  nesting is implied by containment on the same ``(pid, tid)`` track;
* instants -> ``"ph": "i"`` with ``"s": "t"`` (thread scope);
* counter samples -> ``"ph": "C"`` (Perfetto renders a track per series).
"""
from __future__ import annotations

import collections
import json
import time
from contextlib import contextmanager
from typing import Callable, Dict, Iterator, List

# One logical process for the whole trace; tracks ("tid") name subsystems.
PID = 1
DEFAULT_TRACK = "engine"


class Tracer:
    """Bounded in-memory trace log.

    ``capacity`` bounds the ring buffer (events, not bytes); ``clock`` is
    any monotonic ``() -> seconds`` callable — inject a fake for
    deterministic output. ``enabled=False`` builds the shared no-op tracer:
    every record method returns immediately (`span` yields without
    touching the clock), so instrumentation can call it unconditionally.
    """

    def __init__(self, capacity: int = 65536,
                 clock: Callable[[], float] = time.perf_counter,
                 enabled: bool = True):
        self.enabled = enabled
        self.capacity = capacity
        self.clock = clock
        self._events: collections.deque = collections.deque(maxlen=capacity)
        self._tracks: Dict[str, int] = {}
        self._depth: Dict[str, int] = {}
        self.dropped = 0

    # --------------------------- recording --------------------------- #
    def _tid(self, track: str) -> int:
        tid = self._tracks.get(track)
        if tid is None:
            tid = self._tracks[track] = len(self._tracks) + 1
        return tid

    def _push(self, ev: tuple) -> None:
        if len(self._events) == self.capacity:
            self.dropped += 1
        self._events.append(ev)

    @contextmanager
    def span(self, name: str, track: str = DEFAULT_TRACK,
             **args) -> Iterator[None]:
        """Timed nested span (Chrome ``X`` event). Exception-safe: the span
        closes (and is recorded) even if the body raises."""
        if not self.enabled:
            yield
            return
        depth = self._depth.get(track, 0)
        self._depth[track] = depth + 1
        t0 = self.clock()
        try:
            yield
        finally:
            t1 = self.clock()
            self._depth[track] = depth
            self._push(("X", name, track, t0, t1 - t0, depth,
                        args or None))

    def instant(self, name: str, track: str = DEFAULT_TRACK, **args) -> None:
        """Point-in-time event (Chrome ``i`` event)."""
        if not self.enabled:
            return
        self._push(("i", name, track, self.clock(), 0.0,
                    self._depth.get(track, 0), args or None))

    def counter(self, name: str, value: float,
                track: str = DEFAULT_TRACK) -> None:
        """Counter sample (Chrome ``C`` event — a value-over-time track)."""
        if not self.enabled:
            return
        self._push(("C", name, track, self.clock(), 0.0, 0,
                    {"value": value}))

    # ---------------------------- reading ----------------------------- #
    def __len__(self) -> int:
        return len(self._events)

    def events(self) -> List[dict]:
        """Decoded events, oldest first (tests/analysis; the export path
        is :meth:`to_chrome_trace`)."""
        return [{"ph": ph, "name": name, "track": track, "ts": ts,
                 "dur": dur, "depth": depth, "args": args}
                for ph, name, track, ts, dur, depth, args in self._events]

    def find(self, name: str) -> List[dict]:
        return [e for e in self.events() if e["name"] == name]

    # ---------------------------- export ------------------------------ #
    def to_chrome_trace(self) -> dict:
        """Chrome trace-event JSON object (load in chrome://tracing or
        https://ui.perfetto.dev). Deterministic given a deterministic
        clock: events keep ring order, track ids keep first-use order."""
        body: List[dict] = []
        for ph, name, track, ts, dur, depth, args in self._events:
            ev = {"ph": ph, "name": name, "pid": PID,
                  "tid": self._tid(track), "ts": round(ts * 1e6, 3)}
            if ph == "X":
                ev["dur"] = round(dur * 1e6, 3)
            if ph == "i":
                ev["s"] = "t"
            if args is not None:
                ev["args"] = args
            body.append(ev)
        # metadata AFTER the body walk: that's what assigns track ids
        meta = [{"ph": "M", "name": "thread_name", "pid": PID, "tid": tid,
                 "args": {"name": track}}
                for track, tid in sorted(self._tracks.items(),
                                         key=lambda kv: kv[1])]
        return {"traceEvents": meta + body, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def to_json(self, **dump_kw) -> str:
        return json.dumps(self.to_chrome_trace(), sort_keys=True, **dump_kw)

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())


# The shared disabled tracer: safe default for every instrumented module.
NULL_TRACER = Tracer(capacity=0, enabled=False)


def validate_chrome_trace(doc: dict) -> None:
    """Schema check used by tests and the benchmark gate: raises on
    anything chrome://tracing / Perfetto would reject."""
    assert isinstance(doc, dict) and isinstance(doc.get("traceEvents"), list)
    json.dumps(doc)   # must be pure JSON
    for ev in doc["traceEvents"]:
        assert isinstance(ev.get("name"), str) and ev["name"]
        assert ev.get("ph") in ("X", "i", "C", "M"), ev
        assert isinstance(ev.get("pid"), int)
        assert isinstance(ev.get("tid"), int)
        if ev["ph"] == "M":
            continue
        assert isinstance(ev.get("ts"), (int, float)) and ev["ts"] >= 0
        if ev["ph"] == "X":
            assert isinstance(ev.get("dur"), (int, float)) and ev["dur"] >= 0
        if ev["ph"] == "i":
            assert ev.get("s") in ("t", "p", "g")
