"""ShardedPlan: the ExecutionPlan sliced per sequence shard — SALO's
hierarchical window splitting at datacenter scale.

The paper's data scheduler splits a sliding window so a PE array only ever
sees neighboring tiles; across arrays the same argument says a sequence
shard only needs **neighbor** KV tiles (the band reach) plus the tiny
global-token set — a halo exchange, not an all-gather. This module lowers
that into the ExecutionPlan IR:

* ``shard_plan(plan, n_shards)`` slices the plan's step tables by owner
  query block. Every KV tile a shard's rows reference is classified as
  **local** (owned), **halo** (owned by a shard at signed distance ``δ``,
  fetched by one ``ppermute`` per distinct distance — distance sets beyond
  ±1 arise from 2-D ViL bands or windows wider than a shard), or
  **global** (a tile holding global-prefix keys, broadcast once by a
  masked ``psum`` — so ``n_global`` may exceed a shard's length, which the
  retired prototype silently truncated). The tables are remapped onto each
  shard's **local view** ``[local | halo groups | global slots]`` and
  stacked per shard; at run time each device selects its slice by
  ``axis_index`` and feeds it to the *existing fused engines* — the Pallas
  scalar-prefetch kernels or their XLA scan twins — via the table-driven
  entry points (``salo_table_attention`` & co.).

* Because every row's full step set executes on its owner device, the
  windowed + global-column output is already normalized — no cross-device
  softmax merge. Only global *rows* (global queries attending everything)
  need cross-shard state, and they are the same tiny dense epilogue the
  single-device wrapper uses, computed on the original (globally sharded)
  arrays.

* The backward reuses :func:`repro.core.blockwise.plan_backward` — ONE
  contract with the single-device engines — with shard-mapped gradient
  passes: dQ replays the local tables against the re-exchanged view;
  dK/dV walks the shard's PACKED transposed tables over the view, then
  halo-tile gradients ride the *reverse* ``ppermute`` back to their owners
  and global-slot gradients a ``psum``, scatter-added into the owner's
  local dK/dV — the exact adjoint of the forward exchange.

Traffic per device per layer: ``(halo_tiles * Bk + n_global_tiles * Bk) *
d`` — independent of sequence length — vs ``(n_shards - 1) * n_local * d``
for all-gather ring attention (quantified in ``benchmarks/dist_stats.py``
-> ``BENCH_dist.json``).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core.blockwise import (_global_rows, plan_backward,
                                  table_attention_scan, table_dkv_scan,
                                  table_dkv_scatter_scan, table_dq_scan,
                                  undo_working, working_stream)
from repro.core.patterns import HybridSparsePattern
from repro.core.scheduler import (PAD_SENTINEL, ExecutionPlan, build_plan,
                                  pack_rows, schedule)


# ---------------------------------------------------------------------- #
# The ShardedPlan IR
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True, eq=False)
class ShardedPlan:
    """Static per-shard slicing of an ExecutionPlan (pure numpy metadata).

    Stacked arrays carry one row per shard; device ``s`` selects row
    ``axis_index`` at run time. View-tile indices live in
    ``[0, view_tiles)`` over the local layout
    ``[nkb_l local | halo group per distance | n_gt global slots]``.
    """
    plan: ExecutionPlan
    n_shards: int
    nq_l: int                     # query blocks per shard
    nkb_l: int                    # owned KV tiles per shard
    gtiles: Tuple[int, ...]       # global-key tiles (global tile order)
    halo_dists: Tuple[int, ...]   # distinct signed owner distances
    halo_counts: Tuple[int, ...]  # per distance: padded slot count T_δ
    halo_real: Tuple[int, ...]    # per shard: real (unpadded) halo tiles
    view_tiles: int               # nkb_l + sum(halo_counts) + n_gt
    tables: np.ndarray            # (n_shards, nq_l, W) view-tile ids
    flags: np.ndarray             # (n_shards, nq_l, W) step flags
    view_map: np.ndarray          # (n_shards, view_tiles) global tile each
    #                               view slot holds after the exchange (-1 =
    #                               padded halo slot, never referenced) — the
    #                               repro.analysis exchange-soundness hook
    send_idx: Tuple[np.ndarray, ...]  # per distance: (n_shards, T_δ) local
    #                                   tile indices each shard SENDS (pad 0)
    g_owner_idx: np.ndarray       # (n_shards, n_gt) local idx of owned gtile
    g_owned: np.ndarray           # (n_shards, n_gt) bool ownership mask
    pos_q: np.ndarray             # (n_shards, nq_l, block_q) positions
    pos_k: np.ndarray             # (n_shards, view_tiles, block_k) positions
    t_row_tile: np.ndarray        # (n_shards, R) packed dK/dV owner tiles
    t_q_blocks: np.ndarray        # (n_shards, R, Wt) packed local q blocks
    t_flags: np.ndarray           # (n_shards, R, Wt)

    @property
    def n_gt(self) -> int:
        return len(self.gtiles)

    # ------------------------------------------------------------------ #
    def stats(self, d: int, dtype_bytes: int = 2) -> dict:
        """Per-device per-layer collective bytes (the paper's halo claim).

        ``halo_tiles``/``halo_bytes`` count what ``_build_views`` actually
        TRANSMITS: every shard sends the padded ``sum(halo_counts)`` slots
        per direction (SPMD buffers are padded to the worst shard per
        distance, wrap sends included); ``halo_tiles_real`` is the worst
        shard's unpadded need, for reference. ``bcast_bytes`` is the
        global-tile psum, vs the all-gather ring baseline that cycles
        every other shard's full KV through each device."""
        bk = self.plan.block_k
        halo_tiles = sum(self.halo_counts)
        halo_bytes = halo_tiles * bk * d * dtype_bytes * 2
        bcast_bytes = self.n_gt * bk * d * dtype_bytes * 2
        allgather_bytes = ((self.n_shards - 1) * self.nkb_l * bk * d
                           * dtype_bytes * 2)
        return dict(
            n_shards=self.n_shards,
            n_local=self.nkb_l * bk,
            halo_tiles=halo_tiles,
            halo_tiles_real=max(self.halo_real) if self.halo_real else 0,
            global_tiles=self.n_gt,
            halo_bytes=halo_bytes,
            bcast_bytes=bcast_bytes,
            exchange_bytes=halo_bytes + bcast_bytes,
            allgather_bytes=allgather_bytes,
            bytes_ratio=(halo_bytes + bcast_bytes)
            / max(allgather_bytes, 1),
        )


@functools.lru_cache(maxsize=64)
def shard_plan(plan: ExecutionPlan, n_shards: int) -> ShardedPlan:
    """Slice ``plan`` into per-shard step tables + exchange metadata."""
    nq, nkb = plan.nq, plan.nkb
    if nq % n_shards or nkb % n_shards:
        raise ValueError(
            f"plan grid ({nq} q blocks, {nkb} KV tiles) must be divisible "
            f"by n_shards={n_shards}; build the plan with pad_multiple="
            f"n_shards * lcm(block_q, block_k)")
    nq_l, nkb_l = nq // n_shards, nkb // n_shards
    bq, bk = plan.block_q, plan.block_k
    pos = plan.positions_padded()
    g = plan.sched.n_global

    if g > 0:
        gtiles = [int(t) for t in np.nonzero(
            (pos.reshape(nkb, bk) < g).any(axis=1))[0]]
    else:
        gtiles = []
    gset = set(gtiles)
    g_index = {t: i for i, t in enumerate(gtiles)}
    n_gt = len(gtiles)

    # Referenced non-local, non-global tiles per shard, grouped by the
    # signed owner distance δ (owner = shard + δ).
    halo = []
    for s in range(n_shards):
        tiles = set()
        for i in range(s * nq_l, (s + 1) * nq_l):
            for st in range(int(plan.num_steps[i])):
                tiles.add(int(plan.kv_blocks[i, st]))
        halo.append(sorted(t for t in tiles
                           if t // nkb_l != s and t not in gset))
    dists = sorted({t // nkb_l - s for s in range(n_shards)
                    for t in halo[s]})
    need = {d: [[t for t in halo[s] if t // nkb_l - s == d]
                for s in range(n_shards)] for d in dists}
    counts = [max(len(need[d][s]) for s in range(n_shards)) for d in dists]
    view_tiles = nkb_l + sum(counts) + n_gt

    # Group base offsets in the view + per-shard view index of each tile.
    group_off = {}
    off = nkb_l
    for d, T in zip(dists, counts):
        group_off[d] = off
        off += T
    g_base = off
    view_of = []   # per shard: {global tile -> view tile}
    for s in range(n_shards):
        m = {}
        for t in range(s * nkb_l, (s + 1) * nkb_l):
            m[t] = t - s * nkb_l
        for d in dists:
            for slot, t in enumerate(need[d][s]):
                m[t] = group_off[d] + slot
        for t in gtiles:
            m.setdefault(t, g_base + g_index[t])
        view_of.append(m)

    # Remapped step tables (values -> view tiles), stacked per shard.
    W = plan.max_steps
    tables = np.zeros((n_shards, nq_l, W), dtype=np.int32)
    flags = np.zeros((n_shards, nq_l, W), dtype=np.int32)
    for s in range(n_shards):
        for i_l in range(nq_l):
            i = s * nq_l + i_l
            for st in range(int(plan.num_steps[i])):
                tables[s, i_l, st] = view_of[s][int(plan.kv_blocks[i, st])]
                flags[s, i_l, st] = int(plan.flags[i, st])

    # What each view slot physically holds after _build_views runs: the
    # local region is the shard's own tiles, each halo group slot the tile
    # its need-list ordered there, each global slot its gtile. Padded halo
    # slots (beyond a shard's need, up to the SPMD-common T_δ) carry -1:
    # they receive whatever the sender's slot-0 default gathers, are
    # referenced by no table, and keep PAD_SENTINEL positions. This map is
    # what repro.analysis.plan_verify proves the tables + send schedule
    # against.
    view_map = np.full((n_shards, view_tiles), -1, dtype=np.int32)
    for s in range(n_shards):
        view_map[s, :nkb_l] = np.arange(s * nkb_l, (s + 1) * nkb_l)
        for d in dists:
            for slot, t in enumerate(need[d][s]):
                view_map[s, group_off[d] + slot] = t
        for gi, t in enumerate(gtiles):
            view_map[s, g_base + gi] = t

    # What each shard SENDS per distance: the tiles its receiver (shard
    # s - δ, which fetches from owner s) listed, as local tile indices.
    send_idx = []
    for d, T in zip(dists, counts):
        arr = np.zeros((n_shards, T), dtype=np.int32)
        for j in range(n_shards):
            r = j - d
            if 0 <= r < n_shards:
                for slot, t in enumerate(need[d][r]):
                    arr[j, slot] = t - j * nkb_l
        send_idx.append(arr)

    g_owner_idx = np.zeros((n_shards, max(n_gt, 1)), dtype=np.int32)
    g_owned = np.zeros((n_shards, max(n_gt, 1)), dtype=bool)
    for gi, t in enumerate(gtiles):
        o = t // nkb_l
        g_owner_idx[o, gi] = t - o * nkb_l
        g_owned[o, gi] = True
    g_owner_idx = g_owner_idx[:, :n_gt]
    g_owned = g_owned[:, :n_gt]

    # Static positions: local queries; the view's local/halo/global slots.
    pos_q = pos.reshape(n_shards, nq_l, bq).copy()
    pos_k = np.full((n_shards, view_tiles, bk), PAD_SENTINEL, dtype=np.int32)
    pos_t = pos.reshape(nkb, bk)
    for s in range(n_shards):
        pos_k[s, :nkb_l] = pos_t[s * nkb_l: (s + 1) * nkb_l]
        for d in dists:
            for slot, t in enumerate(need[d][s]):
                pos_k[s, group_off[d] + slot] = pos_t[t]
        for gi, t in enumerate(gtiles):
            pos_k[s, g_base + gi] = pos_t[t]

    # Packed local transposed tables (dK/dV): per shard, per VIEW tile, the
    # local query blocks that visit it — one common packed width so the
    # stacked arrays stay rectangular across shards.
    rows_per_shard = []
    all_lens = []
    for s in range(n_shards):
        rows = [[] for _ in range(view_tiles)]
        for i_l in range(nq_l):
            i = s * nq_l + i_l
            for st in range(int(plan.num_steps[i])):
                fl = int(plan.flags[i, st])
                if fl:
                    rows[int(tables[s, i_l, st])].append((i_l, fl))
        rows_per_shard.append(rows)
        all_lens.extend(len(r) for r in rows if r)
    lens = np.asarray(all_lens if all_lens else [1])
    width = max(1, int(np.ceil(np.percentile(lens, 95))))
    packed = [pack_rows(rows, width) for rows in rows_per_shard]
    R = max(p[0].shape[0] for p in packed)
    t_row_tile = np.zeros((n_shards, R), dtype=np.int32)
    t_q_blocks = np.zeros((n_shards, R, width), dtype=np.int32)
    t_flags = np.zeros((n_shards, R, width), dtype=np.int32)
    for s, (rt, qb, fl, _ns, _w) in enumerate(packed):
        r = rt.shape[0]
        t_row_tile[s, :r] = rt
        t_q_blocks[s, :r] = qb
        t_flags[s, :r] = fl

    return ShardedPlan(
        plan=plan, n_shards=n_shards, nq_l=nq_l, nkb_l=nkb_l,
        gtiles=tuple(gtiles), halo_dists=tuple(dists),
        halo_counts=tuple(counts),
        halo_real=tuple(len(h) for h in halo), view_tiles=view_tiles,
        tables=tables, flags=flags, view_map=view_map,
        send_idx=tuple(send_idx),
        g_owner_idx=g_owner_idx, g_owned=g_owned, pos_q=pos_q, pos_k=pos_k,
        t_row_tile=t_row_tile, t_q_blocks=t_q_blocks, t_flags=t_flags)


# ---------------------------------------------------------------------- #
# Cross-shard softmax merge for the sharded SERVING engines
# ---------------------------------------------------------------------- #
def masked_psum_merge(out: jax.Array, m: jax.Array, l: jax.Array,
                      axis: str) -> jax.Array:
    """Combine per-shard finalized attention partials across a mesh axis.

    The serving-side counterpart of the training path's halo exchange: the
    sharded paged slab gives each shard of the "seq" axis a disjoint slice
    of every request's cache, so decode / chunked prefill run ONE launch
    per shard over the owned slots and the partials are merged here — the
    cross-device instance of :func:`repro.core.renorm.merge`, applied to
    finalized triples. ``out``: (..., d) = acc / l (guarded); ``m``/``l``:
    (...) row stats. Each shard's contribution is weighted by
    ``c = l * exp(m - M)`` with ``M = pmax(m)``; the
    ``renorm.PartialState`` empty-row identity ``(0, NEG_INF, 0)`` gives
    ``c == 0``, which is what makes the psum *masked*: shards holding no
    valid slot for a row (inactive request, slot owned elsewhere, ring not
    yet reaching this shard) contribute exactly nothing, with no explicit
    mask traffic.
    """
    from repro.core.renorm import NEG_INF

    M = jax.lax.pmax(m, axis)
    shift = jnp.where(M <= NEG_INF / 2, 0.0, M)
    c = l * jnp.exp(m - shift)       # m <= M; empty rows: l == 0 -> c == 0
    num = jax.lax.psum(out.astype(jnp.float32) * c[..., None], axis)
    den = jax.lax.psum(c, axis)
    return (num / jnp.where(den == 0.0, 1.0, den)[..., None]).astype(
        out.dtype)


# ---------------------------------------------------------------------- #
# The halo/broadcast exchange and its exact adjoint
# ---------------------------------------------------------------------- #
def _build_views(sp: ShardedPlan, axis: str, idx, k_l, v_l):
    """Local KV -> full local view: one ppermute per halo distance (K and V
    ride one stacked buffer) + one masked psum for the global tiles."""
    B, _, D = k_l.shape
    bk = sp.plan.block_k
    kv = jnp.stack([k_l.reshape(B, sp.nkb_l, bk, D),
                    v_l.reshape(B, sp.nkb_l, bk, D)])
    parts = [kv]
    for d_i, (delta, T) in enumerate(zip(sp.halo_dists, sp.halo_counts)):
        sel = jnp.take(jnp.asarray(sp.send_idx[d_i]), idx, axis=0)
        buf = jnp.take(kv, sel, axis=2)                   # (2, B, T, bk, D)
        perm = [(j, (j - delta) % sp.n_shards) for j in range(sp.n_shards)]
        parts.append(jax.lax.ppermute(buf, axis, perm))
    if sp.n_gt:
        gsel = jnp.take(jnp.asarray(sp.g_owner_idx), idx, axis=0)
        gown = jnp.take(jnp.asarray(sp.g_owned), idx, axis=0)
        contrib = jnp.where(gown[None, None, :, None, None],
                            jnp.take(kv, gsel, axis=2),
                            jnp.zeros((), kv.dtype))
        parts.append(jax.lax.psum(contrib, axis))
    view = jnp.concatenate(parts, axis=2)       # (2, B, view_tiles, bk, D)
    return (view[0].reshape(B, sp.view_tiles * bk, D),
            view[1].reshape(B, sp.view_tiles * bk, D))


def _return_views(sp: ShardedPlan, axis: str, idx, dk_view, dv_view):
    """Adjoint of :func:`_build_views`: halo-slot gradients ride the
    REVERSE ppermute back to their owner shard; global-slot gradients are
    psum'd and claimed by each tile's owner. Padded slots are never
    referenced by any table, so their gradients are exactly zero and the
    scatter-adds of the padding lanes are no-ops."""
    B, _, D = dk_view.shape
    bk = sp.plan.block_k
    dkv = jnp.stack([dk_view.reshape(B, sp.view_tiles, bk, D),
                     dv_view.reshape(B, sp.view_tiles, bk, D)])
    dloc = dkv[:, :, : sp.nkb_l]
    off = sp.nkb_l
    for d_i, (delta, T) in enumerate(zip(sp.halo_dists, sp.halo_counts)):
        buf = dkv[:, :, off: off + T]
        off += T
        perm = [(j, (j + delta) % sp.n_shards) for j in range(sp.n_shards)]
        back = jax.lax.ppermute(buf, axis, perm)
        sel = jnp.take(jnp.asarray(sp.send_idx[d_i]), idx, axis=0)
        dloc = dloc.at[:, :, sel].add(back)
    if sp.n_gt:
        dg = jax.lax.psum(dkv[:, :, off: off + sp.n_gt], axis)
        gsel = jnp.take(jnp.asarray(sp.g_owner_idx), idx, axis=0)
        gown = jnp.take(jnp.asarray(sp.g_owned), idx, axis=0)
        dloc = dloc.at[:, :, gsel].add(
            jnp.where(gown[None, None, :, None, None], dg,
                      jnp.zeros((), dg.dtype)))
    return (dloc[0].reshape(B, sp.nkb_l * bk, D),
            dloc[1].reshape(B, sp.nkb_l * bk, D))


# ---------------------------------------------------------------------- #
# Shard-local engines (the existing fused kernels / their XLA twins)
# ---------------------------------------------------------------------- #
def _resolve_engine(impl: str):
    """("pallas", interpret) when the fused kernel can execute, else
    ("blockwise", False) — the ops.py degrade rule, per device."""
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels.ops import _use_fallback
        interpret = impl == "pallas_interpret"
        if not _use_fallback(interpret):
            return "pallas", interpret
    return "blockwise", False


def _shard_tables(sp: ShardedPlan, idx):
    tbl = jnp.take(jnp.asarray(sp.tables), idx, axis=0)     # (nq_l, W)
    flg = jnp.take(jnp.asarray(sp.flags), idx, axis=0)
    pq = jnp.take(jnp.asarray(sp.pos_q), idx, axis=0)       # (nq_l, bq)
    pk = jnp.take(jnp.asarray(sp.pos_k), idx, axis=0)       # (view, bk)
    return tbl, flg, pq, pk


@functools.lru_cache(maxsize=64)
def _sharded_always_keep(sp: ShardedPlan, local_window: int) -> np.ndarray:
    """Per-shard never-drop masks over the candidate tables: the dynamic
    selection runs on each shard's [local | halo | global] view, and the
    causal-local/global exemptions are decided on ORIGINAL positions — the
    view remap is transparent. Stacked (n_shards, nq_l, W) bool."""
    from repro.core.dynamic import always_keep_mask
    out = np.zeros(sp.tables.shape, dtype=bool)
    for s in range(sp.n_shards):
        out[s] = always_keep_mask(sp.tables[s], sp.flags[s], sp.pos_q[s],
                                  sp.pos_k[s], local_window,
                                  sp.plan.sched.causal)
    return out


def _dyn_select(sp: ShardedPlan, dyn, idx, q_l, k_view, tbl, flg, pq, pk,
                scale: float):
    """Per-shard top-k over the traced candidate slice: same selector as
    the single-device path, run INSIDE the shard_map region after the view
    exchange — the ppermute/psum schedule stays static while the executed
    steps are content-chosen. Deterministic in (q_l, k_view), so forward
    and backward replay the identical table."""
    from repro.core.dynamic import _resolve_window, select_steps
    lw = _resolve_window(dyn, sp.plan.block_q, sp.plan.block_k)
    ak = jnp.take(jnp.asarray(_sharded_always_keep(sp, lw)), idx, axis=0)
    keep = min(int(dyn.keep), sp.tables.shape[2])
    return select_steps(q_l, k_view, tbl, flg, pq, pk, ak, keep, scale,
                        dyn.pool_k)


def _make_local_fwd(sp: ShardedPlan, axis: str, scale: float, impl: str,
                    dyn=None):
    engine, interpret = _resolve_engine(impl)
    sched = sp.plan.sched
    bq, bk = sp.plan.block_q, sp.plan.block_k

    def local(q_l, k_l, v_l):
        idx = jax.lax.axis_index(axis)
        tbl, flg, pq, pk = _shard_tables(sp, idx)
        k_view, v_view = _build_views(sp, axis, idx, k_l, v_l)
        if dyn is not None:
            tbl, flg = _dyn_select(sp, dyn, idx, q_l, k_view, tbl, flg,
                                   pq, pk, scale)
        if engine == "pallas":
            from repro.kernels.salo_attention import salo_table_attention
            return salo_table_attention(
                q_l, k_view, v_view, pq, pk, tbl.reshape(-1),
                flg.reshape(-1), sched=sched, block_q=bq, block_k=bk,
                scale=scale, interpret=interpret)
        return table_attention_scan(q_l, k_view, v_view, pq, pk, tbl, flg,
                                    sched, scale)

    return local


def _make_local_bwd(sp: ShardedPlan, axis: str, scale: float, impl: str,
                    dyn=None):
    """ONE shard-local backward: a single view exchange feeds BOTH the dQ
    pass (local forward tables) and the dK/dV pass (packed transposed
    tables) — separate shard_map regions would each re-run the halo
    ppermutes + global psum (collectives don't CSE across regions).

    Dynamic plans replay the forward's selection from (q_l, k_view)
    (gradient-free, deterministic) and swap the packed-transposed dK/dV
    walk — a host-built artifact that cannot exist for runtime tables —
    for the scatter twin over the view."""
    engine, interpret = _resolve_engine(impl)
    sched = sp.plan.sched
    bq, bk = sp.plan.block_q, sp.plan.block_k

    def local(dout, delta, m, l, q_l, k_l, v_l):
        idx = jax.lax.axis_index(axis)
        tbl, flg, pq, pk = _shard_tables(sp, idx)
        rt = jnp.take(jnp.asarray(sp.t_row_tile), idx, axis=0)
        qbt = jnp.take(jnp.asarray(sp.t_q_blocks), idx, axis=0)
        tfl = jnp.take(jnp.asarray(sp.t_flags), idx, axis=0)
        k_view, v_view = _build_views(sp, axis, idx, k_l, v_l)
        if dyn is not None:
            tbl, flg = _dyn_select(sp, dyn, idx, q_l, k_view, tbl, flg,
                                   pq, pk, scale)
            if engine == "pallas":
                from repro.kernels.salo_backward import \
                    salo_table_backward_dq
                dq = salo_table_backward_dq(
                    dout, delta, m, l, q_l, k_view, v_view, pq, pk,
                    tbl.reshape(-1), flg.reshape(-1), sched=sched,
                    block_q=bq, block_k=bk, scale=scale,
                    interpret=interpret)
            else:
                dq = table_dq_scan(dout, delta, m, l, q_l, k_view, v_view,
                                   pq, pk, tbl, flg, sched, scale)
            dk_view, dv_view = table_dkv_scatter_scan(
                dout, delta, m, l, q_l, k_view, v_view, pq, pk, tbl, flg,
                sched, scale)
            dk_l, dv_l = _return_views(sp, axis, idx, dk_view, dv_view)
            return dq, dk_l, dv_l
        if engine == "pallas":
            from repro.kernels.salo_backward import (salo_table_backward_dq,
                                                     salo_table_backward_dkv)
            dq = salo_table_backward_dq(
                dout, delta, m, l, q_l, k_view, v_view, pq, pk,
                tbl.reshape(-1), flg.reshape(-1), sched=sched, block_q=bq,
                block_k=bk, scale=scale, interpret=interpret)
            dk_view, dv_view = salo_table_backward_dkv(
                dout, delta, m, l, q_l, k_view, v_view, pq, pk, rt,
                qbt.reshape(-1), tfl.reshape(-1), sched=sched, block_q=bq,
                block_k=bk, nkb=sp.view_tiles, scale=scale,
                interpret=interpret)
        else:
            dq = table_dq_scan(dout, delta, m, l, q_l, k_view, v_view, pq,
                               pk, tbl, flg, sched, scale)
            dk_view, dv_view = table_dkv_scan(
                dout, delta, m, l, q_l, k_view, v_view, pq, pk, rt, qbt,
                tfl, sched, scale)
        dk_l, dv_l = _return_views(sp, axis, idx, dk_view, dv_view)
        return dq, dk_l, dv_l

    return local


# ---------------------------------------------------------------------- #
# The sharded attention entry point (custom VJP over shard_map passes)
# ---------------------------------------------------------------------- #
def _sharded_forward(q, k, v, sp, mesh, axis, scale, impl, dyn=None):
    plan, sched = sp.plan, sp.plan.sched
    N = q.shape[1]
    qw = working_stream(q, sched, plan)
    kw = working_stream(k, sched, plan)
    vw = working_stream(v, sched, plan)
    fn = shard_map(_make_local_fwd(sp, axis, scale, impl, dyn), mesh=mesh,
                   in_specs=(P(None, axis, None),) * 3,
                   out_specs=(P(None, axis, None), P(None, axis),
                              P(None, axis)),
                   check_vma=False)
    out_w, m, l = fn(qw, kw, vw)
    out_w = out_w.astype(q.dtype)
    out = undo_working(out_w, sched, N)
    if sched.n_global > 0 and sched.global_rows:
        rows = _global_rows(q, k, v, sched, scale, q.dtype)
        # concatenate, NOT out.at[:, :g].set(rows): a dynamic-update-slice
        # into the seq-sharded shard_map output miscompiles on the forced-
        # host-device CPU backend (update lands at per-shard offsets).
        out = jnp.concatenate([rows, out[:, sched.n_global:]], axis=1)
    return out, (out_w, m, l)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _sharded(q, k, v, sp, mesh, axis, scale, impl, dyn):
    out, _ = _sharded_forward(q, k, v, sp, mesh, axis, scale, impl, dyn)
    return out


def _sharded_fwd(q, k, v, sp, mesh, axis, scale, impl, dyn):
    out, (out_w, m, l) = _sharded_forward(q, k, v, sp, mesh, axis, scale,
                                          impl, dyn)
    return out, (q, k, v, out_w, m, l)


def _sharded_bwd(sp, mesh, axis, scale, impl, dyn, res, g):
    q, k, v, out_w, m, l = res

    # plan_backward invokes dq_engine then dkv_engine with identical
    # arguments; both answers come from ONE combined shard_map region
    # (single view exchange), stashed across the two calls.
    stash = {}

    def dq_engine(dout, delta, m_, l_, qw, kw, vw, pos):
        fn = shard_map(_make_local_bwd(sp, axis, scale, impl, dyn),
                       mesh=mesh,
                       in_specs=(P(None, axis, None), P(None, axis),
                                 P(None, axis), P(None, axis),
                                 P(None, axis, None), P(None, axis, None),
                                 P(None, axis, None)),
                       out_specs=(P(None, axis, None), P(None, axis, None),
                                  P(None, axis, None)), check_vma=False)
        dq, dk, dv = fn(dout, delta, m_, l_, qw, kw, vw)
        stash["dkv"] = (dk, dv)
        return dq

    def dkv_engine(dout, delta, m_, l_, qw, kw, vw, pos):
        return stash.pop("dkv")

    return plan_backward(g, q, k, v, out_w, m, l, sp.plan, scale,
                         dq_engine, dkv_engine)


_sharded.defvjp(_sharded_fwd, _sharded_bwd)


def _auto_block(n_work: int, n_shards: int, requested: Optional[int]) -> int:
    """Largest power-of-two block <= min(128, the shard's slot count) —
    keeps pad_multiple (= n_shards * lcm of the blocks) from inflating
    n_pad far past the sequence on small shards."""
    b = 8
    while b * 2 <= min(128, max(8, n_work // n_shards)):
        b *= 2
    return min(requested, b) if requested else b


def sharded_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      pattern: HybridSparsePattern, mesh: Mesh,
                      axis: str = "data", *,
                      block_q: Optional[int] = None,
                      block_k: Optional[int] = None,
                      scale: Optional[float] = None,
                      impl: str = "blockwise",
                      dynamic=None) -> jax.Array:
    """Sequence-parallel hybrid sparse attention over ``mesh[axis]``.

    q/k/v: (B, N, D) with N sharded over ``axis`` (B typically folds
    batch*heads). Supports everything the single-device plan supports —
    dilation > 1 (the stride permutation runs on the global arrays before
    the shard_map region; XLA lowers it to an all-to-all, a one-off
    activation-sized reshuffle), 2-D ViL bands, reordered global tiles,
    causal and bidirectional windows (halos on both sides), and windows
    wider than a shard (multi-hop halo distances). Differentiable: the
    backward is the shared ``plan_backward`` contract with shard-mapped
    dQ/dK/dV passes and reverse-ppermute gradient returns.

    ``impl`` picks the shard-local engine: "blockwise" (XLA scan twin),
    "pallas"/"pallas_interpret" (the fused scalar-prefetch kernels via
    their table-driven entry points; compiled mode degrades to the twin
    off-TPU exactly like kernels/ops.py).

    ``dynamic`` (a :class:`repro.core.dynamic.DynamicConfig`) turns on
    content-based selection: each shard top-k's its own candidate steps
    over the exchanged [local | halo | global] view, so the collective
    schedule stays static while the executed tiles are data-dependent.
    """
    B, N, D = q.shape
    n_shards = int(mesh.shape[axis])
    sched = schedule(pattern, N)
    bq = _auto_block(sched.n_work, n_shards, block_q)
    bk = _auto_block(sched.n_work, n_shards, block_k)
    plan = build_plan(sched, bq, bk, n_shards * math.lcm(bq, bk))
    sp = shard_plan(plan, n_shards)
    scale_ = (D ** -0.5) if scale is None else scale
    if dynamic is not None:
        from repro.core.dynamic import (_account_build, _resolve_window,
                                        check_keep)
        lw = _resolve_window(dynamic, bq, bk)
        check_keep(min(int(dynamic.keep), sp.tables.shape[2]),
                   _sharded_always_keep(sp, lw), what="sharded plan")
        _account_build(sp.flags.reshape(-1, sp.tables.shape[2]),
                       min(int(dynamic.keep), sp.tables.shape[2]))
    return _sharded(q, k, v, sp, mesh, axis, scale_, impl, dynamic)
