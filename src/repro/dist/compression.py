"""int8 gradient compression with error feedback (cross-pod all-reduce).

The pod axis of the production mesh crosses the DCN boundary, where gradient
all-reduce bytes dominate. Symmetric per-tensor int8 quantization cuts them
4x; error feedback (Karimireddy et al., 2019) carries the quantization
residual into the next step so the *accumulated* update stays unbiased
(property-tested in tests/test_substrates.py).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _q8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization: returns (q, scale)."""
    s = jnp.max(jnp.abs(x)) / 127.0
    s = jnp.where(s == 0.0, 1.0, s)
    q = jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8)
    return q, s.astype(jnp.float32)


def _dq(q: jax.Array, s: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * s


def compress_decompress(grads, ef_state=None):
    """Quantize-dequantize every leaf with error feedback.

    ``ef_state`` carries each leaf's residual (None on the first step).
    Returns (grads', ef_state') where grads' is what the (compressed)
    all-reduce would deliver and ef_state' the residual to re-inject.
    """
    if ef_state is None:
        ef_state = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32),
                                grads)

    def one(g, ef):
        e = g.astype(jnp.float32) + ef
        q, s = _q8(e)
        out = _dq(q, s)
        return out.astype(g.dtype), e - out

    flat = jax.tree.map(one, grads, ef_state)
    out = jax.tree.map(lambda t: t[0], flat,
                       is_leaf=lambda t: isinstance(t, tuple))
    ef = jax.tree.map(lambda t: t[1], flat,
                      is_leaf=lambda t: isinstance(t, tuple))
    return out, ef


def compressed_psum(x: jax.Array, axis) -> jax.Array:
    """psum of int8-quantized operands (the wire format of the cross-pod
    all-reduce). Each participant quantizes locally; the sum happens on the
    dequantized values (bandwidth model: int8 + one f32 scale per tensor).
    ``axis``: a mesh axis name or tuple of names (pod x data)."""
    q, s = _q8(x)
    return jax.lax.psum(_dq(q, s), axis)


def compressed_psum_with_residual(x: jax.Array, axis):
    """:func:`compressed_psum` that also returns this participant's
    quantization residual ``x - dq(q8(x))`` — what the train step's error
    feedback carries into the next step so the accumulated update stays
    unbiased (the wire itself moved only int8 + one scale)."""
    q, s = _q8(x)
    dq = _dq(q, s)
    return jax.lax.psum(dq, axis), x - dq
