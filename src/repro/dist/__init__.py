"""Distributed substrate: logical-axis sharding rules and gradient
compression.

* :mod:`repro.dist.sharding` — named logical axes ("batch", "seq", "heads",
  ...) resolved to mesh axes through per-cell rule dicts, plus path-regex
  parameter shardings. Model code only ever names logical axes
  (:func:`repro.dist.sharding.constrain`); the launcher decides the mapping.
* :mod:`repro.dist.compression` — int8 gradient compression with error
  feedback for cross-pod all-reduce.
"""
