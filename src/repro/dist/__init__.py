"""Distributed substrate: logical-axis sharding rules, sequence-parallel
attention, and gradient compression.

* :mod:`repro.dist.sharding` — named logical axes ("batch", "seq", "heads",
  ...) resolved to mesh axes through per-cell rule dicts, plus path-regex
  parameter shardings. Model code only ever names logical axes
  (:func:`repro.dist.sharding.constrain`); the launcher decides the mapping.
  :func:`repro.dist.sharding.sequence_mesh_axis` reports when the "seq"
  axis is live so attention can switch engines.
* :mod:`repro.dist.sharded_plan` — the ShardedPlan IR: the fused
  ExecutionPlan kernels run per sequence shard under ``shard_map`` with
  ppermute halo exchange of neighbor KV tiles and psum-broadcast global
  tiles, forward and backward (reverse-ppermute gradient returns).
* :mod:`repro.dist.compression` — int8 gradient compression with error
  feedback for cross-pod all-reduce.
"""
