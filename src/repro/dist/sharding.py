"""Logical-axis sharding: rules, constraints, and param-path shardings.

Model code names *logical* axes only — ``constrain(x, "batch", "seq",
"embed")`` — and the launcher installs a rules dict mapping each logical
axis to zero or more mesh axes (``{"batch": ("pod", "data"), ...}``) via the
:func:`axis_rules` context manager. Outside any rules context (unit tests,
single-device runs) every constraint is the identity, so pure model code
never needs a mesh.

Parameter shardings are derived from the parameter tree *paths* — key names
in :mod:`repro.models.layers` are load-bearing (``wq``, ``w_in``, ``embed/w``
...) and matched by the regex table below.
"""
from __future__ import annotations

import contextlib
import math
import re
import threading
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Default mapping of logical axes -> mesh axes for the production meshes
# (pod, data, model). Cells override per shape via launch.specs.cell_rules.
DEFAULT_RULES: Dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,            # turned on for long-context cells (SP)
    "cache_seq": None,
    "embed": None,
    "ffn": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": None,
    "vocab": ("model",),
    "experts": ("model",),
    "expert_cap": None,
    "fsdp": ("data",),      # fallback axis for otherwise-replicated 2-D params
}

_CTX = threading.local()


def _stack():
    if not hasattr(_CTX, "stack"):
        _CTX.stack = []
    return _CTX.stack


@contextlib.contextmanager
def axis_rules(rules: Dict[str, Any], mesh: Optional[Mesh] = None):
    """Install ``rules`` (and optionally a mesh) for the dynamic extent."""
    _stack().append((dict(rules), mesh))
    try:
        yield
    finally:
        _stack().pop()


def current_rules() -> Optional[Dict[str, Any]]:
    s = _stack()
    return s[-1][0] if s else None


def _ambient_mesh() -> Optional[Mesh]:
    """Mesh from axis_rules(..., mesh) or the ``with mesh:`` context."""
    s = _stack()
    if s and s[-1][1] is not None:
        return s[-1][1]
    try:
        from jax.interpreters import pxla
        m = pxla.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def resolve(*logical) -> P:
    """PartitionSpec for logical axis names under the current rules."""
    rules = current_rules() or DEFAULT_RULES
    entries = []
    for name in logical:
        e = rules.get(name) if name else None
        if isinstance(e, tuple) and len(e) == 0:
            e = None
        entries.append(e)
    return P(*entries)


def _axes_product(mesh: Mesh, entry) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    axes = (entry if isinstance(entry, tuple)
            else (entry,) if entry else ())
    return math.prod(sizes.get(a, 1) for a in axes)


def _mesh_clean(mesh: Mesh, spec: P, shape=None) -> P:
    """Drop axes missing from the mesh, not dividing their dimension, or
    already consumed by an earlier dimension (a mesh axis may shard at most
    one positional dimension). With ``shape=None`` (shape unknown) the
    divisibility check is skipped — membership and reuse still apply."""
    if shape is None:
        entries, dims = list(spec), [None] * len(spec)
    else:
        entries = list(spec) + [None] * (len(shape) - len(spec))
        dims = list(shape)
    out = []
    used: set = set()
    for e, dim in zip(entries, dims):
        axes = (e if isinstance(e, tuple) else (e,) if e else ())
        axes = tuple(a for a in axes
                     if a in mesh.axis_names and a not in used)
        p = _axes_product(mesh, axes)
        if axes and p > 1 and (dim is None or dim % p == 0):
            used.update(axes)
            out.append(axes)
        else:
            out.append(None)
    return P(*out)


def constrain(x: jax.Array, *logical) -> jax.Array:
    """Sharding constraint by logical axis names; identity outside a rules
    context or on a trivial mesh. Safe inside any jit/grad transform."""
    rules = current_rules()
    if rules is None:
        return x
    mesh = _ambient_mesh()
    if mesh is None or mesh.empty or mesh.devices.size == 1:
        return x
    spec = _mesh_clean(mesh, resolve(*logical), x.shape)
    if all(e is None for e in spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def input_sharding(mesh: Mesh, rules: Dict[str, Any], *logical,
                   shape=None):
    """NamedSharding for an input by logical names (none -> replicated).

    Always ``_mesh_clean``'d: rules may name mesh axes that don't exist on
    this mesh (e.g. the default ``("pod", "data")`` batch rule on a 2-axis
    host mesh) or don't divide the dimension — pjit *argument* shardings
    (unlike constraints) reject both, so they are dropped here. Pass
    ``shape`` to enable the divisibility check (the single source of truth
    formerly duplicated as ``launch.specs._divisible``).
    """
    with axis_rules(rules):
        spec = resolve(*logical)
    return NamedSharding(mesh, _mesh_clean(mesh, spec, shape))


def sequence_mesh_axis():
    """(mesh, axis) when the active rules map "seq" onto exactly one mesh
    axis of size > 1 — the signal for :mod:`repro.dist.sharded_plan` to run
    attention sequence-parallel (halo exchange instead of the all-gather
    pjit would otherwise insert). Returns None outside such a context."""
    rules = current_rules()
    if not rules:
        return None
    e = rules.get("seq")
    axes = e if isinstance(e, tuple) else ((e,) if e else ())
    if len(axes) != 1:
        return None
    mesh = _ambient_mesh()
    if mesh is None or mesh.empty:
        return None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if sizes.get(axes[0], 1) <= 1:
        return None
    return mesh, axes[0]


# ------------------------ parameter shardings --------------------------- #
# Path regexes over '/'-joined param tree keys -> logical axes per dim.
# First match wins; unmatched leaves replicate (always correct) unless the
# fsdp fallback applies.
PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    (r"(^|/)(embed|lm_head)/w$", ("vocab", "embed")),
    (r"(^|/)wq$", ("embed", "heads")),
    (r"(^|/)w[kv]$", ("embed", "kv_heads")),
    (r"(^|/)wo$", ("heads", "embed")),
    (r"(^|/)(w_in|w_gate|w_gate_branch)$", ("embed", "ffn")),
    (r"(^|/)w_out$", ("ffn", "embed")),
    (r"(^|/)router$", ("embed", "experts")),
    (r"(^|/)(scale|bias)$", (None,)),
)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def logical_axes_for(path: str, ndim: int) -> Tuple[Optional[str], ...]:
    for pat, axes in PARAM_RULES:
        if re.search(pat, path):
            # Leading (stacked-layer / expert) dims stay unsharded unless the
            # leaf really is the expert-stationary 3-D tensor.
            if ndim == len(axes) + 1:
                lead = ("experts",) if "w_" in path.rsplit("/", 1)[-1] \
                    and ndim == 3 else (None,)
                return lead + axes
            if ndim >= len(axes):
                return (None,) * (ndim - len(axes)) + axes
            return axes[:ndim]
    return (None,) * ndim


def param_shardings(tree, mesh: Mesh, rules: Dict[str, Any]):
    """NamedSharding tree for a parameter tree by path-regex rules."""

    def one(path, leaf):
        logical = logical_axes_for(_path_str(path), len(leaf.shape))
        with axis_rules(rules):
            spec = resolve(*logical)
        spec = _mesh_clean(mesh, spec, leaf.shape)
        # FSDP fallback: shard the largest dim of otherwise-replicated
        # >=2-D params over the fsdp axis when it divides.
        fsdp = rules.get("fsdp")
        if fsdp and len(leaf.shape) >= 2 and all(e is None for e in spec):
            dim = max(range(len(leaf.shape)), key=lambda i: leaf.shape[i])
            cand = P(*[fsdp if i == dim else None
                       for i in range(len(leaf.shape))])
            spec = _mesh_clean(mesh, cand, leaf.shape)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, tree)
