"""Fixed-point quantization simulation (paper §6.4).

SALO quantizes Q, K, V to **int8 with 4 fractional bits** (scale 2^-4, range
[-8, 7.9375]) and produces 16-bit outputs; the paper shows accuracy within
noise of fp32 after quantization-aware finetuning (Table 3).

We simulate the exact fixed-point grid (not per-tensor dynamic scaling — the
ASIC's format is static) plus an optional dynamic per-tensor variant that a
TPU int8 path would use. ``quantized_attention`` runs any attention engine
on the quantized grid to measure the end-to-end output error (Table 3 analog
in ``benchmarks/paper_claims.py::table3_quantization``).

STE (straight-through estimator) gradients make the simulation usable inside
quantization-aware finetuning, mirroring the paper's QAT setup.

The serving stack stores the paged KV slab in this int8 format with
*per-page* dynamic scales (:func:`group_q8` / :func:`group_dequant`, used by
``repro.serve.paged_cache``) — the deployment-side counterpart of the
paper's Table-3 numerics: one f32 scale per (layer, page) rides next to the
page table, and decode dequantizes page tiles on the fly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

FRAC_BITS = 4
SCALE = 2.0 ** FRAC_BITS  # paper: 4-bit fraction
QMIN, QMAX = -128, 127


@jax.custom_vjp
def fixed_point_q8(x: jax.Array) -> jax.Array:
    """Round to the int8(4-frac) fixed-point grid. Shape-preserving."""
    q = jnp.clip(jnp.round(x * SCALE), QMIN, QMAX)
    return (q / SCALE).astype(x.dtype)


def _fp_fwd(x):
    return fixed_point_q8(x), ()


def _fp_bwd(_, g):
    return (g,)  # STE


fixed_point_q8.defvjp(_fp_fwd, _fp_bwd)


def dynamic_q8(x: jax.Array, axis=None):
    """Per-tensor (or grouped) dynamic int8: returns ``(int8, scale)``.

    ``axis`` semantics: ``None`` (default) computes ONE scale for the whole
    tensor (scalar scale, per-tensor quantization). An int or tuple of ints
    names the axes *reduced away* when computing the scale — every other
    axis indexes an independent quantization group, and ``scale`` comes
    back with the reduced axes kept as size-1 (``keepdims``) so it
    broadcasts directly against ``q`` in :func:`dequant`. E.g. for a slab
    ``(n_pages, page, Hkv, hd)``, ``axis=(1, 2, 3)`` is per-page
    quantization with ``scale: (n_pages, 1, 1, 1)``.

    The ``1e-8`` floor on the group amax keeps all-zero (and denormal-ish)
    groups from producing a zero or subnormal divisor — such groups
    quantize to all-zero ints and dequantize to exact zeros.
    """
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale), QMIN, QMAX).astype(jnp.int8)
    return q, scale


def dequant(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return q.astype(dtype) * scale


def group_q8(x: jax.Array, n_group_axes: int):
    """Leading-axis-grouped int8: one scale per leading-axes group.

    ``x``'s first ``n_group_axes`` axes index quantization groups; the
    trailing axes are reduced into each group's scale. Returns
    ``(q int8 like x, scale f32 of shape x.shape[:n_group_axes])`` — the
    per-(layer, page) layout the quantized KV slab stores: for a slab
    ``(L, n_pages, page, Hkv, hd)``, ``n_group_axes=2`` yields one scale
    per (layer, page)."""
    assert 0 < n_group_axes < x.ndim, (n_group_axes, x.shape)
    axes = tuple(range(n_group_axes, x.ndim))
    q, scale = dynamic_q8(x.astype(jnp.float32), axis=axes)
    return q, scale.reshape(x.shape[:n_group_axes])


def group_dequant(q: jax.Array, scale: jax.Array,
                  dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`group_q8`: ``scale`` broadcasts over the trailing
    (non-group) axes of ``q``."""
    expand = scale.reshape(scale.shape + (1,) * (q.ndim - scale.ndim))
    return (q.astype(jnp.float32) * expand).astype(dtype)


def quantized_attention(q, k, v, pattern, *, impl: str = "blockwise",
                        mode: str = "fixed", **kw):
    """Attention on the quantized grid (paper's deployment numerics).

    mode='fixed'   int8 with 4-bit fraction (the ASIC's format)
    mode='dynamic' per-tensor dynamic int8 (TPU-style)
    """
    from repro.core.attention import hybrid_attention

    if mode == "fixed":
        qq, kq, vq = fixed_point_q8(q), fixed_point_q8(k), fixed_point_q8(v)
    elif mode == "dynamic":
        qq = dequant(*dynamic_q8(q), dtype=q.dtype)
        kq = dequant(*dynamic_q8(k), dtype=k.dtype)
        vq = dequant(*dynamic_q8(v), dtype=v.dtype)
    else:
        raise ValueError(mode)
    return hybrid_attention(qq, kq, vq, pattern, impl=impl, **kw)
