"""Fixed-point quantization simulation (paper §6.4).

SALO quantizes Q, K, V to **int8 with 4 fractional bits** (scale 2^-4, range
[-8, 7.9375]) and produces 16-bit outputs; the paper shows accuracy within
noise of fp32 after quantization-aware finetuning (Table 3).

We simulate the exact fixed-point grid (not per-tensor dynamic scaling — the
ASIC's format is static) plus an optional dynamic per-tensor variant that a
TPU int8 path would use. ``quantized_attention`` runs any attention engine on
the quantized grid to measure the end-to-end output error (Table 3 analog in
benchmarks/quantization.py).

STE (straight-through estimator) gradients make the simulation usable inside
quantization-aware finetuning, mirroring the paper's QAT setup.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

FRAC_BITS = 4
SCALE = 2.0 ** FRAC_BITS  # paper: 4-bit fraction
QMIN, QMAX = -128, 127


@jax.custom_vjp
def fixed_point_q8(x: jax.Array) -> jax.Array:
    """Round to the int8(4-frac) fixed-point grid. Shape-preserving."""
    q = jnp.clip(jnp.round(x * SCALE), QMIN, QMAX)
    return (q / SCALE).astype(x.dtype)


def _fp_fwd(x):
    return fixed_point_q8(x), ()


def _fp_bwd(_, g):
    return (g,)  # STE


fixed_point_q8.defvjp(_fp_fwd, _fp_bwd)


def dynamic_q8(x: jax.Array, axis=None):
    """Per-tensor (or per-``axis``) dynamic int8: returns (int8, scale)."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale), QMIN, QMAX).astype(jnp.int8)
    return q, scale


def dequant(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return q.astype(dtype) * scale


def quantized_attention(q, k, v, pattern, *, impl: str = "blockwise",
                        mode: str = "fixed", **kw):
    """Attention on the quantized grid (paper's deployment numerics).

    mode='fixed'   int8 with 4-bit fraction (the ASIC's format)
    mode='dynamic' per-tensor dynamic int8 (TPU-style)
    """
    from repro.core.attention import hybrid_attention

    if mode == "fixed":
        qq, kq, vq = fixed_point_q8(q), fixed_point_q8(k), fixed_point_q8(v)
    elif mode == "dynamic":
        qq = dequant(*dynamic_q8(q), dtype=q.dtype)
        kq = dequant(*dynamic_q8(k), dtype=k.dtype)
        vq = dequant(*dynamic_q8(v), dtype=v.dtype)
    else:
        raise ValueError(mode)
    return hybrid_attention(qq, kq, vq, pattern, impl=impl, **kw)
