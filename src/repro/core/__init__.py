# The paper's primary contribution: hybrid sparse attention (sliding window
# + dilated window + global tokens) with SALO's data scheduler (splitting,
# reordering) and renormalized merge, as composable JAX modules.
from repro.core.patterns import (HybridSparsePattern, longformer,
                                 causal_sliding_window, dilated_window, vil,
                                 full)
from repro.core.scheduler import (BandSchedule, Band, ExecutionPlan,
                                  PAD_SENTINEL, TransposedPlan, build_plan,
                                  build_transposed, schedule)
from repro.core.attention import hybrid_attention, hybrid_decode_attention
from repro.core.blockwise import blockwise_attention, decode_attention
from repro.core import renorm, quant

__all__ = [
    "HybridSparsePattern", "longformer", "causal_sliding_window",
    "dilated_window", "vil", "full", "BandSchedule", "Band", "ExecutionPlan",
    "PAD_SENTINEL", "TransposedPlan", "build_plan", "build_transposed",
    "schedule",
    "hybrid_attention", "hybrid_decode_attention", "blockwise_attention",
    "decode_attention", "renorm", "quant",
]
