"""Renormalized merge of partial attention outputs (paper Eq. 2 / App. A).

SALO's window splitting computes, for each query row i, partial results over
disjoint key sets T_k with per-part weight W_k = sum_{j in T_k} exp(S_ij), and
recovers the exact output as   out_i = sum_k (W_k / sum W) * out_i^k.

On hardware this is the "weighted sum module". In float we carry a running
max `m` for stability (the fixed-point ASIC skips it; see DESIGN.md §2), so a
partial is the classic online-softmax triple:

    state = (acc, m, l)     acc = sum_j exp(S_ij - m) * v_j     (unnormalized)
                            m   = max_j S_ij
                            l   = sum_j exp(S_ij - m)

``merge`` is associative and commutative (property-tested), which is what
legalizes every level of splitting: KV tiles inside a kernel, multi-band
passes, and cross-device sequence parallelism.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # large-negative instead of -inf: keeps 0*inf NaNs away


class PartialState(NamedTuple):
    """Partial attention for a block of queries. Shapes:
    acc: (..., q, d) f32, m: (..., q) f32, l: (..., q) f32.

    **Empty-row contract.** A row that attended nothing carries exactly
    ``(acc=0, m=NEG_INF, l=0)`` — the identity element of :func:`merge` —
    and finalizes to a zero output row. Every producer keeps this
    normalized form (``empty_state``, ``update``'s guarded shift, the
    Pallas kernel's ``_fin``), and every consumer must branch on
    ``l == 0`` / ``m <= NEG_INF/2`` rather than divide or exponentiate
    blindly: :func:`finalize` and :func:`weights` here, and the fused
    backward's ``p = exp(s - m)/l`` recompute + ``delta`` term
    (``core.blockwise.p_from_stats``, kernels/salo_backward.py), which all
    yield exactly zero for such rows.
    """
    acc: jax.Array
    m: jax.Array
    l: jax.Array


def empty_state(q_shape, d: int, dtype=jnp.float32) -> PartialState:
    """Identity element of ``merge`` (zero weight, -inf max)."""
    return PartialState(
        acc=jnp.zeros((*q_shape, d), dtype),
        m=jnp.full(q_shape, NEG_INF, dtype),
        l=jnp.zeros(q_shape, dtype),
    )


def merge(a: PartialState, b: PartialState) -> PartialState:
    """Exact merge of two disjoint-key partials (paper Eq. 2, stabilized)."""
    m = jnp.maximum(a.m, b.m)
    ca = jnp.exp(a.m - m)
    cb = jnp.exp(b.m - m)
    return PartialState(
        acc=a.acc * ca[..., None] + b.acc * cb[..., None],
        m=m,
        l=a.l * ca + b.l * cb,
    )


def update(state: PartialState, scores: jax.Array, v: jax.Array,
           mask: jax.Array | None = None) -> PartialState:
    """Fold one KV tile into the running state (the in-kernel step).

    scores: (..., q, k) f32 logits; v: (..., k, d); mask True = attend.
    """
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    m_tile = jnp.max(scores, axis=-1)
    m_new = jnp.maximum(state.m, m_tile)
    # Guard: if a row has no valid key anywhere yet, m_new stays NEG_INF and
    # exp(scores - m_new) could overflow; clamp the shift.
    shift = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.exp(scores - shift[..., None])
    if mask is not None:
        p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(jnp.where(state.m <= NEG_INF / 2, NEG_INF, state.m) - shift)
    corr = jnp.where(state.m <= NEG_INF / 2, 0.0, corr)
    # PV contraction in V's dtype (bf16 on TPU -> MXU-native, half the
    # operand bytes), f32 accumulation — standard flash-attention numerics.
    pv = jnp.einsum("...qk,...kd->...qd", p.astype(v.dtype), v,
                    preferred_element_type=jnp.float32)
    return PartialState(
        acc=state.acc * corr[..., None] + pv,
        m=m_new,
        l=state.l * corr + jnp.sum(p, axis=-1),
    )


def finalize(state: PartialState, dtype=None) -> jax.Array:
    """Normalize: out = acc / l. Rows that attended nothing produce zeros."""
    l = jnp.where(state.l == 0.0, 1.0, state.l)
    out = state.acc / l[..., None]
    return out.astype(dtype) if dtype is not None else out


def weights(state: PartialState) -> jax.Array:
    """The paper's W (softmax denominator) in log space: logsumexp row weight."""
    safe_l = jnp.maximum(state.l, 1e-30)
    return state.m + jnp.log(safe_l)
