"""Data scheduler (paper §4): pattern -> executable band schedule.

Transforms a :class:`HybridSparsePattern` into the form the compute engines
(blockwise JAX / Pallas kernel) execute directly:

* **data reordering** (paper §4.2): dilation-``d`` patterns are turned into
  plain sliding windows by the stride-``d`` permutation that groups
  ``q_i, q_{i+d}, q_{i+2d}, ...``. Masks downstream are always evaluated on
  *original* positions carried through the permutation, so reordering only
  changes locality, never semantics.
* **band lowering**: 2-D (ViL) windows become a union of 1-D bands, one per
  row offset ``dy``: ``[dy*W - ww//2, dy*W + ww//2]``.
* **data splitting** (paper §4.2): sequence splitting = query blocks of
  ``block_q``; window splitting = KV tiles of ``block_k`` merged with the
  renormalization of :mod:`repro.core.renorm`.

The schedule is pure static metadata (numpy only) — safe to build at trace
time and cache.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Tuple

import numpy as np

from repro.core.patterns import HybridSparsePattern

# Sentinel original-position for padding slots. Must fit int32 (JAX default
# integer width) *and* keep pos_j - pos_i inside int32 — any mask comparison
# against it must fail via the `pos < n` in-range guard.
BIG = 2 ** 31 - 2 ** 20


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class Band:
    """One working-space band: queries attend keys with lo <= j - i <= hi."""
    lo: int
    hi: int

    def kv_steps(self, block_q: int, block_k: int) -> int:
        """KV tiles a query block touches for this band (window splitting)."""
        span = (block_q - 1) + (self.hi - self.lo)
        return span // block_k + 2  # +2: start misalignment + inclusive end

    def kv_start_block(self, q_block: int, block_q: int, block_k: int) -> int:
        """First (possibly negative, unclamped) KV tile for query block."""
        return math.floor((q_block * block_q + self.lo) / block_k)


@dataclasses.dataclass(frozen=True, eq=False)
class BandSchedule:
    n: int                      # original sequence length
    n_work: int                 # length after dilation padding (= len(perm))
    bands: Tuple[Band, ...]     # working-space bands (dilation removed)
    perm: Optional[np.ndarray]  # working slot -> original position, or None
    n_global: int
    global_rows: bool
    causal: bool
    pattern: HybridSparsePattern

    # A schedule is a pure function of (pattern, n): hash/eq on those so the
    # numpy perm array doesn't break jit static-arg hashing.
    def __hash__(self):
        return hash((self.n, self.pattern))

    def __eq__(self, other):
        return (isinstance(other, BandSchedule)
                and self.n == other.n and self.pattern == other.pattern)

    # ------------------------------------------------------------------ #
    @property
    def reordered(self) -> bool:
        return self.perm is not None

    def positions(self) -> np.ndarray:
        """Original position of each working slot (BIG for padding)."""
        if self.perm is None:
            pos = np.arange(self.n_work, dtype=np.int32)
            pos[self.n :] = BIG
            return pos
        pos = self.perm.astype(np.int32).copy()
        pos[pos >= self.n] = BIG
        return pos

    def inverse_perm(self) -> Optional[np.ndarray]:
        """original position -> working slot (length n)."""
        if self.perm is None:
            return None
        inv = np.full(self.n, -1, dtype=np.int32)
        valid = self.perm < self.n
        inv[self.perm[valid]] = np.nonzero(valid)[0]
        assert (inv >= 0).all()
        return inv

    # ------------------------------------------------------------------ #
    def window_mask(self, pos_i, pos_j):
        """Window-only validity from ORIGINAL positions (jnp-compatible).

        Covers the windowed/dilated/2-D part of the pattern plus causality —
        NOT the global row/column (handled by separate partials). Padding
        (pos == BIG) fails automatically because BIG is out of every window.
        """
        import jax.numpy as jnp

        p = self.pattern
        pos_i = jnp.asarray(pos_i)
        pos_j = jnp.asarray(pos_j)
        in_range = (pos_i < self.n) & (pos_j < self.n)
        if p.is_2d:
            g = p.n_global
            h, w = p.grid2d
            wh, ww = p.window2d
            yi, xi = (pos_i - g) // w, (pos_i - g) % w
            yj, xj = (pos_j - g) // w, (pos_j - g) % w
            m = (jnp.abs(yj - yi) <= wh // 2) & (jnp.abs(xj - xi) <= ww // 2)
            m = m & (pos_i >= g) & (pos_j >= g)
        else:
            a, b = p.window
            rel = pos_j - pos_i
            m = (rel >= a) & (rel <= b)
            if p.dilation > 1:
                m = m & (rel % p.dilation == 0)
        if self.causal:
            m = m & (pos_j <= pos_i)
        return m & in_range

    def global_col_mask(self, pos_i, pos_j):
        """Validity of the global-column partial: key is global, and the pair
        is NOT already covered by the window (no double counting)."""
        import jax.numpy as jnp

        g = self.n_global
        pos_i = jnp.asarray(pos_i)
        pos_j = jnp.asarray(pos_j)
        m = (pos_j < g) & (pos_i < self.n)
        if self.causal:
            m = m & (pos_j <= pos_i)
        return m & ~self.window_mask(pos_i, pos_j)

    # ------------------------------------------------------------------ #
    def work_estimate(self, block_q: int, block_k: int) -> dict:
        """Tile-level work accounting (drives the utilization benchmark)."""
        n_pad = _round_up(self.n_work, max(block_q, block_k))
        nq = n_pad // block_q
        steps = sum(b.kv_steps(block_q, block_k) for b in self.bands)
        tile_flops = 4 * block_q * block_k  # qk + pv MACs per (i,j) pair *2
        useful = int(self.pattern.mask(self.n).sum())
        executed = nq * steps * block_q * block_k
        return dict(
            q_blocks=nq, kv_steps_per_q_block=steps,
            executed_pairs=executed, useful_pairs=useful,
            utilization=useful / max(executed, 1), tile_flops=tile_flops,
        )


# ---------------------------------------------------------------------- #
@functools.lru_cache(maxsize=256)
def schedule(pattern: HybridSparsePattern, n: int) -> BandSchedule:
    """Lower a pattern at sequence length ``n`` into a band schedule."""
    if pattern.is_2d:
        exp = pattern.seq_len()
        if n != exp:
            raise ValueError(f"2-D pattern implies n={exp}, got {n}")
        _, w = pattern.grid2d
        wh, ww = pattern.window2d
        bands = tuple(
            Band(dy * w - ww // 2, dy * w + ww // 2)
            for dy in range(-(wh // 2), wh // 2 + 1)
        )
        return BandSchedule(n=n, n_work=n, bands=bands, perm=None,
                            n_global=pattern.n_global,
                            global_rows=pattern.global_rows,
                            causal=pattern.causal, pattern=pattern)

    a, b = pattern.window
    d = pattern.dilation
    if d == 1:
        lo = max(a, -(n - 1))
        hi = min(b, n - 1)
        if pattern.causal:
            hi = min(hi, 0)
        return BandSchedule(n=n, n_work=n, bands=(Band(lo, hi),), perm=None,
                            n_global=pattern.n_global,
                            global_rows=pattern.global_rows,
                            causal=pattern.causal, pattern=pattern)

    # --- data reordering (paper §4.2): stride-d permutation ------------- #
    if a % d or b % d:
        raise ValueError(f"dilated window offsets ({a},{b}) must be multiples"
                         f" of dilation {d}")
    n_work = _round_up(n, d)
    perm = np.concatenate([np.arange(r, n_work, d) for r in range(d)])
    lo = max(a // d, -(n_work // d - 1))
    hi = min(b // d, n_work // d - 1)
    if pattern.causal:
        hi = min(hi, 0)
    return BandSchedule(n=n, n_work=n_work, bands=(Band(lo, hi),), perm=perm,
                        n_global=pattern.n_global,
                        global_rows=pattern.global_rows,
                        causal=pattern.causal, pattern=pattern)
