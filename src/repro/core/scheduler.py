"""Data scheduler (paper §4): pattern -> band schedule -> ExecutionPlan.

The lowering pipeline every engine shares:

    HybridSparsePattern --schedule()--> BandSchedule --plan()--> ExecutionPlan

**BandSchedule** (this paper's data scheduler, §4.2):

* **data reordering**: dilation-``d`` patterns are turned into plain sliding
  windows by the stride-``d`` permutation that groups
  ``q_i, q_{i+d}, q_{i+2d}, ...``. Masks downstream are always evaluated on
  *original* positions carried through the permutation, so reordering only
  changes locality, never semantics.
* **band lowering**: 2-D (ViL) windows become a union of 1-D bands, one per
  row offset ``dy``: ``[dy*W - ww//2, dy*W + ww//2]``.
* **data splitting**: sequence splitting = query blocks of ``block_q``;
  window splitting = KV tiles of ``block_k`` merged with the
  renormalization of :mod:`repro.core.renorm`.

**ExecutionPlan** (the IR the engines execute): flat, static, per-query-block
step tables. For each query block, the KV tiles it must visit — the union of
every band's tile walk *plus* the tiles holding global keys — deduplicated to
one visit per tile, each visit tagged with the set of bands covering it and
whether it carries global-column work. One (q_block, kv_tile) pair is visited
at most once, so masks are evaluated exactly once per attended pair: the
multi-band + global hybrid becomes a single table-driven pass (one Pallas
launch / one scan) instead of one launch per band plus global special cases.
This mirrors SALO's scheduler packing band segments so global PEs compute
"simultaneously with the same input vectors" as the window PEs.

ARCHITECTURE: every table this module emits — forward plans, transposed /
packed-transposed adjoint walks, sharded per-device slices, chunk prefill
slices — is statically *provable*, and :mod:`repro.analysis.plan_verify`
(run by ``python -m repro.analysis.lint``, the CI soundness gate) proves
exact mask coverage, adjoint permutation equality, shard-exchange
reconstruction and the dynamic never-drop invariant for every registered
pattern, reporting (q_block, kv_block) counterexamples on violation.

**TransposedPlan** (the backward IR): the same deduplicated visits regrouped
into per-KV-block step tables (``plan.transposed()``), walked by the dK/dV
backward kernel; the dQ backward kernel replays the forward tables. Gradients
ride the paper's data-scheduler schedule symmetrically — no extra tiles.

**PackedTransposedPlan** (``plan.transposed_packed()``): the transposed
tables re-laid-out for execution. The raw transposed tables are ragged —
a global-column KV tile's row spans *every* query block, so rectangular
padding to ``max_steps`` inflates the dK/dV walk for all other tiles. The
packed layout splits overlong rows into several fixed-width rows that share
one owner tile (``row_tile``) and drops never-visited tiles entirely;
per-row partials are scatter-added back per owner tile. Same visits, same
flags — only the grid shape changes.

**ChunkPlan** (the serving prefill IR): a causal chunk-slice of the plan —
queries ``[c0, c1)`` of a prompt against the request's paged ring-cache view
plus the chunk itself (``build_chunk_plan``), so prefill is
``ceil(P / chunk)`` fused table-driven passes instead of ``P`` sequential
decode steps. :func:`causal_step_mask` is the shared serving mask (decode
twin, decode kernels, chunked prefill).

All levels are pure static metadata (numpy only) — safe to build at trace
time and cache.

ARCHITECTURE: the step-table *contract* itself — column layout, flag bits,
``PAD_SENTINEL`` padding, the fixed ``steps`` width, the padding-iff-flags-0
and one-visit-per-tile invariants — lives in
:mod:`repro.core.plan_contract`, NOT here. This module is merely one
producer (the static, pattern-driven builder); :mod:`repro.core.dynamic`
produces contract-identical tables at runtime from content, and
:mod:`repro.dist.sharded_plan` / :class:`ChunkPlan` re-slice them per
shard / per chunk. Every producer funnels through
:func:`repro.core.plan_contract.validate_tables`, so the kernels and scan
engines can consume any of them interchangeably. The constants ``BIG`` /
``PAD_SENTINEL`` / ``STEP_WINDOW`` / ``STEP_GLOBAL`` are re-exported here
for compatibility; ``plan_contract`` is their home.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Tuple

import numpy as np

from repro.core.patterns import HybridSparsePattern
# Contract constants re-exported from their home (see module docstring).
from repro.core.plan_contract import (BIG, STEP_GLOBAL, STEP_WINDOW,
                                      validate_tables)
from repro.core.plan_contract import PAD_SENTINEL as PAD_SENTINEL


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class Band:
    """One working-space band: queries attend keys with lo <= j - i <= hi."""
    lo: int
    hi: int

    def kv_steps(self, block_q: int, block_k: int) -> int:
        """KV tiles a query block touches for this band (window splitting)."""
        span = (block_q - 1) + (self.hi - self.lo)
        return span // block_k + 2  # +2: start misalignment + inclusive end

    def kv_start_block(self, q_block: int, block_q: int, block_k: int) -> int:
        """First (possibly negative, unclamped) KV tile for query block."""
        return math.floor((q_block * block_q + self.lo) / block_k)


@dataclasses.dataclass(frozen=True, eq=False)
class BandSchedule:
    n: int                      # original sequence length
    n_work: int                 # length after dilation padding (= len(perm))
    bands: Tuple[Band, ...]     # working-space bands (dilation removed)
    perm: Optional[np.ndarray]  # working slot -> original position, or None
    n_global: int
    global_rows: bool
    causal: bool
    pattern: HybridSparsePattern

    # hash/eq over every field except the numpy perm array (unhashable, and
    # derived from (pattern, n) anyway) so jit static-arg hashing works AND
    # dataclasses.replace'd variants — band subsets / global-stripped
    # schedules, the per-band-launch benchmark baseline — never alias the
    # original in the schedule/plan lru caches.
    def _key(self):
        return (self.n, self.n_work, self.pattern, self.bands,
                self.n_global, self.global_rows, self.causal)

    def __hash__(self):
        return hash(self._key())

    def __eq__(self, other):
        return (isinstance(other, BandSchedule)
                and self._key() == other._key())

    # ------------------------------------------------------------------ #
    @property
    def reordered(self) -> bool:
        return self.perm is not None

    def positions(self) -> np.ndarray:
        """Original position of each working slot (BIG for padding)."""
        if self.perm is None:
            pos = np.arange(self.n_work, dtype=np.int32)
            pos[self.n :] = BIG
            return pos
        pos = self.perm.astype(np.int32).copy()
        pos[pos >= self.n] = BIG
        return pos

    def inverse_perm(self) -> Optional[np.ndarray]:
        """original position -> working slot (length n)."""
        if self.perm is None:
            return None
        inv = np.full(self.n, -1, dtype=np.int32)
        valid = self.perm < self.n
        inv[self.perm[valid]] = np.nonzero(valid)[0]
        assert (inv >= 0).all()
        return inv

    # ------------------------------------------------------------------ #
    def window_mask(self, pos_i, pos_j):
        """Window-only validity from ORIGINAL positions (jnp-compatible).

        Covers the windowed/dilated/2-D part of the pattern plus causality —
        NOT the global row/column (handled by separate partials). Padding
        (pos == BIG) fails automatically because BIG is out of every window.
        """
        import jax.numpy as jnp

        p = self.pattern
        pos_i = jnp.asarray(pos_i)
        pos_j = jnp.asarray(pos_j)
        in_range = (pos_i < self.n) & (pos_j < self.n)
        if p.is_2d:
            g = p.n_global
            h, w = p.grid2d
            wh, ww = p.window2d
            yi, xi = (pos_i - g) // w, (pos_i - g) % w
            yj, xj = (pos_j - g) // w, (pos_j - g) % w
            m = (jnp.abs(yj - yi) <= wh // 2) & (jnp.abs(xj - xi) <= ww // 2)
            m = m & (pos_i >= g) & (pos_j >= g)
        else:
            a, b = p.window
            rel = pos_j - pos_i
            m = (rel >= a) & (rel <= b)
            if p.dilation > 1:
                m = m & (rel % p.dilation == 0)
        if self.causal:
            m = m & (pos_j <= pos_i)
        return m & in_range

    def global_col_mask(self, pos_i, pos_j):
        """Validity of the global-column partial: key is global, and the pair
        is NOT already covered by the window (no double counting)."""
        import jax.numpy as jnp

        g = self.n_global
        pos_i = jnp.asarray(pos_i)
        pos_j = jnp.asarray(pos_j)
        m = (pos_j < g) & (pos_i < self.n)
        if self.causal:
            m = m & (pos_j <= pos_i)
        return m & ~self.window_mask(pos_i, pos_j)

    def step_mask(self, pos_i, pos_j, flags):
        """The ExecutionPlan's per-step mask — THE mask both engines apply.

        ``flags`` (int, broadcastable against the (q, k) tile) selects which
        components this step evaluates: STEP_WINDOW gates the banded window
        term, STEP_GLOBAL the global-column term (disjoint from the window
        by construction — the window evaluation is shared between the two
        terms rather than recomputed via global_col_mask). ``flags == 0``
        steps are padding no-ops.
        """
        import jax.numpy as jnp

        flags = jnp.asarray(flags)
        w = self.window_mask(pos_i, pos_j)
        m = w & ((flags & STEP_WINDOW) != 0)
        if self.n_global > 0:
            gcol = (pos_j < self.n_global) & (pos_i < self.n) & ~w
            if self.causal:
                gcol = gcol & (pos_j <= pos_i)
            m = m | (gcol & ((flags & STEP_GLOBAL) != 0))
        return m

    # ------------------------------------------------------------------ #
    def plan(self, block_q: int, block_k: int,
             pad_multiple: int = 1) -> "ExecutionPlan":
        """Lower this schedule into the deduplicated step-table IR.

        ``pad_multiple`` additionally aligns ``n_pad`` (sequence parallelism
        pads to ``n_shards * lcm(block_q, block_k)`` so every shard owns the
        same number of whole query blocks AND KV tiles)."""
        return build_plan(self, block_q, block_k, pad_multiple)

    def work_estimate(self, block_q: int, block_k: int) -> dict:
        """Tile-level work accounting (drives the utilization benchmark).

        Counts what the fused plan actually executes — overlapping bands'
        shared KV tiles are visited once, not once per band (the old
        per-band accounting over-counted exactly those)."""
        p = self.plan(block_q, block_k)
        return p.stats()


# ---------------------------------------------------------------------- #
@functools.lru_cache(maxsize=256)
def schedule(pattern: HybridSparsePattern, n: int) -> BandSchedule:
    """Lower a pattern at sequence length ``n`` into a band schedule."""
    if pattern.is_2d:
        exp = pattern.seq_len()
        if n != exp:
            raise ValueError(f"2-D pattern implies n={exp}, got {n}")
        _, w = pattern.grid2d
        wh, ww = pattern.window2d
        bands = tuple(
            Band(dy * w - ww // 2, dy * w + ww // 2)
            for dy in range(-(wh // 2), wh // 2 + 1)
        )
        return BandSchedule(n=n, n_work=n, bands=bands, perm=None,
                            n_global=pattern.n_global,
                            global_rows=pattern.global_rows,
                            causal=pattern.causal, pattern=pattern)

    a, b = pattern.window
    d = pattern.dilation
    if d == 1:
        lo = max(a, -(n - 1))
        hi = min(b, n - 1)
        if pattern.causal:
            hi = min(hi, 0)
        return BandSchedule(n=n, n_work=n, bands=(Band(lo, hi),), perm=None,
                            n_global=pattern.n_global,
                            global_rows=pattern.global_rows,
                            causal=pattern.causal, pattern=pattern)

    # --- data reordering (paper §4.2): stride-d permutation ------------- #
    if a % d or b % d:
        raise ValueError(f"dilated window offsets ({a},{b}) must be multiples"
                         f" of dilation {d}")
    n_work = _round_up(n, d)
    perm = np.concatenate([np.arange(r, n_work, d) for r in range(d)])
    lo = max(a // d, -(n_work // d - 1))
    hi = min(b // d, n_work // d - 1)
    if pattern.causal:
        hi = min(hi, 0)
    return BandSchedule(n=n, n_work=n_work, bands=(Band(lo, hi),), perm=perm,
                        n_global=pattern.n_global,
                        global_rows=pattern.global_rows,
                        causal=pattern.causal, pattern=pattern)


# ---------------------------------------------------------------------- #
# ExecutionPlan IR
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True, eq=False)
class ExecutionPlan:
    """Flat per-query-block step tables: what one fused pass executes.

    Row ``i`` of the tables lists the KV tiles query block ``i`` visits, in
    ascending tile order, each tile exactly once:

    * ``kv_blocks[i, s]`` — KV tile index of step ``s`` (0 for padding steps);
    * ``flags[i, s]``     — STEP_WINDOW / STEP_GLOBAL bitmask (0 = padding
      no-op: every mask term evaluates False);
    * ``band_set_ids[i, s]`` — index into ``band_sets``, the distinct subsets
      of schedule bands covering a visit (-1 for padding). Purely
      introspective: since a (q_block, kv_tile) pair is visited once, the
      window mask needs no band restriction — the union mask is exact.

    Rows are right-padded to ``max_steps`` so the table is rectangular (the
    kernel grid's sequential dimension). All arrays are static numpy; the
    plan hashes on (schedule, block_q, block_k) for jit static-arg use.
    """
    sched: BandSchedule
    block_q: int
    block_k: int
    n_pad: int                # padded working length (tile-grid aligned)
    nq: int                   # query blocks
    nkb: int                  # KV tiles
    max_steps: int            # table width = kernel grid steps
    kv_blocks: np.ndarray     # (nq, max_steps) int32
    flags: np.ndarray         # (nq, max_steps) int32
    band_set_ids: np.ndarray  # (nq, max_steps) int32
    band_sets: Tuple[Tuple[int, ...], ...]
    num_steps: np.ndarray     # (nq,) int32 — real (non-padding) steps

    def __hash__(self):
        # n_pad participates: the same (schedule, blocks) at a different
        # pad_multiple is a DIFFERENT plan (more padded rows/tiles) and must
        # not alias it in jit static-arg or transposed-plan caches.
        return hash((self.sched, self.block_q, self.block_k, self.n_pad))

    def __eq__(self, other):
        return (isinstance(other, ExecutionPlan)
                and self.sched == other.sched
                and self.block_q == other.block_q
                and self.block_k == other.block_k
                and self.n_pad == other.n_pad)

    # ------------------------------------------------------------------ #
    def positions_padded(self) -> np.ndarray:
        """Original position per padded working slot (PAD_SENTINEL beyond)."""
        pos = np.full(self.n_pad, BIG, dtype=np.int32)
        pos[: self.sched.n_work] = self.sched.positions()
        return pos

    def step_mask(self, pos_i, pos_j, flags):
        return self.sched.step_mask(pos_i, pos_j, flags)

    def transposed(self) -> "TransposedPlan":
        """The adjoint walk: per-KV-block step tables (cached, see
        :func:`build_transposed`). The dK/dV backward kernel's schedule."""
        return build_transposed(self)

    def transposed_packed(self) -> "PackedTransposedPlan":
        """The transposed walk re-packed to a fixed row width (cached, see
        :func:`build_packed_transposed`) — what the dK/dV engines execute."""
        return build_packed_transposed(self)

    # ------------------------------------------------------------------ #
    def stats(self) -> dict:
        """Plan-level work accounting, fused vs the per-band-launch walk."""
        executed_tiles = int(self.num_steps.sum())
        executed_pairs = executed_tiles * self.block_q * self.block_k
        useful = int(self.sched.pattern.mask(self.sched.n).sum())
        g = self.sched.n_global
        # What the retired one-launch-per-band path executed: every band
        # walks its full (unclipped) tile span per query block, plus the
        # global-column pass — shared tiles re-fetched once per band.
        per_band_steps = sum(b.kv_steps(self.block_q, self.block_k)
                             for b in self.sched.bands)
        if g > 0:
            per_band_steps += -(-g // self.block_k)
        per_band_tiles = self.nq * per_band_steps
        per_band_launches = len(self.sched.bands)
        tp = self.transposed()
        pk = self.transposed_packed()
        return dict(
            q_blocks=self.nq,
            kv_steps_per_q_block=self.max_steps,
            executed_pairs=executed_pairs,
            useful_pairs=useful,
            utilization=useful / max(executed_pairs, 1),
            tile_flops=4 * self.block_q * self.block_k,
            executed_tiles=executed_tiles,
            per_band_tiles=per_band_tiles,
            per_band_launches=per_band_launches,
            launches=1,
            band_sets=len(self.band_sets),
            # Backward accounting: dQ replays the forward tables, dK/dV
            # walks the transposed tables — same deduplicated tile set,
            # regrouped by KV block, in exactly two launches.
            bwd_dq_tiles=executed_tiles,
            bwd_dkv_tiles=int(tp.num_steps.sum()),
            bwd_kv_steps_per_kv_block=tp.max_steps,
            bwd_launches=2,
            # Packed dK/dV layout: padded grid cells before/after packing
            # (global-column patterns pay the ragged transposed rows — the
            # global tile's row spans every q block — unless packed).
            bwd_dkv_grid_unpacked=self.nkb * tp.max_steps,
            bwd_dkv_grid_packed=pk.n_rows * pk.width,
            bwd_dkv_pack_ratio=(self.nkb * tp.max_steps)
            / max(pk.n_rows * pk.width, 1),
        )


def build_plan(sched: BandSchedule, block_q: int, block_k: int,
               pad_multiple: int = 1) -> ExecutionPlan:
    """Lower a band schedule into the deduplicated ExecutionPlan.

    Correctness of the dedup (why one visit per tile suffices): every
    attended pair (i, j) of the windowed part has a working-space offset
    inside some band, so its KV tile lies inside that band's walk for i's
    query block; every global pair's tile holds a global key and is added to
    the visit set explicitly (wherever reordering scattered it). Since each
    pair lives in exactly one KV tile and each tile is visited at most once,
    applying the union mask (window | global-column) at the visit counts
    each pair exactly once — no cross-band double counting, no misses.

    ``pad_multiple`` extends the tile-grid padding (see
    :meth:`BandSchedule.plan`); padded rows/tiles carry ``PAD_SENTINEL``
    positions and mask to nothing, exactly like block-alignment padding.
    """
    # Normalized through one cached entry point so build_plan(s, bq, bk)
    # and s.plan(bq, bk) return the IDENTICAL object (single source of
    # truth for both engines, asserted by the plan contract tests).
    return _build_plan(sched, block_q, block_k, int(pad_multiple))


@functools.lru_cache(maxsize=256)
def _build_plan(sched: BandSchedule, block_q: int, block_k: int,
                pad_multiple: int) -> ExecutionPlan:
    n_pad = _round_up(sched.n_work,
                      math.lcm(max(block_q, block_k), pad_multiple))
    nq = n_pad // block_q
    nkb = n_pad // block_k
    pos = np.full(n_pad, BIG, dtype=np.int32)
    pos[: sched.n_work] = sched.positions()

    g = sched.n_global
    if g > 0:
        # Tiles holding global keys — a contiguous prefix in the identity
        # layout, scattered across residue groups after dilation reordering.
        gtiles = set(np.nonzero(
            (pos.reshape(nkb, block_k) < g).any(axis=1))[0].tolist())
    else:
        gtiles = set()

    band_set_index: dict = {}
    band_sets: list = []
    rows = []
    for i in range(nq):
        cover: dict = {}
        for bi, band in enumerate(sched.bands):
            s0 = band.kv_start_block(i, block_q, block_k)
            for t in range(s0, s0 + band.kv_steps(block_q, block_k)):
                if 0 <= t < nkb:
                    cover.setdefault(t, []).append(bi)
        row = []
        for t in sorted(set(cover) | gtiles):
            bset = tuple(cover.get(t, ()))
            fl = (STEP_WINDOW if bset else 0) | (STEP_GLOBAL
                                                 if t in gtiles else 0)
            if bset not in band_set_index:
                band_set_index[bset] = len(band_sets)
                band_sets.append(bset)
            row.append((t, fl, band_set_index[bset]))
        rows.append(row)

    max_steps = max(1, max(len(r) for r in rows))
    kv_blocks = np.zeros((nq, max_steps), dtype=np.int32)
    flags = np.zeros((nq, max_steps), dtype=np.int32)
    band_set_ids = np.full((nq, max_steps), -1, dtype=np.int32)
    num_steps = np.asarray([len(r) for r in rows], dtype=np.int32)
    for i, row in enumerate(rows):
        for s, (t, fl, sid) in enumerate(row):
            kv_blocks[i, s] = t
            flags[i, s] = fl
            band_set_ids[i, s] = sid

    validate_tables(kv_blocks, flags, nkb=nkb, num_steps=num_steps,
                    name="ExecutionPlan tables")
    return ExecutionPlan(
        sched=sched, block_q=block_q, block_k=block_k, n_pad=n_pad, nq=nq,
        nkb=nkb, max_steps=max_steps, kv_blocks=kv_blocks, flags=flags,
        band_set_ids=band_set_ids, band_sets=tuple(band_sets),
        num_steps=num_steps)


# ---------------------------------------------------------------------- #
# ChunkPlan IR — causal chunk-slicing of the plan for serving prefill
# ---------------------------------------------------------------------- #
def causal_step_mask(pattern: HybridSparsePattern, pos_i, pos_j, flags):
    """The serving-side union mask: window | global column, causal.

    Shared by the ragged decode twin, the chunked-prefill engine, and the
    decode kernels — evaluated on ORIGINAL positions, so ring/paged slot
    layouts are transparent. ``flags`` gates the components exactly like
    :meth:`BandSchedule.step_mask` (0 = padding no-op). Padding slots carry
    ``PAD_SENTINEL`` positions and fail every component: the window by
    distance, the global column by ``pos_j < g``, and padded *query* rows by
    the explicit in-range guard.

    Equivalence to the training mask: for a causal 1-D pattern, row ``i`` of
    ``pattern.mask(n)`` is window ∪ global-column restricted to ``j <= i``
    (global *rows* ``i < g`` degenerate to the global column under
    causality), which is exactly this union — so chunked prefill, decode,
    and the full-sequence forward agree token-for-token.
    """
    import jax.numpy as jnp

    p = pattern
    if p.is_2d:
        raise ValueError("causal_step_mask is the 1-D serving mask; 2-D "
                         "patterns decode through the training engines")
    pos_i = jnp.asarray(pos_i)
    pos_j = jnp.asarray(pos_j)
    flags = jnp.asarray(flags)
    a, b = p.window
    rel = pos_j - pos_i
    w = (rel >= a) & (rel <= min(b, 0))
    if p.dilation > 1:
        w = w & (rel % p.dilation == 0)
    m = w & ((flags & STEP_WINDOW) != 0)
    if p.n_global > 0:
        m = m | ((pos_j < p.n_global) & ((flags & STEP_GLOBAL) != 0))
    return m & (pos_j <= pos_i) & (pos_i < BIG) & (pos_j < BIG)


def ring_view_positions(chunk_start: int, n_sink: int, ring_cap: int,
                        n_global: int) -> np.ndarray:
    """Static position of every cached slot just before chunk ``c0`` starts.

    The paged serving layout is deterministic: sink slot ``j`` holds
    position ``j`` (once prefill has passed it), ring slot ``r`` holds the
    LATEST position ``p < c0`` with ``p >= g`` and ``(p - g) % ring_cap ==
    r``. Returns (n_sink + ring_cap,) int32 with ``BIG`` for slots not yet
    written — the pruning oracle for :func:`build_chunk_plan` (runtime
    masks use the slab's live position table, which matches this by
    construction of the sequential prefill writes).
    """
    g, c0 = n_global, chunk_start
    pos = np.full(n_sink + ring_cap, BIG, dtype=np.int32)
    ns = min(g, c0, n_sink)
    pos[:ns] = np.arange(ns)
    if ring_cap > 0 and c0 > g:
        r = np.arange(ring_cap)
        base = g + r
        latest = base + ((c0 - 1 - base) // ring_cap) * ring_cap
        pos[n_sink:] = np.where(c0 - 1 >= base, latest.astype(np.int64),
                                BIG).astype(np.int32)
    return pos


@dataclasses.dataclass(frozen=True, eq=False)
class ChunkPlan:
    """Step tables for ONE causal prefill chunk: queries ``[c0, c1)``
    against the paged KV view ``[sink slots | ring slots | the chunk
    itself]``.

    The view is position-scrambled (ring slots hold ``(p - g) % ring_cap``)
    but the tables are exact: tile pruning uses the static slot->position
    map (:func:`ring_view_positions`), masks are evaluated at runtime on
    live positions via :func:`causal_step_mask`. Row ``i`` lists the view
    tiles chunk-query-block ``i`` visits (ascending, deduplicated), flags
    gate window vs global work, rows right-padded with ``flags == 0``
    no-ops. One chunk = one fused table-driven pass — the serving mirror of
    :class:`ExecutionPlan`.
    """
    pattern: HybridSparsePattern
    chunk_start: int
    chunk_len: int
    chunk_pad: int            # chunk slots (block-aligned)
    n_sink: int               # sink slots in the view (page-aligned)
    ring_cap: int             # ring slots in the view (page-aligned)
    block: int                # tile size (queries AND keys)
    view_len: int             # n_sink + ring_cap + chunk_pad
    nq: int                   # chunk query blocks
    nkb: int                  # view KV tiles
    max_steps: int
    kv_blocks: np.ndarray     # (nq, max_steps) int32
    flags: np.ndarray         # (nq, max_steps) int32
    num_steps: np.ndarray     # (nq,) int32
    view_positions: np.ndarray  # (view_len,) static positions (BIG = empty)

    def _key(self):
        return (self.pattern, self.chunk_start, self.chunk_len, self.n_sink,
                self.ring_cap, self.block, self.chunk_pad)

    def __hash__(self):
        return hash(self._key())

    def __eq__(self, other):
        return isinstance(other, ChunkPlan) and self._key() == other._key()

    def padded_tables(self, nq: int, width: int):
        """Tables padded to a fixed (nq, width) so every chunk of a request
        compiles to ONE jitted step (padding steps: tile 0, flags 0)."""
        assert nq >= self.nq and width >= self.max_steps, \
            (nq, width, self.nq, self.max_steps)
        kv = np.zeros((nq, width), dtype=np.int32)
        fl = np.zeros((nq, width), dtype=np.int32)
        kv[: self.nq, : self.max_steps] = self.kv_blocks
        fl[: self.nq, : self.max_steps] = self.flags
        validate_tables(kv, fl, nkb=self.nkb, name="ChunkPlan tables")
        return kv, fl

    def sharded_tables(self, n_shards: int, nq: int, width: int,
                       chunk_owner: Optional[int] = None):
        """Per-shard step tables over the ``[sink | ring | chunk]`` view —
        the serving mirror of :func:`repro.dist.sharded_plan.shard_plan`.

        Context tiles are striped contiguously over the shards (tile ``t``
        owned by ``t // tiles_per_shard``, matching the paged layout's
        page striping), so each shard executes only the steps whose KV it
        holds, remapped onto its local view ``[owned ctx tiles | chunk]``.
        The chunk's self-attention tiles are assigned to exactly ONE shard
        (``chunk_owner``, default the last — the chunk KV is replicated, so
        any owner is exact); every (query, kv-slot) pair is therefore
        evaluated on exactly one shard and the per-shard ``(out, m, l)``
        partials combine exactly under the masked-psum merge
        (:func:`repro.dist.sharded_plan.masked_psum_merge` — the
        cross-device instance of ``renorm.merge``). Shards with no step for
        a row keep ``flags == 0`` padding no-ops, which produce the empty
        PartialState identity ``(0, NEG_INF, 0)``.

        Returns ``(kv, fl)`` stacked ``(n_shards, nq, width)``.
        """
        ctx_tiles = (self.n_sink + self.ring_cap) // self.block
        if ctx_tiles % n_shards:
            raise ValueError(f"ctx tiles {ctx_tiles} not divisible by "
                             f"{n_shards} shards (use a shard-aligned "
                             f"PagedLayout)")
        tps = ctx_tiles // n_shards
        if chunk_owner is None:
            chunk_owner = n_shards - 1
        assert nq >= self.nq and width >= tps + (self.chunk_pad
                                                 // self.block)
        kv = np.zeros((n_shards, nq, width), dtype=np.int32)
        fl = np.zeros((n_shards, nq, width), dtype=np.int32)
        fill = np.zeros((n_shards, nq), dtype=np.int64)
        for i in range(self.nq):
            for st in range(int(self.num_steps[i])):
                t = int(self.kv_blocks[i, st])
                f = int(self.flags[i, st])
                if t < ctx_tiles:
                    s, local = t // tps, t % tps
                else:
                    s, local = chunk_owner, tps + (t - ctx_tiles)
                w = fill[s, i]
                kv[s, i, w] = local
                fl[s, i, w] = f
                fill[s, i] = w + 1
        local_tiles = tps + self.chunk_pad // self.block
        for s in range(n_shards):
            validate_tables(kv[s], fl[s], nkb=local_tiles,
                            name=f"ChunkPlan shard {s} tables")
        return kv, fl

    def stats(self) -> dict:
        """Tile accounting: what the fused chunk pass executes vs the
        token-by-token decode replay it replaces."""
        executed = int(self.num_steps.sum())
        dense = self.nq * self.nkb
        return dict(chunk_start=self.chunk_start, chunk_len=self.chunk_len,
                    executed_tiles=executed, dense_tiles=dense,
                    launches=1, token_by_token_launches=self.chunk_len)


@functools.lru_cache(maxsize=4096)
def build_chunk_plan(pattern: HybridSparsePattern, chunk_start: int,
                     chunk_len: int, *, n_sink: int, ring_cap: int,
                     block: int, chunk_pad: Optional[int] = None) -> ChunkPlan:
    """Lower one causal prefill chunk into view-tile step tables.

    ``n_sink``/``ring_cap`` describe the request's paged cache view (both
    multiples of ``block``); the chunk rides behind them. Queries at
    positions ``[c0, c0 + chunk_len)`` attend cached KV + the chunk itself
    under the causal union mask. 2-D and non-causal patterns don't serve
    through this path.
    """
    if pattern.is_2d or not pattern.causal:
        raise ValueError("chunked prefill requires a causal 1-D pattern, "
                         f"got {pattern}")
    if n_sink % block or ring_cap % block:
        raise ValueError(f"view regions ({n_sink}, {ring_cap}) must be "
                         f"multiples of block {block}")
    a, b = pattern.window
    hi = min(b, 0)
    g = pattern.n_global
    c0, c1 = chunk_start, chunk_start + chunk_len
    cp = _round_up(max(chunk_len, 1), block)
    if chunk_pad is not None:
        assert chunk_pad >= cp and chunk_pad % block == 0, (chunk_pad, cp)
        cp = chunk_pad
    ctx = n_sink + ring_cap
    view_len = ctx + cp
    nq, nkb = cp // block, view_len // block
    vpos = np.full(view_len, BIG, dtype=np.int32)
    vpos[:ctx] = ring_view_positions(c0, n_sink, ring_cap, g)
    vpos[ctx: ctx + chunk_len] = np.arange(c0, c1, dtype=np.int32)

    rows = []
    for i in range(nq):
        qlo = c0 + i * block
        qhi = min(c1, qlo + block) - 1
        if qlo >= c1:
            rows.append([])
            continue
        row = []
        for t in range(nkb):
            tp = vpos[t * block: (t + 1) * block]
            tp = tp[tp < BIG]
            if tp.size == 0:
                continue
            fl = 0
            if ((tp >= qlo + a) & (tp <= qhi + hi)).any():
                fl |= STEP_WINDOW
            if g > 0 and (tp < min(g, qhi + 1)).any():
                fl |= STEP_GLOBAL
            if fl:
                row.append((t, fl))
        rows.append(row)

    max_steps = max(1, max(len(r) for r in rows))
    kv_blocks = np.zeros((nq, max_steps), dtype=np.int32)
    flags = np.zeros((nq, max_steps), dtype=np.int32)
    num_steps = np.asarray([len(r) for r in rows], dtype=np.int32)
    for i, row in enumerate(rows):
        for s, (t, fl) in enumerate(row):
            kv_blocks[i, s] = t
            flags[i, s] = fl
    return ChunkPlan(pattern=pattern, chunk_start=c0, chunk_len=chunk_len,
                     chunk_pad=cp, n_sink=n_sink, ring_cap=ring_cap,
                     block=block, view_len=view_len, nq=nq, nkb=nkb,
                     max_steps=max_steps, kv_blocks=kv_blocks, flags=flags,
                     num_steps=num_steps, view_positions=vpos)


# ---------------------------------------------------------------------- #
# TransposedPlan IR — the backward's dK/dV schedule
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True, eq=False)
class TransposedPlan:
    """Per-KV-block step tables: the exact adjoint of an ExecutionPlan.

    Row ``j`` lists the query blocks whose forward walk visits KV tile
    ``j``, in ascending block order, each block exactly once (the forward
    dedup carries over: (q_block, kv_tile) appears in the forward tables
    at most once, hence here at most once too):

    * ``q_blocks[j, s]`` — query block of step ``s`` (0 for padding steps);
    * ``flags[j, s]``    — the SAME ``STEP_WINDOW | STEP_GLOBAL`` bitmask
      the forward visit carried (0 = padding no-op — every mask term
      evaluates False, identical to the forward padding contract);
    * ``num_steps[j]``   — real (non-padding) steps of row ``j``.

    Rows are right-padded to ``max_steps`` (the dK/dV kernel grid's
    sequential dimension). Total real steps equal the forward plan's
    ``executed_tiles`` exactly — the backward re-walks the deduplicated
    tile set, regrouped by KV block, never a superset.
    """
    plan: ExecutionPlan
    max_steps: int
    q_blocks: np.ndarray   # (nkb, max_steps) int32
    flags: np.ndarray      # (nkb, max_steps) int32
    num_steps: np.ndarray  # (nkb,) int32

    def __hash__(self):
        return hash(("transposed", self.plan))

    def __eq__(self, other):
        return isinstance(other, TransposedPlan) and self.plan == other.plan


@functools.lru_cache(maxsize=256)
def build_transposed(plan: ExecutionPlan) -> TransposedPlan:
    """Transpose the forward step tables into per-KV-block tables.

    Pure table surgery — no re-derivation from bands, so the transposed
    walk is the adjoint of what the forward *actually executed* by
    construction (same visits, same flags, regrouped by KV tile).
    """
    rows: list = [[] for _ in range(plan.nkb)]
    for i in range(plan.nq):
        for s in range(int(plan.num_steps[i])):
            fl = int(plan.flags[i, s])
            if fl:  # real forward steps always carry flags; paranoia guard
                rows[int(plan.kv_blocks[i, s])].append((i, fl))
    max_steps = max(1, max(len(r) for r in rows))
    q_blocks = np.zeros((plan.nkb, max_steps), dtype=np.int32)
    flags = np.zeros((plan.nkb, max_steps), dtype=np.int32)
    num_steps = np.asarray([len(r) for r in rows], dtype=np.int32)
    for j, row in enumerate(rows):
        for s, (i, fl) in enumerate(row):  # ascending i: outer loop order
            q_blocks[j, s] = i
            flags[j, s] = fl
    return TransposedPlan(plan=plan, max_steps=max_steps, q_blocks=q_blocks,
                          flags=flags, num_steps=num_steps)


# ---------------------------------------------------------------------- #
# PackedTransposedPlan — the dK/dV walk without the ragged-row tax
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True, eq=False)
class PackedTransposedPlan:
    """The transposed walk packed to fixed-width rows.

    ``row_tile[r]`` names the KV tile packed row ``r`` accumulates into;
    rows longer than ``width`` in the raw transposed tables are split into
    several packed rows sharing one ``row_tile`` (their partial dK/dV are
    scatter-added per owner tile by the engines), and tiles visited by no
    query block get no row at all. Total real steps stay exactly the
    forward plan's ``executed_tiles`` — packing only reshapes the grid.
    """
    plan: Optional[ExecutionPlan]
    width: int
    n_rows: int
    row_tile: np.ndarray   # (n_rows,) int32 — owner KV tile per packed row
    q_blocks: np.ndarray   # (n_rows, width) int32 (0 = padding step)
    flags: np.ndarray      # (n_rows, width) int32 (0 = padding no-op)
    num_steps: np.ndarray  # (n_rows,) int32

    def __hash__(self):
        return hash(("packed", self.plan))

    def __eq__(self, other):
        return (isinstance(other, PackedTransposedPlan)
                and self.plan is not None and self.plan == other.plan)


def pack_rows(rows, width: Optional[int] = None):
    """Pack ragged per-tile visit lists into fixed-width owner-tagged rows.

    ``rows[j]`` is the list of ``(q_block, flags)`` visits of KV tile ``j``.
    Returns ``(row_tile, q_blocks, flags, num_steps, width)`` numpy arrays.
    ``width`` defaults to the 95th-percentile nonzero row length — band rows
    (all of near-equal length) stay one row each, while the global-column
    tile's every-q-block row is split instead of padding everyone to it.
    """
    lens = np.asarray([len(r) for r in rows], dtype=np.int64)
    nz = lens[lens > 0]
    if width is None:
        width = int(np.ceil(np.percentile(nz, 95))) if nz.size else 1
    width = max(1, int(width))
    packed = []  # (tile, [(q, fl), ...]) chunks
    for j, row in enumerate(rows):
        for c0 in range(0, len(row), width):
            packed.append((j, row[c0: c0 + width]))
    if not packed:
        packed = [(0, [])]
    n_rows = len(packed)
    row_tile = np.asarray([t for t, _ in packed], dtype=np.int32)
    q_blocks = np.zeros((n_rows, width), dtype=np.int32)
    flags = np.zeros((n_rows, width), dtype=np.int32)
    num_steps = np.asarray([len(c) for _, c in packed], dtype=np.int32)
    for r, (_, chunk) in enumerate(packed):
        for s, (i, fl) in enumerate(chunk):
            q_blocks[r, s] = i
            flags[r, s] = fl
    return row_tile, q_blocks, flags, num_steps, width


@functools.lru_cache(maxsize=256)
def build_packed_transposed(plan: ExecutionPlan) -> PackedTransposedPlan:
    """Pack :func:`build_transposed`'s tables (pure table surgery again)."""
    tp = build_transposed(plan)
    rows = [[(int(tp.q_blocks[j, s]), int(tp.flags[j, s]))
             for s in range(int(tp.num_steps[j]))] for j in range(plan.nkb)]
    row_tile, q_blocks, flags, num_steps, width = pack_rows(rows)
    return PackedTransposedPlan(plan=plan, width=width,
                                n_rows=row_tile.shape[0], row_tile=row_tile,
                                q_blocks=q_blocks, flags=flags,
                                num_steps=num_steps)
