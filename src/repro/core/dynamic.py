"""Runtime ExecutionPlans: content-based step tables built on device.

The static scheduler hand-designs *which* KV tiles each query block visits
from the pattern alone. This module predicts it from the *content*
(Dynamic Sparse Attention, arXiv:2110.11299; the estimator follows SEA's
pooled-score idea): estimate every (q-block, kv-tile) pair's attention
mass from pooled q·k scores, keep the top-``keep`` tiles per query block,
and emit ``(kv_blocks, flags)`` as traced jnp arrays honoring the exact
contract of :mod:`repro.core.plan_contract` — so every table consumer
(fused Pallas kernels, the XLA scan twins, ShardedPlan's per-shard slices)
runs query-adaptive sparsity without changing a line.

Three load-bearing properties:

* **Selection is a subset of the static plan's visits.** Candidates are
  the static plan's steps, and a selected step keeps its ORIGINAL flags —
  so ``step_mask`` applies the same union mask it always did, full keep
  (``keep >= max_steps``) reproduces the static walk step-for-step (the
  machinery-off invariant), and the dedup/padding contract is inherited.
* **The never-drop guarantee.** Steps whose tile is causal-local to the
  row (within ``local_window`` original positions) or carries global/sink
  columns (``STEP_GLOBAL``) get ``+inf`` selection score: correctness-
  critical tiles can never be dropped, whatever the content says.
  ``keep`` must cover the worst-case always-kept count (checked, raises).
* **The selector is gradient-free.** q/k enter the estimator under
  ``lax.stop_gradient``; training treats the selected table like the
  static one (a constant of the step), and the backward replays the
  SAME selection deterministically from the saved residuals — dQ over the
  forward tables (:func:`repro.core.blockwise.table_dq_scan` or the
  Pallas table kernel), dK/dV through the runtime scatter twin
  (:func:`repro.core.blockwise.table_dkv_scatter_scan`), since the
  host-packed transposed walk cannot exist for device-built tables.

Selected rows are re-sorted into ascending-tile order with right-aligned
padding — matching the static builder's layout, so engines see an
identically-shaped, identically-ordered table whose *values* happen to be
traced.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blockwise import (_global_rows, table_attention_scan,
                                  table_dkv_scatter_scan, table_dq_scan,
                                  plan_backward, undo_working,
                                  working_stream)
from repro.core.patterns import HybridSparsePattern
from repro.core.plan_contract import (PAD_SENTINEL, STEP_GLOBAL,
                                      validate_tables)
from repro.core.scheduler import ExecutionPlan, schedule
from repro.obs.metrics import global_registry


@dataclasses.dataclass(frozen=True)
class DynamicConfig:
    """How to select: ``keep`` tiles per query block.

    ``local_window``: original-position distance under which a tile is
    causal-local and therefore always kept (default: one tile span,
    ``max(block_q, block_k)``). ``pool_k``: key-side pooling granularity
    for the mass estimator — keys are mean-pooled per ``pool_k``-slot
    group and groups reduce by logsumexp, so ``None`` (= whole tile) is
    the cheapest plain block-mean while small values track a tile's
    exp-mass closely (e.g. hot single keys); must divide ``block_k``.
    """
    keep: int
    local_window: Optional[int] = None
    pool_k: Optional[int] = None


def _resolve_window(cfg: DynamicConfig, block_q: int, block_k: int) -> int:
    if cfg.local_window is not None:
        return int(cfg.local_window)
    return max(block_q, block_k)


def always_keep_mask(kv_blocks: np.ndarray, flags: np.ndarray,
                     pos_q: np.ndarray, pos_k: np.ndarray,
                     local_window: int, causal: bool) -> np.ndarray:
    """The never-drop set, statically: which steps of a candidate table are
    exempt from selection. A step is always kept when its tile carries
    global columns (``STEP_GLOBAL``) or is local to the row — the tile's
    original-position range overlaps ``[row_min - local_window, row_max]``
    (``row_max + local_window`` when not causal). Ranges are taken over
    valid (non-``PAD_SENTINEL``) slots; all-padding tiles/rows never
    match. Returns a boolean (nq, W) mask; padding steps are False.
    """
    kv_blocks = np.asarray(kv_blocks)
    flags = np.asarray(flags)
    vq = pos_q < PAD_SENTINEL
    pq = pos_q.astype(np.int64)
    qlo = np.where(vq, pq, np.iinfo(np.int64).max).min(axis=1)
    qhi = np.where(vq, pq, -1).max(axis=1)
    vk = pos_k < PAD_SENTINEL
    pk = pos_k.astype(np.int64)
    klo = np.where(vk, pk, np.iinfo(np.int64).max).min(axis=1)
    khi = np.where(vk, pk, -1).max(axis=1)

    tlo = klo[kv_blocks]                                   # (nq, W)
    thi = khi[kv_blocks]
    lo_q = qlo[:, None]
    hi_q = qhi[:, None]
    reach = hi_q if causal else hi_q + local_window
    local = (thi >= lo_q - local_window) & (tlo <= reach)
    local &= (thi >= 0) & (hi_q >= 0)      # all-padding tile / row: never
    keep = ((flags & STEP_GLOBAL) != 0) | local
    return keep & (flags != 0)


@functools.lru_cache(maxsize=256)
def _plan_always_keep(plan: ExecutionPlan, local_window: int) -> np.ndarray:
    pos = plan.positions_padded()
    return always_keep_mask(
        plan.kv_blocks, plan.flags,
        pos.reshape(plan.nq, plan.block_q),
        pos.reshape(plan.nkb, plan.block_k),
        local_window, plan.sched.causal)


def plan_always_keep(plan: ExecutionPlan, local_window: int) -> np.ndarray:
    """Public analyzer hook: the (nq, max_steps) never-drop mask for a
    static plan — what :mod:`repro.analysis.plan_verify` proves global /
    sink / causal-local tiles can never be dropped against."""
    return _plan_always_keep(plan, int(local_window))


def check_keep(keep: int, always: np.ndarray, what: str = "plan") -> None:
    """The never-drop guarantee needs room: ``keep`` must cover the largest
    per-row always-kept count, else top-k would be forced to drop a
    correctness-critical tile. Static check — raises ValueError."""
    need = int(np.asarray(always).sum(axis=-1).max()) if always.size else 0
    if keep < need:
        raise ValueError(
            f"dynamic keep={keep} is below the {what}'s worst-case "
            f"always-kept count {need} (causal-local + global tiles); "
            f"raise keep or shrink local_window")


def _account_build(flags, keep: int) -> None:
    """Trace-time keep-ratio accounting (host-side: static table shapes and
    the static candidate flags — zero traced operands, zero cost when the
    registry is disabled; the same pattern as ops._trace_accounting)."""
    real = (np.asarray(flags) != 0).sum(axis=-1)
    total = int(real.sum())
    kept = int(np.minimum(real, keep).sum())
    reg = global_registry()
    reg.inc("dynamic_plan_builds")
    reg.observe("dynamic_plan_keep_ratio", kept / max(total, 1))


def block_scores(q, k, pos_q, pos_k, scale: float,
                 pool_k: Optional[int] = None):
    """Pooled per-(q-block, kv-tile) attention-mass estimate, (nq, nkb) f32.

    Queries are mean-pooled per block over valid slots — by linearity the
    pooled score IS the exact mean of the block's pairwise scores. Keys
    are mean-pooled per ``pool_k``-slot group and the groups reduce by
    logsumexp (with the whole tile as one group this is the plain block
    mean; finer groups approximate ``log`` of the tile's exp-mass, which
    is what top-k should rank). Batch/head reduce by mean. Cost is
    ``N^2 D / (block_q * pool_k)`` — ``block_q``x (or more) below the
    attention it prices.
    """
    B, nQ, D = q.shape
    nq, bq = pos_q.shape
    nkb, bk = pos_k.shape
    pk = bk if pool_k is None else int(pool_k)
    if bk % pk:
        raise ValueError(f"pool_k={pk} must divide block_k={bk}")
    S = bk // pk
    vq = jnp.asarray(pos_q) < PAD_SENTINEL                      # (nq, bq)
    vk = (jnp.asarray(pos_k) < PAD_SENTINEL).reshape(nkb, S, pk)
    qf = q.astype(jnp.float32).reshape(B, nq, bq, D)
    kf = k.astype(jnp.float32).reshape(B, nkb, S, pk, D)
    qp = (qf * vq[None, :, :, None]).sum(2) \
        / jnp.maximum(vq.sum(1), 1)[None, :, None]              # (B, nq, D)
    kcnt = vk.sum(2)                                            # (nkb, S)
    kp = (kf * vk[None, :, :, :, None]).sum(3) \
        / jnp.maximum(kcnt, 1)[None, :, :, None]                # (B,nkb,S,D)
    s = jnp.einsum("bqd,bksd->bqks", qp, kp) * scale
    s = jnp.where((kcnt > 0)[None, None], s, -jnp.inf)
    est = jax.nn.logsumexp(s, axis=-1)                          # (B,nq,nkb)
    return est.mean(0)


def select_steps(q, k, kv_blocks, flags, pos_q, pos_k, always, keep: int,
                 scale: float, pool_k: Optional[int] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Top-``keep`` content selection over a candidate step table.

    Works identically on static (numpy) and traced (per-shard slice)
    candidate tables. The selector sees q/k through ``stop_gradient``;
    ``always`` steps score ``+inf`` (never dropped), padding steps
    ``-inf`` (picked only when a row has fewer than ``keep`` real steps,
    and re-emitted as contract padding: flags 0, tile 0). Output rows are
    ascending-tile, right-padded — the static builder's layout. Returns
    ``(kv_blocks, flags)`` int32 (nq, keep).
    """
    q = jax.lax.stop_gradient(q)
    k = jax.lax.stop_gradient(k)
    est = block_scores(q, k, pos_q, pos_k, scale, pool_k)      # (nq, nkb)
    kvb = jnp.asarray(kv_blocks)
    flg = jnp.asarray(flags)
    step_est = jnp.take_along_axis(est, kvb, axis=1)           # (nq, W)
    score = jnp.where(jnp.asarray(always), jnp.inf, step_est)
    score = jnp.where(flg != 0, score, -jnp.inf)
    vals, idx = jax.lax.top_k(score, keep)
    sel_f = jnp.where(vals > -jnp.inf,
                      jnp.take_along_axis(flg, idx, axis=1), 0)
    sel_t = jnp.where(sel_f != 0,
                      jnp.take_along_axis(kvb, idx, axis=1), 0)
    order = jnp.argsort(
        jnp.where(sel_f != 0, sel_t, jnp.iinfo(jnp.int32).max), axis=1)
    sel_t = jnp.take_along_axis(sel_t, order, axis=1)
    sel_f = jnp.take_along_axis(sel_f, order, axis=1)
    return sel_t.astype(jnp.int32), sel_f.astype(jnp.int32)


def _prep(pattern: HybridSparsePattern, N: int, cfg: DynamicConfig,
          block_q: int, block_k: int):
    sched = schedule(pattern, N)
    plan = sched.plan(block_q, block_k)
    always = _plan_always_keep(plan, _resolve_window(cfg, block_q, block_k))
    keep = min(int(cfg.keep), plan.max_steps)
    check_keep(keep, always)
    return sched, plan, always, keep


def dynamic_tables(q, k, pattern: HybridSparsePattern, cfg: DynamicConfig,
                   *, block_q: int = 128, block_k: int = 128,
                   scale: Optional[float] = None):
    """Materialize the selected tables for inspection (tests, benchmarks,
    recall measurement). q/k: (B, N, D) flat. Returns ``(plan, kv_blocks,
    flags, always)`` with tables (nq, keep) on the plan's working grid —
    concrete when called outside jit."""
    B, N, D = q.shape
    scale = (D ** -0.5) if scale is None else scale
    sched, plan, always, keep = _prep(pattern, N, cfg, block_q, block_k)
    qw = working_stream(q, sched, plan)
    kw = working_stream(k, sched, plan)
    pos = plan.positions_padded()
    kvt, flg = select_steps(
        qw, kw, plan.kv_blocks, plan.flags,
        pos.reshape(plan.nq, plan.block_q),
        pos.reshape(plan.nkb, plan.block_k),
        always, keep, scale, cfg.pool_k)
    return plan, kvt, flg, always


def _dyn_forward(q, k, v, pattern, cfg, block_q, block_k, scale, impl):
    B, N, D = q.shape
    scale = (D ** -0.5) if scale is None else scale
    sched, plan, always, keep = _prep(pattern, N, cfg, block_q, block_k)
    out_dtype = q.dtype

    qw = working_stream(q, sched, plan)
    kw = working_stream(k, sched, plan)
    vw = working_stream(v, sched, plan)
    pos = jnp.asarray(plan.positions_padded())
    pos_q = pos.reshape(plan.nq, plan.block_q)
    pos_k = pos.reshape(plan.nkb, plan.block_k)

    kvt, flg = select_steps(qw, kw, plan.kv_blocks, plan.flags, pos_q,
                            pos_k, always, keep, scale, cfg.pool_k)
    validate_tables(kvt, flg, nkb=plan.nkb, name="dynamic tables")
    _account_build(plan.flags, keep)

    from repro.kernels.ops import _use_fallback
    interpret = impl == "pallas_interpret"
    if impl in ("pallas", "pallas_interpret") and not _use_fallback(interpret):
        from repro.kernels.salo_attention import salo_table_attention
        out_w, m, l = salo_table_attention(
            qw, kw, vw, pos_q, pos_k, kvt.reshape(-1), flg.reshape(-1),
            sched=sched, block_q=block_q, block_k=block_k, scale=scale,
            interpret=interpret)
    else:
        out_w, m, l = table_attention_scan(qw, kw, vw, pos_q, pos_k, kvt,
                                           flg, sched, scale)

    out = undo_working(out_w, sched, N)
    if sched.n_global > 0 and sched.global_rows:
        rows = _global_rows(q, k, v, sched, scale, out_dtype)
        out = out.at[:, : sched.n_global].set(rows)
    return out, (out_w, m, l)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _dynamic(q, k, v, pattern, cfg, block_q, block_k, scale, impl):
    out, _ = _dyn_forward(q, k, v, pattern, cfg, block_q, block_k, scale,
                          impl)
    return out


def _dynamic_fwd(q, k, v, pattern, cfg, block_q, block_k, scale, impl):
    out, (out_w, m, l) = _dyn_forward(q, k, v, pattern, cfg, block_q,
                                      block_k, scale, impl)
    return out, (q, k, v, out_w, m, l)


def _dynamic_bwd(pattern, cfg, block_q, block_k, scale, impl, res, g):
    q, k, v, out_w, m, l = res
    B, N, D = q.shape
    scale_ = (D ** -0.5) if scale is None else scale
    sched, plan, always, keep = _prep(pattern, N, cfg, block_q, block_k)
    pos_np = plan.positions_padded()
    pos_q = jnp.asarray(pos_np.reshape(plan.nq, plan.block_q))
    pos_k = jnp.asarray(pos_np.reshape(plan.nkb, plan.block_k))

    # The selector is deterministic in (q, k): replaying it from the saved
    # residuals reproduces the forward's table exactly, once, shared by
    # both gradient walks.
    stash = {}

    def tables(qw, kw):
        if not stash:
            stash["t"] = select_steps(qw, kw, plan.kv_blocks, plan.flags,
                                      pos_q, pos_k, always, keep, scale_,
                                      cfg.pool_k)
        return stash["t"]

    from repro.kernels.ops import _use_fallback
    interpret = impl == "pallas_interpret"
    use_kernel = impl in ("pallas", "pallas_interpret") \
        and not _use_fallback(interpret)

    def dq_engine(dout, delta, m_, l_, qw, kw, vw, pos):
        kvt, flg = tables(qw, kw)
        if use_kernel:
            from repro.kernels.salo_backward import salo_table_backward_dq
            return salo_table_backward_dq(
                dout, delta, m_, l_, qw, kw, vw, pos_q, pos_k,
                kvt.reshape(-1), flg.reshape(-1), sched=sched,
                block_q=block_q, block_k=block_k, scale=scale_,
                interpret=interpret)
        return table_dq_scan(dout, delta, m_, l_, qw, kw, vw, pos_q,
                             pos_k, kvt, flg, sched, scale_)

    def dkv_engine(dout, delta, m_, l_, qw, kw, vw, pos):
        # dK/dV cannot walk the host-packed transposed tables (the table
        # is runtime data): the scatter twin regroups at run time.
        kvt, flg = tables(qw, kw)
        return table_dkv_scatter_scan(dout, delta, m_, l_, qw, kw, vw,
                                      pos_q, pos_k, kvt, flg, sched,
                                      scale_)

    return plan_backward(g, q, k, v, out_w, m, l, plan, scale_, dq_engine,
                         dkv_engine)


_dynamic.defvjp(_dynamic_fwd, _dynamic_bwd)


@functools.partial(jax.jit, static_argnames=("pattern", "cfg", "block_q",
                                             "block_k", "scale", "impl"))
def dynamic_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      pattern: HybridSparsePattern, cfg: DynamicConfig, *,
                      block_q: int = 128, block_k: int = 128,
                      scale: Optional[float] = None,
                      impl: str = "blockwise") -> jax.Array:
    """Content-based dynamically-sparse attention. q/k/v: (B, N, D).

    The static plan supplies the candidate visits and masks; per query
    block only the ``cfg.keep`` highest estimated-mass tiles execute
    (never dropping causal-local/global tiles). Differentiable through the
    shared ``plan_backward`` contract with a gradient-free selector — see
    the module docstring.
    """
    if impl not in ("blockwise", "pallas", "pallas_interpret"):
        raise ValueError(
            f"plan='dynamic' needs a table-driven engine, got impl={impl!r}")
    return _dynamic(q, k, v, pattern, cfg, block_q, block_k, scale, impl)
