"""Plan-driven blockwise attention in pure JAX — SALO's schedule on XLA.

This is the *algorithmic twin* of the Pallas kernel: it walks the SAME
:class:`repro.core.scheduler.ExecutionPlan` step tables with the SAME
per-step masks (``plan.step_mask``), folded through the same renormalized
online-softmax state. It exists because

1. training needs autodiff (everything here is differentiable jnp),
2. the CPU-only dry-run must lower something honest for roofline analysis
   (Pallas TPU kernels cannot be lowered by the CPU backend).

One ``lax.scan`` over ``plan.max_steps`` executes every band AND the global
column — overlapping KV tiles deduplicated to one visit, no per-band passes,
no separate global partial. Global rows (global queries attend everything)
are a dense g-row epilogue shared with the kernel wrapper.

Shapes: q, k, v are ``(B, N, D)`` where ``B`` folds batch*heads. The public
model-facing API lives in :mod:`repro.core.attention`.

Complexity: O(N * deduped_tiles_per_block * block_k * D) — linear in N for
banded patterns, the paper's claim, and strictly fewer tiles than the
per-band walk whenever bands overlap (ViL).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import renorm
from repro.core.scheduler import (BIG, STEP_WINDOW, BandSchedule,
                                  ExecutionPlan, _round_up, schedule)
from repro.core.patterns import HybridSparsePattern


def _dot(a, b):
    return jnp.einsum("...qd,...kd->...qk", a, b,
                      preferred_element_type=jnp.float32)


def _plan_partial(state: renorm.PartialState, q_blk, k_pad, v_pad, pos_pad,
                  plan: ExecutionPlan, scale: float) -> renorm.PartialState:
    """Fold the WHOLE plan (all bands + global column) into the state.

    q_blk: (B, nq, Bq, D); k_pad/v_pad: (B, n_pad, D); pos_pad: (n_pad,).
    state: PartialState over (B, nq, Bq). One scan step = one table column:
    every query block gathers its step-``s`` KV tile and applies the
    flag-gated union mask. Padding steps (flags == 0) mask to nothing.
    """
    B, nq, Bq, D = q_blk.shape
    bk = plan.block_k
    nkb = plan.nkb
    pos_q = pos_pad.reshape(nq, Bq)

    # Fast path (single band, no global, Bq == Bk): the plan's tile walk is
    # the affine shift ``i + c0 + s`` — a CONSTANT shift per step — so the
    # banded walk is a sliced view of the padded KV stream, not a gather.
    # No per-block index materialization; XLA fuses the slice into the
    # matmul operand (EXPERIMENTS.md §Perf gemma/prefill_32k). Out-of-range
    # tiles carry PAD_SENTINEL positions and mask to nothing.
    sched = plan.sched
    if len(sched.bands) == 1 and sched.n_global == 0 and Bq == bk:
        import math as _math
        band = sched.bands[0]
        steps = band.kv_steps(Bq, bk)
        c0 = _math.floor(band.lo / bk)
        c1 = c0 + steps - 1
        lpad = max(0, -c0) * bk
        rpad = max(0, c1) * bk
        n_pad = k_pad.shape[1]
        k_w = jnp.pad(k_pad, ((0, 0), (lpad, rpad), (0, 0)))
        v_w = jnp.pad(v_pad, ((0, 0), (lpad, rpad), (0, 0)))
        pos_w = jnp.pad(pos_pad, (lpad, rpad), constant_values=BIG)

        def body(st, s):
            start = (c0 + s) * bk + lpad     # >= 0 by construction
            k_blk = jax.lax.dynamic_slice_in_dim(
                k_w, start, n_pad, axis=1).reshape(B, nq, bk, D)
            v_blk = jax.lax.dynamic_slice_in_dim(
                v_w, start, n_pad, axis=1).reshape(B, nq, bk, D)
            pos_k = jax.lax.dynamic_slice_in_dim(
                pos_w, start, n_pad).reshape(nq, bk)
            scores = _dot(q_blk, k_blk) * scale
            mask = plan.step_mask(pos_q[:, :, None], pos_k[:, None, :],
                                  STEP_WINDOW)
            return renorm.update(st, scores, v_blk, mask[None]), ()

        state, _ = jax.lax.scan(body, state,
                                jnp.arange(steps, dtype=jnp.int32))
        return state

    # General path: gather each step's KV tile by the plan table.
    k_r = k_pad.reshape(B, nkb, bk, D)
    v_r = v_pad.reshape(B, nkb, bk, D)
    pos_r = pos_pad.reshape(nkb, bk)
    table = jnp.asarray(plan.kv_blocks)    # (nq, max_steps) int32
    flags = jnp.asarray(plan.flags)        # (nq, max_steps) int32

    def body(st, s):
        blk = jax.lax.dynamic_index_in_dim(table, s, axis=1,
                                           keepdims=False)      # (nq,)
        fl = jax.lax.dynamic_index_in_dim(flags, s, axis=1,
                                          keepdims=False)       # (nq,)
        k_blk = jnp.take(k_r, blk, axis=1)                      # (B,nq,Bk,D)
        v_blk = jnp.take(v_r, blk, axis=1)
        pos_k = jnp.take(pos_r, blk, axis=0)                    # (nq, Bk)
        scores = _dot(q_blk, k_blk) * scale
        mask = plan.step_mask(pos_q[:, :, None], pos_k[:, None, :],
                              fl[:, None, None])
        return renorm.update(st, scores, v_blk, mask[None]), ()

    state, _ = jax.lax.scan(body, state,
                            jnp.arange(plan.max_steps, dtype=jnp.int32))
    return state


def _global_rows(q_orig, k_orig, v_orig, sched: BandSchedule, scale: float,
                 out_dtype):
    """Global-row pass: the first n_global queries attend ALL keys (original
    order) — SALO's global PE row. Returns (B, g, D)."""
    g = sched.n_global
    n = sched.n
    qg = q_orig[:, :g]
    scores = _dot(qg, k_orig[:, :n]) * scale      # (B, g, n)
    if sched.causal:
        mask = (jnp.arange(n)[None, :] <= jnp.arange(g)[:, None])[None]
        scores = jnp.where(mask, scores, renorm.NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("...qk,...kd->...qd", p,
                      v_orig[:, :n].astype(p.dtype)).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("pattern", "block_q", "block_k",
                                             "return_state"))
def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                        pattern: HybridSparsePattern, *,
                        block_q: int = 128, block_k: int = 128,
                        scale: Optional[float] = None,
                        return_state: bool = False):
    """Hybrid sparse attention via the SALO ExecutionPlan. q,k,v: (B, N, D)."""
    B, N, D = q.shape
    scale = (D ** -0.5) if scale is None else scale
    sched = schedule(pattern, N)
    plan = sched.plan(block_q, block_k)
    out_dtype = q.dtype

    # --- data reordering (dilation) ------------------------------------ #
    if sched.reordered:
        perm = jnp.asarray(sched.perm)
        take = jnp.clip(perm, 0, N - 1)
        pad_valid = (perm < N)[None, :, None]
        qw = jnp.where(pad_valid, jnp.take(q, take, axis=1), 0)
        kw = jnp.where(pad_valid, jnp.take(k, take, axis=1), 0)
        vw = jnp.where(pad_valid, jnp.take(v, take, axis=1), 0)
    else:
        qw, kw, vw = q, k, v

    # --- sequence splitting: pad to the plan's tile grid ----------------- #
    pad = plan.n_pad - qw.shape[1]
    if pad:
        qw = jnp.pad(qw, ((0, 0), (0, pad), (0, 0)))
        kw = jnp.pad(kw, ((0, 0), (0, pad), (0, 0)))
        vw = jnp.pad(vw, ((0, 0), (0, pad), (0, 0)))
    pos = jnp.asarray(plan.positions_padded())

    nq = plan.nq
    q_blk = qw.reshape(B, nq, block_q, D)

    state = renorm.empty_state((B, nq, block_q), D)
    state = _plan_partial(state, q_blk, kw, vw, pos, plan, scale)

    if return_state:
        return state

    out = renorm.finalize(state, out_dtype).reshape(B, plan.n_pad, D)

    # --- undo reordering / padding -------------------------------------- #
    if sched.reordered:
        inv = jnp.asarray(sched.inverse_perm())
        out = jnp.take(out, inv, axis=1)
    else:
        out = out[:, :N]

    # --- global rows (paper's global PE row) ----------------------------- #
    if sched.n_global > 0 and sched.global_rows:
        rows = _global_rows(q, k, v, sched, scale, out_dtype)
        out = out.at[:, : sched.n_global].set(rows)
    return out


# ---------------------------------------------------------------------- #
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     t: jax.Array, pattern: HybridSparsePattern, *,
                     scale: Optional[float] = None,
                     cache_positions: Optional[jax.Array] = None) -> jax.Array:
    """One-token decode against a KV cache (serve_step path).

    q: (B, 1, D); caches: (B, S, D); ``t`` = current absolute position
    (scalar int). ``cache_positions``: (S,) absolute position of each cache
    slot (defaults to arange — the dense baseline cache); a SALO ring cache
    passes its slot->position map here and everything still works because
    masks are position-based.
    """
    B, S, D = k_cache.shape
    scale = (D ** -0.5) if scale is None else scale
    pos_k = (jnp.arange(S, dtype=jnp.int32) if cache_positions is None
             else cache_positions.astype(jnp.int32))
    pos_i = jnp.asarray(t, jnp.int32)

    p = pattern
    a, b = p.window
    rel = pos_k - pos_i
    m = (rel >= a) & (rel <= b)
    if p.dilation > 1:
        m = m & (rel % p.dilation == 0)
    if p.n_global > 0:
        m = m | (pos_k < p.n_global)
    m = m & (pos_k <= pos_i)  # decode is causal by construction
    scores = _dot(q, k_cache) * scale            # (B, 1, S)
    scores = jnp.where(m[None, None, :], scores, renorm.NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqs,bsd->bqd", w,
                      v_cache.astype(w.dtype)).astype(q.dtype)
