"""Banded blockwise attention in pure JAX — SALO's schedule on XLA.

This is the *algorithmic twin* of the Pallas kernel: identical band walk,
identical masks, identical renormalized merge. It exists because

1. training needs autodiff (everything here is differentiable jnp),
2. the CPU-only dry-run must lower something honest for roofline analysis
   (Pallas TPU kernels cannot be lowered by the CPU backend).

Shapes: q, k, v are ``(B, N, D)`` where ``B`` folds batch*heads. The public
model-facing API lives in :mod:`repro.core.attention`.

Complexity per band: O(N * (band_width + 2*block) * D) — linear in N, the
paper's claim.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import renorm
from repro.core.scheduler import BIG, Band, BandSchedule, _round_up, schedule
from repro.core.patterns import HybridSparsePattern


def _dot(a, b):
    return jnp.einsum("...qd,...kd->...qk", a, b,
                      preferred_element_type=jnp.float32)


def _band_partial(state: renorm.PartialState, q_blk, k_pad, v_pad, pos_pad,
                  sched: BandSchedule, band: Band, block_q: int, block_k: int,
                  scale: float) -> renorm.PartialState:
    """Fold one band into the running partial state.

    q_blk: (B, nq, Bq, D); k_pad/v_pad: (B, n_pad, D); pos_pad: (n_pad,).
    state: PartialState over (B, nq, Bq).

    Fast path (Bq == Bk): the KV tile index for query block i at band step s
    is ``i + lo//Bk + s`` — a CONSTANT shift per step — so the banded walk is
    a sliced view of the padded KV stream, not a gather. No per-block index
    materialization; XLA fuses the slice into the matmul operand
    (EXPERIMENTS.md §Perf gemma/prefill_32k).
    """
    B, nq, Bq, D = q_blk.shape
    n_pad = k_pad.shape[1]
    nkb = n_pad // block_k
    pos_q = pos_pad.reshape(nq, Bq)
    steps = band.kv_steps(Bq, block_k)

    # Working-space indices: restrict each pair to ITS band so overlapping
    # tile walks of different bands (ViL's 15 bands) never double count.
    wq = (jnp.arange(nq) * Bq)[:, None] + jnp.arange(Bq)[None, :]  # (nq, Bq)

    def masked_update(st, scores, v_blk, blk, pos_k):
        mask = sched.window_mask(pos_q[:, :, None], pos_k[:, None, :])
        rel_w = (blk[:, None] * block_k + jnp.arange(block_k)[None, :]
                 )[:, None, :] - wq[:, :, None]   # (nq, Bq, Bk) working rel
        mask = mask & (rel_w >= band.lo) & (rel_w <= band.hi)
        return renorm.update(st, scores, v_blk, mask[None])

    if Bq == block_k:
        import math as _math
        c0 = _math.floor(band.lo / block_k)
        c1 = c0 + steps - 1
        lpad = max(0, -c0) * block_k
        rpad = max(0, c1) * block_k
        k_w = jnp.pad(k_pad, ((0, 0), (lpad, rpad), (0, 0)))
        v_w = jnp.pad(v_pad, ((0, 0), (lpad, rpad), (0, 0)))
        pos_w = jnp.pad(pos_pad, (lpad, rpad), constant_values=BIG)

        def body(carry, s):
            st = carry
            start = (c0 + s) * block_k + lpad     # >= 0 by construction
            k_blk = jax.lax.dynamic_slice_in_dim(
                k_w, start, n_pad, axis=1).reshape(B, nq, block_k, D)
            v_blk = jax.lax.dynamic_slice_in_dim(
                v_w, start, n_pad, axis=1).reshape(B, nq, block_k, D)
            pos_k = jax.lax.dynamic_slice_in_dim(
                pos_w, start, n_pad).reshape(nq, block_k)
            scores = _dot(q_blk, k_blk) * scale
            blk = jnp.arange(nq, dtype=jnp.int32) + (c0 + s)
            return masked_update(st, scores, v_blk, blk, pos_k), ()
    else:
        k_r = k_pad.reshape(B, nkb, block_k, D)
        v_r = v_pad.reshape(B, nkb, block_k, D)
        pos_r = pos_pad.reshape(nkb, block_k)
        s0 = np.array([band.kv_start_block(i, Bq, block_k)
                       for i in range(nq)])
        s0 = jnp.asarray(s0, jnp.int32)

        def body(carry, s):
            st = carry
            blk = s0 + s                          # (nq,) signed tile index
            ok = (blk >= 0) & (blk < nkb)         # window-split validity
            blk_c = jnp.clip(blk, 0, nkb - 1)
            k_blk = jnp.take(k_r, blk_c, axis=1)  # (B, nq, Bk, D)
            v_blk = jnp.take(v_r, blk_c, axis=1)
            pos_k = jnp.take(pos_r, blk_c, axis=0)
            pos_k = jnp.where(ok[:, None], pos_k, BIG)  # clamped dup guard
            scores = _dot(q_blk, k_blk) * scale
            return masked_update(st, scores, v_blk, blk, pos_k), ()

    state, _ = jax.lax.scan(body, state, jnp.arange(steps, dtype=jnp.int32))
    return state


def _global_col_partial(state, q_blk, k_orig, v_orig, pos_pad, sched,
                        block_k: int, scale: float):
    """Global-column pass: every query vs. the first n_global ORIGINAL keys.

    Mirrors SALO's global PE column tapping the un-reordered stream."""
    B, nq, Bq, D = q_blk.shape
    g = sched.n_global
    gp = min(_round_up(max(g, 1), min(block_k, 128)), k_orig.shape[1])
    kg = k_orig[:, :gp]
    vg = v_orig[:, :gp]
    pos_g = jnp.arange(gp, dtype=jnp.int32)
    pos_q = pos_pad.reshape(nq, Bq)
    scores = _dot(q_blk, kg[:, None]) * scale     # (B, nq, Bq, gp)
    mask = sched.global_col_mask(pos_q[None, :, :, None],
                                 pos_g[None, None, None, :])
    mask = mask & (pos_g < g)[None, None, None, :]
    return renorm.update(state, scores, vg[:, None], mask)


def _global_rows(q_orig, k_orig, v_orig, sched, scale: float, out_dtype):
    """Global-row pass: the first n_global queries attend ALL keys (original
    order) — SALO's global PE row. Returns (B, g, D)."""
    g = sched.n_global
    n = sched.n
    qg = q_orig[:, :g]
    scores = _dot(qg, k_orig[:, :n]) * scale      # (B, g, n)
    if sched.causal:
        mask = (jnp.arange(n)[None, :] <= jnp.arange(g)[:, None])[None]
        scores = jnp.where(mask, scores, renorm.NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("...qk,...kd->...qd", p,
                      v_orig[:, :n].astype(p.dtype)).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("pattern", "block_q", "block_k",
                                             "return_state"))
def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                        pattern: HybridSparsePattern, *,
                        block_q: int = 128, block_k: int = 128,
                        scale: Optional[float] = None,
                        return_state: bool = False):
    """Hybrid sparse attention via the SALO band schedule. q,k,v: (B, N, D)."""
    B, N, D = q.shape
    scale = (D ** -0.5) if scale is None else scale
    sched = schedule(pattern, N)
    out_dtype = q.dtype

    # --- data reordering (dilation) ------------------------------------ #
    if sched.reordered:
        perm = jnp.asarray(sched.perm)
        take = jnp.clip(perm, 0, N - 1)
        pad_valid = (perm < N)[None, :, None]
        qw = jnp.where(pad_valid, jnp.take(q, take, axis=1), 0)
        kw = jnp.where(pad_valid, jnp.take(k, take, axis=1), 0)
        vw = jnp.where(pad_valid, jnp.take(v, take, axis=1), 0)
    else:
        qw, kw, vw = q, k, v

    # --- sequence splitting: pad to tile grid --------------------------- #
    n_pad = _round_up(sched.n_work, max(block_q, block_k))
    pad = n_pad - qw.shape[1]
    if pad:
        qw = jnp.pad(qw, ((0, 0), (0, pad), (0, 0)))
        kw = jnp.pad(kw, ((0, 0), (0, pad), (0, 0)))
        vw = jnp.pad(vw, ((0, 0), (0, pad), (0, 0)))
    pos = np.full(n_pad, BIG, dtype=np.int32)
    pos[: sched.n_work] = sched.positions()
    pos = jnp.asarray(pos)

    nq = n_pad // block_q
    q_blk = qw.reshape(B, nq, block_q, D)

    state = renorm.empty_state((B, nq, block_q), D)
    for band in sched.bands:  # static unroll; ViL has 15, most LMs 1
        state = _band_partial(state, q_blk, kw, vw, pos, sched, band,
                              block_q, block_k, scale)
    if sched.n_global > 0:
        state = _global_col_partial(state, q_blk, k, v, pos, sched,
                                    block_k, scale)

    if return_state:
        return state

    out = renorm.finalize(state, out_dtype).reshape(B, n_pad, D)

    # --- undo reordering / padding -------------------------------------- #
    if sched.reordered:
        inv = jnp.asarray(sched.inverse_perm())
        out = jnp.take(out, inv, axis=1)
    else:
        out = out[:, :N]

    # --- global rows (paper's global PE row) ----------------------------- #
    if sched.n_global > 0 and sched.global_rows:
        rows = _global_rows(q, k, v, sched, scale, out_dtype)
        out = out.at[:, : sched.n_global].set(rows)
    return out


# ---------------------------------------------------------------------- #
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     t: jax.Array, pattern: HybridSparsePattern, *,
                     scale: Optional[float] = None,
                     cache_positions: Optional[jax.Array] = None) -> jax.Array:
    """One-token decode against a KV cache (serve_step path).

    q: (B, 1, D); caches: (B, S, D); ``t`` = current absolute position
    (scalar int). ``cache_positions``: (S,) absolute position of each cache
    slot (defaults to arange — the dense baseline cache); a SALO ring cache
    passes its slot->position map here and everything still works because
    masks are position-based.
    """
    B, S, D = k_cache.shape
    scale = (D ** -0.5) if scale is None else scale
    pos_k = (jnp.arange(S, dtype=jnp.int32) if cache_positions is None
             else cache_positions.astype(jnp.int32))
    pos_i = jnp.asarray(t, jnp.int32)

    p = pattern
    a, b = p.window
    rel = pos_k - pos_i
    m = (rel >= a) & (rel <= b)
    if p.dilation > 1:
        m = m & (rel % p.dilation == 0)
    if p.n_global > 0:
        m = m | (pos_k < p.n_global)
    m = m & (pos_k <= pos_i)  # decode is causal by construction
    scores = _dot(q, k_cache) * scale            # (B, 1, S)
    scores = jnp.where(m[None, None, :], scores, renorm.NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqs,bsd->bqd", w,
                      v_cache.astype(w.dtype)).astype(q.dtype)
