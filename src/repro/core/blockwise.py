"""Plan-driven blockwise attention in pure JAX — SALO's schedule on XLA.

This is the *algorithmic twin* of the Pallas kernel: it walks the SAME
:class:`repro.core.scheduler.ExecutionPlan` step tables with the SAME
per-step masks (``plan.step_mask``), folded through the same renormalized
online-softmax state. It exists because

1. training needs a CPU/XLA path (the backward here is the plan-driven
   custom VJP below, not autodiff through the scan),
2. the CPU-only dry-run must lower something honest for roofline analysis
   (Pallas TPU kernels cannot be lowered by the CPU backend).

One ``lax.scan`` over ``plan.max_steps`` executes every band AND the global
column — overlapping KV tiles deduplicated to one visit, no per-band passes,
no separate global partial. Global rows (global queries attend everything)
are a dense g-row epilogue shared with the kernel wrapper.

**Backward contract (shared with kernels/ops.py).** Both engines save the
forward's already-computed partial triple ``(out, m, l)`` as residuals and
recompute the attention probabilities ``p = exp(s - m) / l`` tile-by-tile in
the backward — flash-style, no O(n^2) residuals, no forward re-run. The dQ
pass replays the forward tables; the dK/dV pass walks
``plan.transposed()`` (the exact adjoint regrouping of the same
deduplicated visits). :func:`plan_backward` owns the host-step adjoints
(global-rows epilogue, reorder, pad, the ``delta = sum(dout * out)``
precompute) and is parameterized over the two gradient passes, so the
Pallas kernels (kernels/salo_backward.py) and the scan engines here
(:func:`bwd_dq_scan`, :func:`bwd_dkv_scan`) execute ONE contract.

Shapes: q, k, v are ``(B, N, D)`` where ``B`` folds batch*heads. The public
model-facing API lives in :mod:`repro.core.attention`.

Complexity: O(N * deduped_tiles_per_block * block_k * D) — linear in N for
banded patterns, the paper's claim, and strictly fewer tiles than the
per-band walk whenever bands overlap (ViL).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import renorm
from repro.core.scheduler import (BIG, STEP_GLOBAL, STEP_WINDOW,
                                  BandSchedule, ExecutionPlan, _round_up,
                                  causal_step_mask, schedule)
from repro.core.patterns import HybridSparsePattern


def _dot(a, b):
    return jnp.einsum("...qd,...kd->...qk", a, b,
                      preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------- #
# Working-stream host steps (shared by both engines, forward AND backward)
# ---------------------------------------------------------------------- #
def working_stream(x: jax.Array, sched: BandSchedule,
                   plan: ExecutionPlan) -> jax.Array:
    """Original order -> working layout: dilation reorder + pad to n_pad.

    ``x``: (B, N, ...) along axis 1. The reorder is a permutation, so this
    transform is also the ADJOINT of the forward's output un-reordering —
    the same function maps inputs forward and output-cotangents backward.
    """
    N = x.shape[1]
    if sched.reordered:
        perm = jnp.asarray(sched.perm)
        take = jnp.clip(perm, 0, N - 1)
        valid = (perm < N).reshape((1, -1) + (1,) * (x.ndim - 2))
        x = jnp.where(valid, jnp.take(x, take, axis=1), 0)
    pad = plan.n_pad - x.shape[1]
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
    return x


def undo_working(x_w: jax.Array, sched: BandSchedule, n: int) -> jax.Array:
    """Working layout -> original order (inverse of :func:`working_stream`)."""
    if sched.reordered:
        return jnp.take(x_w, jnp.asarray(sched.inverse_perm()), axis=1)
    return x_w[:, :n]


def _plan_partial(state: renorm.PartialState, q_blk, k_pad, v_pad, pos_pad,
                  plan: ExecutionPlan, scale: float) -> renorm.PartialState:
    """Fold the WHOLE plan (all bands + global column) into the state.

    q_blk: (B, nq, Bq, D); k_pad/v_pad: (B, n_pad, D); pos_pad: (n_pad,).
    state: PartialState over (B, nq, Bq). One scan step = one table column:
    every query block gathers its step-``s`` KV tile and applies the
    flag-gated union mask. Padding steps (flags == 0) mask to nothing.
    """
    B, nq, Bq, D = q_blk.shape
    bk = plan.block_k
    nkb = plan.nkb
    pos_q = pos_pad.reshape(nq, Bq)

    # Fast path (single band, no global, Bq == Bk): the plan's tile walk is
    # the affine shift ``i + c0 + s`` — a CONSTANT shift per step — so the
    # banded walk is a sliced view of the padded KV stream, not a gather.
    # No per-block index materialization; XLA fuses the slice into the
    # matmul operand (measured on the gemma/prefill_32k dry-run cell;
    # see benchmarks/roofline_report.py). Out-of-range
    # tiles carry PAD_SENTINEL positions and mask to nothing.
    sched = plan.sched
    if len(sched.bands) == 1 and sched.n_global == 0 and Bq == bk:
        import math as _math
        band = sched.bands[0]
        steps = band.kv_steps(Bq, bk)
        c0 = _math.floor(band.lo / bk)
        c1 = c0 + steps - 1
        lpad = max(0, -c0) * bk
        rpad = max(0, c1) * bk
        n_pad = k_pad.shape[1]
        k_w = jnp.pad(k_pad, ((0, 0), (lpad, rpad), (0, 0)))
        v_w = jnp.pad(v_pad, ((0, 0), (lpad, rpad), (0, 0)))
        pos_w = jnp.pad(pos_pad, (lpad, rpad), constant_values=BIG)

        def body(st, s):
            start = (c0 + s) * bk + lpad     # >= 0 by construction
            k_blk = jax.lax.dynamic_slice_in_dim(
                k_w, start, n_pad, axis=1).reshape(B, nq, bk, D)
            v_blk = jax.lax.dynamic_slice_in_dim(
                v_w, start, n_pad, axis=1).reshape(B, nq, bk, D)
            pos_k = jax.lax.dynamic_slice_in_dim(
                pos_w, start, n_pad).reshape(nq, bk)
            scores = _dot(q_blk, k_blk) * scale
            mask = plan.step_mask(pos_q[:, :, None], pos_k[:, None, :],
                                  STEP_WINDOW)
            return renorm.update(st, scores, v_blk, mask[None]), ()

        state, _ = jax.lax.scan(body, state,
                                jnp.arange(steps, dtype=jnp.int32))
        return state

    # General path: gather each step's KV tile by the plan table — the
    # same scan body as table_attention_scan (ONE copy, _table_fold).
    return _table_fold(state, q_blk, k_pad.reshape(B, nkb, bk, D),
                       v_pad.reshape(B, nkb, bk, D), pos_q,
                       pos_pad.reshape(nkb, bk),
                       jnp.asarray(plan.kv_blocks),
                       jnp.asarray(plan.flags), plan.sched, scale)


def _table_fold(state, q_blk, k_r, v_r, pos_q, pos_k, kv_blocks, flags,
                sched: BandSchedule, scale: float):
    """Fold step tables into a renorm state: one lax.scan over the table
    width, gathering each step's KV tile — THE table walk shared by the
    plan-driven general path and the (sharded) table-driven entry point.

    q_blk: (B, nq, Bq, D); k_r/v_r: (B, nkb, Bk, D); pos_q: (nq, Bq);
    pos_k: (nkb, Bk); kv_blocks/flags: (nq, W) — table values may be
    traced (per-device slices under shard_map).
    """
    def body(st, s):
        blk = jax.lax.dynamic_index_in_dim(kv_blocks, s, axis=1,
                                           keepdims=False)      # (nq,)
        fl = jax.lax.dynamic_index_in_dim(flags, s, axis=1,
                                          keepdims=False)       # (nq,)
        k_blk = jnp.take(k_r, blk, axis=1)                      # (B,nq,Bk,D)
        v_blk = jnp.take(v_r, blk, axis=1)
        pos_kb = jnp.take(pos_k, blk, axis=0)                   # (nq, Bk)
        scores = _dot(q_blk, k_blk) * scale
        mask = sched.step_mask(pos_q[:, :, None], pos_kb[:, None, :],
                               fl[:, None, None])
        return renorm.update(st, scores, v_blk, mask[None]), ()

    state, _ = jax.lax.scan(body, state,
                            jnp.arange(kv_blocks.shape[1], dtype=jnp.int32))
    return state


def table_attention_scan(q, k, v, pos_q, pos_k, kv_blocks, flags,
                         sched: BandSchedule, scale: float):
    """Generic table-driven forward on XLA: one ``lax.scan`` over step
    tables whose *values* may be traced (the sharded per-device tables are
    selected by ``axis_index`` at run time) and whose q/KV sides may have
    different lengths (the sharded local view).

    q: (B, nq*Bq, D); k/v: (B, nkb*Bk, D); pos_q: (nq, Bq); pos_k:
    (nkb, Bk) ORIGINAL positions; kv_blocks/flags: (nq, W). Returns the
    normalized partial triple ``(out, m, l)`` — the same contract as
    :func:`repro.kernels.salo_attention.salo_plan_attention`.
    """
    B, nQ, D = q.shape
    nq, _W = kv_blocks.shape
    bq = nQ // nq
    nkb, bk = pos_k.shape
    st = renorm.empty_state((B, nq, bq), D)
    st = _table_fold(st, q.reshape(B, nq, bq, D),
                     k.reshape(B, nkb, bk, D), v.reshape(B, nkb, bk, D),
                     pos_q, pos_k, kv_blocks, flags, sched, scale)
    out = renorm.finalize(st, q.dtype).reshape(B, nQ, D)
    return out, st.m.reshape(B, nQ), st.l.reshape(B, nQ)


def _global_rows(q_orig, k_orig, v_orig, sched: BandSchedule, scale: float,
                 out_dtype):
    """Global-row pass: the first n_global queries attend ALL keys (original
    order) — SALO's global PE row. Returns (B, g, D)."""
    g = sched.n_global
    n = sched.n
    qg = q_orig[:, :g]
    scores = _dot(qg, k_orig[:, :n]) * scale      # (B, g, n)
    if sched.causal:
        mask = (jnp.arange(n)[None, :] <= jnp.arange(g)[:, None])[None]
        scores = jnp.where(mask, scores, renorm.NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("...qk,...kd->...qd", p,
                      v_orig[:, :n].astype(p.dtype)).astype(out_dtype)


def _blockwise_forward(q, k, v, pattern, block_q, block_k, scale,
                       return_state=False):
    """Plan walk + host steps. Returns ``(out, (out_w, m, l))`` — the
    working-space partial triple doubles as the backward's residuals —
    or the raw PartialState when ``return_state``."""
    B, N, D = q.shape
    scale = (D ** -0.5) if scale is None else scale
    sched = schedule(pattern, N)
    plan = sched.plan(block_q, block_k)
    out_dtype = q.dtype

    # --- data reordering (dilation) + padding to the tile grid ---------- #
    qw = working_stream(q, sched, plan)
    kw = working_stream(k, sched, plan)
    vw = working_stream(v, sched, plan)
    pos = jnp.asarray(plan.positions_padded())

    nq = plan.nq
    q_blk = qw.reshape(B, nq, block_q, D)

    state = renorm.empty_state((B, nq, block_q), D)
    state = _plan_partial(state, q_blk, kw, vw, pos, plan, scale)

    if return_state:
        return state

    out_w = renorm.finalize(state, out_dtype).reshape(B, plan.n_pad, D)
    m = state.m.reshape(B, plan.n_pad)
    l = state.l.reshape(B, plan.n_pad)

    # --- undo reordering / padding -------------------------------------- #
    out = undo_working(out_w, sched, N)

    # --- global rows (paper's global PE row) ----------------------------- #
    if sched.n_global > 0 and sched.global_rows:
        rows = _global_rows(q, k, v, sched, scale, out_dtype)
        out = out.at[:, : sched.n_global].set(rows)
    return out, (out_w, m, l)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _blockwise(q, k, v, pattern, block_q, block_k, scale):
    out, _ = _blockwise_forward(q, k, v, pattern, block_q, block_k, scale)
    return out


def _blockwise_fwd(q, k, v, pattern, block_q, block_k, scale):
    out, (out_w, m, l) = _blockwise_forward(q, k, v, pattern, block_q,
                                            block_k, scale)
    return out, (q, k, v, out_w, m, l)


def _blockwise_bwd(pattern, block_q, block_k, scale, res, g):
    q, k, v, out_w, m, l = res
    B, N, D = q.shape
    scale_ = (D ** -0.5) if scale is None else scale
    plan = schedule(pattern, N).plan(block_q, block_k)
    return plan_backward(
        g, q, k, v, out_w, m, l, plan, scale_,
        functools.partial(bwd_dq_scan, plan=plan, scale=scale_),
        functools.partial(bwd_dkv_scan, plan=plan, scale=scale_))


_blockwise.defvjp(_blockwise_fwd, _blockwise_bwd)


@functools.partial(jax.jit, static_argnames=("pattern", "block_q", "block_k",
                                             "scale", "return_state"))
def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                        pattern: HybridSparsePattern, *,
                        block_q: int = 128, block_k: int = 128,
                        scale: Optional[float] = None,
                        return_state: bool = False):
    """Hybrid sparse attention via the SALO ExecutionPlan. q,k,v: (B, N, D).

    Differentiating through this uses the plan-driven custom VJP (dQ over
    the forward tables, dK/dV over the transposed tables, ``p`` recomputed
    from the saved ``(out, m, l)``) — NOT autodiff through the scan, which
    would re-run the forward sequentially and materialize per-step
    residuals. ``return_state=True`` returns the raw PartialState (for
    cross-device merges) and bypasses the custom VJP.
    """
    if return_state:
        return _blockwise_forward(q, k, v, pattern, block_q, block_k, scale,
                                  return_state=True)
    return _blockwise(q, k, v, pattern, block_q, block_k, scale)


# ---------------------------------------------------------------------- #
# The backward contract: shared host steps + the XLA gradient engines
# ---------------------------------------------------------------------- #
def p_from_stats(scores, mask, m, l):
    """Recompute normalized attention probabilities from saved row stats:
    ``p = exp(s - m) / l`` where ``s`` is the masked scaled score.

    Empty rows — every step masked; the forward emitted ``(out=0,
    m=NEG_INF, l=0)``, see :class:`repro.core.renorm.PartialState` — take
    the guarded branch (shift 0, l 1) and end at exactly ``p == 0`` via the
    mask, so their gradients vanish identically in every engine.
    """
    l_safe = jnp.where(l == 0.0, 1.0, l)
    shift = jnp.where(m <= renorm.NEG_INF / 2, 0.0, m)
    p = jnp.exp(scores - shift[..., None]) / l_safe[..., None]
    return jnp.where(mask, p, 0.0)


def table_dq_scan(dout, delta, m, l, q, k, v, pos_q, pos_k, kv_blocks,
                  flags, sched: BandSchedule, scale: float) -> jax.Array:
    """dQ pass: one scan over (possibly dynamic) FORWARD step tables.

    ds = p * (dout.v - delta);  dq_i += scale * sum_j ds_ij k_j

    Generic over table *values* and over asymmetric q/KV lengths (the
    sharded local view streams ``nkb_view`` tiles past ``nq_local`` query
    blocks): q-side arrays (dout/delta/m/l/q) are (B, nq*Bq, ...), KV-side
    (k/v) are (B, nkb*Bk, D); pos_q: (nq, Bq); pos_k: (nkb, Bk);
    kv_blocks/flags: (nq, W). Returns (B, nq*Bq, D) f32.
    """
    B, nQ, D = q.shape
    nq, W = kv_blocks.shape
    bq = nQ // nq
    nkb, bk = pos_k.shape
    q_blk = q.reshape(B, nq, bq, D)
    do_blk = dout.reshape(B, nq, bq, D)
    m_blk = m.reshape(B, nq, bq)
    l_blk = l.reshape(B, nq, bq)
    dl_blk = delta.reshape(B, nq, bq)
    k_r = k.reshape(B, nkb, bk, D)
    v_r = v.reshape(B, nkb, bk, D)

    def body(dq, s):
        blk = jax.lax.dynamic_index_in_dim(kv_blocks, s, 1, keepdims=False)
        fl = jax.lax.dynamic_index_in_dim(flags, s, 1, keepdims=False)
        k_b = jnp.take(k_r, blk, axis=1)                       # (B,nq,Bk,D)
        v_b = jnp.take(v_r, blk, axis=1)
        pos_kb = jnp.take(pos_k, blk, axis=0)                  # (nq, Bk)
        scores = _dot(q_blk, k_b) * scale
        mask = sched.step_mask(pos_q[:, :, None], pos_kb[:, None, :],
                               fl[:, None, None])[None]
        p = p_from_stats(scores, mask, m_blk, l_blk)
        ds = p * (_dot(do_blk, v_b) - dl_blk[..., None])
        dq = dq + jnp.einsum("bnqk,bnkd->bnqd", ds,
                             k_b.astype(jnp.float32)) * scale
        return dq, ()

    dq0 = jnp.zeros((B, nq, bq, D), jnp.float32)
    dq, _ = jax.lax.scan(body, dq0, jnp.arange(W, dtype=jnp.int32))
    return dq.reshape(B, nQ, D)


def bwd_dq_scan(dout, delta, m, l, qw, kw, vw, pos, *,
                plan: ExecutionPlan, scale: float) -> jax.Array:
    """Plan-driven dQ (the single-device engine): replay the forward
    tables. All arrays working-space padded; returns (B, n_pad, D) f32."""
    pos_q = pos.reshape(plan.nq, plan.block_q)
    pos_k = pos.reshape(plan.nkb, plan.block_k)
    return table_dq_scan(dout, delta, m, l, qw, kw, vw, pos_q, pos_k,
                         jnp.asarray(plan.kv_blocks),
                         jnp.asarray(plan.flags), plan.sched, scale)


def table_dkv_scan(dout, delta, m, l, q, k, v, pos_q, pos_k, row_tile,
                   q_blocks, flags, sched: BandSchedule, scale: float):
    """dK/dV pass over PACKED transposed tables: each packed row keeps its
    owner KV tile (``row_tile``) resident while its slice of visiting query
    blocks streams past; per-row partials are scatter-added per owner tile
    (rows split from one ragged transposed row recombine here).

    dv_j += sum_i p_ij dout_i;  dk_j += scale * sum_i ds_ij q_i

    Shapes as :func:`table_dq_scan`, plus row_tile: (R,), q_blocks/flags:
    (R, W). Returns ``(dk, dv)`` both (B, nkb*Bk, D) f32.
    """
    B, nQ, D = q.shape
    nq, bq = pos_q.shape
    nkb, bk = pos_k.shape
    R, W = q_blocks.shape
    q_r = q.reshape(B, nq, bq, D)
    do_r = dout.reshape(B, nq, bq, D)
    m_r = m.reshape(B, nq, bq)
    l_r = l.reshape(B, nq, bq)
    dl_r = delta.reshape(B, nq, bq)
    k_rt = jnp.take(k.reshape(B, nkb, bk, D), row_tile, axis=1)  # (B,R,Bk,D)
    v_rt = jnp.take(v.reshape(B, nkb, bk, D), row_tile, axis=1)
    pos_kr = jnp.take(pos_k, row_tile, axis=0)                   # (R, Bk)

    def body(carry, s):
        dk, dv = carry
        qb = jax.lax.dynamic_index_in_dim(q_blocks, s, 1, keepdims=False)
        fl = jax.lax.dynamic_index_in_dim(flags, s, 1, keepdims=False)
        q_b = jnp.take(q_r, qb, axis=1)                        # (B,R,Bq,D)
        do_b = jnp.take(do_r, qb, axis=1)
        m_b = jnp.take(m_r, qb, axis=1)
        l_b = jnp.take(l_r, qb, axis=1)
        dl_b = jnp.take(dl_r, qb, axis=1)
        pos_qb = jnp.take(pos_q, qb, axis=0)                   # (R, Bq)
        scores = _dot(q_b, k_rt) * scale
        mask = sched.step_mask(pos_qb[:, :, None], pos_kr[:, None, :],
                               fl[:, None, None])[None]
        p = p_from_stats(scores, mask, m_b, l_b)
        ds = p * (_dot(do_b, v_rt) - dl_b[..., None])
        dv = dv + jnp.einsum("bnqk,bnqd->bnkd", p, do_b)
        dk = dk + jnp.einsum("bnqk,bnqd->bnkd", ds,
                             q_b.astype(jnp.float32)) * scale
        return (dk, dv), ()

    z = jnp.zeros((B, R, bk, D), jnp.float32)
    (dk_r, dv_r), _ = jax.lax.scan(body, (z, z),
                                   jnp.arange(W, dtype=jnp.int32))
    zt = jnp.zeros((B, nkb, bk, D), jnp.float32)
    dk = zt.at[:, row_tile].add(dk_r).reshape(B, nkb * bk, D)
    dv = zt.at[:, row_tile].add(dv_r).reshape(B, nkb * bk, D)
    return dk, dv


def table_dkv_scatter_scan(dout, delta, m, l, q, k, v, pos_q, pos_k,
                           kv_blocks, flags, sched: BandSchedule,
                           scale: float):
    """dK/dV pass over (possibly runtime-valued) FORWARD step tables.

    The static engines walk ``plan.transposed_packed()`` — a host-built
    regrouping that cannot exist for tables computed on device
    (:mod:`repro.core.dynamic`, per-shard dynamic selection). This twin
    walks the forward table width instead: at step ``s`` every query block
    computes its (dk, dv) contribution to its step-``s`` tile, scatter-added
    into the tile's slot (``.at[].add`` — duplicate tile indices across
    query blocks accumulate, the runtime mirror of the transposed
    regrouping). Same visits, same masks, same p recompute; padding steps
    (flags 0) mask to nothing and add zeros to tile 0.

    Shapes as :func:`table_dq_scan`. Returns ``(dk, dv)``
    (B, nkb*Bk, D) f32.
    """
    B, nQ, D = q.shape
    nq, W = kv_blocks.shape
    bq = nQ // nq
    nkb, bk = pos_k.shape
    q_blk = q.reshape(B, nq, bq, D)
    do_blk = dout.reshape(B, nq, bq, D)
    m_blk = m.reshape(B, nq, bq)
    l_blk = l.reshape(B, nq, bq)
    dl_blk = delta.reshape(B, nq, bq)
    k_r = k.reshape(B, nkb, bk, D)
    v_r = v.reshape(B, nkb, bk, D)

    def body(carry, s):
        dk, dv = carry
        blk = jax.lax.dynamic_index_in_dim(kv_blocks, s, 1, keepdims=False)
        fl = jax.lax.dynamic_index_in_dim(flags, s, 1, keepdims=False)
        k_b = jnp.take(k_r, blk, axis=1)                       # (B,nq,Bk,D)
        v_b = jnp.take(v_r, blk, axis=1)
        pos_kb = jnp.take(pos_k, blk, axis=0)                  # (nq, Bk)
        scores = _dot(q_blk, k_b) * scale
        mask = sched.step_mask(pos_q[:, :, None], pos_kb[:, None, :],
                               fl[:, None, None])[None]
        p = p_from_stats(scores, mask, m_blk, l_blk)
        ds = p * (_dot(do_blk, v_b) - dl_blk[..., None])
        dv = dv.at[:, blk].add(jnp.einsum("bnqk,bnqd->bnkd", p, do_blk))
        dk = dk.at[:, blk].add(jnp.einsum("bnqk,bnqd->bnkd", ds,
                                          q_blk.astype(jnp.float32)) * scale)
        return (dk, dv), ()

    z = jnp.zeros((B, nkb, bk, D), jnp.float32)
    (dk, dv), _ = jax.lax.scan(body, (z, z), jnp.arange(W, dtype=jnp.int32))
    return dk.reshape(B, nkb * bk, D), dv.reshape(B, nkb * bk, D)


def bwd_dkv_scan(dout, delta, m, l, qw, kw, vw, pos, *,
                 plan: ExecutionPlan, scale: float):
    """Plan-driven dK/dV (the single-device engine): walk
    ``plan.transposed_packed()`` — the exact adjoint regrouping of the
    forward's deduplicated visits, packed so global-column tiles' ragged
    rows don't inflate everyone's padding."""
    pk = plan.transposed_packed()
    pos_q = pos.reshape(plan.nq, plan.block_q)
    pos_k = pos.reshape(plan.nkb, plan.block_k)
    return table_dkv_scan(dout, delta, m, l, qw, kw, vw, pos_q, pos_k,
                          jnp.asarray(pk.row_tile),
                          jnp.asarray(pk.q_blocks), jnp.asarray(pk.flags),
                          plan.sched, scale)


def plan_backward(g, q, k, v, out_w, m, l, plan: ExecutionPlan, scale: float,
                  dq_engine, dkv_engine):
    """THE backward contract of both engines: host-step adjoints around two
    plan-walking gradient passes.

    ``kernels/ops.py`` passes the Pallas launchers (kernels/salo_backward),
    the blockwise custom VJP passes :func:`bwd_dq_scan`/:func:`bwd_dkv_scan`
    — everything else (global-rows epilogue VJP, cotangent reorder/pad, the
    ``delta`` precompute, gradient un-reordering) is this one code path.

    Engines take ``(dout, delta, m, l, qw, kw, vw, pos)`` in the padded
    working layout and return working-layout gradients.
    """
    sched = plan.sched
    B, N, D = q.shape
    # 1. Global-rows epilogue: the forward overwrote rows [:g] with the
    #    dense g-row pass on ORIGINAL-order tensors; its VJP is dense but
    #    tiny (g rows), and those rows' main-path cotangent is zeroed.
    if sched.n_global > 0 and sched.global_rows:
        ng = sched.n_global
        _, rows_vjp = jax.vjp(
            lambda q_, k_, v_: _global_rows(q_, k_, v_, sched, scale,
                                            g.dtype), q, k, v)
        dq_rows, dk_rows, dv_rows = rows_vjp(g[:, :ng])
        g = g.at[:, :ng].set(0)
    else:
        dq_rows = dk_rows = dv_rows = None
    # 2. The output reorder is a permutation: the cotangent takes the SAME
    #    working-stream transform as the inputs did.
    dout = working_stream(g, sched, plan).astype(jnp.float32)
    qw = working_stream(q, sched, plan)
    kw = working_stream(k, sched, plan)
    vw = working_stream(v, sched, plan)
    pos = jnp.asarray(plan.positions_padded())
    # 3. delta = rowwise dout . out — the flash-backward precompute.
    delta = jnp.sum(dout * out_w.astype(jnp.float32), axis=-1)
    # 4. The two plan walks.
    dq_w = dq_engine(dout, delta, m, l, qw, kw, vw, pos)
    dk_w, dv_w = dkv_engine(dout, delta, m, l, qw, kw, vw, pos)
    # 5. Back to original order (+ the epilogue contributions).
    dq = undo_working(dq_w, sched, N)
    dk = undo_working(dk_w, sched, N)
    dv = undo_working(dv_w, sched, N)
    if dq_rows is not None:
        dq = dq + dq_rows
        dk = dk + dk_rows
        dv = dv + dv_rows
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


# ---------------------------------------------------------------------- #
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     t: jax.Array, pattern: HybridSparsePattern, *,
                     scale: Optional[float] = None,
                     cache_positions: Optional[jax.Array] = None,
                     k_scale: Optional[jax.Array] = None,
                     v_scale: Optional[jax.Array] = None) -> jax.Array:
    """One-token decode against a KV cache (serve_step path) — RAGGED aware.

    q: (B, 1, D); caches: (B, S, D); ``t`` = current absolute position:
    a scalar (lockstep batch) OR a (B,) vector — one position per request,
    the continuous-batching decode twin. ``cache_positions``: (S,) or
    (B, S) absolute position per cache slot (defaults to arange — the dense
    baseline cache); ring/paged caches pass their slot->position maps here
    and everything still works because masks are position-based
    (``scheduler.causal_step_mask``).

    int8 caches pass per-slot dequant scales ``k_scale``/``v_scale``
    ((S,) or (B, S) f32 — a paged caller expands its per-page scales
    page->slots): slots are dequantized to ``q.dtype`` before the score
    matmul, mirroring the in-kernel dequant of the Pallas paged path.
    """
    B, S, D = k_cache.shape
    scale = (D ** -0.5) if scale is None else scale
    if k_scale is not None:
        sk = jnp.broadcast_to(jnp.asarray(k_scale, jnp.float32), (B, S))
        sv = jnp.broadcast_to(jnp.asarray(v_scale, jnp.float32), (B, S))
        k_cache = (k_cache.astype(jnp.float32)
                   * sk[..., None]).astype(q.dtype)
        v_cache = (v_cache.astype(jnp.float32)
                   * sv[..., None]).astype(q.dtype)
    pos_k = (jnp.arange(S, dtype=jnp.int32) if cache_positions is None
             else cache_positions.astype(jnp.int32))
    pos_k = jnp.broadcast_to(pos_k, (B, S))
    pos_i = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (B,))

    m = causal_step_mask(pattern, pos_i[:, None], pos_k,
                         STEP_WINDOW | STEP_GLOBAL)           # (B, S)
    scores = _dot(q, k_cache) * scale            # (B, 1, S)
    scores = jnp.where(m[:, None, :], scores, renorm.NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqs,bsd->bqd", w,
                      v_cache.astype(w.dtype)).astype(q.dtype)


def chunk_attention(q: jax.Array, k_view: jax.Array, v_view: jax.Array,
                    pos_q: jax.Array, pos_k: jax.Array,
                    kv_blocks: jax.Array, flags: jax.Array,
                    pattern: HybridSparsePattern, *,
                    scale: Optional[float] = None,
                    return_state: bool = False):
    """Plan-driven chunked-prefill attention: ONE table-driven pass.

    q: (B, Cp, D) chunk queries; k_view/v_view: (B, Vp, D) the request's
    paged KV view (sinks + ring) with the fresh chunk appended; pos_q:
    (B, Cp) and pos_k: (B, Vp) ORIGINAL positions (``BIG`` = empty/pad);
    kv_blocks/flags: (nq, W) ChunkPlan step tables (dynamic arrays — the
    same compiled step serves every chunk of a request). One ``lax.scan``
    over W table columns folds the whole causal hybrid pattern through the
    renormalized online softmax — the serving twin of ``_plan_partial``.

    ``return_state=True`` additionally returns the finalized partial triple
    ``(out, m, l)`` with m/l (B, Cp) — what a sequence shard feeds the
    cross-shard masked-psum merge (a chunk row whose every step is masked
    on this shard carries the ``(0, NEG_INF, 0)`` identity).
    """
    B, Cp, D = q.shape
    nq, W = kv_blocks.shape
    block = Cp // nq
    Vp = k_view.shape[1]
    nkb = Vp // block
    q_blk = q.reshape(B, nq, block, D)
    k_r = k_view.reshape(B, nkb, block, D)
    v_r = v_view.reshape(B, nkb, block, D)
    pos_qb = pos_q.reshape(B, nq, block)
    pos_kr = pos_k.reshape(B, nkb, block)
    scale_ = (D ** -0.5) if scale is None else scale

    def body(st, s):
        blk = jax.lax.dynamic_index_in_dim(kv_blocks, s, axis=1,
                                           keepdims=False)     # (nq,)
        fl = jax.lax.dynamic_index_in_dim(flags, s, axis=1,
                                          keepdims=False)      # (nq,)
        k_blk = jnp.take(k_r, blk, axis=1)                     # (B,nq,Bk,D)
        v_blk = jnp.take(v_r, blk, axis=1)
        pos_kb = jnp.take(pos_kr, blk, axis=1)                 # (B,nq,Bk)
        scores = _dot(q_blk, k_blk) * scale_
        mask = causal_step_mask(pattern, pos_qb[:, :, :, None],
                                pos_kb[:, :, None, :],
                                fl[None, :, None, None])
        return renorm.update(st, scores, v_blk, mask), ()

    state = renorm.empty_state((B, nq, block), D)
    state, _ = jax.lax.scan(body, state, jnp.arange(W, dtype=jnp.int32))
    if return_state:
        # f32 partial: the cross-shard merge rounds to the compute dtype
        # once, AFTER combining (single-device round-once numerics)
        return (renorm.finalize(state).reshape(B, Cp, D),
                state.m.reshape(B, Cp), state.l.reshape(B, Cp))
    return renorm.finalize(state, q.dtype).reshape(B, Cp, D)
