"""Hybrid sparse attention patterns (paper §2.3).

A pattern is the union of
  * a (possibly dilated) relative-offset window  a <= j - i <= b, (j-i) % d == 0
  * global tokens: a prefix of ``n_global`` tokens whose keys every query
    attends (global column) and whose queries attend every key (global row)
  * an optional causal constraint j <= i.

2-D patterns (ViL) are expressed on a flattened (H, W) grid: token i sits at
(i // W, i % W) and attends tokens within a (wh, ww) Chebyshev-box window.
The scheduler lowers 2-D windows into a union of 1-D bands (one per row
offset), exactly as SALO's data reordering does.

``mask()`` materializes the boolean attention mask — the oracle every other
implementation is tested against. O(n^2) memory; for tests and small shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class HybridSparsePattern:
    """Metadata the data scheduler receives (paper Fig. 3)."""

    # 1-D sliding/dilated window: relative offsets [a, b], stride `dilation`.
    window: Tuple[int, int] = (0, 0)
    dilation: int = 1
    # Leading `n_global` tokens are global.
    n_global: int = 0
    # Global rows: do global queries attend everything? (Longformer: yes.
    # StreamingLLM-style attention sinks: only the global *column* matters.)
    global_rows: bool = True
    # Causal masking on top of everything (LM decode/training).
    causal: bool = False
    # 2-D (ViL): grid (H, W) and window (wh, ww), both odd. Overrides `window`.
    grid2d: Optional[Tuple[int, int]] = None
    window2d: Optional[Tuple[int, int]] = None

    def __post_init__(self):
        a, b = self.window
        if a > b:
            raise ValueError(f"window lo {a} > hi {b}")
        if self.dilation < 1:
            raise ValueError("dilation must be >= 1")
        if (self.grid2d is None) != (self.window2d is None):
            raise ValueError("grid2d and window2d must be given together")
        if self.grid2d is not None:
            wh, ww = self.window2d
            if wh % 2 == 0 or ww % 2 == 0:
                raise ValueError("2-D windows must be odd-sized")
            if self.dilation != 1:
                raise ValueError("2-D windows do not compose with dilation")

    # ------------------------------------------------------------------ #
    @property
    def is_2d(self) -> bool:
        return self.grid2d is not None

    def seq_len(self) -> Optional[int]:
        """Implied sequence length for 2-D patterns (n_global + H*W)."""
        if self.is_2d:
            h, w = self.grid2d
            return self.n_global + h * w
        return None

    def window_size(self) -> int:
        a, b = self.window
        return (b - a) // self.dilation + 1

    # ------------------------------------------------------------------ #
    def mask(self, n: int, n_kv: Optional[int] = None) -> np.ndarray:
        """Dense boolean mask oracle, shape (n, n_kv). True = attend."""
        n_kv = n if n_kv is None else n_kv
        i = np.arange(n)[:, None]
        j = np.arange(n_kv)[None, :]
        g = self.n_global

        if self.is_2d:
            h, w = self.grid2d
            wh, ww = self.window2d
            if n != g + h * w or n_kv != g + h * w:
                raise ValueError(
                    f"2-D pattern implies n = {g + h * w}, got ({n}, {n_kv})")
            # Grid coordinates for non-global tokens (global tokens prepended).
            yi, xi = (i - g) // w, (i - g) % w
            yj, xj = (j - g) // w, (j - g) % w
            m = (np.abs(yj - yi) <= wh // 2) & (np.abs(xj - xi) <= ww // 2)
            m &= (i >= g) & (j >= g)
        else:
            a, b = self.window
            rel = j - i
            m = (rel >= a) & (rel <= b) & (rel % self.dilation == 0)

        # Global column: every query sees global keys.
        if g > 0:
            m = m | (j < g)
            # Global rows: global queries see every key.
            if self.global_rows:
                m = m | (i < g)
        if self.causal:
            m = m & (j <= i)
        return m

    def sparsity(self, n: int) -> float:
        """Fraction of attended entries (paper Table 2 'Sparsity')."""
        return float(self.mask(n).mean())


# ---------------------------------------------------------------------- #
# Pattern library — the paper's workloads plus the patterns the framework
# applies to the assigned LM architectures.
# ---------------------------------------------------------------------- #

def longformer(window_size: int = 512, n_global: int = 1,
               causal: bool = False) -> HybridSparsePattern:
    """Longformer-Base-4096 style: symmetric window + leading global tokens."""
    half = window_size // 2
    return HybridSparsePattern(window=(-half, half - 1 + window_size % 2),
                               n_global=n_global, causal=causal)


def causal_sliding_window(window_size: int, n_sinks: int = 0,
                          dilation: int = 1) -> HybridSparsePattern:
    """Causal LM pattern: attend the last `window_size` tokens (+ sinks).

    ``n_sinks`` leading global *keys* (StreamingLLM attention sinks) — the
    paper's global column with global_rows=False (row i<g is still causal).
    """
    return HybridSparsePattern(window=(-(window_size - 1) * dilation, 0),
                               dilation=dilation, n_global=n_sinks,
                               global_rows=False, causal=True)


def dilated_window(window_size: int, dilation: int,
                   causal: bool = False) -> HybridSparsePattern:
    half = window_size // 2
    return HybridSparsePattern(
        window=(-half * dilation, (window_size - 1 - half) * dilation),
        dilation=dilation, causal=causal)


def vil(grid: Tuple[int, int], window: Tuple[int, int] = (15, 15),
        n_global: int = 1) -> HybridSparsePattern:
    """ViL stage pattern: 2-D local window + global CLS token (paper Table 2)."""
    return HybridSparsePattern(grid2d=grid, window2d=window, n_global=n_global)


def full(causal: bool = False, n: int = 2 ** 30) -> HybridSparsePattern:
    """Dense attention expressed as a degenerate (huge-window) pattern."""
    return HybridSparsePattern(window=(-n, n), causal=causal)
