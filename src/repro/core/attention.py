"""Public hybrid-sparse-attention API: one entry point, many engines.

``hybrid_attention(q, k, v, pattern, impl=...)`` with q/k/v ``(B, H, N, D)``
(batch, heads, seq, head_dim — the model-facing layout).

Every sparse engine executes the same lowering pipeline (core/scheduler.py):

    HybridSparsePattern --schedule()--> BandSchedule --plan()--> ExecutionPlan

The ExecutionPlan is the single source of truth for the tile walk and the
per-step masks: flat per-query-block step tables covering the union of all
bands plus the global-key tiles, deduplicated to one visit per KV tile.

Engines:
  * ``dense_ref``          O(n^2) masked oracle (tests/small shapes)
  * ``blockwise``          the plan on XLA: one lax.scan over the step table
                           (training, dry-run) [default]
  * ``pallas``             the plan on TPU: ONE table-driven pallas_call,
                           step table streamed via scalar prefetch
  * ``pallas_interpret``   same kernel, interpret mode (CPU numerics check)

All engines are drop-in equivalent (tested to tolerance), forward AND
backward: both differentiable engines install a plan-driven custom VJP that
reuses the forward's saved ``(out, m, l)`` partials — ``blockwise`` as two
table-walking scans, ``pallas`` as two flash-style kernel launches (dQ over
the forward tables, dK/dV over the transposed tables; see
kernels/salo_backward.py). The blockwise scan engines stand in for the
``pallas`` kernels only when they cannot execute (compiled mode on a
non-TPU backend; see kernels/ops.py) — same residuals, same contract.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.patterns import HybridSparsePattern
from repro.core.blockwise import blockwise_attention, decode_attention

IMPLS = ("dense_ref", "blockwise", "pallas", "pallas_interpret")


def hybrid_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     pattern: HybridSparsePattern, *,
                     impl: str = "blockwise",
                     block_q: int = 128, block_k: int = 128,
                     scale: Optional[float] = None) -> jax.Array:
    """Hybrid sparse attention. q: (B, H, N, D); k/v: (B, Hkv, N, D).

    GQA: if Hkv < H, KV heads are repeated to match (H % Hkv == 0).
    """
    B, H, N, D = q.shape
    Hkv = k.shape[1]
    if Hkv != H:
        assert H % Hkv == 0, f"GQA heads {H} not divisible by kv heads {Hkv}"
        rep = H // Hkv
        # broadcast_to + reshape, NOT jnp.repeat: XLA keeps the expand as a
        # no-copy broadcast fused into the consumer (repeat materializes the
        # KV stream rep x in HBM).
        k = jnp.broadcast_to(k[:, :, None], (B, Hkv, rep, N, D))
        v = jnp.broadcast_to(v[:, :, None], (B, Hkv, rep, N, D))
        k = k.reshape(B, H, N, D)
        v = v.reshape(B, H, N, D)

    qf = q.reshape(B * H, N, D)
    kf = k.reshape(B * H, N, D)
    vf = v.reshape(B * H, N, D)
    assert qf.shape == kf.shape == vf.shape == (B * H, N, D), \
        "engines (incl. pallas) require the flat (B*H, N, D) layout"

    if impl == "dense_ref":
        from repro.kernels.ref import reference_attention
        out = reference_attention(qf, kf, vf, pattern, scale=scale)
    elif impl == "blockwise":
        out = blockwise_attention(qf, kf, vf, pattern, block_q=block_q,
                                  block_k=block_k, scale=scale)
    elif impl in ("pallas", "pallas_interpret"):
        from repro.kernels.ops import salo_attention
        out = salo_attention(qf, kf, vf, pattern, block_q=block_q,
                             block_k=block_k, scale=scale,
                             interpret=(impl == "pallas_interpret"))
    else:
        raise ValueError(f"unknown impl {impl!r}; choose from {IMPLS}")
    return out.reshape(B, H, N, D)


def hybrid_decode_attention(q: jax.Array, k_cache: jax.Array,
                            v_cache: jax.Array, t, pattern, *,
                            scale: Optional[float] = None,
                            cache_positions=None,
                            slice_window: bool = False) -> jax.Array:
    """Single-token decode. q: (B, H, 1, D); caches: (B, Hkv, S, D).

    GQA is computed with a grouped einsum — KV heads are NEVER repeated
    (a `jnp.repeat` materializes rep x the cache and breaks seq-sharding
    propagation under pjit; see EXPERIMENTS.md §Perf granite/long_500k).

    ``slice_window=True`` (SALO windowed decode): read only the last
    ``window`` cache slots + the global-token prefix instead of the whole
    sequence — O(w) instead of O(n) HBM traffic per step, the serving-side
    payoff of the paper's pattern. Requires the slot==position cache layout
    (``cache_positions is None``).
    """
    from repro.core import renorm

    B, H, _, D = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    rep = H // Hkv
    scale_ = (D ** -0.5) if scale is None else scale
    qg = q.reshape(B, Hkv, rep, D)
    p = pattern
    a, _b = p.window
    g = p.n_global

    def grouped(kc, vc, pos_k, extra_mask=None):
        """kc/vc: (B, Hkv, L, D); pos_k: (L,) -> (scores-masked) out parts."""
        s = jnp.einsum("bgrd,bgsd->bgrs", qg, kc,
                       preferred_element_type=jnp.float32) * scale_
        pos_i = jnp.asarray(t, jnp.int32)
        rel = pos_k - pos_i
        m = (rel >= a) & (rel <= 0)  # decode: lookback window only
        if p.dilation > 1:
            m = m & (rel % p.dilation == 0)
        if g > 0:
            m = m | (pos_k < g)
        m = m & (pos_k <= pos_i)  # decode is causal
        if extra_mask is not None:
            m = m & extra_mask
        return jnp.where(m[None, None, None, :], s, renorm.NEG_INF)

    if slice_window and cache_positions is None and a > -(1 << 29):
        w = -a + 1
        L = min(S, w)
        start = jnp.clip(jnp.asarray(t, jnp.int32) - (L - 1), 0, S - L)
        k_win = jax.lax.dynamic_slice_in_dim(k_cache, start, L, axis=2)
        v_win = jax.lax.dynamic_slice_in_dim(v_cache, start, L, axis=2)
        pos_win = start + jnp.arange(L, dtype=jnp.int32)
        parts_k, parts_v, parts_s = [k_win], [v_win], []
        s_win = grouped(k_win, v_win, pos_win)
        parts_s.append(s_win)
        if g > 0:
            gp = min(g, S)
            k_sink = k_cache[:, :, :gp]
            v_sink = v_cache[:, :, :gp]
            pos_sink = jnp.arange(gp, dtype=jnp.int32)
            # exclude sink slots already inside the window slice
            s_sink = grouped(k_sink, v_sink, pos_sink,
                             extra_mask=pos_sink < start)
            parts_s.insert(0, s_sink)
            parts_k.insert(0, k_sink)
            parts_v.insert(0, v_sink)
        s = jnp.concatenate(parts_s, axis=-1)
        vc = jnp.concatenate(parts_v, axis=2)
    else:
        pos_k = (jnp.arange(S, dtype=jnp.int32) if cache_positions is None
                 else cache_positions.astype(jnp.int32))
        s = grouped(k_cache, v_cache, pos_k)
        vc = v_cache
    wts = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrs,bgsd->bgrd", wts, vc.astype(wts.dtype))
    return out.astype(q.dtype).reshape(B, H, 1, D)
