"""Public hybrid-sparse-attention API: one entry point, many engines.

``hybrid_attention(q, k, v, pattern, impl=...)`` with q/k/v ``(B, H, N, D)``
(batch, heads, seq, head_dim — the model-facing layout).

Every sparse engine executes the same lowering pipeline (core/scheduler.py):

    HybridSparsePattern --schedule()--> BandSchedule --plan()--> ExecutionPlan

The ExecutionPlan is the single source of truth for the tile walk and the
per-step masks: flat per-query-block step tables covering the union of all
bands plus the global-key tiles, deduplicated to one visit per KV tile.

Engines:
  * ``dense_ref``          O(n^2) masked oracle (tests/small shapes)
  * ``blockwise``          the plan on XLA: one lax.scan over the step table
                           (training, dry-run) [default]
  * ``pallas``             the plan on TPU: ONE table-driven pallas_call,
                           step table streamed via scalar prefetch
  * ``pallas_interpret``   same kernel, interpret mode (CPU numerics check)

All engines are drop-in equivalent (tested to tolerance), forward AND
backward: both differentiable engines install a plan-driven custom VJP that
reuses the forward's saved ``(out, m, l)`` partials — ``blockwise`` as two
table-walking scans, ``pallas`` as two flash-style kernel launches (dQ over
the forward tables, dK/dV over the transposed tables; see
kernels/salo_backward.py). The blockwise scan engines stand in for the
``pallas`` kernels only when they cannot execute (compiled mode on a
non-TPU backend; see kernels/ops.py) — same residuals, same contract.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.patterns import HybridSparsePattern
from repro.core.blockwise import blockwise_attention
from repro.obs.metrics import global_registry

IMPLS = ("dense_ref", "blockwise", "pallas", "pallas_interpret")


def hybrid_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     pattern: HybridSparsePattern, *,
                     impl: str = "blockwise",
                     block_q: int = 128, block_k: int = 128,
                     scale: Optional[float] = None,
                     plan: str = "static",
                     dynamic_keep: Optional[int] = None,
                     dynamic_local_window: Optional[int] = None,
                     dynamic_pool_k: Optional[int] = None) -> jax.Array:
    """Hybrid sparse attention. q: (B, H, N, D); k/v: (B, Hkv, N, D).

    GQA: if Hkv < H, KV heads are repeated to match (H % Hkv == 0).

    ``plan`` selects how step tables are built: ``"static"`` lowers the
    pattern alone (the default ExecutionPlan path); ``"dynamic"`` routes
    through :mod:`repro.core.dynamic` — per query block only the
    ``dynamic_keep`` highest estimated-mass candidate tiles execute
    (causal-local and global/sink tiles are never dropped; see the
    DynamicConfig knobs ``dynamic_local_window`` / ``dynamic_pool_k``).
    Dynamic plans need a table-driven engine (any ``impl`` but
    ``dense_ref``) and compose with sequence parallelism: the selection
    happens per shard over its [local | halo | global] view while the
    exchange schedule stays static.
    """
    if plan not in ("static", "dynamic"):
        raise ValueError(f"unknown plan {plan!r}; choose static or dynamic")
    dcfg = None
    if plan == "dynamic":
        if impl == "dense_ref":
            raise ValueError("plan='dynamic' needs a table-driven engine "
                             "(impl != 'dense_ref')")
        if dynamic_keep is None:
            raise ValueError("plan='dynamic' requires dynamic_keep")
        from repro.core.dynamic import DynamicConfig
        dcfg = DynamicConfig(keep=int(dynamic_keep),
                             local_window=dynamic_local_window,
                             pool_k=dynamic_pool_k)
    B, H, N, D = q.shape
    Hkv = k.shape[1]
    if Hkv != H:
        assert H % Hkv == 0, f"GQA heads {H} not divisible by kv heads {Hkv}"
        rep = H // Hkv
        # broadcast_to + reshape, NOT jnp.repeat: XLA keeps the expand as a
        # no-copy broadcast fused into the consumer (repeat materializes the
        # KV stream rep x in HBM).
        k = jnp.broadcast_to(k[:, :, None], (B, Hkv, rep, N, D))
        v = jnp.broadcast_to(v[:, :, None], (B, Hkv, rep, N, D))
        k = k.reshape(B, H, N, D)
        v = v.reshape(B, H, N, D)

    qf = q.reshape(B * H, N, D)
    kf = k.reshape(B * H, N, D)
    vf = v.reshape(B * H, N, D)
    assert qf.shape == kf.shape == vf.shape == (B * H, N, D), \
        "engines (incl. pallas) require the flat (B*H, N, D) layout"

    # Trace-time call accounting (host-side, once per compilation — the
    # dispatch-level complement of the per-launch accounting in
    # kernels/ops.py; zero traced operands).
    global_registry().inc("attention_trace_calls", impl=impl)

    # Sequence parallelism: when the active sharding rules map the "seq"
    # logical axis onto a mesh axis (long-context cells turn this on in
    # launch.specs.cell_rules), run the ShardedPlan path — the same fused
    # engines under shard_map with ppermute halo exchange — instead of
    # letting pjit all-gather K/V.
    if impl in ("blockwise", "pallas", "pallas_interpret"):
        from repro.dist.sharding import sequence_mesh_axis
        seq = sequence_mesh_axis()
        if seq is not None:
            from repro.dist.sharded_plan import sharded_attention
            mesh, ax = seq
            out = sharded_attention(qf, kf, vf, pattern, mesh, ax,
                                    block_q=block_q, block_k=block_k,
                                    scale=scale, impl=impl, dynamic=dcfg)
            return out.reshape(B, H, N, D)

    if dcfg is not None:
        from repro.core.dynamic import dynamic_attention
        out = dynamic_attention(qf, kf, vf, pattern, dcfg, block_q=block_q,
                                block_k=block_k, scale=scale, impl=impl)
        return out.reshape(B, H, N, D)

    if impl == "dense_ref":
        from repro.kernels.ref import reference_attention
        out = reference_attention(qf, kf, vf, pattern, scale=scale)
    elif impl == "blockwise":
        out = blockwise_attention(qf, kf, vf, pattern, block_q=block_q,
                                  block_k=block_k, scale=scale)
    elif impl in ("pallas", "pallas_interpret"):
        from repro.kernels.ops import salo_attention
        out = salo_attention(qf, kf, vf, pattern, block_q=block_q,
                             block_k=block_k, scale=scale,
                             interpret=(impl == "pallas_interpret"))
    else:
        raise ValueError(f"unknown impl {impl!r}; choose from {IMPLS}")
    return out.reshape(B, H, N, D)


def hybrid_decode_attention(q: jax.Array, k_cache: jax.Array,
                            v_cache: jax.Array, t, pattern, *,
                            scale: Optional[float] = None,
                            cache_positions=None,
                            slice_window: bool = False,
                            return_state: bool = False,
                            return_slot_m: bool = False):
    """Single-token decode — ragged aware. q: (B, H, 1, D); caches:
    (B, Hkv, S, D); ``t``: scalar position (lockstep batch) OR a (B,)
    vector — one position per request, so a single call serves a
    continuous batch whose members sit at different depths.
    ``cache_positions``: (S,) shared slots or (B, S) per-request slots
    (the paged ring-cache view).

    GQA is computed with a grouped einsum — KV heads are NEVER repeated
    (a `jnp.repeat` materializes rep x the cache and breaks seq-sharding
    propagation under pjit).

    ``slice_window=True`` (SALO windowed decode): read only the last
    ``window`` cache slots + the global-token prefix instead of the whole
    sequence — O(w) instead of O(n) HBM traffic per step, the serving-side
    payoff of the paper's pattern. Requires the slot==position cache layout
    (``cache_positions is None``) and a lockstep scalar ``t``.

    ``return_state=True`` returns the finalized partial triple
    ``(out, m, l)`` with m/l (B, H, 1) instead of the softmaxed output —
    what a sequence shard contributes to the cross-shard masked-psum merge
    over its owned cache slots. A request with no valid slot on this shard
    yields the ``(0, NEG_INF, 0)`` identity (renorm.PartialState contract).
    Incompatible with ``slice_window`` (the sharded slab path passes
    ``cache_positions``, which already disables the slice).

    ``return_slot_m=True`` appends ``slot_m`` (B, S) — each request's max
    masked score against each cache slot (NEG_INF where masked), the raw
    per-slot statistic the paged engine reduces to per-page maxima for
    its stats-driven page-keep mask. Composes with ``return_state``;
    incompatible with ``slice_window`` (slot order would be scrambled).
    """
    from repro.core import renorm
    from repro.core.scheduler import (STEP_GLOBAL, STEP_WINDOW,
                                      causal_step_mask)

    B, H, _, D = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    rep = H // Hkv
    scale_ = (D ** -0.5) if scale is None else scale
    qg = q.reshape(B, Hkv, rep, D)
    p = pattern
    a, _b = p.window
    g = p.n_global
    ragged_t = jnp.ndim(t) > 0

    def grouped(kc, vc, pos_k, extra_mask=None):
        """kc/vc: (B, Hkv, L, D); pos_k: (L,) or (B, L) -> masked scores."""
        s = jnp.einsum("bgrd,bgsd->bgrs", qg, kc,
                       preferred_element_type=jnp.float32) * scale_
        L = kc.shape[2]
        pos_i = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (B,))
        pos_kb = jnp.broadcast_to(jnp.asarray(pos_k, jnp.int32), (B, L))
        m = causal_step_mask(p, pos_i[:, None], pos_kb,
                             STEP_WINDOW | STEP_GLOBAL)        # (B, L)
        if extra_mask is not None:
            m = m & extra_mask
        return jnp.where(m[:, None, None, :], s, renorm.NEG_INF)

    if return_state:
        pos_k = (jnp.arange(S, dtype=jnp.int32) if cache_positions is None
                 else cache_positions.astype(jnp.int32))
        s = grouped(k_cache, v_cache, pos_k)          # (B, Hkv, rep, S)
        slot_m = jnp.max(s, axis=(1, 2)) if return_slot_m else None
        m = jnp.max(s, axis=-1)
        # masked entries sit at NEG_INF: exp(NEG_INF - shift) underflows to
        # exactly 0, and an all-masked row keeps (0, NEG_INF, 0).
        shift = jnp.where(m <= renorm.NEG_INF / 2, 0.0, m)
        p = jnp.exp(s - shift[..., None])
        l = jnp.sum(p, axis=-1)
        # f32 contraction AND an f32 partial: the cross-shard merge
        # re-weights partials, so the round to the compute dtype must
        # happen ONCE, after the merge — per-shard bf16 rounding here
        # would diverge from the single-device round-once numerics
        acc = jnp.einsum("bgrs,bgsd->bgrd", p, v_cache.astype(p.dtype))
        out = acc / jnp.where(l == 0.0, 1.0, l)[..., None]
        res = (out.reshape(B, H, 1, D),
               m.reshape(B, H, 1), l.reshape(B, H, 1))
        return (*res, slot_m) if return_slot_m else res

    if return_slot_m:
        pos_k = (jnp.arange(S, dtype=jnp.int32) if cache_positions is None
                 else cache_positions.astype(jnp.int32))
        s = grouped(k_cache, v_cache, pos_k)
        wts = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bgrs,bgsd->bgrd", wts, v_cache.astype(wts.dtype))
        return (out.astype(q.dtype).reshape(B, H, 1, D),
                jnp.max(s, axis=(1, 2)))

    if slice_window and cache_positions is None and a > -(1 << 29) \
            and not ragged_t:
        w = -a + 1
        L = min(S, w)
        start = jnp.clip(jnp.asarray(t, jnp.int32) - (L - 1), 0, S - L)
        k_win = jax.lax.dynamic_slice_in_dim(k_cache, start, L, axis=2)
        v_win = jax.lax.dynamic_slice_in_dim(v_cache, start, L, axis=2)
        pos_win = start + jnp.arange(L, dtype=jnp.int32)
        parts_v, parts_s = [v_win], []
        s_win = grouped(k_win, v_win, pos_win)
        parts_s.append(s_win)
        if g > 0:
            gp = min(g, S)
            k_sink = k_cache[:, :, :gp]
            v_sink = v_cache[:, :, :gp]
            pos_sink = jnp.arange(gp, dtype=jnp.int32)
            # exclude sink slots already inside the window slice
            s_sink = grouped(k_sink, v_sink, pos_sink,
                             extra_mask=pos_sink < start)
            parts_s.insert(0, s_sink)
            parts_v.insert(0, v_sink)
        s = jnp.concatenate(parts_s, axis=-1)
        vc = jnp.concatenate(parts_v, axis=2)
    else:
        pos_k = (jnp.arange(S, dtype=jnp.int32) if cache_positions is None
                 else cache_positions.astype(jnp.int32))
        s = grouped(k_cache, v_cache, pos_k)
        vc = v_cache
    wts = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrs,bgsd->bgrd", wts, vc.astype(wts.dtype))
    return out.astype(q.dtype).reshape(B, H, 1, D)


def hybrid_chunk_attention(q: jax.Array, k_view: jax.Array,
                           v_view: jax.Array, pos_q: jax.Array,
                           pos_k: jax.Array, kv_blocks: jax.Array,
                           flags: jax.Array, pattern, *,
                           scale: Optional[float] = None,
                           return_state: bool = False):
    """Chunked-prefill attention (model-facing layout): one fused pass of a
    prompt chunk against the request's paged KV view + the chunk itself.

    q: (B, H, Cp, D); k_view/v_view: (B, Hkv, Vp, D); pos_q: (B, Cp);
    pos_k: (B, Vp) original positions; kv_blocks/flags: (nq, W) ChunkPlan
    step tables. GQA via no-copy broadcast (same rule as the training
    path). Returns (B, H, Cp, D), plus (m, l) of shape (B, H, Cp) when
    ``return_state`` (the per-shard partial for the cross-shard merge).
    """
    from repro.core.blockwise import chunk_attention

    B, H, Cp, D = q.shape
    Hkv, Vp = k_view.shape[1], k_view.shape[2]
    rep = H // Hkv
    if Hkv != H:
        k_view = jnp.broadcast_to(k_view[:, :, None],
                                  (B, Hkv, rep, Vp, D)).reshape(B, H, Vp, D)
        v_view = jnp.broadcast_to(v_view[:, :, None],
                                  (B, Hkv, rep, Vp, D)).reshape(B, H, Vp, D)
    qf = q.reshape(B * H, Cp, D)
    kf = k_view.reshape(B * H, Vp, D)
    vf = v_view.reshape(B * H, Vp, D)
    pos_qf = jnp.broadcast_to(pos_q[:, None], (B, H, Cp)).reshape(B * H, Cp)
    pos_kf = jnp.broadcast_to(pos_k[:, None], (B, H, Vp)).reshape(B * H, Vp)
    res = chunk_attention(qf, kf, vf, pos_qf, pos_kf, kv_blocks, flags,
                          pattern, scale=scale, return_state=return_state)
    if return_state:
        out, m, l = res
        return (out.reshape(B, H, Cp, D), m.reshape(B, H, Cp),
                l.reshape(B, H, Cp))
    return res.reshape(B, H, Cp, D)
