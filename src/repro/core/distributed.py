"""Sequence-parallel attention — retired prototype, now a thin shim.

The original module computed dense-tile XLA partials per shard (1-D
patterns only, ``dilation == 1`` asserted, windows clamped to one shard,
forward-only) and had two real bugs: global tokens were read as
``k_local[:, :g]`` on shard 0, silently truncating whenever
``g > n_local``, and ``_local_banded`` accepted ``block_q``/``block_k``
parameters it never used. All of it is superseded by
:mod:`repro.dist.sharded_plan`, which slices the ExecutionPlan IR per
shard and runs the *fused* engines under ``shard_map`` (ppermute halo
exchange, psum-broadcast global tiles keyed by owner — no shard-0
assumption — multi-hop halos, dilation, 2-D patterns, and the full
fused backward).

This shim keeps the old entry point importable; new code should call
:func:`repro.dist.sharded_plan.sharded_attention` directly.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh

from repro.core.patterns import HybridSparsePattern
from repro.dist.sharded_plan import sharded_attention


def sequence_parallel_attention(
        q: jax.Array, k: jax.Array, v: jax.Array,
        pattern: HybridSparsePattern, mesh: Mesh, axis: str = "data", *,
        scale: Optional[float] = None) -> jax.Array:
    """q/k/v: (B, N, D) sharded on N over ``axis``. Delegates to the
    ShardedPlan engine (any pattern the single-device plan supports)."""
    return sharded_attention(q, k, v, pattern, mesh, axis, scale=scale)
