"""Sequence-parallel hybrid sparse attention (shard_map + halo exchange).

The paper's window splitting (Eq. 2) applied at *datacenter* scale: shard the
sequence across mesh devices; each device computes the attention partials for
its local queries, and the banded structure means a query near a shard edge
only needs K/V from the **adjacent** shard(s) — a halo exchange via
``ppermute``, not an all-gather. Global tokens live on shard 0 and are
broadcast once (the paper's global PE row/column tapping the stream).

Traffic per device per layer: ``halo = (w + Bk) * d`` bytes to neighbors +
one small broadcast — independent of sequence length, vs ``n*d`` for
all-gather ring attention. For long_500k with w=4096 that is a 128x
collective-byte reduction (quantified in EXPERIMENTS.md §Perf).

Restrictions (asserted): 1-D patterns, dilation folded in by the caller,
window must fit within one neighbor shard (w <= n_local), bidirectional
windows exchange halos on both sides.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

from repro.core import renorm
from repro.core.blockwise import blockwise_attention, _dot
from repro.core.patterns import HybridSparsePattern
from repro.core.scheduler import PAD_SENTINEL, schedule


def _local_banded(q, k, v, pos_q, pos_k, pattern, scale, block_q, block_k):
    """Dense-tiles banded partial on local (q x k) with position masks.
    q: (B, nq, D); k/v: (B, nk, D). Returns PartialState over (B, nq)."""
    sched = schedule(pattern, 1 << 30)  # masks only depend on the pattern
    state = renorm.empty_state(q.shape[:-1], v.shape[-1])
    scores = _dot(q, k) * scale
    mask = sched.window_mask(pos_q[:, None], pos_k[None, :])
    # window_mask checks pos < n with n=1<<30: padding handled by caller
    return renorm.update(state, scores, v, mask[None])


def sequence_parallel_attention(
        q: jax.Array, k: jax.Array, v: jax.Array,
        pattern: HybridSparsePattern, mesh: Mesh, axis: str = "data", *,
        scale: Optional[float] = None) -> jax.Array:
    """q/k/v: (B, N, D) sharded on N over ``axis``. Causal or bidirectional
    sliding window + leading-global patterns."""
    assert not pattern.is_2d and pattern.dilation == 1
    B, N, D = q.shape
    scale_ = (D ** -0.5) if scale is None else scale
    n_shards = mesh.shape[axis]
    n_local = N // n_shards
    a, b = pattern.window
    a = max(a, -(N - 1))
    b = min(b, 0 if pattern.causal else N - 1)
    g = pattern.n_global
    assert -a <= n_local and b <= n_local, (
        f"window ({a},{b}) must fit in one shard (n_local={n_local})")

    def local_fn(q_l, k_l, v_l):
        idx = jax.lax.axis_index(axis)
        pos_l = idx * n_local + jnp.arange(n_local)

        # halo exchange: neighbor K/V + neighbor positions
        right = [(i, (i + 1) % n_shards) for i in range(n_shards)]
        left = [(i, (i - 1) % n_shards) for i in range(n_shards)]
        k_prev = jax.lax.ppermute(k_l, axis, right)   # from idx-1
        v_prev = jax.lax.ppermute(v_l, axis, right)
        state = _local_banded(q_l, k_l, v_l, pos_l, pos_l, pattern, scale_,
                              0, 0)
        pos_prev = pos_l - n_local  # idx==0 receives wrap: mask via pos<0
        pos_prev = jnp.where(pos_prev < 0, jnp.int32(PAD_SENTINEL),
                             pos_prev)
        st_prev = _local_banded(q_l, k_prev, v_prev, pos_l, pos_prev,
                                pattern, scale_, 0, 0)
        state = renorm.merge(state, st_prev)
        if not pattern.causal and b > 0:
            k_next = jax.lax.ppermute(k_l, axis, left)
            v_next = jax.lax.ppermute(v_l, axis, left)
            pos_next = pos_l + n_local
            pos_next = jnp.where(pos_next >= N,
                                 jnp.int32(PAD_SENTINEL), pos_next)
            st_next = _local_banded(q_l, k_next, v_next, pos_l, pos_next,
                                    pattern, scale_, 0, 0)
            state = renorm.merge(state, st_next)

        # global column: shard 0 broadcasts its leading g keys
        if g > 0:
            kg = jnp.where(jax.lax.axis_index(axis) == 0, 1.0, 0.0)
            k_g = jax.lax.psum(k_l[:, :g] * kg.astype(k_l.dtype), axis)
            v_g = jax.lax.psum(v_l[:, :g] * kg.astype(v_l.dtype), axis)
            sched = schedule(pattern, 1 << 30)
            scores = _dot(q_l, k_g) * scale_
            gmask = sched.global_col_mask(pos_l[:, None],
                                          jnp.arange(g)[None, :])
            state = renorm.update(state, scores, v_g, gmask[None])

        out = renorm.finalize(state, q_l.dtype)

        # global rows: shard 0's first g queries attend everything.
        if g > 0 and pattern.global_rows:
            qg = jax.lax.psum(q_l[:, :g] * kg.astype(q_l.dtype), axis)
            sg = _dot(qg, k_l) * scale_
            if pattern.causal:
                cm = pos_l[None, :] <= jnp.arange(g)[:, None]
                sg = jnp.where(cm[None], sg, renorm.NEG_INF)
            stg = renorm.empty_state((B, g), D)
            stg = renorm.update(stg, sg, v_l)
            # merge across shards via psum on the state triple
            m_max = jax.lax.pmax(stg.m, axis)
            corr = jnp.exp(stg.m - m_max)
            acc = jax.lax.psum(stg.acc * corr[..., None], axis)
            l = jax.lax.psum(stg.l * corr, axis)
            rows = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(out.dtype)
            out = jnp.where((jax.lax.axis_index(axis) == 0)
                            & (jnp.arange(n_local) < g)[None, :, None],
                            jnp.pad(rows, ((0, 0), (0, n_local - g), (0, 0))),
                            out)
        return out

    fn = shard_map(local_fn, mesh=mesh,
                   in_specs=(P(None, axis, None),) * 3,
                   out_specs=P(None, axis, None), check_vma=False)
    return fn(q, k, v)
