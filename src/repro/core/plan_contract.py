"""THE step-table contract: one spec every table producer and consumer share.

Every table-driven engine in this codebase — the fused Pallas kernels
(:mod:`repro.kernels.salo_attention` / ``salo_backward``), the XLA scan
twins (:mod:`repro.core.blockwise`), the sharded per-device slices
(:mod:`repro.dist.sharded_plan`), the serving chunk tables
(:class:`repro.core.scheduler.ChunkPlan`) and the runtime content-based
builder (:mod:`repro.core.dynamic`) — consumes the same IR: a pair of
rectangular int32 arrays

    ``kv_blocks[i, s]`` — the KV tile query-block row ``i`` visits at
    step ``s`` (a value in ``[0, nkb)`` over whatever tile universe the
    consumer walks: the padded working grid, a shard's local view, a
    chunk's paged view);
    ``flags[i, s]``     — which mask components that visit evaluates, a
    bitmask of :data:`STEP_WINDOW` and :data:`STEP_GLOBAL`.

The contract, checked by :func:`validate_tables`:

* both arrays are rank-2 ``int32`` of identical shape ``(nq, width)``,
  ``width >= 1`` (the fixed ``steps`` dimension of the kernel grid —
  rows are padded to it, never ragged);
* every tile index lies in ``[0, nkb)`` — including padding steps, which
  point at tile 0 so gathers stay in-bounds;
* ``flags`` uses no bits outside ``STEP_WINDOW | STEP_GLOBAL``;
* a step is padding **iff** ``flags == 0``; padding steps carry
  ``kv_blocks == 0`` (the no-op contract: every mask term of
  ``step_mask``/``causal_step_mask`` evaluates False, the gathered tile 0
  contributes nothing);
* within a row, no real tile is visited twice (the dedup invariant that
  makes the union mask exact — each attended pair is counted once);
* when the producer also emits ``num_steps``, row ``i``'s real steps are
  a left-aligned prefix: ``flags[i, :num_steps[i]]`` all nonzero,
  ``flags[i, num_steps[i]:]`` all zero.

Positions are NOT part of the tables: padding *slots* (not steps) are
expressed through the position streams, where :data:`PAD_SENTINEL` marks
a slot holding nothing — every mask fails on it by the in-range guard.
The static builder additionally emits rows in ascending tile order; that
is a convention (it gives deterministic step order), not a contract —
sharded view remapping and runtime top-k selection produce other orders
and every consumer folds steps through an order-invariant online softmax.

Table *values* may be traced (sharded per-device slices, runtime-built
dynamic tables): :func:`validate_tables` then checks everything static
(rank, shape, dtype, width) and skips the value checks, which the tests
pin on materialized tables instead.
"""
from __future__ import annotations

from typing import Iterator, Optional, Tuple

import numpy as np

# Sentinel original-position for padding slots — THE one padding sentinel,
# shared by every cache/halo/kernel path. Must fit int32 (JAX default
# integer width) *and* keep pos_j - pos_i inside int32 — any mask
# comparison against it must fail via the `pos < n` in-range guard or a
# window-distance check.
BIG = 2 ** 31 - 2 ** 20
PAD_SENTINEL = BIG

# Step flags: which mask components a step evaluates.
STEP_WINDOW = 1   # some band covers this (q_block, kv_tile) visit
STEP_GLOBAL = 2   # the KV tile holds global-prefix keys

VALID_FLAGS = STEP_WINDOW | STEP_GLOBAL


def _concrete(a) -> Optional[np.ndarray]:
    """numpy view of ``a`` when its values are known now, else None."""
    if isinstance(a, np.ndarray):
        return a
    try:
        import jax

        if isinstance(a, jax.core.Tracer):
            return None
        return np.asarray(a)
    except Exception:
        return None


def iter_real_steps(kv_blocks, flags) -> Iterator[Tuple[int, int, int, int]]:
    """Yield ``(row, step, tile, flag)`` for every real (``flags != 0``)
    step of a contract table pair — the one walk order every analysis pass
    shares (:mod:`repro.analysis.plan_verify` builds its coverage counts
    and visit multisets from exactly this iteration)."""
    kv = np.asarray(kv_blocks)
    fl = np.asarray(flags)
    for i, s in zip(*np.nonzero(fl)):
        yield int(i), int(s), int(kv[i, s]), int(fl[i, s])


def validate_tables(kv_blocks, flags, *, nkb: int,
                    num_steps=None, name: str = "step tables") -> None:
    """Check a ``(kv_blocks, flags)`` pair against the table contract.

    ``nkb`` is the tile universe the consumer will index with these
    values (padded working grid / shard view / chunk view). Raises
    :class:`ValueError` with the offending row/step on violation. Traced
    arrays get the structural checks only (see module docstring).
    """
    shape = getattr(kv_blocks, "shape", None)
    fshape = getattr(flags, "shape", None)
    if shape is None or fshape is None or len(shape) != 2 \
            or shape != fshape:
        raise ValueError(
            f"{name}: kv_blocks/flags must be rank-2 arrays of one shape, "
            f"got {shape} vs {fshape}")
    if shape[1] < 1:
        raise ValueError(f"{name}: table width must be >= 1, got {shape[1]}")
    for label, arr in (("kv_blocks", kv_blocks), ("flags", flags)):
        dt = np.dtype(getattr(arr, "dtype", None))
        if dt != np.int32:
            raise ValueError(f"{name}: {label} must be int32, got {dt}")
    if nkb < 1:
        raise ValueError(f"{name}: tile universe nkb must be >= 1, "
                         f"got {nkb}")

    kv = _concrete(kv_blocks)
    fl = _concrete(flags)
    if kv is None or fl is None:
        return                      # traced values: structural checks only

    bad = fl & ~VALID_FLAGS
    if bad.any():
        i, s = np.argwhere(bad != 0)[0]
        raise ValueError(
            f"{name}: unknown flag bits {int(fl[i, s])} at row {i} step {s}"
            f" (valid mask: {VALID_FLAGS})")
    oob = (kv < 0) | (kv >= nkb)
    if oob.any():
        i, s = np.argwhere(oob)[0]
        raise ValueError(
            f"{name}: tile index {int(kv[i, s])} at row {i} step {s} "
            f"outside [0, {nkb})")
    pad_bad = (fl == 0) & (kv != 0)
    if pad_bad.any():
        i, s = np.argwhere(pad_bad)[0]
        raise ValueError(
            f"{name}: padding step (flags == 0) at row {i} step {s} must "
            f"point at tile 0, got tile {int(kv[i, s])}")
    # per-row dedup of REAL tiles: padding steps all alias tile 0 and are
    # excluded via a sort key that keeps them distinct from real tile 0.
    key = np.where(fl != 0, kv.astype(np.int64), -1)
    srt = np.sort(key, axis=1)
    dup = (srt[:, 1:] == srt[:, :-1]) & (srt[:, 1:] >= 0)
    if dup.any():
        i = int(np.argwhere(dup.any(axis=1))[0][0])
        t = int(srt[i][1:][dup[i]][0])
        raise ValueError(
            f"{name}: row {i} visits tile {t} more than once "
            f"(the dedup invariant — one visit per (row, tile))")
    if num_steps is not None:
        ns = _concrete(num_steps)
        if ns is not None:
            ns = ns.astype(np.int64)
            if (ns < 0).any() or (ns > shape[1]).any():
                raise ValueError(
                    f"{name}: num_steps outside [0, {shape[1]}]")
            cols = np.arange(shape[1])[None, :]
            real = fl != 0
            if (real != (cols < ns[:, None])).any():
                i = int(np.argwhere(
                    (real != (cols < ns[:, None])).any(axis=1))[0][0])
                raise ValueError(
                    f"{name}: row {i} padding is not right-aligned — real "
                    f"steps must be exactly flags[:, :num_steps] nonzero, "
                    f"flags[:, num_steps:] zero")
