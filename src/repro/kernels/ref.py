"""Pure-jnp dense-masked oracle for hybrid sparse attention.

O(n^2) memory — the ground truth every implementation (blockwise JAX and the
Pallas kernel) is tested against. Materializes the pattern mask directly from
:meth:`HybridSparsePattern.mask`.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.patterns import HybridSparsePattern

NEG_INF = -1e30


def reference_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                        pattern: HybridSparsePattern, *,
                        scale: Optional[float] = None) -> jax.Array:
    """q, k, v: (B, N, D) with B folding batch*heads."""
    B, N, D = q.shape
    scale = (D ** -0.5) if scale is None else scale
    mask = jnp.asarray(np.asarray(pattern.mask(N)))
    s = jnp.einsum("bqd,bkd->bqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # Rows with no attended key (possible for exotic patterns): zero them.
    any_valid = mask.any(axis=-1)
    p = jnp.where(any_valid[None, :, None], p, 0.0)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(p.dtype)).astype(q.dtype)
