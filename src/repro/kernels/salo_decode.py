"""SALO ragged decode kernels (Pallas, TPU target).

One new token per request against the SALO cache, **one launch for the whole
continuous batch**: the per-request position vector ``t`` rides in via
scalar prefetch (``PrefetchScalarGridSpec``), so batch members at different
depths — the normal state of a continuous-batching engine — share a single
kernel launch instead of a lockstep scalar ``t``. Two cache layouts:

* :func:`salo_decode` — per-request contiguous caches ``(B, Hkv, S, hd)``
  (dense baseline or the legacy ring layout). Per-request slot-position
  tiles make ring indexing transparent, exactly like the jnp engine.
* :func:`salo_paged_decode` — the pooled paged ring-cache slab
  ``(n_pages, page, Hkv, hd)`` shared by every request
  (:mod:`repro.serve.paged_cache`): the per-request **page table** is the
  second scalar-prefetch operand, and the BlockSpec index map chases it so
  each grid step DMAs exactly one physical page tile — no per-request
  gather ever materializes in HBM. int8 slabs additionally prefetch the
  per-page f32 scales (operands 3/4) and dequantize each tile in VMEM;
  ``return_page_stats`` emits per-(request, page) max masked scores for
  the engine's stats-driven page-keep mask.

Both kernels stream cache tiles through VMEM past the resident grouped
query (GQA: rep = H/Hkv query rows share each KV head — no KV repeat), with
the usual online-softmax scratch. Masks are evaluated on original positions
(``scheduler.causal_step_mask`` semantics, inlined below).

Grid: ``(B, Hkv, n_slot_tiles)`` — last dim sequential.
Compiled off-TPU both degrade to the XLA ragged decode twin
(:func:`repro.core.attention.hybrid_decode_attention`) — same pattern as
``kernels/ops.py`` for the forward/backward. Validated in interpret mode in
tests/test_decode_kernel.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params
from repro.core.patterns import HybridSparsePattern
from repro.core.scheduler import PAD_SENTINEL

NEG_INF = -1e30
LANES = 128


def _use_fallback(interpret: bool) -> bool:
    """Compiled (non-interpret) Pallas TPU kernels only execute on TPU;
    everywhere else the XLA ragged twin stands in (same masks)."""
    return not interpret and jax.default_backend() != "tpu"


def _tile_update(s, steps, t, q, k, v, pos_k, out_ref, acc_ref, m_scr, l_scr,
                 *, pattern: HybridSparsePattern, scale: float,
                 m_ref=None, l_ref=None, pm_ref=None):
    """Fold one cache tile into the online-softmax scratch; finalize on the
    last sequential step. q: (rep, hd); k/v: (Bs, hd); pos_k: (Bs,) int32;
    t: per-request scalar position. ``m_ref``/``l_ref`` (optional
    (1, 1, rep, LANES) out refs) additionally emit the row stats — the
    per-shard partial the sequence-parallel decode merge consumes; rows
    that attended nothing finalize to the (0, NEG_INF, 0) identity.
    ``pm_ref`` (optional (1, 1, 1, LANES) out ref, one block per
    sequential step) emits THIS tile's max masked score — the raw
    material of the engine's page-sparsity statistics; an all-masked tile
    emits NEG_INF."""

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # (rep, Bs)

    # causal_step_mask with both flags, inlined (no in-range guard needed:
    # PAD_SENTINEL slots fail the window by distance and pos_k <= t).
    a, _ = pattern.window
    g = pattern.n_global
    rel = pos_k - t
    mask = (rel >= a) & (rel <= 0)
    if pattern.dilation > 1:
        mask = mask & (rel % pattern.dilation == 0)
    if g > 0:
        mask = mask | (pos_k < g)
    mask = mask & (pos_k <= t)
    scores = jnp.where(mask[None, :], scores, NEG_INF)

    if pm_ref is not None:
        pm_ref[0, 0] = jnp.full((1, LANES), jnp.max(scores), jnp.float32)

    m_prev = m_scr[...][:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
    shift = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.where(mask[None, :], jnp.exp(scores - shift), 0.0)
    corr = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - shift))
    pv = jax.lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    @pl.when(s == steps - 1)
    def _fin():
        l = l_scr[...][:, :1]
        out_ref[0, 0] = (acc_ref[...] /
                         jnp.where(l == 0.0, 1.0, l)).astype(out_ref.dtype)
        if m_ref is not None:
            m_ref[0, 0] = m_scr[...]
            l_ref[0, 0] = l_scr[...]


def _ragged_kernel(t_ref, q_ref, k_ref, v_ref, pos_ref, out_ref,
                   acc_ref, m_scr, l_scr, *, pattern: HybridSparsePattern,
                   steps: int, scale: float):
    b = pl.program_id(0)
    s = pl.program_id(2)
    _tile_update(s, steps, t_ref[b], q_ref[0, 0], k_ref[0, 0], v_ref[0, 0],
                 pos_ref[0, 0], out_ref, acc_ref, m_scr, l_scr,
                 pattern=pattern, scale=scale)


def _make_paged_kernel(*, pattern: HybridSparsePattern, steps: int,
                       scale: float, npp: int, tpp: int, quant: bool,
                       want_state: bool, want_pm: bool, compute_dtype):
    """Paged-decode kernel for any combination of the static features:
    ``quant`` dequantizes the int8 slab tile by its page's scalar-
    prefetched scale (no fp slab ever exists in HBM), ``want_state``
    emits the (m, l) row stats, ``want_pm`` emits the per-tile max
    masked score. Refs arrive positionally (prefetch, ins, outs,
    scratch) so the one body parses them by the same flags."""

    def kern(*refs):
        t_ref, pt_ref = refs[0], refs[1]
        i = 2
        if quant:
            ks_ref, vs_ref = refs[2], refs[3]
            i = 4
        q_ref, k_ref, v_ref, pos_ref = refs[i:i + 4]
        i += 4
        out_ref = refs[i]
        i += 1
        m_ref = l_ref = pm_ref = None
        if want_state:
            m_ref, l_ref = refs[i], refs[i + 1]
            i += 2
        if want_pm:
            pm_ref = refs[i]
            i += 1
        acc_ref, m_scr, l_scr = refs[i:i + 3]
        b = pl.program_id(0)
        s = pl.program_id(2)
        k = k_ref[0, :, 0]
        v = v_ref[0, :, 0]
        if quant:
            pg = pt_ref[b * npp + s // tpp]
            k = (k.astype(jnp.float32) * ks_ref[pg]).astype(compute_dtype)
            v = (v.astype(jnp.float32) * vs_ref[pg]).astype(compute_dtype)
        _tile_update(s, steps, t_ref[b], q_ref[0, 0], k, v, pos_ref[0, 0],
                     out_ref, acc_ref, m_scr, l_scr, pattern=pattern,
                     scale=scale, m_ref=m_ref, l_ref=l_ref, pm_ref=pm_ref)

    return kern


@functools.partial(jax.jit, static_argnames=("pattern", "block_s", "scale",
                                             "interpret"))
def salo_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                positions: jax.Array, t, *, pattern: HybridSparsePattern,
                block_s: int = 128, scale: Optional[float] = None,
                interpret: bool = False) -> jax.Array:
    """q: (B, H, 1, hd); caches: (B, Hkv, S, hd); positions: (S,) shared or
    (B, S) per-request absolute position per slot (huge sentinel = empty);
    ``t``: scalar (lockstep) or (B,) per-request position — one launch
    serves a ragged continuous batch. Returns (B, H, 1, hd)."""
    B, H, _, hd = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    rep = H // Hkv
    scale_ = (hd ** -0.5) if scale is None else scale
    t_arr = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (B,))
    pos = jnp.broadcast_to(jnp.asarray(positions, jnp.int32), (B, S))
    if _use_fallback(interpret):
        from repro.core.attention import hybrid_decode_attention
        return hybrid_decode_attention(q, k_cache, v_cache, t_arr, pattern,
                                       scale=scale_, cache_positions=pos)
    S_pad = -(-S // block_s) * block_s
    if S_pad != S:
        padc = ((0, 0), (0, 0), (0, S_pad - S), (0, 0))
        k_cache = jnp.pad(k_cache, padc)
        v_cache = jnp.pad(v_cache, padc)
        pos = jnp.pad(pos, ((0, 0), (0, S_pad - S)),
                      constant_values=PAD_SENTINEL)
    steps = S_pad // block_s
    qg = q.reshape(B, Hkv, rep, hd)
    pos3d = pos.reshape(B, steps, block_s)

    kern = functools.partial(_ragged_kernel, pattern=pattern, steps=steps,
                             scale=scale_)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                                # t vector
        grid=(B, Hkv, steps),
        in_specs=[
            pl.BlockSpec((1, 1, rep, hd), lambda b, h, s, t: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_s, hd),
                         lambda b, h, s, t: (b, h, s, 0)),
            pl.BlockSpec((1, 1, block_s, hd),
                         lambda b, h, s, t: (b, h, s, 0)),
            pl.BlockSpec((1, 1, block_s), lambda b, h, s, t: (b, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, hd),
                               lambda b, h, s, t: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep, hd), jnp.float32),
            pltpu.VMEM((rep, LANES), jnp.float32),
            pltpu.VMEM((rep, LANES), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, rep, hd), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="salo_decode",
    )(t_arr, qg, k_cache, v_cache, pos3d)
    return out.reshape(B, H, 1, hd)


@functools.partial(jax.jit, static_argnames=("pattern", "block_s", "scale",
                                             "interpret", "return_state",
                                             "return_page_stats"))
def salo_paged_decode(q: jax.Array, k_slab: jax.Array, v_slab: jax.Array,
                      page_tables: jax.Array, positions: jax.Array, t, *,
                      pattern: HybridSparsePattern,
                      block_s: Optional[int] = None,
                      scale: Optional[float] = None,
                      interpret: bool = False,
                      return_state: bool = False,
                      k_scale: Optional[jax.Array] = None,
                      v_scale: Optional[jax.Array] = None,
                      return_page_stats: bool = False):
    """Ragged decode straight off the pooled paged slab.

    q: (B, H, 1, hd); slabs: (n_pages, page, Hkv, hd) shared by ALL
    requests; page_tables: (B, pages_per_req) int32 physical page per
    logical page; positions: (B, S_req) absolute position per logical slot
    (S_req = pages_per_req * page); ``t``: (B,) per-request position. The
    page table is scalar-prefetched, so the BlockSpec index map resolves
    logical tile -> physical page before each DMA — the kernel never sees a
    gathered copy of the cache. Returns (B, H, 1, hd).

    **int8 slab**: pass the layer's per-page ``k_scale``/``v_scale``
    (n_pages,) f32 — they ride as scalar-prefetch operands 3/4 next to
    the page table and each tile is dequantized in VMEM right after its
    DMA (the fp cache never materializes anywhere).

    ``return_page_stats=True`` additionally emits ``page_m`` (B, npp): the
    max masked score each request produced against each of its logical
    pages this step (NEG_INF for fully-masked pages) — the statistic the
    engine's Salca-style page-keep mask accumulates. Composes with
    ``return_state``; outputs are ``out[, m, l][, page_m]`` in that order.

    Under sequence-parallel serving each shard runs this launch over its
    OWN page tables / slot positions (its slice of the paged slab) and
    ``return_state=True`` makes the kernel also emit the online-softmax row
    stats ``(m, l)`` as (B, H, 1) — the per-shard partial the masked-psum
    merge combines across the "seq" axis. Requests with no owned live slot
    finalize to the (0, NEG_INF, 0) merge identity."""
    B, H, _, hd = q.shape
    n_pages, page, Hkv, _ = k_slab.shape
    npp = page_tables.shape[1]
    S_req = npp * page
    assert positions.shape == (B, S_req), (positions.shape, B, S_req)
    quant = k_scale is not None
    rep = H // Hkv
    scale_ = (hd ** -0.5) if scale is None else scale
    t_arr = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (B,))
    if _use_fallback(interpret):
        from repro.core.attention import hybrid_decode_attention
        from repro.serve.paged_cache import gather_view
        k_req, v_req = gather_view(
            k_slab, v_slab, page_tables,
            *((k_scale, v_scale, q.dtype) if quant else ()))
        res = hybrid_decode_attention(
            q, k_req.transpose(0, 2, 1, 3), v_req.transpose(0, 2, 1, 3),
            t_arr, pattern, scale=scale_, cache_positions=positions,
            return_state=return_state, return_slot_m=return_page_stats)
        if not return_page_stats:
            return res
        parts, slot_m = (res[:-1], res[-1])
        page_m = slot_m.reshape(B, npp, page).max(axis=-1)
        return (*parts, page_m) if return_state else (parts[0], page_m)
    bs = page if block_s is None else block_s
    assert page % bs == 0, f"block_s {bs} must divide page {page}"
    tpp = page // bs                       # tiles per page
    steps = S_req // bs
    qg = q.reshape(B, Hkv, rep, hd)
    pos3d = positions.astype(jnp.int32).reshape(B, steps, bs)
    pt_flat = page_tables.astype(jnp.int32).reshape(-1)
    n_pref = 4 if quant else 2

    def kv_idx(b, h, s, t_ref, pt_ref, *_):
        return (pt_ref[b * npp + s // tpp], s % tpp, h, 0)

    kern = _make_paged_kernel(pattern=pattern, steps=steps, scale=scale_,
                              npp=npp, tpp=tpp, quant=quant,
                              want_state=return_state,
                              want_pm=return_page_stats,
                              compute_dtype=q.dtype)
    out_specs = [pl.BlockSpec((1, 1, rep, hd),
                              lambda b, h, s, *_: (b, h, 0, 0))]
    # state mode emits the out partial in f32: the cross-shard merge
    # rounds to q.dtype once, after combining (per-shard rounding would
    # diverge from the single-device round-once numerics)
    out_shape = [jax.ShapeDtypeStruct(
        (B, Hkv, rep, hd), jnp.float32 if return_state else q.dtype)]
    if return_state:
        # m/l ride full LANES-wide blocks (every lane equal) so the output
        # keeps the TPU-native tiling; callers read lane 0.
        stat_spec = pl.BlockSpec((1, 1, rep, LANES),
                                 lambda b, h, s, *_: (b, h, 0, 0))
        stat_shape = jax.ShapeDtypeStruct((B, Hkv, rep, LANES), jnp.float32)
        out_specs += [stat_spec, stat_spec]
        out_shape += [stat_shape, stat_shape]
    if return_page_stats:
        # one LANES-wide block per sequential step (lanes equal); the host
        # reduces tiles->pages and KV heads below.
        out_specs.append(pl.BlockSpec((1, 1, 1, LANES),
                                      lambda b, h, s, *_: (b, h, s, 0)))
        out_shape.append(
            jax.ShapeDtypeStruct((B, Hkv, steps, LANES), jnp.float32))
    single = len(out_specs) == 1
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=n_pref,   # t, page tables[, k_scale, v_scale]
        grid=(B, Hkv, steps),
        in_specs=[
            pl.BlockSpec((1, 1, rep, hd),
                         lambda b, h, s, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd), kv_idx),              # k slab
            pl.BlockSpec((1, bs, 1, hd), kv_idx),              # v slab
            pl.BlockSpec((1, 1, bs), lambda b, h, s, *_: (b, s, 0)),
        ],
        out_specs=out_specs[0] if single else tuple(out_specs),
        scratch_shapes=[
            pltpu.VMEM((rep, hd), jnp.float32),
            pltpu.VMEM((rep, LANES), jnp.float32),
            pltpu.VMEM((rep, LANES), jnp.float32),
        ],
    )
    pref = (t_arr, pt_flat) + (
        (k_scale.astype(jnp.float32), v_scale.astype(jnp.float32))
        if quant else ())
    res = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=out_shape[0] if single else tuple(out_shape),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="salo_paged_decode",
    )(*pref, qg, k_slab, v_slab, pos3d)
    res = (res,) if single else list(res)
    out = res[0].reshape(B, H, 1, hd)
    rest = []
    if return_state:
        m, l = res[1], res[2]
        rest += [m[..., 0].reshape(B, H, 1), l[..., 0].reshape(B, H, 1)]
    if return_page_stats:
        pm = res[-1][..., 0]                       # (B, Hkv, steps)
        page_m = pm.max(axis=1).reshape(B, npp, tpp).max(axis=-1)
        rest.append(page_m)
    return (out, *rest) if rest else out
