"""SALO single-token decode kernel (Pallas, TPU target).

One new token against the SALO ring cache (``g`` sink slots + ``w``-slot
ring): the kernel streams cache tiles through VMEM past the resident grouped
query (GQA: rep = H/Hkv query rows share each KV head — no KV repeat), with
the usual online-softmax scratch. Slot validity comes from the slot-position
array, so ring indexing is transparent (exactly like the jnp engine).

Grid: ``(B, Hkv, n_slot_tiles)`` — last dim sequential.
Validated in interpret mode against `core.attention.hybrid_decode_attention`
(tests/test_decode_kernel.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params
from repro.core.patterns import HybridSparsePattern
from repro.core.scheduler import PAD_SENTINEL

NEG_INF = -1e30
LANES = 128


def _kernel(t_ref, q_ref, k_ref, v_ref, pos_ref, out_ref,
            acc_ref, m_scr, l_scr, *, pattern: HybridSparsePattern,
            block_s: int, steps: int, scale: float):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    q = q_ref[0, 0]                                   # (rep, hd)
    k = k_ref[0, 0]                                   # (Bs, hd)
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # (rep, Bs)

    t = t_ref[0]
    pos_k = pos_ref[0]                                # (Bs,) int32
    a, _ = pattern.window
    g = pattern.n_global
    rel = pos_k - t
    mask = (rel >= a) & (rel <= 0)
    if pattern.dilation > 1:
        mask = mask & (rel % pattern.dilation == 0)
    if g > 0:
        mask = mask | (pos_k < g)
    mask = mask & (pos_k <= t)
    scores = jnp.where(mask[None, :], scores, NEG_INF)

    m_prev = m_scr[...][:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
    shift = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.where(mask[None, :], jnp.exp(scores - shift), 0.0)
    corr = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - shift))
    v = v_ref[0, 0]
    pv = jax.lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    @pl.when(s == steps - 1)
    def _fin():
        l = l_scr[...][:, :1]
        out_ref[0, 0] = (acc_ref[...] /
                         jnp.where(l == 0.0, 1.0, l)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("pattern", "block_s", "scale",
                                             "interpret"))
def salo_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                positions: jax.Array, t, *, pattern: HybridSparsePattern,
                block_s: int = 128, scale: Optional[float] = None,
                interpret: bool = False) -> jax.Array:
    """q: (B, H, 1, hd); caches: (B, Hkv, S, hd); positions: (S,) absolute
    position per slot (huge sentinel = empty). Returns (B, H, 1, hd)."""
    B, H, _, hd = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    rep = H // Hkv
    scale_ = (hd ** -0.5) if scale is None else scale
    S_pad = -(-S // block_s) * block_s
    if S_pad != S:
        padc = ((0, 0), (0, 0), (0, S_pad - S), (0, 0))
        k_cache = jnp.pad(k_cache, padc)
        v_cache = jnp.pad(v_cache, padc)
        positions = jnp.pad(positions, (0, S_pad - S),
                            constant_values=PAD_SENTINEL)
    steps = S_pad // block_s
    qg = q.reshape(B, Hkv, rep, hd)
    pos2d = positions.reshape(steps, block_s)
    t_arr = jnp.asarray(t, jnp.int32)[None]

    kern = functools.partial(_kernel, pattern=pattern, block_s=block_s,
                             steps=steps, scale=scale_)
    out = pl.pallas_call(
        kern,
        grid=(B, Hkv, steps),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, s: (0,)),                 # t
            pl.BlockSpec((1, 1, rep, hd), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_s, hd), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, block_s, hd), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, block_s), lambda b, h, s: (s, 0)),       # pos
        ],
        out_specs=pl.BlockSpec((1, 1, rep, hd), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, rep, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rep, hd), jnp.float32),
            pltpu.VMEM((rep, LANES), jnp.float32),
            pltpu.VMEM((rep, LANES), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="salo_decode",
    )(t_arr, qg, k_cache, v_cache, pos2d)
    return out.reshape(B, H, 1, hd)
