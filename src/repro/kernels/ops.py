"""jit'd wrapper around the SALO Pallas kernel — ONE launch per forward.

The lowering pipeline (core/scheduler.py): pattern -> BandSchedule ->
ExecutionPlan. This wrapper only does what a host must:

1. data reordering (dilation) on the host side of the kernel,
2. padding to the plan's tile grid,
3. ONE ``pallas_call`` executing the plan's step tables — every band and the
   global column fused, exactly as the paper's scheduler drives the array,
4. global rows (global queries attend everything) as a tiny g-row dense
   epilogue (not a kernel launch),
5. custom_vjp: backward = autodiff of the algorithmic twin
   (`core.blockwise`), which walks the SAME plan and recomputes activations
   flash-style (no O(n^2) residuals live).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.blockwise import blockwise_attention, _global_rows
from repro.core.patterns import HybridSparsePattern
from repro.core.scheduler import schedule
from repro.kernels.salo_attention import salo_plan_attention


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7))
def salo_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                   pattern: HybridSparsePattern,
                   block_q: int = 128, block_k: int = 128,
                   scale: Optional[float] = None,
                   interpret: bool = False) -> jax.Array:
    """Hybrid sparse attention via the Pallas kernel. q/k/v: (B, N, D)."""
    return _forward(q, k, v, pattern, block_q, block_k, scale, interpret)


def _forward(q, k, v, pattern, block_q, block_k, scale, interpret):
    B, N, D = q.shape
    scale_ = (D ** -0.5) if scale is None else scale
    sched = schedule(pattern, N)
    plan = sched.plan(block_q, block_k)
    out_dtype = q.dtype

    # --- data reordering (paper §4.2) ----------------------------------- #
    if sched.reordered:
        perm = jnp.asarray(sched.perm)
        take = jnp.clip(perm, 0, N - 1)
        valid = (perm < N)[None, :, None]
        qw = jnp.where(valid, jnp.take(q, take, axis=1), 0)
        kw = jnp.where(valid, jnp.take(k, take, axis=1), 0)
        vw = jnp.where(valid, jnp.take(v, take, axis=1), 0)
    else:
        qw, kw, vw = q, k, v

    pad = plan.n_pad - qw.shape[1]
    if pad:
        qw = jnp.pad(qw, ((0, 0), (0, pad), (0, 0)))
        kw = jnp.pad(kw, ((0, 0), (0, pad), (0, 0)))
        vw = jnp.pad(vw, ((0, 0), (0, pad), (0, 0)))
    pos = jnp.asarray(plan.positions_padded())

    # --- the single table-driven launch --------------------------------- #
    # (m, l) are emitted for cross-device merges; the full pattern is one
    # launch, so `out` is already the normalized result.
    out, _m, _l = salo_plan_attention(qw, kw, vw, pos, plan=plan,
                                      scale=scale_, interpret=interpret)
    out = out.astype(out_dtype)

    if sched.reordered:
        inv = jnp.asarray(sched.inverse_perm())
        out = jnp.take(out, inv, axis=1)
    else:
        out = out[:, :N]

    if sched.n_global > 0 and sched.global_rows:
        rows = _global_rows(q, k, v, sched, scale_, out_dtype)
        out = out.at[:, : sched.n_global].set(rows)
    return out


def _fwd(q, k, v, pattern, block_q, block_k, scale, interpret):
    out = _forward(q, k, v, pattern, block_q, block_k, scale, interpret)
    return out, (q, k, v)


def _bwd(pattern, block_q, block_k, scale, interpret, res, g):
    q, k, v = res
    # Backward through the algorithmic twin: identical plan walk,
    # autodiffable, flash-style memory (recompute, no n^2 residuals).
    _, vjp = jax.vjp(
        lambda q_, k_, v_: blockwise_attention(
            q_, k_, v_, pattern, block_q=block_q, block_k=block_k,
            scale=scale), q, k, v)
    return vjp(g)


salo_attention.defvjp(_fwd, _bwd)
