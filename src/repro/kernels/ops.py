"""jit'd wrapper around the SALO Pallas kernel.

Composes the full hybrid pattern from kernel calls, exactly as the paper's
data scheduler drives the accelerator:

1. data reordering (dilation) on the host side of the kernel,
2. one kernel launch per band; the global column fused into the first launch
   (non-reordered patterns) or computed as an extra partial (reordered —
   global tokens tap the ORIGINAL stream, paper §5.2),
3. partials merged with `core.renorm.merge` (paper Eq. 2),
4. global rows (global queries attend everything) as one dense flash pass,
5. custom_vjp: backward = autodiff of the algorithmic twin
   (`core.blockwise`), which recomputes activations flash-style (no O(n^2)
   residuals live).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import renorm
from repro.core.blockwise import blockwise_attention, _global_rows
from repro.core.patterns import HybridSparsePattern
from repro.core.scheduler import BIG, _round_up, schedule
from repro.kernels.salo_attention import salo_band_attention


def _to_state(out, m, l):
    """(normalized out, m, l) -> mergeable PartialState (acc = out * l)."""
    return renorm.PartialState(acc=out.astype(jnp.float32) * l[..., None],
                               m=m, l=l)


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7))
def salo_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                   pattern: HybridSparsePattern,
                   block_q: int = 128, block_k: int = 128,
                   scale: Optional[float] = None,
                   interpret: bool = False) -> jax.Array:
    """Hybrid sparse attention via the Pallas kernel. q/k/v: (B, N, D)."""
    return _forward(q, k, v, pattern, block_q, block_k, scale, interpret)


def _forward(q, k, v, pattern, block_q, block_k, scale, interpret):
    B, N, D = q.shape
    scale_ = (D ** -0.5) if scale is None else scale
    sched = schedule(pattern, N)
    out_dtype = q.dtype

    # --- data reordering (paper §4.2) ----------------------------------- #
    if sched.reordered:
        perm = jnp.asarray(sched.perm)
        take = jnp.clip(perm, 0, N - 1)
        valid = (perm < N)[None, :, None]
        qw = jnp.where(valid, jnp.take(q, take, axis=1), 0)
        kw = jnp.where(valid, jnp.take(k, take, axis=1), 0)
        vw = jnp.where(valid, jnp.take(v, take, axis=1), 0)
    else:
        qw, kw, vw = q, k, v

    n_pad = _round_up(sched.n_work, max(block_q, block_k))
    pad = n_pad - qw.shape[1]
    if pad:
        qw = jnp.pad(qw, ((0, 0), (0, pad), (0, 0)))
        kw = jnp.pad(kw, ((0, 0), (0, pad), (0, 0)))
        vw = jnp.pad(vw, ((0, 0), (0, pad), (0, 0)))
    pos = np.full(n_pad, BIG, dtype=np.int32)
    pos[: sched.n_work] = sched.positions()
    pos = jnp.asarray(pos)

    # --- one kernel launch per band; global fused into launch #0 -------- #
    fuse_global = sched.n_global > 0 and not sched.reordered
    state = None
    for bi, band in enumerate(sched.bands):
        out_b, m_b, l_b = salo_band_attention(
            qw, kw, vw, pos, sched=sched, band=band, block_q=block_q,
            block_k=block_k, fuse_global=(fuse_global and bi == 0),
            scale=scale_, interpret=interpret)
        st = _to_state(out_b, m_b, l_b)
        state = st if state is None else renorm.merge(state, st)

    # --- reordered patterns: global column taps the ORIGINAL stream ----- #
    if sched.n_global > 0 and sched.reordered:
        from repro.core.blockwise import _global_col_partial
        nq = n_pad // block_q
        q_blk = qw.reshape(B, nq, block_q, D)
        gst = renorm.empty_state((B, nq, block_q), D)
        gst = _global_col_partial(gst, q_blk, k, v, pos, sched, block_k,
                                  scale_)
        gst = renorm.PartialState(acc=gst.acc.reshape(B, n_pad, D),
                                  m=gst.m.reshape(B, n_pad),
                                  l=gst.l.reshape(B, n_pad))
        state = renorm.merge(state, gst)

    out = renorm.finalize(state, out_dtype)

    if sched.reordered:
        inv = jnp.asarray(sched.inverse_perm())
        out = jnp.take(out, inv, axis=1)
    else:
        out = out[:, :N]

    if sched.n_global > 0 and sched.global_rows:
        rows = _global_rows(q, k, v, sched, scale_, out_dtype)
        out = out.at[:, : sched.n_global].set(rows)
    return out


def _fwd(q, k, v, pattern, block_q, block_k, scale, interpret):
    out = _forward(q, k, v, pattern, block_q, block_k, scale, interpret)
    return out, (q, k, v)


def _bwd(pattern, block_q, block_k, scale, interpret, res, g):
    q, k, v = res
    # Backward through the algorithmic twin: identical math, autodiffable,
    # flash-style memory (recompute, no n^2 residuals).
    _, vjp = jax.vjp(
        lambda q_, k_, v_: blockwise_attention(
            q_, k_, v_, pattern, block_q=block_q, block_k=block_k,
            scale=scale), q, k, v)
    return vjp(g)


salo_attention.defvjp(_fwd, _bwd)
