"""jit'd wrapper around the SALO Pallas kernels — fully kernel-driven
forward AND backward.

The lowering pipeline (core/scheduler.py): pattern -> BandSchedule ->
ExecutionPlan. This wrapper only does what a host must:

1. data reordering (dilation) + padding to the plan's tile grid
   (``core.blockwise.working_stream`` — shared with the XLA engine),
2. ONE ``pallas_call`` executing the plan's step tables — every band and the
   global column fused, exactly as the paper's scheduler drives the array,
3. global rows (global queries attend everything) as a tiny g-row dense
   epilogue (not a kernel launch),
4. custom_vjp: the forward saves the kernel's already-emitted partial
   triple ``(out, m, l)`` as residuals, and the backward is exactly TWO
   plan-walking launches (kernels/salo_backward.py): dQ over the forward
   tables, dK/dV over the transposed tables, with ``p`` recomputed
   flash-style from the residuals — no forward re-run, no O(n^2) storage.
   Host-step adjoints (reorder/pad/global rows, the ``delta`` precompute)
   are the shared ``core.blockwise.plan_backward`` contract. When compiled
   (non-interpret) kernels are requested on a non-TPU backend — where the
   Pallas forward itself cannot execute — BOTH directions degrade to the
   XLA twin (blockwise forward + scan gradient engines): same plan walk,
   same residual contract, still no forward recompute in the VJP.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.blockwise import (_blockwise_forward, _global_rows,
                                  bwd_dkv_scan, bwd_dq_scan, plan_backward,
                                  undo_working, working_stream)
from repro.core.patterns import HybridSparsePattern
from repro.core.scheduler import schedule
from repro.kernels.salo_attention import salo_plan_attention
from repro.kernels.salo_backward import (salo_plan_backward_dq,
                                         salo_plan_backward_dkv)
from repro.obs.metrics import global_registry

# The launch contract :mod:`repro.analysis.jaxpr_lint` proves by tracing
# this wrapper: ONE fused ``pallas_call`` forward (the paper's
# single-launch claim), exactly THREE for the full gradient (fwd replay
# for residuals + dQ + dK/dV — a fourth launch means the custom_vjp
# regressed into recomputing the forward).
LAUNCH_CONTRACT = {"forward": 1, "grad": 3}


def _trace_accounting(kernel: str, plan, q, tiles: int) -> None:
    """Launch / deduped-tile / estimated-HBM-byte accounting, unified into
    the observability registry (the plan ``stats()`` numbers, recorded at
    the point a launch is actually built).

    This hook runs when JAX *traces* the wrapper — once per compilation,
    host-side, zero traced operands — so the counters measure launch
    STRUCTURE (launches per trace, tiles per launch, bytes per launch),
    which is exactly what the plan benchmarks gate. Runtime launch volume
    is the serving engine's job; it counts per executed step host-side.
    Byte estimate per launch: every executed tile streams one K and one V
    tile, every query block streams its Q tile in and its output tile out.
    """
    B, _, D = q.shape
    itemsize = jnp.dtype(q.dtype).itemsize
    est = B * itemsize * D * (2 * tiles * plan.block_k
                              + 2 * plan.nq * plan.block_q)
    reg = global_registry()
    reg.inc("kernel_trace_launches", kernel=kernel)
    reg.inc("kernel_trace_tiles", B * tiles, kernel=kernel)
    reg.inc("kernel_trace_est_hbm_bytes", est, kernel=kernel)


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7))
def salo_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                   pattern: HybridSparsePattern,
                   block_q: int = 128, block_k: int = 128,
                   scale: Optional[float] = None,
                   interpret: bool = False) -> jax.Array:
    """Hybrid sparse attention via the Pallas kernel. q/k/v: (B, N, D)."""
    out, _ = _forward(q, k, v, pattern, block_q, block_k, scale, interpret)
    return out


def _use_fallback(interpret):
    """Compiled (non-interpret) Pallas TPU kernels only execute on TPU;
    everywhere else the XLA twin stands in (same plan, same residuals)."""
    return not interpret and jax.default_backend() != "tpu"


def _forward(q, k, v, pattern, block_q, block_k, scale, interpret):
    """One fused launch + host steps. Returns ``(out, (out_w, m, l))`` —
    the kernel's working-space partial triple, kept as backward residuals
    instead of being thrown away."""
    B, N, D = q.shape
    sched = schedule(pattern, N)
    plan = sched.plan(block_q, block_k)
    fallback = _use_fallback(interpret)
    _trace_accounting("blockwise_forward" if fallback
                      else "salo_plan_attention", plan, q,
                      int(plan.num_steps.sum()))
    if fallback:
        return _blockwise_forward(q, k, v, pattern, block_q, block_k, scale)
    scale_ = (D ** -0.5) if scale is None else scale
    out_dtype = q.dtype

    # --- data reordering (paper §4.2) + tile-grid padding ---------------- #
    qw = working_stream(q, sched, plan)
    kw = working_stream(k, sched, plan)
    vw = working_stream(v, sched, plan)
    pos = jnp.asarray(plan.positions_padded())

    # --- the single table-driven launch --------------------------------- #
    # The full pattern is one launch, so `out_w` is already normalized;
    # (m, l) feed cross-device merges AND the fused backward.
    out_w, m, l = salo_plan_attention(qw, kw, vw, pos, plan=plan,
                                      scale=scale_, interpret=interpret)
    out_w = out_w.astype(out_dtype)

    out = undo_working(out_w, sched, N)

    if sched.n_global > 0 and sched.global_rows:
        rows = _global_rows(q, k, v, sched, scale_, out_dtype)
        out = out.at[:, : sched.n_global].set(rows)
    return out, (out_w, m, l)


def _fwd(q, k, v, pattern, block_q, block_k, scale, interpret):
    out, (out_w, m, l) = _forward(q, k, v, pattern, block_q, block_k, scale,
                                  interpret)
    return out, (q, k, v, out_w, m, l)


def _bwd(pattern, block_q, block_k, scale, interpret, res, g):
    q, k, v, out_w, m, l = res
    B, N, D = q.shape
    scale_ = (D ** -0.5) if scale is None else scale
    plan = schedule(pattern, N).plan(block_q, block_k)
    fb = "_scan" if _use_fallback(interpret) else ""
    _trace_accounting("salo_backward_dq" + fb, plan, q,
                      int(plan.num_steps.sum()))
    _trace_accounting("salo_backward_dkv" + fb, plan, q,
                      int(plan.transposed().num_steps.sum()))
    if _use_fallback(interpret):
        # The forward ran on the XLA twin (same residual contract); run the
        # blockwise (XLA scan) gradient engines too — same plan walk, same
        # residual reuse, same plan_backward contract, no forward recompute.
        dq_engine = functools.partial(bwd_dq_scan, plan=plan, scale=scale_)
        dkv_engine = functools.partial(bwd_dkv_scan, plan=plan, scale=scale_)
    else:
        # Exactly two launches: dQ (forward tables), dK/dV (transposed).
        dq_engine = functools.partial(salo_plan_backward_dq, plan=plan,
                                      scale=scale_, interpret=interpret)
        dkv_engine = functools.partial(salo_plan_backward_dkv, plan=plan,
                                       scale=scale_, interpret=interpret)
    return plan_backward(g, q, k, v, out_w, m, l, plan, scale_,
                         dq_engine, dkv_engine)


salo_attention.defvjp(_fwd, _bwd)
