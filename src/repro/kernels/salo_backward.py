"""Fused table-driven backward: flash-style dQ and dK/dV Pallas kernels.

The gradient counterpart of :mod:`repro.kernels.salo_attention` — SALO's
data-scheduler insight applied symmetrically to training. Exactly TWO
scalar-prefetch launches per backward, both recomputing the attention
probabilities from the forward's saved partial triple ``(out, m, l)``
(``p = exp(s - m) / l``) instead of re-running the forward:

* **dQ kernel** — replays the FORWARD plan (grid ``(B, nq, max_steps)``):
  the query tile, its cotangent and row stats stay resident while the
  plan's deduplicated KV tiles stream past, accumulating
  ``dq_i += scale * sum_j ds_ij k_j`` with ``ds = p * (dout.v - delta)``.
* **dK/dV kernel** — walks the TRANSPOSED plan
  (:meth:`ExecutionPlan.transposed`, grid ``(B, nkb, max_steps_t)``): each
  KV tile stays resident while the query blocks that visited it stream
  past, accumulating ``dv_j += sum_i p_ij dout_i`` and
  ``dk_j += scale * sum_i ds_ij q_i``. The transposed tables are the exact
  adjoint regrouping of the forward's deduplicated visits — same total
  tiles, no extra work.

The ``delta = sum(dout * out)`` rowwise precompute and every host-step
adjoint (global rows, reorder, pad) live in
:func:`repro.core.blockwise.plan_backward` — ONE backward contract shared
with the XLA scan engines; these kernels are its Pallas instantiation
(wired up in :mod:`repro.kernels.ops`).

Masking/padding follow the forward contract: per-step flags gate the union
mask, ``flags == 0`` steps (table padding) mask to nothing and leave the
accumulators untouched, and empty rows (``l == 0``, ``m == NEG_INF`` —
see :class:`repro.core.renorm.PartialState`) produce exactly zero
gradients via the guarded ``p`` recompute.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params
from repro.core.renorm import NEG_INF
from repro.core.scheduler import ExecutionPlan


def _p_ds(scores, mask, m_row, l_row, dp, delta):
    """Recomputed probabilities + score gradient (the in-kernel twin of
    ``core.blockwise.p_from_stats``). Guarded so empty rows (l == 0,
    m == NEG_INF) contribute exactly zero."""
    l_safe = jnp.where(l_row == 0.0, 1.0, l_row)
    shift = jnp.where(m_row <= NEG_INF / 2, 0.0, m_row)
    p = jnp.exp(scores - shift[:, None]) / l_safe[:, None]
    p = jnp.where(mask, p, 0.0)
    ds = p * (dp - delta[:, None])
    return p, ds


def _dq_kernel(kvt_ref, flg_ref,                                # prefetch
               pos_q_ref, pos_k_ref, q_ref, k_ref, v_ref,       # inputs
               do_ref, m_ref, l_ref, delta_ref,
               dq_ref,                                          # output
               acc_ref,                                         # scratch
               *, plan: ExecutionPlan, scale: float):
    i = pl.program_id(1)
    s = pl.program_id(2)
    steps = plan.max_steps

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                     # (Bq, D)
    k = k_ref[0]                                     # (Bk, D)
    v = v_ref[0]
    do = do_ref[0].astype(jnp.float32)               # (Bq, D)
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (Bq, Bk)

    fl = flg_ref[i * steps + s]
    mask = plan.step_mask(pos_q_ref[0][:, None], pos_k_ref[0][None, :], fl)
    dp = jax.lax.dot_general(
        do, v.astype(jnp.float32), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (Bq, Bk)
    _, ds = _p_ds(scores, mask, m_ref[0], l_ref[0], dp, delta_ref[0])

    acc_ref[...] += jax.lax.dot_general(
        ds, k.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (Bq, D)

    @pl.when(s == steps - 1)
    def _fin():
        dq_ref[0] = acc_ref[...].astype(dq_ref.dtype)


def _dkv_kernel(qbt_ref, flg_ref,                               # prefetch
                pos_k_ref, pos_q_ref, q_ref, k_ref, v_ref,      # inputs
                do_ref, m_ref, l_ref, delta_ref,
                dk_ref, dv_ref,                                 # outputs
                dk_acc, dv_acc,                                 # scratch
                *, plan: ExecutionPlan, scale: float):
    j = pl.program_id(1)
    s = pl.program_id(2)
    steps = plan.transposed().max_steps

    @pl.when(s == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[0]                                     # (Bq, D)
    k = k_ref[0]                                     # (Bk, D) resident
    v = v_ref[0]
    do = do_ref[0].astype(jnp.float32)
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (Bq, Bk)

    fl = flg_ref[j * steps + s]
    mask = plan.step_mask(pos_q_ref[0][:, None], pos_k_ref[0][None, :], fl)
    dp = jax.lax.dot_general(
        do, v.astype(jnp.float32), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    p, ds = _p_ds(scores, mask, m_ref[0], l_ref[0], dp, delta_ref[0])

    # Contract over the streaming query dimension: p^T dout and ds^T q.
    dv_acc[...] += jax.lax.dot_general(
        p, do, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # (Bk, D)
    dk_acc[...] += jax.lax.dot_general(
        ds, q.astype(jnp.float32), (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * scale

    @pl.when(s == steps - 1)
    def _fin():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


@functools.partial(jax.jit, static_argnames=("plan", "scale", "interpret"))
def salo_plan_backward_dq(dout, delta, m, l, q, k, v, pos, *,
                          plan: ExecutionPlan, scale: float,
                          interpret: bool = False) -> jax.Array:
    """dQ in ONE launch over the forward plan. All arrays working-space
    padded: q/k/v/dout (B, n_pad, D); delta/m/l (B, n_pad); pos (n_pad,).
    """
    B, n_pad, D = q.shape
    assert n_pad == plan.n_pad, (n_pad, plan.n_pad)
    bq, bk = plan.block_q, plan.block_k
    nq, nkb, steps = plan.nq, plan.nkb, plan.max_steps

    kvt = jnp.asarray(plan.kv_blocks.reshape(-1))    # (nq*steps,) int32
    flg = jnp.asarray(plan.flags.reshape(-1))
    pos_q = pos.reshape(nq, bq)
    pos_k = pos.reshape(nkb, bk)

    def q_idx(b, i, s, kvt_ref, flg_ref):
        return (b, i, 0)

    def kv_idx(b, i, s, kvt_ref, flg_ref):
        return (b, kvt_ref[i * steps + s], 0)

    def row_idx(b, i, s, kvt_ref, flg_ref):
        return (b, i)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, nq, steps),
        in_specs=[
            pl.BlockSpec((1, bq),
                         lambda b, i, s, kvt_ref, flg_ref: (i, 0)),  # pos_q
            pl.BlockSpec((1, bk),
                         lambda b, i, s, kvt_ref, flg_ref:
                         (kvt_ref[i * steps + s], 0)),               # pos_k
            pl.BlockSpec((1, bq, D), q_idx),                         # q
            pl.BlockSpec((1, bk, D), kv_idx),                        # k
            pl.BlockSpec((1, bk, D), kv_idx),                        # v
            pl.BlockSpec((1, bq, D), q_idx),                         # dout
            pl.BlockSpec((1, bq), row_idx),                          # m
            pl.BlockSpec((1, bq), row_idx),                          # l
            pl.BlockSpec((1, bq), row_idx),                          # delta
        ],
        out_specs=pl.BlockSpec((1, bq, D), q_idx),
        scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32)],
    )

    kern = functools.partial(_dq_kernel, plan=plan, scale=scale)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, n_pad, D), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="salo_plan_backward_dq",
    )(kvt, flg, pos_q, pos_k, q, k, v, dout, m, l, delta)


@functools.partial(jax.jit, static_argnames=("plan", "scale", "interpret"))
def salo_plan_backward_dkv(dout, delta, m, l, q, k, v, pos, *,
                           plan: ExecutionPlan, scale: float,
                           interpret: bool = False):
    """dK and dV in ONE launch over the transposed plan. Returns
    ``(dk, dv)``, both (B, n_pad, D) working-space padded."""
    B, n_pad, D = q.shape
    assert n_pad == plan.n_pad, (n_pad, plan.n_pad)
    bq, bk = plan.block_q, plan.block_k
    nq, nkb = plan.nq, plan.nkb
    tp = plan.transposed()
    steps = tp.max_steps

    qbt = jnp.asarray(tp.q_blocks.reshape(-1))       # (nkb*steps,) int32
    flg = jnp.asarray(tp.flags.reshape(-1))
    pos_q = pos.reshape(nq, bq)
    pos_k = pos.reshape(nkb, bk)

    def kv_idx(b, j, s, qbt_ref, flg_ref):
        return (b, j, 0)

    def q_idx(b, j, s, qbt_ref, flg_ref):
        return (b, qbt_ref[j * steps + s], 0)

    def row_idx(b, j, s, qbt_ref, flg_ref):
        return (b, qbt_ref[j * steps + s])

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, nkb, steps),
        in_specs=[
            pl.BlockSpec((1, bk),
                         lambda b, j, s, qbt_ref, flg_ref: (j, 0)),  # pos_k
            pl.BlockSpec((1, bq),
                         lambda b, j, s, qbt_ref, flg_ref:
                         (qbt_ref[j * steps + s], 0)),               # pos_q
            pl.BlockSpec((1, bq, D), q_idx),                         # q
            pl.BlockSpec((1, bk, D), kv_idx),                        # k
            pl.BlockSpec((1, bk, D), kv_idx),                        # v
            pl.BlockSpec((1, bq, D), q_idx),                         # dout
            pl.BlockSpec((1, bq), row_idx),                          # m
            pl.BlockSpec((1, bq), row_idx),                          # l
            pl.BlockSpec((1, bq), row_idx),                          # delta
        ],
        out_specs=[
            pl.BlockSpec((1, bk, D), kv_idx),
            pl.BlockSpec((1, bk, D), kv_idx),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, D), jnp.float32),        # dk accumulator
            pltpu.VMEM((bk, D), jnp.float32),        # dv accumulator
        ],
    )

    kern = functools.partial(_dkv_kernel, plan=plan, scale=scale)
    dk, dv = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, n_pad, D), k.dtype),
            jax.ShapeDtypeStruct((B, n_pad, D), v.dtype),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="salo_plan_backward_dkv",
    )(qbt, flg, pos_k, pos_q, q, k, v, dout, m, l, delta)
    return dk, dv
