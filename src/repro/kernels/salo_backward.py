"""Fused table-driven backward: flash-style dQ and dK/dV Pallas kernels.

The gradient counterpart of :mod:`repro.kernels.salo_attention` — SALO's
data-scheduler insight applied symmetrically to training. Exactly TWO
scalar-prefetch launches per backward, both recomputing the attention
probabilities from the forward's saved partial triple ``(out, m, l)``
(``p = exp(s - m) / l``) instead of re-running the forward:

* **dQ kernel** — replays the FORWARD plan (grid ``(B, nq, max_steps)``):
  the query tile, its cotangent and row stats stay resident while the
  plan's deduplicated KV tiles stream past, accumulating
  ``dq_i += scale * sum_j ds_ij k_j`` with ``ds = p * (dout.v - delta)``.
* **dK/dV kernel** — walks the PACKED transposed plan
  (:meth:`ExecutionPlan.transposed_packed`, grid ``(B, n_rows, width)``):
  each packed row keeps its owner KV tile resident while its slice of
  visiting query blocks streams past, accumulating
  ``dv_j += sum_i p_ij dout_i`` and ``dk_j += scale * sum_i ds_ij q_i``;
  per-row partials are scatter-added per owner tile afterwards. The
  transposed tables are the exact adjoint regrouping of the forward's
  deduplicated visits — same total tiles, no extra work — and packing
  keeps global-column patterns (whose global KV tile is visited by every
  query block) from padding every other row to that ragged width.

The ``delta = sum(dout * out)`` rowwise precompute and every host-step
adjoint (global rows, reorder, pad) live in
:func:`repro.core.blockwise.plan_backward` — ONE backward contract shared
with the XLA scan engines; these kernels are its Pallas instantiation
(wired up in :mod:`repro.kernels.ops`).

Masking/padding follow the forward contract: per-step flags gate the union
mask, ``flags == 0`` steps (table padding) mask to nothing and leave the
accumulators untouched, and empty rows (``l == 0``, ``m == NEG_INF`` —
see :class:`repro.core.renorm.PartialState`) produce exactly zero
gradients via the guarded ``p`` recompute.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params
from repro.core.renorm import NEG_INF
from repro.core.scheduler import BandSchedule, ExecutionPlan


def _p_ds(scores, mask, m_row, l_row, dp, delta):
    """Recomputed probabilities + score gradient (the in-kernel twin of
    ``core.blockwise.p_from_stats``). Guarded so empty rows (l == 0,
    m == NEG_INF) contribute exactly zero."""
    l_safe = jnp.where(l_row == 0.0, 1.0, l_row)
    shift = jnp.where(m_row <= NEG_INF / 2, 0.0, m_row)
    p = jnp.exp(scores - shift[:, None]) / l_safe[:, None]
    p = jnp.where(mask, p, 0.0)
    ds = p * (dp - delta[:, None])
    return p, ds


def _dq_kernel(kvt_ref, flg_ref,                                # prefetch
               pos_q_ref, pos_k_ref, q_ref, k_ref, v_ref,       # inputs
               do_ref, m_ref, l_ref, delta_ref,
               dq_ref,                                          # output
               acc_ref,                                         # scratch
               *, sched: BandSchedule, steps: int, scale: float):
    i = pl.program_id(1)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                     # (Bq, D)
    k = k_ref[0]                                     # (Bk, D)
    v = v_ref[0]
    do = do_ref[0].astype(jnp.float32)               # (Bq, D)
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (Bq, Bk)

    fl = flg_ref[i * steps + s]
    mask = sched.step_mask(pos_q_ref[0][:, None], pos_k_ref[0][None, :], fl)
    dp = jax.lax.dot_general(
        do, v.astype(jnp.float32), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (Bq, Bk)
    _, ds = _p_ds(scores, mask, m_ref[0], l_ref[0], dp, delta_ref[0])

    acc_ref[...] += jax.lax.dot_general(
        ds, k.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (Bq, D)

    @pl.when(s == steps - 1)
    def _fin():
        dq_ref[0] = acc_ref[...].astype(dq_ref.dtype)


def _dkv_kernel(rt_ref, qbt_ref, flg_ref,                       # prefetch
                pos_k_ref, pos_q_ref, q_ref, k_ref, v_ref,      # inputs
                do_ref, m_ref, l_ref, delta_ref,
                dk_ref, dv_ref,                                 # outputs
                dk_acc, dv_acc,                                 # scratch
                *, sched: BandSchedule, steps: int, scale: float):
    r = pl.program_id(1)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[0]                                     # (Bq, D)
    k = k_ref[0]                                     # (Bk, D) resident
    v = v_ref[0]
    do = do_ref[0].astype(jnp.float32)
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (Bq, Bk)

    fl = flg_ref[r * steps + s]
    mask = sched.step_mask(pos_q_ref[0][:, None], pos_k_ref[0][None, :], fl)
    dp = jax.lax.dot_general(
        do, v.astype(jnp.float32), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    p, ds = _p_ds(scores, mask, m_ref[0], l_ref[0], dp, delta_ref[0])

    # Contract over the streaming query dimension: p^T dout and ds^T q.
    dv_acc[...] += jax.lax.dot_general(
        p, do, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # (Bk, D)
    dk_acc[...] += jax.lax.dot_general(
        ds, q.astype(jnp.float32), (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * scale

    @pl.when(s == steps - 1)
    def _fin():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


@functools.partial(jax.jit, static_argnames=("sched", "block_q", "block_k",
                                             "scale", "interpret"))
def salo_table_backward_dq(dout, delta, m, l, q, k, v, pos_q, pos_k,
                           kvt, flg, *, sched: BandSchedule, block_q: int,
                           block_k: int, scale: float,
                           interpret: bool = False) -> jax.Array:
    """dQ in ONE launch over forward step tables passed as traced operands
    (the ShardedPlan per-device slice under ``shard_map``, or the plan's
    own tables via :func:`salo_plan_backward_dq`). The q side
    (q/dout/delta/m/l, length nq*block_q) and KV side (k/v, length
    nkb*block_k) may differ; kvt/flg: (nq*steps,) int32.
    """
    B, nQ, D = q.shape
    bq, bk = block_q, block_k
    nq = nQ // bq
    nkb = k.shape[1] // bk
    steps = kvt.shape[0] // nq

    def q_idx(b, i, s, kvt_ref, flg_ref):
        return (b, i, 0)

    def kv_idx(b, i, s, kvt_ref, flg_ref):
        return (b, kvt_ref[i * steps + s], 0)

    def row_idx(b, i, s, kvt_ref, flg_ref):
        return (b, i)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, nq, steps),
        in_specs=[
            pl.BlockSpec((1, bq),
                         lambda b, i, s, kvt_ref, flg_ref: (i, 0)),  # pos_q
            pl.BlockSpec((1, bk),
                         lambda b, i, s, kvt_ref, flg_ref:
                         (kvt_ref[i * steps + s], 0)),               # pos_k
            pl.BlockSpec((1, bq, D), q_idx),                         # q
            pl.BlockSpec((1, bk, D), kv_idx),                        # k
            pl.BlockSpec((1, bk, D), kv_idx),                        # v
            pl.BlockSpec((1, bq, D), q_idx),                         # dout
            pl.BlockSpec((1, bq), row_idx),                          # m
            pl.BlockSpec((1, bq), row_idx),                          # l
            pl.BlockSpec((1, bq), row_idx),                          # delta
        ],
        out_specs=pl.BlockSpec((1, bq, D), q_idx),
        scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32)],
    )

    kern = functools.partial(_dq_kernel, sched=sched, steps=steps,
                             scale=scale)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, nQ, D), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="salo_plan_backward_dq",
    )(kvt, flg, pos_q, pos_k, q, k, v, dout, m, l, delta)


def salo_plan_backward_dq(dout, delta, m, l, q, k, v, pos, *,
                          plan: ExecutionPlan, scale: float,
                          interpret: bool = False) -> jax.Array:
    """dQ in ONE launch over the forward plan. All arrays working-space
    padded: q/k/v/dout (B, n_pad, D); delta/m/l (B, n_pad); pos (n_pad,).
    """
    B, n_pad, D = q.shape
    assert n_pad == plan.n_pad, (n_pad, plan.n_pad)
    return salo_table_backward_dq(
        dout, delta, m, l, q, k, v,
        pos.reshape(plan.nq, plan.block_q),
        pos.reshape(plan.nkb, plan.block_k),
        jnp.asarray(plan.kv_blocks.reshape(-1)),
        jnp.asarray(plan.flags.reshape(-1)),
        sched=plan.sched, block_q=plan.block_q, block_k=plan.block_k,
        scale=scale, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("sched", "block_q", "block_k",
                                             "nkb", "scale", "interpret"))
def salo_table_backward_dkv(dout, delta, m, l, q, k, v, pos_q, pos_k,
                            row_tile, qbt, flg, *, sched: BandSchedule,
                            block_q: int, block_k: int, nkb: int,
                            scale: float, interpret: bool = False):
    """dK and dV in ONE launch over PACKED transposed tables.

    Grid row ``r`` keeps KV tile ``row_tile[r]`` resident while its slice
    of visiting query blocks streams past; per-row partials land in a
    (B, n_rows*block_k, D) buffer and are scatter-added per owner tile on
    the host side (rows split from one ragged transposed row — the
    global-column tile that every query block visits — recombine there).
    row_tile: (R,); qbt/flg: (R*W,) int32 flattened. Returns ``(dk, dv)``,
    both (B, nkb*block_k, D) float32.
    """
    B, nQ, D = q.shape
    bq, bk = block_q, block_k
    R = row_tile.shape[0]
    steps = qbt.shape[0] // R

    def kv_idx(b, r, s, rt_ref, qbt_ref, flg_ref):
        return (b, r, 0)

    def q_idx(b, r, s, rt_ref, qbt_ref, flg_ref):
        return (b, qbt_ref[r * steps + s], 0)

    def row_idx(b, r, s, rt_ref, qbt_ref, flg_ref):
        return (b, qbt_ref[r * steps + s])

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, R, steps),
        in_specs=[
            pl.BlockSpec((1, bk),
                         lambda b, r, s, rt_ref, qbt_ref, flg_ref:
                         (rt_ref[r], 0)),                            # pos_k
            pl.BlockSpec((1, bq),
                         lambda b, r, s, rt_ref, qbt_ref, flg_ref:
                         (qbt_ref[r * steps + s], 0)),               # pos_q
            pl.BlockSpec((1, bq, D), q_idx),                         # q
            pl.BlockSpec((1, bk, D),
                         lambda b, r, s, rt_ref, qbt_ref, flg_ref:
                         (b, rt_ref[r], 0)),                         # k
            pl.BlockSpec((1, bk, D),
                         lambda b, r, s, rt_ref, qbt_ref, flg_ref:
                         (b, rt_ref[r], 0)),                         # v
            pl.BlockSpec((1, bq, D), q_idx),                         # dout
            pl.BlockSpec((1, bq), row_idx),                          # m
            pl.BlockSpec((1, bq), row_idx),                          # l
            pl.BlockSpec((1, bq), row_idx),                          # delta
        ],
        out_specs=[
            pl.BlockSpec((1, bk, D), kv_idx),
            pl.BlockSpec((1, bk, D), kv_idx),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, D), jnp.float32),        # dk accumulator
            pltpu.VMEM((bk, D), jnp.float32),        # dv accumulator
        ],
    )

    kern = functools.partial(_dkv_kernel, sched=sched, steps=steps,
                             scale=scale)
    dk_r, dv_r = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, R * bk, D), jnp.float32),
            jax.ShapeDtypeStruct((B, R * bk, D), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="salo_plan_backward_dkv",
    )(row_tile, qbt, flg, pos_k, pos_q, q, k, v, dout, m, l, delta)
    z = jnp.zeros((B, nkb, bk, D), jnp.float32)
    dk = z.at[:, row_tile].add(dk_r.reshape(B, R, bk, D))
    dv = z.at[:, row_tile].add(dv_r.reshape(B, R, bk, D))
    return dk.reshape(B, nkb * bk, D), dv.reshape(B, nkb * bk, D)


def salo_plan_backward_dkv(dout, delta, m, l, q, k, v, pos, *,
                           plan: ExecutionPlan, scale: float,
                           interpret: bool = False):
    """dK and dV in ONE launch over the packed transposed plan. Returns
    ``(dk, dv)``, both (B, n_pad, D) working-space padded."""
    B, n_pad, D = q.shape
    assert n_pad == plan.n_pad, (n_pad, plan.n_pad)
    pk = plan.transposed_packed()
    return salo_table_backward_dkv(
        dout, delta, m, l, q, k, v,
        pos.reshape(plan.nq, plan.block_q),
        pos.reshape(plan.nkb, plan.block_k),
        jnp.asarray(pk.row_tile),
        jnp.asarray(pk.q_blocks.reshape(-1)),
        jnp.asarray(pk.flags.reshape(-1)),
        sched=plan.sched, block_q=plan.block_q, block_k=plan.block_k,
        nkb=plan.nkb, scale=scale, interpret=interpret)
