"""SALO hybrid sparse attention as ONE table-driven Pallas TPU kernel.

The TPU-native incarnation of the paper's spatial accelerator (DESIGN.md §2),
driven by the :class:`repro.core.scheduler.ExecutionPlan` IR:

* The MXU plays the 32x32 PE systolic array: each grid step multiplies a
  resident (block_q, D) query tile against a streamed (block_k, D) K tile and
  the matching V tile — stage 1 and stage 5 of the paper's 5-stage PE pipeline
  collapse into two MXU contractions.
* The paper's data scheduler becomes the plan's **step table**, streamed in
  via scalar prefetch (``PrefetchScalarGridSpec``): step ``s`` of query block
  ``i`` fetches KV tile ``kv_blocks[i, s]`` HBM->VMEM. The table is the union
  of every band's walk plus the global-key tiles, deduplicated — overlapping
  bands (ViL's 15) share one visit per tile, and global attention rides the
  same stream ("simultaneously with the same input vectors", paper §5.2)
  instead of a separate pass. One ``pallas_call`` per forward, period.
* The paper's window splitting + weighted-sum module (Eq. 2) is the online
  softmax accumulator in VMEM scratch: (acc, m, l) updated once per visited
  tile — no per-band partials, no inter-launch merges.
* Masks come from *original token positions* streamed as int32 tiles plus the
  plan's per-step flags, so dilation-reordered inputs, 2-D grids, global
  columns, and padding are all the same code path (core/scheduler.py).
  (Global *rows* — global queries attending everything — are a tiny dense
  epilogue over g rows in ops.py, not a kernel launch.)

Grid: ``(B, num_q_blocks, plan.max_steps)``; the last dimension is
sequential ("arbitrary"), the first two parallel. Padding steps (flags == 0)
mask to nothing and leave the accumulator untouched.

The kernel emits the *partial state* (normalized out, m, l) so cross-device
sequence parallelism can still merge outputs with `core.renorm.merge` AND so
the fused backward (kernels/salo_backward.py) can recompute attention
probabilities from it instead of re-running the forward. Empty rows follow
the renorm.PartialState contract: (out=0, m=NEG_INF, l=0) — the merge
identity, and exactly zero gradient through the backward's guards.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params
from repro.core.scheduler import BandSchedule, ExecutionPlan

NEG_INF = -1e30
LANES = 128  # TPU vector lane count; m/l scratch is lane-replicated


def _kernel(kvt_ref, flg_ref,                           # scalar prefetch
            pos_q_ref, pos_k_ref, q_ref, k_ref, v_ref,  # inputs
            out_ref, m_ref, l_ref,                      # outputs
            acc_ref, m_scr, l_scr,                      # VMEM scratch
            *, sched: BandSchedule, steps: int, scale: float):
    i = pl.program_id(1)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    q = q_ref[0]                                     # (Bq, D)
    k = k_ref[0]                                     # (Bk, D)
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (Bq, Bk)

    # ---- plan mask: window | global column, gated by the step flags ---- #
    fl = flg_ref[i * steps + s]                      # int32 scalar
    pos_q = pos_q_ref[0]                             # (Bq,) int32
    pos_k = pos_k_ref[0]                             # (Bk,) int32
    mask = sched.step_mask(pos_q[:, None], pos_k[None, :], fl)

    scores = jnp.where(mask, scores, NEG_INF)

    # ---- online softmax update (paper Eq. 2, stabilized) ---------------- #
    m_prev = m_scr[...][:, :1]                        # (Bq, 1)
    m_tile = jnp.max(scores, axis=-1, keepdims=True)  # (Bq, 1)
    m_new = jnp.maximum(m_prev, m_tile)
    shift = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.exp(scores - shift)
    p = jnp.where(mask, p, 0.0)
    corr = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - shift))

    v = v_ref[0]                                      # (Bk, D)
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)           # (Bq, D)
    acc_ref[...] = acc_ref[...] * corr + pv
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    # ---- finalize on the last sequential step ---------------------------- #
    @pl.when(s == steps - 1)
    def _fin():
        # Empty-row contract (shared with renorm.PartialState): a row whose
        # EVERY step masked to nothing — tile-grid padding, or a pattern
        # row with no reachable key — emits exactly (out=0, m=NEG_INF,
        # l=0), the identity element of renorm.merge. The l == 0 guard
        # below only protects the normalization; m is deliberately left at
        # NEG_INF so merges keep zero weight and the fused backward's
        # p-recompute / delta term (kernels/salo_backward.py) sees the
        # same guarded branch and yields exactly zero gradients.
        l = l_scr[...][:, :1]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        out_ref[0] = (acc_ref[...] / l_safe).astype(out_ref.dtype)
        m_ref[0] = m_scr[...][:, 0]
        l_ref[0] = l_scr[...][:, 0]


@functools.partial(jax.jit, static_argnames=("sched", "block_q", "block_k",
                                             "scale", "interpret"))
def salo_table_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                         pos_q: jax.Array, pos_k: jax.Array,
                         kvt: jax.Array, flg: jax.Array, *,
                         sched: BandSchedule, block_q: int, block_k: int,
                         scale: float, interpret: bool = False):
    """The table-driven launch with the step tables as *traced operands*.

    The tables only reach the kernel through scalar prefetch, so their
    values may be runtime data — e.g. a per-device slice of the
    ShardedPlan's stacked tables selected by ``axis_index`` under
    ``shard_map``. The q side and KV side may differ in length (the sharded
    local view streams ``nkb_view`` tiles past ``nq_local`` query blocks).

    q: (B, nq*block_q, D); k/v: (B, nkb*block_k, D); pos_q: (nq, block_q);
    pos_k: (nkb, block_k); kvt/flg: (nq*steps,) int32 flattened tables.
    Returns (out, m, l) exactly like :func:`salo_plan_attention`.
    """
    B, nQ, D = q.shape
    assert nQ % block_q == 0 and k.shape[1] % block_k == 0, \
        (nQ, block_q, k.shape[1], block_k)
    nq = nQ // block_q
    steps = kvt.shape[0] // nq

    def kv_idx(b, i, s, kvt_ref, flg_ref):
        return (b, kvt_ref[i * steps + s], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, nq, steps),
        in_specs=[
            pl.BlockSpec((1, block_q),
                         lambda b, i, s, kvt_ref, flg_ref: (i, 0)),  # pos_q
            pl.BlockSpec((1, block_k),
                         lambda b, i, s, kvt_ref, flg_ref:
                         (kvt_ref[i * steps + s], 0)),               # pos_k
            pl.BlockSpec((1, block_q, D),
                         lambda b, i, s, kvt_ref, flg_ref: (b, i, 0)),  # q
            pl.BlockSpec((1, block_k, D), kv_idx),                      # k
            pl.BlockSpec((1, block_k, D), kv_idx),                      # v
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, D),
                         lambda b, i, s, kvt_ref, flg_ref: (b, i, 0)),
            pl.BlockSpec((1, block_q),
                         lambda b, i, s, kvt_ref, flg_ref: (b, i)),
            pl.BlockSpec((1, block_q),
                         lambda b, i, s, kvt_ref, flg_ref: (b, i)),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),      # acc
            pltpu.VMEM((block_q, LANES), jnp.float32),  # m (lane-replicated)
            pltpu.VMEM((block_q, LANES), jnp.float32),  # l
        ],
    )

    kern = functools.partial(_kernel, sched=sched, steps=steps, scale=scale)
    out, m, l = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, nQ, D), q.dtype),
            jax.ShapeDtypeStruct((B, nQ), jnp.float32),
            jax.ShapeDtypeStruct((B, nQ), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="salo_plan_attention",
    )(kvt, flg, pos_q, pos_k, q, k, v)
    return out, m, l


def salo_plan_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                        pos: jax.Array, *, plan: ExecutionPlan,
                        scale: Optional[float] = None,
                        interpret: bool = False):
    """The whole hybrid pattern (all bands + global column) in ONE launch.

    q/k/v: (B, n_pad, D) padded working-space inputs; pos: (n_pad,) original
    positions. Returns (out, m, l): normalized output and softmax stats — a
    mergeable partial (out*l rebuilds `renorm.PartialState.acc`).
    """
    B, n_pad, D = q.shape
    assert n_pad == plan.n_pad, (n_pad, plan.n_pad)
    scale = (D ** -0.5) if scale is None else scale
    return salo_table_attention(
        q, k, v,
        pos.reshape(plan.nq, plan.block_q),
        pos.reshape(plan.nkb, plan.block_k),
        jnp.asarray(plan.kv_blocks.reshape(-1)),
        jnp.asarray(plan.flags.reshape(-1)),
        sched=plan.sched, block_q=plan.block_q, block_k=plan.block_k,
        scale=scale, interpret=interpret)
