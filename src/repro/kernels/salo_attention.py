"""SALO banded attention as a Pallas TPU kernel.

The TPU-native incarnation of the paper's spatial accelerator (DESIGN.md §2):

* The MXU plays the 32x32 PE systolic array: each grid step multiplies a
  resident (block_q, D) query tile against a streamed (block_k, D) K tile and
  the matching V tile — stage 1 and stage 5 of the paper's 5-stage PE pipeline
  collapse into two MXU contractions.
* The paper's diagonal K/V streaming (data reuse between successive queries)
  becomes the banded KV walk: for query block ``i`` only the KV tiles
  intersecting the window band are fetched HBM->VMEM. Work per query block is
  O(band), not O(n) — linear total complexity.
* The paper's window splitting + weighted-sum module (Eq. 2) is the online
  softmax accumulator in VMEM scratch: (acc, m, l) updated per KV tile.
* The paper's global PE column (every query attends the global-token keys) is
  fused into the same grid as ``grid_global`` leading steps that walk the
  global key prefix of the SAME K/V stream — no extra HBM pass, mirroring
  SALO's "compute global attention simultaneously with the same input
  vectors". (Global *rows* — global queries attending everything — are one
  extra dense flash pass over the same stream, done by ops.py.)

The kernel emits the *partial state* (normalized out, m, l) so multi-band
patterns (ViL's 15 bands) and cross-device sequence parallelism can merge
kernels' outputs with `core.renorm.merge` — exactly the paper's scheme.

Grid: ``(B, num_q_blocks, grid_global + band_steps)``; the last dimension is
sequential ("arbitrary"), the first two parallel.

Masks are evaluated from *original token positions* streamed in as int32
tiles, so dilation-reordered inputs and padding are handled uniformly
(see core/scheduler.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.scheduler import BandSchedule, Band

NEG_INF = -1e30
LANES = 128  # TPU vector lane count; m/l scratch is lane-replicated


def _kernel(pos_q_ref, pos_k_ref, q_ref, k_ref, v_ref,      # inputs
            out_ref, m_ref, l_ref,                          # outputs
            acc_ref, m_scr, l_scr,                          # VMEM scratch
            *, sched: BandSchedule, band: Band, block_q: int, block_k: int,
            grid_global: int, steps: int, nkb: int, scale: float):
    i = pl.program_id(1)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    # ---- recompute the (signed, unclamped) KV tile this step addresses ---- #
    s0 = (i * block_q + band.lo) // block_k          # first band tile (signed)
    is_band = s >= grid_global
    blk = jnp.where(is_band, s0 + s - grid_global, s)
    in_range = (blk >= 0) & (blk < nkb)

    q = q_ref[0]                                     # (Bq, D)
    k = k_ref[0]                                     # (Bk, D)
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (Bq, Bk)

    # ---- masks from original positions (dilation/2-D/causal/padding) ---- #
    pos_q = pos_q_ref[0]                             # (Bq,) int32
    pos_k = pos_k_ref[0]                             # (Bk,) int32
    pi = pos_q[:, None]
    pj = pos_k[None, :]
    wmask = sched.window_mask(pi, pj)
    # Working-space band restriction (prevents double-count across bands).
    wi = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    wj = blk * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    rel_w = wj - wi
    band_mask = wmask & (rel_w >= band.lo) & (rel_w <= band.hi)
    if grid_global > 0:
        gmask = sched.global_col_mask(pi, pj)
        mask = jnp.where(is_band, band_mask, gmask)
    else:
        mask = band_mask
    mask = mask & in_range

    scores = jnp.where(mask, scores, NEG_INF)

    # ---- online softmax update (paper Eq. 2, stabilized) ---------------- #
    m_prev = m_scr[...][:, :1]                        # (Bq, 1)
    m_tile = jnp.max(scores, axis=-1, keepdims=True)  # (Bq, 1)
    m_new = jnp.maximum(m_prev, m_tile)
    shift = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.exp(scores - shift)
    p = jnp.where(mask, p, 0.0)
    corr = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - shift))

    v = v_ref[0]                                      # (Bk, D)
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)           # (Bq, D)
    acc_ref[...] = acc_ref[...] * corr + pv
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)

    # ---- finalize on the last sequential step ---------------------------- #
    @pl.when(s == steps - 1)
    def _fin():
        l = l_scr[...][:, :1]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        out_ref[0] = (acc_ref[...] / l_safe).astype(out_ref.dtype)
        m_ref[0] = m_scr[...][:, 0]
        l_ref[0] = l_scr[...][:, 0]


@functools.partial(jax.jit, static_argnames=(
    "sched", "band", "block_q", "block_k", "fuse_global", "scale",
    "interpret"))
def salo_band_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                        pos: jax.Array, *, sched: BandSchedule, band: Band,
                        block_q: int = 128, block_k: int = 128,
                        fuse_global: bool = False,
                        scale: Optional[float] = None,
                        interpret: bool = False):
    """One band (+ optionally fused global column) on padded working-space
    inputs. q/k/v: (B, n_pad, D); pos: (n_pad,) original positions.

    Returns (out, m, l): normalized output and softmax stats — a mergeable
    partial (out*l rebuilds `renorm.PartialState.acc`).
    """
    B, n_pad, D = q.shape
    assert n_pad % block_q == 0 and n_pad % block_k == 0
    scale = (D ** -0.5) if scale is None else scale
    nq = n_pad // block_q
    nkb = n_pad // block_k

    g = sched.n_global if fuse_global else 0
    grid_global = -(-g // block_k) if g > 0 else 0    # ceil
    steps = grid_global + band.kv_steps(block_q, block_k)

    pos_q = pos.reshape(nq, block_q)
    pos_k = pos.reshape(nkb, block_k)

    def kv_idx(b, i, s):
        s0 = (i * block_q + band.lo) // block_k
        blk = jnp.where(s >= grid_global, s0 + s - grid_global, s)
        return (b, jnp.clip(blk, 0, nkb - 1), 0)

    kern = functools.partial(
        _kernel, sched=sched, band=band, block_q=block_q, block_k=block_k,
        grid_global=grid_global, steps=steps, nkb=nkb, scale=scale)

    out, m, l = pl.pallas_call(
        kern,
        grid=(B, nq, steps),
        in_specs=[
            pl.BlockSpec((1, block_q), lambda b, i, s: (i, 0)),      # pos_q
            pl.BlockSpec((1, block_k),
                         lambda b, i, s: (kv_idx(b, i, s)[1], 0)),   # pos_k
            pl.BlockSpec((1, block_q, D), lambda b, i, s: (b, i, 0)),  # q
            pl.BlockSpec((1, block_k, D), kv_idx),                     # k
            pl.BlockSpec((1, block_k, D), kv_idx),                     # v
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, s: (b, i, 0)),
            pl.BlockSpec((1, block_q), lambda b, i, s: (b, i)),
            pl.BlockSpec((1, block_q), lambda b, i, s: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, n_pad, D), q.dtype),
            jax.ShapeDtypeStruct((B, n_pad), jnp.float32),
            jax.ShapeDtypeStruct((B, n_pad), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),      # acc
            pltpu.VMEM((block_q, LANES), jnp.float32),  # m (lane-replicated)
            pltpu.VMEM((block_q, LANES), jnp.float32),  # l
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name=f"salo_band_{band.lo}_{band.hi}",
    )(pos_q, pos_k, q, k, v)
    return out, m, l
