"""Serving engines: the lockstep baseline and the continuous-batching engine.

``ServeEngine`` (lockstep): prefill a rectangular batch token-by-token, then
step the decode loop in lockstep — every sequence at the same position. The
correctness baseline, and the thing the continuous engine is measured
against.

``ContinuousEngine``: the production-style path. Requests of different
lengths enter a scheduler (:mod:`repro.serve.batcher`), share ONE pooled
paged ring-cache slab (:mod:`repro.serve.paged_cache`), prefill in
plan-driven chunks (``ChunkPlan`` — ``ceil(P/chunk)`` fused passes instead
of ``P`` sequential decode steps), and decode ragged: one launch per step
serves every in-flight request at its own position via the per-request
``t`` vector / page tables of :mod:`repro.kernels.salo_decode`. Greedy
outputs match the lockstep baseline token-for-token
(tests/test_serve_continuous.py).
"""
from __future__ import annotations

import dataclasses
import json
import time
from collections.abc import MutableMapping
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ft.faults import ResourceExhausted
from repro.models.model import Model
from repro.obs import Observability


class CountersView(MutableMapping):
    """The old ``ContinuousEngine.counters`` dict, now a live view over
    registry counters (``serve_<key>``). Every historical access pattern
    keeps working — ``counters["x"] += 1``, ``dict(counters)``,
    ``counters.update(snapshot)`` — while the values live in the metrics
    registry alongside everything else observability collects."""

    KEYS = ("prefill_launches", "decode_launches", "prefill_tokens",
            "decode_tokens", "decode_pages_read", "decode_pages_total",
            "prefill_pages_read", "prefill_pages_total", "engine_steps")

    def __init__(self, registry):
        self._reg = registry

    def __getitem__(self, key: str) -> int:
        if key not in self.KEYS:
            raise KeyError(key)
        return int(self._reg.value("serve_" + key))

    def __setitem__(self, key: str, value) -> None:
        if key not in self.KEYS:
            raise KeyError(key)
        self._reg.set_counter("serve_" + key, int(value))

    def __delitem__(self, key: str) -> None:
        raise TypeError("engine counters are a fixed set")

    def __iter__(self):
        return iter(self.KEYS)

    def __len__(self) -> int:
        return len(self.KEYS)

    def __repr__(self) -> str:
        return repr(dict(self))


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int
    temperature: float = 0.0   # 0 = greedy
    seed: int = 0


class ServeEngine:
    def __init__(self, model: Model, scfg: ServeConfig):
        self.model = model
        self.scfg = scfg
        self._decode = jax.jit(model.decode_step)

    def prefill(self, params, prompts: jax.Array):
        """prompts: (B, P). Returns (cache, last_logits) after P steps.

        Token-by-token prefill through decode_step — exercises exactly the
        decode path (production engines fuse this; the framework keeps it
        simple and correct, and the dry-run lowers the fused full-sequence
        forward separately)."""
        B, P = prompts.shape
        cache = self.model.init_cache(B, self.scfg.max_len)

        def body(carry, t):
            cache = carry
            logits, cache = self.model.decode_step(
                params, cache, {"tokens": jax.lax.dynamic_slice_in_dim(
                    prompts, t, 1, axis=1)}, t)
            return cache, logits

        cache, logits = jax.lax.scan(body, cache, jnp.arange(P))
        return cache, logits[-1][:, -1, :]   # (B, V) at the last position

    def generate(self, params, prompts: jax.Array, n_new: int):
        """Greedy/temperature generation. Returns (B, n_new) tokens."""
        B, P = prompts.shape
        cache, logits = self.prefill(params, prompts)
        rng = jax.random.PRNGKey(self.scfg.seed)

        def sample(logits, rng):  # logits: (B, V)
            if self.scfg.temperature == 0.0:
                return jnp.argmax(logits, axis=-1)
            return jax.random.categorical(
                rng, logits / self.scfg.temperature, axis=-1)

        def body(carry, i):
            cache, logits, rng = carry
            rng, sub = jax.random.split(rng)
            tok = sample(logits, sub)
            new_logits, cache = self.model.decode_step(
                params, cache, {"tokens": tok[:, None]}, P + i)
            return (cache, new_logits[:, -1, :], rng), tok

        (_, _, _), toks = jax.lax.scan(
            body, (cache, logits, rng), jnp.arange(n_new))
        return toks.T  # (B, n_new)


# ====================================================================== #
# Continuous batching
# ====================================================================== #
@dataclasses.dataclass(frozen=True)
class ContinuousConfig:
    """Knobs of the continuous-batching engine.

    ``n_pages`` sizes the pooled slab (page 0 is reserved); ``chunk`` is
    the prefill chunk length (one fused launch each); ``max_batch`` the
    engine rows (max concurrent requests); ``decode_impl`` selects the
    ragged decode engine: ``xla`` (gather + ragged twin — trains anywhere),
    ``pallas`` (the paged kernel; degrades to xla off-TPU) or
    ``pallas_interpret`` (CPU numerics check of the kernel).

    ``seq_shards > 1`` shards the engine over the "seq" mesh axis
    (sequence-parallel serving): each shard holds its OWN ``n_pages``-page
    slab pool covering the request slots it owns (contiguous page
    striping — see :class:`repro.serve.paged_cache.PagedLayout`), chunked
    prefill and ragged decode run one launch per shard over per-shard step
    tables / page tables / slot maps, and per-layer partials combine by a
    masked psum. Greedy output stays token-exact vs ``seq_shards=1``.

    ``kv_dtype``: ``"compute"`` stores the slab at the model's compute
    dtype; ``"int8"`` stores it quantized with per-(layer, page) scales
    (paper §6.4 deployment numerics — ~4x less resident KV HBM).

    ``page_sparsity_threshold``: ``None`` disables the stats machinery
    entirely (dense reads, no per-page score tracking). A float enables
    Salca-style page-skip: each decode step every request's per-page max
    attention score (log-space, relative to its row max) updates a
    decayed historical max, and pages whose history falls below the
    threshold are routed to the null page for the next launch — sink
    pages and the current write page are always kept. ``-inf`` keeps the
    machinery on but skips nothing (token-identical to ``None``).
    ``page_stat_decay`` is the per-step additive log-space decay
    (``hist = max(rel_score, hist - decay)``); 0 = pure historical max.

    ``max_queue`` bounds the admission queue (``submit`` raises
    :class:`~repro.ft.faults.QueueFull` beyond it — backpressure); ``None``
    is unbounded. ``preempt`` enables page-pressure preemption: when the
    queue head cannot get pages, the youngest strictly-lower-priority
    decoding request is evicted and later recovered by chunked re-prefill
    (see :meth:`repro.serve.batcher.Batcher.maybe_preempt`)."""
    n_pages: int
    page: int = 8
    chunk: int = 16
    max_batch: int = 4
    decode_impl: str = "xla"
    seq_shards: int = 1
    kv_dtype: str = "compute"
    page_sparsity_threshold: Optional[float] = None
    page_stat_decay: float = 0.0
    max_queue: Optional[int] = None
    preempt: bool = True


class ContinuousEngine:
    """Continuous-batching serving over the paged ring-cache slab.

    Greedy decoding only (temperature sampling needs per-request RNG
    streams — a scheduler policy, not an engine limitation). Supports every
    attention-block architecture with a causal 1-D SALO pattern; SSM /
    recurrent / encoder-decoder programs keep the lockstep path.
    """

    def __init__(self, model: Model, ccfg: ContinuousConfig, mesh=None,
                 seq_axis: str = "seq",
                 clock: Optional[Callable[[], float]] = None,
                 obs: Optional[Observability] = None):
        from repro.models import layers as L
        from repro.models import transformer as T
        from repro.serve.batcher import Batcher
        from repro.serve.paged_cache import layout_for_pattern, slab_init

        cfg = model.cfg
        if cfg.mrope_sections is not None or cfg.encoder_decoder:
            raise NotImplementedError("continuous serving: text-only LMs")
        for kind, _ in model.program:
            if kind not in T.ATTN_KINDS:
                raise NotImplementedError(
                    f"continuous serving needs attention blocks, got {kind}")
        self.model = model
        self.ccfg = ccfg
        self.n_shards = ccfg.seq_shards
        self.mesh, self.seq_axis = mesh, seq_axis
        if self.n_shards > 1:
            if mesh is None or dict(zip(mesh.axis_names, mesh.devices.shape)
                                    ).get(seq_axis, 0) != self.n_shards:
                raise ValueError(
                    f"seq_shards={self.n_shards} needs a mesh with a "
                    f"{seq_axis!r} axis of that size, got {mesh}")
        if ccfg.kv_dtype not in ("compute", "int8"):
            raise ValueError(f"kv_dtype must be 'compute' or 'int8', got "
                             f"{ccfg.kv_dtype!r}")
        self.quantized = ccfg.kv_dtype == "int8"
        self.track_stats = ccfg.page_sparsity_threshold is not None
        self.pattern = L.salo_pattern(cfg, causal=True)
        if self.pattern.is_2d or not self.pattern.causal:
            raise NotImplementedError("continuous serving: causal 1-D only")
        # Observability: registry always live (the engine counters ARE
        # registry counters), tracing opt-in. All hooks are host-side —
        # see the zero-jitted-operand contract in repro.obs.
        self.obs = obs if obs is not None else Observability()
        self.tracer = self.obs.tracer
        self.registry = self.obs.registry
        self.layout = layout_for_pattern(self.pattern, ccfg.page,
                                         shards=self.n_shards)
        self.batcher = Batcher(self.layout, ccfg.n_pages, ccfg.max_batch,
                               max_queue=ccfg.max_queue,
                               clock=clock or time.monotonic, obs=self.obs)
        self.batcher.on_finish = self._release_hook

        lay = self.layout
        self.chunk_pad = -(-max(ccfg.chunk, 1) // ccfg.page) * ccfg.page
        self.nq = self.chunk_pad // ccfg.page
        self.ctx_len = lay.n_sink + lay.ring_cap
        # step-table width: per shard under SP (owned ctx tiles + chunk),
        # the full view on a single device — one compiled step per engine
        self.table_w = (self.ctx_len // self.n_shards
                        + self.chunk_pad) // ccfg.page

        dtype = jnp.dtype(cfg.compute_dtype)
        shard_dims = (self.n_shards,) if self.n_shards > 1 else ()
        self.slabs = {
            f"seg{i}_{kind}": slab_init(n, ccfg.n_pages, ccfg.page,
                                        cfg.n_kv_heads, cfg.hd, dtype,
                                        lead=shard_dims,
                                        quantized=self.quantized)
            for i, (kind, n) in enumerate(model.program)}
        # Per-(request row, logical page) decayed historical max score
        # (log-space, relative to the row max). 0 = "hot" — fresh pages
        # start kept; fully-masked/skipped pages only ever decay.
        self.page_hist = np.zeros(
            (ccfg.max_batch, self.layout.pages_per_req), np.float64)
        from repro.core.scheduler import PAD_SENTINEL
        if self.n_shards > 1:
            self.slot_pos = jnp.full(
                (self.n_shards, ccfg.max_batch, lay.slots_per_shard),
                PAD_SENTINEL, jnp.int32)
            self._shard_state()
        else:
            from repro.serve.paged_cache import empty_positions
            self.slot_pos = empty_positions(ccfg.max_batch, lay)
        self.page_tables = np.zeros((ccfg.max_batch, lay.pages_per_req),
                                    np.int32)
        self.counters = CountersView(self.registry)
        for key in CountersView.KEYS:
            self.registry.counter("serve_" + key)
        # Per-launch estimated HBM traffic of the KV slab reads (pages
        # actually read x page bytes across all layers) — the byte half of
        # the paper's tile/launch/byte accounting, at serving granularity.
        kv_itemsize = 1 if self.quantized else jnp.dtype(
            cfg.compute_dtype).itemsize
        self._page_read_bytes = (2 * sum(n for _, n in model.program)
                                 * ccfg.page * cfg.n_kv_heads * cfg.hd
                                 * kv_itemsize)
        # Quantization effectiveness as a registry gauge (once, at init —
        # int8 slabs show ~4x fewer resident bytes than the compute dtype).
        self.registry.set("serve_slab_resident_bytes",
                          self.slab_resident_bytes())
        if self.n_shards > 1:
            self._chunk_jit = jax.jit(self._chunk_sharded)
            self._decode_jit = jax.jit(self._decode_sharded)
        else:
            self._chunk_jit = jax.jit(self._chunk_fn)
            self._decode_jit = jax.jit(self._decode_fn)

    def _shard_state(self):
        """Pin the stacked (shard-leading) device state to the mesh."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        sh = NamedSharding(self.mesh, P(self.seq_axis))
        self.slabs = jax.device_put(self.slabs, sh)
        self.slot_pos = jax.device_put(self.slot_pos, sh)

    # -------------------------- jitted steps --------------------------- #
    def _run_lm(self, params, slabs, x, seg_step):
        """THE model core shared by the four engine steps (single/sharded
        x chunk/decode): run every stacked segment through ``seg_step``,
        then the final norm + logits head. ``x``: embedded inputs."""
        from repro.models import layers as L

        cfg = self.model.cfg
        new_slabs = {}
        for i, (kind, n) in enumerate(self.model.program):
            key = f"seg{i}_{kind}"
            x, new_slabs[key] = seg_step(kind, params[key], slabs[key], x)
        x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
        logits = L.logits_apply(params["embed"], params.get("lm_head"),
                                x, cfg)
        return logits, new_slabs

    def _chunk_core(self, params, slabs, page_table, ctx_pos, pos_q,
                    tokens, kv_blocks, flags, phys_w, off_w, axis=None):
        """One plan-driven prefill chunk for ONE request (all layers).

        All operands are fixed-shape (chunk padded to ``chunk_pad``, tables
        to ``table_w``), so every chunk of every request reuses one
        compilation. Returns (chunk logits (Cp, V), new slabs). ``axis``:
        running as one shard of the "seq" mesh (per-shard operands,
        cross-shard attention merge)."""
        from repro.models import transformer as T

        cfg = self.model.cfg
        x = self.model._embed_inputs(params, {"tokens": tokens[None]})
        logits, new_slabs = self._run_lm(
            params, slabs, x,
            lambda kind, p, s, x: T.segment_chunk_prefill(
                p, s, x, page_table, ctx_pos[None], pos_q[None], kv_blocks,
                flags, phys_w, off_w, cfg, kind, self.pattern, axis=axis))
        return logits[0], new_slabs

    def _decode_core(self, params, slabs, page_tables, slot_pos, tokens,
                     t_vec, phys_w, off_w, axis=None):
        """One ragged decode step for the WHOLE cohort, write targets
        already resolved (null page for dropped writes). Returns
        (logits (R, V), new slabs, page_m) — ``page_m`` (R, npp), the max
        per-(request, page) score over ALL layers of ALL segments when
        page stats are tracked, else None."""
        from repro.models import transformer as T

        cfg = self.model.cfg
        x = self.model._embed_inputs(params, {"tokens": tokens[:, None]})
        pms = []

        def seg_step(kind, p, s, x):
            res = T.segment_decode_paged(
                p, s, x, page_tables, slot_pos, t_vec, phys_w, off_w, cfg,
                kind, self.pattern, self.ccfg.decode_impl, axis=axis,
                want_page_stats=self.track_stats)
            if self.track_stats:
                x, new_slab, pm = res
                pms.append(pm)
                return x, new_slab
            return res

        logits, new_slabs = self._run_lm(params, slabs, x, seg_step)
        page_m = jnp.max(jnp.stack(pms), axis=0) if pms else None
        return logits[:, 0, :], new_slabs, page_m

    def _chunk_fn(self, params, slabs, page_table, ctx_pos, pos_q, tokens,
                  kv_blocks, flags, phys_w, off_w):
        return self._chunk_core(params, slabs, page_table, ctx_pos, pos_q,
                                tokens, kv_blocks, flags, phys_w, off_w)

    def _decode_fn(self, params, slabs, page_tables, slot_pos, tokens,
                   t_vec, active, page_keep=None):
        """Every in-flight request advances one token at its own position.
        Inactive rows write to the null page; their logits are discarded.

        ``page_keep`` (R, npp) bool (page-sparsity mode only): pages the
        stats history says to read this step. Dropped pages are routed to
        the null page AND their slots' read positions masked to PAD — the
        persisted ``slot_pos``/page tables are untouched, so a page that
        would come back above threshold later would simply be read again."""
        from repro.core.scheduler import PAD_SENTINEL

        R = tokens.shape[0]
        lay = self.layout
        slot = lay.slot(t_vec)
        phys_w, off_w = lay.write_target(jnp.asarray(page_tables), t_vec,
                                         keep=active)
        rows = jnp.arange(R)
        slot_pos = slot_pos.at[rows, slot].set(
            jnp.where(active, t_vec, slot_pos[rows, slot]))
        pt_read, pos_read = jnp.asarray(page_tables), slot_pos
        if page_keep is not None:
            pt_read = jnp.where(page_keep, pt_read, 0)
            pos_read = jnp.where(jnp.repeat(page_keep, lay.page, axis=1),
                                 slot_pos, PAD_SENTINEL)
        logits, new_slabs, page_m = self._decode_core(
            params, slabs, pt_read, pos_read, tokens, t_vec, phys_w, off_w)
        if self.track_stats:
            return logits, new_slabs, slot_pos, page_m
        return logits, new_slabs, slot_pos

    # --------------------- sharded (seq-parallel) steps ----------------- #
    def _chunk_sharded(self, params, slabs, page_table, ctx_pos, pos_q,
                       tokens, kv_blocks, flags, phys_w, off_w):
        """One prefill chunk under sequence parallelism: ONE launch per
        shard over per-shard tables, per-layer masked-psum merge.

        Shard-leading operands (sharded over the "seq" axis): ``slabs``
        (S, L, n_pages, page, Hkv, hd), ``page_table`` (S, npp_s),
        ``ctx_pos`` (S, S_s), ``kv_blocks``/``flags`` (S, nq, W_s),
        ``phys_w``/``off_w`` (S, Cp) — non-owned chunk positions already
        routed to the null page. ``pos_q``/``tokens`` (Cp,) replicated."""
        from jax.sharding import PartitionSpec as P

        from repro.compat import shard_map

        ax = self.seq_axis

        def local(params, slabs, page_table, ctx_pos, kv_blocks, flags,
                  phys_w, off_w, pos_q, tokens):
            slabs = jax.tree.map(lambda a: a[0], slabs)
            logits, new_slabs = self._chunk_core(
                params, slabs, page_table[0], ctx_pos[0], pos_q, tokens,
                kv_blocks[0], flags[0], phys_w[0], off_w[0], axis=ax)
            return logits, jax.tree.map(lambda a: a[None], new_slabs)

        fn = shard_map(
            local, mesh=self.mesh,
            in_specs=(P(), P(ax), P(ax), P(ax), P(ax), P(ax), P(ax), P(ax),
                      P(), P()),
            out_specs=(P(), P(ax)), check_vma=False)
        return fn(params, slabs, page_table, ctx_pos, kv_blocks, flags,
                  phys_w, off_w, pos_q, tokens)

    def _decode_sharded(self, params, slabs, page_tables, slot_pos, tokens,
                        t_vec, active, page_keep=None):
        """One ragged decode step under sequence parallelism: each shard
        attends its owned slots (per-shard page tables + slot map), the
        new KV is written only by the written slot's owner, and per-layer
        (out, m, l) partials combine by masked psum — the sharded decode
        slot map. ``page_tables`` (S, R, npp_s), ``slot_pos`` (S, R, S_s);
        tokens/t_vec/active replicated. ``page_keep`` (S, R, npp_s) —
        the host-built keep mask striped like the page tables; each shard
        masks its own reads (writes are never masked). Page stats come
        back shard-stacked (S, R, npp_s); the host re-assembles the
        logical (R, npp) view."""
        from jax.sharding import PartitionSpec as P

        from repro.compat import shard_map
        from repro.core.scheduler import PAD_SENTINEL

        ax, lay = self.seq_axis, self.layout
        R = tokens.shape[0]
        page = self.ccfg.page
        sparse = page_keep is not None

        def local(params, slabs, page_tables, slot_pos, tokens, t_vec,
                  active, *rest):
            slabs = jax.tree.map(lambda a: a[0], slabs)
            page_tables, slot_pos = page_tables[0], slot_pos[0]
            idx = jax.lax.axis_index(ax)
            keep, local_slot, phys, off = sharded_write_target(
                lay, page_tables, t_vec, active, idx)
            rows = jnp.arange(R)
            slot_pos = slot_pos.at[rows, local_slot].set(
                jnp.where(keep, t_vec, slot_pos[rows, local_slot]))
            pt_read, pos_read = page_tables, slot_pos
            if sparse:
                pk = rest[0][0]                        # (R, npp_s)
                pt_read = jnp.where(pk, pt_read, 0)
                pos_read = jnp.where(jnp.repeat(pk, page, axis=1),
                                     slot_pos, PAD_SENTINEL)
            logits, new_slabs, page_m = self._decode_core(
                params, slabs, pt_read, pos_read, tokens, t_vec, phys,
                off, axis=ax)
            out = (logits, jax.tree.map(lambda a: a[None], new_slabs),
                   slot_pos[None])
            return out + ((page_m[None],) if self.track_stats else ())

        specs = [P(), P(ax), P(ax), P(ax), P(), P(), P()]
        args = [params, slabs, page_tables, slot_pos, tokens, t_vec, active]
        if sparse:
            specs.append(P(ax))
            args.append(page_keep)
        out_specs = (P(), P(ax), P(ax)) + ((P(ax),) if self.track_stats
                                           else ())
        fn = shard_map(local, mesh=self.mesh, in_specs=tuple(specs),
                       out_specs=out_specs, check_vma=False)
        return fn(*args)

    # --------------------------- host driving -------------------------- #
    def submit(self, prompt, max_new: int, priority: int = 0,
               deadline_s: Optional[float] = None) -> int:
        return self.batcher.submit(prompt, max_new, priority=priority,
                                   deadline_s=deadline_s)

    def _release_hook(self, row: int, pages: np.ndarray):
        """Batcher completion callback: retire the row's page stats and
        (int8 slabs) zero the recycled pages' scales in every slab, so a
        reused page starts from a fresh quantization grid instead of the
        old request's amax."""
        self.page_hist[row] = 0.0
        if not self.quantized:
            return
        S = self.n_shards
        if S > 1:
            p2d = jnp.asarray(pages.reshape(S, self.layout.pages_per_shard))
            idx = jnp.arange(S)[:, None]
            self.slabs = {
                k: s._replace(k_scale=s.k_scale.at[idx, :, p2d].set(0.0),
                              v_scale=s.v_scale.at[idx, :, p2d].set(0.0))
                for k, s in self.slabs.items()}
        else:
            from repro.serve.paged_cache import reset_page_scales
            self.slabs = {
                k: s._replace(k_scale=reset_page_scales(s.k_scale, pages),
                              v_scale=reset_page_scales(s.v_scale, pages))
                for k, s in self.slabs.items()}

    def _admit(self):
        from repro.core.scheduler import PAD_SENTINEL

        for req in self.batcher.admit():
            self.page_tables[req.row] = req.pages
            self.page_hist[req.row] = 0.0
            if self.n_shards > 1:
                self.slot_pos = self.slot_pos.at[:, req.row].set(
                    PAD_SENTINEL)
            else:
                self.slot_pos = self.slot_pos.at[req.row].set(PAD_SENTINEL)

    def _advance_prefill(self, params, req):
        """Run the request's next chunk: ONE fused table-driven pass
        (one per shard under sequence parallelism).

        A fresh request prefills its prompt; a preemption-resumed request
        prefills ``prompt + out[:-1]`` (``req.prefill_tokens``) — the exact
        token stream the evicted KV was built from — through this same
        chunked path, then rejoins decode at its old position without
        re-emitting anything."""
        from repro.core.scheduler import (BIG, build_chunk_plan,
                                          ring_view_positions)

        lay, page, S = self.layout, self.ccfg.page, self.n_shards
        src = req.prefill_tokens
        P = req.prefill_len
        c0 = req.prefilled
        clen = min(self.ccfg.chunk, P - c0)
        c1 = c0 + clen
        plan = build_chunk_plan(self.pattern, c0, clen, n_sink=lay.n_sink,
                                ring_cap=lay.ring_cap, block=page,
                                chunk_pad=self.chunk_pad)
        ctx_pos = plan.view_positions[: self.ctx_len]
        Cp = self.chunk_pad
        pos_q = np.full(Cp, BIG, np.int32)
        pos_q[:clen] = np.arange(c0, c1, dtype=np.int32)
        tokens = np.zeros(Cp, np.int32)
        tokens[:clen] = src[c0:c1]
        # Slab write targets: ring-overwritten positions (chunk longer than
        # the ring) and padded rows route to the null page.
        pos = np.arange(c0, c0 + Cp, dtype=np.int64)
        keep = (np.arange(Cp) < clen) & (
            (pos < lay.n_global) | (pos + lay.ring_cap >= c1))
        slot = np.where(pos < lay.n_global, pos,
                        lay.n_sink + (pos - lay.n_global) % lay.ring_cap)
        # Stats-driven ctx-page skipping for the chunk's READ of the paged
        # context — the chunked-prefill twin of the decode page-keep mask
        # (same history, same Salca rule): pages whose decayed max-score
        # history fell below the threshold are routed to the null page and
        # their positions to PAD_SENTINEL; sink pages and pages the chunk
        # WRITES are unconditionally kept. Fresh/just-admitted requests
        # have an all-zero (hot) history, so plain prefill is untouched —
        # the mask only bites when a request re-prefills with accumulated
        # stats (preemption resume) or the threshold is driven externally.
        npp = lay.pages_per_req
        pt_read, ctx_read = req.pages, ctx_pos
        pages_read = npp
        if self.track_stats:
            rkeep = self.page_hist[req.row] \
                >= self.ccfg.page_sparsity_threshold
            rkeep[: lay.sink_pages] = True
            rkeep[np.unique(slot[keep] // page)] = True
            pages_read = int(rkeep.sum())
            pt_read = np.where(rkeep, req.pages, 0).astype(np.int32)
            ctx_read = np.where(np.repeat(rkeep, page), ctx_pos,
                                BIG).astype(np.int32)
        if S > 1:
            kv, fl = plan.sharded_tables(S, self.nq, self.table_w)
            owner = slot // lay.slots_per_shard
            local = slot % lay.slots_per_shard
            pages2d = pt_read.reshape(S, lay.pages_per_shard)
            keep_s = keep[None] & (owner[None] == np.arange(S)[:, None])
            phys = np.where(keep_s,
                            req.pages.reshape(S, lay.pages_per_shard)[
                                np.arange(S)[:, None], local[None] // page],
                            0).astype(np.int32)
            off = np.where(keep_s, local[None] % page, 0).astype(np.int32)
            logits, self.slabs = self._chunk_jit(
                params, self.slabs,
                jnp.asarray(pages2d), jnp.asarray(
                    ctx_read.reshape(S, lay.slots_per_shard)),
                jnp.asarray(pos_q), jnp.asarray(tokens), jnp.asarray(kv),
                jnp.asarray(fl), jnp.asarray(phys), jnp.asarray(off))
        else:
            kv, fl = plan.padded_tables(self.nq, self.table_w)
            phys = np.where(keep, req.pages[slot // page], 0).astype(np.int32)
            off = np.where(keep, slot % page, 0).astype(np.int32)
            logits, self.slabs = self._chunk_jit(
                params, self.slabs, jnp.asarray(pt_read),
                jnp.asarray(ctx_read), jnp.asarray(pos_q),
                jnp.asarray(tokens), jnp.asarray(kv), jnp.asarray(fl),
                jnp.asarray(phys), jnp.asarray(off))
        self.counters["prefill_launches"] += 1
        self.counters["prefill_tokens"] += clen
        self.counters["prefill_pages_read"] += pages_read
        self.counters["prefill_pages_total"] += npp
        self.registry.inc("serve_prefill_est_hbm_bytes",
                          pages_read * self._page_read_bytes)
        self.registry.inc("serve_prefill_tiles",
                          plan.stats()["executed_tiles"])
        req.prefilled = c1
        if c1 == P:
            first = int(np.argmax(np.asarray(logits[clen - 1])))
            rvp = ring_view_positions(P, lay.n_sink, lay.ring_cap,
                                      lay.n_global)
            if S > 1:
                self.slot_pos = self.slot_pos.at[:, req.row].set(
                    jnp.asarray(rvp.reshape(S, lay.slots_per_shard)))
            else:
                self.slot_pos = self.slot_pos.at[req.row].set(
                    jnp.asarray(rvp))
            self.batcher.to_decode(req, first)

    def _page_keep_mask(self, t_vec, active) -> np.ndarray:
        """(R, npp) bool: pages each request reads this step. History at or
        above the threshold keeps a page; sink pages and the page being
        written are unconditionally kept (Salca's rule: never starve the
        global prefix or the live write point); inactive rows keep-all
        (their reads are already null-routed)."""
        lay = self.layout
        R = self.ccfg.max_batch
        keep = self.page_hist >= self.ccfg.page_sparsity_threshold
        keep[:, :lay.sink_pages] = True
        p = np.asarray(t_vec, np.int64)
        slot = np.where(p < lay.n_global, p,
                        lay.n_sink + (p - lay.n_global) % lay.ring_cap)
        keep[np.arange(R), slot // lay.page] = True
        keep[~np.asarray(active, bool)] = True
        return keep

    def _update_page_stats(self, page_m: np.ndarray, active) -> None:
        """Fold one step's per-page max scores into the decayed history.
        ``rel`` is log-relative to the request's row max, so the history
        is softmax-shift invariant; fully-masked/skipped pages carry
        NEG_INF and therefore only decay."""
        pm = np.asarray(page_m, np.float64)
        rowmax = pm.max(axis=1, keepdims=True)
        rel = pm - np.where(rowmax <= -1e29, 0.0, rowmax)
        upd = np.maximum(rel, self.page_hist - self.ccfg.page_stat_decay)
        act = np.asarray(active, bool)[:, None]
        self.page_hist = np.where(act, upd, self.page_hist)

    def _advance_decode(self, params, reqs):
        R, S = self.ccfg.max_batch, self.n_shards
        lay = self.layout
        tokens = np.zeros(R, np.int32)
        t_vec = np.zeros(R, np.int32)
        active = np.zeros(R, bool)
        for req in reqs:
            tokens[req.row] = req.out[-1]
            t_vec[req.row] = req.t_next
            active[req.row] = True
        page_tables = (self.page_tables.reshape(
            R, S, lay.pages_per_shard).transpose(1, 0, 2).copy()
            if S > 1 else self.page_tables.copy())
        args = [params, self.slabs, page_tables, self.slot_pos,
                jnp.asarray(tokens), jnp.asarray(t_vec), jnp.asarray(active)]
        if self.track_stats:
            keep = self._page_keep_mask(t_vec, active)
            keep_dev = (keep.reshape(R, S, lay.pages_per_shard)
                        .transpose(1, 0, 2).copy() if S > 1 else keep)
            with self.tracer.span("ragged_decode", cohort=len(reqs)):
                logits, self.slabs, self.slot_pos, page_m = self._decode_jit(
                    *args, jnp.asarray(keep_dev))
                logits = np.asarray(logits)   # span covers the host sync
            with self.tracer.span("page_stats_fold"):
                if S > 1:
                    page_m = np.asarray(page_m).transpose(1, 0, 2).reshape(
                        R, lay.pages_per_req)
                self._update_page_stats(np.asarray(page_m), active)
            pages_read = int(keep[active].sum())
        else:
            with self.tracer.span("ragged_decode", cohort=len(reqs)):
                logits, self.slabs, self.slot_pos = self._decode_jit(*args)
                logits = np.asarray(logits)
            pages_read = len(reqs) * lay.pages_per_req
        self.counters["decode_launches"] += 1
        self.counters["decode_tokens"] += len(reqs)
        self.counters["decode_pages_read"] += pages_read
        self.counters["decode_pages_total"] += len(reqs) * lay.pages_per_req
        self.registry.inc("serve_decode_est_hbm_bytes",
                          pages_read * self._page_read_bytes)
        with self.tracer.span("sample", cohort=len(reqs)):
            for req in reqs:
                self.batcher.record_token(req,
                                          int(np.argmax(logits[req.row])))

    def slab_resident_bytes(self) -> int:
        """Actual bytes of the pooled KV slabs (all segments, K+V, plus
        the per-(layer, page) scale arrays for int8 slabs) — what the
        quantized-serving benchmark reports as resident KV footprint."""
        return sum(int(a.size) * a.dtype.itemsize
                   for a in jax.tree_util.tree_leaves(self.slabs))

    def step(self, params) -> bool:
        """One engine iteration: expire overdue requests, admit (preempting
        lower-priority decoders on page pressure), advance every prefilling
        request by one chunk, run one ragged decode step for the decoding
        cohort. Returns True while work remains.

        Truly-oversized requests are rejected at ``submit``, so a stalled
        queue here means transient pressure: if nothing at all is in
        flight and the head still cannot get pages (e.g. an injected
        exhaustion window), the step raises the RECOVERABLE
        :class:`~repro.ft.faults.ResourceExhausted` — the supervisor
        retries instead of the old drain-time dead-end ``RuntimeError``."""
        trc = self.tracer
        with trc.span("engine.step", step=self.counters["engine_steps"]):
            with trc.span("assemble"):
                self.batcher.expire()
                self._admit()
                if self.batcher.queue and self.ccfg.preempt \
                        and self.batcher.maybe_preempt():
                    self._admit()
                pre, dec = self.batcher.assemble()
            if not pre and not dec:
                if self.batcher.queue:
                    raise ResourceExhausted(
                        "admission stalled with nothing in flight: head of "
                        f"queue needs {self.batcher._shard_needs(self.batcher.queue[0])} "
                        f"pages per shard, free "
                        f"{[a.n_free for a in self.batcher.allocs]}")
                return False
            for req in pre:
                with trc.span("chunk_prefill", rid=req.rid,
                              prefilled=req.prefilled):
                    self._advance_prefill(params, req)
            if dec:
                self._advance_decode(params, dec)
            self.counters["engine_steps"] += 1
        return not self.batcher.idle

    def run(self, params) -> Dict[int, np.ndarray]:
        """Drive all submitted requests to completion; returns
        {rid: generated tokens}."""
        while self.step(params):
            pass
        return self.batcher.results()

    # --------------------------- snapshotting --------------------------- #
    def state_dict(self) -> dict:
        """Full serving state as a checkpointable pytree: the KV slabs
        (payload + int8 scales), the device slot map, the host page
        tables / page-stats history, and ONE variable-length uint8 leaf of
        JSON bytes carrying all control-plane state (the metrics registry —
        engine counters included — plus the batcher's entire request
        lifecycle, see ``Batcher.state_dict``). Encoding the control plane
        as bytes keeps
        the tree STRUCTURE fixed (a ``ft.checkpoint.restore`` requirement)
        while its shape tracks queue depth. Host arrays are copied so an
        in-flight snapshot cannot be torn by subsequent steps; a snapshot
        is only taken at step boundaries, where device + host state are
        mutually consistent."""
        ctl = {"counters": dict(self.counters),
               "batcher": self.batcher.state_dict(),
               "metrics": self.registry.state_dict()}
        blob = np.frombuffer(json.dumps(ctl).encode("utf-8"),
                             np.uint8).copy()
        return {"slabs": self.slabs,
                "slot_pos": self.slot_pos,
                "page_tables": self.page_tables.copy(),
                "page_hist": self.page_hist.copy(),
                "control": blob}

    def load_state(self, tree: dict) -> None:
        """Wholesale state replacement from a :meth:`state_dict` image
        (same model + config; the mesh may be a different physical mesh of
        the same "seq" extent — checkpoints are host numpy, re-placed
        here). After this the engine continues exactly where the snapshot
        was taken: greedy outputs match an uninterrupted run token-for-
        token (exactly-once emission; tests/test_serve_ft.py)."""
        slabs, slot_pos = tree["slabs"], tree["slot_pos"]
        if self.n_shards > 1:
            from jax.sharding import NamedSharding, PartitionSpec as P

            sh = NamedSharding(self.mesh, P(self.seq_axis))
            slabs = jax.device_put(
                jax.tree.map(jnp.asarray, slabs), sh)
            slot_pos = jax.device_put(jnp.asarray(slot_pos), sh)
        else:
            slabs = jax.tree.map(jnp.asarray, slabs)
            slot_pos = jnp.asarray(slot_pos)
        self.slabs = slabs
        self.slot_pos = slot_pos
        self.page_tables = np.asarray(tree["page_tables"],
                                      np.int32).copy()
        self.page_hist = np.asarray(tree["page_hist"], np.float64).copy()
        ctl = json.loads(bytes(np.asarray(tree["control"],
                                          np.uint8)).decode("utf-8"))
        self.counters.update(ctl["counters"])
        if "metrics" in ctl:   # full-registry image; absent in pre-obs
            self.registry.load_state(ctl["metrics"])   # snapshots, whose
        self.batcher.load_state(ctl["batcher"])        # counters loaded above


# ---------------------------------------------------------------------- #
# Decode write routing under sequence parallelism — module-level so the
# static analyzer can probe it over every (position, shard) pair without
# building an engine (repro.analysis.jaxpr_lint.check_write_ownership).
# ---------------------------------------------------------------------- #
def sharded_write_target(lay, page_tables, t_vec, active, idx):
    """Per-shard decode write target: each new token's KV lands on the
    writing shard ONLY if that shard owns the token's logical slot; every
    other shard (and every inactive row) routes the write to the reserved
    null page 0. ``page_tables``: (R, pages_per_shard) this shard's stripe;
    ``t_vec``: (R,) positions; ``idx``: this shard's "seq" axis index.
    Returns ``(keep, local_slot, phys, off)``.
    """
    slot = lay.slot(t_vec)
    keep = active & (lay.slot_owner(slot) == idx)
    local_slot = lay.slot_local(slot)
    phys = jnp.take_along_axis(
        page_tables, (local_slot // lay.page)[:, None], axis=1)[:, 0]
    phys = jnp.where(keep, phys, 0)
    off = jnp.where(keep, local_slot % lay.page, 0)
    return keep, local_slot, phys, off
