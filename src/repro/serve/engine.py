"""Serving engine: batched prefill + decode over either cache layout.

``ServeEngine`` drives a model end-to-end: prefill a batch of prompts (one
full-sequence forward that also writes KV caches), then step the decode loop
with greedy/temperature sampling. The SALO ring cache path demonstrates the
O(window) memory serving mode; the full cache path is the dense baseline the
decode dry-run shapes use.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int
    temperature: float = 0.0   # 0 = greedy
    seed: int = 0


class ServeEngine:
    def __init__(self, model: Model, scfg: ServeConfig):
        self.model = model
        self.scfg = scfg
        self._decode = jax.jit(model.decode_step)

    def prefill(self, params, prompts: jax.Array):
        """prompts: (B, P). Returns (cache, last_logits) after P steps.

        Token-by-token prefill through decode_step — exercises exactly the
        decode path (production engines fuse this; the framework keeps it
        simple and correct, and the dry-run lowers the fused full-sequence
        forward separately)."""
        B, P = prompts.shape
        cache = self.model.init_cache(B, self.scfg.max_len)

        def body(carry, t):
            cache = carry
            logits, cache = self.model.decode_step(
                params, cache, {"tokens": jax.lax.dynamic_slice_in_dim(
                    prompts, t, 1, axis=1)}, t)
            return cache, logits

        cache, logits = jax.lax.scan(body, cache, jnp.arange(P))
        return cache, logits[-1][:, -1, :]   # (B, V) at the last position

    def generate(self, params, prompts: jax.Array, n_new: int):
        """Greedy/temperature generation. Returns (B, n_new) tokens."""
        B, P = prompts.shape
        cache, logits = self.prefill(params, prompts)
        rng = jax.random.PRNGKey(self.scfg.seed)

        def sample(logits, rng):  # logits: (B, V)
            if self.scfg.temperature == 0.0:
                return jnp.argmax(logits, axis=-1)
            return jax.random.categorical(
                rng, logits / self.scfg.temperature, axis=-1)

        def body(carry, i):
            cache, logits, rng = carry
            rng, sub = jax.random.split(rng)
            tok = sample(logits, sub)
            new_logits, cache = self.model.decode_step(
                params, cache, {"tokens": tok[:, None]}, P + i)
            return (cache, new_logits[:, -1, :], rng), tok

        (_, _, _), toks = jax.lax.scan(
            body, (cache, logits, rng), jnp.arange(n_new))
        return toks.T  # (B, n_new)
