"""Paged ring-cache slab: ONE pooled KV allocation shared by all requests.

The serving-side mirror of the paper's hybrid sparse pattern, upgraded from
the per-batch :class:`repro.serve.kv_cache.RingCache` to a production-style
paged pool (vLLM-style paging x SALO's O(window) live set):

* **One slab per model segment** — ``(n_layers, n_pages, page, Hkv, hd)``
  for K and V. No per-request allocation ever happens after engine init;
  admission just hands out pages, completion recycles them.
* **Per-request page table** — each request owns ``sink_pages`` pages
  pinned to the global/sink prefix plus ``ring_pages`` pages forming a ring
  over the window lookback. Under dilation ``d`` the ring spans the full
  dilated lookback ``(w - 1) * d + 1`` positions (the legacy ring kept only
  ``w`` slots, silently dropping dilated keys — see
  tests/test_serve_continuous.py::test_dilated_decode_parity).
* **Per-request positions** — ``(R, slots_per_req)`` absolute position per
  logical slot (``PAD_SENTINEL`` = empty), fixing the legacy cache's
  batch-shared ``positions: (g + w,)``: a continuous batch's members sit at
  different depths, so slot->position maps cannot be shared.

Page 0 is reserved as the **null page**: inactive batch rows and dropped
writes are routed there, which keeps every scatter shape-static under jit
without masking logic in the hot path.

Slot map (logical, per request): position ``p < g`` lives at slot ``p``
inside the sink region ``[0, n_sink)``; position ``p >= g`` lives at slot
``n_sink + (p - g) % ring_cap``. Masks downstream are position-based
(:func:`repro.core.scheduler.causal_step_mask`), so the scrambled ring
order is transparent — exactly the legacy ring-cache argument, per request.

Cache footprint accounting lives in :func:`slab_bytes` and feeds
``benchmarks/serve_stats.py`` (BENCH_serve.json).

**Quantized slab** (``kv_dtype="int8"``): K/V are stored int8 with one f32
scale per (layer, page) riding next to the page tables
(:class:`PagedSlab` ``k_scale``/``v_scale``). :func:`quant_slab_write`
grows a page's scale monotonically as hotter rows land in it (rescaling
the already-resident int8 payload by the old/new ratio — exact where the
ratio is 1) and forces the null page's scale to 0, so inactive-row
scatters stay harmless AND dequantize to exact zeros. Reads dequantize
per page tile — :func:`gather_view` for the XLA twin, scalar-prefetched
scales inside the Pallas kernel. Recycled pages get their scales reset to
0 on admission (the position map, not the scale, is the validity source
of truth; the reset just stops stale amaxes from inflating the grid).
"""
from __future__ import annotations

import dataclasses
from typing import List, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheduler import PAD_SENTINEL
from repro.ft.faults import ResourceExhausted


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Static per-request geometry of the paged ring cache.

    ``shards > 1`` is the sequence-parallel serving layout: the request's
    logical pages are striped contiguously over the ``shards`` devices of
    the "seq" mesh axis — logical page ``j`` (and every slot inside it) is
    owned by shard ``j // pages_per_shard``, so sink/global pages land on
    the shards covering their positions and the ring pages are row-sharded
    across the rest. ``ring_pages`` absorbs the alignment padding (a ring
    larger than the dilated lookback is semantically identity: positions
    older than the lookback are masked out by the window term regardless of
    whether a slot still holds them).
    """
    page: int
    window: int
    n_global: int
    dilation: int = 1
    shards: int = 1

    def __post_init__(self):
        if self.page < 1 or self.window < 1 or self.dilation < 1 \
                or self.shards < 1:
            raise ValueError(f"bad paged layout {self}")
        if self.window > 1 << 28:
            raise ValueError("paged serving needs a bounded window "
                             "(salo pattern disabled / dense?)")

    @property
    def span(self) -> int:
        """Positions the ring must retain: the full dilated lookback."""
        return (self.window - 1) * self.dilation + 1

    @property
    def sink_pages(self) -> int:
        return _ceil_div(self.n_global, self.page) if self.n_global else 0

    @property
    def ring_pages(self) -> int:
        base = _ceil_div(self.span, self.page)
        # shard alignment: total pages padded so every shard owns the same
        # number of whole pages (padding slots stay PAD and mask to nothing)
        pad = -(self.sink_pages + base) % self.shards
        return base + pad

    @property
    def n_sink(self) -> int:
        return self.sink_pages * self.page

    @property
    def ring_cap(self) -> int:
        return self.ring_pages * self.page

    @property
    def pages_per_req(self) -> int:
        return self.sink_pages + self.ring_pages

    @property
    def slots_per_req(self) -> int:
        return self.pages_per_req * self.page

    # ---------------------- sequence-parallel view --------------------- #
    @property
    def pages_per_shard(self) -> int:
        assert self.pages_per_req % self.shards == 0
        return self.pages_per_req // self.shards

    @property
    def slots_per_shard(self) -> int:
        return self.pages_per_shard * self.page

    def slot_owner(self, s):
        """Shard owning logical slot ``s`` (contiguous page striping)."""
        return jnp.asarray(s, jnp.int32) // self.slots_per_shard

    def slot_local(self, s):
        """Shard-local slot index of logical slot ``s``."""
        return jnp.asarray(s, jnp.int32) % self.slots_per_shard

    # ------------------------- variable footprint ---------------------- #
    def pages_needed(self, total_positions: int) -> int:
        """Physical pages a request writing positions ``[0, total)`` ever
        touches. Touched logical slots are a PREFIX of the slot space
        (positions below ``n_global`` map to slot ``p``; later positions
        fill the ring in order until it wraps), so a short request —
        ``total <= n_global + ring_cap`` — needs strictly fewer pages than
        :attr:`pages_per_req`. This is what admission actually allocates;
        the page table's unneeded tail entries stay on the null page."""
        t = int(total_positions)
        if t <= 0:
            return 0
        if t <= self.n_global:
            return _ceil_div(t, self.page)
        if t - self.n_global >= self.ring_cap:
            return self.pages_per_req
        return self.sink_pages + _ceil_div(t - self.n_global, self.page)

    def pages_needed_per_shard(self, total_positions: int) -> List[int]:
        """Split :meth:`pages_needed` over the contiguous page striping:
        shard ``s`` owns logical pages ``[s*pps, (s+1)*pps)``, and the
        touched-page prefix intersects each stripe in a prefix."""
        need = self.pages_needed(total_positions)
        pps = self.pages_per_shard
        return [min(max(need - s * pps, 0), pps)
                for s in range(self.shards)]

    # ------------------------------------------------------------------ #
    def slot(self, p):
        """Logical slot of absolute position ``p`` (jnp-compatible)."""
        p = jnp.asarray(p, jnp.int32)
        g = self.n_global
        return jnp.where(p < g, p, self.n_sink + (p - g) % self.ring_cap)

    def write_target(self, page_table, p, keep=None):
        """(physical page, offset) for writing position ``p``.

        ``page_table``: (..., pages_per_req) int32; ``p``: (...) positions
        (leading dims must match). ``keep``: optional bool mask — False
        routes the write to the reserved null page 0 (inactive rows,
        ring-overwritten chunk positions). Returns (phys, off).
        """
        s = self.slot(p)
        pg = s // self.page
        off = s % self.page
        phys = jnp.take_along_axis(page_table, pg[..., None],
                                   axis=-1)[..., 0]
        if keep is not None:
            phys = jnp.where(keep, phys, 0)
            off = jnp.where(keep, off, 0)
        return phys, off


def layout_for_pattern(pattern, page: int, shards: int = 1) -> PagedLayout:
    """THE layout derivation — engine and pool-sizing callers share it, so
    ``n_pages = 1 + max_batch * layout.pages_per_req`` (or
    ``pages_per_shard`` per shard pool under sequence parallelism) always
    matches what admission will actually request."""
    if pattern.is_2d or not pattern.causal:
        raise ValueError(f"paged serving needs a causal 1-D pattern: "
                         f"{pattern}")
    return PagedLayout(page=page, window=pattern.window_size(),
                       n_global=pattern.n_global, dilation=pattern.dilation,
                       shards=shards)


class PagedSlab(NamedTuple):
    """Pooled KV for one model segment: (n_layers, n_pages, page, Hkv, hd).

    Layer ``i`` of the segment's stacked scan uses slab row ``i``; all
    layers of all segments share the SAME page tables (a request's page p
    means page p in every layer — the standard paged-KV invariant).

    ``k_scale``/``v_scale`` are ``None`` for fp slabs; for int8 slabs they
    are f32 ``(n_layers, n_pages)`` per-(layer, page) dequant scales
    (``lead`` dims prepended under sharding, striping with their pages).
    Scale 0 marks an empty page — in particular the null page 0, always."""
    k: jax.Array
    v: jax.Array
    k_scale: jax.Array = None
    v_scale: jax.Array = None

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None


def slab_init(n_layers: int, n_pages: int, page: int, n_kv_heads: int,
              head_dim: int, dtype, lead: tuple = (),
              quantized: bool = False) -> PagedSlab:
    """``lead``: extra leading dims — ``(n_shards,)`` stacks one per-shard
    pool per sequence shard (row s lives on shard s of the "seq" axis).
    ``quantized=True`` allocates int8 K/V (``dtype`` then only names the
    compute dtype readers dequantize to) plus zeroed per-(layer, page)
    scale arrays."""
    shape = (*lead, n_layers, n_pages, page, n_kv_heads, head_dim)
    if not quantized:
        return PagedSlab(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))
    sshape = (*lead, n_layers, n_pages)
    return PagedSlab(k=jnp.zeros(shape, jnp.int8),
                     v=jnp.zeros(shape, jnp.int8),
                     k_scale=jnp.zeros(sshape, jnp.float32),
                     v_scale=jnp.zeros(sshape, jnp.float32))


def slab_write(k_slab: jax.Array, v_slab: jax.Array, phys: jax.Array,
               off: jax.Array, k_t: jax.Array, v_t: jax.Array):
    """Scatter per-request new KV into ONE layer's slab.

    k_slab/v_slab: (n_pages, page, Hkv, hd); phys/off: (B,) from
    :meth:`PagedLayout.write_target`; k_t/v_t: (B, Hkv, hd). Rows routed to
    the null page collide harmlessly (page 0 is never read)."""
    return (k_slab.at[phys, off].set(k_t.astype(k_slab.dtype)),
            v_slab.at[phys, off].set(v_t.astype(v_slab.dtype)))


def _quant_write_one(slab: jax.Array, scale: jax.Array, phys: jax.Array,
                     off: jax.Array, x: jax.Array):
    """int8 scatter of ``x`` into one layer's slab with per-page scales.

    slab: (n_pages, page, Hkv, hd) int8; scale: (n_pages,) f32; phys/off:
    (...,) write targets; x: (..., Hkv, hd) new rows. Page scales grow
    MONOTONICALLY (scatter-max of the incoming rows' amax/127): growth
    rescales the page's resident int8 payload by old/new — exactly 1.0
    (bit-identical payload) for untouched pages — and the null page's
    scale is pinned to 0 so routed-away writes quantize to zeros."""
    x = x.astype(jnp.float32)
    row_scale = jnp.max(jnp.abs(x), axis=(-2, -1)) / 127.0      # (...,)
    new_scale = scale.at[phys].max(row_scale).at[0].set(0.0)
    ratio = jnp.where(new_scale > 0.0,
                      scale / jnp.maximum(new_scale, 1e-30), 1.0)
    slab = jnp.clip(jnp.round(slab.astype(jnp.float32)
                              * ratio[:, None, None, None]),
                    -128, 127).astype(jnp.int8)
    s = new_scale[phys][..., None, None]                        # (...,1,1)
    q = jnp.where(s > 0.0,
                  jnp.clip(jnp.round(x / jnp.maximum(s, 1e-30)), -128, 127),
                  0.0).astype(jnp.int8)
    return slab.at[phys, off].set(q), new_scale


def quant_slab_write(k_slab: jax.Array, v_slab: jax.Array,
                     k_scale: jax.Array, v_scale: jax.Array,
                     phys: jax.Array, off: jax.Array,
                     k_t: jax.Array, v_t: jax.Array):
    """Quantizing twin of :func:`slab_write` for int8 slabs.

    Same write targets/contract, plus the per-(page,) scale vectors for
    the layer being written; returns (k_slab, v_slab, k_scale, v_scale)."""
    k_slab, k_scale = _quant_write_one(k_slab, k_scale, phys, off, k_t)
    v_slab, v_scale = _quant_write_one(v_slab, v_scale, phys, off, v_t)
    return k_slab, v_slab, k_scale, v_scale


def reset_page_scales(scale: jax.Array, pages: np.ndarray) -> jax.Array:
    """Zero the scales of freshly (re)allocated pages, all layers at once.

    scale: (..., n_layers, n_pages); pages: (n,) physical page ids. Called
    on admission so a recycled page's stale amax can't inflate the new
    request's quantization grid."""
    return scale.at[..., jnp.asarray(pages, jnp.int32)].set(0.0)


def gather_view(k_slab: jax.Array, v_slab: jax.Array,
                page_tables: jax.Array, k_scale: jax.Array = None,
                v_scale: jax.Array = None, dtype=None):
    """Materialize per-request logical KV views (the XLA decode twin path;
    the Pallas kernel chases the page table instead and never does this).

    k_slab/v_slab: (n_pages, page, Hkv, hd); page_tables: (B, npp).
    For int8 slabs pass the layer's ``k_scale``/``v_scale`` (n_pages,)
    and the compute ``dtype``: each gathered page tile is dequantized by
    its own scale. Returns (B, npp * page, Hkv, hd) x 2."""
    B, npp = page_tables.shape
    _, page, Hkv, hd = k_slab.shape
    kv = k_slab[page_tables]                     # (B, npp, page, Hkv, hd)
    vv = v_slab[page_tables]
    if k_scale is not None:
        sk = k_scale[page_tables][:, :, None, None, None]
        sv = v_scale[page_tables][:, :, None, None, None]
        kv = (kv.astype(jnp.float32) * sk).astype(dtype)
        vv = (vv.astype(jnp.float32) * sv).astype(dtype)
    return (kv.reshape(B, npp * page, Hkv, hd),
            vv.reshape(B, npp * page, Hkv, hd))


def empty_positions(n_requests: int, layout: PagedLayout) -> jax.Array:
    """Per-request slot->position table, all-empty (PAD_SENTINEL)."""
    return jnp.full((n_requests, layout.slots_per_req), PAD_SENTINEL,
                    jnp.int32)


# ---------------------------------------------------------------------- #
class PageAllocator:
    """Free-list page allocator over the pooled slab (host-side).

    Page 0 is reserved as the null page and never handed out. Admission
    calls :meth:`alloc`; completion calls :meth:`release` — recycled pages
    go straight back to the free list (no zeroing needed: positions are the
    validity source of truth, stale KV in a reused page is masked out by
    its PAD positions until overwritten)."""

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is reserved)")
        self.n_pages = n_pages
        self._free: List[int] = list(range(n_pages - 1, 0, -1))

    @property
    def n_free(self) -> int:
        return len(self._free)

    def can_alloc(self, n: int) -> bool:
        return len(self._free) >= n

    def alloc(self, n: int) -> np.ndarray:
        if not self.can_alloc(n):
            raise ResourceExhausted(
                f"page pool exhausted ({n} > {self.n_free})")
        pages = [self._free.pop() for _ in range(n)]
        return np.asarray(pages, dtype=np.int32)

    def release(self, pages) -> None:
        for p in np.asarray(pages).tolist():
            assert 0 < p < self.n_pages, p
            assert p not in self._free, f"double free of page {p}"
            self._free.append(p)


# ---------------------------------------------------------------------- #
def slab_bytes(n_layers_total: int, n_pages: int, page: int,
               n_kv_heads: int, head_dim: int, dtype_bytes: int = 2,
               with_scales: bool = False) -> int:
    """Total pooled slab footprint (all segments' layers, K+V).

    ``with_scales`` adds the int8 slab's per-(layer, page) f32 scale
    arrays (K and V) — the honest footprint the quantized-serving
    benchmark compares against the fp slab."""
    base = 2 * n_layers_total * n_pages * page * n_kv_heads * head_dim \
        * dtype_bytes
    if with_scales:
        base += 2 * n_layers_total * n_pages * 4
    return base


def full_cache_bytes(n_layers_total: int, batch: int, max_len: int,
                     n_kv_heads: int, head_dim: int,
                     dtype_bytes: int = 2) -> int:
    """What the lockstep dense baseline allocates for the same traffic."""
    return 2 * n_layers_total * batch * max_len * n_kv_heads * head_dim \
        * dtype_bytes
