"""Request scheduler for the continuous-batching engine.

Host-side control plane: requests enter a FIFO admission queue, get pages
and an engine row on admission, move through PREFILL (one plan-driven chunk
per engine step) into DECODE (all decoding rows share one ragged kernel
launch per step), and on completion release their pages back to the pool —
which is what lets the next waiting request in. The engine
(:class:`repro.serve.engine.ContinuousEngine`) owns the device arrays; this
module owns the lifecycle.

Per-step work assembly (:meth:`Batcher.assemble`) deliberately mixes the
two phases: every engine step advances each prefilling request by exactly
one chunk AND runs one decode step for the whole decoding cohort, so long
prompts never stall token emission for requests already decoding — the
standard continuous-batching contract (Orca/vLLM), driven here by the
ChunkPlan/ragged-decode machinery.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serve.paged_cache import PageAllocator, PagedLayout

WAITING, PREFILL, DECODE, DONE = "waiting", "prefill", "decode", "done"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new: int
    state: str = WAITING
    row: int = -1                 # engine batch row while running
    pages: Optional[np.ndarray] = None   # (pages_per_req,) physical pages
    prefilled: int = 0            # prompt tokens already in the cache
    out: List[int] = dataclasses.field(default_factory=list)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def t_next(self) -> int:
        """Position of the next token to feed in DECODE state (the last
        sampled token): prompt_len + generated - 1."""
        return self.prompt_len + len(self.out) - 1

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new


class Batcher:
    """Admission, per-step batch assembly, completion/eviction."""

    # Completion callback, set by the engine: called as
    # ``on_finish(row, pages)`` right after a request's pages return to
    # the pool and before the row is cleared — the engine uses it to
    # retire per-row page statistics and zero recycled pages' int8
    # scales so a reused page starts from a fresh quantization grid.
    on_finish = None

    def __init__(self, layout: PagedLayout, n_pages: int, max_batch: int):
        # One allocator per sequence shard (layout.shards == 1 -> exactly
        # the single-pool engine): every request takes pages_per_shard
        # pages from EVERY shard's pool, so the pools advance in lockstep
        # and ``n_pages`` is the per-shard pool size. Request.pages
        # concatenates the per-shard page ids (shard-local id spaces) —
        # entry j names a physical page on shard j // pages_per_shard.
        self.layout = layout
        self.allocs = [PageAllocator(n_pages) for _ in range(layout.shards)]
        self.alloc = self.allocs[0]
        self.max_batch = max_batch
        self.queue: List[Request] = []
        self.rows: List[Optional[Request]] = [None] * max_batch
        self.finished: Dict[int, Request] = {}
        self._next_rid = 0

    # ------------------------------- intake ---------------------------- #
    def submit(self, prompt, max_new: int) -> int:
        prompt = np.asarray(prompt, dtype=np.int32).reshape(-1)
        assert prompt.size > 0 and max_new > 0
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid=rid, prompt=prompt, max_new=max_new))
        return rid

    def admit(self) -> List[Request]:
        """FIFO admission while a row AND a full page set are available."""
        admitted = []
        while self.queue:
            row = next((r for r, q in enumerate(self.rows) if q is None),
                       None)
            if row is None:
                break
            pps = self.layout.pages_per_shard
            if not all(a.can_alloc(pps) for a in self.allocs):
                break  # head-of-line waits for an eviction to recycle pages
            req = self.queue.pop(0)
            req.pages = np.concatenate([a.alloc(pps) for a in self.allocs])
            req.row = row
            req.state = PREFILL
            self.rows[row] = req
            admitted.append(req)
        return admitted

    # ---------------------------- assembly ----------------------------- #
    def assemble(self) -> Tuple[List[Request], List[Request]]:
        """Work for one engine step: (prefilling requests — one chunk each,
        decoding requests — one shared ragged decode step)."""
        pre = [q for q in self.rows if q is not None and q.state == PREFILL]
        dec = [q for q in self.rows if q is not None and q.state == DECODE]
        return pre, dec

    # --------------------------- transitions --------------------------- #
    def to_decode(self, req: Request, first_token: int) -> None:
        """Prefill finished: record the token sampled from the last-chunk
        logits and (unless max_new == 1) enter the decode cohort."""
        assert req.state == PREFILL and req.prefilled == req.prompt_len
        req.out.append(int(first_token))
        if req.done:
            self.finish(req)
        else:
            req.state = DECODE

    def record_token(self, req: Request, token: int) -> None:
        assert req.state == DECODE
        req.out.append(int(token))
        if req.done:
            self.finish(req)

    def finish(self, req: Request) -> None:
        """Completion/eviction: recycle the pages, free the row."""
        req.state = DONE
        pps = self.layout.pages_per_shard
        for s, a in enumerate(self.allocs):
            a.release(req.pages[s * pps: (s + 1) * pps])
        if self.on_finish is not None:
            self.on_finish(req.row, req.pages)
        req.pages = None
        self.rows[req.row] = None
        req.row = -1
        self.finished[req.rid] = req

    # ------------------------------ status ----------------------------- #
    @property
    def idle(self) -> bool:
        return not self.queue and all(q is None for q in self.rows)

    def results(self) -> Dict[int, np.ndarray]:
        return {rid: np.asarray(req.out, dtype=np.int32)
                for rid, req in sorted(self.finished.items())}
