"""Request scheduler for the continuous-batching engine.

Host-side control plane: requests enter a priority/FIFO admission queue,
get pages and an engine row on admission, move through PREFILL (one
plan-driven chunk per engine step) into DECODE (all decoding rows share one
ragged kernel launch per step), and on completion release their pages back
to the pool — which is what lets the next waiting request in. The engine
(:class:`repro.serve.engine.ContinuousEngine`) owns the device arrays; this
module owns the lifecycle.

Per-step work assembly (:meth:`Batcher.assemble`) deliberately mixes the
two phases: every engine step advances each prefilling request by exactly
one chunk AND runs one decode step for the whole decoding cohort, so long
prompts never stall token emission for requests already decoding — the
standard continuous-batching contract (Orca/vLLM), driven here by the
ChunkPlan/ragged-decode machinery.

Robust-serving semantics (the fault-tolerance control plane):

* **Variable footprints** — admission allocates only the pages a request's
  full span ``prompt_len + max_new - 1`` can ever touch
  (:meth:`PagedLayout.pages_needed`); unneeded page-table tail entries stay
  on the null page. A short request no longer pins the worst-case ring.
* **Admission control** — ``submit`` rejects immediately
  (:class:`~repro.ft.faults.RejectedRequest`, with sizing) when the
  footprint exceeds what the pool can EVER provide — the scenario that
  previously deadlocked behind FIFO until a drain-time ``RuntimeError`` —
  and applies backpressure (:class:`~repro.ft.faults.QueueFull`) when the
  bounded queue is full.
* **Preemption** — when admission stalls on pages, the youngest
  strictly-lower-priority DECODE request is evicted: pages released,
  request requeued carrying ``prompt + out``, later recovered through the
  ordinary chunked re-prefill path (``prefill_tokens``). Emission stays
  exactly-once: a resumed request's re-prefill does NOT re-sample the token
  it already emitted.
* **Deadlines** — ``submit(..., deadline_s=...)`` arms a per-request
  deadline on the injectable ``clock``; :meth:`expire` moves overdue
  requests (queued or running) to a failed-with-reason terminal state and
  frees their pages instead of occupying them forever.
* **Snapshot/restore** — :meth:`state_dict`/:meth:`load_state` serialize
  the ENTIRE lifecycle (queue, rows, finished, allocator free lists in
  exact order, counters), riding the engine snapshot so a restored run
  replays deterministically.
* **Observability** — every lifecycle transition (submitted -> admitted ->
  first token -> preempted/expired/finished) emits a trace event on the
  ``requests`` track and feeds the metrics registry: queue-wait, TTFT and
  per-output-token latency histograms plus preemption / deadline-miss /
  completion counters, all labeled by priority class (the per-tenant
  fairness story in BENCH_serve.json). Timestamps ride the batcher's
  injectable ``clock`` — the same one deadlines use — and survive
  snapshot/restore as relative offsets, like deadlines do.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.ft.faults import QueueFull, RejectedRequest
from repro.obs import Observability
from repro.serve.paged_cache import PageAllocator, PagedLayout

WAITING, PREFILL, DECODE, DONE, FAILED = (
    "waiting", "prefill", "decode", "done", "failed")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new: int
    priority: int = 0             # higher preempts lower on page pressure
    deadline: Optional[float] = None   # absolute, on the batcher's clock
    state: str = WAITING
    row: int = -1                 # engine batch row while running
    pages: Optional[np.ndarray] = None   # (pages_per_req,) physical pages
    prefilled: int = 0            # prefill tokens already in the cache
    out: List[int] = dataclasses.field(default_factory=list)
    error: Optional[str] = None   # failure reason in FAILED state
    preemptions: int = 0
    # Lifecycle timestamps on the batcher's clock (observability):
    # ``submit_ts`` anchors TTFT, ``wait_since`` anchors the current
    # queue-wait (reset on preemption requeue), ``last_token_ts`` anchors
    # per-output-token latency. Snapshots carry them as relative offsets.
    submit_ts: Optional[float] = None
    wait_since: Optional[float] = None
    last_token_ts: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def total_positions(self) -> int:
        """Positions this request can ever write: the prompt plus every
        generated token that gets fed back (the final sampled token is
        emitted but never fed)."""
        return self.prompt_len + self.max_new - 1

    @property
    def prefill_tokens(self) -> np.ndarray:
        """What (re-)prefill must feed: the prompt, plus — after a
        preemption — every already-emitted token except the last (which is
        fed by the next decode step, exactly as it would have been)."""
        if not self.out:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.out[:-1], np.int32)])

    @property
    def prefill_len(self) -> int:
        return self.prompt_len + max(len(self.out) - 1, 0)

    @property
    def t_next(self) -> int:
        """Position of the next token to feed in DECODE state (the last
        sampled token): prompt_len + generated - 1."""
        return self.prompt_len + len(self.out) - 1

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new


class Batcher:
    """Admission, per-step batch assembly, preemption/expiry, completion."""

    # Release callback, set by the engine: called as
    # ``on_finish(row, pages)`` whenever a row's pages return to the pool
    # (completion, preemption, deadline expiry) and before the row is
    # cleared — the engine uses it to retire per-row page statistics and
    # zero recycled pages' int8 scales so a reused page starts from a
    # fresh quantization grid.
    on_finish = None

    # Fault-injection hook (``FaultInjector.attach``): admission treats a
    # False return exactly like an empty page pool.
    admission_gate: Optional[Callable[[], bool]] = None

    def __init__(self, layout: PagedLayout, n_pages: int, max_batch: int,
                 max_queue: Optional[int] = None,
                 clock: Callable[[], float] = time.monotonic,
                 obs: Optional[Observability] = None):
        # One allocator per sequence shard (layout.shards == 1 -> exactly
        # the single-pool engine): a request takes its per-shard page needs
        # (:meth:`PagedLayout.pages_needed_per_shard`) from each shard's
        # pool, so ``n_pages`` is the per-shard pool size. Request.pages is
        # the full-width (pages_per_req,) table image — entry j names a
        # physical page on shard j // pages_per_shard, 0 (null) where the
        # request's span never reaches.
        self.layout = layout
        self.n_pages = n_pages
        self.allocs = [PageAllocator(n_pages) for _ in range(layout.shards)]
        self.alloc = self.allocs[0]
        self.max_batch = max_batch
        self.max_queue = max_queue
        self.clock = clock
        self.queue: List[Request] = []
        self.rows: List[Optional[Request]] = [None] * max_batch
        self.finished: Dict[int, Request] = {}
        self._next_rid = 0
        self.preemptions = 0
        self.expired = 0
        self.obs = obs if obs is not None else Observability()

    # --------------------------- observability ------------------------- #
    def _event(self, name: str, req: Request, **args) -> None:
        self.obs.tracer.instant(name, track="requests", rid=req.rid,
                                priority=req.priority, **args)

    def _observe_wait(self, req: Request) -> float:
        """Record the queue wait ending now (admission); returns it."""
        wait = (0.0 if req.wait_since is None
                else max(self.clock() - req.wait_since, 0.0))
        self.obs.registry.observe("serve_queue_wait_s", wait,
                                  priority=req.priority)
        return wait

    # ------------------------------- intake ---------------------------- #
    def submit(self, prompt, max_new: int, priority: int = 0,
               deadline_s: Optional[float] = None) -> int:
        """Admission-controlled intake. Raises
        :class:`~repro.ft.faults.RejectedRequest` when the request's KV
        footprint can never fit the page pool (previously discovered only
        at drain time via ``engine.step``'s RuntimeError), and
        :class:`~repro.ft.faults.QueueFull` when the bounded queue is at
        capacity (backpressure — shed load or retry later)."""
        prompt = np.asarray(prompt, dtype=np.int32).reshape(-1)
        assert prompt.size > 0 and max_new > 0
        total = int(prompt.size) + max_new - 1
        needs = self.layout.pages_needed_per_shard(total)
        usable = self.n_pages - 1     # page 0 is the reserved null page
        if max(needs) > usable:
            self.obs.registry.inc("serve_requests_rejected")
            raise RejectedRequest(
                f"request can never fit: prompt_len={prompt.size} + "
                f"max_new={max_new} spans {total} positions needing "
                f"{max(needs)} pages on a shard (page={self.layout.page}), "
                f"but each pool holds only {usable} usable pages — resize "
                f"n_pages or split the request")
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            self.obs.registry.inc("serve_queue_full")
            raise QueueFull(
                f"admission queue full ({len(self.queue)} waiting, "
                f"max_queue={self.max_queue})")
        rid = self._next_rid
        self._next_rid += 1
        now = self.clock()
        req = Request(
            rid=rid, prompt=prompt, max_new=max_new, priority=priority,
            deadline=(None if deadline_s is None else now + deadline_s),
            submit_ts=now, wait_since=now)
        self.queue.append(req)
        self.obs.registry.inc("serve_requests_submitted", priority=priority)
        self._event("request.submitted", req, prompt_len=int(prompt.size),
                    max_new=max_new)
        return rid

    # ----------------------------- admission --------------------------- #
    def _sort_queue(self) -> None:
        """Priority order, FIFO within a priority class."""
        self.queue.sort(key=lambda r: (-r.priority, r.rid))

    def _shard_needs(self, req: Request) -> List[int]:
        return self.layout.pages_needed_per_shard(req.total_positions)

    def _pages_fit(self, needs: List[int]) -> bool:
        if self.admission_gate is not None and not self.admission_gate():
            return False
        return all(a.can_alloc(n) for a, n in zip(self.allocs, needs))

    def _take_pages(self, needs: List[int]) -> np.ndarray:
        pps = self.layout.pages_per_shard
        pages = np.zeros(self.layout.pages_per_req, np.int32)
        for s, (a, n) in enumerate(zip(self.allocs, needs)):
            if n:
                pages[s * pps: s * pps + n] = a.alloc(n)
        return pages

    def admit(self) -> List[Request]:
        """Head-of-line admission in priority order while a row AND the
        head's page needs are available (head-of-line per sorted order —
        later requests cannot starve an earlier bigger one)."""
        admitted = []
        while self.queue:
            self._sort_queue()
            row = next((r for r, q in enumerate(self.rows) if q is None),
                       None)
            if row is None:
                break
            needs = self._shard_needs(self.queue[0])
            if not self._pages_fit(needs):
                break  # head-of-line waits for recycled pages (or preempts)
            req = self.queue.pop(0)
            req.pages = self._take_pages(needs)
            req.row = row
            req.state = PREFILL
            req.prefilled = 0
            self.rows[row] = req
            admitted.append(req)
            wait = self._observe_wait(req)
            self.obs.registry.inc("serve_requests_admitted",
                                  priority=req.priority)
            self._event("request.admitted", req, row=row,
                        queue_wait_s=round(wait, 6))
        return admitted

    def maybe_preempt(self) -> int:
        """Page-pressure preemption: while the queue head cannot get its
        pages, evict the youngest DECODE request of strictly lower
        priority — release its pages, requeue it carrying ``prompt + out``
        for chunked re-prefill. Only strictly-lower-priority victims are
        eligible (monotone: a requeued victim can never bounce its own
        preemptor), so equal-priority traffic stays FIFO and livelock-free.
        Returns the number of requests preempted."""
        n = 0
        while self.queue:
            self._sort_queue()
            head = self.queue[0]
            if next((r for r in self.rows if r is None), None) is not None \
                    and self._pages_fit(self._shard_needs(head)):
                break
            victims = [q for q in self.rows
                       if q is not None and q.state == DECODE
                       and q.priority < head.priority]
            if not victims:
                break
            victim = max(victims, key=lambda q: (-q.priority, q.rid))
            self.preempt(victim)
            n += 1
        return n

    def preempt(self, req: Request) -> None:
        """Evict one DECODE request: pages back to the pool, request back
        to the queue with its emitted tokens intact (re-prefill recovers
        the KV; nothing is re-emitted)."""
        assert req.state == DECODE, req.state
        self._release(req)
        req.state = WAITING
        req.prefilled = 0
        req.preemptions += 1
        self.preemptions += 1
        req.wait_since = self.clock()   # queue wait restarts at eviction
        self.queue.append(req)
        self.obs.registry.inc("serve_preemptions", priority=req.priority)
        self._event("request.preempted", req, emitted=len(req.out))

    # ---------------------------- assembly ----------------------------- #
    def assemble(self) -> Tuple[List[Request], List[Request]]:
        """Work for one engine step: (prefilling requests — one chunk each,
        decoding requests — one shared ragged decode step)."""
        pre = [q for q in self.rows if q is not None and q.state == PREFILL]
        dec = [q for q in self.rows if q is not None and q.state == DECODE]
        return pre, dec

    # --------------------------- transitions --------------------------- #
    def to_decode(self, req: Request, first_token: int) -> None:
        """Prefill finished. A fresh request records the token sampled from
        the last-chunk logits; a preemption-resumed request (``out``
        non-empty) already emitted that token before eviction — re-sampling
        would double-emit, so it goes straight back to the decode cohort
        (exactly-once emission)."""
        assert req.state == PREFILL and req.prefilled == req.prefill_len
        now = self.clock()
        if not req.out:
            req.out.append(int(first_token))
            ttft = (max(now - req.submit_ts, 0.0)
                    if req.submit_ts is not None else 0.0)
            self.obs.registry.observe("serve_ttft_s", ttft,
                                      priority=req.priority)
            self._event("request.first_token", req, ttft_s=round(ttft, 6))
        req.last_token_ts = now
        if req.done:
            self.finish(req)
        else:
            req.state = DECODE

    def record_token(self, req: Request, token: int) -> None:
        assert req.state == DECODE
        req.out.append(int(token))
        now = self.clock()
        if req.last_token_ts is not None:
            self.obs.registry.observe(
                "serve_tpot_s", max(now - req.last_token_ts, 0.0),
                priority=req.priority)
        req.last_token_ts = now
        if req.done:
            self.finish(req)

    def _release(self, req: Request) -> None:
        """Return a running request's pages to the pool and free its row
        (shared by completion, preemption, and deadline expiry)."""
        pps = self.layout.pages_per_shard
        for s, a in enumerate(self.allocs):
            held = req.pages[s * pps: (s + 1) * pps]
            a.release(held[held > 0])
        if self.on_finish is not None:
            self.on_finish(req.row, req.pages)
        self.rows[req.row] = None
        req.pages = None
        req.row = -1

    def finish(self, req: Request) -> None:
        """Completion/eviction: recycle the pages, free the row."""
        req.state = DONE
        self._release(req)
        self.finished[req.rid] = req
        self.obs.registry.inc("serve_requests_finished",
                              priority=req.priority)
        self._event("request.finished", req, n_out=len(req.out),
                    preemptions=req.preemptions)

    def expire(self) -> List[Request]:
        """Deadline sweep: move every overdue request — queued or running —
        to the FAILED terminal state with a reason, freeing its pages/row
        so it stops occupying the pool. Returns the expired requests."""
        now = self.clock()
        out = []
        for req in list(self.queue) + [q for q in self.rows if q]:
            if req.deadline is None or now <= req.deadline:
                continue
            if req.row >= 0:
                self._release(req)
            else:
                self.queue.remove(req)
            req.state = FAILED
            req.error = (f"deadline expired after "
                         f"{len(req.out)}/{req.max_new} tokens")
            self.finished[req.rid] = req
            self.expired += 1
            out.append(req)
            self.obs.registry.inc("serve_deadline_miss",
                                  priority=req.priority)
            self._event("request.expired", req, emitted=len(req.out))
        return out

    # --------------------------- snapshotting --------------------------- #
    def state_dict(self) -> dict:
        """JSON-serializable image of the whole lifecycle. Deadlines are
        stored as remaining time and re-anchored on the restoring
        process's clock; allocator free lists keep their exact order so a
        restored run hands out the same physical pages (determinism)."""
        now = self.clock()

        def rel(t: Optional[float]) -> Optional[float]:
            return None if t is None else t - now

        def enc(req: Optional[Request]):
            if req is None:
                return None
            return {"rid": req.rid, "prompt": req.prompt.tolist(),
                    "max_new": req.max_new, "priority": req.priority,
                    "deadline_rem": (None if req.deadline is None
                                     else req.deadline - now),
                    "state": req.state, "row": req.row,
                    "pages": (None if req.pages is None
                              else req.pages.tolist()),
                    "prefilled": req.prefilled, "out": list(req.out),
                    "error": req.error, "preemptions": req.preemptions,
                    "submit_rel": rel(req.submit_ts),
                    "wait_since_rel": rel(req.wait_since),
                    "last_token_rel": rel(req.last_token_ts)}

        return {"queue": [enc(q) for q in self.queue],
                "rows": [enc(q) for q in self.rows],
                "finished": [enc(q) for q in self.finished.values()],
                "next_rid": self._next_rid,
                "free": [list(a._free) for a in self.allocs],
                "preemptions": self.preemptions,
                "expired": self.expired}

    def load_state(self, st: dict) -> None:
        now = self.clock()

        def abs_(r: Optional[float]) -> Optional[float]:
            # old snapshots have no timestamp keys -> None (metrics that
            # need them degrade gracefully, nothing else changes)
            return None if r is None else now + r

        def dec(d):
            if d is None:
                return None
            return Request(
                rid=d["rid"], prompt=np.asarray(d["prompt"], np.int32),
                max_new=d["max_new"], priority=d["priority"],
                deadline=(None if d["deadline_rem"] is None
                          else now + d["deadline_rem"]),
                state=d["state"], row=d["row"],
                pages=(None if d["pages"] is None
                       else np.asarray(d["pages"], np.int32)),
                prefilled=d["prefilled"], out=list(d["out"]),
                error=d["error"], preemptions=d["preemptions"],
                submit_ts=abs_(d.get("submit_rel")),
                wait_since=abs_(d.get("wait_since_rel")),
                last_token_ts=abs_(d.get("last_token_rel")))

        self.queue = [dec(d) for d in st["queue"]]
        self.rows = [dec(d) for d in st["rows"]]
        self.finished = {r.rid: r for r in map(dec, st["finished"])}
        self._next_rid = st["next_rid"]
        for a, free in zip(self.allocs, st["free"]):
            a._free = [int(p) for p in free]
        self.preemptions = st["preemptions"]
        self.expired = st["expired"]

    # ------------------------------ status ----------------------------- #
    @property
    def idle(self) -> bool:
        return not self.queue and all(q is None for q in self.rows)

    def results(self) -> Dict[int, np.ndarray]:
        """Generated tokens of successfully completed requests."""
        return {rid: np.asarray(req.out, dtype=np.int32)
                for rid, req in sorted(self.finished.items())
                if req.state == DONE}

    def failures(self) -> Dict[int, str]:
        """rid -> reason for requests in the FAILED terminal state."""
        return {rid: req.error for rid, req in sorted(self.finished.items())
                if req.state == FAILED}
