"""KV caches for serving: dense baseline and the SALO ring cache.

Baseline (assignment's decode shapes): a full ``(B, seq_len, Hkv, hd)`` cache
— slot == absolute position.

**SALO ring cache** (beyond-paper serving optimization; footprint numbers in
README §Serving and ``benchmarks/serve_stats.py`` -> BENCH_serve.json):
under the paper's hybrid sparse pattern a decode step only ever reads the
``n_global`` sink keys plus the last ``window`` keys, so the cache needs
``window + n_global`` slots regardless of context length — O(1) memory in
sequence length, the serving-side mirror of the paper's O(n·w) training
claim. Slots carry their absolute position; the position-based masks in
:func:`repro.core.blockwise.decode_attention` make ring indexing transparent
(out-of-window slots mask themselves out).

Layout: slots [0, g) pinned to the global/sink tokens; slots [g, g+w) a ring
keyed by ``position % window``.

NOTE: this is the *lockstep* cache — ``positions`` is shared by the whole
batch, so every sequence must sit at the same ``t``. The continuous-batching
engine uses the pooled paged slab (:mod:`repro.serve.paged_cache`) instead:
per-request page tables AND per-request positions (plus a ring sized for the
full dilated lookback, which this layout under-provisions at dilation > 1).

NOTE: this cache is full-precision only — K/V are stored in the model's
compute dtype. The int8 quantized-slab path (``kv_dtype="int8"`` with
per-(layer, page) scales) lives entirely in the paged slab; quantizing here
would buy little (the ring is already O(window) slots) and the lockstep
engine stays the exact-arithmetic baseline the quant path is tested against.
"""
from __future__ import annotations

import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.scheduler import PAD_SENTINEL


class RingCache(NamedTuple):
    k: jax.Array           # (B, g + w, Hkv, hd)
    v: jax.Array
    positions: jax.Array   # (g + w,) absolute position per slot (-1 = empty)


def ring_init(batch: int, window: int, n_global: int, n_kv_heads: int,
              head_dim: int, dtype) -> RingCache:
    warnings.warn(
        "ring_init builds the legacy LOCKSTEP ring cache (whole-batch "
        "shared positions, dilation-unaware ring sizing); new serving "
        "paths should use the pooled paged slab "
        "(repro.serve.paged_cache.layout_for_pattern + slab_init)",
        DeprecationWarning, stacklevel=2)
    size = n_global + window
    return RingCache(
        k=jnp.zeros((batch, size, n_kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, size, n_kv_heads, head_dim), dtype),
        positions=jnp.full((size,), -1, jnp.int32))


def ring_update(cache: RingCache, k_t: jax.Array, v_t: jax.Array, t,
                window: int, n_global: int) -> RingCache:
    """Insert the KV of position ``t`` (k_t: (B, 1, Hkv, hd))."""
    slot = jnp.where(t < n_global, t, n_global + (t - n_global) % window)
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_t, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_t, slot, axis=1)
    pos = jax.lax.dynamic_update_slice_in_dim(
        cache.positions, jnp.asarray(t, jnp.int32)[None], slot, axis=0)
    return RingCache(k, v, pos)


def ring_positions_mask(cache: RingCache):
    """Positions array for decode_attention: empty slots -> huge (masked)."""
    return jnp.where(cache.positions < 0, jnp.int32(PAD_SENTINEL),
                     cache.positions)


def bytes_per_layer(batch: int, seq_len: int, n_kv_heads: int, head_dim: int,
                    dtype_bytes: int = 2, *, window: int | None = None,
                    n_global: int = 0) -> int:
    """Cache footprint accounting (drives the serving roofline numbers)."""
    slots = seq_len if window is None else min(seq_len, window + n_global)
    return 2 * batch * slots * n_kv_heads * head_dim * dtype_bytes
