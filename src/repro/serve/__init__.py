from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve import kv_cache
