from repro.serve.engine import (ContinuousConfig, ContinuousEngine,
                                ServeConfig, ServeEngine)
from repro.serve import batcher, kv_cache, paged_cache
