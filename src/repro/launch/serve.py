"""Serving driver: lockstep baseline OR the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \\
      --batch 4 --prompt-len 32 --new-tokens 32
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \\
      --engine continuous --batch 4 --prompt-len 32 --new-tokens 16 \\
      --chunk 16 --page 8

``--engine continuous`` submits a RAGGED batch (prompt lengths spread
around ``--prompt-len``) to the paged-slab engine and reports launch
counters alongside throughput.

``--seq-shards N`` shards the continuous engine over an N-way "seq" mesh
axis (sequence-parallel serving: per-shard slab pools, sharded decode slot
map, masked-psum partial combine). Needs >= N devices — on a CPU host set
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before launching.

``--snapshot-dir DIR`` runs the continuous engine under the fault-tolerant
:class:`~repro.ft.manager.ServeSupervisor`: full engine snapshots (slabs,
page tables, request lifecycle) every ``--snapshot-every`` steps through
the atomic keep-k writer, bounded restarts on recoverable faults. Token
output is exactly-once across kill/resume. ``--inject-crash-at`` takes a
comma list of step attempts to crash (fault-injection demo):

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \\
      --engine continuous --snapshot-dir /tmp/snap --inject-crash-at 3,7

``--trace-out trace.json`` records the engine's step-phase spans and every
request's lifecycle events and writes Chrome trace-event JSON at exit
(open in chrome://tracing or https://ui.perfetto.dev); ``--metrics-out``
dumps the full metrics registry; ``--summary-every N`` prints a one-line
stderr summary (steps, launches, TTFT/TPOT p50) every N engine steps.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.models.model import build_model
from repro.obs import Observability, summary_line
from repro.serve.engine import (ContinuousConfig, ContinuousEngine,
                                ServeConfig, ServeEngine)


def _ragged_lengths(base: int, batch: int, rng) -> list:
    """Prompt lengths spread around ``base`` (min 2) — continuous batching
    exists precisely because real traffic is ragged."""
    return [max(2, int(l)) for l in
            rng.integers(max(2, base // 2), base + 1, batch)]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", choices=("lockstep", "continuous"),
                    default="lockstep")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--chunk", type=int, default=16)
    ap.add_argument("--page", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=0,
                    help="engine rows (0 = --batch)")
    ap.add_argument("--seq-shards", type=int, default=1,
                    help="sequence-parallel serving shards (continuous "
                         "engine; needs a 'seq' mesh of that many devices)")
    ap.add_argument("--kv-dtype", choices=("compute", "int8"),
                    default="compute",
                    help="paged-slab storage dtype (continuous engine): "
                         "'int8' stores K/V quantized per (layer, page) "
                         "with f32 scales, dequantized in-kernel")
    ap.add_argument("--page-sparsity-threshold", type=float, default=None,
                    help="continuous engine: skip reading pages whose "
                         "historical max attention score (log-space, "
                         "relative to the row max) fell below this; sink "
                         "and write pages are always read. Unset = dense "
                         "reads; -inf = track stats but keep everything")
    ap.add_argument("--page-stat-decay", type=float, default=0.0,
                    help="per-step decay of the per-page score history; "
                         "must be > 0 for --page-sparsity-threshold to "
                         "ever skip a page")
    ap.add_argument("--snapshot-dir", default=None,
                    help="continuous engine: run under the ServeSupervisor "
                         "with engine snapshots in this directory "
                         "(fault-tolerant serving)")
    ap.add_argument("--snapshot-every", type=int, default=4,
                    help="engine steps between snapshots")
    ap.add_argument("--max-restarts", type=int, default=4,
                    help="restart budget before RestartsExhausted")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bound the admission queue (submit raises "
                         "QueueFull beyond it); unset = unbounded")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request deadline in seconds; overdue "
                         "requests fail with a reason and free their pages")
    ap.add_argument("--inject-crash-at", default=None,
                    help="comma list of step attempts at which to inject "
                         "a StepCrash (needs --snapshot-dir)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace-event JSON of engine phases "
                         "+ request lifecycle here at exit (continuous "
                         "engine; open in chrome://tracing / Perfetto)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the full metrics-registry JSON here at exit")
    ap.add_argument("--summary-every", type=int, default=0,
                    help="print a one-line metrics summary to stderr every "
                         "N engine steps (0 = off)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    if args.engine != "continuous" and (args.trace_out or args.metrics_out
                                        or args.summary_every):
        ap.error("--trace-out/--metrics-out/--summary-every need "
                 "--engine continuous (the instrumented engine)")

    if args.engine == "continuous":
        if args.temperature != 0.0:
            ap.error("--engine continuous is greedy-only "
                     "(temperature sampling needs per-request RNG streams)")
        max_batch = args.max_batch or args.batch
        from repro.models.layers import salo_pattern
        from repro.serve.paged_cache import layout_for_pattern
        mesh = None
        if args.seq_shards > 1:
            if len(jax.devices()) < args.seq_shards:
                ap.error(f"--seq-shards {args.seq_shards} needs that many "
                         f"devices (have {len(jax.devices())}; on CPU set "
                         f"XLA_FLAGS=--xla_force_host_platform_device_"
                         f"count={args.seq_shards})")
            from repro.compat import make_mesh
            mesh = make_mesh((args.seq_shards,), ("seq",))
        lay = layout_for_pattern(salo_pattern(cfg, causal=True), args.page,
                                 shards=args.seq_shards)
        ccfg = ContinuousConfig(
            n_pages=1 + max_batch * lay.pages_per_shard, page=args.page,
            chunk=args.chunk, max_batch=max_batch,
            seq_shards=args.seq_shards, kv_dtype=args.kv_dtype,
            page_sparsity_threshold=args.page_sparsity_threshold,
            page_stat_decay=args.page_stat_decay,
            max_queue=args.max_queue)
        lens = _ragged_lengths(args.prompt_len, args.batch, rng)
        prompts = [rng.integers(0, cfg.vocab_size, (L,)) for L in lens]
        # ONE obs bundle shared by the engine, the batcher, and the
        # supervisor — and across supervisor restarts — so the exported
        # trace holds the whole timeline including kills and restores.
        obs = Observability(tracing=bool(args.trace_out))

        def summarize(reg):
            if args.summary_every and \
                    reg.total("serve_engine_steps") % args.summary_every == 0:
                print(f"# {summary_line(reg)}", file=sys.stderr, flush=True)

        def make_engine():
            eng = ContinuousEngine(model, ccfg, mesh=mesh, obs=obs)
            for p in prompts:
                eng.submit(p, args.new_tokens, deadline_s=args.deadline_s)
            return eng

        t0 = time.perf_counter()
        if args.snapshot_dir:
            from repro.ft import FaultInjector, FaultPlan, ServeSupervisor
            injector = None
            if args.inject_crash_at:
                injector = FaultInjector(FaultPlan(crash_steps=frozenset(
                    int(s) for s in args.inject_crash_at.split(","))))
            sup = ServeSupervisor(
                make_engine, params, args.snapshot_dir,
                checkpoint_every=args.snapshot_every,
                max_restarts=args.max_restarts, injector=injector, obs=obs,
                on_step=lambda eng, hist: summarize(obs.registry))
            eng, history = sup.run()
            results = eng.batcher.results()
            print(f"# supervisor: {history}")
            if eng.batcher.failures():
                print(f"# failed: {eng.batcher.failures()}")
        else:
            if args.inject_crash_at:
                ap.error("--inject-crash-at needs --snapshot-dir")
            eng = make_engine()
            while eng.step(params):
                summarize(obs.registry)
            results = eng.batcher.results()
        if args.trace_out:
            obs.write_trace(args.trace_out)
            print(f"# trace: {args.trace_out} "
                  f"({len(obs.tracer)} events)", file=sys.stderr)
        if args.metrics_out:
            obs.write_metrics(args.metrics_out)
            print(f"# metrics: {args.metrics_out}", file=sys.stderr)
        rids = sorted(results)
        dt = time.perf_counter() - t0
        total_new = args.batch * args.new_tokens
        print(f"# arch={cfg.name} engine=continuous batch={args.batch} "
              f"prompts={lens} new={args.new_tokens} chunk={args.chunk} "
              f"page={args.page} seq_shards={args.seq_shards} "
              f"kv_dtype={args.kv_dtype} "
              f"page_thr={args.page_sparsity_threshold}")
        print(f"# {dt:.2f}s total, {total_new/dt:.1f} tok/s "
              f"(includes compile); counters={eng.counters}")
        for rid in rids[:2]:
            print(f"sample[{rid}]: {results[rid][:16].tolist()}")
        return results

    max_len = args.prompt_len + args.new_tokens
    eng = ServeEngine(model, ServeConfig(max_len=max_len,
                                         temperature=args.temperature,
                                         seed=args.seed))
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                       (args.batch, args.prompt_len)))
    t0 = time.perf_counter()
    toks = jax.block_until_ready(eng.generate(params, prompts,
                                              args.new_tokens))
    dt = time.perf_counter() - t0
    total_new = args.batch * args.new_tokens
    print(f"# arch={cfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} new={args.new_tokens}")
    print(f"# {dt:.2f}s total, {total_new/dt:.1f} tok/s "
          f"(includes compile)")
    for b in range(min(args.batch, 2)):
        print(f"sample[{b}]: {np.asarray(toks[b])[:16].tolist()}")
    return toks


if __name__ == "__main__":
    main()
