"""Serving driver: batched prefill + decode with the SALO windowed cache.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \\
      --batch 4 --prompt-len 32 --new-tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.models.model import build_model
from repro.serve.engine import ServeConfig, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    max_len = args.prompt_len + args.new_tokens
    eng = ServeEngine(model, ServeConfig(max_len=max_len,
                                         temperature=args.temperature,
                                         seed=args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                       (args.batch, args.prompt_len)))
    t0 = time.perf_counter()
    toks = jax.block_until_ready(eng.generate(params, prompts,
                                              args.new_tokens))
    dt = time.perf_counter() - t0
    total_new = args.batch * args.new_tokens
    print(f"# arch={cfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} new={args.new_tokens}")
    print(f"# {dt:.2f}s total, {total_new/dt:.1f} tok/s "
          f"(includes compile)")
    for b in range(min(args.batch, 2)):
        print(f"sample[{b}]: {np.asarray(toks[b])[:16].tolist()}")
    return toks


if __name__ == "__main__":
    main()
