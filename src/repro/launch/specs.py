"""Input specs and step functions per (arch x shape) dry-run cell.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input (weak-type-correct, shardable, no device allocation) and
``build_step(cfg, shape)`` returns the function the cell lowers:

  train_4k     -> train_step  (loss + grads + AdamW update)
  prefill_32k  -> forward     (full-sequence logits)
  decode_*     -> serve_step  (one token against a seq_len KV cache)

Sharding rules per cell live here too (``cell_rules``): long-context cells
turn on sequence parallelism over the ``data`` axis; MoE cells shard expert
capacity over DP; GQA KV-head axes fall back to replication when the head
count doesn't divide the model axis.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.dist import sharding as shlib
from repro.models.model import Model, build_model
from repro.optim import adamw
from repro.train.trainer import TrainConfig, make_train_step


# ------------------------------ rules ---------------------------------- #
def cell_rules(cfg: ModelConfig, shape: ShapeCell, mesh) -> Dict[str, Any]:
    rules = dict(shlib.DEFAULT_RULES)
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = axes.get("pod", 1) * axes.get("data", 1)
    tp = axes.get("model", 1)

    if "pod" not in axes:
        rules["batch"] = ("data",)
    # Batch too small to use the whole DP product: drop to what divides.
    if shape.global_batch % dp != 0:
        if shape.global_batch % axes.get("data", 1) == 0:
            rules["batch"] = ("data",)
        else:
            rules["batch"] = None
    # Sequence parallelism for long-context cells (the SALO band makes the
    # halo cheap — DESIGN.md §4). Applies to activation/cache seq axes.
    # Exactly ONE mesh axis: the ShardedPlan halo exchange runs over a
    # single named axis (dist.sharding.sequence_mesh_axis), and keeping the
    # halo off the cross-pod DCN boundary is the right call anyway — "pod"
    # never carries seq.
    if shape.seq_len >= 32768 and rules["batch"] is None:
        rules["seq"] = ("data",)
    # KV heads: replicate when they don't divide the model axis.
    if cfg.n_kv_heads % tp != 0:
        rules["kv_heads"] = None
    if cfg.n_heads % tp != 0:
        rules["heads"] = None
    # MoE: EP over `model` only (the default "experts" rule) unless the
    # refuted expert-stationary A/B variant is requested.
    if cfg.moe is not None:
        if os.environ.get("REPRO_MOE_STATIONARY") == "1":
            ep_axes = tuple(a for a in ("model", "data", "pod") if a in axes)
            rules["experts"] = ep_axes
        rules["expert_cap"] = None
    if cfg.vocab_size % tp != 0:
        rules["vocab"] = None
    return rules


# --------------------------- input specs -------------------------------- #
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def batch_specs(cfg: ModelConfig, shape: ShapeCell) -> Dict[str, Any]:
    """ShapeDtypeStructs for the data batch of a train/prefill cell."""
    B, S = shape.global_batch, shape.seq_len
    specs = {"tokens": _sds((B, S), jnp.int32)}
    if shape.kind == "train":
        specs["labels"] = _sds((B, S), jnp.int32)
    if cfg.encoder_decoder:
        specs["audio_embeds"] = _sds((B, cfg.n_audio_frames, cfg.d_model),
                                     jnp.bfloat16)
    if cfg.n_vision_tokens:
        specs["vision_embeds"] = _sds((B, S, cfg.d_model), jnp.bfloat16)
        specs["vision_mask"] = _sds((B, S), jnp.bool_)
        specs["positions"] = _sds((3, B, S), jnp.int32)
    return specs


def decode_specs(cfg: ModelConfig, shape: ShapeCell,
                 model: Model) -> Tuple[Dict, Any]:
    """(batch_t specs, cache specs) for a decode cell: one new token with a
    KV cache of seq_len (the assignment's serve_step definition)."""
    B, S = shape.global_batch, shape.seq_len
    batch_t = {"tokens": _sds((B, 1), jnp.int32)}
    if cfg.encoder_decoder:
        batch_t["audio_embeds"] = _sds((B, cfg.n_audio_frames, cfg.d_model),
                                       jnp.bfloat16)
    if cfg.n_vision_tokens:
        batch_t["vision_embeds"] = _sds((B, 1, cfg.d_model), jnp.bfloat16)
        batch_t["vision_mask"] = _sds((B, 1), jnp.bool_)
        batch_t["positions"] = _sds((3, B, 1), jnp.int32)
    cache = jax.eval_shape(lambda: model.init_cache(B, S))
    return batch_t, cache


# ----------------------- sharding for the specs ------------------------- #
def _logical_for_batch_key(key: str):
    return {
        "tokens": ("batch", "seq"),
        "labels": ("batch", "seq"),
        "audio_embeds": ("batch", None, "embed"),
        "vision_embeds": ("batch", "seq", "embed"),
        "vision_mask": ("batch", "seq"),
        "positions": (None, "batch", "seq"),
    }[key]


def batch_shardings(specs, mesh, rules):
    # input_sharding applies _mesh_clean with the shape: pjit *argument*
    # shardings (unlike constraints) require every named axis to exist on
    # the mesh and divide its dimension (single source of truth in
    # repro.dist.sharding — the _divisible/_axes_product copies that used
    # to live here are gone).
    return {k: shlib.input_sharding(mesh, rules, *_logical_for_batch_key(k),
                                    shape=specs[k].shape)
            for k in specs}


def cache_shardings(cache_specs, mesh, rules, decode_seq_axis=None):
    """Caches: (layers, batch, slots/seq, heads..., ...) — batch over DP,
    KV seq per the cell rules (SP for long contexts). ``decode_seq_axis``
    overrides the seq rule for 5-D attention caches (e.g. shard the cache
    sequence over `model` when kv_heads doesn't divide the TP axis)."""
    r = dict(rules)
    if decode_seq_axis is not None:
        r["cache_seq"] = decode_seq_axis
    else:
        r["cache_seq"] = rules.get("seq")

    def one(path, leaf):
        nd = len(leaf.shape)
        # (L, B, S, Hkv, hd) attention caches; (L, B, *state) others.
        if nd == 5:
            logical = (None, "batch", "cache_seq", "kv_heads", None)
        elif nd == 4:
            logical = (None, "batch", None, None)
        elif nd == 3:
            logical = (None, "batch", None)
        else:
            logical = (None,) * nd
        return shlib.input_sharding(mesh, r, *logical, shape=leaf.shape)
    return jax.tree_util.tree_map_with_path(one, cache_specs)


# --------------------------- step builders ------------------------------- #
def build_cell(arch_cfg: ModelConfig, shape: ShapeCell, mesh,
               train_cfg: TrainConfig | None = None):
    """Returns (fn, example_args_specs, in_shardings, out_shardings, rules).

    ``fn`` is what gets lowered; everything is abstract (no allocation).
    """
    # A/B experiment knobs — env so a dry-run cell
    # can be re-lowered with one factor changed and nothing else.
    salo_over = {}
    if os.environ.get("REPRO_DECODE_SLICE"):
        salo_over["decode_slice"] = os.environ["REPRO_DECODE_SLICE"] == "1"
    if os.environ.get("REPRO_RING_CACHE"):
        salo_over["ring_cache"] = os.environ["REPRO_RING_CACHE"] == "1"
    if os.environ.get("REPRO_BLOCK_Q"):
        salo_over["block_q"] = int(os.environ["REPRO_BLOCK_Q"])
    if os.environ.get("REPRO_BLOCK_K"):
        salo_over["block_k"] = int(os.environ["REPRO_BLOCK_K"])
    if salo_over:
        arch_cfg = dataclasses.replace(
            arch_cfg, salo=dataclasses.replace(arch_cfg.salo, **salo_over))
    model = build_model(arch_cfg)
    rules = cell_rules(arch_cfg, shape, mesh)
    pspec_fn = functools.partial(shlib.param_shardings, mesh=mesh,
                                 rules=rules)

    params_specs = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    params_sh = pspec_fn(params_specs)

    if shape.kind == "train":
        if train_cfg is None:
            # Baseline defaults that must hold at scale: bf16 optimizer
            # moments for >=10B-param models (fp32 m/v alone would blow
            # 16 GB/chip), microbatching to bound activation memory at
            # ~16k tokens per device per microbatch.
            axes = dict(zip(mesh.axis_names, mesh.devices.shape))
            dp = axes.get("pod", 1) * axes.get("data", 1)
            tok_per_dev = shape.global_batch * shape.seq_len // max(dp, 1)
            mb = 1
            while (tok_per_dev // mb > 16384 and mb < 16
                   and shape.global_batch % (mb * 2) == 0):
                mb *= 2
            if os.environ.get("REPRO_MICROBATCHES"):
                mb = int(os.environ["REPRO_MICROBATCHES"])
            moment_dtype = ("bfloat16" if arch_cfg.n_params() > 10e9
                            else "float32")
            train_cfg = TrainConfig(
                optimizer=adamw.AdamWConfig(moment_dtype=moment_dtype),
                microbatches=mb)
        tcfg = train_cfg
        step = make_train_step(model, tcfg)
        opt_specs = jax.eval_shape(
            functools.partial(adamw.init, tcfg.optimizer), params_specs)
        opt_sh = adamw.AdamWState(
            step=shlib.input_sharding(mesh, rules),
            m=pspec_fn(opt_specs.m), v=pspec_fn(opt_specs.v),
            master=None if opt_specs.master is None
            else pspec_fn(opt_specs.master))
        bspecs = batch_specs(arch_cfg, shape)
        bsh = batch_shardings(bspecs, mesh, rules)

        def fn(params, opt_state, batch):
            with shlib.axis_rules(rules):
                # cells run compress_grads=False, so the threaded ef_state
                # is None; the cell contract stays a 3-tuple
                p, o, m, _ef = step(params, opt_state, batch)
                return p, o, m

        args = (params_specs, opt_specs, bspecs)
        in_sh = (params_sh, opt_sh, bsh)
        out_sh = (params_sh, opt_sh, None)
        fn.donate_argnums = (0, 1)   # params/opt updated in place
        return fn, args, in_sh, out_sh, rules

    if shape.kind == "prefill":
        bspecs = batch_specs(arch_cfg, shape)
        bsh = batch_shardings(bspecs, mesh, rules)

        def fn(params, batch):
            with shlib.axis_rules(rules):
                return model.forward(params, batch)

        fn.donate_argnums = ()
        return fn, (params_specs, bspecs), (params_sh, bsh), None, rules

    # decode
    bt_specs, cache_specs = decode_specs(arch_cfg, shape, model)

    def _decode_logical(key):
        # one-token inputs: never shard the (length-1) seq axis
        logical = list(_logical_for_batch_key(key))
        for i, name in enumerate(logical):
            if name == "seq":
                logical[i] = None
        return tuple(logical)

    bt_sh = {k: shlib.input_sharding(mesh, rules, *_decode_logical(k),
                                     shape=bt_specs[k].shape)
             for k in bt_specs}
    # If KV heads don't divide the TP axis, put the model axis on the cache
    # sequence instead: TP ranks each hold a slice of the context and the
    # softmax merges across them (the paper's Eq. 2 at TP scale).
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = axes.get("model", 1)
    decode_seq_axis = None
    if arch_cfg.n_kv_heads % tp != 0:
        existing = rules.get("seq") or ()
        existing = existing if isinstance(existing, tuple) else (existing,)
        decode_seq_axis = tuple(existing) + ("model",)
    cache_sh = cache_shardings(cache_specs, mesh, rules,
                               decode_seq_axis=decode_seq_axis)
    t_spec = _sds((), jnp.int32)
    t_sh = shlib.input_sharding(mesh, rules)

    def fn(params, cache, batch_t, t):
        with shlib.axis_rules(rules):
            return model.decode_step(params, cache, batch_t, t)

    args = (params_specs, cache_specs, bt_specs, t_spec)
    in_sh = (params_sh, cache_sh, bt_sh, t_sh)
    out_sh = (None, cache_sh)
    fn.donate_argnums = (1,)         # KV cache updated in place
    return fn, args, in_sh, out_sh, rules
