"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \\
      --steps 300 --seq 512 --batch 8 [--smoke] [--ckpt DIR] [--resume]

Runs on whatever devices exist (`--data/--model` mesh dims), with the full
production stack: SALO attention, sharding rules, grad clip + schedule,
checkpoint manager (atomic/keep-k/async), straggler watchdog, restart-safe
data stream.

``--trace-out trace.json`` records per-step spans (+ checkpoint/straggler
instants) and writes Chrome trace-event JSON at exit; ``--metrics-out``
dumps the metrics registry (step-time histogram, token/step counters,
kernel trace-time launch accounting).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.dist import sharding as shlib
from repro.ft.checkpoint import CheckpointManager
from repro.ft.manager import StragglerWatchdog
from repro.launch.mesh import make_host_mesh
from repro.models.model import build_model
from repro.obs import Observability
from repro.obs.metrics import global_registry
from repro.optim import adamw
from repro.optim.schedule import Schedule
from repro.train.trainer import TrainConfig, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true",
                    help="int8 error-feedback gradient all-reduce over the "
                         "data/pod mesh axes")
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data-branch", type=int, default=16)
    ap.add_argument("--data-docs", type=int, default=64)
    ap.add_argument("--trace-out", default=None,
                    help="write Chrome trace-event JSON of the step "
                         "timeline here at exit (chrome://tracing/Perfetto)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the full metrics-registry JSON here at exit")
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    mesh = make_host_mesh(args.data, args.model)
    rules = dict(shlib.DEFAULT_RULES, batch=("data",), fsdp=None)

    tcfg = TrainConfig(
        optimizer=adamw.AdamWConfig(lr=args.lr),
        schedule=Schedule(warmup_steps=max(10, args.steps // 20),
                          total_steps=args.steps),
        microbatches=args.microbatches,
        compress_grads=args.compress_grads)

    params = model.init(jax.random.PRNGKey(args.seed))
    opt = adamw.init(tcfg.optimizer, params)
    n_par = sum(x.size for x in jax.tree.leaves(params))
    print(f"# arch={cfg.name} params={n_par/1e6:.1f}M "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"window={cfg.salo.window} sinks={cfg.salo.n_global}")

    mgr = CheckpointManager(args.ckpt, keep=3) if args.ckpt else None
    start = 0
    if mgr and args.resume:
        restored, step0 = mgr.restore_latest({"params": params, "opt": opt})
        if restored is not None:
            params, opt = restored["params"], restored["opt"]
            start = step0
            print(f"# resumed from step {start}")

    raw_step = make_train_step(model, tcfg)

    def fn(p, o, b, ef):
        with shlib.axis_rules(rules, mesh):
            return raw_step(p, o, b, ef)

    # donate ef too: under --compress-grads it is a params-sized f32 tree
    # per participant, replaced wholesale every step (None when off —
    # donating an empty pytree is a no-op)
    step = jax.jit(fn, donate_argnums=(0, 1, 3))
    ef = None   # error-feedback residual, threaded through every step
    ds = SyntheticLM(cfg, DataConfig(args.seq, args.batch, seed=args.seed,
                                     branch=args.data_branch,
                                     n_docs=args.data_docs))
    wd = StragglerWatchdog()
    obs = Observability(tracing=bool(args.trace_out))
    reg = obs.registry

    with mesh:
        for i in range(start, args.steps):
            t0 = time.perf_counter()
            with obs.tracer.span("train.step", track="train", step=i):
                batch = {k: jnp.asarray(v)
                         for k, v in ds.batch(i).items()}
                params, opt, metrics, ef = step(params, opt, batch, ef)
                loss = float(metrics["loss"])   # host sync inside the span
            dt = time.perf_counter() - t0
            reg.inc("train_steps")
            reg.inc("train_tokens", args.batch * args.seq)
            reg.observe("train_step_s", dt)
            straggler = wd.observe(dt)
            if straggler:
                reg.inc("ft_straggler_events")
                obs.tracer.instant("ft.straggler", track="ft", step=i,
                                   step_time_s=round(dt, 6))
            if i % args.log_every == 0 or i == args.steps - 1:
                toks = args.batch * args.seq / dt
                print(f"step {i:5d} loss {loss:8.4f} "
                      f"gnorm {float(metrics['grad_norm']):7.3f} "
                      f"{dt*1e3:7.1f} ms {toks/1e3:7.1f} ktok/s"
                      + (" [straggler]" if straggler else ""), flush=True)
            if mgr and (i + 1) % args.ckpt_every == 0:
                mgr.save({"params": params, "opt": opt}, i + 1)
                obs.tracer.instant("ft.snapshot", track="ft", step=i + 1)
    if mgr:
        mgr.save({"params": params, "opt": opt}, args.steps)
        mgr.wait()
    if args.trace_out:
        obs.write_trace(args.trace_out)
        print(f"# trace: {args.trace_out} ({len(obs.tracer)} events)",
              file=sys.stderr)
    if args.metrics_out:
        # Fold in the process-wide kernel trace-time launch accounting so
        # the dump is the complete picture for this run.
        reg.merge(global_registry().snapshot())
        obs.write_metrics(args.metrics_out)
        print(f"# metrics: {args.metrics_out}", file=sys.stderr)
    st = reg.percentiles("train_step_s")
    print(f"# done: final loss {loss:.4f}, straggler events {wd.events}, "
          f"step p50 {st['p50'] * 1e3:.1f} ms")
    return loss


if __name__ == "__main__":
    main()
