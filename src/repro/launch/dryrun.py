import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract the roofline terms.

  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod] [--force]

Per cell: jit(step).lower(**ShapeDtypeStructs) -> .compile() ->
memory_analysis() (bytes/device: proves it fits) + cost_analysis() (FLOPs,
bytes) + HLO collective parse -> results/dryrun/<cell>.json. Resumable —
existing JSONs are skipped unless --force.
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCHS, get_config
from repro.configs.base import SHAPES, SHAPES_BY_NAME
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell
from repro.roofline import analysis

ASSIGNED = tuple(a for a in ARCHS if a != "longformer-4k")
RESULTS = os.path.join(os.path.dirname(__file__), "../../..", "results",
                       "dryrun")


def cell_id(arch: str, shape: str, multi_pod: bool) -> str:
    return f"{arch}__{shape}__{'pod2' if multi_pod else 'pod1'}"


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             results_dir: str = RESULTS, force: bool = False,
             keep_hlo: bool = False) -> dict:
    os.makedirs(results_dir, exist_ok=True)
    cid = cell_id(arch, shape_name, multi_pod)
    out_path = os.path.join(results_dir, cid + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()

    fn, args, in_sh, out_sh, rules = build_cell(cfg, shape, mesh)
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=getattr(fn, "donate_argnums", ()))
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()

    roof = analysis.analyze(cost, hlo, n_chips,
                            analysis.model_flops(cfg, shape))
    result = {
        "cell": cid, "arch": arch, "shape": shape_name,
        "mesh": list(mesh.devices.shape), "n_chips": n_chips,
        "rules": {k: (list(v) if isinstance(v, tuple) else v)
                  for k, v in rules.items()},
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes_per_device": (mem.argument_size_in_bytes
                                      + mem.temp_size_in_bytes),
            "fits_16GB": (mem.argument_size_in_bytes
                          + mem.temp_size_in_bytes) < 16e9,
        },
        "roofline": roof.to_dict(),
        "n_params": cfg.n_params(),
        "n_active_params": cfg.n_active_params(),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    if keep_hlo:
        with open(os.path.join(results_dir, cid + ".hlo.txt"), "w") as f:
            f.write(hlo)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=[s.name for s in SHAPES] + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--results", default=RESULTS)
    args = ap.parse_args()

    archs = ASSIGNED if (args.all or args.arch is None) else [args.arch]
    shapes = ([s.name for s in SHAPES] if (args.all or args.shape is None)
              else [args.shape])
    meshes = [False, True] if args.both_meshes else [args.multipod]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                cid = cell_id(arch, shape, mp)
                try:
                    r = run_cell(arch, shape, mp, args.results, args.force,
                                 args.keep_hlo)
                    roof = r["roofline"]
                    print(f"{cid:55s} ok  dom={roof['dominant']:10s} "
                          f"bound={max(roof['compute_s'], roof['memory_s'], roof['collective_s']):.4f}s "
                          f"mem/dev={r['memory']['peak_bytes_per_device']/1e9:.2f}GB "
                          f"compile={r.get('compile_s', 0)}s", flush=True)
                except Exception as e:
                    failures.append((cid, repr(e)))
                    print(f"{cid:55s} FAIL {e!r}", flush=True)
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for cid, err in failures:
            print(f"  {cid}: {err}")
        raise SystemExit(1)
    print("\nall cells passed")


if __name__ == "__main__":
    main()
