"""Production meshes.

Single pod: 16 x 16 = 256 chips (``data`` x ``model``).
Multi-pod:  2 x 16 x 16 = 512 chips (``pod`` x ``data`` x ``model``) — the
``pod`` axis carries only data parallelism (gradient all-reduce crosses the
DCN/ICI pod boundary; everything bandwidth-hungry stays intra-pod).

Functions, not module constants: importing this module must never touch jax
device state (smoke tests run on 1 CPU device; only dryrun.py forces 512).
"""
from __future__ import annotations

from repro.compat import make_mesh, _axis_type_auto


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=_axis_type_auto(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests, examples)."""
    return make_mesh((data, model), ("data", "model"),
                     axis_types=_axis_type_auto(2))


# TPU v5e hardware constants (roofline denominators; consumed by
# repro/roofline/analysis.py and benchmarks/roofline_report.py).
PEAK_FLOPS_BF16 = 197e12     # per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
