"""The static soundness gate: ``python -m repro.analysis.lint``.

Runs all three analysis passes over every registered config/pattern and
exits nonzero on any error finding:

1. the plan soundness prover (:mod:`repro.analysis.plan_verify`) over the
   registry's plan targets — coverage, adjoint, per-shard exchange,
   never-drop for the dynamic targets, dynamic full-keep replay — and
   over every prefill chunk slice of the chunk targets;
2. the jaxpr effect linter (:mod:`repro.analysis.jaxpr_lint`) over the
   traced entry points — forward/backward launch contract, the dK/dV
   scatter twin, the masked psum merge, the engine's ragged-decode step —
   plus the decode write-ownership probe and per-launch VMEM estimates;
3. the stdlib AST code lint (:mod:`repro.analysis.code_lint`) over
   ``src``, ``tests`` and ``benchmarks`` (CI additionally runs ruff).

``--out report.json`` writes the machine-readable report
(``{"targets": [...], "findings": [...], "summary": {...}}``) that
``benchmarks/verify_stats.py`` gates on.
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from typing import List

from repro.analysis import Finding, render


def run_plan_pass(findings: List[Finding], targets: List[str]) -> None:
    from repro.analysis import plan_verify as pv
    from repro.analysis.registry import chunk_targets, plan_targets
    from repro.core.scheduler import build_chunk_plan, build_plan, schedule

    for t in plan_targets():
        sched = schedule(t.pattern, t.n)
        plan = sched.plan(t.block_q, t.block_k)
        findings += pv.verify_plan(plan, t.name, never_drop=t.dynamic,
                                   local_window=t.local_window)
        if t.dynamic:
            findings += pv.verify_dynamic_full_keep(plan, t.name)
        for S in t.n_shards:
            padded = build_plan(sched, t.block_q, t.block_k,
                                S * math.lcm(t.block_q, t.block_k))
            findings += pv.verify_plan(padded, t.name, n_shards=(S,))
        targets.append(t.name)

    from repro.serve.paged_cache import layout_for_pattern
    for ct in chunk_targets():
        lay = layout_for_pattern(ct.pattern, ct.page)
        c0 = 0
        while c0 < ct.prompt:
            clen = min(ct.chunk, ct.prompt - c0)
            cp = build_chunk_plan(ct.pattern, c0, clen, n_sink=lay.n_sink,
                                  ring_cap=lay.ring_cap, block=ct.page)
            findings += pv.verify_chunk(
                cp, f"{ct.name}[{c0}:{c0 + clen}]", n_shards=ct.n_shards)
            c0 += clen
        targets.append(ct.name)


def run_jaxpr_pass(findings: List[Finding], targets: List[str],
                   engine: bool = True) -> None:
    import repro.core.patterns as P
    from repro.analysis import jaxpr_lint as jl
    from repro.core.scheduler import schedule
    from repro.serve.paged_cache import layout_for_pattern

    pat = P.longformer(32, n_global=4)
    findings += jl.check_launch_contract(pat, 128, 32, 32, "kernels.ops")
    findings += jl.lint_traced(jl.trace_dkv_scatter(pat, 128, 32, 32),
                               "table_dkv_scatter_scan")
    findings += jl.lint_traced(jl.trace_masked_psum_merge(),
                               "masked_psum_merge")
    findings += jl.check_vmem(schedule(pat, 1024).plan(128, 128), d=64,
                              target="kernels.salo_attention",
                              decode={"rep": 4, "head_dim": 64,
                                      "block_s": 8})
    targets += ["kernels.ops", "table_dkv_scatter_scan",
                "masked_psum_merge"]

    for shards in (1, 2):
        lay = layout_for_pattern(P.causal_sliding_window(16, n_sinks=2), 8,
                                 shards=shards)
        findings += jl.check_write_ownership(
            lay, f"paged_layout@{shards}shards")
        targets.append(f"paged_layout@{shards}shards")

    if engine:
        import jax

        from repro.configs import get_smoke
        from repro.models.layers import salo_pattern
        from repro.models.model import build_model
        from repro.serve.engine import ContinuousConfig, ContinuousEngine

        cfg = get_smoke("smollm-135m")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        lay = layout_for_pattern(salo_pattern(cfg, causal=True), 8)
        eng = ContinuousEngine(model, ContinuousConfig(
            n_pages=1 + 4 * lay.pages_per_req, page=8, chunk=8,
            max_batch=4))
        findings += jl.lint_traced(jl.trace_engine_decode(eng, params),
                                   "engine.decode")
        targets.append("engine.decode")


def run_code_pass(findings: List[Finding], targets: List[str],
                  paths: List[str]) -> None:
    from repro.analysis.code_lint import lint_paths
    findings += lint_paths(paths)
    targets += paths


def collect(engine: bool = True,
            paths: List[str] = ("src", "tests", "benchmarks")) -> dict:
    """Run every pass; the report dict the CLI and benchmark share."""
    findings: List[Finding] = []
    targets: List[str] = []
    run_plan_pass(findings, targets)
    run_jaxpr_pass(findings, targets, engine=engine)
    run_code_pass(findings, targets, list(paths))
    errors = [f for f in findings if f.severity == "error"]
    by_pass: dict = {}
    for f in findings:
        by_pass[f.pass_name] = by_pass.get(f.pass_name, 0) + 1
    return {
        "targets": targets,
        "findings": [f.as_dict() for f in findings],
        "summary": {
            "targets_checked": len(targets),
            "findings": len(findings),
            "errors": len(errors),
            "by_pass": by_pass,
            "plans_sound": 1.0 if not errors else 0.0,
        },
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="static soundness gate: plan prover + jaxpr effect "
                    "lint + code lint")
    ap.add_argument("--out", default=None,
                    help="write the JSON report here")
    ap.add_argument("--skip-engine", action="store_true",
                    help="skip the (slow) serving-engine decode trace")
    ap.add_argument("--paths", nargs="*",
                    default=["src", "tests", "benchmarks"],
                    help="roots for the code lint pass")
    args = ap.parse_args(argv)

    report = collect(engine=not args.skip_engine, paths=args.paths)
    if args.out:
        with open(args.out, "w") as fh:
            json.dump(report, fh, indent=2, sort_keys=True)
    findings = [Finding(**d) for d in report["findings"]]
    print(render(findings))
    s = report["summary"]
    print(f"checked {s['targets_checked']} targets: "
          f"{s['errors']} errors, {s['findings']} findings")
    return 1 if s["errors"] else 0


if __name__ == "__main__":
    sys.exit(main())
