"""repro.analysis — static soundness verification and repo lint gates.

Three cooperating passes (run together by ``python -m repro.analysis.lint``):

* :mod:`repro.analysis.plan_verify` — the plan soundness prover. For every
  concrete ExecutionPlan / TransposedPlan / PackedTransposedPlan /
  ChunkPlan / ShardedPlan it proves exact tile coverage against the
  pattern mask (no missing tiles, no double-counted tiles), adjoint
  soundness (transposed/packed tables are an exact permutation of the
  forward walk), shard-exchange soundness (per-shard tables plus the
  ppermute/psum schedule reconstruct exactly the unsharded tile set) and
  the dynamic never-drop invariant — with counterexamples naming the
  offending (q-block, kv-block) tile.
* :mod:`repro.analysis.jaxpr_lint` — the effect linter over the jitted
  entry points: scatter index-mode races, non-owner slab writes,
  collective dtype leaks, unreduced shard_map outputs, double dequant,
  pallas launch-count contract, per-launch VMEM budget estimates.
* :mod:`repro.analysis.code_lint` — a stdlib-``ast`` fallback for the
  ruff CI step (unused imports, mutable default arguments, shadowed
  builtins), so the gate also runs on hosts without ruff installed.

Which plans/patterns get verified is declared once, in
:mod:`repro.analysis.registry`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class Finding:
    """One verified defect, with the counterexample that proves it.

    ``q_block``/``kv_block`` name the offending tile of the plan grid the
    pass was walking (the working/view tile universe of that plan) when
    the defect is tile-addressable; pure structural findings leave them
    ``None``.
    """
    pass_name: str                    # "coverage" | "adjoint" | "exchange" |
    #                                   "never-drop" | "chunk" | "jaxpr" | ...
    target: str                       # registry target / entry point name
    message: str
    q_block: Optional[int] = None
    kv_block: Optional[int] = None
    severity: str = "error"

    def counterexample(self) -> str:
        loc = ""
        if self.q_block is not None or self.kv_block is not None:
            loc = f" [counterexample: (q_block={self.q_block}, " \
                  f"kv_block={self.kv_block})]"
        return f"{self.severity}: {self.target}: {self.pass_name}: " \
               f"{self.message}{loc}"

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def render(findings: List[Finding]) -> str:
    return "\n".join(f.counterexample() for f in findings)


__all__ = ["Finding", "render"]
