"""Stdlib-AST code lint: the local stand-in for the ruff gate.

CI runs ruff (``[tool.ruff]`` in pyproject.toml) — but ruff is an
optional install, and the analysis gate must work on a bare interpreter.
This module re-implements the violation classes the repo actually gates
on, using only ``ast``:

* **unused imports** (F401): an imported name never read anywhere in the
  module (``__init__.py`` re-exports and ``__all__`` entries excepted);
* **undefined exports**: an ``__all__`` string naming nothing defined or
  imported at module level;
* **mutable default arguments**: a ``list``/``dict``/``set`` literal or
  constructor call as a parameter default;
* **shadowed builtins**: a function/class/assignment binding over a
  curated set of builtins where shadowing is overwhelmingly a bug
  (``list``/``dict``/``set``/… — deliberately NOT ``l``/``id``-style
  single letters the numeric code uses idiomatically);
* **bare except**: ``except:`` with no exception class (E722).

Findings come back as :class:`repro.analysis.Finding` values with the
file and line.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Iterable, List, Set

from repro.analysis import Finding

# Builtins whose shadowing is gated. Deliberately excludes single-letter
# math/softmax names (l, id-style) the numeric code uses idiomatically.
SHADOW_BUILTINS = {"list", "dict", "set", "tuple", "str", "bytes", "type",
                   "object", "print", "open", "isinstance", "getattr",
                   "setattr", "super", "property", "staticmethod",
                   "classmethod", "enumerate", "zip", "map"}


def _imported_names(tree: ast.Module):
    """(alias node, bound name, lineno) for every module-level import."""
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out.append((a, (a.asname or a.name).split(".")[0],
                            node.lineno))
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            for a in node.names:
                if a.name == "*":
                    continue
                out.append((a, a.asname or a.name, node.lineno))
    return out


def _used_names(tree: ast.Module) -> Set[str]:
    used: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.Attribute):
            root = node
            while isinstance(root, ast.Attribute):
                root = root.value
            if isinstance(root, ast.Name):
                used.add(root.id)
    for node in ast.walk(tree):          # strings in __all__ count as usage
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == "__all__":
                    for el in ast.walk(node.value):
                        if isinstance(el, ast.Constant) \
                                and isinstance(el.value, str):
                            used.add(el.value)
    return used


def _module_bindings(tree: ast.Module) -> Set[str]:
    out: Set[str] = set()
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            out.add(node.name)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    out.add(t.id)
        elif isinstance(node, ast.AnnAssign) \
                and isinstance(node.target, ast.Name):
            out.add(node.target.id)
    return out


def _is_mutable_default(node: ast.expr) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                         ast.DictComp, ast.SetComp)):
        return True
    return (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id in ("list", "dict", "set"))


def lint_source(src: str, path: str) -> List[Finding]:
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [Finding("code-lint", path,
                        f"syntax error at line {e.lineno}: {e.msg}")]
    findings: List[Finding] = []
    is_init = Path(path).name == "__init__.py"

    used = _used_names(tree)
    if not is_init:                      # __init__ re-exports are the point
        for a, name, lineno in _imported_names(tree):
            if a.asname is not None and a.asname == a.name:
                continue                 # `import X as X`: explicit re-export
            if name not in used and not name.startswith("_"):
                findings.append(Finding(
                    "code-lint", path,
                    f"line {lineno}: unused import '{name}'"))

    bound = _module_bindings(tree) | {n for _, n, _l in
                                      _imported_names(tree)}
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == "__all__" \
                        and isinstance(node.value, (ast.List, ast.Tuple)):
                    for el in node.value.elts:
                        if isinstance(el, ast.Constant) \
                                and isinstance(el.value, str) \
                                and el.value not in bound:
                            findings.append(Finding(
                                "code-lint", path,
                                f"line {node.lineno}: __all__ exports "
                                f"undefined name '{el.value}'"))

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for d in list(node.args.defaults) \
                    + [d for d in node.args.kw_defaults if d is not None]:
                if _is_mutable_default(d):
                    findings.append(Finding(
                        "code-lint", path,
                        f"line {node.lineno}: function '{node.name}' has a "
                        f"mutable default argument"))
        elif isinstance(node, ast.ExceptHandler) and node.type is None:
            findings.append(Finding(
                "code-lint", path,
                f"line {node.lineno}: bare 'except:' (catch a class)"))

    # Shadowing is gated at MODULE level only (a method named ``set`` is
    # normal API; a module-level ``list = ...`` is a landmine).
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)) \
                and node.name in SHADOW_BUILTINS:
            findings.append(Finding(
                "code-lint", path,
                f"line {node.lineno}: module-level '{node.name}' shadows "
                f"a builtin"))
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id in SHADOW_BUILTINS:
                    findings.append(Finding(
                        "code-lint", path,
                        f"line {node.lineno}: module-level assignment "
                        f"shadows builtin '{t.id}'"))
    return findings


def lint_paths(roots: Iterable[str]) -> List[Finding]:
    findings: List[Finding] = []
    for root in roots:
        p = Path(root)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            findings += lint_source(f.read_text(), str(f))
    return findings
