"""The jaxpr effect linter: trace the jitted entry points, walk every
(nested) jaxpr, and flag effect-level hazards the unit tests cannot see
from output values alone.

What is checked, per traced entry point:

* **launch contract** (:func:`check_launch_contract`): the forward wrapper
  lowers to exactly ONE ``pallas_call`` (the paper's fused single-launch
  claim) and its gradient to exactly three (forward replay for residual
  recompute is forbidden — dQ and dK/dV walk the saved stats).
* **scatter modes** (:func:`check_scatter_modes`): a ``scatter-add`` with
  ``unique_indices=True`` is a write-write race — the dK/dV scatter twin
  and the packed transposed walk *rely* on duplicate owner tiles
  accumulating; an overwrite ``scatter`` with ``unique_indices=True``
  breaks the paged-slab null-page contract, where every inactive row's
  write deliberately collides on page 0.
* **psum dtype** (:func:`check_psum_dtype`): any floating ``psum`` operand
  narrower than f32 means partial ``(out, m, l)`` triples were downcast
  before the cross-shard merge — the masked psum must combine f32.
* **double dequant** (:func:`check_double_dequant`): one int8 value
  widened by two separate ``convert_element_type`` equations in the same
  jaxpr is the int8-slab double-dequant bug shape (scale applied twice).
* **shard_map reductions** (:func:`check_shard_map_reduction`): a
  ``shard_map`` region with sharded inputs, replicated outputs, and NO
  collective anywhere inside is letting unreduced partials escape.
* **write ownership** (:func:`check_write_ownership`): a numeric probe of
  the decode write routing — for every shard index and every cache
  position, the physical write target must be the owner's page or the
  null page 0, never another shard's storage.
* **VMEM budget** (:func:`check_vmem`): per-``pallas_call`` resident-block
  estimates (block shapes x dtype bytes, including the LANES-wide decode
  stat layout and f32 scratch) against the 16 MiB VMEM budget.

Pure stdlib + jax tracing: nothing here executes a kernel.
"""
from __future__ import annotations

from typing import Any, Dict, Iterator, List, Optional

import numpy as np

from repro.analysis import Finding

VMEM_BUDGET = 16 * 2 ** 20       # bytes of VMEM one core can hold resident
LANES = 128                      # TPU lane width (decode stat blocks)

_COLLECTIVES = ("psum", "pmax", "pmin", "all_gather", "reduce_scatter",
                "ppermute", "all_to_all", "psum_scatter")


# ---------------------------------------------------------------------- #
# Generic jaxpr walking (duck-typed: survives jax API renames)
# ---------------------------------------------------------------------- #
def _as_jaxpr(obj) -> Optional[Any]:
    if hasattr(obj, "jaxpr") and hasattr(obj, "consts"):   # ClosedJaxpr
        return obj.jaxpr
    if hasattr(obj, "eqns") and hasattr(obj, "invars"):    # Jaxpr
        return obj
    return None


def walk_jaxprs(obj) -> Iterator[Any]:
    """Yield ``obj``'s jaxpr and every jaxpr nested in equation params
    (scan/cond/while/pjit/shard_map/custom_vjp bodies), depth-first,
    each distinct jaxpr once."""
    seen: set = set()

    def rec(o):
        j = _as_jaxpr(o)
        if j is None:
            if isinstance(o, (tuple, list)):
                for x in o:
                    rec(x)
            return
        if id(j) in seen:
            return
        seen.add(id(j))
        yield_list.append(j)
        for eqn in j.eqns:
            for p in eqn.params.values():
                rec(p)

    yield_list: List[Any] = []
    rec(obj)
    return iter(yield_list)


def iter_eqns(obj) -> Iterator[Any]:
    for j in walk_jaxprs(obj):
        for eqn in j.eqns:
            yield eqn


def count_primitive(obj, name: str) -> int:
    return sum(1 for e in iter_eqns(obj) if e.primitive.name == name)


def _dtype_of(var) -> Optional[np.dtype]:
    aval = getattr(var, "aval", None)
    dt = getattr(aval, "dtype", None)
    return np.dtype(dt) if dt is not None else None


# ---------------------------------------------------------------------- #
# Launch contract
# ---------------------------------------------------------------------- #
def check_launch_contract(pattern, n: int, block_q: int, block_k: int,
                          target: str = "") -> List[Finding]:
    """Forward = 1 ``pallas_call``, grad = 3 (dQ + packed dK/dV + the
    forward's own launch replayed for residuals is NOT allowed — the
    third launch is the grad-time forward of ``custom_vjp`` residual
    plumbing, i.e. fwd(1) + dq(1) + dkv(1))."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.ops import LAUNCH_CONTRACT, salo_attention

    findings: List[Finding] = []
    d = 16
    q = jnp.zeros((1, n, d), jnp.float32)

    fwd = jax.make_jaxpr(
        lambda a, b, c: salo_attention(a, b, c, pattern, block_q, block_k,
                                       None, True))(q, q, q)
    n_fwd = count_primitive(fwd, "pallas_call")
    if n_fwd != LAUNCH_CONTRACT["forward"]:
        findings.append(Finding(
            "launch-contract", target,
            f"forward lowers to {n_fwd} pallas_call launches, the fused "
            f"single-launch contract requires exactly "
            f"{LAUNCH_CONTRACT['forward']}"))

    grad = jax.make_jaxpr(jax.grad(
        lambda a, b, c: salo_attention(a, b, c, pattern, block_q, block_k,
                                       None, True).sum(),
        argnums=(0, 1, 2)))(q, q, q)
    n_grad = count_primitive(grad, "pallas_call")
    if n_grad != LAUNCH_CONTRACT["grad"]:
        findings.append(Finding(
            "launch-contract", target,
            f"gradient lowers to {n_grad} pallas_call launches, the "
            f"no-forward-recompute contract requires exactly "
            f"{LAUNCH_CONTRACT['grad']} (fwd + dQ + dK/dV)"))
    return findings


# ---------------------------------------------------------------------- #
# Effect checks over an arbitrary traced jaxpr
# ---------------------------------------------------------------------- #
def check_scatter_modes(traced, target: str = "") -> List[Finding]:
    findings: List[Finding] = []
    for eqn in iter_eqns(traced):
        name = eqn.primitive.name
        if name not in ("scatter-add", "scatter", "scatter-max",
                        "scatter-mul", "scatter-min"):
            continue
        if not eqn.params.get("unique_indices", False):
            continue
        if name == "scatter-add":
            findings.append(Finding(
                "scatter-race", target,
                "scatter-add with unique_indices=True: duplicate owner "
                "tiles across packed rows make this a write-write race"))
        else:
            findings.append(Finding(
                "scatter-race", target,
                f"{name} with unique_indices=True: the paged-slab write "
                f"path relies on harmless null-page-0 collisions"))
    return findings


def check_psum_dtype(traced, target: str = "") -> List[Finding]:
    findings: List[Finding] = []
    for eqn in iter_eqns(traced):
        if eqn.primitive.name != "psum":
            continue
        import jax.numpy as jnp
        for var in eqn.invars:
            dt = _dtype_of(var)
            if dt is not None and jnp.issubdtype(dt, jnp.floating) \
                    and dt != np.float32 and dt != np.float64:
                findings.append(Finding(
                    "psum-dtype", target,
                    f"psum over {dt} operand: partial (out, m, l) stats "
                    f"must stay f32 until after the cross-shard merge"))
    return findings


def check_double_dequant(traced, target: str = "") -> List[Finding]:
    findings: List[Finding] = []
    for j in walk_jaxprs(traced):
        widened: Dict[int, int] = {}
        for eqn in j.eqns:
            if eqn.primitive.name != "convert_element_type":
                continue
            for var in eqn.invars:
                dt = _dtype_of(var)
                if dt == np.int8 and not isinstance(
                        getattr(var, "val", None), (int, np.generic)):
                    widened[id(var)] = widened.get(id(var), 0) + 1
        for n_conv in widened.values():
            if n_conv > 1:
                findings.append(Finding(
                    "double-dequant", target,
                    f"one int8 value widened by {n_conv} separate "
                    f"convert_element_type equations in a single jaxpr — "
                    f"the double-dequant bug shape (scale applied twice)"))
    return findings


def check_shard_map_reduction(traced, target: str = "") -> List[Finding]:
    """A shard_map with sharded inputs, replicated outputs, and no
    collective inside leaks unreduced partials. Param layout differs
    across jax versions — every access is defensive; regions we cannot
    interpret are skipped, not flagged."""
    findings: List[Finding] = []
    for eqn in iter_eqns(traced):
        if eqn.primitive.name != "shard_map":
            continue
        body = eqn.params.get("jaxpr")
        if body is None:
            continue
        names_in = eqn.params.get("in_names", eqn.params.get("in_specs"))
        names_out = eqn.params.get("out_names", eqn.params.get("out_specs"))
        if names_in is None or names_out is None:
            continue

        def _mapped(spec) -> Optional[bool]:
            if isinstance(spec, dict):                   # {axis_pos: names}
                return bool(spec)
            try:                                         # PartitionSpec-like
                return any(x is not None for x in tuple(spec))
            except TypeError:
                return None
        ins = [_mapped(s) for s in names_in]
        outs = [_mapped(s) for s in names_out]
        if any(i for i in ins if i) and outs \
                and all(o is False for o in outs):
            has_collective = any(
                e.primitive.name in _COLLECTIVES for e in iter_eqns(body))
            if not has_collective:
                findings.append(Finding(
                    "shard-map-reduction", target,
                    "shard_map region consumes sharded inputs, emits only "
                    "replicated outputs, and contains no collective — "
                    "unreduced per-shard partials escape"))
    return findings


# ---------------------------------------------------------------------- #
# Decode write ownership (numeric probe)
# ---------------------------------------------------------------------- #
def check_write_ownership(lay, target: str = "") -> List[Finding]:
    """Probe the sharded decode write routing over every cache position
    and shard index: a shard may write its owned slot's physical page or
    the null page 0 — never another shard's storage, never an inactive
    row's page."""
    import jax.numpy as jnp

    from repro.serve.engine import sharded_write_target

    findings: List[Finding] = []
    npp_s = lay.pages_per_shard
    T = lay.n_sink + lay.ring_cap + 5
    t_vec = jnp.arange(T, dtype=jnp.int32)
    active_np = (np.arange(T) % 4) != 3          # mix of live/dead rows
    active = jnp.asarray(active_np)
    table_np = 1 + np.arange(T * npp_s).reshape(T, npp_s)
    for idx in range(lay.shards):
        own_table = table_np + idx * T * npp_s
        keep, local_slot, phys, off = (
            np.asarray(a) for a in sharded_write_target(
                lay, jnp.asarray(own_table, jnp.int32), t_vec, active, idx))
        slot = np.asarray(lay.slot(t_vec))
        owner = np.asarray(lay.slot_owner(slot))
        for r in range(T):
            owned = bool(active_np[r]) and int(owner[r]) == idx
            if not owned:
                if phys[r] != 0:
                    findings.append(Finding(
                        "write-ownership", target,
                        f"shard {idx} writes physical page {int(phys[r])} "
                        f"for position {r} it does not own (owner "
                        f"{int(owner[r])}, active={bool(active_np[r])}) — "
                        f"non-owner writes must route to null page 0"))
                continue
            want = int(own_table[r, int(local_slot[r]) // lay.page])
            if int(phys[r]) != want or int(off[r]) != \
                    int(local_slot[r]) % lay.page:
                findings.append(Finding(
                    "write-ownership", target,
                    f"shard {idx} position {r}: write lands on page "
                    f"{int(phys[r])} offset {int(off[r])}, expected its "
                    f"own page {want} offset "
                    f"{int(local_slot[r]) % lay.page}"))

    # Unsharded twin: inactive rows must hit the null page.
    table = jnp.asarray(1 + np.arange(
        T * lay.pages_per_req).reshape(T, lay.pages_per_req), jnp.int32)
    phys, off = (np.asarray(a) for a in
                 lay.write_target(table, t_vec, keep=active))
    if (phys[~active_np] != 0).any():
        r = int(np.nonzero((phys != 0) & ~active_np)[0][0])
        findings.append(Finding(
            "write-ownership", target,
            f"inactive row {r} writes physical page {int(phys[r])}, "
            f"expected null page 0"))
    return findings


# ---------------------------------------------------------------------- #
# VMEM budget estimates
# ---------------------------------------------------------------------- #
def attention_vmem_bytes(block_q: int, block_k: int, d: int,
                         dtype_bytes: int = 4) -> Dict[str, int]:
    """Resident bytes per grid step for each training launch, from the
    kernels' BlockSpecs (q/k/v/out tiles, f32 row stats, f32 scratch)."""
    bq, bk = block_q, block_k
    fwd = (dtype_bytes * (bq * d + 2 * bk * d + bq * d)   # q, k, v, out
           + 4 * (bq + bk)                                # position tiles
           + 4 * 2 * bq                                   # m, l outputs
           + 4 * (bq * d + 2 * bq))                       # acc + m/l scratch
    dq = (4 * (bq + bk)
          + dtype_bytes * (bq * d + 2 * bk * d + bq * d)  # q, k, v, dout
          + 4 * 3 * bq                                    # m, l, delta
          + 4 * 2 * bq * d)                               # dq out + scratch
    dkv = (4 * (bq + bk)
           + dtype_bytes * (bq * d + 2 * bk * d + bq * d)
           + 4 * 3 * bq
           + 4 * 4 * bk * d)                              # dk/dv out+scratch
    return {"forward": fwd, "backward_dq": dq, "backward_dkv": dkv}


def decode_vmem_bytes(rep: int, head_dim: int, block_s: int,
                      dtype_bytes: int = 4) -> int:
    """Paged ragged decode: q/out (rep, hd), k/v slab tiles (bs, hd), pos
    (bs,), LANES-wide f32 (m, l) stat blocks + per-step page_m block, f32
    scratch (acc + m + l)."""
    return (dtype_bytes * (2 * rep * head_dim + 2 * block_s * head_dim)
            + 4 * block_s
            + 4 * 2 * rep * LANES                         # m, l out blocks
            + 4 * LANES                                   # page_m block
            + 4 * (rep * head_dim + 2 * rep * LANES))     # scratch


def check_vmem(plan, d: int = 64, dtype_bytes: int = 4,
               target: str = "", decode: Optional[dict] = None,
               budget: int = VMEM_BUDGET) -> List[Finding]:
    findings: List[Finding] = []
    est = attention_vmem_bytes(plan.block_q, plan.block_k, d, dtype_bytes)
    if decode is not None:
        est["paged_decode"] = decode_vmem_bytes(
            decode["rep"], decode["head_dim"], decode["block_s"],
            decode.get("dtype_bytes", dtype_bytes))
    for name, b in est.items():
        if b > budget:
            findings.append(Finding(
                "vmem-budget", target,
                f"{name} launch holds ~{b / 2 ** 20:.1f} MiB resident "
                f"(blocks x dtype), over the {budget / 2 ** 20:.0f} MiB "
                f"VMEM budget"))
    return findings


# ---------------------------------------------------------------------- #
# Entry-point tracing drivers
# ---------------------------------------------------------------------- #
def trace_dkv_scatter(pattern, n: int, block_q: int, block_k: int):
    """Jaxpr of the runtime dK/dV scatter twin over a real plan's tables."""
    import jax
    import jax.numpy as jnp

    from repro.core.blockwise import table_dkv_scatter_scan
    from repro.core.scheduler import schedule

    sched = schedule(pattern, n)
    plan = sched.plan(block_q, block_k)
    pos = plan.positions_padded()
    pos_q = jnp.asarray(pos.reshape(plan.nq, block_q))
    pos_k = jnp.asarray(pos.reshape(plan.nkb, block_k))
    d = 16
    z = jnp.zeros((1, plan.n_pad, d), jnp.float32)
    r = jnp.zeros((1, plan.n_pad), jnp.float32)
    return jax.make_jaxpr(
        lambda dout, delta, m, l, q, k, v, kvb, fl: table_dkv_scatter_scan(
            dout, delta, m, l, q, k, v, pos_q, pos_k, kvb, fl, sched, 1.0)
    )(z, r, r, r, z, z, z, jnp.asarray(plan.kv_blocks),
      jnp.asarray(plan.flags))


def trace_masked_psum_merge():
    """Jaxpr of the cross-shard merge under a 1-device-mesh shard_map,
    with a bf16 ``out`` operand (the merge must cast, then psum f32)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as Pspec

    from repro.compat import shard_map
    from repro.dist.sharded_plan import masked_psum_merge

    mesh = Mesh(np.array(jax.devices()[:1]), ("seq",))
    f = shard_map(
        lambda o, m, l: masked_psum_merge(o, m, l, "seq"),
        mesh=mesh, in_specs=(Pspec("seq"), Pspec("seq"), Pspec("seq")),
        out_specs=Pspec("seq"), check_vma=False)
    o = jnp.zeros((1, 4, 8), jnp.bfloat16)
    s = jnp.zeros((1, 4), jnp.float32)
    return jax.make_jaxpr(f)(o, s, s)


def trace_engine_decode(eng, params):
    """Jaxpr of an engine's ragged-decode step from its live state (the
    same trace the observability zero-cost gate compares)."""
    import jax
    import jax.numpy as jnp

    R = eng.ccfg.max_batch
    z = jnp.zeros(R, jnp.int32)
    return jax.make_jaxpr(eng._decode_fn)(
        params, eng.slabs, eng.page_tables.copy(), eng.slot_pos,
        z, z, jnp.zeros(R, bool))


def lint_traced(traced, target: str = "") -> List[Finding]:
    """All effect checks that apply to an arbitrary traced jaxpr."""
    return (check_scatter_modes(traced, target)
            + check_psum_dtype(traced, target)
            + check_double_dequant(traced, target)
            + check_shard_map_reduction(traced, target))
