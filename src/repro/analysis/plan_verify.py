"""The plan soundness prover: static proofs over concrete step tables.

Every check here is an *independent* numpy re-derivation — masks are
re-evaluated from the pattern definition (not through the jnp
``step_mask`` the engines use), visit multisets are rebuilt from the raw
tables, and the exchange schedule is replayed hop by hop — so a bug in
the builders cannot hide inside a shared helper. What is proved, per
plan:

* **coverage** (:func:`verify_coverage`): walking the forward tables and
  applying each step's flag-gated mask touches every attended
  (query, key) pair of ``window ∪ global-column`` exactly once — no
  missing tiles, no double-counted tiles across fused steps — and the
  union, mapped back through the data-reordering permutation, equals the
  dense ``pattern.mask(n)`` oracle on every row the plan owns (global
  rows belong to the dense epilogue).
* **adjoint** (:func:`verify_transposed` / :func:`verify_packed`): the
  transposed and packed-transposed tables are an exact permutation of
  the forward walk — the same ``(q_block, kv_tile, flags)`` visit
  multiset, nothing dropped, nothing invented.
* **exchange** (:func:`verify_sharded`): each shard's remapped
  ``[local | halo | global]`` tables, pushed through
  ``ShardedPlan.view_map``, reconstruct exactly the unsharded visit set;
  every halo view slot's owner sits at its group's declared distance and
  the owner's ``send_idx`` schedules precisely that tile on that
  ppermute hop; every global tile has exactly one owner feeding the
  masked psum; view positions agree with the owning tile's positions.
* **never-drop** (:func:`verify_never_drop`): global/sink steps and
  causal-local tiles are all inside the always-keep mask, the worst-case
  always count fits the table width (a feasible keep budget exists),
  ``check_keep`` accepts it and rejects one less, and an adversarial
  top-k simulation (content maximally against the protected tiles)
  still keeps every protected step.
* **chunk** (:func:`verify_chunk`): each prefill chunk slice covers,
  exactly once, every causally attended (query position, cached/chunk
  key position) pair; every attended key position is actually present
  in the ``[sink | ring | chunk]`` view (the ring never evicts a key
  the pattern still needs); the per-shard chunk tables reconstruct the
  unsharded chunk walk with each view tile on exactly one shard.

Failures come back as :class:`repro.analysis.Finding` values naming the
offending (q-block, kv-block) tile.
"""
from __future__ import annotations

from collections import Counter
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.analysis import Finding
from repro.core.plan_contract import (PAD_SENTINEL, STEP_GLOBAL, STEP_WINDOW,
                                      VALID_FLAGS, iter_real_steps)
from repro.core.scheduler import (BandSchedule, ChunkPlan, ExecutionPlan,
                                  PackedTransposedPlan, TransposedPlan)

Visit = Tuple[int, int, int]           # (q_block, kv_tile, flags)
VisitCounter = Counter                 # Counter[Visit]


# ---------------------------------------------------------------------- #
# Independent numpy mask references (NOT the jnp step_mask the engines run)
# ---------------------------------------------------------------------- #
def _np_window(sched: BandSchedule, pi: np.ndarray,
               pj: np.ndarray) -> np.ndarray:
    p = sched.pattern
    pi = pi.astype(np.int64)
    pj = pj.astype(np.int64)
    ok = (pi < sched.n) & (pj < sched.n)
    if p.is_2d:
        g = p.n_global
        _, w = p.grid2d
        wh, ww = p.window2d
        yi, xi = (pi - g) // w, (pi - g) % w
        yj, xj = (pj - g) // w, (pj - g) % w
        m = (np.abs(yj - yi) <= wh // 2) & (np.abs(xj - xi) <= ww // 2)
        m = m & (pi >= g) & (pj >= g)
    else:
        a, b = p.window
        rel = pj - pi
        m = (rel >= a) & (rel <= b)
        if p.dilation > 1:
            m = m & (rel % p.dilation == 0)
    if sched.causal:
        m = m & (pj <= pi)
    return m & ok


def _np_step_mask(sched: BandSchedule, pi: np.ndarray, pj: np.ndarray,
                  flags: int) -> np.ndarray:
    w = _np_window(sched, pi, pj)
    m = w & bool(flags & STEP_WINDOW)
    if sched.n_global > 0:
        gcol = (pj.astype(np.int64) < sched.n_global) \
            & (pi.astype(np.int64) < sched.n) & ~w
        if sched.causal:
            gcol = gcol & (pj.astype(np.int64) <= pi.astype(np.int64))
        m = m | (gcol & bool(flags & STEP_GLOBAL))
    return m


def _np_causal_union(pattern, qp: np.ndarray, kp: np.ndarray,
                     flags: int) -> np.ndarray:
    """Serving-side reference of ``causal_step_mask`` (original positions,
    causal window ∪ global column, flag-gated) in pure numpy."""
    qp = qp.astype(np.int64)
    kp = kp.astype(np.int64)
    a, b = pattern.window
    rel = kp - qp
    w = (rel >= a) & (rel <= min(b, 0))
    if pattern.dilation > 1:
        w = w & (rel % pattern.dilation == 0)
    m = w & bool(flags & STEP_WINDOW)
    if pattern.n_global > 0:
        m = m | ((kp < pattern.n_global) & bool(flags & STEP_GLOBAL))
    return m & (kp <= qp) & (qp < PAD_SENTINEL) & (kp < PAD_SENTINEL)


# ---------------------------------------------------------------------- #
# Visit multisets
# ---------------------------------------------------------------------- #
def forward_visits(plan: ExecutionPlan) -> VisitCounter:
    """The forward walk as a ``(q_block, kv_tile, flags)`` multiset."""
    return Counter((i, t, f)
                   for i, _s, t, f in iter_real_steps(plan.kv_blocks,
                                                      plan.flags))


def _diff_visits(fwd: VisitCounter, other: VisitCounter, pass_name: str,
                 target: str, other_name: str) -> List[Finding]:
    out: List[Finding] = []
    for (i, t, f), c in sorted((fwd - other).items()):
        out.append(Finding(
            pass_name, target,
            f"{other_name} drops {c} forward visit(s) of q_block {i} x "
            f"kv_block {t} (flags {f})", q_block=i, kv_block=t))
    for (i, t, f), c in sorted((other - fwd).items()):
        out.append(Finding(
            pass_name, target,
            f"{other_name} invents {c} visit(s) of q_block {i} x "
            f"kv_block {t} (flags {f}) absent from the forward walk",
            q_block=i, kv_block=t))
    return out


# ---------------------------------------------------------------------- #
# 1. Coverage
# ---------------------------------------------------------------------- #
def verify_coverage(plan: ExecutionPlan, target: str = "") -> List[Finding]:
    """Prove exact tile coverage of the forward tables (see module doc)."""
    findings: List[Finding] = []
    sched = plan.sched
    bq, bk = plan.block_q, plan.block_k
    pos = plan.positions_padded().astype(np.int64)
    pos_q = pos.reshape(plan.nq, bq)
    pos_k = pos.reshape(plan.nkb, bk)

    count = np.zeros((plan.n_pad, plan.n_pad), dtype=np.int32)
    for i, _s, t, f in iter_real_steps(plan.kv_blocks, plan.flags):
        if f & ~VALID_FLAGS:
            findings.append(Finding(
                "coverage", target,
                f"step of q_block {i} carries unknown flag bits {f}",
                q_block=i, kv_block=t))
            continue
        sub = _np_step_mask(sched, pos_q[i][:, None], pos_k[t][None, :], f)
        count[i * bq:(i + 1) * bq, t * bk:(t + 1) * bk] += sub

    expected = _np_step_mask(sched, pos[:, None], pos[None, :], VALID_FLAGS)

    dbl = count > 1
    if dbl.any():
        wi, wj = (int(x) for x in np.argwhere(dbl)[0])
        findings.append(Finding(
            "coverage", target,
            f"pair (working {wi}, {wj}) = original "
            f"({int(pos[wi])}, {int(pos[wj])}) is double-counted across "
            f"fused steps ({int(count[wi, wj])} visits)",
            q_block=wi // bq, kv_block=wj // bk))
    miss = expected & (count == 0)
    if miss.any():
        wi, wj = (int(x) for x in np.argwhere(miss)[0])
        findings.append(Finding(
            "coverage", target,
            f"attended pair (working {wi}, {wj}) = original "
            f"({int(pos[wi])}, {int(pos[wj])}) is missing from every step",
            q_block=wi // bq, kv_block=wj // bk))
    extra = (count > 0) & ~expected
    if extra.any():
        wi, wj = (int(x) for x in np.argwhere(extra)[0])
        findings.append(Finding(
            "coverage", target,
            f"unattended pair (working {wi}, {wj}) is covered by a step",
            q_block=wi // bq, kv_block=wj // bk))

    # Cross-check against the dense pattern oracle on ORIGINAL positions.
    n, g = sched.n, sched.n_global
    valid = pos < n
    vp = pos[valid].astype(np.int64)
    cov = np.zeros((n, n), dtype=bool)
    cov[vp[:, None], vp[None, :]] = (count > 0)[np.ix_(valid, valid)]
    oracle = sched.pattern.mask(n)
    rowsel = np.ones(n, dtype=bool)
    if g > 0 and sched.global_rows:
        rowsel[:g] = False          # dense-epilogue rows: not the plan's job
    mismatch = (cov != oracle) & rowsel[:, None]
    if mismatch.any():
        oi, oj = (int(x) for x in np.argwhere(mismatch)[0])
        inv = sched.inverse_perm()
        wi = int(inv[oi]) if inv is not None else oi
        wj = int(inv[oj]) if inv is not None else oj
        what = "missing from" if oracle[oi, oj] else "not in the pattern yet"
        findings.append(Finding(
            "coverage", target,
            f"plan coverage disagrees with pattern.mask at original pair "
            f"({oi}, {oj}): pair {what} the plan walk",
            q_block=wi // bq, kv_block=wj // bk))
    return findings


# ---------------------------------------------------------------------- #
# 2. Adjoint
# ---------------------------------------------------------------------- #
def verify_transposed(plan: ExecutionPlan,
                      tp: Optional[TransposedPlan] = None,
                      target: str = "") -> List[Finding]:
    """Prove the transposed tables are an exact permutation of the forward
    walk (adjoint soundness of the dK/dV schedule)."""
    tp = plan.transposed() if tp is None else tp
    got: VisitCounter = Counter(
        (qb, j, f) for j, _s, qb, f in iter_real_steps(tp.q_blocks, tp.flags))
    return _diff_visits(forward_visits(plan), got, "adjoint", target,
                        "transposed walk")


def verify_packed(plan: ExecutionPlan,
                  pk: Optional[PackedTransposedPlan] = None,
                  target: str = "") -> List[Finding]:
    """Same proof for the packed layout: rows map through ``row_tile``."""
    pk = plan.transposed_packed() if pk is None else pk
    got: VisitCounter = Counter(
        (qb, int(pk.row_tile[r]), f)
        for r, _s, qb, f in iter_real_steps(pk.q_blocks, pk.flags))
    return _diff_visits(forward_visits(plan), got, "adjoint", target,
                        "packed transposed walk")


# ---------------------------------------------------------------------- #
# 3. Shard-exchange soundness
# ---------------------------------------------------------------------- #
def verify_sharded(plan: ExecutionPlan, n_shards: int, sp=None,
                   target: str = "") -> List[Finding]:
    """Prove a ShardedPlan reconstructs the unsharded tile set exactly and
    that its ppermute/psum exchange schedule delivers every referenced
    halo/global view slot (see module doc)."""
    from repro.dist.sharded_plan import shard_plan
    sp = shard_plan(plan, n_shards) if sp is None else sp
    findings: List[Finding] = []
    nkb_l, nq_l = sp.nkb_l, sp.nq_l
    vm = np.asarray(sp.view_map)

    # Local view region must be the shard's own tiles, in order.
    for s in range(sp.n_shards):
        want = np.arange(s * nkb_l, (s + 1) * nkb_l)
        if not np.array_equal(vm[s, :nkb_l], want):
            t = int(np.nonzero(vm[s, :nkb_l] != want)[0][0])
            findings.append(Finding(
                "exchange", target,
                f"shard {s} local view slot {t} maps to tile "
                f"{int(vm[s, t])}, expected {int(want[t])}",
                kv_block=int(want[t])))

    # View positions must agree with the mapped tile's positions.
    pos_t = plan.positions_padded().reshape(plan.nkb, plan.block_k)
    for s in range(sp.n_shards):
        for vt in range(sp.view_tiles):
            gt = int(vm[s, vt])
            if gt >= 0:
                if not np.array_equal(sp.pos_k[s, vt], pos_t[gt]):
                    findings.append(Finding(
                        "exchange", target,
                        f"shard {s} view slot {vt} positions disagree with "
                        f"tile {gt}'s positions", kv_block=gt))
            elif not (sp.pos_k[s, vt] == PAD_SENTINEL).all():
                findings.append(Finding(
                    "exchange", target,
                    f"shard {s} padded view slot {vt} carries non-sentinel "
                    f"positions", kv_block=vt))

    # The per-shard tables, remapped to global tiles, must reconstruct the
    # unsharded visit multiset exactly.
    got: VisitCounter = Counter()
    for s in range(sp.n_shards):
        for i_l, _st, vt, f in iter_real_steps(sp.tables[s], sp.flags[s]):
            gt = int(vm[s, vt]) if 0 <= vt < sp.view_tiles else -1
            if gt < 0:
                findings.append(Finding(
                    "exchange", target,
                    f"shard {s} row {i_l} references view slot {vt}, which "
                    f"no exchange ever fills",
                    q_block=s * nq_l + i_l, kv_block=vt))
                continue
            got[(s * nq_l + i_l, gt, f)] += 1
    findings += _diff_visits(forward_visits(plan), got, "exchange", target,
                             f"{sp.n_shards}-shard reconstruction")

    # Every halo view slot's owner must sit at the group's distance and be
    # scheduled to send exactly that tile on that hop.
    off = nkb_l
    for d_i, (delta, T) in enumerate(zip(sp.halo_dists, sp.halo_counts)):
        send = np.asarray(sp.send_idx[d_i])
        for s in range(sp.n_shards):
            for slot in range(T):
                gt = int(vm[s, off + slot])
                if gt < 0:
                    continue
                owner = gt // nkb_l
                if owner != s + delta:
                    findings.append(Finding(
                        "exchange", target,
                        f"shard {s} halo slot {slot} (distance {delta}) "
                        f"holds tile {gt} owned by shard {owner} — owner "
                        f"distance {owner - s} has no hop in this group",
                        kv_block=gt))
                elif int(send[owner, slot]) != gt - owner * nkb_l:
                    findings.append(Finding(
                        "exchange", target,
                        f"no scheduled ppermute hop delivers tile {gt} to "
                        f"shard {s}: owner {owner} sends local tile "
                        f"{int(send[owner, slot])} on distance-{delta} "
                        f"slot {slot}, expected {gt - owner * nkb_l}",
                        kv_block=gt))
        off += T

    # Global slots: exactly one owner feeding the masked psum, the owner's
    # local index correct, and the slot mapped identically on every shard.
    g_base = sp.view_tiles - sp.n_gt
    for gi, t in enumerate(sp.gtiles):
        owners = np.nonzero(np.asarray(sp.g_owned)[:, gi])[0]
        if owners.size != 1:
            findings.append(Finding(
                "exchange", target,
                f"global tile {t} has {owners.size} psum owners "
                f"(exactly 1 required)", kv_block=int(t)))
            continue
        o = int(owners[0])
        if o != t // nkb_l or int(sp.g_owner_idx[o, gi]) != t - o * nkb_l:
            findings.append(Finding(
                "exchange", target,
                f"global tile {t} claimed by shard {o} local "
                f"{int(sp.g_owner_idx[o, gi])}, expected shard "
                f"{t // nkb_l} local {t % nkb_l}", kv_block=int(t)))
        for s in range(sp.n_shards):
            if int(vm[s, g_base + gi]) != t:
                findings.append(Finding(
                    "exchange", target,
                    f"shard {s} global slot {gi} maps to tile "
                    f"{int(vm[s, g_base + gi])}, expected {t}",
                    kv_block=int(t)))

    # Per-shard packed transposed tables: the dK/dV walk over the view must
    # also be the exact adjoint of the unsharded forward.
    tgot: VisitCounter = Counter()
    for s in range(sp.n_shards):
        for r, _st, qb, f in iter_real_steps(sp.t_q_blocks[s],
                                             sp.t_flags[s]):
            vt = int(sp.t_row_tile[s, r])
            gt = int(vm[s, vt]) if 0 <= vt < sp.view_tiles else -1
            if gt < 0:
                findings.append(Finding(
                    "exchange", target,
                    f"shard {s} packed dK/dV row {r} accumulates into "
                    f"unfilled view slot {vt}", kv_block=vt))
                continue
            tgot[(s * nq_l + qb, gt, f)] += 1
    findings += _diff_visits(forward_visits(plan), tgot, "exchange", target,
                             f"{sp.n_shards}-shard packed dK/dV walk")
    return findings


# ---------------------------------------------------------------------- #
# 4. Never-drop
# ---------------------------------------------------------------------- #
def verify_never_drop(plan: ExecutionPlan,
                      local_window: Optional[int] = None,
                      target: str = "", seeds: int = 3) -> List[Finding]:
    """Prove the dynamic never-drop invariant for this plan's candidate
    tables (see module doc)."""
    from repro.core.dynamic import check_keep, plan_always_keep
    findings: List[Finding] = []
    lw = int(local_window) if local_window is not None \
        else max(plan.block_q, plan.block_k)
    always = np.asarray(plan_always_keep(plan, lw))

    pos = plan.positions_padded().astype(np.int64)
    pos_q = pos.reshape(plan.nq, plan.block_q)
    pos_k = pos.reshape(plan.nkb, plan.block_k)
    vq, vk = pos_q < PAD_SENTINEL, pos_k < PAD_SENTINEL

    for i, s, t, f in iter_real_steps(plan.kv_blocks, plan.flags):
        if (f & STEP_GLOBAL) and not always[i, s]:
            findings.append(Finding(
                "never-drop", target,
                f"global/sink step (q_block {i}, kv_block {t}) is "
                f"droppable under a tight keep budget",
                q_block=i, kv_block=t))
            continue
        if not (vq[i].any() and vk[t].any()):
            continue
        qlo, qhi = int(pos_q[i][vq[i]].min()), int(pos_q[i][vq[i]].max())
        tlo, thi = int(pos_k[t][vk[t]].min()), int(pos_k[t][vk[t]].max())
        reach = qhi if plan.sched.causal else qhi + lw
        if thi >= qlo - lw and tlo <= reach and not always[i, s]:
            findings.append(Finding(
                "never-drop", target,
                f"causal-local tile (q_block {i}, kv_block {t}; positions "
                f"[{tlo}, {thi}] vs row [{qlo}, {qhi}]) is droppable",
                q_block=i, kv_block=t))
    if (always & (np.asarray(plan.flags) == 0)).any():
        i, s = (int(x) for x in
                np.argwhere(always & (np.asarray(plan.flags) == 0))[0])
        findings.append(Finding(
            "never-drop", target,
            f"padding step (row {i}, step {s}) marked always-keep",
            q_block=i))

    need = int(always.sum(axis=1).max()) if always.size else 0
    if need > plan.max_steps:
        findings.append(Finding(
            "never-drop", target,
            f"worst-case always-kept count {need} exceeds the table width "
            f"{plan.max_steps}: no feasible keep budget exists"))
        return findings
    try:
        check_keep(need, always)
    except ValueError:
        findings.append(Finding(
            "never-drop", target,
            f"check_keep rejects the provably sufficient budget {need}"))
    if need > 0:
        try:
            check_keep(need - 1, always)
            findings.append(Finding(
                "never-drop", target,
                f"check_keep accepts keep={need - 1}, one below the "
                f"worst-case always-kept count {need}"))
        except ValueError:
            pass

        # Adversarial selection: content scores maximally against the
        # protected set must still keep every protected step at keep=need.
        rng = np.random.default_rng(0)
        flags = np.asarray(plan.flags)
        for _ in range(seeds):
            score = rng.standard_normal(always.shape)
            score = np.where(always, np.inf, score)
            score = np.where(flags != 0, score, -np.inf)
            kept = np.zeros_like(always)
            top = np.argpartition(-score, need - 1, axis=1)[:, :need]
            np.put_along_axis(kept, top, True, axis=1)
            dropped = always & ~kept
            if dropped.any():
                i, s = (int(x) for x in np.argwhere(dropped)[0])
                findings.append(Finding(
                    "never-drop", target,
                    f"adversarial top-k at keep={need} drops protected "
                    f"step (q_block {i}, kv_block "
                    f"{int(plan.kv_blocks[i, s])})",
                    q_block=i, kv_block=int(plan.kv_blocks[i, s])))
                break
    return findings


# ---------------------------------------------------------------------- #
# 5. ChunkPlan prefill slices
# ---------------------------------------------------------------------- #
def verify_chunk(cp: ChunkPlan, target: str = "",
                 n_shards: Tuple[int, ...] = ()) -> List[Finding]:
    """Prove one prefill chunk slice covers its causal pair set exactly
    once over a view that actually holds every needed key (module doc)."""
    findings: List[Finding] = []
    pat = cp.pattern
    c0, c1 = cp.chunk_start, cp.chunk_start + cp.chunk_len
    vpos = cp.view_positions.astype(np.int64)
    block = cp.block

    live = vpos[vpos < PAD_SENTINEL]
    if np.unique(live).size != live.size:
        dup = int(live[np.argwhere(
            np.diff(np.sort(live)) == 0)[0][0] + 1])
        findings.append(Finding(
            "chunk", target,
            f"view holds position {dup} in more than one slot"))

    # Query positions per chunk row (PAD beyond the chunk length).
    qpos = np.full(cp.chunk_pad, PAD_SENTINEL, dtype=np.int64)
    qpos[: cp.chunk_len] = np.arange(c0, c1)

    count = np.zeros((cp.chunk_pad, cp.view_len), dtype=np.int32)
    for i, _s, t, f in iter_real_steps(cp.kv_blocks, cp.flags):
        qp = qpos[i * block:(i + 1) * block]
        kp = vpos[t * block:(t + 1) * block]
        sub = _np_causal_union(pat, qp[:, None], kp[None, :], f)
        count[i * block:(i + 1) * block,
              t * block:(t + 1) * block] += sub
    expected = _np_causal_union(pat, qpos[:, None], vpos[None, :],
                                VALID_FLAGS)
    dbl = count > 1
    if dbl.any():
        qi, vj = (int(x) for x in np.argwhere(dbl)[0])
        findings.append(Finding(
            "chunk", target,
            f"chunk [{c0},{c1}) double-counts pair (query {int(qpos[qi])}, "
            f"key {int(vpos[vj])})", q_block=qi // block,
            kv_block=vj // block))
    miss = expected & (count == 0)
    if miss.any():
        qi, vj = (int(x) for x in np.argwhere(miss)[0])
        findings.append(Finding(
            "chunk", target,
            f"chunk [{c0},{c1}) misses attended pair (query "
            f"{int(qpos[qi])}, key {int(vpos[vj])})",
            q_block=qi // block, kv_block=vj // block))

    # View completeness: every key position the pattern attends from any
    # chunk query must be resident in [sink | ring | chunk].
    present = set(int(p) for p in live)
    oracle = pat.mask(c1)
    for q in range(c0, c1):
        needed = np.nonzero(oracle[q, : q + 1])[0]
        for kpos in needed:
            if int(kpos) not in present:
                inv_row = (q - c0) // block
                findings.append(Finding(
                    "chunk", target,
                    f"view under-provisioned for chunk [{c0},{c1}): query "
                    f"{q} attends key {int(kpos)}, which no sink/ring/"
                    f"chunk slot holds", q_block=inv_row))
                break
        else:
            continue
        break

    # Sharded chunk tables: union must reconstruct the unsharded walk with
    # every (row, view tile) step on exactly one shard.
    ctx_tiles = (cp.n_sink + cp.ring_cap) // block
    base: VisitCounter = Counter(
        (i, t, f) for i, _s, t, f in iter_real_steps(cp.kv_blocks, cp.flags))
    for S in n_shards:
        if ctx_tiles % S:
            continue
        tps = ctx_tiles // S
        kv, fl = cp.sharded_tables(S, cp.nq, cp.max_steps + tps)
        got: VisitCounter = Counter()
        for s in range(S):
            for i, _st, lt, f in iter_real_steps(kv[s], fl[s]):
                gt = s * tps + lt if lt < tps else ctx_tiles + (lt - tps)
                got[(i, gt, f)] += 1
        findings += _diff_visits(base, got, "chunk", target,
                                 f"{S}-shard chunk [{c0},{c1}) tables")
    return findings


# ---------------------------------------------------------------------- #
# 6. Dynamic full-keep replay (runtime, tiny)
# ---------------------------------------------------------------------- #
def verify_dynamic_full_keep(plan: ExecutionPlan,
                             target: str = "") -> List[Finding]:
    """A full keep budget must reproduce the static walk step-for-step —
    the machinery-off invariant, replayed on random content."""
    from repro.core.dynamic import DynamicConfig, dynamic_tables
    rng = np.random.default_rng(7)
    n, d = plan.sched.n, 16
    q = rng.standard_normal((1, n, d)).astype(np.float32)
    k = rng.standard_normal((1, n, d)).astype(np.float32)
    _plan, kvt, flg, _always = dynamic_tables(
        q, k, plan.sched.pattern, DynamicConfig(keep=plan.max_steps),
        block_q=plan.block_q, block_k=plan.block_k)
    kvt, flg = np.asarray(kvt), np.asarray(flg)
    if not (np.array_equal(kvt, plan.kv_blocks)
            and np.array_equal(flg, plan.flags)):
        bad = np.argwhere((kvt != plan.kv_blocks) | (flg != plan.flags))
        i, s = (int(x) for x in bad[0])
        return [Finding(
            "dynamic-full-keep", target,
            f"full-keep selection diverges from the static walk at row {i} "
            f"step {s}: got (tile {int(kvt[i, s])}, flags {int(flg[i, s])})"
            f", static (tile {int(plan.kv_blocks[i, s])}, flags "
            f"{int(plan.flags[i, s])})",
            q_block=i, kv_block=int(plan.kv_blocks[i, s]))]
    return []


# ---------------------------------------------------------------------- #
# Composite driver (what the CLI gate and ExecutionPlan.verify run)
# ---------------------------------------------------------------------- #
def verify_plan(plan: ExecutionPlan, target: str = "",
                n_shards: Tuple[int, ...] = (),
                never_drop: bool = False,
                local_window: Optional[int] = None) -> List[Finding]:
    """All static proofs for one plan: coverage, adjoint (transposed and
    packed), per-shard exchange soundness, and optionally never-drop."""
    findings = verify_coverage(plan, target)
    findings += verify_transposed(plan, target=target)
    findings += verify_packed(plan, target=target)
    for S in n_shards:
        if plan.nq % S == 0 and plan.nkb % S == 0:
            findings += verify_sharded(plan, S, target=f"{target}@{S}shards")
        else:
            findings.append(Finding(
                "exchange", target,
                f"plan grid ({plan.nq}, {plan.nkb}) not divisible by "
                f"{S} shards — build with pad_multiple", severity="warn"))
    if never_drop:
        findings += verify_never_drop(plan, local_window, target=target)
    return findings


def verify_stats(findings: List[Finding]) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for f in findings:
        out[f.pass_name] = out.get(f.pass_name, 0) + 1
    return out
