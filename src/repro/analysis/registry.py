"""The registered verification targets: which patterns/plans the gate proves.

One declarative list, mirrored after the benchmark workloads but sized for
an exhaustive pairwise proof (the prover materializes a (n_pad, n_pad)
coverage count per plan). Every entry is verified for forward coverage,
adjoint (transposed + packed) soundness and — where ``n_shards`` is
non-empty — shard-exchange soundness; causal 1-D entries additionally get
the never-drop proof and the dynamic full-keep replay, and chunk targets
the ChunkPlan prefill-slice proofs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core import patterns as P
from repro.core.patterns import HybridSparsePattern


@dataclasses.dataclass(frozen=True)
class VerifyTarget:
    """One (pattern, geometry) pair the soundness gate must prove."""
    name: str
    pattern: HybridSparsePattern
    n: int
    block_q: int
    block_k: int
    n_shards: Tuple[int, ...] = ()      # shard counts to prove exchange for
    dynamic: bool = False               # never-drop + full-keep replay
    local_window: Optional[int] = None  # never-drop locality (None = auto)


@dataclasses.dataclass(frozen=True)
class ChunkTarget:
    """One serving prefill workload: every chunk slice of ``prompt`` gets
    the chunk coverage/view-completeness proof over the paged layout
    derived from ``pattern`` (page size ``page``, chunk length ``chunk``),
    plus the sharded-tables reconstruction for each entry of
    ``n_shards``."""
    name: str
    pattern: HybridSparsePattern
    prompt: int
    chunk: int
    page: int
    n_shards: Tuple[int, ...] = ()


def plan_targets() -> Tuple[VerifyTarget, ...]:
    return (
        VerifyTarget("longformer", P.longformer(64, n_global=8),
                     n=256, block_q=32, block_k=32, n_shards=(2, 4)),
        VerifyTarget("longformer-causal",
                     P.longformer(64, n_global=8, causal=True),
                     n=256, block_q=32, block_k=32, n_shards=(2,),
                     dynamic=True),
        VerifyTarget("vil-2d", P.vil((12, 12), (3, 3), n_global=1),
                     n=145, block_q=16, block_k=16, n_shards=(2,)),
        VerifyTarget("dilated", P.dilated_window(8, 2),
                     n=192, block_q=16, block_k=16, n_shards=(2,)),
        # dilation scatters the global tiles across residue groups after
        # data reordering — the exchange proof's hardest static case.
        VerifyTarget("reordered-global",
                     HybridSparsePattern(window=(-16, 16), dilation=2,
                                         n_global=6),
                     n=192, block_q=16, block_k=16, n_shards=(2,)),
        VerifyTarget("causal-sw-sinks", P.causal_sliding_window(32, n_sinks=8),
                     n=256, block_q=32, block_k=32, n_shards=(2, 4),
                     dynamic=True),
        VerifyTarget("causal-dilated",
                     P.causal_sliding_window(8, n_sinks=4, dilation=2),
                     n=128, block_q=16, block_k=16, dynamic=True),
    )


def chunk_targets() -> Tuple[ChunkTarget, ...]:
    return (
        ChunkTarget("chunk-sw-sinks", P.causal_sliding_window(16, n_sinks=2),
                    prompt=70, chunk=16, page=8, n_shards=(2,)),
        ChunkTarget("chunk-dilated",
                    P.causal_sliding_window(8, n_sinks=2, dilation=2),
                    prompt=52, chunk=12, page=8, n_shards=(2,)),
        ChunkTarget("chunk-short-prompt",
                    P.causal_sliding_window(16, n_sinks=2),
                    prompt=11, chunk=16, page=8),
    )
