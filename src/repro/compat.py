"""jax version compatibility shims.

The repo targets the jax API current at HEAD (``jax.make_mesh(...,
axis_types=...)``, ``jax.shard_map``, ``pltpu.CompilerParams``) but must run
on the pinned 0.4.x toolchain too. Every version-sensitive construct goes
through here so the rest of the codebase reads like modern jax.
"""
from __future__ import annotations

import jax

__all__ = ["make_mesh", "shard_map", "tpu_compiler_params"]


def make_mesh(axis_shapes, axis_names, **kwargs):
    """``jax.make_mesh`` accepting (and dropping, pre-AxisType) axis_types."""
    try:
        return jax.make_mesh(axis_shapes, axis_names, **kwargs)
    except TypeError:
        kwargs.pop("axis_types", None)
        return jax.make_mesh(axis_shapes, axis_names, **kwargs)


def _resolve_shard_map():
    if hasattr(jax, "shard_map"):
        return jax.shard_map, "check_vma"
    from jax.experimental.shard_map import shard_map as sm
    return sm, "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """jax.shard_map with the check_vma/check_rep rename bridged."""
    sm, kw = _resolve_shard_map()
    kwargs = {} if check_vma is None else {kw: check_vma}
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


def tpu_compiler_params(**kwargs):
    """pltpu.CompilerParams (renamed from TPUCompilerParams)."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)


def _axis_type_auto(n: int):
    """(AxisType.Auto,) * n where supported, else None (old make_mesh)."""
    at = getattr(jax.sharding, "AxisType", None)
    return (at.Auto,) * n if at is not None else None
