"""Mixture-of-Experts FFN with expert-parallel capacity dispatch.

Sort-based dispatch (Megablocks-style, dropless up to the capacity factor):
tokens are argsorted by assigned expert, placed into a ``(E, C, d)`` buffer
sharded over the ``model`` mesh axis (EP) with capacity sharded over DP axes
— pjit lowers the scatter/gather into the all-to-all-equivalent collectives a
real MoE pipeline performs.

Supports the two assigned MoE architectures:
  * arctic-480b: 128 experts top-2 **+ dense residual** (dense FFN in
    parallel with the MoE output),
  * kimi-k2:     384 experts top-8, shared expert, leading dense layer(s).

Aux losses: Switch load-balance + router z-loss.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import constrain
from repro.models.layers import dense_init, dt, mlp_init, mlp_apply


def moe_init(rng, cfg: ModelConfig):
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_ff_expert, m.n_experts
    ks = jax.random.split(rng, 5)

    def experts(key, din, dout):
        sub = jax.random.split(key, E)
        return jnp.stack([dense_init(k, din, dout, dt(cfg)) for k in sub])

    p = {"router": dense_init(ks[0], d, E, jnp.float32),
         "w_in": experts(ks[1], d, f),
         "w_out": experts(ks[2], f, d)}
    if cfg.act in ("swiglu", "geglu"):
        p["w_gate"] = experts(ks[3], d, f)
    if m.n_shared_experts:
        p["shared"] = mlp_init(ks[4], cfg, d_ff=f * m.n_shared_experts)
    return p


def _expert_ffn(p, buf, cfg: ModelConfig):
    """buf: (E, C, d) -> (E, C, d), per-expert SwiGLU/GeGLU/GELU."""
    w_in = p["w_in"].astype(buf.dtype)
    h = jnp.einsum("ecd,edf->ecf", buf, w_in)
    if cfg.act in ("swiglu", "geglu"):
        g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(buf.dtype))
        act = jax.nn.silu if cfg.act == "swiglu" else jax.nn.gelu
        h = act(g) * h
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, "experts", "expert_cap", None)
    return jnp.einsum("ecf,efd->ecd", h, p["w_out"].astype(buf.dtype))


def _dispatch_group(xt, probs, E, k, C, dtype):
    """Sort-dispatch ONE token group (vmapped over groups; everything here
    is group-local, so under a G->data sharding no collective is needed for
    the sort/scatter). xt: (Tg, d); probs: (Tg, E).
    Returns (buf (E,C,d), combine metadata)."""
    Tg = xt.shape[0]
    gates, expert_idx = jax.lax.top_k(probs, k)            # (Tg, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    flat_e = expert_idx.reshape(-1)                        # (Tg*k,)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E))
    pos = jnp.arange(Tg * k) - starts[sorted_e]
    keep = pos < C
    pos_c = jnp.clip(pos, 0, C - 1)
    tok = order // k
    vals = xt[tok] * keep[:, None].astype(dtype)
    buf = jnp.zeros((E, C, xt.shape[1]), dtype)
    buf = buf.at[sorted_e, pos_c].add(vals)
    return buf, (gates, sorted_e, pos_c, keep, order)


def _combine_group(out_buf, meta, T_g, k, dtype):
    gates, sorted_e, pos_c, keep, order = meta
    gathered = out_buf[sorted_e, pos_c] * keep[:, None].astype(dtype)
    contrib = jnp.zeros((T_g * k, out_buf.shape[-1]), dtype)
    contrib = contrib.at[order].set(gathered).reshape(T_g, k, -1)
    return jnp.einsum("tkd,tk->td", contrib, gates.astype(dtype))


def moe_apply(p, x, cfg: ModelConfig):
    """x: (B, S, d) -> (y, aux_losses dict).

    Group-local dispatch: tokens are split
    into ``dispatch_groups`` groups aligned with the DP sharding; routing,
    sort and capacity are PER GROUP (vmapped — no global argsort, no
    cross-shard scatter). The only cross-device movement left is the
    (G, E, C, d) -> expert-sharded transpose, the real MoE all-to-all.
    """
    m = cfg.moe
    B, S, d = x.shape
    E, k = m.n_experts, m.top_k
    T = B * S
    xt = constrain(x.reshape(T, d), "batch", None)

    # --- routing (f32) -------------------------------------------------- #
    logits = xt.astype(jnp.float32) @ p["router"]          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)

    # --- group-local dispatch ------------------------------------------- #
    # REPRO_MOE_GROUPS=1 reproduces the global-sort baseline (A/B runs).
    G = int(os.environ.get("REPRO_MOE_GROUPS", m.dispatch_groups))
    while G > 1 and T % G:
        G //= 2
    Tg = T // G
    C = int(Tg * k / E * m.capacity_factor)
    C = max(8, -(-C // 8) * 8)

    xg = constrain(xt.reshape(G, Tg, d), "batch", None, None)
    pg = constrain(probs.reshape(G, Tg, E), "batch", None, None)
    buf, meta = jax.vmap(
        lambda a, b: _dispatch_group(a, b, E, k, C, xt.dtype))(xg, pg)
    # Two-hop reshard to the expert-sharded layout. Hop 1 is collective-free
    # (G->data and E->model live on DIFFERENT mesh axes); hop 2 moves ONLY
    # the `data` axis from the G dim to the E dim — a single-axis dim-to-dim
    # move that GSPMD lowers as a true all-to-all instead of the
    # all-gather+slice it emits for the one-shot reshard (§Perf kimi-k2).
    buf = constrain(buf, "batch", "experts_tp", None, None)   # hop 1: free
    buf = constrain(buf, None, "experts", None, None)         # hop 2: A2A

    out_buf = jax.vmap(lambda b_: _expert_ffn(p, b_, cfg))(buf)
    out_buf = constrain(out_buf, None, "experts", None, None)
    out_buf = constrain(out_buf, "batch", "experts_tp", None, None)  # A2A

    # --- combine (group-local gather again) ------------------------------ #
    y = jax.vmap(lambda ob, me: _combine_group(ob, me, Tg, k, xt.dtype))(
        constrain(out_buf, "batch", None, None, None), meta)
    y = constrain(y.reshape(T, d), "batch", None)

    # --- aux losses ------------------------------------------------------ #
    # Switch load balance: E * sum_e (fraction routed to e) * (mean prob e).
    top1 = jnp.argmax(probs, axis=-1)
    frac = jnp.zeros(E, jnp.float32).at[top1].add(1.0) / T
    mean_p = probs.mean(0)
    lb = E * jnp.sum(frac * mean_p)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    keep_frac = meta[3].astype(jnp.float32).mean()
    aux = {"load_balance": m.load_balance_coef * lb,
           "router_z": m.router_z_coef * z,
           "dropped_frac": 1.0 - keep_frac}

    y = y.reshape(B, S, d)
    if m.n_shared_experts:
        y = y + mlp_apply(p["shared"], x, cfg)
    return y, aux
