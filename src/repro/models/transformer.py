"""Block assembly and the unified causal LM.

An architecture is a *program*: a list of (block_kind, count) segments. Each
segment's layer params are stacked on a leading layer axis and executed with
``jax.lax.scan`` (+ configurable remat) — HLO stays O(1) in depth, which is
what keeps 61-layer/1T-param dry-runs compilable.

Block kinds:
  attn_mlp        pre-norm attention + MLP           (dense archs, whisper enc)
  attn_moe        attention + MoE FFN                (kimi)
  attn_moe_dense  attention + dense MLP + MoE in parallel (arctic)
  ssm             Mamba2 SSD block                   (mamba2)
  rec_mlp         RG-LRU recurrent block + MLP       (recurrentgemma)
  griffin         (rec_mlp, rec_mlp, attn_mlp) supergroup, scanned as one

Decode caches are pytrees stacked the same way, scanned alongside params.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import constrain
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import rglru as RG
from repro.models import ssm as SSM


# ===================== per-kind init / apply / decode ==================== #
def block_init(rng, cfg: ModelConfig, kind: str):
    ks = jax.random.split(rng, 6)
    if kind in ("attn_mlp", "attn_mlp_local"):
        return {"ln1": L.rmsnorm_init(cfg.d_model),
                "attn": L.attn_init(ks[0], cfg),
                "ln2": L.rmsnorm_init(cfg.d_model),
                "mlp": L.mlp_init(ks[1], cfg)}
    if kind == "attn_moe":
        return {"ln1": L.rmsnorm_init(cfg.d_model),
                "attn": L.attn_init(ks[0], cfg),
                "ln2": L.rmsnorm_init(cfg.d_model),
                "moe": MOE.moe_init(ks[1], cfg)}
    if kind == "attn_moe_dense":
        return {"ln1": L.rmsnorm_init(cfg.d_model),
                "attn": L.attn_init(ks[0], cfg),
                "ln2": L.rmsnorm_init(cfg.d_model),
                "mlp": L.mlp_init(ks[1], cfg),
                "moe": MOE.moe_init(ks[2], cfg)}
    if kind == "ssm":
        return {"ln1": L.rmsnorm_init(cfg.d_model),
                "ssm": SSM.ssm_init(ks[0], cfg)}
    if kind == "rec_mlp":
        return {"ln1": L.rmsnorm_init(cfg.d_model),
                "rec": RG.rglru_init(ks[0], cfg),
                "ln2": L.rmsnorm_init(cfg.d_model),
                "mlp": L.mlp_init(ks[1], cfg)}
    if kind == "griffin":
        return {"r1": block_init(ks[0], cfg, "rec_mlp"),
                "r2": block_init(ks[1], cfg, "rec_mlp"),
                "a": block_init(ks[2], cfg, "attn_mlp_local")}
    if kind == "xattn":  # whisper decoder block: self + cross + mlp
        return {"ln1": L.rmsnorm_init(cfg.d_model),
                "attn": L.attn_init(ks[0], cfg),
                "ln_x": L.rmsnorm_init(cfg.d_model),
                "xattn": L.attn_init(ks[1], cfg),
                "ln2": L.rmsnorm_init(cfg.d_model),
                "mlp": L.mlp_init(ks[2], cfg)}
    raise ValueError(kind)


def _patterns(cfg: ModelConfig, causal: bool = True):
    import dataclasses

    main = L.salo_pattern(cfg, causal=causal)
    if cfg.recurrent is not None:  # recurrentgemma local-attention third
        local = dataclasses.replace(cfg.salo,
                                    window=cfg.recurrent.local_window)
        localp = L.salo_pattern(cfg, causal=causal, salo=local)
        return {"attn_mlp": main, "attn_mlp_local": localp}
    return {"attn_mlp": main, "attn_mlp_local": main}


def block_apply(p, x, cfg: ModelConfig, kind: str, pattern, positions=None,
                mrope=None, enc_out=None):
    """Full-sequence block. Returns (x, aux) where aux holds MoE losses."""
    aux = {}
    x = constrain(x, "batch", "seq", "embed")
    if kind == "xattn":
        h, _ = L.attn_apply(p["attn"], L.rmsnorm(p["ln1"], x, cfg.norm_eps),
                            cfg, pattern, positions=positions)
        x = x + h
        hx, _ = L.cross_attn_apply(
            p["xattn"], L.rmsnorm(p["ln_x"], x, cfg.norm_eps), enc_out, cfg)
        x = x + hx
        x = x + L.mlp_apply(p["mlp"],
                            L.rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
        return x, aux
    if kind == "griffin":
        pats = _patterns(cfg)
        x, a1 = block_apply(p["r1"], x, cfg, "rec_mlp", pattern, positions)
        x, a2 = block_apply(p["r2"], x, cfg, "rec_mlp", pattern, positions)
        x, a3 = block_apply(p["a"], x, cfg, "attn_mlp_local",
                            pats["attn_mlp_local"], positions)
        return x, aux
    if kind in ("attn_mlp", "attn_mlp_local", "attn_moe", "attn_moe_dense"):
        h, _ = L.attn_apply(p["attn"], L.rmsnorm(p["ln1"], x, cfg.norm_eps),
                            cfg, pattern, positions=positions, mrope=mrope)
        x = x + h
        h2 = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        if kind == "attn_mlp" or kind == "attn_mlp_local":
            x = x + L.mlp_apply(p["mlp"], h2, cfg)
        elif kind == "attn_moe":
            y, aux = MOE.moe_apply(p["moe"], h2, cfg)
            x = x + y
        else:  # arctic: dense residual MLP in parallel with MoE
            y, aux = MOE.moe_apply(p["moe"], h2, cfg)
            x = x + y + L.mlp_apply(p["mlp"], h2, cfg)
        return x, aux
    if kind == "ssm":
        x = x + SSM.ssm_apply(p["ssm"], L.rmsnorm(p["ln1"], x, cfg.norm_eps),
                              cfg)
        return x, aux
    if kind == "rec_mlp":
        x = x + RG.rglru_apply(p["rec"],
                               L.rmsnorm(p["ln1"], x, cfg.norm_eps), cfg)
        x = x + L.mlp_apply(p["mlp"],
                            L.rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
        return x, aux
    raise ValueError(kind)


# --------------------------- decode caches ------------------------------ #
def block_cache_init(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     dtype):
    Hkv, hd = cfg.n_kv_heads, cfg.hd
    if cfg.salo.ring_cache:  # SALO ring cache: O(window) slots
        max_len = min(max_len, cfg.salo.window + cfg.salo.n_global)
    if kind == "griffin":
        return {"r1": block_cache_init(cfg, "rec_mlp", batch, max_len, dtype),
                "r2": block_cache_init(cfg, "rec_mlp", batch, max_len, dtype),
                "a": block_cache_init(cfg, "attn_mlp_local", batch,
                                      max_len, dtype)}
    if kind == "xattn":
        return {"k": jnp.zeros((batch, max_len, Hkv, hd), dtype),
                "v": jnp.zeros((batch, max_len, Hkv, hd), dtype),
                # cross K/V filled at prefill from the encoder output
                "xk": jnp.zeros((batch, cfg.n_audio_frames, Hkv, hd), dtype),
                "xv": jnp.zeros((batch, cfg.n_audio_frames, Hkv, hd), dtype)}
    if kind.startswith("attn"):
        return {"k": jnp.zeros((batch, max_len, Hkv, hd), dtype),
                "v": jnp.zeros((batch, max_len, Hkv, hd), dtype)}
    if kind == "ssm":
        d_inner, H, N, P = SSM._dims(cfg)
        W = cfg.ssm.conv_width
        return {"conv": jnp.zeros((batch, W - 1, d_inner + 2 * N), dtype),
                "state": jnp.zeros((batch, H, N, P), jnp.float32)}
    if kind == "rec_mlp":
        dr = RG._d_rnn(cfg)
        W = cfg.recurrent.conv_width
        return {"conv": jnp.zeros((batch, W - 1, dr), dtype),
                "state": jnp.zeros((batch, dr), jnp.float32)}
    raise ValueError(kind)


def block_decode(p, cache, x_t, t, cfg: ModelConfig, kind: str, pattern,
                 positions=None, mrope=None):
    """One-token decode. Returns (x_t, cache)."""
    if kind == "xattn":
        h, ck, cv = L.attn_decode(p["attn"],
                                  L.rmsnorm(p["ln1"], x_t, cfg.norm_eps),
                                  cache["k"], cache["v"], t, cfg, pattern,
                                  positions=positions)
        x_t = x_t + h
        x_t = x_t + L.cross_attn_decode(
            p["xattn"], L.rmsnorm(p["ln_x"], x_t, cfg.norm_eps),
            cache["xk"], cache["xv"], cfg)
        x_t = x_t + L.mlp_apply(p["mlp"],
                                L.rmsnorm(p["ln2"], x_t, cfg.norm_eps), cfg)
        return x_t, {"k": ck, "v": cv, "xk": cache["xk"], "xv": cache["xv"]}
    if kind == "griffin":
        pats = _patterns(cfg)
        x_t, c1 = block_decode(p["r1"], cache["r1"], x_t, t, cfg, "rec_mlp",
                               pattern)
        x_t, c2 = block_decode(p["r2"], cache["r2"], x_t, t, cfg, "rec_mlp",
                               pattern)
        x_t, c3 = block_decode(p["a"], cache["a"], x_t, t, cfg,
                               "attn_mlp_local", pats["attn_mlp_local"])
        return x_t, {"r1": c1, "r2": c2, "a": c3}
    if kind.startswith("attn"):
        h, ck, cv = L.attn_decode(p["attn"],
                                  L.rmsnorm(p["ln1"], x_t, cfg.norm_eps),
                                  cache["k"], cache["v"], t, cfg, pattern,
                                  positions=positions, mrope=mrope)
        return _ffn_residual(p, x_t + h, cfg, kind), {"k": ck, "v": cv}
    if kind == "ssm":
        y, conv, st = SSM.ssm_decode(p["ssm"],
                                     L.rmsnorm(p["ln1"], x_t, cfg.norm_eps),
                                     cache["conv"], cache["state"], cfg)
        return x_t + y, {"conv": conv, "state": st}
    if kind == "rec_mlp":
        y, conv, st = RG.rglru_decode(p["rec"],
                                      L.rmsnorm(p["ln1"], x_t, cfg.norm_eps),
                                      cache["conv"], cache["state"], cfg)
        x_t = x_t + y
        x_t = x_t + L.mlp_apply(p["mlp"],
                                L.rmsnorm(p["ln2"], x_t, cfg.norm_eps), cfg)
        return x_t, {"conv": conv, "state": st}
    raise ValueError(kind)


# ----------------- continuous-batching serve block paths ---------------- #
ATTN_KINDS = ("attn_mlp", "attn_mlp_local", "attn_moe", "attn_moe_dense")


def _ffn_residual(p, x, cfg: ModelConfig, kind: str):
    """The post-attention FFN residual shared by every attn block kind
    (MoE aux losses are dropped — serving never backprops)."""
    h2 = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if kind in ("attn_mlp", "attn_mlp_local"):
        return x + L.mlp_apply(p["mlp"], h2, cfg)
    if kind == "attn_moe":
        y, _ = MOE.moe_apply(p["moe"], h2, cfg)
        return x + y
    if kind == "attn_moe_dense":
        y, _ = MOE.moe_apply(p["moe"], h2, cfg)
        return x + y + L.mlp_apply(p["mlp"], h2, cfg)
    raise ValueError(f"continuous serving supports attention block kinds "
                     f"{ATTN_KINDS}, got {kind!r}")


def block_chunk_prefill(p, x, ctx_k, ctx_v, ctx_pos, pos_q, kv_blocks,
                        flags, cfg: ModelConfig, kind: str, pattern,
                        axis=None):
    """One prompt chunk through one block. Returns (x, k_chunk, v_chunk)."""
    h, k_c, v_c = L.attn_chunk_prefill(
        p["attn"], L.rmsnorm(p["ln1"], x, cfg.norm_eps), ctx_k, ctx_v,
        ctx_pos, pos_q, kv_blocks, flags, cfg, pattern, axis=axis)
    return _ffn_residual(p, x + h, cfg, kind), k_c, v_c


def block_decode_paged(p, x_t, k_slab, v_slab, page_tables, slot_pos, t_vec,
                       phys_w, off_w, cfg: ModelConfig, kind: str, pattern,
                       impl: str, axis=None, k_scale=None, v_scale=None,
                       want_page_stats: bool = False):
    """Ragged one-token decode through one block against the paged slab.
    Returns (x, k_slab, v_slab, k_scale, v_scale, page_m) — scales/stats
    ``None`` unless the slab is int8 / stats were requested."""
    h, k_slab, v_slab, k_scale, v_scale, page_m = L.attn_decode_paged(
        p["attn"], L.rmsnorm(p["ln1"], x_t, cfg.norm_eps), k_slab, v_slab,
        page_tables, slot_pos, t_vec, phys_w, off_w, cfg, pattern, impl,
        axis=axis, k_scale=k_scale, v_scale=v_scale,
        want_page_stats=want_page_stats)
    return (_ffn_residual(p, x_t + h, cfg, kind), k_slab, v_slab,
            k_scale, v_scale, page_m)


def segment_chunk_prefill(params, slab, x, page_table, ctx_pos, pos_q,
                          kv_blocks, flags, phys_w, off_w, cfg: ModelConfig,
                          kind: str, pattern, axis=None):
    """Scan one stacked segment over a prompt chunk, writing the slab.

    ``slab``: :class:`repro.serve.paged_cache.PagedSlab` with leading layer
    axis; ``page_table``: (npp,) the request's pages; ``phys_w``/``off_w``:
    (Cp,) precomputed slab write targets for the chunk positions (ring-
    overwritten and padded positions already routed to the null page).
    Returns (x, new slab).

    ``axis``: sequence-parallel serving — the slab / page table / ctx
    positions / step tables / write targets are this shard's slice, the
    chunk activations and fresh chunk KV are replicated, and each layer's
    attention merges its partial across the mesh axis (one cross-shard
    combine per layer inside the scan).

    int8 slabs (``slab.quantized``) thread each layer's per-page scales
    through the scan: the ctx view is dequantized at the gather and the
    fresh chunk KV is quantized at the write-back (monotone per-page
    scale growth).
    """
    from repro.serve.paged_cache import (PagedSlab, gather_view,
                                         quant_slab_write)

    npp = page_table.shape[0]
    page = slab.k.shape[2]
    quant = slab.quantized

    def body(carry, inp):
        x = carry
        if quant:
            layer_params, (k_l, v_l, ks_l, vs_l) = inp
            ctx_k, ctx_v = gather_view(k_l, v_l, page_table[None],
                                       ks_l, vs_l, x.dtype)
        else:
            layer_params, (k_l, v_l) = inp
            Hkv, hd = k_l.shape[-2], k_l.shape[-1]
            ctx_k = k_l[page_table].reshape(1, npp * page, Hkv, hd)
            ctx_v = v_l[page_table].reshape(1, npp * page, Hkv, hd)
        x, k_c, v_c = block_chunk_prefill(
            layer_params, x, ctx_k, ctx_v, ctx_pos, pos_q, kv_blocks,
            flags, cfg, kind, pattern, axis=axis)
        if quant:
            k_l, v_l, ks_l, vs_l = quant_slab_write(
                k_l, v_l, ks_l, vs_l, phys_w, off_w, k_c[0], v_c[0])
            return x, (k_l, v_l, ks_l, vs_l)
        k_l = k_l.at[phys_w, off_w].set(k_c[0].astype(k_l.dtype))
        v_l = v_l.at[phys_w, off_w].set(v_c[0].astype(v_l.dtype))
        return x, (k_l, v_l)

    xs = ((params, (slab.k, slab.v, slab.k_scale, slab.v_scale)) if quant
          else (params, (slab.k, slab.v)))
    x, new = jax.lax.scan(body, x, xs)
    return x, PagedSlab(*new)


def segment_decode_paged(params, slab, x_t, page_tables, slot_pos, t_vec,
                         phys_w, off_w, cfg: ModelConfig, kind: str,
                         pattern, impl: str, axis=None,
                         want_page_stats: bool = False):
    """Scan one stacked segment for one ragged decode step. Returns
    (x_t, new slab) — plus ``page_m`` (R, npp), the max masked score over
    the segment's layers per (request, logical page), when
    ``want_page_stats`` (the engine's page-sparsity statistic). int8
    slabs thread per-layer scales through the scan exactly like
    :func:`segment_chunk_prefill`. ``axis``: sequence-parallel serving
    (per-shard slab slice + cross-shard partial merge per layer, see
    :func:`repro.models.layers.attn_decode_paged`)."""
    from repro.core.renorm import NEG_INF
    from repro.serve.paged_cache import PagedSlab

    quant = slab.quantized

    def body(carry, inp):
        x_t, pm_acc = carry
        if quant:
            layer_params, (k_l, v_l, ks_l, vs_l) = inp
        else:
            layer_params, (k_l, v_l) = inp
            ks_l = vs_l = None
        x_t, k_l, v_l, ks_l, vs_l, pm = block_decode_paged(
            layer_params, x_t, k_l, v_l, page_tables, slot_pos, t_vec,
            phys_w, off_w, cfg, kind, pattern, impl, axis=axis,
            k_scale=ks_l, v_scale=vs_l, want_page_stats=want_page_stats)
        if want_page_stats:
            pm_acc = jnp.maximum(pm_acc, pm)
        return ((x_t, pm_acc),
                (k_l, v_l, ks_l, vs_l) if quant else (k_l, v_l))

    R, npp = page_tables.shape
    pm0 = jnp.full((R, npp), NEG_INF, jnp.float32)
    xs = ((params, (slab.k, slab.v, slab.k_scale, slab.v_scale)) if quant
          else (params, (slab.k, slab.v)))
    (x_t, pm), new = jax.lax.scan(body, (x_t, pm0), xs)
    slab = PagedSlab(*new)
    return (x_t, slab, pm) if want_page_stats else (x_t, slab)


# ========================= programs & segments ========================== #
def make_program(cfg: ModelConfig) -> List[Tuple[str, int]]:
    """(block_kind, count) segments; each segment is one lax.scan."""
    if cfg.family == "ssm":
        return [("ssm", cfg.n_layers)]
    if cfg.family == "hybrid":
        n_groups, rem = divmod(cfg.n_layers, 3)
        prog = [("griffin", n_groups)]
        if rem:
            prog.append(("rec_mlp", rem))
        return prog
    if cfg.encoder_decoder:
        return [("xattn", cfg.n_layers)]   # decoder stack; encoder separate
    if cfg.family == "moe":
        m = cfg.moe
        prog = []
        if m.first_k_dense:
            prog.append(("attn_mlp", m.first_k_dense))
        kind = "attn_moe_dense" if m.dense_residual else "attn_moe"
        prog.append((kind, cfg.n_layers - m.first_k_dense))
        return prog
    return [("attn_mlp", cfg.n_layers)]  # dense / vlm / audio backbones


def segment_init(rng, cfg: ModelConfig, kind: str, n: int):
    rngs = jax.random.split(rng, n)
    return jax.vmap(lambda r: block_init(r, cfg, kind))(rngs)


def _remat(f, cfg: ModelConfig):
    if cfg.remat == "none":
        return f
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(f, policy=policy)
    return jax.checkpoint(f)


def segment_apply(params, x, cfg: ModelConfig, kind: str, pattern,
                  positions=None, mrope=None, enc_out=None):
    """Scan a stacked segment. Returns (x, summed aux).

    Activations stay constrained to ("batch", "seq", "embed") through every
    block, so under a long-context cell's rules (``"seq"`` mapped to a mesh
    axis) the whole stack runs sequence-parallel: norms/MLPs shard
    elementwise and attention takes the ShardedPlan halo-exchange path
    inside :func:`repro.models.layers.attn_apply`.
    """
    def body(carry, layer_params):
        y, aux = block_apply(layer_params, carry, cfg, kind, pattern,
                             positions=positions, mrope=mrope,
                             enc_out=enc_out)
        return y, aux

    body = _remat(body, cfg)
    x, auxs = jax.lax.scan(body, x, params)
    aux = jax.tree.map(lambda a: jnp.sum(a), auxs) if auxs else {}
    return x, aux


def segment_decode(params, caches, x_t, t, cfg: ModelConfig, kind: str,
                   pattern, positions=None, mrope=None):
    def body(carry, inp):
        layer_params, layer_cache = inp
        y, new_cache = block_decode(layer_params, layer_cache, carry, t, cfg,
                                    kind, pattern, positions=positions,
                                    mrope=mrope)
        return y, new_cache

    x_t, new_caches = jax.lax.scan(body, x_t, (params, caches))
    return x_t, new_caches
