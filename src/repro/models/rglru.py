"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Gated linear recurrence:  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
with a_t = exp(-c * softplus(Lambda) * r_t),  r/i = sigmoid(linear(x)).

Training uses ``jax.lax.associative_scan`` (log-depth parallel scan — the
TPU-native replacement for the paper-of-record's fused GPU kernel); decode is
a single multiply-add. The block wraps the recurrence Griffin-style: two
input branches (conv+RG-LRU, GeLU) merged multiplicatively.

RecurrentGemma alternates (rec, rec, attn) — the attention third uses *local
sliding-window* attention, which we implement with the SALO core: this arch
is the closest published match to the paper's workload (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import constrain
from repro.models.layers import dense_init, dt

C_FACTOR = 8.0


def _d_rnn(cfg: ModelConfig) -> int:
    r = cfg.recurrent
    return r.d_rnn if r.d_rnn is not None else cfg.d_model


def rglru_init(rng, cfg: ModelConfig):
    d = cfg.d_model
    dr = _d_rnn(cfg)
    W = cfg.recurrent.conv_width
    ks = jax.random.split(rng, 6)
    return {
        "w_in": dense_init(ks[0], d, dr, dt(cfg)),        # recurrent branch
        "w_gate_branch": dense_init(ks[1], d, dr, dt(cfg)),  # gelu branch
        "w_out": dense_init(ks[2], dr, d, dt(cfg)),
        "conv_w": (jax.random.normal(ks[3], (W, dr)) * 0.1).astype(dt(cfg)),
        "w_a": dense_init(ks[4], dr, dr, dt(cfg)),        # recurrence gate
        "w_i": dense_init(ks[5], dr, dr, dt(cfg)),        # input gate
        # Lambda init so a^c in [0.9, 0.999] (paper §2.4).
        "lam": jnp.log(jnp.expm1(                         # inv-softplus
            -jnp.log(jnp.linspace(0.9, 0.999, dr)) / C_FACTOR)
        ).astype(jnp.float32),
    }


def _rglru_core(p, xr, h0=None):
    """xr: (B, T, dr) post-conv. Returns (y, h_last). Linear recurrence via
    associative scan: pair (a, b) composes as (a2*a1, a2*b1 + b2)."""
    r = jax.nn.sigmoid(xr.astype(jnp.float32) @ p["w_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(xr.astype(jnp.float32) @ p["w_i"].astype(jnp.float32))
    log_a = -C_FACTOR * jax.nn.softplus(p["lam"]) * r      # (B,T,dr) <= 0
    a = jnp.exp(log_a)
    gated = i * xr.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated

    if h0 is not None:  # fold initial state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1]


def rglru_apply(p, x, cfg: ModelConfig):
    """Griffin recurrent block, full sequence. x: (B,T,d) -> (B,T,d)."""
    from repro.models.ssm import _causal_conv

    xr = x @ p["w_in"].astype(x.dtype)
    xr, _ = _causal_conv(xr, p["conv_w"].astype(x.dtype), act=None)
    h, _ = _rglru_core(p, xr)
    gate = jax.nn.gelu(x @ p["w_gate_branch"].astype(x.dtype))
    y = h.astype(x.dtype) * gate
    y = constrain(y, "batch", "seq", "ffn")
    return y @ p["w_out"].astype(x.dtype)


def rglru_decode(p, x_t, conv_state, h_state, cfg: ModelConfig):
    """One-token step. x_t: (B,1,d); conv_state: (B,W-1,dr); h_state: (B,dr).
    Returns (y, conv_state, h_state)."""
    from repro.models.ssm import _causal_conv

    xr = x_t @ p["w_in"].astype(x_t.dtype)
    xr, conv_state = _causal_conv(xr, p["conv_w"].astype(x_t.dtype),
                                  state=conv_state, act=None)
    xr1 = xr[:, 0].astype(jnp.float32)
    r = jax.nn.sigmoid(xr1 @ p["w_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(xr1 @ p["w_i"].astype(jnp.float32))
    a = jnp.exp(-C_FACTOR * jax.nn.softplus(p["lam"]) * r)
    h_state = a * h_state + jnp.sqrt(jnp.maximum(1 - a * a, 1e-12)) * (i * xr1)
    gate = jax.nn.gelu(x_t @ p["w_gate_branch"].astype(x_t.dtype))
    y = h_state[:, None, :].astype(x_t.dtype) * gate
    return y @ p["w_out"].astype(x_t.dtype), conv_state, h_state
