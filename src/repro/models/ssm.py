"""Mamba2 SSD (state-space duality) block — chunked, MXU-friendly.

Implements the SSD chunked algorithm (arXiv:2405.21060 §6): intra-chunk
quadratic term (batched matmuls — maps to the MXU) + inter-chunk linear
recurrence over per-chunk states (lax.scan). Attention-free: the paper's
sparse-attention technique is inapplicable here (DESIGN.md §5); this arch
exists to prove the framework hosts non-attention families.

Decode carries (conv_state, ssd_state) and costs O(1) per token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import constrain
from repro.models.layers import dense_init, dt


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    return d_inner, H, s.d_state, s.head_dim


def ssm_init(rng, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    d_inner, H, N, P = _dims(cfg)
    conv_ch = d_inner + 2 * N  # x, B, C share the causal conv (G=1 group)
    ks = jax.random.split(rng, 4)
    return {
        "w_in": dense_init(ks[0], d, 2 * d_inner + 2 * N + H, dt(cfg)),
        "w_out": dense_init(ks[1], d_inner, d, dt(cfg)),
        "conv_w": (jax.random.normal(ks[2], (s.conv_width, conv_ch))
                   * 0.1).astype(dt(cfg)),
        "A_log": jnp.zeros((H,), jnp.float32),       # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_scale": jnp.zeros((d_inner,), jnp.float32),
    }


def _split(cfg, h):
    d_inner, H, N, P = _dims(cfg)
    z, xbc, dt_raw = jnp.split(h, [d_inner, 2 * d_inner + 2 * N], axis=-1)
    return z, xbc, dt_raw


def _causal_conv(xbc, w, state=None, act=jax.nn.silu):
    """Depthwise causal conv. xbc: (B, T, C); w: (W, C).

    state: (B, W-1, C) trailing context for decode; returns (y, new_state).
    """
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], W - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, xbc], axis=1)
    y = sum(xp[:, i : i + xbc.shape[1]] * w[i] for i in range(W))
    if act is not None:
        y = act(y)
    return y, xp[:, -(W - 1) :]


def _gated_norm(p, y, z, eps):
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * (1 + p["norm_scale"])).astype(y.dtype)


def ssd_chunked(x, B_mat, C_mat, a, chunk: int):
    """SSD scan. x: (B,T,H,P); B_mat/C_mat: (B,T,N); a: (B,T,H) log-decay<=0.
    Returns y (B,T,H,P). Single B/C group broadcast over heads (G=1)."""
    Bsz, T, H, P = x.shape
    N = B_mat.shape[-1]
    Q = chunk
    assert T % Q == 0, (T, Q)
    nc = T // Q

    xc = x.reshape(Bsz, nc, Q, H, P)
    Bc = B_mat.reshape(Bsz, nc, Q, N)
    Cc = C_mat.reshape(Bsz, nc, Q, N)
    ac = a.reshape(Bsz, nc, Q, H).astype(jnp.float32)
    Acum = jnp.cumsum(ac, axis=2)                      # (B,nc,Q,H)

    # Intra-chunk (quadratic within chunk — the MXU part).
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc.astype(jnp.float32),
                        Bc.astype(jnp.float32))        # (B,nc,Q,Q)
    L = Acum[:, :, :, None, :] - Acum[:, :, None, :, :]  # (B,nc,Q,Q,H)
    iq = jnp.arange(Q)
    causal = (iq[:, None] >= iq[None, :])[None, None, :, :, None]
    L = jnp.where(causal, jnp.exp(L), 0.0)
    M = scores[..., None] * L                          # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", M, xc.astype(jnp.float32))

    # Per-chunk output states.
    decay_out = jnp.exp(Acum[:, :, -1:, :] - Acum)     # (B,nc,Q,H)
    state_c = jnp.einsum("bcqn,bcqh,bcqhp->bchnp",
                         Bc.astype(jnp.float32), decay_out,
                         xc.astype(jnp.float32))       # (B,nc,H,N,P)

    # Inter-chunk recurrence (linear scan over nc).
    chunk_decay = jnp.exp(Acum[:, :, -1, :])           # (B,nc,H)

    def step(s, inp):
        dec, st = inp                                  # (B,H), (B,H,N,P)
        s_new = s * dec[:, :, None, None] + st
        return s_new, s                                # emit INPUT state

    s0 = jnp.zeros((Bsz, H, N, P), jnp.float32)
    _, s_in = jax.lax.scan(step, s0,
                           (chunk_decay.transpose(1, 0, 2),
                            state_c.transpose(1, 0, 2, 3, 4)))
    s_in = s_in.transpose(1, 0, 2, 3, 4)               # (B,nc,H,N,P)

    y_inter = jnp.einsum("bcqn,bcqh,bchnp->bcqhp",
                         Cc.astype(jnp.float32), jnp.exp(Acum), s_in)
    y = (y_intra + y_inter).reshape(Bsz, T, H, P)
    return y


def ssm_apply(p, x, cfg: ModelConfig):
    """Train/prefill path. x: (B, T, d) -> (B, T, d)."""
    s = cfg.ssm
    d_inner, H, N, P = _dims(cfg)
    B_, T, _ = x.shape
    h = x @ p["w_in"].astype(x.dtype)
    z, xbc, dt_raw = _split(cfg, h)
    xbc, _ = _causal_conv(xbc, p["conv_w"].astype(x.dtype))
    xi, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)
    delta = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                           # (H,)
    xh = xi.reshape(B_, T, H, P)
    xdt = xh.astype(jnp.float32) * delta[..., None]
    a = delta * A                                      # (B,T,H) log decay
    y = ssd_chunked(xdt, Bm, Cm, a, s.chunk)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B_, T, d_inner)
    y = _gated_norm(p, y, z, cfg.norm_eps).astype(x.dtype)
    y = constrain(y, "batch", "seq", "ffn")
    return y @ p["w_out"].astype(x.dtype)


def ssm_decode(p, x_t, conv_state, ssd_state, cfg: ModelConfig):
    """One-token step. x_t: (B,1,d); conv_state: (B,W-1,C);
    ssd_state: (B,H,N,P) f32. Returns (y, conv_state, ssd_state)."""
    d_inner, H, N, P = _dims(cfg)
    B_ = x_t.shape[0]
    h = x_t @ p["w_in"].astype(x_t.dtype)
    z, xbc, dt_raw = _split(cfg, h)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"].astype(x_t.dtype),
                                   state=conv_state)
    xi, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)
    delta = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])[:, 0]
    A = -jnp.exp(p["A_log"])
    xh = xi.reshape(B_, 1, H, P)[:, 0].astype(jnp.float32)  # (B,H,P)
    a = jnp.exp(delta * A)                                   # (B,H)
    upd = jnp.einsum("bn,bh,bhp->bhnp", Bm[:, 0].astype(jnp.float32),
                     delta, xh)
    ssd_state = ssd_state * a[:, :, None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0].astype(jnp.float32), ssd_state)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(B_, 1, d_inner)
    y = _gated_norm(p, y, z, cfg.norm_eps).astype(x_t.dtype)
    return y @ p["w_out"].astype(x_t.dtype), conv_state, ssd_state
