"""Shared layer library: norms, RoPE/M-RoPE, projections, MLPs, attention.

Pure-functional modules: ``*_init(rng, ...) -> params dict`` and
``*_apply(params, x, ...) -> y``. Parameter key names are load-bearing — the
path-regex sharding rules in :mod:`repro.dist.sharding` match on them.

The attention layer is where the paper's technique enters every model: QKV
projection -> RoPE -> :func:`repro.core.hybrid_attention` with the arch's
:class:`SALOConfig` pattern -> output projection.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, SALOConfig
from repro.core import (HybridSparsePattern, causal_sliding_window,
                        hybrid_attention, hybrid_decode_attention, longformer,
                        full)
from repro.core.attention import hybrid_chunk_attention
from repro.core.scheduler import PAD_SENTINEL
from repro.dist.sharding import constrain


def dt(cfg: ModelConfig, kind: str = "param"):
    return jnp.dtype(cfg.param_dtype if kind == "param" else cfg.compute_dtype)


# --------------------------- init helpers ------------------------------ #
def dense_init(rng, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale / np.sqrt(d_in)
    return (jax.random.normal(rng, (d_in, d_out)) * std).astype(dtype)


# ------------------------------ norms ---------------------------------- #
def rmsnorm_init(d: int):
    return {"scale": jnp.zeros((d,), jnp.float32)}  # gemma-style (1 + scale)


def rmsnorm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * (1.0 + p["scale"])
    return y.astype(x.dtype)


# ------------------------------- RoPE ----------------------------------- #
def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0,
         sections: Optional[tuple] = None) -> jax.Array:
    """Rotary embedding. x: (B, S, H, D); positions: (B, S) or (3, B, S) for
    M-RoPE with ``sections=(t, h, w)`` splitting D//2 frequency pairs."""
    B, S, H, D = x.shape
    half = D // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if sections is None:
        ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,half)
    else:
        t, h, w = sections
        assert t + h + w == half, (sections, half)
        # Each frequency pair uses the position component of its section.
        sec = jnp.concatenate([jnp.zeros(t, jnp.int32),
                               jnp.ones(h, jnp.int32),
                               jnp.full((w,), 2, jnp.int32)])
        pos = jnp.take_along_axis(
            positions.astype(jnp.float32).transpose(1, 2, 0),  # (B,S,3)
            jnp.broadcast_to(sec, (B, S, half)).astype(jnp.int32), axis=-1)
        ang = pos * freqs
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------- MLPs ----------------------------------- #
def mlp_init(rng, cfg: ModelConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    p = {"w_in": dense_init(ks[0], d, f, dt(cfg)),
         "w_out": dense_init(ks[1], f, d, dt(cfg))}
    if cfg.act in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(ks[2], d, f, dt(cfg))
    return p


def mlp_apply(p, x, cfg: ModelConfig):
    h = x @ p["w_in"].astype(x.dtype)
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"].astype(x.dtype)) * h
    elif cfg.act == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"].astype(x.dtype)) * h
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, "batch", "seq", "ffn")
    return h @ p["w_out"].astype(x.dtype)


# ---------------------------- attention --------------------------------- #
def salo_pattern(cfg: ModelConfig, causal: bool = True,
                 salo: Optional[SALOConfig] = None) -> HybridSparsePattern:
    """The pattern this architecture's attention layers run (DESIGN.md §5)."""
    s = salo or cfg.salo
    if not s.enabled:
        return full(causal=causal)
    if s.bidirectional and not causal:
        return longformer(s.window, n_global=s.n_global)
    return causal_sliding_window(s.window, n_sinks=s.n_global,
                                 dilation=s.dilation)


def attn_init(rng, cfg: ModelConfig):
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(rng, 4)
    return {"wq": dense_init(ks[0], d, H * hd, dt(cfg)),
            "wk": dense_init(ks[1], d, Hkv * hd, dt(cfg)),
            "wv": dense_init(ks[2], d, Hkv * hd, dt(cfg)),
            "wo": dense_init(ks[3], H * hd, d, dt(cfg))}


def attn_qkv(p, x, cfg: ModelConfig, positions, mrope=None):
    B, S, _ = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, H, hd)
    k = (x @ p["wk"].astype(x.dtype)).reshape(B, S, Hkv, hd)
    v = (x @ p["wv"].astype(x.dtype)).reshape(B, S, Hkv, hd)
    q = rope(q, positions, cfg.rope_theta, mrope)
    k = rope(k, positions, cfg.rope_theta, mrope)
    return q, k, v


def attn_apply(p, x, cfg: ModelConfig, pattern: HybridSparsePattern,
               positions=None, mrope=None, kv=None):
    """Full-sequence attention (train / prefill).

    kv: optional externally-provided (k, v) — used for cross-attention.
    Returns (out, (k, v)) so prefill can populate caches.
    """
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = attn_qkv(p, x, cfg, positions, mrope)
    if kv is not None:
        k, v = kv
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
    v = constrain(v, "batch", "seq", "kv_heads", "head_dim")
    # When the cell rules map "seq" to a mesh axis (long-context SP),
    # hybrid_attention routes to the ShardedPlan shard_map path — the same
    # fused engines with ppermute halo exchange instead of a K/V
    # all-gather (repro.dist.sharded_plan).
    out = hybrid_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), pattern, impl=cfg.salo.impl,
        block_q=cfg.salo.block_q, block_k=cfg.salo.block_k)
    out = out.transpose(0, 2, 1, 3)
    out = constrain(out, "batch", "seq", "heads", "head_dim")
    out = out.reshape(B, S, cfg.n_heads * cfg.hd)
    return out @ p["wo"].astype(x.dtype), (k, v)


def attn_decode(p, x_t, cache_k, cache_v, t, cfg: ModelConfig,
                pattern: HybridSparsePattern, cache_positions=None,
                positions=None, mrope=None):
    """One-token decode. x_t: (B, 1, d); caches: (B, S, Hkv, hd); t scalar.

    Writes the new KV at slot ``t`` (full-cache baseline) unless the caller
    manages slots itself (SALO ring cache passes ``cache_positions``)."""
    B = x_t.shape[0]
    if positions is None:
        # M-RoPE text decode: all three components advance together.
        shape = (3, B, 1) if mrope is not None else (B, 1)
        positions = jnp.full(shape, t, jnp.int32)
    q, k, v = attn_qkv(p, x_t, cfg, positions, mrope)
    if cfg.salo.ring_cache and cache_positions is None:
        # SALO ring cache: slots = [sinks | ring of
        # size w]; slot j >= g holds the most recent position p <= t with
        # (p - g) mod w == j - g.
        w_, g_ = cfg.salo.window, max(cfg.salo.n_global, 0)
        S_slots = cache_k.shape[1]
        tt = jnp.asarray(t, jnp.int32)
        slot = jnp.where(tt < g_, tt, g_ + (tt - g_) % w_)
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, slot,
                                                      axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, slot,
                                                      axis=1)
        j = jnp.arange(S_slots, dtype=jnp.int32)
        pos_ring = tt - ((tt - j) % w_)
        pos = jnp.where(j < g_, j, pos_ring)
        # unwritten ring slots (pos < g) mask out via the padding sentinel
        cache_positions = jnp.where((j >= g_) & (pos < g_),
                                    jnp.int32(PAD_SENTINEL), pos)
    elif cache_positions is None:  # full cache: slot == position
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, t, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, t, axis=1)
    out = hybrid_decode_attention(
        q.transpose(0, 2, 1, 3), cache_k.transpose(0, 2, 1, 3),
        cache_v.transpose(0, 2, 1, 3), t, pattern,
        cache_positions=cache_positions,
        slice_window=cfg.salo.decode_slice and not cfg.salo.ring_cache)
    out = out.transpose(0, 2, 1, 3).reshape(B, 1, cfg.n_heads * cfg.hd)
    return out @ p["wo"].astype(x_t.dtype), cache_k, cache_v


# ------------------- continuous-batching serve paths -------------------- #
def attn_chunk_prefill(p, x_chunk, ctx_k, ctx_v, ctx_pos, pos_q, kv_blocks,
                       flags, cfg: ModelConfig,
                       pattern: HybridSparsePattern, axis=None):
    """One prompt chunk through a layer's attention (plan-driven prefill).

    x_chunk: (1, Cp, d) chunk activations; ctx_k/ctx_v: (1, S_req, Hkv, hd)
    the request's paged KV view (sinks + ring); ctx_pos: (1, S_req) live
    slot positions; pos_q: (1, Cp) chunk positions (PAD_SENTINEL on padded
    rows); kv_blocks/flags: (nq, W) ChunkPlan step tables. Returns
    (out, k_chunk, v_chunk) — the fresh chunk KV for the caller's slab
    write-back (the paper's window stream, cached as it flows by).

    ``axis``: sequence-parallel serving — this shard's ctx view/positions
    and per-shard tables cover only the slots it owns (plus the replicated
    chunk on the chunk-owner shard); the partial (out, m, l) is merged
    across the mesh axis before the output projection."""
    B, Cp, _ = x_chunk.shape
    rope_pos = jnp.where(pos_q < PAD_SENTINEL, pos_q, 0)
    q, k, v = attn_qkv(p, x_chunk, cfg, rope_pos)
    k_view = jnp.concatenate([ctx_k.astype(k.dtype), k], axis=1)
    v_view = jnp.concatenate([ctx_v.astype(v.dtype), v], axis=1)
    pos_k = jnp.concatenate([ctx_pos, pos_q], axis=1)
    if axis is None:
        out = hybrid_chunk_attention(
            q.transpose(0, 2, 1, 3), k_view.transpose(0, 2, 1, 3),
            v_view.transpose(0, 2, 1, 3), pos_q, pos_k, kv_blocks, flags,
            pattern)
    else:
        from repro.dist.sharded_plan import masked_psum_merge
        out, m, l = hybrid_chunk_attention(
            q.transpose(0, 2, 1, 3), k_view.transpose(0, 2, 1, 3),
            v_view.transpose(0, 2, 1, 3), pos_q, pos_k, kv_blocks, flags,
            pattern, return_state=True)
        # partials are f32; ONE round to the compute dtype, post-merge
        out = masked_psum_merge(out, m, l, axis).astype(x_chunk.dtype)
    out = out.transpose(0, 2, 1, 3).reshape(B, Cp, cfg.n_heads * cfg.hd)
    return out @ p["wo"].astype(x_chunk.dtype), k, v


def attn_decode_paged(p, x_t, k_slab, v_slab, page_tables, slot_pos, t_vec,
                      phys_w, off_w, cfg: ModelConfig,
                      pattern: HybridSparsePattern, impl: str = "xla",
                      axis=None, k_scale=None, v_scale=None,
                      want_page_stats: bool = False):
    """Ragged one-token decode against ONE layer's pooled paged slab.

    x_t: (R, 1, d) — one token per engine row; k_slab/v_slab:
    (n_pages, page, Hkv, hd); page_tables: (R, npp); slot_pos: (R, S_req)
    live positions (already updated for this step's writes); t_vec: (R,)
    per-request positions; phys_w/off_w: (R,) slab write targets (null page
    for inactive rows). Returns
    ``(out, k_slab, v_slab, k_scale, v_scale, page_m)``.

    ``k_scale``/``v_scale``: the layer's per-page (n_pages,) f32 dequant
    scales — present iff the slab is int8. The fresh token KV is
    quantized into its page (:func:`~repro.serve.paged_cache
    .quant_slab_write`, monotone scale growth) and reads dequantize
    per page — in-kernel for the Pallas impls, via the dequantizing
    ``gather_view`` for the XLA twin. Returned ``k_scale``/``v_scale``
    are the updated vectors (``None`` for fp slabs).

    ``want_page_stats=True`` makes ``page_m`` (R, npp) the max masked
    score this request produced against each of its logical pages
    (NEG_INF for fully-masked pages) — the engine's page-sparsity
    statistic; otherwise ``page_m`` is ``None``.

    ``axis``: sequence-parallel serving — slab/page_tables/slot_pos are
    this shard's slice (npp = pages_per_shard; non-owned writes already
    routed to the null page via phys_w), so the decode launch covers only
    the owned slots and the (out, m, l) partial is merged across the mesh
    axis (one ragged launch per shard, masked-psum combine)."""
    from repro.serve.paged_cache import (gather_view, quant_slab_write,
                                         slab_write)

    R = x_t.shape[0]
    quant = k_scale is not None
    q, k, v = attn_qkv(p, x_t, cfg, t_vec[:, None])
    if quant:
        k_slab, v_slab, k_scale, v_scale = quant_slab_write(
            k_slab, v_slab, k_scale, v_scale, phys_w, off_w, k[:, 0], v[:, 0])
    else:
        k_slab, v_slab = slab_write(k_slab, v_slab, phys_w, off_w,
                                    k[:, 0], v[:, 0])
    qt = q.transpose(0, 2, 1, 3)                       # (R, H, 1, hd)
    state = axis is not None
    page_m = None
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels.salo_decode import salo_paged_decode
        res = salo_paged_decode(qt, k_slab, v_slab, page_tables, slot_pos,
                                t_vec, pattern=pattern,
                                interpret=(impl == "pallas_interpret"),
                                return_state=state, k_scale=k_scale,
                                v_scale=v_scale,
                                return_page_stats=want_page_stats)
        if want_page_stats:
            res, page_m = res[:-1], res[-1]
            res = res if state else res[0]
    else:
        k_req, v_req = gather_view(
            k_slab, v_slab, page_tables,
            *((k_scale, v_scale, x_t.dtype) if quant else ()))
        res = hybrid_decode_attention(
            qt, k_req.transpose(0, 2, 1, 3), v_req.transpose(0, 2, 1, 3),
            t_vec, pattern, cache_positions=slot_pos, return_state=state,
            return_slot_m=want_page_stats)
        if want_page_stats:
            res, slot_m = (res[:-1], res[-1])
            res = res if state else res[0]
            page = k_slab.shape[1]
            npp = page_tables.shape[1]
            page_m = slot_m.reshape(R, npp, page).max(axis=-1)
    if state:
        from repro.dist.sharded_plan import masked_psum_merge
        out, m, l = res
        # partials are f32; ONE round to the compute dtype, post-merge
        out = masked_psum_merge(out, m, l, axis).astype(x_t.dtype)
    else:
        out = res
    out = out.transpose(0, 2, 1, 3).reshape(R, 1, cfg.n_heads * cfg.hd)
    return (out @ p["wo"].astype(x_t.dtype), k_slab, v_slab,
            k_scale, v_scale, page_m)


# ------------------------------ embedding -------------------------------- #
def embed_init(rng, cfg: ModelConfig):
    # std 1/sqrt(d): embed_apply rescales by sqrt(d) to unit variance, and
    # the (tied) readout keeps logits O(1) at init.
    std = cfg.d_model ** -0.5
    w = (jax.random.normal(rng, (cfg.vocab_size, cfg.d_model)) * std)
    return {"w": w.astype(dt(cfg))}


def embed_apply(p, tokens, cfg: ModelConfig):
    x = jnp.take(p["w"], tokens, axis=0).astype(dt(cfg, "compute"))
    # NB: python float (weak type) — a numpy scalar would promote bf16->f32.
    return x * float(np.sqrt(cfg.d_model))  # gemma-style scaling


def logits_apply(p_embed, p_head, x, cfg: ModelConfig):
    w = (p_embed["w"] if cfg.tie_embeddings else p_head["w"]).astype(x.dtype)
    logits = x @ w.T
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def cross_entropy(logits, targets, mask=None):
    """logits (B,S,V), targets (B,S) int32. Mean NLL over mask."""
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# --------------------------- cross attention ----------------------------- #
def cross_attn_apply(p, x, enc_out, cfg: ModelConfig):
    """Encoder-decoder cross attention (dense over the encoder sequence —
    n_enc is short for the audio stub; no RoPE, whisper-style).

    Rectangular (S_q != S_kv), so computed directly rather than through the
    square-pattern SALO engines."""
    B, S, _ = x.shape
    Se = enc_out.shape[1]
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, H, hd)
    k = (enc_out @ p["wk"].astype(x.dtype)).reshape(B, Se, Hkv, hd)
    v = (enc_out @ p["wv"].astype(x.dtype)).reshape(B, Se, Hkv, hd)
    kr, vr = k, v
    if Hkv != H:
        kr = jnp.repeat(k, H // Hkv, axis=2)
        vr = jnp.repeat(v, H // Hkv, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kr,
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, vr.astype(w.dtype))
    out = out.astype(x.dtype).reshape(B, S, H * hd)
    return out @ p["wo"].astype(x.dtype), (k, v)


def cross_attn_decode(p, x_t, k_enc, v_enc, cfg: ModelConfig):
    """Decode-time cross attention with precomputed encoder K/V."""
    B = x_t.shape[0]
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x_t @ p["wq"].astype(x_t.dtype)).reshape(B, 1, H, hd)
    if Hkv != H:
        k_enc = jnp.repeat(k_enc, H // Hkv, axis=2)
        v_enc = jnp.repeat(v_enc, H // Hkv, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k_enc,
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v_enc.astype(w.dtype))
    out = out.astype(x_t.dtype).reshape(B, 1, H * hd)
    return out @ p["wo"].astype(x_t.dtype)


def sinusoidal_pos(S: int, d: int, dtype) -> jnp.ndarray:
    """Whisper-style sinusoidal positional embedding (S, d)."""
    half = d // 2
    freqs = np.exp(-np.log(10000.0) * np.arange(half) / max(half - 1, 1))
    ang = np.arange(S)[:, None] * freqs[None, :]
    pe = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(pe, dtype)
