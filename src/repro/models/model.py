"""Unified model API: ``build_model(cfg) -> Model``.

Every architecture exposes the same surface:
  * ``init(rng) -> params``
  * ``forward(params, batch) -> logits``          (train / prefill)
  * ``loss(params, batch) -> (loss, metrics)``
  * ``init_cache(batch_size, max_len) -> cache``  (decode shapes)
  * ``decode_step(params, cache, batch_t, t) -> (logits, cache)``

``batch`` is a dict; which keys exist per family is defined by
``launch.specs.input_specs`` (the dry-run and the data pipeline agree on it).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import constrain
from repro.models import layers as L
from repro.models import transformer as T


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.program = T.make_program(cfg)

    # ------------------------------ init ------------------------------- #
    def init(self, rng) -> Dict[str, Any]:
        cfg = self.cfg
        ks = jax.random.split(rng, 8)
        params = {"embed": L.embed_init(ks[0], cfg),
                  "ln_f": L.rmsnorm_init(cfg.d_model)}
        if not cfg.tie_embeddings:
            params["lm_head"] = {"w": L.embed_init(ks[1], cfg)["w"]}
        for i, (kind, n) in enumerate(self.program):
            params[f"seg{i}_{kind}"] = T.segment_init(ks[2 + i], cfg, kind, n)
        if cfg.encoder_decoder:
            params["enc"] = {
                "seg0_attn_mlp": T.segment_init(ks[6], cfg, "attn_mlp",
                                                cfg.n_layers),
                "ln_f": L.rmsnorm_init(cfg.d_model)}
        if cfg.n_vision_tokens:
            params["vision_proj"] = {
                "w": L.dense_init(ks[7], cfg.d_model, cfg.d_model,
                                  L.dt(cfg))}
        return params

    # ----------------------------- forward ----------------------------- #
    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        x = L.embed_apply(params["embed"], batch["tokens"], cfg)
        if cfg.n_vision_tokens and "vision_embeds" in batch:
            vis = batch["vision_embeds"].astype(x.dtype)
            vis = vis @ params["vision_proj"]["w"].astype(x.dtype)
            x = jnp.where(batch["vision_mask"][..., None], vis, x)
        return x

    def _encode(self, params, batch):
        """Whisper encoder over stub audio-frame embeddings."""
        cfg = self.cfg
        import dataclasses
        enc_salo = dataclasses.replace(cfg.salo, bidirectional=True)
        pattern = L.salo_pattern(cfg, causal=False, salo=enc_salo)
        x = batch["audio_embeds"].astype(L.dt(cfg, "compute"))
        x = x + L.sinusoidal_pos(x.shape[1], cfg.d_model, x.dtype)
        x, _ = T.segment_apply(params["enc"]["seg0_attn_mlp"], x, cfg,
                               "attn_mlp", pattern)
        return L.rmsnorm(params["enc"]["ln_f"], x, cfg.norm_eps)

    def forward(self, params, batch, return_aux: bool = False):
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        x = constrain(x, "batch", "seq", "embed")
        positions = batch.get("positions", None)
        mrope = cfg.mrope_sections
        if mrope is not None and positions is None:
            B, S = batch["tokens"].shape
            positions = jnp.broadcast_to(jnp.arange(S), (3, B, S))
        enc_out = self._encode(params, batch) if cfg.encoder_decoder else None
        pats = T._patterns(cfg)
        aux_total: Dict[str, jax.Array] = {}
        for i, (kind, n) in enumerate(self.program):
            pattern = pats.get(kind, pats["attn_mlp"])
            x, aux = T.segment_apply(
                params[f"seg{i}_{kind}"], x, cfg, kind, pattern,
                positions=positions, mrope=mrope, enc_out=enc_out)
            for k_, v_ in aux.items():
                aux_total[k_] = aux_total.get(k_, 0.0) + v_
        x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
        logits = L.logits_apply(params["embed"], params.get("lm_head"),
                                x, cfg)
        logits = constrain(logits, "batch", "seq", "vocab")
        if return_aux:
            return logits, aux_total
        return logits

    # ------------------------------ loss -------------------------------- #
    def loss(self, params, batch):
        logits, aux = self.forward(params, batch, return_aux=True)
        nll = L.cross_entropy(logits, batch["labels"], batch.get("mask"))
        loss = nll
        metrics = {"nll": nll}
        for k_, v_ in aux.items():
            if k_ in ("load_balance", "router_z"):
                loss = loss + v_
            metrics[k_] = v_
        metrics["loss"] = loss
        return loss, metrics

    # ------------------------------ decode ------------------------------ #
    def init_cache(self, batch_size: int, max_len: int):
        cfg = self.cfg
        dtype = L.dt(cfg, "compute")
        cache = {}
        for i, (kind, n) in enumerate(self.program):
            one = T.block_cache_init(cfg, kind, batch_size, max_len, dtype)
            cache[f"seg{i}_{kind}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (n, *a.shape)), one)
        return cache

    def decode_step(self, params, cache, batch_t, t):
        """batch_t: {'tokens': (B, 1), ...}; t: scalar position index."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch_t)
        mrope = cfg.mrope_sections
        positions = batch_t.get("positions", None)
        pats = T._patterns(cfg)
        new_cache = {}
        for i, (kind, n) in enumerate(self.program):
            key = f"seg{i}_{kind}"
            pattern = pats.get(kind, pats["attn_mlp"])
            x, new_cache[key] = T.segment_decode(
                params[key], cache[key], x, t, cfg, kind, pattern,
                positions=positions, mrope=mrope)
        x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
        logits = L.logits_apply(params["embed"], params.get("lm_head"),
                                x, cfg)
        return logits, new_cache

    # ------------------------------ prefill ------------------------------ #
    def prefill(self, params, batch):
        """Run the full-sequence path and build a decode-ready cache.

        Returns (logits, cache). Implemented by re-projecting K/V per layer
        — same math the train path uses, so it reuses the SALO engines.
        """
        raise NotImplementedError(
            "prefill-to-cache is exercised via serve.engine")


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
