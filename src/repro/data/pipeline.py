"""Deterministic synthetic LM data pipeline.

Produces packed token streams with enough structure to be *learnable* (a
mixture of order-k Markov chains with per-document transition tables), so the
end-to-end training example shows a real loss curve rather than noise-floor
flatlining. Host-sharded: each data-parallel host materializes only its slice
of the global batch; resumable by step (stateless indexing by (seed, step)).

Per-family extras (audio embeddings, vision embeddings/masks, M-RoPE
positions) mirror ``launch.specs.input_specs`` exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 0
    markov_order: int = 2
    n_docs: int = 64          # distinct "documents" (transition tables)
    branch: int = 16          # candidate successors per state


class SyntheticLM:
    """Markov-mixture synthetic corpus. Deterministic in (seed, step, host)."""

    def __init__(self, cfg: ModelConfig, data: DataConfig,
                 host_id: int = 0, n_hosts: int = 1):
        assert data.global_batch % n_hosts == 0
        self.cfg, self.data = cfg, data
        self.host_id, self.n_hosts = host_id, n_hosts
        self.local_batch = data.global_batch // n_hosts
        rng = np.random.default_rng(data.seed)
        # Tokens are drawn from the first `n_states` vocabulary entries so
        # the Markov state IS the token (no aliasing) — the structure is
        # directly learnable by a bigram-capable model.
        self.n_states = min(cfg.vocab_size, 4096)
        # Per-doc successor tables: state -> `branch` allowed next tokens.
        self._succ = rng.integers(
            0, self.n_states, size=(data.n_docs, self.n_states, data.branch),
            dtype=np.int32)

    def _sample_doc(self, rng: np.random.Generator, length: int) -> np.ndarray:
        doc = rng.integers(0, self.data.n_docs)
        succ = self._succ[doc]
        toks = np.empty(length, np.int32)
        state = rng.integers(0, self.n_states)
        toks[0] = state
        branches = rng.integers(0, self.data.branch, size=length)
        for i in range(1, length):
            state = succ[state, branches[i]]
            toks[i] = state
        return toks

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Global-step-indexed batch for THIS host (resume = same stream)."""
        d, cfg = self.data, self.cfg
        rng = np.random.default_rng(
            (d.seed, step, self.host_id))
        S = d.seq_len
        toks = np.stack([self._sample_doc(rng, S + 1)
                         for _ in range(self.local_batch)])
        out = {"tokens": toks[:, :S].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}
        if cfg.encoder_decoder:
            out["audio_embeds"] = rng.normal(
                size=(self.local_batch, cfg.n_audio_frames, cfg.d_model)
            ).astype(np.float32)
        if cfg.n_vision_tokens:
            nv = min(cfg.n_vision_tokens, S // 2)
            mask = np.zeros((self.local_batch, S), bool)
            mask[:, :nv] = True
            out["vision_mask"] = mask
            out["vision_embeds"] = rng.normal(
                size=(self.local_batch, S, cfg.d_model)).astype(np.float32)
            pos = np.broadcast_to(np.arange(S, dtype=np.int32),
                                  (3, self.local_batch, S)).copy()
            out["positions"] = pos
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
