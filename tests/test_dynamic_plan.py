"""Runtime ExecutionPlans (core/dynamic.py):
  * full keep reproduces the static fused path exactly — fwd AND grads —
    across window/sink, longformer-global and dilated patterns (the
    machinery-off invariant)
  * small keep equals a masked dense reference built from the implied
    token mask (selection is deterministic + stop-grad, so grads match
    the fixed-mask reference too)
  * the never-drop guarantee: causal-local and global tiles survive any
    keep; check_keep raises when keep can't cover them
  * emitted tables honor the plan contract (validate_tables accepts)
  * the Pallas table engine (interpret) matches the XLA scan twin
  * under shard_map: full-keep == static sharded == single-device fused,
    and small-keep sharded == small-keep single-device (per-shard top-k
    over the exchanged view is exhaustive for the rows a shard owns)
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import patterns as P
from repro.core.blockwise import blockwise_attention
from repro.core.dynamic import (DynamicConfig, check_keep, dynamic_attention,
                                dynamic_tables)
from repro.core.plan_contract import validate_tables

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

PATTERNS = [
    ("window_sinks", P.causal_sliding_window(48, n_sinks=8)),
    ("longformer_global", P.longformer(32, n_global=8)),
    ("dilated", P.dilated_window(32, 2)),
]


def _data(rng, n=256, d=32, b=2, count=4):
    return tuple(jnp.asarray(rng.normal(size=(b, n, d)), jnp.float32)
                 for _ in range(count))


@pytest.mark.parametrize("name,pat", PATTERNS)
def test_full_keep_matches_static(name, pat):
    """keep >= max_steps selects every candidate step: outputs and all
    three gradients must match the static fused path to 1e-4."""
    q, k, v, cot = _data(np.random.default_rng(0))
    cfg = DynamicConfig(keep=10 ** 6)
    ref = blockwise_attention(q, k, v, pat, block_q=32, block_k=32)
    out = dynamic_attention(q, k, v, pat, cfg, block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4, err_msg=name)
    g_ref = jax.grad(lambda a, b, c: jnp.sum(blockwise_attention(
        a, b, c, pat, block_q=32, block_k=32) * cot),
        argnums=(0, 1, 2))(q, k, v)
    g_dyn = jax.grad(lambda a, b, c: jnp.sum(dynamic_attention(
        a, b, c, pat, cfg, block_q=32, block_k=32) * cot),
        argnums=(0, 1, 2))(q, k, v)
    for gname, ga, gb in zip("qkv", g_ref, g_dyn):
        np.testing.assert_allclose(np.asarray(gb), np.asarray(ga),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"{name}: d{gname}")


def test_small_keep_matches_masked_dense():
    """keep < max_steps: the executed computation must equal dense
    attention under the IMPLIED token mask (pattern mask restricted to
    the selected tiles). The selector is deterministic and gradient-free,
    so gradients match the fixed-mask dense reference as well."""
    pat = P.causal_sliding_window(64)
    N, BLK, KEEP = 256, 32, 3
    rng = np.random.default_rng(1)
    q, k, v, cot = _data(rng, n=N)
    cfg = DynamicConfig(keep=KEEP)
    plan, kvt, flg, _ = dynamic_tables(q, k, pat, cfg,
                                       block_q=BLK, block_k=BLK)
    # this reference construction assumes the working grid is the identity
    # (true for pure-window patterns)
    assert np.array_equal(plan.positions_padded(), np.arange(N))
    kvt, flg = np.asarray(kvt), np.asarray(flg)
    sel = np.zeros((N // BLK, N // BLK), bool)
    for i in range(N // BLK):
        sel[i, kvt[i][flg[i] != 0]] = True
    mask = np.asarray(pat.mask(N)) & np.repeat(
        np.repeat(sel, BLK, axis=0), BLK, axis=1)

    def dense_ref(a, b, c):
        s = jnp.einsum("bqd,bkd->bqk", a, b) * (32 ** -0.5)
        s = jnp.where(jnp.asarray(mask)[None], s, -1e30)
        return jnp.einsum("bqk,bkd->bqd", jax.nn.softmax(s, axis=-1), c)

    out = dynamic_attention(q, k, v, pat, cfg, block_q=BLK, block_k=BLK)
    ref = dense_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    # fewer tiles actually execute than the static plan carries
    assert (flg != 0).sum() < (plan.flags != 0).sum()
    g_ref = jax.grad(lambda a, b, c: jnp.sum(dense_ref(a, b, c) * cot),
                     argnums=(0, 1, 2))(q, k, v)
    g_dyn = jax.grad(lambda a, b, c: jnp.sum(dynamic_attention(
        a, b, c, pat, cfg, block_q=BLK, block_k=BLK) * cot),
        argnums=(0, 1, 2))(q, k, v)
    for gname, ga, gb in zip("qkv", g_ref, g_dyn):
        np.testing.assert_allclose(np.asarray(gb), np.asarray(ga),
                                   rtol=1e-4, atol=2e-4,
                                   err_msg=f"d{gname}")


@pytest.mark.parametrize("name,pat", PATTERNS)
def test_never_drop_and_contract(name, pat):
    """Whatever the content says, every always-keep step (causal-local +
    global/sink tiles) appears in the selection, and the emitted tables
    pass the shared contract validator."""
    q, k, _, _ = _data(np.random.default_rng(2))
    cfg = DynamicConfig(keep=6)
    plan, kvt, flg, always = dynamic_tables(q, k, pat, cfg,
                                            block_q=32, block_k=32)
    kvt, flg = np.asarray(kvt), np.asarray(flg)
    validate_tables(kvt, flg, nkb=plan.nkb, name=f"dynamic[{name}]")
    for i in range(plan.nq):
        picked = set(kvt[i][flg[i] != 0].tolist())
        needed = set(plan.kv_blocks[i][always[i]].tolist())
        assert needed <= picked, \
            f"{name} row {i}: dropped always-keep tiles {needed - picked}"
        assert len(picked) <= 6


def test_check_keep_raises():
    """keep below the worst-case always-kept count must refuse loudly, not
    silently drop a correctness-critical tile."""
    q, k, _, _ = _data(np.random.default_rng(3))
    with pytest.raises(ValueError, match="always-kept"):
        dynamic_tables(q, k, P.causal_sliding_window(48, n_sinks=8),
                       DynamicConfig(keep=1), block_q=32, block_k=32)
    check_keep(3, np.ones((4, 3), bool)[:, :2])  # 3 >= 2: fine


def test_pallas_interpret_engine_parity():
    """The fused table kernel (interpret mode) under a dynamic table must
    match the XLA scan twin — fwd and grads."""
    pat = P.causal_sliding_window(48, n_sinks=8)
    q, k, v, cot = _data(np.random.default_rng(4))
    cfg = DynamicConfig(keep=5)
    ref = dynamic_attention(q, k, v, pat, cfg, block_q=32, block_k=32)
    out = dynamic_attention(q, k, v, pat, cfg, block_q=32, block_k=32,
                            impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    g_ref = jax.grad(lambda a, b, c: jnp.sum(dynamic_attention(
        a, b, c, pat, cfg, block_q=32, block_k=32) * cot),
        argnums=(0, 1, 2))(q, k, v)
    g_pl = jax.grad(lambda a, b, c: jnp.sum(dynamic_attention(
        a, b, c, pat, cfg, block_q=32, block_k=32,
        impl="pallas_interpret") * cot), argnums=(0, 1, 2))(q, k, v)
    for gname, ga, gb in zip("qkv", g_ref, g_pl):
        np.testing.assert_allclose(np.asarray(gb), np.asarray(ga),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"d{gname}")


def test_hybrid_attention_dynamic_route():
    """plan="dynamic" on the public multi-head entry point routes through
    dynamic_attention; dense_ref and missing keep are rejected."""
    from repro.core.attention import hybrid_attention
    rng = np.random.default_rng(5)
    q, k, v = (jnp.asarray(rng.normal(size=(2, 2, 128, 16)), jnp.float32)
               for _ in range(3))
    pat = P.causal_sliding_window(32, n_sinks=4)
    ref = hybrid_attention(q, k, v, pat, block_q=16, block_k=16)
    full = hybrid_attention(q, k, v, pat, plan="dynamic",
                            dynamic_keep=10 ** 6, block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(full), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    small = hybrid_attention(q, k, v, pat, plan="dynamic", dynamic_keep=4,
                             block_q=16, block_k=16)
    assert np.all(np.isfinite(np.asarray(small)))
    with pytest.raises(ValueError, match="dense_ref"):
        hybrid_attention(q, k, v, pat, plan="dynamic", dynamic_keep=4,
                         impl="dense_ref")
    with pytest.raises(ValueError, match="dynamic_keep"):
        hybrid_attention(q, k, v, pat, plan="dynamic")
    with pytest.raises(ValueError, match="plan"):
        hybrid_attention(q, k, v, pat, plan="adaptive")


def test_invalid_impl_rejected():
    q, k, v, _ = _data(np.random.default_rng(6), n=64)
    with pytest.raises(ValueError, match="table-driven"):
        dynamic_attention(q, k, v, P.causal_sliding_window(32),
                          DynamicConfig(keep=4), block_q=32, block_k=32,
                          impl="dense_ref")


def test_sharded_dynamic_parity():
    """Under an 8-device shard_map: full keep == the single-device STATIC
    fused path (fwd + grads), and small keep == the single-device DYNAMIC
    path — each shard's top-k over its exchanged [local|halo|global] view
    is exhaustive for the query rows it owns."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import patterns as P_
        from repro.core.blockwise import blockwise_attention
        from repro.core.dynamic import DynamicConfig, dynamic_attention
        from repro.dist.sharded_plan import sharded_attention
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        B, N, D = 2, 512, 16
        pat = P_.causal_sliding_window(48, n_sinks=8)
        q, k, v, cot = (jnp.asarray(rng.normal(size=(B, N, D)), jnp.float32)
                        for _ in range(4))

        full = DynamicConfig(keep=10 ** 6)
        ref = blockwise_attention(q, k, v, pat, block_q=16, block_k=16)
        g_ref = jax.grad(lambda a, b, c: jnp.sum(blockwise_attention(
            a, b, c, pat, block_q=16, block_k=16) * cot),
            argnums=(0, 1, 2))(q, k, v)
        with mesh:
            out = jax.jit(lambda a, b, c: sharded_attention(
                a, b, c, pat, mesh, block_q=16, block_k=16,
                dynamic=full))(q, k, v)
            g = jax.jit(jax.grad(lambda a, b, c: jnp.sum(sharded_attention(
                a, b, c, pat, mesh, block_q=16, block_k=16,
                dynamic=full) * cot), argnums=(0, 1, 2)))(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
        for name, ga, gb in zip("qkv", g_ref, g):
            np.testing.assert_allclose(np.asarray(gb), np.asarray(ga),
                                       rtol=1e-4, atol=1e-4,
                                       err_msg="d" + name)
        print("FULL-KEEP-SHARDED-OK")

        small = DynamicConfig(keep=6)
        dref = dynamic_attention(q, k, v, pat, small,
                                 block_q=16, block_k=16)
        gd_ref = jax.grad(lambda a, b, c: jnp.sum(dynamic_attention(
            a, b, c, pat, small, block_q=16, block_k=16) * cot),
            argnums=(0, 1, 2))(q, k, v)
        with mesh:
            dout = jax.jit(lambda a, b, c: sharded_attention(
                a, b, c, pat, mesh, block_q=16, block_k=16,
                dynamic=small))(q, k, v)
            gd = jax.jit(jax.grad(lambda a, b, c: jnp.sum(sharded_attention(
                a, b, c, pat, mesh, block_q=16, block_k=16,
                dynamic=small) * cot), argnums=(0, 1, 2)))(q, k, v)
        np.testing.assert_allclose(np.asarray(dout), np.asarray(dref),
                                   rtol=1e-4, atol=1e-4)
        for name, ga, gb in zip("qkv", gd_ref, gd):
            np.testing.assert_allclose(np.asarray(gb), np.asarray(ga),
                                       rtol=1e-4, atol=1e-4,
                                       err_msg="d" + name)
        print("SMALL-KEEP-SHARDED-OK")
    """)
    r = subprocess.run([sys.executable, "-c", prog],
                       env={**os.environ, "PYTHONPATH": SRC},
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "SMALL-KEEP-SHARDED-OK" in r.stdout
