"""Continuous-batching serving subsystem: paged slab, chunked prefill,
ragged decode, scheduler lifecycle — all pinned against the lockstep
baseline and the dense oracle."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import patterns as P
from repro.core.scheduler import (BIG, build_chunk_plan,
                                  ring_view_positions)
from repro.models.model import build_model
from repro.serve.engine import (ContinuousConfig, ContinuousEngine,
                                ServeConfig, ServeEngine)
from repro.serve.paged_cache import PagedLayout, PageAllocator

RNG = np.random.default_rng(7)


def _engine(cfg, *, page=8, chunk=8, max_batch=4, extra_pages=0,
            decode_impl="xla"):
    from repro.models.layers import salo_pattern
    from repro.serve.paged_cache import layout_for_pattern

    model = build_model(cfg)
    lay = layout_for_pattern(salo_pattern(cfg, causal=True), page)
    eng = ContinuousEngine(model, ContinuousConfig(
        n_pages=1 + max_batch * lay.pages_per_req + extra_pages, page=page,
        chunk=chunk, max_batch=max_batch, decode_impl=decode_impl))
    return model, eng


def _lockstep_refs(model, params, prompts, n_new):
    """Per-request lockstep greedy generation (the parity oracle)."""
    out = []
    for p in prompts:
        eng = ServeEngine(model, ServeConfig(max_len=len(p) + n_new))
        out.append(np.asarray(
            eng.generate(params, jnp.asarray(p)[None], n_new))[0])
    return out


# ===================== end-to-end greedy parity ======================== #
def test_ragged_batch_matches_lockstep():
    """A ragged batch (different prompt lengths => different positions per
    row at every decode step) matches per-request lockstep generation
    token-for-token. Ring wraps: prompts + new tokens exceed the window."""
    cfg = get_smoke("smollm-135m")  # window=16, n_global=2
    model, eng = _engine(cfg, chunk=8)
    params = model.init(jax.random.PRNGKey(0))
    lens, n_new = [5, 9, 13, 26], 8
    prompts = [RNG.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
               for L in lens]
    refs = _lockstep_refs(model, params, prompts, n_new)
    rids = [eng.submit(p, n_new) for p in prompts]
    results = eng.run(params)
    for rid, ref in zip(rids, refs):
        np.testing.assert_array_equal(results[rid], ref, err_msg=str(rid))
    # per-step assembly really was ragged: decode launches < sum of tokens
    assert eng.counters["decode_launches"] < sum(n_new - 1 for _ in lens)


def test_ring_wraparound_t_much_greater_than_window():
    """t >> window: generation runs many full ring revolutions past the
    window and stays token-exact vs the full-cache lockstep baseline."""
    cfg = get_smoke("smollm-135m")
    cfg = dataclasses.replace(cfg, salo=dataclasses.replace(
        cfg.salo, window=8))
    model, eng = _engine(cfg, chunk=8, max_batch=2)
    params = model.init(jax.random.PRNGKey(1))
    prompts = [RNG.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
               for L in (21, 6)]
    n_new = 40  # final t = 60 -> 7+ ring revolutions past window=8
    refs = _lockstep_refs(model, params, prompts, n_new)
    rids = [eng.submit(p, n_new) for p in prompts]
    results = eng.run(params)
    for rid, ref in zip(rids, refs):
        np.testing.assert_array_equal(results[rid], ref)


def test_dilated_decode_parity():
    """dilation > 1: the paged ring spans the full dilated lookback
    (w-1)*d + 1 (the legacy batch ring under-provisioned this), so decode
    matches the full-cache lockstep baseline exactly."""
    cfg = get_smoke("smollm-135m")
    cfg = dataclasses.replace(cfg, salo=dataclasses.replace(
        cfg.salo, window=4, dilation=2, n_global=2))
    model, eng = _engine(cfg, chunk=8, max_batch=2)
    assert eng.layout.ring_cap >= (4 - 1) * 2 + 1
    params = model.init(jax.random.PRNGKey(2))
    prompts = [RNG.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
               for L in (11, 17)]
    refs = _lockstep_refs(model, params, prompts, 10)
    rids = [eng.submit(p, 10) for p in prompts]
    results = eng.run(params)
    for rid, ref in zip(rids, refs):
        np.testing.assert_array_equal(results[rid], ref)


def test_paged_kernel_decode_impl_parity():
    """The whole engine run with decode_impl=pallas_interpret (the paged
    kernel, page tables scalar-prefetched) matches the XLA gather twin."""
    cfg = get_smoke("smollm-135m")
    prompts = [RNG.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
               for L in (7, 12)]
    outs = {}
    for impl in ("xla", "pallas_interpret"):
        model, eng = _engine(cfg, chunk=8, max_batch=2, decode_impl=impl)
        params = model.init(jax.random.PRNGKey(3))
        rids = [eng.submit(p, 6) for p in prompts]
        outs[impl] = [eng.run(params)[r] for r in rids]
    for a, b in zip(outs["xla"], outs["pallas_interpret"]):
        np.testing.assert_array_equal(a, b)


# ===================== chunked prefill contract ======================== #
def test_chunked_prefill_launch_count_and_cache_state():
    """A P-token prompt prefills in exactly ceil(P/chunk) fused launches
    (counted, not estimated), and the resulting slab state — KV values AND
    per-slot positions — matches the token-by-token lockstep prefill."""
    cfg = get_smoke("smollm-135m")
    chunk, page = 8, 8
    model, eng = _engine(cfg, chunk=chunk, page=page)
    params = model.init(jax.random.PRNGKey(4))
    P = 27
    prompt = RNG.integers(0, cfg.vocab_size, (P,)).astype(np.int32)
    eng.submit(prompt, 5)
    eng._admit()
    req = eng.batcher.rows[0]
    while req.state == "prefill":
        eng._advance_prefill(params, req)
    assert eng.counters["prefill_launches"] == math.ceil(P / chunk)

    # token-by-token reference: the lockstep engine's prefill cache
    lock = ServeEngine(model, ServeConfig(max_len=P + 5))
    cache, last_logits = lock.prefill(params, jnp.asarray(prompt)[None])

    lay = eng.layout
    slot_pos = np.asarray(eng.slot_pos[req.row])
    expect_pos = ring_view_positions(P, lay.n_sink, lay.ring_cap,
                                     lay.n_global)
    np.testing.assert_array_equal(slot_pos, expect_pos)
    key = "seg0_attn_mlp"
    slab = eng.slabs[key]
    ref_k = np.asarray(cache[key]["k"])      # (L, 1, max_len, Hkv, hd)
    ref_v = np.asarray(cache[key]["v"])
    live = np.nonzero(slot_pos < BIG)[0]
    assert live.size == min(P, lay.n_global) + min(
        max(P - lay.n_global, 0), lay.ring_cap)
    for s in live:
        p = int(slot_pos[s])
        phys, off = int(req.pages[s // page]), s % page
        np.testing.assert_allclose(
            np.asarray(slab.k[:, phys, off]), ref_k[:, 0, p],
            rtol=1e-5, atol=1e-5, err_msg=f"k slot {s} pos {p}")
        np.testing.assert_allclose(
            np.asarray(slab.v[:, phys, off]), ref_v[:, 0, p],
            rtol=1e-5, atol=1e-5, err_msg=f"v slot {s} pos {p}")
    # and the first sampled token agrees with the lockstep prefill logits
    assert req.out[0] == int(np.argmax(np.asarray(last_logits[0])))


def test_chunk_attention_matches_dense_prefix():
    """chunk_attention over the [sink|ring|chunk] view == rows [c0, c1) of
    the dense oracle over the full prefix, including ring wraparound."""
    from repro.core.blockwise import chunk_attention
    from repro.kernels.ref import reference_attention

    pat = P.causal_sliding_window(6, n_sinks=2)
    block, n_sink, ring_cap = 4, 4, 8
    c0, clen = 17, 5
    c1 = c0 + clen
    D, B = 16, 3
    kf = jnp.asarray(RNG.normal(size=(B, c1, D)), jnp.float32)
    vf = jnp.asarray(RNG.normal(size=(B, c1, D)), jnp.float32)
    qf = jnp.asarray(RNG.normal(size=(B, c1, D)), jnp.float32)
    ref = reference_attention(qf, kf, vf, pat)[:, c0:c1]

    plan = build_chunk_plan(pat, c0, clen, n_sink=n_sink, ring_cap=ring_cap,
                            block=block)
    vpos = plan.view_positions
    ctx = n_sink + ring_cap
    # scatter the prefix KV into the static slot layout
    k_view = np.zeros((B, plan.view_len, D), np.float32)
    v_view = np.zeros((B, plan.view_len, D), np.float32)
    for s in range(plan.view_len):
        if vpos[s] < BIG:
            k_view[:, s] = np.asarray(kf[:, vpos[s]])
            v_view[:, s] = np.asarray(vf[:, vpos[s]])
    pos_q = np.full(plan.chunk_pad, BIG, np.int32)
    pos_q[:clen] = np.arange(c0, c1)
    q = np.zeros((B, plan.chunk_pad, D), np.float32)
    q[:, :clen] = np.asarray(qf[:, c0:c1])
    out = chunk_attention(
        jnp.asarray(q), jnp.asarray(k_view), jnp.asarray(v_view),
        jnp.broadcast_to(jnp.asarray(pos_q), (B, plan.chunk_pad)),
        jnp.broadcast_to(jnp.asarray(vpos), (B, plan.view_len)),
        jnp.asarray(plan.kv_blocks), jnp.asarray(plan.flags), pat)
    np.testing.assert_allclose(np.asarray(out[:, :clen]), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_chunk_plan_prunes_and_covers():
    """Tables stay within the view, carry sink tiles only when sinks exist,
    and the first chunk of an empty cache visits only chunk tiles."""
    pat = P.causal_sliding_window(6, n_sinks=2)
    first = build_chunk_plan(pat, 0, 8, n_sink=4, ring_cap=8, block=4)
    live_tiles = set(first.kv_blocks[first.flags > 0].tolist())
    assert all(t >= (4 + 8) // 4 for t in live_tiles), live_tiles
    later = build_chunk_plan(pat, 16, 8, n_sink=4, ring_cap=8, block=4)
    assert (later.num_steps > first.num_steps).any()
    # static view positions: ring slot holds the latest pre-chunk position
    vpos = ring_view_positions(16, 4, 8, 2)
    live = vpos[vpos < BIG]
    assert set(live.tolist()) >= set(range(8, 16))  # full lookback present


# ===================== scheduler / allocator =========================== #
def test_page_recycling_admits_waves():
    """More requests than rows AND pages: later requests wait, admitted as
    completions recycle pages; everything completes and matches lockstep."""
    cfg = get_smoke("smollm-135m")
    model, eng = _engine(cfg, chunk=8, max_batch=2)  # pool fits 2 requests
    params = model.init(jax.random.PRNGKey(5))
    prompts = [RNG.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
               for L in (5, 11, 7, 9, 6)]
    refs = _lockstep_refs(model, params, prompts, 4)
    rids = [eng.submit(p, 4) for p in prompts]
    results = eng.run(params)
    assert len(results) == len(prompts)
    for rid, ref in zip(rids, refs):
        np.testing.assert_array_equal(results[rid], ref)
    # pool fully recycled
    assert eng.batcher.alloc.n_free == eng.ccfg.n_pages - 1


def test_allocator_contract():
    alloc = PageAllocator(6)
    a = alloc.alloc(3)
    assert alloc.n_free == 2 and 0 not in a.tolist()
    with pytest.raises(RuntimeError):
        alloc.alloc(3)
    alloc.release(a)
    assert alloc.n_free == 5
    with pytest.raises(AssertionError):
        alloc.release(a[:1])  # double free

def test_pool_too_small_rejected_at_submit():
    """A request whose footprint can NEVER fit the pool is rejected at
    submit with a sizing message — the old behavior (accepted, then a
    drain-time 'page pool too small' RuntimeError) is gone; requests whose
    actual span fits a small pool now run (tests/test_serve_ft.py)."""
    from repro.ft.faults import RejectedRequest
    cfg = get_smoke("smollm-135m")
    model = build_model(cfg)
    lay = PagedLayout(page=8, window=cfg.salo.window,
                      n_global=cfg.salo.n_global)
    eng = ContinuousEngine(model, ContinuousConfig(
        n_pages=lay.pages_per_req, page=8, chunk=8, max_batch=1))
    with pytest.raises(RejectedRequest, match="can never fit"):
        eng.submit(np.arange(40, dtype=np.int32) + 1, 8)


def test_unsupported_programs_rejected():
    cfg = get_smoke("mamba2-370m")
    with pytest.raises(NotImplementedError):
        ContinuousEngine(build_model(cfg),
                         ContinuousConfig(n_pages=8, page=8))
