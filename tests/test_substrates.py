"""Substrate tests: optimizer, schedule, data pipeline, compression,
checkpointing, fault-tolerance manager, serve engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw
from repro.optim.schedule import Schedule


# ------------------------------ optimizer ------------------------------- #
def test_adamw_quadratic_convergence():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw.init(cfg, params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum((p["w"] - 1.0) ** 2))(params)
        params, state, _ = adamw.update(cfg, state, params, grads)
    np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 1.0],
                               atol=1e-2)


def test_adamw_bf16_moments_still_converge():
    cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0,
                            moment_dtype="bfloat16")
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw.init(cfg, params)
    assert state.m["w"].dtype == jnp.bfloat16
    for _ in range(300):
        grads = jax.grad(lambda p: jnp.sum((p["w"] - 1.0) ** 2))(params)
        params, state, _ = adamw.update(cfg, state, params, grads)
    np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 1.0],
                               atol=5e-2)


def test_grad_clip():
    g = {"a": jnp.asarray([3.0, 4.0])}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-5
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8],
                               rtol=1e-5)


def test_schedule_shapes():
    s = Schedule(warmup_steps=10, total_steps=100, kind="cosine",
                 min_ratio=0.1)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 1e-6
    assert float(s(100)) == pytest.approx(0.1, abs=1e-3)
    assert float(s(55)) < 1.0


# ------------------------------ data ------------------------------------ #
def test_data_deterministic_and_host_sharded():
    from repro.configs import get_smoke
    from repro.data.pipeline import DataConfig, SyntheticLM
    cfg = get_smoke("smollm-135m")
    d = DataConfig(seq_len=32, global_batch=8)
    a = SyntheticLM(cfg, d, host_id=0, n_hosts=2)
    b = SyntheticLM(cfg, d, host_id=1, n_hosts=2)
    a1, a2 = a.batch(3), a.batch(3)
    np.testing.assert_array_equal(a1["tokens"], a2["tokens"])  # resumable
    assert a1["tokens"].shape == (4, 32)
    assert not np.array_equal(a1["tokens"], b.batch(3)["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a1["tokens"][:, 1:], a1["labels"][:, :-1])


def test_data_learnable_structure():
    """Markov structure: unigram entropy over successors is bounded."""
    from repro.configs import get_smoke
    from repro.data.pipeline import DataConfig, SyntheticLM
    cfg = get_smoke("smollm-135m")
    ds = SyntheticLM(cfg, DataConfig(seq_len=256, global_batch=4, branch=4))
    b = ds.batch(0)
    # successors of any state are limited to `branch` values per doc
    toks = b["tokens"][0]
    succ = {}
    for x, y in zip(toks[:-1], toks[1:]):
        succ.setdefault(int(x), set()).add(int(y))
    avg_branch = np.mean([len(v) for v in succ.values()])
    assert avg_branch <= 4.5


# --------------------------- compression -------------------------------- #
def test_int8_error_feedback_unbiased():
    """With error feedback, the ACCUMULATED update converges to the true
    accumulated gradient (bias cancels across steps)."""
    from repro.dist.compression import compress_decompress
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    ef = None
    total = jnp.zeros(64)
    for _ in range(50):
        out, ef = compress_decompress({"g": g_true}, ef)
        total = total + out["g"]
    np.testing.assert_allclose(np.asarray(total / 50), np.asarray(g_true),
                               atol=2e-2)


def test_int8_without_ef_is_lossy_but_bounded():
    from repro.dist.compression import _q8, _dq
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(128,)), jnp.float32)
    q, s = _q8(x)
    err = float(jnp.max(jnp.abs(_dq(q, s) - x)))
    assert err <= float(s) * 0.5 + 1e-6


# --------------------------- checkpointing ------------------------------- #
def test_checkpoint_roundtrip(tmp_path):
    from repro.ft import checkpoint as ck
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones(4, jnp.bfloat16)}}
    ck.save(str(tmp_path), tree, 7)
    assert ck.latest_step(str(tmp_path)) == 7
    restored = ck.restore(str(tmp_path), tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_manager_keepk_and_async(tmp_path):
    from repro.ft.checkpoint import CheckpointManager
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=True)
    for s in (10, 20, 30, 40):
        mgr.save({"x": jnp.full((3,), s)}, s)
    mgr.wait()
    steps = sorted(os.listdir(tmp_path))
    assert steps == ["step_00000030", "step_00000040"]
    restored, step = mgr.restore_latest({"x": jnp.zeros(3)})
    assert step == 40
    np.testing.assert_array_equal(np.asarray(restored["x"]), [40, 40, 40])


def test_run_with_restarts_recovers(tmp_path):
    """Injected failures: training resumes from the last checkpoint and
    reaches the target step count with no lost progress beyond the
    checkpoint interval."""
    from repro.ft.checkpoint import CheckpointManager
    from repro.ft.manager import StragglerWatchdog, run_with_restarts
    mgr = CheckpointManager(str(tmp_path), keep=3, async_write=False)

    def step_fn(state, step):
        return {"x": state["x"] + 1}

    state0 = {"x": jnp.zeros(())}
    final, hist = run_with_restarts(
        step_fn, state0, n_steps=20, manager=mgr, checkpoint_every=5,
        fail_at={7, 13}, watchdog=StragglerWatchdog())
    assert hist["restarts"] == 2
    assert float(final["x"]) == 20.0


def test_straggler_watchdog_flags_outlier():
    from repro.ft.manager import StragglerWatchdog
    wd = StragglerWatchdog(threshold=3.0, warmup_steps=0)
    flagged = [wd.observe(t) for t in [1.0, 1.1, 0.9, 1.0, 10.0, 1.0]]
    assert flagged == [False, False, False, False, True, False]
    assert wd.events == 1


def test_elastic_reshard_checkpoint(tmp_path):
    """A checkpoint restores onto a different device layout (1 device here;
    the multi-device elastic path is exercised in test_distributed.py)."""
    from repro.ft import checkpoint as ck
    from jax.sharding import NamedSharding, PartitionSpec as P
    tree = {"w": jnp.arange(8, dtype=jnp.float32)}
    ck.save(str(tmp_path), tree, 1)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data"))}
    restored = ck.restore(str(tmp_path), tree, shardings=sh)
    assert restored["w"].sharding == sh["w"]


# ------------------------------ serving --------------------------------- #
def test_serve_engine_greedy_generation():
    from repro.configs import get_smoke
    from repro.models.model import build_model
    from repro.serve.engine import ServeConfig, ServeEngine
    cfg = get_smoke("smollm-135m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, ServeConfig(max_len=32))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)))
    toks = eng.generate(params, prompts, n_new=6)
    assert toks.shape == (2, 6)
    assert bool(jnp.all((toks >= 0) & (toks < cfg.vocab_size)))
    # greedy decode is deterministic
    toks2 = eng.generate(params, prompts, n_new=6)
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(toks2))


# --------------------------- train step ---------------------------------- #
def test_train_step_decreases_loss():
    from repro.configs import get_smoke
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.models.model import build_model
    from repro.train.trainer import TrainConfig, make_train_step
    cfg = get_smoke("smollm-135m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tcfg = TrainConfig(optimizer=adamw.AdamWConfig(lr=1e-2, grad_clip=1.0),
                       schedule=Schedule(warmup_steps=5, total_steps=100))
    step = jax.jit(make_train_step(model, tcfg))
    opt = adamw.init(tcfg.optimizer, params)
    ds = SyntheticLM(cfg, DataConfig(seq_len=64, global_batch=8))
    losses = []
    ef = None
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(i % 4).items()}
        params, opt, metrics, ef = step(params, opt, batch, ef)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::6]
    assert ef is None  # no compression -> no error-feedback state


def test_train_step_microbatch_equivalence():
    """mb=2 grad accumulation == mb=1 on the same batch (to tolerance)."""
    from repro.configs import get_smoke
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.models.model import build_model
    from repro.train.trainer import TrainConfig, make_train_step
    cfg = get_smoke("smollm-135m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ds = SyntheticLM(cfg, DataConfig(seq_len=32, global_batch=4))
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
    outs = {}
    for mb in (1, 2):
        tcfg = TrainConfig(optimizer=adamw.AdamWConfig(lr=1e-3),
                           microbatches=mb)
        step = make_train_step(model, tcfg)
        opt = adamw.init(tcfg.optimizer, params)
        p2, _, m, _ef = step(params, opt, batch)
        outs[mb] = p2
    flat1 = jax.tree.leaves(outs[1])
    flat2 = jax.tree.leaves(outs[2])
    for a, b in zip(flat1, flat2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-4)


def test_microbatch_metrics_averaged_and_grad_dtype():
    """mb > 1 aux metrics are the MEAN across microbatches (the old code
    reported only the last microbatch's), and both mb paths hand the
    optimizer f32 grads (the mb==1 path used to pass param-dtype)."""
    from repro.configs import get_smoke
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.models.model import build_model
    from repro.train import trainer as trmod
    from repro.train.trainer import TrainConfig, make_train_step
    cfg = get_smoke("smollm-135m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ds = SyntheticLM(cfg, DataConfig(seq_len=32, global_batch=4))
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
    mb = 4

    # per-microbatch reference nll (each slice through model.loss directly)
    per = []
    for i in range(mb):
        mbatch = {k: v[i: i + 1] for k, v in batch.items()}
        _, m = model.loss(params, mbatch)
        per.append(float(m["nll"]))

    seen = {}
    orig = adamw.update

    def spy(cfg_, state, params_, grads, lr):
        seen["dtypes"] = set(g.dtype for g in jax.tree.leaves(grads))
        return orig(cfg_, state, params_, grads, lr)

    trmod.adamw.update = spy
    try:
        for mbs in (1, mb):
            tcfg = TrainConfig(optimizer=adamw.AdamWConfig(lr=1e-3),
                               microbatches=mbs)
            step = make_train_step(model, tcfg)
            opt = adamw.init(tcfg.optimizer, params)
            _, _, metrics, _ = step(params, opt, batch)
            assert seen["dtypes"] == {jnp.dtype(jnp.float32)}, \
                (mbs, seen["dtypes"])
        np.testing.assert_allclose(float(metrics["nll"]),
                                   np.mean(per), rtol=1e-5)
    finally:
        trmod.adamw.update = orig


def test_compress_grads_single_device_ef_threading():
    """compress_grads on one device: local quantize-dequantize + error
    feedback, ef_state threaded through the fixed 4-tuple arity (the old
    3-vs-4-tuple switch broke donate_argnums callers)."""
    from repro.configs import get_smoke
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.models.model import build_model
    from repro.train.trainer import TrainConfig, make_train_step
    cfg = get_smoke("smollm-135m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tcfg = TrainConfig(optimizer=adamw.AdamWConfig(lr=1e-2, grad_clip=1.0),
                       compress_grads=True)
    step = make_train_step(model, tcfg)
    opt = adamw.init(tcfg.optimizer, params)
    ds = SyntheticLM(cfg, DataConfig(seq_len=64, global_batch=8))
    ef, losses = None, []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(i % 4).items()}
        params, opt, metrics, ef = step(params, opt, batch, ef)
        losses.append(float(metrics["loss"]))
    assert ef is not None
    assert jax.tree.structure(ef) == jax.tree.structure(params)
    assert losses[-1] < losses[0] - 0.5, losses[::6]
