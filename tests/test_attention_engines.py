"""Engine equivalence: blockwise / Pallas-interpret vs the dense oracle,
swept over patterns, shapes, dtypes, and block sizes (the per-kernel
allclose requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import patterns as P
from repro.core.attention import hybrid_attention
from repro.core.blockwise import blockwise_attention, decode_attention
from repro.kernels.ref import reference_attention
from repro.kernels.ops import salo_attention

RNG = np.random.default_rng(42)

PATTERNS = [
    ("causal_sw", P.causal_sliding_window(16)),
    ("causal_sw_sinks", P.causal_sliding_window(16, n_sinks=4)),
    ("longformer", P.longformer(32, n_global=2)),
    ("longformer_causal", P.longformer(32, n_global=2, causal=True)),
    ("dilated", P.dilated_window(8, 3)),
    ("dilated_causal", P.dilated_window(8, 3, causal=True)),
    ("dilated_sinks", P.causal_sliding_window(8, n_sinks=2, dilation=2)),
    ("vil_2d", P.vil((8, 9), (3, 5), n_global=2)),
    ("full_causal", P.full(causal=True)),
    ("asym", P.HybridSparsePattern(window=(-5, 3), n_global=3)),
]


def _qkv(n, d, dtype=jnp.float32, b=2):
    return tuple(jnp.asarray(RNG.normal(size=(b, n, d)), dtype)
                 for _ in range(3))


def _n_for(pat, default):
    return pat.seq_len() or default


@pytest.mark.parametrize("name,pat", PATTERNS)
def test_blockwise_matches_oracle(name, pat):
    n = _n_for(pat, 100)
    q, k, v = _qkv(n, 32)
    ref = reference_attention(q, k, v, pat)
    out = blockwise_attention(q, k, v, pat, block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("name,pat", PATTERNS)
def test_pallas_interpret_matches_oracle(name, pat):
    n = _n_for(pat, 100)
    q, k, v = _qkv(n, 32)
    ref = reference_attention(q, k, v, pat)
    out = salo_attention(q, k, v, pat, 32, 32, None, True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("bq,bk", [(16, 16), (16, 64), (64, 16), (128, 128)])
def test_block_size_sweep(bq, bk):
    """Window splitting is exact for ANY tile geometry (paper Eq. 2)."""
    pat = P.causal_sliding_window(24, n_sinks=2)
    q, k, v = _qkv(200, 16)
    ref = reference_attention(q, k, v, pat)
    for impl in ("blockwise",):
        out = blockwise_attention(q, k, v, pat, block_q=bq, block_k=bk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3, err_msg=impl)
    out = salo_attention(q, k, v, pat, bq, bk, None, True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-3),
                                       (jnp.bfloat16, 4e-2)])
@pytest.mark.parametrize("d", [16, 64, 128, 256])
def test_dtype_headdim_sweep(dtype, tol, d):
    pat = P.causal_sliding_window(16, n_sinks=2)
    q, k, v = _qkv(64, d, dtype)
    ref = reference_attention(q, k, v, pat)
    out = salo_attention(q, k, v, pat, 32, 32, None, True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_gqa_head_repeat():
    pat = P.causal_sliding_window(16)
    B, H, Hkv, N, D = 2, 8, 2, 64, 16
    q = jnp.asarray(RNG.normal(size=(B, H, N, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, N, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, N, D)), jnp.float32)
    out = hybrid_attention(q, k, v, pat)
    kr = jnp.repeat(k, H // Hkv, axis=1)
    vr = jnp.repeat(v, H // Hkv, axis=1)
    ref = hybrid_attention(q, kr, vr, pat, impl="dense_ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_decode_matches_full_forward_rows():
    """Decode step at position t == row t of the full-sequence attention."""
    pat = P.causal_sliding_window(12, n_sinks=2)
    n, d = 80, 16
    q, k, v = _qkv(n, d)
    full = reference_attention(q, k, v, pat)
    for t in (0, 5, 13, 79):
        out = decode_attention(q[:, t:t + 1], k, v, t, pat)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(full[:, t:t + 1]),
                                   rtol=2e-3, atol=2e-3, err_msg=str(t))


def test_ring_cache_decode_equivalence():
    """SALO ring cache (w+g slots) == full cache decode for the same pattern."""
    from repro.serve.kv_cache import (ring_init, ring_update,
                                      ring_positions_mask)
    w_, g = 8, 2
    pat = P.causal_sliding_window(w_, n_sinks=g)
    n, d, B = 40, 8, 2
    q, k, v = _qkv(n, d, b=B)
    cache = ring_init(B, w_, g, 1, d, jnp.float32)
    for t in range(n):
        cache = ring_update(cache, k[:, t:t + 1, None, :],
                            v[:, t:t + 1, None, :], t, w_, g)
        out_ring = decode_attention(
            q[:, t:t + 1], cache.k[:, :, 0], cache.v[:, :, 0], t, pat,
            cache_positions=ring_positions_mask(cache))
        out_full = decode_attention(q[:, t:t + 1], k[:, :t + 1],
                                    v[:, :t + 1], t, pat)
        np.testing.assert_allclose(np.asarray(out_ring),
                                   np.asarray(out_full),
                                   rtol=2e-3, atol=2e-3, err_msg=str(t))


def test_gradients_blockwise_vs_oracle():
    pat = P.causal_sliding_window(16, n_sinks=2)
    q, k, v = _qkv(64, 16)

    def loss_ref(q_, k_, v_):
        return jnp.sum(reference_attention(q_, k_, v_, pat) ** 2)

    def loss_blk(q_, k_, v_):
        return jnp.sum(blockwise_attention(q_, k_, v_, pat, block_q=32,
                                           block_k=32) ** 2)

    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    g_blk = jax.grad(loss_blk, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_ref, g_blk):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=5e-3, atol=5e-3)


def test_dynamic_q8_roundtrip_per_tensor():
    """Per-tensor dynamic int8: round-trip error bounded by scale/2 (one
    rounding step) and the max-magnitude element is exactly representable."""
    from repro.core.quant import dequant, dynamic_q8
    x = jnp.asarray(RNG.normal(size=(4, 33, 7)) * 3.0, jnp.float32)
    q, scale = dynamic_q8(x)
    assert q.dtype == jnp.int8 and scale.ndim == 0
    back = dequant(q, scale)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                               atol=float(scale) / 2 + 1e-7)
    amax_idx = np.unravel_index(np.argmax(np.abs(np.asarray(x))), x.shape)
    assert abs(int(q[amax_idx])) == 127


def test_dynamic_q8_roundtrip_grouped_axis():
    """axis=reduced axes: one scale per remaining-axis group, each group's
    round-trip bounded by ITS scale (not the global amax)."""
    from repro.core.quant import dequant, dynamic_q8
    x = np.asarray(RNG.normal(size=(5, 16, 8)), np.float32)
    x[0] *= 100.0   # wildly different group magnitudes
    x[1] *= 0.01
    q, scale = dynamic_q8(jnp.asarray(x), axis=(1, 2))
    assert scale.shape == (5, 1, 1)
    back = np.asarray(dequant(q, scale))
    for g in range(5):
        bound = float(np.asarray(scale)[g, 0, 0]) / 2 + 1e-7
        assert np.max(np.abs(back[g] - x[g])) <= bound


def test_dynamic_q8_all_zero_and_denormal():
    """All-zero input survives (1e-8 amax floor, no div-by-zero NaNs) and
    denormal-range inputs quantize to finite values."""
    from repro.core.quant import dequant, dynamic_q8
    q, scale = dynamic_q8(jnp.zeros((3, 4)))
    assert float(scale) > 0.0 and not np.any(np.asarray(q))
    assert not np.any(np.isnan(np.asarray(dequant(q, scale))))
    tiny = jnp.full((2, 2), 1e-12, jnp.float32)  # below the 1e-8 floor
    q, scale = dynamic_q8(tiny)
    back = np.asarray(dequant(q, scale))
    assert np.all(np.isfinite(back)) and np.max(np.abs(back)) <= 1e-8


def test_group_q8_roundtrip_matches_page_layout():
    """group_q8 over the slab layout (L, P, page, Hkv, hd) with
    n_group_axes=2: one scale per (layer, page), group-wise round-trip
    bound, and group_dequant inverts to the requested dtype."""
    from repro.core.quant import group_dequant, group_q8
    x = jnp.asarray(RNG.normal(size=(2, 3, 4, 2, 8)), jnp.float32)
    q, scale = group_q8(x, 2)
    assert q.shape == x.shape and scale.shape == (2, 3)
    back = group_dequant(q, scale, dtype=jnp.bfloat16)
    assert back.dtype == jnp.bfloat16
    err = np.abs(np.asarray(back, np.float32) - np.asarray(x))
    bound = np.asarray(scale)[:, :, None, None, None] / 2 + 0.05
    assert np.all(err <= bound)


def test_quantized_attention_error_small():
    """Paper §6.4: int8(4-frac) QKV quantization has small output error."""
    from repro.core.quant import quantized_attention
    pat = P.longformer(32, n_global=1)
    q, k, v = _qkv(128, 32)
    q, k, v = q * 0.5, k * 0.5, v * 0.5  # typical activation scale
    ref = hybrid_attention(q[:, None], k[:, None], v[:, None], pat)[:, 0]
    out = quantized_attention(q[:, None], k[:, None], v[:, None],
                              pat, mode="fixed")[:, 0]
    err = float(jnp.mean(jnp.abs(out - ref)))
    assert err < 0.05, err
