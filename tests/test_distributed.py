"""Multi-device tests (8 forced host devices, run in a subprocess so the
rest of the suite keeps its single-device view):
  * sequence-parallel SALO attention == single-device oracle
  * pjit'd train step runs under a (2, 4) mesh with the production rules
  * elastic rescale: checkpoint from mesh A restores onto mesh B
  * int8-compressed gradient psum convergence
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(body: str):
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
    """) + textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", prog],
                       env={**os.environ, "PYTHONPATH": SRC},
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sequence_parallel_attention_matches_oracle():
    _run("""
        from repro.core import patterns as P_
        from repro.core.distributed import sequence_parallel_attention
        from repro.kernels.ref import reference_attention
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        B, N, D = 2, 128, 16
        q, k, v = (jnp.asarray(rng.normal(size=(B, N, D)), jnp.float32)
                   for _ in range(3))
        for pat in (P_.causal_sliding_window(12, n_sinks=3),
                    P_.longformer(8, n_global=2),
                    P_.causal_sliding_window(16)):
            ref = reference_attention(q, k, v, pat)
            with mesh:
                out = jax.jit(lambda a, b, c: sequence_parallel_attention(
                    a, b, c, pat, mesh))(q, k, v)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=2e-3, atol=2e-3)
        print("SP-ATTN-OK")
    """)


def test_pjit_train_step_under_mesh():
    _run("""
        from repro.configs import get_smoke
        from repro.configs.base import ShapeCell
        from repro.launch.specs import build_cell
        import dataclasses
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_smoke("smollm-135m")
        shape = ShapeCell("t", 64, 4, "train")
        fn, args, in_sh, out_sh, rules = build_cell(cfg, shape, mesh)
        from repro.models.model import build_model
        from repro.optim import adamw
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        tcfg_opt = adamw.AdamWConfig()
        opt = adamw.init(tcfg_opt, params)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 64))),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 64)))}
        with mesh:
            params = jax.device_put(params, in_sh[0])
            opt = jax.device_put(opt, jax.tree.map(lambda s: s, in_sh[1],
                                 is_leaf=lambda x: hasattr(x, "spec")))
            step = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
            p2, o2, metrics = step(params, opt, batch)
        assert np.isfinite(float(metrics["loss"]))
        print("PJIT-TRAIN-OK", float(metrics["loss"]))
    """)


def test_elastic_rescale_8_to_4():
    _run("""
        import tempfile
        from repro.ft import checkpoint as ck
        tree = {"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}
        mesh8 = jax.make_mesh((8,), ("data",))
        sh8 = {"w": NamedSharding(mesh8, P("data", None))}
        placed = jax.device_put(tree, sh8)
        d = tempfile.mkdtemp()
        ck.save(d, placed, 1)
        # restore onto a 4-device mesh (elastic shrink)
        devs = jax.devices()[:4]
        import numpy as _np
        mesh4 = jax.sharding.Mesh(_np.array(devs), ("data",))
        sh4 = {"w": NamedSharding(mesh4, P("data", None))}
        restored = ck.restore(d, tree, shardings=sh4)
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))
        assert restored["w"].sharding.num_devices == 4
        print("ELASTIC-OK")
    """)


def test_compressed_psum_across_shards():
    _run("""
        from repro.compat import shard_map
        from repro.dist.compression import compressed_psum
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)
        def f(x):
            return compressed_psum(x[0], "data")[None]
        with mesh:
            out = jax.jit(shard_map(f, mesh=mesh, in_specs=(P("data", None),),
                                    out_specs=P("data", None)))(g)
        ref = jnp.sum(g, axis=0)
        rel = float(jnp.max(jnp.abs(out[0] - ref)) / jnp.max(jnp.abs(ref)))
        assert rel < 0.05, rel
        print("COMPRESSED-PSUM-OK", rel)
    """)


def test_multipod_mesh_shape():
    _run("""
        # 8 devices reshaped as a miniature (pod, data, model) mesh to prove
        # the 3-axis sharding rules compose (full 512-chip version runs in
        # the dry-run).
        from repro.configs import get_smoke
        from repro.configs.base import ShapeCell
        from repro.launch.specs import build_cell
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = get_smoke("arctic-480b")  # MoE: exercises EP rules too
        shape = ShapeCell("t", 64, 4, "train")
        fn, args, in_sh, out_sh, rules = build_cell(cfg, shape, mesh)
        with mesh:
            lowered = jax.jit(fn, in_shardings=in_sh,
                              out_shardings=out_sh).lower(*args)
            compiled = lowered.compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):  # older jax: dict per device
            cost = cost[0]
        assert cost.get("flops", 0) > 0
        print("MULTIPOD-SMOKE-OK")
    """)
