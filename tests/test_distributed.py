"""Multi-device tests (8 forced host devices, run in a subprocess so the
rest of the suite keeps its single-device view):
  * ShardedPlan sequence-parallel attention: fwd + bwd parity vs the
    single-device fused path across every supported pattern family
    (longformer bidirectional + global rows, dilated/reordered-global,
    ViL 2-D multi-band, window == n_local boundary, g > n_local), with
    both shard-local engines (XLA scan twin and the Pallas table kernels)
  * a model forward under live "seq" rules takes the sharded route and
    matches the unsharded logits
  * the retired sequence_parallel_attention entry point still answers
    (now a shim over the ShardedPlan engine)
  * input_sharding drops absent / non-dividing mesh axes (_mesh_clean)
  * pjit'd train step runs under a (2, 4) mesh with the production rules
  * elastic rescale: checkpoint from mesh A restores onto mesh B
  * int8-compressed gradient psum convergence
  * compress_grads wires compressed_psum into the pod/data reduce INSIDE
    train_step (shard_map), error feedback converging on the int8 wire
  * sequence-parallel continuous serving: the 8-shard engine (sharded
    paged slab + distributed ragged decode) emits greedy tokens identical
    to the single-device ContinuousEngine across ragged batches, page
    recycling, ring wraparound across shard boundaries, dilation > 1, and
    the paged decode kernel inside shard_map
"""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(body: str):
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
    """) + textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", prog],
                       env={**os.environ, "PYTHONPATH": SRC},
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sequence_parallel_attention_matches_oracle():
    """sharded_attention keeps the retired prototype's contract on the
    patterns the prototype supported (its shim was deleted — this is the
    direct entry point)."""
    _run("""
        from repro.core import patterns as P_
        from repro.dist.sharded_plan import sharded_attention
        from repro.kernels.ref import reference_attention
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        B, N, D = 2, 128, 16
        q, k, v = (jnp.asarray(rng.normal(size=(B, N, D)), jnp.float32)
                   for _ in range(3))
        for pat in (P_.causal_sliding_window(12, n_sinks=3),
                    P_.longformer(8, n_global=2),
                    P_.causal_sliding_window(16)):
            ref = reference_attention(q, k, v, pat)
            with mesh:
                out = jax.jit(lambda a, b, c: sharded_attention(
                    a, b, c, pat, mesh, "data"))(q, k, v)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=2e-3, atol=2e-3)
        print("SP-ATTN-OK")
    """)


# --------------------- ShardedPlan fwd + bwd parity --------------------- #
_PARITY_PRELUDE = """
        from repro.core import patterns as P_
        from repro.core.blockwise import blockwise_attention
        from repro.dist.sharded_plan import sharded_attention
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)

        def check(name, pat, N, impl):
            B, D = 2, 16
            q, k, v, cot = (jnp.asarray(rng.normal(size=(B, N, D)), jnp.float32)
                            for _ in range(4))
            # single-device fused-path twin (same plan IR, same backward)
            ref = blockwise_attention(q, k, v, pat, block_q=16, block_k=16)
            g_ref = jax.grad(lambda a, b, c: jnp.sum(blockwise_attention(
                a, b, c, pat, block_q=16, block_k=16) * cot),
                argnums=(0, 1, 2))(q, k, v)
            with mesh:
                out = jax.jit(lambda a, b, c: sharded_attention(
                    a, b, c, pat, mesh, impl=impl))(q, k, v)
                g = jax.jit(jax.grad(lambda a, b, c: jnp.sum(sharded_attention(
                    a, b, c, pat, mesh, impl=impl) * cot),
                    argnums=(0, 1, 2)))(q, k, v)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=1e-4, atol=1e-4, err_msg=name)
            for gname, a, b in zip("qkv", g_ref, g):
                np.testing.assert_allclose(
                    np.asarray(b), np.asarray(a), rtol=1e-4, atol=1e-4,
                    err_msg=f"{name}: d{gname}")
            print("ok", name, impl)

"""

_PARITY_RUN = """
        for case in CASES:
            check(*case)
        print("SHARDED-PARITY-OK")
"""


def test_sharded_plan_parity_pattern_families():
    """Sharded fwd+bwd == single-device fused path across the supported
    families: longformer (bidirectional window + global rows => both-side
    halos + psum merge), dilated (data reordering), reordered-global
    (dilated sinks), ViL 2-D multi-band, and the window == n_local
    boundary."""
    _run(_PARITY_PRELUDE + """
        CASES = [
            ("longformer", P_.longformer(8, n_global=2), 128, "blockwise"),
            ("longformer_causal",
             P_.longformer(8, n_global=2, causal=True), 128, "blockwise"),
            ("dilated", P_.dilated_window(4, 3), 128, "blockwise"),
            ("reordered_global",
             P_.causal_sliding_window(5, n_sinks=2, dilation=2), 128,
             "blockwise"),
            ("vil_2d", P_.vil((16, 16), (5, 5), 1), 257, "blockwise"),
            ("window_eq_nlocal", P_.causal_sliding_window(16), 128,
             "blockwise"),
        ]
    """ + _PARITY_RUN)


def test_sharded_plan_parity_pallas_engine():
    """The fused Pallas kernels (table-driven entry points, interpret mode
    on CPU) execute inside shard_map with the same parity."""
    _run(_PARITY_PRELUDE + """
        CASES = [
            ("sinks_pallas", P_.causal_sliding_window(12, n_sinks=3), 128,
             "pallas_interpret"),
            ("vil_pallas", P_.vil((8, 9), (3, 5), 1), 73,
             "pallas_interpret"),
            ("longformer_pallas", P_.longformer(8, n_global=2), 128,
             "pallas_interpret"),
        ]
    """ + _PARITY_RUN)


def test_sharded_plan_global_exceeds_shard():
    """Regression for the retired prototype's silent truncation: with
    g > N // n_shards the global prefix spans multiple shards; the
    owner-keyed psum broadcast must still deliver every global tile."""
    _run(_PARITY_PRELUDE + """
        CASES = [
            ("g_gt_nlocal", P_.causal_sliding_window(8, n_sinks=24), 128,
             "blockwise"),
            ("g_gt_nlocal_rows", P_.longformer(8, n_global=24), 128,
             "blockwise"),
        ]
    """ + _PARITY_RUN)


def test_sharded_route_via_seq_rules_in_model():
    """A model forward under live "seq" rules takes the ShardedPlan route
    through layers.attn_apply and matches the unsharded logits."""
    _run("""
        from repro.configs import get_smoke
        from repro.dist import sharding as shlib
        from repro.dist import sharded_plan as spm
        from repro.models.model import build_model
        cfg = get_smoke("smollm-135m")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (2, 64)))}
        base = model.forward(params, batch)

        calls = []
        orig = spm.sharded_attention
        def spy(*a, **kw):
            calls.append(1)
            return orig(*a, **kw)
        spm.sharded_attention = spy

        mesh = jax.make_mesh((8,), ("data",))
        rules = dict(shlib.DEFAULT_RULES)
        rules.update(batch=None, seq=("data",))
        def fwd(p, b):
            with shlib.axis_rules(rules, mesh):
                return model.forward(p, b)
        with mesh:
            out = jax.jit(fwd)(params, batch)
        assert calls, "seq rules did not engage the sharded route"
        np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                   rtol=2e-3, atol=2e-3)
        print("SEQ-RULES-ROUTE-OK", len(calls))
    """)


def test_input_sharding_mesh_clean():
    """input_sharding must produce VALID NamedShardings when a rule names a
    mesh axis that is absent or doesn't divide the dim (the bug
    launch/specs.py used to work around with a duplicated _divisible)."""
    _run("""
        from repro.dist.sharding import input_sharding
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = {"batch": ("pod", "data"), "seq": None, "vocab": ("model",)}
        # "pod" doesn't exist on this mesh: must be dropped, "data" kept.
        sh = input_sharding(mesh, rules, "batch", "seq",
                            shape=(4, 64))
        x = jax.device_put(jnp.zeros((4, 64)), sh)      # must not raise
        assert sh.spec == P(("data",), None), sh.spec
        # 63 % 4 != 0: the vocab axis must be dropped for an argument
        # sharding (pjit rejects non-dividing argument shardings).
        sh2 = input_sharding(mesh, rules, "vocab", shape=(63,))
        assert sh2.spec == P(None), sh2.spec
        jax.device_put(jnp.zeros((63,)), sh2)
        # without a shape the membership check still applies
        sh3 = input_sharding(mesh, rules, "batch")
        assert sh3.spec == P(("data",)), sh3.spec
        # one mesh axis may shard at most one dim
        sh4 = input_sharding(mesh, {"a": ("model",), "b": ("model",)},
                             "a", "b", shape=(8, 8))
        assert sh4.spec == P(("model",), None), sh4.spec
        print("INPUT-SHARDING-OK")
    """)


def test_pjit_train_step_under_mesh():
    _run("""
        from repro.configs import get_smoke
        from repro.configs.base import ShapeCell
        from repro.launch.specs import build_cell
        import dataclasses
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_smoke("smollm-135m")
        shape = ShapeCell("t", 64, 4, "train")
        fn, args, in_sh, out_sh, rules = build_cell(cfg, shape, mesh)
        from repro.models.model import build_model
        from repro.optim import adamw
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        tcfg_opt = adamw.AdamWConfig()
        opt = adamw.init(tcfg_opt, params)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 64))),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 64)))}
        with mesh:
            params = jax.device_put(params, in_sh[0])
            opt = jax.device_put(opt, jax.tree.map(lambda s: s, in_sh[1],
                                 is_leaf=lambda x: hasattr(x, "spec")))
            step = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
            p2, o2, metrics = step(params, opt, batch)
        assert np.isfinite(float(metrics["loss"]))
        print("PJIT-TRAIN-OK", float(metrics["loss"]))
    """)


def test_elastic_rescale_8_to_4():
    _run("""
        import tempfile
        from repro.ft import checkpoint as ck
        tree = {"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}
        mesh8 = jax.make_mesh((8,), ("data",))
        sh8 = {"w": NamedSharding(mesh8, P("data", None))}
        placed = jax.device_put(tree, sh8)
        d = tempfile.mkdtemp()
        ck.save(d, placed, 1)
        # restore onto a 4-device mesh (elastic shrink)
        devs = jax.devices()[:4]
        import numpy as _np
        mesh4 = jax.sharding.Mesh(_np.array(devs), ("data",))
        sh4 = {"w": NamedSharding(mesh4, P("data", None))}
        restored = ck.restore(d, tree, shardings=sh4)
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))
        assert restored["w"].sharding.num_devices == 4
        print("ELASTIC-OK")
    """)


def test_compressed_psum_in_train_step_pod_axis():
    """compress_grads=True wires compressed_psum into the pod/data-axis
    reduce INSIDE train_step (shard_map over both axes): the first step's
    loss matches the pjit fp32 path exactly (loss is computed before the
    reduce), error feedback keeps convergence on top of the int8 wire, and
    the per-participant residual state is threaded with the fixed 4-tuple
    arity."""
    _run("""
        from repro.configs import get_smoke
        from repro.data.pipeline import DataConfig, SyntheticLM
        from repro.dist import sharding as shlib
        from repro.models.model import build_model
        from repro.optim import adamw
        from repro.train.trainer import TrainConfig, make_train_step
        cfg = get_smoke("smollm-135m")
        model = build_model(cfg)
        params0 = model.init(jax.random.PRNGKey(0))
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        rules = dict(shlib.DEFAULT_RULES, batch=("pod", "data"), fsdp=None)
        ds = SyntheticLM(cfg, DataConfig(seq_len=64, global_batch=8))

        def run(compress, steps):
            tcfg = TrainConfig(
                optimizer=adamw.AdamWConfig(lr=1e-2, grad_clip=1.0),
                compress_grads=compress)
            raw = make_train_step(model, tcfg)
            def fn(p, o, b, ef):
                with shlib.axis_rules(rules, mesh):
                    return raw(p, o, b, ef)
            step = jax.jit(fn)
            params, opt, ef = params0, adamw.init(tcfg.optimizer,
                                                  params0), None
            losses = []
            with mesh:
                for i in range(steps):
                    batch = {k: jnp.asarray(v)
                             for k, v in ds.batch(i % 4).items()}
                    params, opt, metrics, ef = step(params, opt, batch, ef)
                    losses.append(float(metrics["loss"]))
            return params, losses, ef

        p_ref, l_ref, ef_ref = run(False, 25)
        p_c, l_c, ef_c = run(True, 25)
        assert ef_ref is None
        leaf = jax.tree.leaves(ef_c)[0]
        assert leaf.shape[0] == 8, leaf.shape  # 2 pod x 4 data participants
        # first-step loss is pre-reduce: must agree exactly
        assert abs(l_c[0] - l_ref[0]) < 1e-5, (l_c[0], l_ref[0])
        # error feedback: int8 wire converges alongside fp32
        assert l_c[-1] < l_c[0] - 0.5, l_c[::6]
        assert abs(l_c[-1] - l_ref[-1]) < 0.3, (l_c[-1], l_ref[-1])
        print("COMPRESSED-TRAIN-STEP-OK", l_c[-1])
    """)


def test_compressed_psum_across_shards():
    _run("""
        from repro.compat import shard_map
        from repro.dist.compression import compressed_psum
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)
        def f(x):
            return compressed_psum(x[0], "data")[None]
        with mesh:
            out = jax.jit(shard_map(f, mesh=mesh, in_specs=(P("data", None),),
                                    out_specs=P("data", None)))(g)
        ref = jnp.sum(g, axis=0)
        rel = float(jnp.max(jnp.abs(out[0] - ref)) / jnp.max(jnp.abs(ref)))
        assert rel < 0.05, rel
        print("COMPRESSED-PSUM-OK", rel)
    """)


# ----------------- sequence-parallel continuous serving ----------------- #
_SERVE_PRELUDE = """
        import dataclasses
        from repro.configs import get_smoke
        from repro.models.model import build_model
        from repro.models.layers import salo_pattern
        from repro.serve.engine import ContinuousConfig, ContinuousEngine
        from repro.serve.paged_cache import layout_for_pattern
        mesh = jax.make_mesh((8,), ("seq",))
        rng = np.random.default_rng(3)

        def pair(cfg, lens, n_new, max_batch, impl="xla", seed=1):
            '''Greedy tokens of the 8-shard engine must equal the
            single-device ContinuousEngine token-for-token.'''
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(seed))
            prompts = [rng.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
                       for L in lens]
            pat = salo_pattern(cfg, causal=True)
            l1 = layout_for_pattern(pat, 8)
            e1 = ContinuousEngine(model, ContinuousConfig(
                n_pages=1 + max_batch * l1.pages_per_req, page=8, chunk=8,
                max_batch=max_batch, decode_impl=impl))
            r1 = [e1.submit(p, n_new) for p in prompts]
            ref = e1.run(params)
            l8 = layout_for_pattern(pat, 8, shards=8)
            e8 = ContinuousEngine(model, ContinuousConfig(
                n_pages=1 + max_batch * l8.pages_per_shard, page=8, chunk=8,
                max_batch=max_batch, decode_impl=impl, seq_shards=8),
                mesh=mesh)
            r8 = [e8.submit(p, n_new) for p in prompts]
            out = e8.run(params)
            for a, b in zip(r1, r8):
                np.testing.assert_array_equal(ref[a], out[b])
            # per-shard pools fully recycled on completion
            for al in e8.batcher.allocs:
                assert al.n_free == e8.ccfg.n_pages - 1
            return e8
"""


def test_sharded_serving_ragged_and_recycling():
    """8-shard continuous engine == single-device engine token-for-token on
    a ragged batch with more requests than rows (page-recycling waves over
    the per-shard pools), and with the paged decode KERNEL inside
    shard_map (pallas_interpret partial-state path)."""
    _run(_SERVE_PRELUDE + """
        cfg = get_smoke("smollm-135m")
        pair(cfg, (5, 11, 7, 9, 6), 4, 2)
        print("RAGGED-RECYCLE-OK")
        pair(cfg, (7, 12), 4, 2, impl="pallas_interpret")
        print("SHARDED-KERNEL-OK")
        # bf16 compute: partials stay f32 until ONE post-merge round, so
        # the low-precision dtype must not break token-exactness either
        cfgb = dataclasses.replace(cfg, compute_dtype="bfloat16")
        pair(cfgb, (9, 14), 6, 2, seed=2)
        print("SHARDED-BF16-OK")
    """)


def test_sharded_serving_ring_wraparound_and_dilation():
    """Ring wraparound ACROSS shard boundaries: window=8 with 8 shards puts
    each shard's slice at a couple of ring slots, and t >> window drives
    many revolutions through all of them; dilation > 1 exercises the
    dilated-lookback ring under the sharded slot map."""
    _run(_SERVE_PRELUDE + """
        cfg = get_smoke("smollm-135m")
        cfgw = dataclasses.replace(cfg, salo=dataclasses.replace(
            cfg.salo, window=8))
        pair(cfgw, (21, 6), 40, 2)
        print("SHARD-WRAP-OK")
        cfgd = dataclasses.replace(cfg, salo=dataclasses.replace(
            cfg.salo, window=4, dilation=2, n_global=2))
        pair(cfgd, (11, 17), 10, 2)
        print("SHARD-DILATED-OK")
    """)


def test_multipod_mesh_shape():
    _run("""
        # 8 devices reshaped as a miniature (pod, data, model) mesh to prove
        # the 3-axis sharding rules compose (full 512-chip version runs in
        # the dry-run).
        from repro.configs import get_smoke
        from repro.configs.base import ShapeCell
        from repro.launch.specs import build_cell
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = get_smoke("arctic-480b")  # MoE: exercises EP rules too
        shape = ShapeCell("t", 64, 4, "train")
        fn, args, in_sh, out_sh, rules = build_cell(cfg, shape, mesh)
        with mesh:
            lowered = jax.jit(fn, in_shardings=in_sh,
                              out_shardings=out_sh).lower(*args)
            compiled = lowered.compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):  # older jax: dict per device
            cost = cost[0]
        assert cost.get("flops", 0) > 0
        print("MULTIPOD-SMOKE-OK")
    """)
