"""Pallas decode kernel vs the jnp decode engine, swept over shapes/dtypes
(ring-cache layouts included)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import patterns as P
from repro.core.attention import hybrid_decode_attention
from repro.kernels.salo_decode import salo_decode

RNG = np.random.default_rng(3)


@pytest.mark.parametrize("H,Hkv,hd", [(8, 2, 32), (4, 4, 64), (6, 1, 128)])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-3),
                                       (jnp.bfloat16, 4e-2)])
def test_decode_kernel_full_cache(H, Hkv, hd, dtype, tol):
    pat = P.causal_sliding_window(24, n_sinks=3)
    B, S = 2, 100
    q = jnp.asarray(RNG.normal(size=(B, H, 1, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, S, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, S, hd)), dtype)
    pos = jnp.arange(S, dtype=jnp.int32)
    for t in (0, 30, 99):
        ref = hybrid_decode_attention(q, k, v, t, pat)
        out = salo_decode(q, k, v, pos, t, pattern=pat, block_s=32,
                          interpret=True)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=tol, atol=tol, err_msg=str(t))


def test_decode_kernel_ring_layout():
    """Kernel on a ring cache == jnp engine with the same slot positions."""
    from repro.serve.kv_cache import (ring_init, ring_update,
                                      ring_positions_mask)
    w_, g = 16, 2
    pat = P.causal_sliding_window(w_, n_sinks=g)
    B, Hkv, hd = 2, 2, 32
    H = 4
    n = 50
    q_all = jnp.asarray(RNG.normal(size=(B, H, n, hd)), jnp.float32)
    k_all = jnp.asarray(RNG.normal(size=(B, Hkv, n, hd)), jnp.float32)
    v_all = jnp.asarray(RNG.normal(size=(B, Hkv, n, hd)), jnp.float32)
    cache = ring_init(B, w_, g, Hkv, hd, jnp.float32)
    for t in range(n):
        cache = ring_update(cache,
                            k_all[:, :, t:t + 1].transpose(0, 2, 1, 3),
                            v_all[:, :, t:t + 1].transpose(0, 2, 1, 3),
                            t, w_, g)
        if t % 9 != 0:
            continue
        kc = cache.k.transpose(0, 2, 1, 3)
        vc = cache.v.transpose(0, 2, 1, 3)
        pos = ring_positions_mask(cache)
        ref = hybrid_decode_attention(q_all[:, :, t:t + 1], kc, vc, t, pat,
                                      cache_positions=pos)
        out = salo_decode(q_all[:, :, t:t + 1], kc, vc, pos, t,
                          pattern=pat, block_s=8, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3, err_msg=str(t))
