"""Pallas decode kernels vs the jnp decode engine, swept over shapes/dtypes
(ring-cache layouts, ragged per-request positions, and the paged slab)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import patterns as P
from repro.core.attention import hybrid_decode_attention
from repro.kernels.salo_decode import salo_decode, salo_paged_decode

RNG = np.random.default_rng(3)


@pytest.mark.parametrize("H,Hkv,hd", [(8, 2, 32), (4, 4, 64), (6, 1, 128)])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-3),
                                       (jnp.bfloat16, 4e-2)])
def test_decode_kernel_full_cache(H, Hkv, hd, dtype, tol):
    pat = P.causal_sliding_window(24, n_sinks=3)
    B, S = 2, 100
    q = jnp.asarray(RNG.normal(size=(B, H, 1, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, S, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, S, hd)), dtype)
    pos = jnp.arange(S, dtype=jnp.int32)
    for t in (0, 30, 99):
        ref = hybrid_decode_attention(q, k, v, t, pat)
        out = salo_decode(q, k, v, pos, t, pattern=pat, block_s=32,
                          interpret=True)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=tol, atol=tol, err_msg=str(t))


def test_decode_kernel_ring_layout():
    """Kernel on a ring cache == jnp engine with the same slot positions."""
    from repro.serve.kv_cache import (ring_init, ring_update,
                                      ring_positions_mask)
    w_, g = 16, 2
    pat = P.causal_sliding_window(w_, n_sinks=g)
    B, Hkv, hd = 2, 2, 32
    H = 4
    n = 50
    q_all = jnp.asarray(RNG.normal(size=(B, H, n, hd)), jnp.float32)
    k_all = jnp.asarray(RNG.normal(size=(B, Hkv, n, hd)), jnp.float32)
    v_all = jnp.asarray(RNG.normal(size=(B, Hkv, n, hd)), jnp.float32)
    cache = ring_init(B, w_, g, Hkv, hd, jnp.float32)
    for t in range(n):
        cache = ring_update(cache,
                            k_all[:, :, t:t + 1].transpose(0, 2, 1, 3),
                            v_all[:, :, t:t + 1].transpose(0, 2, 1, 3),
                            t, w_, g)
        if t % 9 != 0:
            continue
        kc = cache.k.transpose(0, 2, 1, 3)
        vc = cache.v.transpose(0, 2, 1, 3)
        pos = ring_positions_mask(cache)
        ref = hybrid_decode_attention(q_all[:, :, t:t + 1], kc, vc, t, pat,
                                      cache_positions=pos)
        out = salo_decode(q_all[:, :, t:t + 1], kc, vc, pos, t,
                          pattern=pat, block_s=8, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3, err_msg=str(t))


# =================== ragged / paged continuous decode =================== #
def _rand_decode(B, H, Hkv, hd, S, dtype=jnp.float32, seed=11):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, H, 1, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, hd)), dtype)
    return q, k, v


@pytest.mark.parametrize("dilation", [1, 2])
def test_ragged_t_vector_one_launch(dilation):
    """ONE kernel launch with a per-request t vector == per-row lockstep
    reference calls — batch members at different positions (the continuous
    batching state), dilated windows included."""
    pat = P.causal_sliding_window(6, n_sinks=2, dilation=dilation)
    B, H, Hkv, hd, S = 4, 4, 2, 32, 64
    q, k, v = _rand_decode(B, H, Hkv, hd, S)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    tv = jnp.asarray([0, 7, 23, 63], jnp.int32)
    out = salo_decode(q, k, v, pos, tv, pattern=pat, block_s=16,
                      interpret=True)
    for b in range(B):
        ref = hybrid_decode_attention(q[b:b + 1], k[b:b + 1], v[b:b + 1],
                                      int(tv[b]), pat)
        np.testing.assert_allclose(np.asarray(out[b]), np.asarray(ref[0]),
                                   rtol=2e-3, atol=2e-3, err_msg=str(b))


def test_per_request_positions():
    """Per-request slot->position tables (the paged view): each row's cache
    is scrambled differently; masks follow positions, not slots."""
    pat = P.causal_sliding_window(8, n_sinks=1)
    B, H, Hkv, hd, S = 3, 2, 1, 16, 32
    q, k, v = _rand_decode(B, H, Hkv, hd, S)
    rng = np.random.default_rng(5)
    pos = np.stack([rng.permutation(S) for _ in range(B)]).astype(np.int32)
    tv = jnp.asarray([9, 31, 14], jnp.int32)
    out = salo_decode(q, k, v, jnp.asarray(pos), tv, pattern=pat,
                      block_s=8, interpret=True)
    for b in range(B):
        ref = hybrid_decode_attention(q[b:b + 1], k[b:b + 1], v[b:b + 1],
                                      int(tv[b]), pat,
                                      cache_positions=jnp.asarray(pos[b]))
        np.testing.assert_allclose(np.asarray(out[b]), np.asarray(ref[0]),
                                   rtol=2e-3, atol=2e-3, err_msg=str(b))


def test_off_tpu_compiled_degrades_to_xla_twin():
    """Compiled (non-interpret) kernels off-TPU fall back to the XLA ragged
    twin instead of crashing — same degrade pattern as kernels/ops.py."""
    if jax.default_backend() == "tpu":
        pytest.skip("degrade path is for non-TPU backends")
    pat = P.causal_sliding_window(6, n_sinks=2)
    B, H, Hkv, hd, S = 2, 4, 2, 32, 40
    q, k, v = _rand_decode(B, H, Hkv, hd, S)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    tv = jnp.asarray([12, 39], jnp.int32)
    ref = salo_decode(q, k, v, pos, tv, pattern=pat, block_s=8,
                      interpret=True)
    out = salo_decode(q, k, v, pos, tv, pattern=pat, block_s=8,
                      interpret=False)   # would crash without the fallback
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def _slabify(k, v, page):
    """Pack per-request contiguous caches into a pooled slab + page tables
    (page 0 reserved as the null page)."""
    B, Hkv, S, hd = k.shape
    npp = S // page
    n_pages = 1 + B * npp
    ks = np.zeros((n_pages, page, Hkv, hd), np.float32)
    vs = np.zeros((n_pages, page, Hkv, hd), np.float32)
    pt = np.zeros((B, npp), np.int32)
    for b in range(B):
        for g in range(npp):
            phys = 1 + b * npp + g
            pt[b, g] = phys
            ks[phys] = np.asarray(
                k[b, :, g * page:(g + 1) * page]).transpose(1, 0, 2)
            vs[phys] = np.asarray(
                v[b, :, g * page:(g + 1) * page]).transpose(1, 0, 2)
    return jnp.asarray(ks), jnp.asarray(vs), jnp.asarray(pt)


@pytest.mark.parametrize("block_s", [None, 8])
def test_paged_kernel_matches_contiguous(block_s):
    """salo_paged_decode chasing scalar-prefetched page tables == the
    contiguous-cache kernel on the same logical content."""
    pat = P.causal_sliding_window(10, n_sinks=2)
    B, H, Hkv, hd, S, page = 3, 4, 2, 32, 48, 16
    q, k, v = _rand_decode(B, H, Hkv, hd, S)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    tv = jnp.asarray([3, 30, 47], jnp.int32)
    ks, vs, pt = _slabify(k, v, page)
    ref = salo_decode(q, k, v, pos, tv, pattern=pat, block_s=16,
                      interpret=True)
    out = salo_paged_decode(q, ks, vs, pt, pos, tv, pattern=pat,
                            block_s=block_s, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
    if jax.default_backend() != "tpu":
        out2 = salo_paged_decode(q, ks, vs, pt, pos, tv, pattern=pat,
                                 block_s=block_s, interpret=False)
        np.testing.assert_allclose(np.asarray(out2), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)
