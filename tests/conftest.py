import numpy as np
import pytest

# NB: deliberately NO xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device (dryrun.py sets 512 for itself only). Tests
# that need a few devices live in tests/test_distributed.py, which spawns a
# subprocess with the flag set.


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def assert_allclose(a, b, rtol=2e-3, atol=2e-3):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=rtol, atol=atol)
