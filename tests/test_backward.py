"""Fused plan-driven backward: gradient parity + transposed-plan contract.

The backward is a first-class ExecutionPlan consumer (two flash-style
passes: dQ over the forward tables, dK/dV over the transposed tables,
``p`` recomputed from the saved ``(out, m, l)``), so these tests pin:

  * gradient parity of BOTH differentiable engines (pallas_interpret and
    blockwise) against dense_ref autodiff, <= 1e-4, across the four
    pattern families (Longformer window+global, ViL 2-D multi-band,
    dilated/reordered, reordered+global sinks);
  * exactly TWO backward kernel launches and ZERO forward kernel
    launches inside the VJP (no full-forward recompute);
  * the transposed plan is the EXACT adjoint of the forward coverage
    (same visits, same flags, dedup preserved — equal tile totals);
  * the empty-row contract: rows that attend nothing emit
    (out=0, m=NEG_INF, l=0) and get exactly zero gradients.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import patterns as P
from repro.core.attention import hybrid_attention
from repro.core.scheduler import build_plan, schedule

# The four pattern families named by the training configs (scaled down so
# interpret-mode gradients stay fast): Longformer-4k window+global, ViL 2-D
# multi-band, dilated (data-reordered), and reordered-global (sinks).
GRAD_CASES = [
    ("longformer", P.longformer(8, n_global=2), 37, 8, 8),
    ("longformer_causal", P.longformer(8, n_global=2, causal=True), 37, 8, 8),
    ("vil_2d", P.vil((5, 7), (3, 3), n_global=2), None, 8, 8),
    ("vil_2d_overlap", P.vil((5, 4), (3, 5), n_global=1), None, 8, 8),
    ("dilated", P.dilated_window(4, 3), 29, 8, 8),
    ("reordered_global", P.causal_sliding_window(5, n_sinks=2, dilation=2),
     31, 8, 8),
]


def _qkv_cot(n, d=16, b=1, h=2, seed=0):
    rng = np.random.default_rng(seed)
    q, k, v, cot = (jnp.asarray(rng.normal(size=(b, h, n, d)), jnp.float32)
                    for _ in range(4))
    return q, k, v, cot


def _grads(impl, pat, n, bq, bk, q, k, v, cot):
    def loss(q_, k_, v_):
        out = hybrid_attention(q_, k_, v_, pat, impl=impl, block_q=bq,
                               block_k=bk)
        return jnp.sum(out * cot)
    return jax.grad(loss, argnums=(0, 1, 2))(q, k, v)


@pytest.mark.parametrize("impl", ["pallas_interpret", "blockwise"])
@pytest.mark.parametrize("name,pat,n,bq,bk", GRAD_CASES)
def test_gradient_parity_vs_dense_ref(impl, name, pat, n, bq, bk):
    """dQ/dK/dV through the fused plan backward == dense_ref autodiff."""
    n = n if n is not None else pat.seq_len()
    q, k, v, cot = _qkv_cot(n)
    g_ref = _grads("dense_ref", pat, n, bq, bk, q, k, v, cot)
    g_out = _grads(impl, pat, n, bq, bk, q, k, v, cot)
    for gname, a, b in zip("qkv", g_ref, g_out):
        np.testing.assert_allclose(
            np.asarray(b), np.asarray(a), rtol=1e-4, atol=1e-4,
            err_msg=f"{name}/{impl}: d{gname} mismatch")


def test_gqa_gradient_parity():
    """GQA (broadcast KV, no repeat-copy) keeps fwd+bwd parity."""
    pat = P.longformer(8, n_global=1)
    n, d, b, h, hkv = 24, 8, 2, 4, 2
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(b, h, n, d)), jnp.float32)
    k, v = (jnp.asarray(rng.normal(size=(b, hkv, n, d)), jnp.float32)
            for _ in range(2))
    cot = jnp.asarray(rng.normal(size=(b, h, n, d)), jnp.float32)

    def loss(impl):
        def f(q_, k_, v_):
            out = hybrid_attention(q_, k_, v_, pat, impl=impl, block_q=8,
                                   block_k=8)
            return jnp.sum(out * cot)
        return f

    g_ref = jax.grad(loss("dense_ref"), argnums=(0, 1, 2))(q, k, v)
    for impl in ("blockwise", "pallas_interpret"):
        g_out = jax.grad(loss(impl), argnums=(0, 1, 2))(q, k, v)
        for gname, a, b in zip("qkv", g_ref, g_out):
            assert a.shape == b.shape  # KV grads stay (B, Hkv, N, D)
            np.testing.assert_allclose(
                np.asarray(b), np.asarray(a), rtol=1e-4, atol=1e-4,
                err_msg=f"{impl}: d{gname}")


# ---------------- launch accounting: 2 bwd, 0 fwd-recompute ------------- #
def test_backward_is_two_launches_no_forward_recompute(monkeypatch):
    # salo_attention and salo_backward share the one pallas module object,
    # so patch it once and classify launches by kernel name.
    from repro.kernels import salo_attention as sa
    from repro.kernels.ops import salo_attention

    jax.clear_caches()  # launch counts are per-trace; force fresh traces
    launches = []
    real = sa.pl.pallas_call

    def counting(*args, **kwargs):
        launches.append(kwargs.get("name", "?"))
        return real(*args, **kwargs)

    monkeypatch.setattr(sa.pl, "pallas_call", counting)

    pat = P.vil((5, 7), (3, 3), n_global=2)
    n = pat.seq_len()
    rng = np.random.default_rng(0)
    q, k, v, cot = (jnp.asarray(rng.normal(size=(2, n, 16)), jnp.float32)
                    for _ in range(4))
    out, vjp = jax.vjp(
        lambda q_, k_, v_: salo_attention(q_, k_, v_, pat, 8, 8, None, True),
        q, k, v)
    assert launches == ["salo_plan_attention"], launches
    dq, dk, dv = vjp(cot)
    jax.block_until_ready((dq, dk, dv))
    bwd = launches[1:]
    assert sorted(bwd) == ["salo_plan_backward_dkv",
                           "salo_plan_backward_dq"], \
        f"want exactly dQ + dK/dV and NO forward recompute, got {launches}"


def test_compiled_pallas_off_tpu_degrades_to_xla_twin():
    """impl="pallas" with interpret=False on a non-TPU backend must not
    crash: forward AND backward degrade to the XLA twin (same plan, same
    residual contract)."""
    from repro.kernels.ops import salo_attention

    if jax.default_backend() == "tpu":
        pytest.skip("fallback path only exists off-TPU")
    pat = P.longformer(8, n_global=2)
    n = 26
    rng = np.random.default_rng(5)
    q, k, v, cot = (jnp.asarray(rng.normal(size=(1, n, 8)), jnp.float32)
                    for _ in range(4))

    def loss(impl_interpret):
        def f(q_, k_, v_):
            out = salo_attention(q_, k_, v_, pat, 8, 8, None, impl_interpret)
            return jnp.sum(out * cot)
        return f

    out_c = salo_attention(q, k, v, pat, 8, 8, None, False)   # compiled: twin
    out_i = salo_attention(q, k, v, pat, 8, 8, None, True)    # interpret
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_i),
                               rtol=2e-3, atol=2e-3)
    g_c = jax.grad(loss(False), argnums=(0, 1, 2))(q, k, v)
    g_i = jax.grad(loss(True), argnums=(0, 1, 2))(q, k, v)
    for gname, a, b in zip("qkv", g_i, g_c):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=1e-4,
                                   atol=1e-4, err_msg=f"d{gname}")


# ---------------------- transposed-plan contract ------------------------ #
TP_CASES = [
    ("longformer", P.longformer(8, n_global=2), 37, 8, 8),
    ("vil_2d", P.vil((5, 7), (3, 3), n_global=2), None, 8, 8),
    ("dilated_sinks", P.causal_sliding_window(5, n_sinks=2, dilation=2),
     31, 8, 8),
    ("asym_blocks", P.causal_sliding_window(7), 33, 8, 16),
]


@pytest.mark.parametrize("name,pat,n,bq,bk", TP_CASES)
def test_transposed_plan_exact_adjoint(name, pat, n, bq, bk):
    """Transposed tables = the forward visit set with (i, j) swapped —
    same flags, each visit once, dedup preserved (equal totals)."""
    n = n if n is not None else pat.seq_len()
    plan = build_plan(schedule(pat, n), bq, bk)
    tp = plan.transposed()

    fwd = {(i, int(plan.kv_blocks[i, s])): int(plan.flags[i, s])
           for i in range(plan.nq) for s in range(int(plan.num_steps[i]))}
    bwd = {(int(tp.q_blocks[j, s]), j): int(tp.flags[j, s])
           for j in range(plan.nkb) for s in range(int(tp.num_steps[j]))}
    assert fwd == bwd, f"{name}: transposed walk is not the exact adjoint"
    # dedup preserved: identical tile totals (so within any 1.1x budget)
    assert int(tp.num_steps.sum()) == int(plan.num_steps.sum())
    # same padding contract: flags 0 beyond num_steps, ascending q order
    for j in range(plan.nkb):
        ns = int(tp.num_steps[j])
        assert (tp.flags[j, ns:] == 0).all()
        assert (tp.q_blocks[j, ns:] == 0).all()
        row = tp.q_blocks[j, :ns]
        assert (np.diff(row) > 0).all(), f"{name}: row {j} not deduped/sorted"


def test_transposed_plan_cached_and_in_stats():
    pat = P.vil((5, 7), (3, 3), 1)
    plan = build_plan(schedule(pat, pat.seq_len()), 8, 8)
    assert plan.transposed() is plan.transposed()  # lru-cached
    stats = plan.stats()
    assert stats["bwd_dq_tiles"] == stats["executed_tiles"]
    assert stats["bwd_dkv_tiles"] == stats["executed_tiles"]
    assert stats["bwd_launches"] == 2


# ------------------------- empty-row contract --------------------------- #
def test_dead_rows_emit_merge_identity_and_zero_grads():
    """Rows with no reachable key: (out=0, m=NEG_INF, l=0) from the kernel,
    and exactly zero (finite!) gradients through the fused backward."""
    from repro.core.blockwise import working_stream
    from repro.core.renorm import NEG_INF
    from repro.kernels.salo_attention import salo_plan_attention

    pat = P.HybridSparsePattern(window=(2, 5))  # rows >= n-2 attend nothing
    n, d = 16, 8
    sched = schedule(pat, n)
    plan = sched.plan(8, 8)
    rng = np.random.default_rng(4)
    q, k, v, cot = (jnp.asarray(rng.normal(size=(1, n, d)), jnp.float32)
                    for _ in range(4))
    empty = ~pat.mask(n).any(axis=1)
    assert empty.sum() >= 2

    qw = working_stream(q, sched, plan)
    kw = working_stream(k, sched, plan)
    vw = working_stream(v, sched, plan)
    pos = jnp.asarray(plan.positions_padded())
    out_w, m, l = salo_plan_attention(qw, kw, vw, pos, plan=plan,
                                      scale=d ** -0.5, interpret=True)
    np.testing.assert_array_equal(np.asarray(l)[0, :n][empty], 0.0)
    np.testing.assert_array_equal(np.asarray(m)[0, :n][empty],
                                  np.float32(NEG_INF))
    np.testing.assert_array_equal(np.asarray(out_w)[0, :n][empty], 0.0)

    for impl in ("pallas_interpret", "blockwise"):
        def loss(q_, k_, v_):
            out = hybrid_attention(q_[None], k_[None], v_[None], pat,
                                   impl=impl, block_q=8, block_k=8)[0]
            return jnp.sum(out * cot)
        dq, dk, dv = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
        for g in (dq, dk, dv):
            assert np.isfinite(np.asarray(g)).all(), impl
        np.testing.assert_array_equal(np.asarray(dq)[0, empty], 0.0,
                                      err_msg=impl)
