"""Property tests for the renormalized merge (paper Eq. 2 / App. A)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # every test here is a property test
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core import renorm

F = st.floats(-8, 8, allow_nan=False, width=32)


def _state_from(scores, v):
    st_ = renorm.empty_state(scores.shape[:-1], v.shape[-1])
    return renorm.update(st_, jnp.asarray(scores), jnp.asarray(v))


def _softmax_out(scores, v):
    p = jax.nn.softmax(jnp.asarray(scores), axis=-1)
    return jnp.einsum("...qk,...kd->...qd", p, jnp.asarray(v))


@given(hnp.arrays(np.float32, (2, 3, 6), elements=F),
       hnp.arrays(np.float32, (2, 6, 4), elements=F),
       st.integers(1, 5))
@settings(max_examples=40, deadline=None)
def test_split_merge_exact(scores, v, cut):
    """Splitting the key set at any point and merging == unsplit softmax."""
    sa = _state_from(scores[..., :cut], v[:, :cut])
    sb = _state_from(scores[..., cut:], v[:, cut:])
    merged = renorm.finalize(renorm.merge(sa, sb))
    np.testing.assert_allclose(np.asarray(merged),
                               np.asarray(_softmax_out(scores, v)),
                               rtol=1e-4, atol=1e-5)


@given(hnp.arrays(np.float32, (1, 2, 9), elements=F),
       hnp.arrays(np.float32, (1, 9, 3), elements=F))
@settings(max_examples=30, deadline=None)
def test_merge_associative_commutative(scores, v):
    parts = [(_state_from(scores[..., i:i + 3], v[:, i:i + 3]))
             for i in (0, 3, 6)]
    a, b, c = parts
    left = renorm.merge(renorm.merge(a, b), c)
    right = renorm.merge(a, renorm.merge(b, c))
    perm = renorm.merge(renorm.merge(c, a), b)
    for other in (right, perm):
        np.testing.assert_allclose(np.asarray(renorm.finalize(left)),
                                   np.asarray(renorm.finalize(other)),
                                   rtol=1e-4, atol=1e-5)


def test_identity_element():
    rng = np.random.default_rng(0)
    scores = rng.normal(size=(2, 3, 5)).astype(np.float32)
    v = rng.normal(size=(2, 5, 4)).astype(np.float32)
    s = _state_from(scores, v)
    e = renorm.empty_state((2, 3), 4)
    for merged in (renorm.merge(s, e), renorm.merge(e, s)):
        np.testing.assert_allclose(np.asarray(renorm.finalize(merged)),
                                   np.asarray(renorm.finalize(s)), rtol=1e-5)


def test_masked_update_rows_with_nothing():
    """Fully-masked rows finalize to zeros, not NaN."""
    s = renorm.empty_state((1, 2), 3)
    scores = jnp.zeros((1, 2, 4))
    v = jnp.ones((1, 4, 3))
    mask = jnp.array([[[True] * 4, [False] * 4]])
    s = renorm.update(s, scores, v, mask)
    out = renorm.finalize(s)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_allclose(np.asarray(out[0, 0]), np.ones(3), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out[0, 1]), np.zeros(3))


def test_extreme_scores_stable():
    """Paper's fixed-point HW doesn't subtract a max; our float version must
    survive +-large scores (DESIGN.md deviation)."""
    s = renorm.empty_state((1, 1), 2)
    s = renorm.update(s, jnp.array([[[300.0, -300.0]]]),
                      jnp.ones((1, 2, 2)))
    s = renorm.update(s, jnp.array([[[310.0]]]), 2 * jnp.ones((1, 1, 2)))
    out = renorm.finalize(s)
    assert bool(jnp.all(jnp.isfinite(out)))
    # 310 dominates: output ~ 2
    np.testing.assert_allclose(np.asarray(out), 2.0, rtol=1e-3)
