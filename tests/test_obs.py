"""Observability layer: metrics registry (labeled counters / gauges /
log-bucketed histograms, mergeable snapshots), span tracer (ring buffer,
injectable clock, Chrome-trace export), engine/batcher instrumentation
(lifecycle latency metrics, counters-dict compatibility, snapshot
round-trip incl. old-format snapshots), and FT event plumbing.

The two hard contracts pinned here and gated in benchmarks/obs_stats.py:
disabled observability adds nothing to any jitted computation, and the
registry rides the engine snapshot/restore path exactly as the old
``counters`` dict did."""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models.model import build_model
from repro.obs import (MetricsRegistry, Observability, Tracer,
                       merge_snapshots, summary_line, validate_chrome_trace)
from repro.obs.metrics import BASE, bucket_index
from repro.obs.trace import NULL_TRACER
from repro.serve.engine import ContinuousConfig, ContinuousEngine

RNG = np.random.default_rng(7)


@pytest.fixture(scope="module")
def stack():
    cfg = get_smoke("smollm-135m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.models.layers import salo_pattern
    from repro.serve.paged_cache import layout_for_pattern
    lay = layout_for_pattern(salo_pattern(cfg, causal=True), 8)
    return cfg, model, params, lay


def _engine(model, lay, *, max_batch=4, obs=None, n_pages=None):
    return ContinuousEngine(model, ContinuousConfig(
        n_pages=n_pages or 1 + max_batch * lay.pages_per_req, page=8,
        chunk=8, max_batch=max_batch), obs=obs)


# ============================ registry ================================== #
def test_registry_counters_gauges_labels():
    reg = MetricsRegistry()
    reg.inc("steps")
    reg.inc("steps", 2)
    assert reg.value("steps") == 3
    reg.inc("finished", priority=0)
    reg.inc("finished", priority=1)
    reg.inc("finished", priority=1)
    assert reg.value("finished", priority=0) == 1
    assert reg.value("finished", priority=1) == 2
    assert reg.total("finished") == 3
    reg.set("resident", 7.0)
    reg.set("resident", 5.0)           # gauges overwrite
    assert reg.value("resident") == 5.0
    # label mismatch and kind re-declaration are hard errors
    with pytest.raises(ValueError):
        reg.inc("finished", tenant="a")
    with pytest.raises(ValueError):
        reg.set("steps", 1.0)


def test_histogram_percentiles_nearest_rank():
    reg = MetricsRegistry()
    for v in (0.01, 0.02, 0.03, 0.5):
        reg.observe("lat", v)
    p = reg.percentiles("lat", qs=(0.5, 0.99))
    # nearest-rank: p99 of 4 samples is the max sample's bucket, and the
    # estimate is clamped to the exact observed [min, max]
    assert abs(p["p50"] - 0.02) / 0.02 < 0.25
    assert abs(p["p99"] - 0.5) / 0.5 < 0.25
    assert p["count"] == 4
    assert p["mean"] == pytest.approx(0.14)
    h = reg.merged_hist("lat")
    assert h.min == 0.01 and h.max == 0.5
    # every estimate stays within one bucket width of the true quantile
    for q in (0.1, 0.5, 0.9):
        est = h.percentile(q)
        assert 0.01 <= est <= 0.5
    # empty histogram: NaN percentiles, zero count
    empty = reg.percentiles("never_observed_family_x")
    assert math.isnan(empty["p50"]) and empty["count"] == 0


def test_bucket_index_resolution():
    # adjacent bucket edges differ by BASE (~19%) — the resolution claim
    for x in (1e-6, 0.004, 1.0, 37.5):
        i = bucket_index(x)
        assert BASE ** i <= x < BASE ** (i + 1)


def _random_snapshot(rng):
    reg = MetricsRegistry()
    for _ in range(rng.integers(1, 5)):
        reg.inc("c", float(rng.integers(1, 10)), shard=int(rng.integers(3)))
    reg.set("g", float(rng.integers(100)))
    for _ in range(int(rng.integers(1, 20))):
        reg.observe("h", float(rng.uniform(1e-4, 10.0)))
    return reg.snapshot()


def test_merge_snapshots_associative_commutative():
    snaps = [_random_snapshot(RNG) for _ in range(3)]
    a, b, c = snaps
    left = merge_snapshots(merge_snapshots(a, b), c)
    right = merge_snapshots(a, merge_snapshots(b, c))
    assert left == right
    assert merge_snapshots(a, b) == merge_snapshots(b, a)
    # counters add, gauges max, histogram counts add
    m = merge_snapshots(a, b)
    ca = sum(a["c"]["cells"].values())
    cb = sum(b["c"]["cells"].values())
    assert sum(m["c"]["cells"].values()) == pytest.approx(ca + cb)
    ga = list(a["g"]["cells"].values())[0]
    gb = list(b["g"]["cells"].values())[0]
    assert list(m["g"]["cells"].values())[0] == max(ga, gb)
    ha = list(a["h"]["cells"].values())[0]["count"]
    hb = list(b["h"]["cells"].values())[0]["count"]
    assert list(m["h"]["cells"].values())[0]["count"] == ha + hb


def test_registry_state_roundtrip_exact():
    snap = _random_snapshot(RNG)
    reg = MetricsRegistry()
    reg.load_state(snap)
    assert reg.state_dict() == snap
    # and the image is pure JSON
    assert json.loads(json.dumps(snap)) == snap


# ============================= tracer =================================== #
def _fake_clock():
    t = [0.0]

    def clock():
        t[0] += 0.001
        return t[0]
    return clock


def test_tracer_nested_spans_and_chrome_export():
    trc = Tracer(clock=_fake_clock())
    with trc.span("outer", step=0):
        with trc.span("inner"):
            pass
        trc.instant("mark", kind="x")
    trc.counter("queue_depth", 3)
    evs = trc.events()
    by = {e["name"]: e for e in evs}
    # inner closes first (ring holds completion order) and nests deeper
    assert [e["name"] for e in evs] == ["inner", "mark", "outer",
                                       "queue_depth"]
    assert by["inner"]["depth"] == 1 and by["outer"]["depth"] == 0
    # containment: inner's interval inside outer's
    o, i = by["outer"], by["inner"]
    assert o["ts"] <= i["ts"] and i["ts"] + i["dur"] <= o["ts"] + o["dur"]
    doc = trc.to_chrome_trace()
    validate_chrome_trace(doc)
    phases = {e["name"]: e["ph"] for e in doc["traceEvents"]
              if e["ph"] != "M"}
    assert phases == {"outer": "X", "inner": "X", "mark": "i",
                      "queue_depth": "C"}


def test_tracer_deterministic_under_fake_clock():
    def run():
        trc = Tracer(clock=_fake_clock())
        with trc.span("a", step=1):
            trc.instant("b")
        return trc.to_json()
    assert run() == run()


def test_tracer_ring_eviction():
    trc = Tracer(capacity=4, clock=_fake_clock())
    for i in range(10):
        trc.instant(f"e{i}")
    assert len(trc) == 4
    assert trc.dropped == 6
    assert [e["name"] for e in trc.events()] == ["e6", "e7", "e8", "e9"]
    validate_chrome_trace(trc.to_chrome_trace())


def test_disabled_tracer_is_noop():
    assert not NULL_TRACER.enabled
    with NULL_TRACER.span("x"):
        NULL_TRACER.instant("y")
    NULL_TRACER.counter("z", 1)
    assert len(NULL_TRACER) == 0
    # exception safety: a raising body still propagates, span still closes
    trc = Tracer(clock=_fake_clock())
    with pytest.raises(ValueError):
        with trc.span("boom"):
            raise ValueError("body")
    assert trc.find("boom")


# ================== engine instrumentation + compat ===================== #
def test_counters_view_compat_and_metrics(stack):
    cfg, model, params, lay = stack
    eng = _engine(model, lay)
    prompts = [RNG.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
               for L in (11, 6)]
    for p in prompts:
        eng.submit(p, 4)
    eng.run(params)
    # the dict-compat view: iteration, membership, int values
    c = dict(eng.counters)
    assert c["engine_steps"] > 0 and isinstance(c["engine_steps"], int)
    assert set(c) == set(eng.counters.KEYS)
    assert eng.counters["prefill_launches"] == \
        sum(-(-len(p) // 8) for p in prompts)
    # the same numbers ARE registry counters
    assert eng.registry.value("serve_engine_steps") == c["engine_steps"]
    # lifecycle latency histograms populated per priority
    assert eng.registry.percentiles("serve_ttft_s",
                                    priority=0)["count"] == 2
    assert eng.registry.percentiles("serve_tpot_s",
                                    priority=0)["count"] == 2 * 3
    assert eng.registry.percentiles("serve_queue_wait_s",
                                    priority=0)["count"] == 2
    assert summary_line(eng.registry).startswith("steps=")


def test_engine_snapshot_roundtrip_and_old_format(stack):
    cfg, model, params, lay = stack
    prompts = [RNG.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
               for L in (9, 13)]

    def mk():
        eng = _engine(model, lay)
        for p in prompts:
            eng.submit(p, 6)
        return eng

    ref = mk()
    full = ref.run(params)

    # run half, snapshot, restore into a fresh engine: registry AND tokens
    eng = mk()
    for _ in range(4):
        eng.step(params)
    snap = eng.state_dict()
    eng2 = mk()
    eng2.load_state(snap)
    assert eng2.registry.state_dict() == eng.registry.state_dict()
    assert dict(eng2.counters) == dict(eng.counters)
    while eng2.step(params):
        pass
    res = eng2.batcher.results()
    assert all(np.array_equal(full[r], res[r]) for r in full)

    # OLD-format snapshot: strip the "metrics" key (pre-registry snapshots
    # carried only the counters dict) — must still load, counters intact
    leaves, treedef = jax.tree_util.tree_flatten(snap)
    old = jax.tree_util.tree_unflatten(treedef, leaves)
    ctl_leaf = None
    for i, leaf in enumerate(leaves):
        try:
            d = json.loads(bytes(np.asarray(leaf)).decode())
            if isinstance(d, dict) and "counters" in d:
                ctl_leaf, ctl, idx = leaf, d, i
        except Exception:
            continue
    assert ctl_leaf is not None and "metrics" in ctl
    del ctl["metrics"]
    blob = np.frombuffer(json.dumps(ctl).encode(), np.uint8)
    leaves[idx] = blob
    old = jax.tree_util.tree_unflatten(treedef, leaves)
    eng3 = mk()
    eng3.load_state(old)
    assert dict(eng3.counters) == dict(eng.counters)
    while eng3.step(params):
        pass
    res3 = eng3.batcher.results()
    assert all(np.array_equal(full[r], res3[r]) for r in full)


def test_engine_trace_lifecycle_events(stack):
    cfg, model, params, lay = stack
    obs = Observability(tracing=True)
    eng = _engine(model, lay, obs=obs)
    p = RNG.integers(0, cfg.vocab_size, (10,)).astype(np.int32)
    eng.submit(p, 4)
    eng.run(params)
    names = {e["name"] for e in obs.tracer.events()}
    for want in ("engine.step", "assemble", "chunk_prefill", "ragged_decode",
                 "sample", "request.submitted", "request.admitted",
                 "request.first_token", "request.finished"):
        assert want in names, want
    # spans nest: phases sit at depth 1 inside engine.step on one track
    steps = obs.tracer.find("engine.step")
    assert len(steps) == eng.counters["engine_steps"]
    assert all(e["depth"] == 0 for e in steps)
    assert all(e["depth"] == 1 for e in obs.tracer.find("assemble"))
    ft = obs.tracer.find("request.first_token")[0]
    assert ft["args"]["ttft_s"] > 0
    validate_chrome_trace(obs.tracer.to_chrome_trace())


def test_engine_default_obs_disabled(stack):
    """No obs argument: tracer is the shared no-op, metrics still count."""
    cfg, model, params, lay = stack
    eng = _engine(model, lay)
    assert eng.tracer is NULL_TRACER
    assert not eng.obs.tracing


# ======================= FT events through the tracer =================== #
def test_supervisor_fault_events_land_in_trace(stack, tmp_path):
    from repro.ft import FaultInjector, FaultPlan, ServeSupervisor

    cfg, model, params, lay = stack
    prompts = [RNG.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
               for L in (9, 7)]
    obs = Observability(tracing=True)

    def mk():
        eng = _engine(model, lay, obs=obs)
        for p in prompts:
            eng.submit(p, 4)
        return eng

    sup = ServeSupervisor(
        mk, params, str(tmp_path / "ck"), checkpoint_every=2,
        injector=FaultInjector(FaultPlan(crash_steps=frozenset({3}))),
        obs=obs)
    eng, hist = sup.run()
    assert hist["restarts"] == 1
    names = [e["name"] for e in obs.tracer.events()]
    assert "ft.fault" in names and "ft.restart" in names \
        and "ft.snapshot" in names
    fault = obs.tracer.find("ft.fault")[0]
    assert fault["args"]["kind"] == "StepCrash"
    # crash at attempt 3 lands after the step-2 checkpoint: a restore event
    assert obs.tracer.find("ft.restore")
    assert obs.registry.value("ft_restarts") == 1
    assert obs.registry.value("ft_faults", kind="StepCrash") == 1
    # engine spans and supervisor instants share one exported timeline
    doc = obs.tracer.to_chrome_trace()
    validate_chrome_trace(doc)
    tracks = {e["args"]["name"] for e in doc["traceEvents"]
              if e["ph"] == "M"}
    assert {"engine", "requests", "ft"} <= tracks


def test_run_with_restarts_events(tmp_path):
    from repro.ft import CheckpointManager, run_with_restarts

    obs = Observability(tracing=True)
    mgr = CheckpointManager(tmp_path / "ck", keep=2, async_write=False)
    state, hist = run_with_restarts(
        lambda s, i: s + 1, 0, 8, mgr, checkpoint_every=2,
        fail_at={5}, obs=obs)
    assert state == 8 and hist["restarts"] == 1
    assert obs.tracer.find("ft.fault") and obs.tracer.find("ft.restore")
    assert len(obs.tracer.find("train.step")) == hist["steps_run"]
    assert obs.registry.value("ft_faults", kind="StepCrash") == 1


# ==================== runtime-ExecutionPlan metrics ===================== #
def test_dynamic_plan_build_metrics():
    """Tracing a plan="dynamic" attention accounts one build and one
    keep-ratio observation in the process-wide registry (host-side, at
    trace time — the same pattern as the kernel launch accounting)."""
    from repro.core import patterns as P
    from repro.core.attention import hybrid_attention
    from repro.obs.metrics import global_registry

    reg = global_registry()
    builds0 = (reg.value("dynamic_plan_builds")
               if "dynamic_plan_builds" in reg.families() else 0)
    h0 = (reg.hist("dynamic_plan_keep_ratio")
          if "dynamic_plan_keep_ratio" in reg.families() else None)
    count0 = h0.count if h0 is not None else 0

    rng = np.random.default_rng(11)
    q, k, v = (jnp.asarray(rng.normal(size=(1, 2, 96, 16)), jnp.float32)
               for _ in range(3))
    # an odd shape/keep combination, so this trace can't be jit-cached by
    # an earlier test (the accounting runs at trace time only)
    out = hybrid_attention(q, k, v, P.causal_sliding_window(31, n_sinks=3),
                           plan="dynamic", dynamic_keep=5,
                           block_q=16, block_k=16)
    assert np.all(np.isfinite(np.asarray(out)))
    assert reg.value("dynamic_plan_builds") >= builds0 + 1
    h = reg.hist("dynamic_plan_keep_ratio")
    assert h is not None and h.count >= count0 + 1
    # keep=5 of max 5-ish candidate steps: ratio lies in (0, 1]
    assert 0.0 < h.max <= 1.0
