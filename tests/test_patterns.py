"""Pattern/mask/scheduler unit + property tests."""
import numpy as np
import pytest

try:  # property tests need hypothesis; deterministic tests run without it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core import patterns as P
from repro.core.scheduler import schedule


def test_sliding_window_mask_matches_definition():
    pat = P.HybridSparsePattern(window=(-3, 2))
    m = pat.mask(10)
    for i in range(10):
        for j in range(10):
            assert m[i, j] == (-3 <= j - i <= 2)


def test_causal_sliding_window_sinks():
    pat = P.causal_sliding_window(4, n_sinks=2)
    m = pat.mask(12)
    for i in range(12):
        for j in range(12):
            expect = (j <= i) and (i - j < 4 or j < 2)
            assert m[i, j] == expect, (i, j)


def test_longformer_paper_sparsity():
    """Paper Table 2: Longformer n=4096 w=512 g=1 -> sparsity 0.125."""
    pat = P.longformer(512, n_global=1)
    s = pat.sparsity(4096)
    assert abs(s - 0.125) < 0.01, s


def test_vil_stage_sparsities():
    """Paper Table 2: ViL-stage1 0.072, ViL-stage2 0.288. Those are the
    interior approximation window^2/grid^2 (no edge clipping); our exact
    mask is necessarily <= that and close to it."""
    for grid, paper in (((56, 56), 0.072), ((28, 28), 0.288)):
        interior = 15 * 15 / (grid[0] * grid[1])
        assert abs(interior - paper) < 0.002  # paper's formula recovered
        exact = P.vil(grid, (15, 15), 1).sparsity(1 + grid[0] * grid[1])
        assert exact <= interior + 1e-6
        assert exact > 0.7 * interior  # same ballpark (edge effect only)


def test_dilated_mask():
    pat = P.dilated_window(4, 3)
    m = pat.mask(20)
    i = 10
    attended = set(np.nonzero(m[i])[0])
    expect = {j for j in range(20)
              if (j - i) % 3 == 0 and -6 <= j - i <= 3}
    assert attended == expect


def test_2d_mask_neighbourhood():
    pat = P.vil((5, 7), (3, 3), n_global=1)
    m = pat.mask(1 + 35)
    # global token attends everything and is attended by everything
    assert m[0].all() and m[:, 0].all()
    # token at grid (2,3) = index 1 + 2*7+3 = 18
    i = 18
    att = set(np.nonzero(m[i])[0]) - {0}
    expect = {1 + y * 7 + x for y in (1, 2, 3) for x in (2, 3, 4)}
    assert att == expect


if HAVE_HYPOTHESIS:
    @given(w=st.integers(1, 9), d=st.integers(1, 4), n=st.integers(4, 64),
           g=st.integers(0, 3), causal=st.booleans())
    @settings(max_examples=60, deadline=None)
    def test_schedule_bands_cover_mask(w, d, n, g, causal):
        """Property: the band schedule + global column covers EXACTLY the
        pattern mask (no pair missed, none double-counted)."""
        pat = P.causal_sliding_window(w, n_sinks=g, dilation=d) if causal \
            else P.HybridSparsePattern(
                window=(-(w // 2) * d, (w - w // 2 - 1) * d),
                dilation=d, n_global=g, global_rows=False)
        sched = schedule(pat, n)
        mask = pat.mask(n)
        pos = sched.positions()
        nw = sched.n_work
        covered = np.zeros((n, n), dtype=int)
        # band coverage in working space
        for band in sched.bands:
            for wi in range(nw):
                for wj in range(max(0, wi + band.lo),
                                min(nw, wi + band.hi + 1)):
                    pi, pj = pos[wi], pos[wj]
                    if pi < n and pj < n:
                        wm = bool(np.asarray(sched.window_mask(pi, pj)))
                        if wm:
                            covered[pi, pj] += 1
        # global column
        for pi in range(n):
            for pj in range(min(g, n)):
                if bool(np.asarray(sched.global_col_mask(pi, pj))):
                    covered[pi, pj] += 1
        assert (covered <= 1).all(), "double counted"
        np.testing.assert_array_equal(covered.astype(bool), mask)

    @given(d=st.integers(1, 5), n=st.integers(3, 50))
    @settings(max_examples=30, deadline=None)
    def test_reorder_perm_is_permutation(d, n):
        pat = P.causal_sliding_window(2, dilation=d)
        sched = schedule(pat, n)
        if sched.perm is None:
            assert d == 1
            return
        inv = sched.inverse_perm()
        assert sorted(sched.perm[sched.perm < n]) == list(range(n))
        np.testing.assert_array_equal(sched.perm[inv], np.arange(n))
else:  # visible skips, not silent disappearance
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_schedule_bands_cover_mask():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_reorder_perm_is_permutation():
        pass


def test_work_estimate_utilization():
    """Paper §6.3: SALO's PE utilization > 75% on its workloads (the tiled
    analog: useful pairs / executed pairs at the paper tile size)."""
    pat = P.longformer(512, n_global=1)
    sched = schedule(pat, 4096)
    est = sched.work_estimate(32, 32)
    assert est["utilization"] > 0.75, est
