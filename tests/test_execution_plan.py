"""ExecutionPlan IR tests: exact coverage, real dedup, and ONE launch.

The plan is the single source of truth for the tile walk + masks of both
engines, so these tests pin down its contract:

  * simulating the step tables + `step_mask` covers EXACTLY `pattern.mask()`
    (window part — global rows are a dense epilogue): no missed pairs, no
    double-counted pairs, across 1-D / dilated / 2-D / causal / global;
  * deduplication is real: ViL's overlapping bands execute strictly fewer
    tiles than the sum of per-band walks;
  * the whole hybrid pattern is exactly ONE `pallas_call` per forward;
  * ViL multi-band pallas_interpret == dense_ref.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import patterns as P
from repro.core.scheduler import (STEP_GLOBAL, STEP_WINDOW, build_plan,
                                  schedule)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False


# --------------------------- coverage oracle --------------------------- #
def _simulate_coverage(pat, n, block_q, block_k):
    """Walk the plan tables exactly as the engines do; count mask hits per
    ORIGINAL (i, j) pair. Returns the (n, n) count matrix."""
    sched = schedule(pat, n)
    plan = build_plan(sched, block_q, block_k)
    pos = plan.positions_padded()
    counts = np.zeros((n, n), dtype=int)
    for i in range(plan.nq):
        pos_q = pos[i * block_q:(i + 1) * block_q]
        for s in range(int(plan.num_steps[i])):
            t = int(plan.kv_blocks[i, s])
            fl = int(plan.flags[i, s])
            pos_k = pos[t * block_k:(t + 1) * block_k]
            mask = np.asarray(plan.step_mask(
                jnp.asarray(pos_q)[:, None], jnp.asarray(pos_k)[None, :],
                jnp.int32(fl)))
            qi, kj = np.nonzero(mask)
            counts[pos_q[qi], pos_k[kj]] += 1
    return counts, plan


def _window_part_mask(pat, n):
    """pattern.mask() minus the global-rows overwrite (dense epilogue)."""
    m = pat.mask(n).copy()
    if pat.n_global > 0 and pat.global_rows:
        # plan covers global ROWS only where window/global-col do; the dense
        # epilogue overwrites those rows, so exclude them from the contract
        sub = P.HybridSparsePattern(
            window=pat.window, dilation=pat.dilation, n_global=pat.n_global,
            global_rows=False, causal=pat.causal, grid2d=pat.grid2d,
            window2d=pat.window2d)
        m = sub.mask(n)
    return m


PLAN_CASES = [
    ("sliding", P.HybridSparsePattern(window=(-3, 2)), 20, 8, 8),
    ("causal_sw", P.causal_sliding_window(7), 33, 8, 16),
    ("sinks", P.causal_sliding_window(6, n_sinks=3), 40, 16, 8),
    ("longformer", P.longformer(8, n_global=2), 37, 8, 8),
    ("longformer_causal", P.longformer(8, n_global=2, causal=True),
     37, 8, 8),
    ("dilated", P.dilated_window(4, 3), 29, 8, 8),
    ("dilated_causal", P.dilated_window(4, 3, causal=True), 29, 8, 8),
    ("dilated_sinks", P.causal_sliding_window(5, n_sinks=2, dilation=2),
     31, 8, 8),
    ("vil_2d", P.vil((5, 7), (3, 3), n_global=2), 37, 8, 8),
    ("vil_2d_wide", P.vil((4, 5), (3, 5), n_global=1), 21, 4, 8),
    # ww > W: adjacent bands' rel ranges overlap — the per-band walk with a
    # rel-only restriction double-counted these; one-visit-per-tile can't.
    ("vil_2d_overlap", P.vil((5, 4), (3, 5), n_global=1), 21, 8, 8),
    ("asym", P.HybridSparsePattern(window=(-5, 3), n_global=3,
                                   global_rows=False), 26, 8, 4),
    ("full_causal", P.full(causal=True), 19, 8, 8),
]


@pytest.mark.parametrize("name,pat,n,bq,bk", PLAN_CASES)
def test_plan_covers_mask_exactly(name, pat, n, bq, bk):
    """Each attended pair is visited EXACTLY once; nothing else ever is."""
    counts, _ = _simulate_coverage(pat, n, bq, bk)
    expect = _window_part_mask(pat, n)
    assert (counts <= 1).all(), f"{name}: double-counted pairs"
    np.testing.assert_array_equal(counts.astype(bool), expect,
                                  err_msg=f"{name}: coverage != mask")


if HAVE_HYPOTHESIS:
    @given(w=st.integers(1, 9), d=st.integers(1, 4), n=st.integers(4, 64),
           g=st.integers(0, 3), causal=st.booleans(),
           bq=st.sampled_from([4, 8, 16]), bk=st.sampled_from([4, 8, 16]))
    @settings(max_examples=40, deadline=None)
    def test_plan_coverage_property(w, d, n, g, causal, bq, bk):
        pat = (P.causal_sliding_window(w, n_sinks=g, dilation=d) if causal
               else P.HybridSparsePattern(
                   window=(-(w // 2) * d, (w - w // 2 - 1) * d),
                   dilation=d, n_global=g, global_rows=False))
        counts, _ = _simulate_coverage(pat, n, bq, bk)
        assert (counts <= 1).all()
        np.testing.assert_array_equal(counts.astype(bool), pat.mask(n))
else:  # visible skip, not silent disappearance
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_plan_coverage_property():
        pass


# ------------------------------ dedup ---------------------------------- #
def test_vil_dedup_is_real():
    """ViL multi-band: fused executed tiles STRICTLY below the sum of
    per-band walks (the tiles overlapping bands used to re-fetch)."""
    pat = P.vil((8, 9), (3, 5), n_global=1)
    sched = schedule(pat, pat.seq_len())
    assert len(sched.bands) >= 3
    plan = build_plan(sched, 16, 16)
    stats = plan.stats()
    assert stats["executed_tiles"] < stats["per_band_tiles"], stats
    assert stats["launches"] == 1
    assert stats["per_band_launches"] == len(sched.bands)


def test_vil_15_band_dedup_ratio():
    """Paper-scale ViL (15 bands, 64x64 grid, 128-tiles): dedup collapses
    the walk by >2x — the launch-per-band path re-fetched that much."""
    pat = P.vil((64, 64), (15, 15), n_global=1)
    sched = schedule(pat, pat.seq_len())
    assert len(sched.bands) == 15
    plan = build_plan(sched, 128, 128)
    stats = plan.stats()
    assert stats["per_band_tiles"] / stats["executed_tiles"] > 2.0, stats


def test_work_estimate_uses_plan():
    """work_estimate no longer over-counts overlapping bands."""
    pat = P.vil((8, 9), (3, 5), n_global=1)
    sched = schedule(pat, pat.seq_len())
    est = sched.work_estimate(16, 16)
    assert est["executed_pairs"] == est["executed_tiles"] * 16 * 16
    # single-band sanity: longformer utilization stays high
    est_lf = schedule(P.longformer(512, n_global=1), 4096).work_estimate(
        32, 32)
    assert est_lf["utilization"] > 0.75


def test_band_set_ids_index_covering_bands():
    """band_set_ids tags each visit with the bands whose walk covers it."""
    pat = P.vil((5, 7), (3, 3), n_global=1)
    sched = schedule(pat, pat.seq_len())
    plan = build_plan(sched, 8, 8)
    for i in range(plan.nq):
        for s in range(int(plan.num_steps[i])):
            sid = int(plan.band_set_ids[i, s])
            fl = int(plan.flags[i, s])
            bset = plan.band_sets[sid]
            assert (fl & STEP_WINDOW != 0) == (len(bset) > 0)
            t = int(plan.kv_blocks[i, s])
            for bi in bset:
                band = sched.bands[bi]
                s0 = band.kv_start_block(i, 8, 8)
                assert s0 <= t < s0 + band.kv_steps(8, 8)
    # padding steps carry no band set and no flags
    for i in range(plan.nq):
        for s in range(int(plan.num_steps[i]), plan.max_steps):
            assert plan.band_set_ids[i, s] == -1
            assert plan.flags[i, s] == 0


def test_global_tiles_follow_reordering():
    """Dilation scatters the global keys; the plan's STEP_GLOBAL tiles must
    follow them into the reordered working stream."""
    pat = P.causal_sliding_window(5, n_sinks=3, dilation=2)
    sched = schedule(pat, 30)
    plan = build_plan(sched, 8, 8)
    pos = plan.positions_padded()
    gtiles = {t for t in range(plan.nkb)
              if (pos[t * 8:(t + 1) * 8] < 3).any()}
    assert len(gtiles) > 1  # reordering really scattered the sinks
    for i in range(plan.nq):
        row = {int(plan.kv_blocks[i, s])
               for s in range(int(plan.num_steps[i]))
               if plan.flags[i, s] & STEP_GLOBAL}
        assert row == gtiles


# ------------------------- one launch, one truth ------------------------ #
def _count_pallas_calls(monkeypatch, fn):
    """Count pallas_call invocations during (re)tracing of ``fn()``."""
    from jax.experimental import pallas as pl_mod
    from repro.kernels import salo_attention as sa

    counter = {"n": 0}
    real = pl_mod.pallas_call

    def counting(*args, **kwargs):
        counter["n"] += 1
        return real(*args, **kwargs)

    monkeypatch.setattr(sa.pl, "pallas_call", counting)
    out = fn()
    return counter["n"], out


LAUNCH_CASES = [
    # ViL: 2-D, >= 3 bands, global token
    ("vil", P.vil((8, 9), (3, 5), n_global=1), 16, 16),
    # reordered + global: dilated sliding window + attention sinks
    ("dilated_sinks", P.causal_sliding_window(6, n_sinks=2, dilation=3),
     16, 16),
]


@pytest.mark.parametrize("name,pat,bq,bk", LAUNCH_CASES)
def test_exactly_one_pallas_call_per_forward(monkeypatch, name, pat, bq, bk):
    from repro.kernels.ops import salo_attention
    from repro.kernels.ref import reference_attention

    n = pat.seq_len() or 50
    rng = np.random.default_rng(0)
    q, k, v = (jnp.asarray(rng.normal(size=(2, n, 16)), jnp.float32)
               for _ in range(3))
    launches, out = _count_pallas_calls(
        monkeypatch, lambda: salo_attention(q, k, v, pat, bq, bk, None, True))
    assert launches == 1, f"{name}: {launches} launches (want exactly 1)"
    ref = reference_attention(q, k, v, pat)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_vil_multiband_interpret_matches_dense_ref():
    """ViL (15 overlapping bands at this tile size) end to end through the
    fused kernel in interpret mode vs the dense oracle."""
    from repro.core.attention import hybrid_attention

    pat = P.vil((8, 9), (5, 5), n_global=2)
    n = pat.seq_len()
    rng = np.random.default_rng(1)
    q, k, v = (jnp.asarray(rng.normal(size=(1, 2, n, 32)), jnp.float32)
               for _ in range(3))
    ref = hybrid_attention(q, k, v, pat, impl="dense_ref")
    out = hybrid_attention(q, k, v, pat, impl="pallas_interpret",
                           block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_overlapping_bands_no_double_count_end_to_end():
    """ww > W makes adjacent bands' offset ranges overlap; both engines must
    still weight each pair once (softmax would skew if counted twice)."""
    from repro.core.attention import hybrid_attention

    pat = P.vil((5, 4), (3, 5), n_global=1)
    n = pat.seq_len()
    rng = np.random.default_rng(2)
    q, k, v = (jnp.asarray(rng.normal(size=(1, 2, n, 16)), jnp.float32)
               for _ in range(3))
    ref = hybrid_attention(q, k, v, pat, impl="dense_ref")
    for impl in ("blockwise", "pallas_interpret"):
        out = hybrid_attention(q, k, v, pat, impl=impl, block_q=8,
                               block_k=8)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3, err_msg=impl)


def test_blockwise_and_kernel_share_plan_tables():
    """Both engines consume the identical plan object (single source of
    truth): same tables, same masks."""
    pat = P.vil((5, 7), (3, 3), n_global=1)
    sched = schedule(pat, pat.seq_len())
    p1 = build_plan(sched, 8, 8)
    p2 = sched.plan(8, 8)
    assert p1 is p2  # lru-cached: one plan per (schedule, tile geometry)
