"""The step-table contract (core/plan_contract.py), fuzzed:
  * randomly generated VALID tables — random widths, random real/padding
    interleaving, random tile order, both flag bits — are accepted by
    validate_tables, and the fused Pallas table kernel (interpret mode)
    plus the XLA scan twin both match a dense reference built from the
    union of per-step masks (the contract's semantics)
  * each contract violation is rejected with a specific ValueError
  * traced table values downgrade to structural-only checks (the jit path
    runtime builders rely on)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import patterns as P
from repro.core.blockwise import table_attention_scan
from repro.core.plan_contract import validate_tables
from repro.core.scheduler import schedule
from repro.kernels.salo_attention import salo_table_attention

BLK = 16
SCHEDS = [
    ("longformer", schedule(P.longformer(16, n_global=8), 128)),
    ("window_sinks", schedule(P.causal_sliding_window(24, n_sinks=4), 128)),
]


def _random_tables(rng, nq, nkb, width):
    """A random contract-conforming table: per row a random-size set of
    distinct tiles with random flags, scattered among interleaved padding
    steps (padding placement is NOT constrained by the contract)."""
    kvt = np.zeros((nq, width), np.int32)
    flg = np.zeros((nq, width), np.int32)
    for i in range(nq):
        r = int(rng.integers(0, min(width, nkb) + 1))
        tiles = rng.choice(nkb, size=r, replace=False)
        slots = rng.choice(width, size=r, replace=False)
        for t, s in zip(tiles, slots):
            kvt[i, s] = t
            flg[i, s] = int(rng.integers(1, 4))    # WINDOW, GLOBAL, or both
    return kvt, flg


def _dense_from_tables(qw, kw, vw, pos_q, pos_k, kvt, flg, sched, scale):
    """The contract's meaning: the union of per-step masks applied to a
    dense softmax over the working grid (rows with no allowed key -> 0)."""
    nq, bq = pos_q.shape
    nkb, bk = pos_k.shape
    allow = np.zeros((nq * bq, nkb * bk), bool)
    for i in range(nq):
        for s in range(kvt.shape[1]):
            f = int(flg[i, s])
            if f == 0:
                continue
            t = int(kvt[i, s])
            m = np.asarray(sched.step_mask(
                pos_q[i][:, None], pos_k[t][None, :], f))
            allow[i * bq:(i + 1) * bq, t * bk:(t + 1) * bk] |= m
    s_ = np.einsum("bqd,bkd->bqk", np.asarray(qw, np.float64),
                   np.asarray(kw, np.float64)) * scale
    s_ = np.where(allow[None], s_, -np.inf)
    mx = np.max(s_, axis=-1, keepdims=True)
    e = np.exp(s_ - np.where(np.isfinite(mx), mx, 0.0))
    den = e.sum(-1, keepdims=True)
    p = np.where(den > 0, e / np.maximum(den, 1e-30), 0.0)
    return p @ np.asarray(vw, np.float64)


@pytest.mark.parametrize("name,sched", SCHEDS)
def test_fuzz_valid_tables_accepted_and_engines_match(name, sched):
    """~12 random valid tables per schedule: validate_tables accepts, and
    both consumers (Pallas interpret kernel, XLA scan twin) agree with
    the mask-union dense reference."""
    rng = np.random.default_rng(0)
    plan = sched.plan(BLK, BLK)
    pos = plan.positions_padded()
    pos_q = pos.reshape(plan.nq, BLK)
    pos_k = pos.reshape(plan.nkb, BLK)
    n_pad = pos.shape[0]
    B, D = 2, 16
    scale = D ** -0.5
    for case in range(12):
        width = int(rng.integers(1, 7))
        kvt, flg = _random_tables(rng, plan.nq, plan.nkb, width)
        validate_tables(kvt, flg, nkb=plan.nkb,
                        name=f"fuzz[{name}/{case}]")
        qw, kw, vw = (jnp.asarray(rng.normal(size=(B, n_pad, D)),
                                  jnp.float32) for _ in range(3))
        ref = _dense_from_tables(qw, kw, vw, pos_q, pos_k, kvt, flg,
                                 sched, scale)
        out_k, _, _ = salo_table_attention(
            qw, kw, vw, jnp.asarray(pos_q), jnp.asarray(pos_k),
            jnp.asarray(kvt.reshape(-1)), jnp.asarray(flg.reshape(-1)),
            sched=sched, block_q=BLK, block_k=BLK, scale=scale,
            interpret=True)
        np.testing.assert_allclose(
            np.asarray(out_k), ref, rtol=2e-5, atol=2e-5,
            err_msg=f"{name} case {case}: pallas kernel vs mask union")
        out_s, _, _ = table_attention_scan(
            qw, kw, vw, jnp.asarray(pos_q), jnp.asarray(pos_k),
            jnp.asarray(kvt), jnp.asarray(flg), sched, scale)
        np.testing.assert_allclose(
            np.asarray(out_s), ref, rtol=2e-5, atol=2e-5,
            err_msg=f"{name} case {case}: scan twin vs mask union")


def test_static_builder_tables_pass():
    """Every static plan's tables satisfy the contract it defined."""
    for name, sched in SCHEDS:
        plan = sched.plan(BLK, BLK)
        validate_tables(plan.kv_blocks, plan.flags, nkb=plan.nkb,
                        num_steps=plan.num_steps, name=name)


def _ok():
    kvt = np.array([[1, 0, 2], [0, 0, 0]], np.int32)
    flg = np.array([[1, 3, 2], [0, 0, 0]], np.int32)
    return kvt, flg


def test_rejects_shape_and_dtype():
    kvt, flg = _ok()
    with pytest.raises(ValueError, match="rank-2"):
        validate_tables(kvt.reshape(-1), flg.reshape(-1), nkb=4)
    with pytest.raises(ValueError, match="rank-2"):
        validate_tables(kvt, flg[:, :2], nkb=4)
    with pytest.raises(ValueError, match="width"):
        validate_tables(kvt[:, :0], flg[:, :0], nkb=4)
    with pytest.raises(ValueError, match="int32"):
        validate_tables(kvt.astype(np.float32), flg, nkb=4)
    with pytest.raises(ValueError, match="int32"):
        validate_tables(kvt, flg.astype(np.int64), nkb=4)
    with pytest.raises(ValueError, match="nkb"):
        validate_tables(kvt, flg, nkb=0)


def test_rejects_value_violations():
    kvt, flg = _ok()
    validate_tables(kvt, flg, nkb=4)                      # baseline passes
    bad_f = flg.copy()
    bad_f[0, 0] = 4                                       # unknown bit
    with pytest.raises(ValueError, match="unknown flag bits"):
        validate_tables(kvt, bad_f, nkb=4)
    bad_t = kvt.copy()
    bad_t[0, 2] = 9                                       # out of range
    with pytest.raises(ValueError, match=r"outside \[0, 4\)"):
        validate_tables(bad_t, flg, nkb=4)
    with pytest.raises(ValueError, match="outside"):
        validate_tables(-kvt, flg, nkb=4)
    bad_p = kvt.copy()
    bad_p[1, 1] = 3                                       # padding w/ tile
    with pytest.raises(ValueError, match="padding step"):
        validate_tables(bad_p, flg, nkb=4)
    dup_t = np.array([[2, 1, 2]], np.int32)               # tile 2 twice
    dup_f = np.array([[1, 1, 2]], np.int32)
    with pytest.raises(ValueError, match="more than once"):
        validate_tables(dup_t, dup_f, nkb=4)
    # padding steps aliasing tile 0 do NOT count as duplicate visits
    validate_tables(np.array([[0, 0, 0]], np.int32),
                    np.array([[1, 0, 0]], np.int32), nkb=4)


def test_rejects_num_steps_violations():
    kvt, flg = _ok()
    validate_tables(kvt, flg, nkb=4, num_steps=np.array([3, 0]))
    with pytest.raises(ValueError, match="right-aligned"):
        validate_tables(kvt, flg, nkb=4, num_steps=np.array([2, 0]))
    with pytest.raises(ValueError, match="num_steps outside"):
        validate_tables(kvt, flg, nkb=4, num_steps=np.array([5, 0]))
    gap_t = np.array([[1, 0, 2]], np.int32)               # hole in prefix
    gap_f = np.array([[1, 0, 2]], np.int32)
    with pytest.raises(ValueError, match="right-aligned"):
        validate_tables(gap_t, gap_f, nkb=4, num_steps=np.array([3]))


def test_traced_values_structural_only():
    """Inside jit the VALUES are unknowable: structural checks still apply
    (and fail eagerly), value checks are skipped — contract-breaking
    values must flow through untouched (runtime builders validate their
    materialized twins in tests instead)."""
    def f(kvt, flg):
        validate_tables(kvt, flg, nkb=2, name="traced")
        return kvt + flg

    bad_kvt = jnp.array([[7, 7]], jnp.int32)        # oob + dup: not checked
    bad_flg = jnp.array([[1, 1]], jnp.int32)
    jax.jit(f)(bad_kvt, bad_flg)                    # must not raise

    def g(kvt, flg):
        validate_tables(kvt.astype(jnp.float32), flg, nkb=2, name="traced")
        return kvt

    with pytest.raises(ValueError, match="int32"):
        jax.jit(g)(bad_kvt, bad_flg)
