"""Per-architecture smoke tests (assignment requirement): reduced config of
the same family, one forward/train step on CPU, output shapes + no NaNs —
plus a decode step against the cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke
from repro.models.model import build_model


def _batch(cfg, rng, B=2, S=64):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))}
    if cfg.encoder_decoder:
        batch["audio_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_audio_frames, cfg.d_model)),
            jnp.float32)
    if cfg.n_vision_tokens:
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
        batch["vision_mask"] = jnp.asarray(
            rng.integers(0, 2, (B, S)).astype(bool))
    return batch


@pytest.fixture(scope="module")
def np_rng():
    return np.random.default_rng(7)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_loss_grad(arch, np_rng):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, np_rng)
    logits = jax.jit(model.forward)(params, batch)
    assert logits.shape == (2, 64, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    loss, metrics = model.loss(params, batch)
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gn = sum(jnp.sum(jnp.abs(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    assert bool(jnp.isfinite(gn)) and float(gn) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch, np_rng):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, maxlen = 2, 32
    cache = model.init_cache(B, maxlen)
    batch = _batch(cfg, np_rng, B=B, S=1)
    bt = {k: v for k, v in batch.items() if k != "labels"}
    step = jax.jit(model.decode_step)
    for t in range(3):
        logits, cache = step(params, cache, bt, t)
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_decode_matches_forward_smollm(np_rng):
    """Teacher-forced decode == full forward (same tokens), step by step."""
    cfg = get_smoke("smollm-135m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 24
    batch = _batch(cfg, np_rng, B=B, S=S)
    full = model.forward(params, batch)
    cache = model.init_cache(B, S)
    step = jax.jit(model.decode_step)
    for t in range(S):
        logits, cache = step(params, cache,
                             {"tokens": batch["tokens"][:, t:t + 1]}, t)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32),
            np.asarray(full[:, t], np.float32), rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ["mamba2-370m", "recurrentgemma-9b"])
def test_recurrent_decode_matches_forward(arch, np_rng):
    """SSM/RG-LRU recurrent decode == chunked/scan full forward."""
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch(cfg, np_rng, B=B, S=S)
    full = model.forward(params, batch)
    cache = model.init_cache(B, S)
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(S):
        logits, cache = step(params, cache,
                             {"tokens": batch["tokens"][:, t:t + 1]}, t)
        outs.append(np.asarray(logits[:, 0], np.float32))
    np.testing.assert_allclose(np.stack(outs, 1),
                               np.asarray(full, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_param_count_formulas():
    """n_params() formula vs actual initialized parameter count."""
    for arch in ("smollm-135m", "gemma-7b", "kimi-k2-1t-a32b"):
        cfg = get_smoke(arch)
        model = build_model(cfg)
        params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        actual = sum(np.prod(p.shape) for p in jax.tree.leaves(params))
        est = cfg.n_params()
        assert 0.5 < actual / est < 2.0, (arch, actual, est)
