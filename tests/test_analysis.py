"""repro.analysis: the prover proves builder plans sound, and every
seeded mutation class is caught with a specific counterexample."""
import dataclasses
import warnings

import numpy as np
import pytest

import repro.core.patterns as P
from repro.analysis import Finding, plan_verify as pv, render
from repro.analysis.code_lint import lint_paths, lint_source
from repro.analysis.registry import chunk_targets, plan_targets
from repro.core.scheduler import build_chunk_plan, build_plan, schedule

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False


def _plan(pattern=None, n=256, bq=32, bk=32, pad=None):
    pattern = pattern or P.longformer(64, n_global=8)
    sched = schedule(pattern, n)
    if pad is not None:
        return build_plan(sched, bq, bk, pad)
    return sched.plan(bq, bk)


# ---------------------------------------------------------------------- #
# The builder's plans prove sound
# ---------------------------------------------------------------------- #
def test_registry_targets_prove_sound():
    for t in plan_targets()[:3]:
        plan = schedule(t.pattern, t.n).plan(t.block_q, t.block_k)
        assert pv.verify_plan(plan, t.name) == []


def test_sharded_and_never_drop_prove_sound():
    plan = _plan(pad=2 * 32)
    assert pv.verify_sharded(plan, 2) == []
    assert pv.verify_never_drop(_plan(P.causal_sliding_window(
        32, n_sinks=8), 256), local_window=32) == []


def test_chunk_slices_prove_sound():
    t = chunk_targets()[0]
    from repro.serve.paged_cache import layout_for_pattern
    lay = layout_for_pattern(t.pattern, t.page)
    c0 = 0
    while c0 < t.prompt:
        clen = min(t.chunk, t.prompt - c0)
        cp = build_chunk_plan(t.pattern, c0, clen, n_sink=lay.n_sink,
                              ring_cap=lay.ring_cap, block=t.page)
        assert pv.verify_chunk(cp, n_shards=t.n_shards) == []
        c0 += clen


def test_dynamic_full_keep_matches_static():
    plan = _plan(P.causal_sliding_window(32, n_sinks=8), 256)
    assert pv.verify_dynamic_full_keep(plan) == []


# ---------------------------------------------------------------------- #
# Seeded mutations: each class caught, with the offending tile named
# ---------------------------------------------------------------------- #
def _drop_covering_step(plan):
    """Zero out a step that really covers pairs (the diagonal tile —
    boundary tiles can be conservatively scheduled yet pair-empty)."""
    kv, fl = plan.kv_blocks.copy(), plan.flags.copy()
    i, s = next((i, s) for i in range(plan.nq)
                for s in range(int(plan.num_steps[i]))
                if kv[i, s] == i and fl[i, s] != 0)
    kv[i, s] = 0
    fl[i, s] = 0
    return dataclasses.replace(plan, kv_blocks=kv, flags=fl), i


def test_mutation_dropped_tile():
    plan = _plan()
    mut, i = _drop_covering_step(plan)
    findings = pv.verify_coverage(mut, "mut")
    assert findings, "dropped tile not caught"
    f = findings[0]
    assert "missing" in f.message and f.q_block == i
    assert f"q_block={f.q_block}" in f.counterexample()


def test_mutation_duplicated_tile():
    plan = _plan()
    kv, fl = plan.kv_blocks.copy(), plan.flags.copy()
    r = int(np.nonzero(plan.num_steps < plan.max_steps)[0][0])
    ns = int(plan.num_steps[r])
    kv[r, ns], fl[r, ns] = kv[r, 0], fl[r, 0]
    mut = dataclasses.replace(plan, kv_blocks=kv, flags=fl)
    findings = pv.verify_coverage(mut, "mut")
    assert findings and "double-counted" in findings[0].message
    assert findings[0].q_block == r


def test_mutation_wrong_flag():
    plan = _plan()
    kv, fl = plan.kv_blocks.copy(), plan.flags.copy()
    i, s = (int(x) for x in np.argwhere(fl == 1)[0])   # window-only step
    fl[i, s] = 2                                       # -> global-only
    mut = dataclasses.replace(plan, kv_blocks=kv, flags=fl)
    findings = pv.verify_coverage(mut, "mut")
    assert findings and "missing" in findings[0].message
    assert findings[0].q_block == i


def test_mutation_transposed_row_swap():
    plan = _plan()
    tp = plan.transposed()
    qb, fl, ns = (tp.q_blocks.copy(), tp.flags.copy(), tp.num_steps.copy())
    qb[[0, 1]], fl[[0, 1]], ns[[0, 1]] = qb[[1, 0]], fl[[1, 0]], ns[[1, 0]]
    mut = dataclasses.replace(tp, q_blocks=qb, flags=fl, num_steps=ns)
    findings = pv.verify_transposed(plan, mut, "mut")
    assert findings and "transposed walk" in findings[0].message


def test_mutation_broken_halo_hop():
    from repro.dist.sharded_plan import shard_plan
    plan = _plan(pad=2 * 32)
    sp = shard_plan(plan, 2)
    assert sp.halo_dists, "config must produce halo traffic"
    vm = np.asarray(sp.view_map)
    send = tuple(a.copy() for a in sp.send_idx)
    off = sp.nkb_l
    hop = None
    for d_i, (delta, T) in enumerate(zip(sp.halo_dists, sp.halo_counts)):
        for s in range(sp.n_shards):
            for slot in range(T):
                gt = int(vm[s, off + slot])
                if gt >= 0:
                    hop = (d_i, gt // sp.nkb_l, slot, gt)
                    break
            if hop:
                break
        if hop:
            break
        off += T
    d_i, owner, slot, gt = hop
    send[d_i][owner, slot] = (send[d_i][owner, slot] + 1) % sp.nkb_l
    mut = dataclasses.replace(sp, send_idx=send)
    findings = pv.verify_sharded(plan, 2, mut, "mut")
    assert findings
    assert any("no scheduled ppermute hop delivers" in f.message
               and f.kv_block == gt for f in findings)


def test_mutation_unfilled_view_slot():
    from repro.dist.sharded_plan import shard_plan
    plan = _plan(pad=2 * 32)
    sp = shard_plan(plan, 2)
    vm = np.asarray(sp.view_map).copy()
    used = np.unique(np.asarray(sp.tables)[np.asarray(sp.flags) != 0])
    vt = int(used[-1])
    vm[:, vt] = -1                       # exchange never fills this slot
    mut = dataclasses.replace(sp, view_map=vm)
    findings = pv.verify_sharded(plan, 2, mut, "mut")
    assert any("no exchange ever fills" in f.message for f in findings)


# ---------------------------------------------------------------------- #
# Finding plumbing + the gate's report
# ---------------------------------------------------------------------- #
def test_finding_counterexample_and_render():
    f = Finding("coverage", "t", "msg", q_block=3, kv_block=7)
    assert "(q_block=3, kv_block=7)" in f.counterexample()
    assert Finding(**f.as_dict()) == f
    assert "coverage" in render([f])
    assert render([]) == ""


# ---------------------------------------------------------------------- #
# Code lint: repo sources clean, synthetic violations caught
# ---------------------------------------------------------------------- #
def test_code_lint_repo_clean():
    assert lint_paths(["src", "tests", "benchmarks"]) == []


def test_code_lint_catches_violations():
    src = (
        "import os\n"
        "from typing import List\n"
        "def f(x=[]):\n"
        "    try:\n"
        "        pass\n"
        "    except:\n"
        "        pass\n"
        "list = 3\n"
    )
    msgs = [f.message for f in lint_source(src, "x.py")]
    assert any("unused import 'os'" in m for m in msgs)
    assert any("unused import 'List'" in m for m in msgs)
    assert any("mutable default" in m for m in msgs)
    assert any("bare 'except:'" in m for m in msgs)
    assert any("shadows builtin 'list'" in m for m in msgs)


def test_code_lint_allows_reexport_idiom():
    src = "from a import X as X\nfrom __future__ import annotations\n"
    assert lint_source(src, "x.py") == []


# ---------------------------------------------------------------------- #
# Jaxpr lint (cheap checks only — the gate runs the full set)
# ---------------------------------------------------------------------- #
def test_jaxpr_lint_negative_checks():
    import jax
    import jax.numpy as jnp

    from repro.analysis import jaxpr_lint as jl

    tr = jax.make_jaxpr(
        lambda x, i, u: x.at[i].add(u, unique_indices=True))(
            jnp.zeros(8), jnp.array([1, 1]), jnp.ones(2))
    assert any("write-write race" in f.message
               for f in jl.check_scatter_modes(tr, "t"))

    tr2 = jax.make_jaxpr(
        lambda x8: x8.astype(jnp.float32) + x8.astype(jnp.float32))(
            jnp.zeros(4, jnp.int8))
    assert any("double-dequant" in f.message
               for f in jl.check_double_dequant(tr2, "t"))


def test_jaxpr_lint_launch_contract_and_twins():
    from repro.analysis import jaxpr_lint as jl

    pat = P.longformer(32, n_global=4)
    assert jl.check_launch_contract(pat, 128, 32, 32, "t") == []
    assert jl.lint_traced(jl.trace_dkv_scatter(pat, 128, 32, 32), "t") == []
    assert jl.lint_traced(jl.trace_masked_psum_merge(), "t") == []


def test_write_ownership_probe():
    from repro.analysis import jaxpr_lint as jl
    from repro.serve.paged_cache import layout_for_pattern

    for shards in (1, 2):
        lay = layout_for_pattern(P.causal_sliding_window(16, n_sinks=2), 8,
                                 shards=shards)
        assert jl.check_write_ownership(lay, "t") == []


def test_vmem_estimates_within_budget():
    from repro.analysis import jaxpr_lint as jl

    plan = _plan(n=1024, bq=128, bk=128)
    assert jl.check_vmem(plan, d=64,
                         decode={"rep": 4, "head_dim": 64,
                                 "block_s": 8}) == []
    huge = _plan(P.longformer(2048, n_global=8), 4096, 2048, 2048)
    assert jl.check_vmem(huge, d=256), "oversized blocks must be flagged"


# ---------------------------------------------------------------------- #
# Deprecation pin (satellite: legacy lockstep cache)
# ---------------------------------------------------------------------- #
def test_ring_init_deprecation_warning():
    import jax.numpy as jnp

    from repro.serve.kv_cache import ring_init

    with pytest.warns(DeprecationWarning, match="LOCKSTEP"):
        ring_init(1, 8, 2, 1, 4, jnp.float32)
    # paged path warns nothing
    from repro.serve.paged_cache import layout_for_pattern
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        layout_for_pattern(P.causal_sliding_window(16, n_sinks=2), 8)


# ---------------------------------------------------------------------- #
# Property tests (hypothesis is an optional dependency)
# ---------------------------------------------------------------------- #
@pytest.mark.skipif(not HAVE_HYPOTHESIS,
                    reason="hypothesis not installed")
def test_property_random_patterns_prove_sound():
    @settings(max_examples=15, deadline=None)
    @given(window=st.integers(4, 24), n_global=st.integers(0, 6),
           causal=st.booleans(), dilation=st.sampled_from([1, 2]),
           block=st.sampled_from([8, 16]))
    def inner(window, n_global, causal, dilation, block):
        if dilation > 1:
            pat = P.causal_sliding_window(window, n_sinks=n_global,
                                          dilation=dilation)
        else:
            pat = P.longformer(2 * window, n_global=n_global,
                               causal=causal)
        plan = schedule(pat, 96).plan(block, block)
        assert pv.verify_coverage(plan) == []
        assert pv.verify_transposed(plan) == []
        assert pv.verify_packed(plan) == []
    inner()


@pytest.mark.skipif(not HAVE_HYPOTHESIS,
                    reason="hypothesis not installed")
def test_property_random_step_drop_is_caught():
    @settings(max_examples=10, deadline=None)
    @given(row=st.integers(0, 7))
    def inner(row):
        plan = _plan()
        r = row % plan.nq
        try:
            mut, i = _drop_covering_step(
                dataclasses.replace(plan))
        except StopIteration:
            return
        assert pv.verify_coverage(mut)
    inner()
