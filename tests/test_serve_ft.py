"""Fault-tolerant continuous serving: engine snapshot/restore with
exactly-once token emission, supervisor kill/resume, page-pressure
preemption + chunked re-prefill, admission control, deadlines, and the
deterministic fault-injection harness. Parity oracle throughout: the
lockstep ``ServeEngine`` (and, for kill/resume, the uninterrupted
continuous run)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.ft import (CheckpointManager, FaultInjector, FaultPlan,
                      ServeSupervisor, StragglerWatchdog, run_with_restarts,
                      save, sweep_stale_tmp)
from repro.ft.faults import (QueueFull, RejectedRequest, ResourceExhausted,
                             RestartsExhausted, StepCrash)
from repro.models.model import build_model
from repro.serve.engine import (ContinuousConfig, ContinuousEngine,
                                ServeConfig, ServeEngine)

RNG = np.random.default_rng(11)
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="module")
def stack():
    cfg = get_smoke("smollm-135m")   # window=16, page 8 -> 3 pages/request
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.models.layers import salo_pattern
    from repro.serve.paged_cache import layout_for_pattern
    lay = layout_for_pattern(salo_pattern(cfg, causal=True), 8)
    return cfg, model, params, lay


def _refs(model, params, prompts, n_new):
    out = []
    for p in prompts:
        eng = ServeEngine(model, ServeConfig(max_len=len(p) + n_new))
        out.append(np.asarray(
            eng.generate(params, jnp.asarray(p)[None], n_new))[0])
    return out


def _engine(model, lay, *, n_pages=None, max_batch=4, clock=None,
            max_queue=None):
    return ContinuousEngine(model, ContinuousConfig(
        n_pages=n_pages or 1 + max_batch * lay.pages_per_req, page=8,
        chunk=8, max_batch=max_batch, max_queue=max_queue), clock=clock)


# ===================== restart loop + checkpoint hygiene ================ #
def test_run_with_restarts_bounded(tmp_path):
    """A deterministically failing step no longer spins forever: after
    ``max_restarts`` restarts the loop raises RestartsExhausted (chaining
    the fault) instead of retrying — and bare RuntimeError is NOT in the
    recoverable taxonomy, so it propagates without a single restart."""
    mgr = CheckpointManager(tmp_path / "ck", keep=2, async_write=False)

    def bad_step(state, step):
        raise StepCrash("always")

    with pytest.raises(RestartsExhausted, match="after 3 restarts"):
        run_with_restarts(bad_step, 0, 5, mgr, checkpoint_every=2,
                          max_restarts=3)

    calls = []

    def rt_step(state, step):
        calls.append(step)
        raise RuntimeError("not a taxonomy fault")

    with pytest.raises(RuntimeError, match="not a taxonomy"):
        run_with_restarts(rt_step, 0, 5, mgr, checkpoint_every=2,
                          max_restarts=3)
    assert len(calls) == 1   # no retry on unclassified failures


def test_stale_tmp_sweep(tmp_path):
    """Orphaned ``tmp.<step>.<pid>`` staging dirs from crashed writers are
    garbage-collected: dead-pid and own-pid (pre-crash leftover) dirs go,
    a live foreign writer's dir survives, and ``save`` sweeps on entry."""
    d = tmp_path / "ck"
    d.mkdir()
    dead = subprocess.Popen([sys.executable, "-c", "pass"])
    dead.wait()   # reaped: the pid no longer exists
    for name in (f"tmp.3.{os.getpid()}", f"tmp.4.{dead.pid}", "tmp.5.1"):
        (d / name).mkdir()
        (d / name / "leaf.npy").write_bytes(b"x")
    assert sweep_stale_tmp(d) == 2
    assert sorted(p.name for p in d.iterdir()) == ["tmp.5.1"]
    (d / f"tmp.9.{dead.pid}").mkdir()
    save(d, {"x": np.arange(3)}, step=1)
    names = sorted(p.name for p in d.iterdir())
    assert names == ["step_00000001", "tmp.5.1"]


# ======================= lifecycle snapshotting ======================== #
def test_batcher_state_roundtrip(stack):
    """The scheduler's full lifecycle — queue, resident rows, finished,
    allocator free-list ORDER, counters, remaining deadlines — survives a
    state_dict/load_state roundtrip into a fresh batcher."""
    from repro.serve.batcher import DECODE, Batcher
    _, _, _, lay = stack
    clk = [100.0]
    b = Batcher(lay, n_pages=7, max_batch=2, max_queue=8,
                clock=lambda: clk[0])
    r0 = b.submit(np.arange(12) + 1, 6, priority=1, deadline_s=9.0)
    r1 = b.submit(np.arange(5) + 1, 4)
    r2 = b.submit(np.arange(3) + 1, 2)
    b.admit()
    req0 = next(q for q in b.rows if q is not None and q.rid == r0)
    req0.state = DECODE
    req0.out.extend([7, 8])
    st = b.state_dict()

    clk[0] = 200.0   # restore on a shifted clock: deadlines re-anchor
    b2 = Batcher(lay, n_pages=7, max_batch=2, clock=lambda: clk[0])
    b2.load_state(st)
    q0 = next(q for q in b2.rows if q is not None and q.rid == r0)
    assert q0.state == DECODE and q0.out == [7, 8] and q0.priority == 1
    assert q0.deadline == pytest.approx(209.0)   # 9s remaining, re-anchored
    np.testing.assert_array_equal(
        q0.pages, next(q for q in b.rows if q.rid == r0).pages)
    assert [q.rid for q in b2.queue] == [q.rid for q in b.queue]
    assert b2._next_rid == 3 and r2 in {q.rid for q in b2.queue}
    for a, a2 in zip(b.allocs, b2.allocs):
        assert a._free == a2._free   # order-exact: same future page grants
    assert b2.submit(np.arange(4) + 1, 2) == 3


def test_engine_snapshot_restore_parity(stack, tmp_path):
    """Snapshot mid-flight (rows prefilling AND decoding), push through the
    atomic checkpoint writer, restore into a FRESH engine: the resumed run
    emits exactly the remaining tokens — full outputs match both the
    uninterrupted run and the lockstep oracle (exactly-once emission)."""
    cfg, model, params, lay = stack
    n_new = 8
    prompts = [RNG.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
               for L in (5, 9, 13, 26)]
    refs = _refs(model, params, prompts, n_new)

    eng = _engine(model, lay)
    rids = [eng.submit(p, n_new) for p in prompts]
    for _ in range(5):
        eng.step(params)
    save(tmp_path / "ck", eng.state_dict(), step=5)
    while eng.step(params):
        pass
    uninterrupted = eng.batcher.results()

    from repro.ft import restore
    eng2 = _engine(model, lay)
    eng2.load_state(restore(tmp_path / "ck", eng2.state_dict()))
    assert eng2.counters["engine_steps"] == 5
    while eng2.step(params):
        pass
    resumed = eng2.batcher.results()
    for rid, ref in zip(rids, refs):
        np.testing.assert_array_equal(resumed[rid], uninterrupted[rid])
        np.testing.assert_array_equal(resumed[rid], ref)


def test_supervisor_kill_resume_parity(stack, tmp_path):
    """Injected step crashes mid-serve: the supervisor restores the latest
    snapshot into a rebuilt engine and finishes with token output
    identical to the lockstep oracle; work lost per crash is bounded by
    the checkpoint interval."""
    cfg, model, params, lay = stack
    n_new = 8
    prompts = [RNG.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
               for L in (20, 18, 22)]
    refs = _refs(model, params, prompts, n_new)

    def make_engine():
        eng = _engine(model, lay, max_batch=4)
        for p in prompts:
            eng.submit(p, n_new)
        return eng

    sup = ServeSupervisor(
        make_engine, params, tmp_path / "snap", checkpoint_every=2,
        injector=FaultInjector(FaultPlan(crash_steps=frozenset({3, 6}))))
    eng, hist = sup.run()
    res = eng.batcher.results()
    for rid, ref in zip(sorted(res), refs):
        np.testing.assert_array_equal(res[rid], ref)
    assert hist["restarts"] == 2
    assert hist["max_step_loss"] <= 2   # bounded by checkpoint_every
    assert all(a.n_free == eng.ccfg.n_pages - 1
               for a in eng.batcher.allocs)


def test_supervisor_restart_budget(stack, tmp_path):
    """Crashing on every attempt exhausts the restart budget and raises
    RestartsExhausted instead of looping."""
    cfg, model, params, lay = stack

    def make_engine():
        eng = _engine(model, lay)
        eng.submit(np.arange(4) + 1, 2)
        return eng

    sup = ServeSupervisor(
        make_engine, params, tmp_path / "snap", max_restarts=2,
        injector=FaultInjector(FaultPlan(crash_steps=frozenset(range(50)))))
    with pytest.raises(RestartsExhausted):
        sup.run()


# ================ preemption, admission control, deadlines ============= #
def test_preemption_reprefill_parity(stack):
    """Page pressure with a higher-priority arrival: low-priority decoding
    requests are evicted (pages released, requeued with their emitted
    tokens), the high-priority request runs, and the victims recover via
    chunked re-prefill — every request still matches the lockstep oracle
    token-for-token, nothing double-emitted."""
    cfg, model, params, lay = stack
    n_new = 8
    pa, pb, pc = (RNG.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
                  for L in (20, 18, 22))
    refs = _refs(model, params, [pa, pb, pc], n_new)
    eng = _engine(model, lay, n_pages=1 + 2 * lay.pages_per_req)
    ra = eng.submit(pa, n_new, priority=0)
    rb = eng.submit(pb, n_new, priority=0)
    while True:   # both resident and decoding -> pool fully occupied
        eng.step(params)
        if len(eng.batcher.assemble()[1]) == 2:
            break
    rc = eng.submit(pc, n_new, priority=1)
    res = eng.run(params)
    for rid, ref in zip((ra, rb, rc), refs):
        np.testing.assert_array_equal(res[rid], ref, err_msg=str(rid))
    assert eng.batcher.preemptions >= 1
    victim = next(r for r in eng.batcher.finished.values()
                  if r.preemptions > 0)
    assert victim.priority == 0
    assert all(a.n_free == eng.ccfg.n_pages - 1
               for a in eng.batcher.allocs)


def test_small_footprint_fits_small_pool(stack):
    """Regression of the old drain-time dead-end: a pool smaller than the
    WORST-CASE footprint (pages_per_req) now serves a request whose actual
    span fits (variable footprints) — previously this exact scenario
    raised 'page pool too small' at drain time."""
    cfg, model, params, lay = stack
    eng = _engine(model, lay, n_pages=lay.pages_per_req)  # 2 usable < 3
    prompt = (np.arange(4) + 1).astype(np.int32)
    rid = eng.submit(prompt, 2)    # spans 5 positions -> 1 page
    res = eng.run(params)
    np.testing.assert_array_equal(
        res[rid], _refs(model, params, [prompt], 2)[0])


def test_admission_control_at_submit(stack):
    """Truly oversized requests are rejected AT SUBMIT with a sizing
    message (not discovered at drain time), and a bounded queue applies
    backpressure via QueueFull."""
    cfg, model, _, lay = stack
    eng = _engine(model, lay, n_pages=lay.pages_per_req, max_queue=2)
    with pytest.raises(RejectedRequest, match="can never fit"):
        eng.submit(np.arange(40) + 1, 8)   # needs all 3 pages, pool has 2
    eng.submit(np.arange(4) + 1, 2)
    eng.submit(np.arange(4) + 1, 2)
    with pytest.raises(QueueFull, match="max_queue=2"):
        eng.submit(np.arange(4) + 1, 2)


def test_deadline_expiry_frees_pages(stack):
    """A request past its deadline moves to the failed-with-reason
    terminal state and releases its pages/row; co-resident traffic is
    unaffected and the pool fully recycles."""
    cfg, model, params, lay = stack
    clk = [0.0]
    n_new = 8
    pa, pb = (RNG.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
              for L in (20, 18))
    ref_b = _refs(model, params, [pb], n_new)[0]
    eng = _engine(model, lay, clock=lambda: clk[0])
    rd = eng.submit(pa, n_new, deadline_s=5.0)
    ro = eng.submit(pb, n_new)
    for _ in range(4):
        eng.step(params)
    clk[0] = 10.0   # past rd's deadline mid-decode
    res = eng.run(params)
    assert rd not in res
    assert "deadline expired" in eng.batcher.failures()[rd]
    np.testing.assert_array_equal(res[ro], ref_b)
    assert eng.batcher.expired == 1
    assert all(a.n_free == eng.ccfg.n_pages - 1
               for a in eng.batcher.allocs)


# ========================= fault injection ============================= #
def test_injected_exhaustion_recovery(stack, tmp_path):
    """An injected allocator-exhaustion window (admission sees zero free
    pages): the bare engine raises the RECOVERABLE ResourceExhausted when
    nothing is in flight; under the supervisor the same plan just costs
    restarts — final tokens still match the oracle."""
    cfg, model, params, lay = stack
    n_new = 6
    prompts = [RNG.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
               for L in (7, 12)]
    refs = _refs(model, params, prompts, n_new)

    def make_engine():
        eng = _engine(model, lay)
        for p in prompts:
            eng.submit(p, n_new)
        return eng

    inj = FaultInjector(FaultPlan(exhaust_steps=frozenset({0, 1})))
    eng = make_engine()
    inj.attach(eng)
    inj.before_step(0)
    with pytest.raises(ResourceExhausted, match="admission stalled"):
        eng.step(params)

    sup = ServeSupervisor(
        make_engine, params, tmp_path / "snap",
        injector=FaultInjector(FaultPlan(exhaust_steps=frozenset({0, 1}))))
    eng, hist = sup.run()
    res = eng.batcher.results()
    for rid, ref in zip(sorted(res), refs):
        np.testing.assert_array_equal(res[rid], ref)
    assert hist["restarts"] == 2   # one per exhausted attempt


def test_injected_stragglers_flagged(stack, tmp_path):
    """Straggler injection + the step watchdog: slept steps are counted by
    the injector and flagged by a watchdog fed synthetic step times (the
    EWMA machinery itself is deterministic)."""
    cfg, model, params, lay = stack
    naps = []
    plan = FaultPlan(straggle_steps=frozenset({5}), straggle_s=0.3)
    inj = FaultInjector(plan, sleep=naps.append)

    def make_engine():
        eng = _engine(model, lay)
        eng.submit(RNG.integers(0, cfg.vocab_size, (9,)).astype(np.int32),
                   6)
        return eng

    sup = ServeSupervisor(make_engine, params, tmp_path / "snap",
                          injector=inj)
    sup.run()
    assert inj.injected["stragglers"] == 1 and naps == [0.3]

    wd = StragglerWatchdog(threshold=3.0, warmup_steps=1)
    times = [0.1, 0.1, 0.1, 0.1, 0.9, 0.1]   # one 9x outlier
    assert [wd.observe(t) for t in times].count(True) == 1
    assert wd.events == 1


def test_fault_plan_sampling_deterministic():
    plan1 = FaultPlan.sample(3, 100, crash_rate=0.1, exhaust_rate=0.05)
    plan2 = FaultPlan.sample(3, 100, crash_rate=0.1, exhaust_rate=0.05)
    assert plan1 == plan2
    assert plan1.crash_steps and plan1.crash_steps < frozenset(range(100))


# ===================== sequence-parallel kill/resume =================== #
def test_sharded_kill_resume_parity():
    """8-shard engine under the supervisor: crashes mid-serve, snapshots
    restored into freshly-built sharded engines (mesh re-placement), final
    tokens identical to the single-device uninterrupted run."""
    prog = textwrap.dedent("""
        import os, tempfile
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, numpy as np
        from repro.configs import get_smoke
        from repro.models.model import build_model
        from repro.models.layers import salo_pattern
        from repro.serve.paged_cache import layout_for_pattern
        from repro.serve.engine import ContinuousConfig, ContinuousEngine
        from repro.ft import FaultInjector, FaultPlan, ServeSupervisor

        cfg = get_smoke("smollm-135m")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(1))
        rng = np.random.default_rng(3)
        mesh = jax.make_mesh((8,), ("seq",))
        pat = salo_pattern(cfg, causal=True)
        lens, n_new = (5, 11, 7, 9), 6
        prompts = [rng.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
                   for L in lens]

        l1 = layout_for_pattern(pat, 8)
        e1 = ContinuousEngine(model, ContinuousConfig(
            n_pages=1 + 4 * l1.pages_per_req, page=8, chunk=8, max_batch=4))
        r1 = [e1.submit(p, n_new) for p in prompts]
        ref = e1.run(params)

        l8 = layout_for_pattern(pat, 8, shards=8)
        def mk():
            e = ContinuousEngine(model, ContinuousConfig(
                n_pages=1 + 4 * l8.pages_per_shard, page=8, chunk=8,
                max_batch=4, seq_shards=8), mesh=mesh)
            for p in prompts:
                e.submit(p, n_new)
            return e

        with tempfile.TemporaryDirectory() as d:
            sup = ServeSupervisor(mk, params, d, checkpoint_every=2,
                injector=FaultInjector(
                    FaultPlan(crash_steps=frozenset({3, 6}))))
            e8, hist = sup.run()
        out = e8.batcher.results()
        for a, b in zip(r1, sorted(out)):
            np.testing.assert_array_equal(ref[a], out[b])
        assert hist["restarts"] == 2
        assert hist["max_step_loss"] <= 2
        for al in e8.batcher.allocs:
            assert al.n_free == e8.ccfg.n_pages - 1
        print("SHARDED-KILL-RESUME-OK")
    """)
    r = subprocess.run([sys.executable, "-c", prog],
                       env={**os.environ, "PYTHONPATH": SRC},
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "SHARDED-KILL-RESUME-OK" in r.stdout
