"""Quantized int8 KV slab + stats-driven page-sparse decode, pinned
against the full-precision continuous engine:
  * int8 engine greedy parity vs the fp engine across ring wraparound
    (t >> window), dilation > 1, page-recycling waves, and the paged
    decode kernel (pallas_interpret)
  * quant_slab_write -> gather_view round-trip at the slab level
  * int8 slab resident footprint ~4x under the f32 slab
  * page_sparsity_threshold=-inf (stats machinery ON, keep everything)
    token-identical to the machinery being off — the read-masking-only
    invariant
  * a finite threshold actually skips page reads (counters) at parity
  * the 8-shard int8 + page-sparse engine matches its single-device twin
    (subprocess with 8 forced host devices)
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.models.model import build_model
from repro.serve.engine import ContinuousConfig, ContinuousEngine

RNG = np.random.default_rng(11)
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _engine(cfg, model, *, page=8, chunk=8, max_batch=4, decode_impl="xla",
            kv_dtype="compute", thr=None, decay=0.0):
    from repro.models.layers import salo_pattern
    from repro.serve.paged_cache import layout_for_pattern

    lay = layout_for_pattern(salo_pattern(cfg, causal=True), page)
    return ContinuousEngine(model, ContinuousConfig(
        n_pages=1 + max_batch * lay.pages_per_req, page=page, chunk=chunk,
        max_batch=max_batch, decode_impl=decode_impl, kv_dtype=kv_dtype,
        page_sparsity_threshold=thr, page_stat_decay=decay))


def _prompts(cfg, lens):
    return [RNG.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
            for L in lens]


def _run(eng, params, prompts, n_new):
    rids = [eng.submit(p, n_new) for p in prompts]
    res = eng.run(params)
    return [res[r] for r in rids]


def _assert_parity(a_toks, b_toks):
    for i, (a, b) in enumerate(zip(a_toks, b_toks)):
        np.testing.assert_array_equal(a, b, err_msg=f"request {i}")


# ==================== int8 engine vs fp engine parity ================== #
def test_int8_parity_ring_wraparound():
    """t >> window: many full ring revolutions re-quantize every ring page
    over and over (monotone per-page scale growth + whole-slab rescale);
    greedy tokens stay identical to the fp engine."""
    cfg = get_smoke("smollm-135m")
    cfg = dataclasses.replace(cfg, salo=dataclasses.replace(
        cfg.salo, window=8))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _prompts(cfg, (21, 6))
    n_new = 40  # final t = 60 -> 7+ revolutions past window=8
    ref = _run(_engine(cfg, model, max_batch=2), params, prompts, n_new)
    out = _run(_engine(cfg, model, max_batch=2, kv_dtype="int8"),
               params, prompts, n_new)
    _assert_parity(out, ref)


def test_int8_parity_dilated():
    """dilation > 1: the quantized ring spans the full dilated lookback
    and dequantized reads stay greedy-exact vs the fp engine."""
    cfg = get_smoke("smollm-135m")
    cfg = dataclasses.replace(cfg, salo=dataclasses.replace(
        cfg.salo, window=4, dilation=2, n_global=2))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    prompts = _prompts(cfg, (11, 17))
    ref = _run(_engine(cfg, model, max_batch=2), params, prompts, 10)
    out = _run(_engine(cfg, model, max_batch=2, kv_dtype="int8"),
               params, prompts, 10)
    _assert_parity(out, ref)


def test_int8_parity_page_recycling_waves():
    """More requests than rows: finished requests hand their pages (and
    rows) to waiting ones; recycled pages' scales reset to 0 so the new
    tenant starts on a fresh quantization grid."""
    cfg = get_smoke("smollm-135m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    prompts = _prompts(cfg, (9, 26, 5, 14, 22, 7))
    ref = _run(_engine(cfg, model, max_batch=2), params, prompts, 8)
    eng = _engine(cfg, model, max_batch=2, kv_dtype="int8")
    out = _run(eng, params, prompts, 8)
    _assert_parity(out, ref)
    # the waves really happened: 6 requests through 2 rows
    assert len(eng.batcher.finished) == 6


def test_int8_parity_pallas_interpret():
    """The paged decode kernel (scales scalar-prefetched next to the page
    table, int8 dequantized in-kernel) matches the fp XLA engine."""
    cfg = get_smoke("smollm-135m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    prompts = _prompts(cfg, (7, 12))
    ref = _run(_engine(cfg, model, max_batch=2), params, prompts, 6)
    out = _run(_engine(cfg, model, max_batch=2, kv_dtype="int8",
                       decode_impl="pallas_interpret"),
               params, prompts, 6)
    _assert_parity(out, ref)


# ======================= slab-level invariants ========================= #
def test_quant_slab_write_gather_roundtrip():
    """quant_slab_write (one layer's slab) then a dequantizing gather_view
    approximates the fp slab within the per-page scale bound, and the null
    page reads back exactly zero (scale pinned to 0)."""
    from repro.serve.paged_cache import gather_view, quant_slab_write

    n_pages, page, Hkv, hd = 5, 4, 2, 8
    shape = (n_pages, page, Hkv, hd)
    k8 = jnp.zeros(shape, jnp.int8)
    v8 = jnp.zeros(shape, jnp.int8)
    ks = jnp.zeros((n_pages,), jnp.float32)
    vs = jnp.zeros((n_pages,), jnp.float32)
    fp_k = np.zeros(shape, np.float32)
    fp_v = np.zeros(shape, np.float32)
    writes = ((1, 0), (1, 1), (2, 3), (4, 2), (0, 0))  # incl. null route
    for phys, off in writes:
        k_t = RNG.normal(size=(Hkv, hd)).astype(np.float32) * 2.0
        v_t = RNG.normal(size=(Hkv, hd)).astype(np.float32)
        k8, v8, ks, vs = quant_slab_write(
            k8, v8, ks, vs, jnp.asarray([phys], jnp.int32),
            jnp.asarray([off], jnp.int32), jnp.asarray(k_t)[None],
            jnp.asarray(v_t)[None])
        if phys != 0:  # the null page swallows routed-away writes
            fp_k[phys, off] = k_t
            fp_v[phys, off] = v_t
    pt = jnp.asarray([[0, 1, 2, 4]], jnp.int32)  # null + written pages
    got_k, got_v = gather_view(k8, v8, pt, ks, vs, dtype=jnp.float32)
    want_k, want_v = gather_view(jnp.asarray(fp_k), jnp.asarray(fp_v), pt)
    # per-page bound: scale/2 rounding plus one re-rescale rounding step
    bound = float(jnp.maximum(jnp.max(ks), jnp.max(vs))) + 1e-6
    assert float(jnp.max(jnp.abs(got_k - want_k))) <= bound
    assert float(jnp.max(jnp.abs(got_v - want_v))) <= bound
    assert not np.any(np.asarray(got_k[:, :page]))  # null page all-zero


def test_int8_slab_resident_footprint():
    """int8 slab (K/V int8 + per-(layer, page) f32 scales) sits ~4x under
    the f32 compute-dtype slab for the same pool."""
    cfg = get_smoke("smollm-135m")
    model = build_model(cfg)
    fp = _engine(cfg, model).slab_resident_bytes()
    q8 = _engine(cfg, model, kv_dtype="int8").slab_resident_bytes()
    assert fp / q8 >= 3.5, (fp, q8)


# ==================== stats-driven page sparsity ======================= #
def test_keepall_threshold_exact_vs_none():
    """threshold=-inf turns the stats machinery ON but keeps every page:
    reads are masked (not state), so tokens are bit-identical to
    threshold=None and no page read is ever skipped."""
    cfg = get_smoke("smollm-135m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(4))
    prompts = _prompts(cfg, (9, 26, 5, 14))
    ref = _run(_engine(cfg, model, kv_dtype="int8"), params, prompts, 10)
    eng = _engine(cfg, model, kv_dtype="int8", thr=float("-inf"),
                  decay=0.5)
    out = _run(eng, params, prompts, 10)
    _assert_parity(out, ref)
    assert (eng.counters["decode_pages_read"]
            == eng.counters["decode_pages_total"] > 0)


def test_page_skip_engages_at_parity():
    """A finite threshold with decay > 0 skips real page reads (counters
    prove it) while this workload's greedy tokens stay identical to the
    dense-read int8 engine."""
    cfg = get_smoke("smollm-135m")
    cfg = dataclasses.replace(cfg, salo=dataclasses.replace(
        cfg.salo, window=64))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(5))
    prompts = _prompts(cfg, (24, 17, 9, 30))
    ref = _run(_engine(cfg, model, kv_dtype="int8"), params, prompts, 24)
    eng = _engine(cfg, model, kv_dtype="int8", thr=-3.0, decay=0.3)
    out = _run(eng, params, prompts, 24)
    _assert_parity(out, ref)
    read = eng.counters["decode_pages_read"]
    total = eng.counters["decode_pages_total"]
    assert 0 < read < total, (read, total)


def test_page_skip_zero_decay_never_skips():
    """decay=0 can never skip a page: the history init (0) is the maximum
    possible relative score, so nothing ever falls below a threshold <= 0
    without decay pulling it down."""
    cfg = get_smoke("smollm-135m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(6))
    prompts = _prompts(cfg, (9, 14))
    eng = _engine(cfg, model, max_batch=2, kv_dtype="int8", thr=-0.1,
                  decay=0.0)
    _run(eng, params, prompts, 8)
    assert (eng.counters["decode_pages_read"]
            == eng.counters["decode_pages_total"] > 0)


# ========================= sharded (8 devices) ========================= #
def test_sharded_int8_page_sparse_matches_single_device():
    """8-shard engine, int8 slab + page sparsity: scales stripe with the
    pages, the keep mask comes from merged shard stats, and greedy tokens
    match the single-device engine token-for-token (with pages actually
    skipped on both sides). Subprocess: 8 forced host devices."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, numpy as np
        from repro.configs import get_smoke
        from repro.models.model import build_model
        from repro.models.layers import salo_pattern
        from repro.serve.engine import ContinuousConfig, ContinuousEngine
        from repro.serve.paged_cache import layout_for_pattern

        cfg = get_smoke("smollm-135m")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(11)
        prompts = [rng.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
                   for L in (24, 17, 9, 30)]
        pat = salo_pattern(cfg, causal=True)
        quant = dict(kv_dtype="int8", page_sparsity_threshold=-0.5,
                     page_stat_decay=0.3)
        l1 = layout_for_pattern(pat, 8)
        e1 = ContinuousEngine(model, ContinuousConfig(
            n_pages=1 + 4 * l1.pages_per_req, page=8, chunk=8,
            max_batch=4, **quant))
        r1 = [e1.submit(p, 8) for p in prompts]
        ref = e1.run(params)
        mesh = jax.make_mesh((8,), ("seq",))
        l8 = layout_for_pattern(pat, 8, shards=8)
        e8 = ContinuousEngine(model, ContinuousConfig(
            n_pages=1 + 4 * l8.pages_per_shard, page=8, chunk=8,
            max_batch=4, seq_shards=8, **quant), mesh=mesh)
        r8 = [e8.submit(p, 8) for p in prompts]
        out = e8.run(params)
        for a, b in zip(r1, r8):
            np.testing.assert_array_equal(ref[a], out[b])
        assert e1.counters["decode_pages_read"] < \\
            e1.counters["decode_pages_total"]
        assert e8.counters["decode_pages_read"] < \\
            e8.counters["decode_pages_total"]
        print("QUANT-SHARD-OK")
    """)
    r = subprocess.run([sys.executable, "-c", prog],
                       env={**os.environ, "PYTHONPATH": SRC},
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "QUANT-SHARD-OK" in r.stdout


# ================== stats-driven chunked-prefill reads ================== #
def test_prefill_keepall_counters_and_parity():
    """threshold=-inf through MULTI-CHUNK prefill: the ctx-read mask is on
    but keeps every page — tokens identical to the machinery being off,
    and the prefill page-read counters prove no read was skipped."""
    cfg = get_smoke("smollm-135m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(7))
    prompts = _prompts(cfg, (21, 30))          # > chunk: several chunks each
    ref = _run(_engine(cfg, model, kv_dtype="int8"), params, prompts, 6)
    eng = _engine(cfg, model, kv_dtype="int8", thr=float("-inf"), decay=0.5)
    out = _run(eng, params, prompts, 6)
    _assert_parity(out, ref)
    assert (eng.counters["prefill_pages_read"]
            == eng.counters["prefill_pages_total"] > 0)


def test_prefill_page_skip_engages():
    """Chunked prefill actually skips ctx-page reads once a row's history
    falls below the threshold (driven directly here — fresh requests are
    admitted hot, the PR-6 decode stats populate the history in service):
    the skipped chunk reads only sink + chunk-written pages, and the
    request still completes."""
    cfg = get_smoke("smollm-135m")
    cfg = dataclasses.replace(cfg, salo=dataclasses.replace(
        cfg.salo, window=64))                  # ring spans several pages
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(8))
    eng = _engine(cfg, model, thr=-0.1, decay=0.3, max_batch=1)
    prompt = RNG.integers(0, cfg.vocab_size, (40,)).astype(np.int32)
    rid = eng.submit(prompt, 4)
    eng.step(params)                           # admit + first chunk (hot)
    r0, t0 = (eng.counters["prefill_pages_read"],
              eng.counters["prefill_pages_total"])
    assert r0 == t0 > 0                        # all-zero history: no skip
    req = next(r for r in eng.batcher.rows if r is not None)
    eng.page_hist[req.row, :] = -1.0           # below threshold everywhere
    eng.step(params)                           # next chunk: mask bites
    r1, t1 = (eng.counters["prefill_pages_read"],
              eng.counters["prefill_pages_total"])
    assert r1 - r0 < t1 - t0, (r1 - r0, t1 - t0)
    res = eng.run(params)
    assert res[rid].shape[0] == 4              # completes, emits max_new
