"""End-to-end system behaviour: train -> checkpoint -> crash -> resume ->
serve, through the public launchers (the paths a user actually runs)."""
import os

import numpy as np


def test_train_resume_serve_roundtrip(tmp_path):
    """Train a smoke model, stop it, resume from the checkpoint, verify the
    loss continues from where it left off."""
    from repro.launch.train import main as train_main

    ckpt = str(tmp_path / "ckpt")
    args = ["--arch", "smollm-135m", "--smoke", "--seq", "64", "--batch",
            "4", "--lr", "5e-3", "--ckpt", ckpt, "--ckpt-every", "10",
            "--log-every", "50", "--data-branch", "2", "--data-docs", "4"]
    loss_a = train_main(args + ["--steps", "20"])
    # resume for 10 more steps — must restore step 20's state
    loss_b = train_main(args + ["--steps", "30", "--resume"])
    assert np.isfinite(loss_a) and np.isfinite(loss_b)
    assert loss_b < loss_a + 0.5  # no reset-to-init blowup

    from repro.ft.checkpoint import latest_step
    assert latest_step(ckpt) == 30


def test_training_learns_smoke():
    """The smoke LM must actually learn the synthetic Markov structure."""
    from repro.launch.train import main as train_main
    final = train_main(["--arch", "smollm-135m", "--smoke", "--steps", "60",
                        "--seq", "64", "--batch", "8", "--lr", "1e-2",
                        "--log-every", "30",
                        "--data-branch", "2", "--data-docs", "2"])
    import math
    start = math.log(256)  # smoke vocab
    assert final < start - 1.0, f"loss {final} vs start {start}"


def test_serve_driver_end_to_end():
    from repro.launch.serve import main as serve_main
    toks = serve_main(["--arch", "smollm-135m", "--smoke", "--batch", "2",
                       "--prompt-len", "8", "--new-tokens", "8"])
    assert np.asarray(toks).size == 16


def test_dryrun_single_cell_smoke(tmp_path):
    """The dry-run machinery itself (lower+compile+roofline) on a tiny mesh,
    via a subprocess with forced devices."""
    import subprocess
    import sys
    import textwrap
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        from repro.configs import get_smoke
        from repro.configs.base import ShapeCell
        from repro.launch.specs import build_cell
        from repro.roofline import analysis
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_smoke("gemma-7b")
        for shape in (ShapeCell("t", 64, 4, "train"),
                      ShapeCell("d", 64, 4, "decode")):
            fn, args, in_sh, out_sh, rules = build_cell(cfg, shape, mesh)
            with mesh:
                c = jax.jit(fn, in_shardings=in_sh,
                            out_shardings=out_sh).lower(*args).compile()
            roof = analysis.analyze(c.cost_analysis(), c.as_text(), 8,
                                    analysis.model_flops(cfg, shape))
            assert roof.compute_s > 0 or roof.memory_s > 0
            assert roof.dominant in ("compute", "memory", "collective")
        print("DRYRUN-SMOKE-OK")
    """)
    r = subprocess.run([sys.executable, "-c", prog],
                       env={**os.environ, "PYTHONPATH": src},
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "DRYRUN-SMOKE-OK" in r.stdout
