"""End-to-end training example: ~100M-param smollm-135m with SALO sliding
window attention for a few hundred steps on synthetic Markov data; loss
must drop substantially from the ~ln(V) start.

  PYTHONPATH=src python examples/train_smollm.py [--steps 300]

(Uses the full production path: repro.launch.train with checkpointing +
straggler watchdog. On CPU this takes a few minutes; pass --smoke to run the
reduced config in seconds.)
"""
import argparse
import sys

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    argv = ["--arch", "smollm-135m", "--steps", str(args.steps),
            "--seq", "128", "--batch", "4", "--lr", "1e-2",
            "--data-branch", "2", "--data-docs", "8",
            "--ckpt", "/tmp/salo_smollm_ckpt", "--ckpt-every", "100"]
    if args.smoke:
        argv.append("--smoke")
    final_loss = train_main(argv)
    # start ~= ln(49152) ~= 10.8 (unigram floor over the 4096 active states
    # ~= 8.3); dropping well below the start proves real learning — full
    # convergence toward the ln(2)=0.69 conditional entropy needs more
    # tokens than a CPU example budget allows.
    assert final_loss < 9.0, f"training did not learn: {final_loss}"
    print("training example OK")
