"""Serving example: batched generation with the SALO windowed KV cache,
plus a side-by-side of full-cache vs ring-cache memory for long contexts.

  PYTHONPATH=src python examples/serve_longformer.py
"""
from repro.configs import get_smoke
from repro.launch.serve import main as serve_main
from repro.serve.kv_cache import bytes_per_layer

# 1. generate with the production engine (smoke-size longformer LM)
serve_main(["--arch", "longformer-4k", "--smoke", "--batch", "4",
            "--prompt-len", "24", "--new-tokens", "24"])

# 2. the paper's serving payoff: O(window) cache vs O(context) cache
cfg = get_smoke("longformer-4k")
for ctx in (32_768, 524_288):
    full = bytes_per_layer(1, ctx, 8, 128)
    ring = bytes_per_layer(1, ctx, 8, 128, window=4096, n_global=4)
    print(f"context {ctx:>7d}: full cache {full/1e6:8.1f} MB/layer, "
          f"SALO ring cache {ring/1e6:6.1f} MB/layer "
          f"({full/ring:.0f}x smaller)")
print("serving example OK")
