"""ViL example (paper Table 2 rows 2-3): 2-D windowed attention on an image
patch grid, reproducing the stage-1/stage-2 attention layers and their
sparsity/utilization numbers.

  PYTHONPATH=src:. python examples/vil_2d_attention.py
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
import jax.numpy as jnp
import numpy as np

from benchmarks.salo_cycle_model import attention_cycles
from repro.configs.vil import VIL_STAGE1, VIL_STAGE2
from repro.core import hybrid_attention

rng = np.random.default_rng(0)
for name, stage in (("stage1", VIL_STAGE1), ("stage2", VIL_STAGE2)):
    pat = stage["pattern"]
    n = pat.seq_len()
    d_head = 64
    heads = stage["hidden"] // d_head
    q, k, v = (jnp.asarray(rng.normal(size=(1, heads, n, d_head)),
                           jnp.float32) for _ in range(3))
    out = hybrid_attention(q, k, v, pat, block_q=64, block_k=64)
    ref = hybrid_attention(q, k, v, pat, impl="dense_ref")
    err = float(jnp.max(jnp.abs(out - ref)))
    cyc = attention_cycles(pat, n, d_head, heads)
    print(f"ViL-{name}: grid={stage['grid']} n={n} heads={heads} "
          f"sparsity={pat.sparsity(n):.3f} err={err:.1e} "
          f"salo_latency={cyc['latency_s']*1e6:.0f}us "
          f"util={cyc['utilization']:.2f}")
print("ViL example OK")
